//! End-to-end driver (the mandated E2E validation): plan with the robust
//! optimizer, then **serve real batched requests** through the three-layer
//! stack — rust coordinator → PJRT CPU executables ← JAX/Pallas AOT
//! artifacts — and report latency/throughput/violations.
//!
//! Requires `make artifacts` to have produced `artifacts/manifest.json`.
//!
//! ```bash
//! make artifacts && cargo run --release --example serve_edge
//! ```

use std::time::Duration;

use ripra::coordinator::{self, ServeOptions};
use ripra::engine::{PlanRequest, PlannerBuilder, Policy};
use ripra::models::manifest::Manifest;
use ripra::models::ModelProfile;
use ripra::optim::Scenario;
use ripra::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let artifacts = Manifest::default_dir();
    anyhow::ensure!(
        artifacts.join("manifest.json").exists(),
        "artifacts not built — run `make artifacts` first"
    );

    for (model, bandwidth, deadline, risk) in [
        (ModelProfile::alexnet_paper(), 10e6, 0.20, 0.02),
        (ModelProfile::resnet152_paper(), 30e6, 0.16, 0.04),
    ] {
        println!("=== {} ===", model.name);
        let mut rng = Rng::new(1234);
        let sc = Scenario::uniform(&model, 6, bandwidth, deadline, risk, &mut rng);

        // L3 planning: the engine facade (Algorithm 2 under the hood).
        let mut planner = PlannerBuilder::new().build();
        let plan = planner
            .plan(&PlanRequest::new(sc.clone(), Policy::Robust))
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
        println!(
            "plan: partition={:?}  energy={:.4} J  ({} outer iters)",
            plan.plan.partition, plan.energy, plan.diagnostics.outer_iters
        );

        // Serve: device agents run the *real* compiled device parts, the
        // edge VM pool batches the real edge parts (vLLM-style window).
        // time_scale 1.0: model time == wall time, so wall scheduling
        // noise is not amplified in the report.  On a single-core host
        // (like CI) the p99 still carries OS-scheduler tails — p50/mean
        // are the meaningful numbers; see EXPERIMENTS.md §E2E.
        let opts = ServeOptions {
            model: model.name.clone(),
            requests_per_device: 15,
            arrival_rate_hz: 5.0,
            batch_window: Duration::from_millis(6),
            max_batch: 8,
            time_scale: 1.0,
            seed: 99,
        };
        let rep = coordinator::serve(artifacts.clone(), &sc, &plan.plan, &opts)?;
        println!(
            "served {} requests in {:.2} s wall  ->  {:.1} req/s",
            rep.completed,
            rep.wall_time.as_secs_f64(),
            rep.throughput_rps
        );
        println!(
            "model-time latency: mean {:.1} ms | p50 {:.1} ms | p99 {:.1} ms  \
             (deadline {:.0} ms, violations {}/{})",
            rep.mean_latency_s * 1e3,
            rep.p50_latency_s * 1e3,
            rep.p99_latency_s * 1e3,
            deadline * 1e3,
            rep.violations,
            rep.completed
        );
        println!(
            "PJRT wall times: device part {:.2} ms, edge part {:.2} ms; \
             mean edge batch {:.2}; modeled energy {:.3} J\n",
            rep.mean_device_exec_s * 1e3,
            rep.mean_edge_exec_s * 1e3,
            rep.mean_batch,
            rep.total_energy_j
        );
    }
    Ok(())
}
