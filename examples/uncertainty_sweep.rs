//! Distribution-robustness sweep: the ECR guarantee is distribution-free,
//! so the violation probability must stay under ε for *every* jitter
//! family with the profiled mean/variance — including the adversarial
//! heavy-one-sided-tail shifted-exponential.
//!
//! Also demonstrates graceful degradation: what happens when the true
//! variance exceeds the profiled one (model misspecification).
//!
//! ```bash
//! cargo run --release --example uncertainty_sweep
//! ```

use ripra::engine::{PlanRequest, Planner, Policy};
use ripra::models::ModelProfile;
use ripra::optim::Scenario;
use ripra::profile::Dist;
use ripra::sim::{self, SimOptions};
use ripra::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let mut planner = Planner::default();
    for model in [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()] {
        let (b, d, eps) = ripra::figures::default_setting(&model.name);
        let mut rng = Rng::new(11);
        let sc = Scenario::uniform(&model, 8, b, d + 0.02, eps, &mut rng);
        let plan = planner
            .plan(&PlanRequest::new(sc.clone(), Policy::Robust))
            .map_err(|e| anyhow::anyhow!(e.to_string()))?
            .plan;

        println!("=== {} (eps = {eps}) ===", model.name);
        for dist in [Dist::Lognormal, Dist::Gamma, Dist::ShiftedExp] {
            let rep = sim::evaluate(&sc, &plan, &SimOptions { trials: 20_000, dist, seed: 3 });
            println!(
                "  {dist:?}: worst violation {:.4}  mean latency {:.1} ms  p99 {:.1} ms",
                rep.worst_violation,
                rep.mean_latency[0] * 1e3,
                rep.p99_latency[0] * 1e3
            );
            assert!(rep.worst_violation <= eps, "{dist:?} broke the guarantee");
        }

        // Misspecification: inflate the true variance 2x beyond what the
        // planner was told.  The Cantelli bound degrades gracefully: the
        // violation can exceed eps but stays in the same order.
        let mut inflated = sc.clone();
        for dev in &mut inflated.devices {
            for p in &mut dev.model.points {
                p.v_loc_s2 *= 2.0;
            }
        }
        let rep = sim::evaluate(&inflated, &plan, &SimOptions { trials: 20_000, ..Default::default() });
        println!(
            "  2x variance misspecification: violation {:.4} (eps {eps}) — \
             degrades but does not explode\n",
            rep.worst_violation
        );
    }
    Ok(())
}
