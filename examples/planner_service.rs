//! Sharded multi-tenant planner service walkthrough: admit two tenant
//! fleets across 4 planner shards, push a coalescible burst of deltas
//! through the bounded queue, churn membership to trigger the
//! load-factor rebalancer, and print the service/cache counters.
//!
//! Run with `cargo run --release --example planner_service`.
//! Equivalent fleet-level CLI: `ripra simulate --shards 4 --json`.

use ripra::channel::Uplink;
use ripra::engine::ScenarioDelta;
use ripra::models::ModelProfile;
use ripra::optim::types::{Device, Scenario};
use ripra::service::{PlannerService, ServiceError, ServiceOptions};

fn device(distance_m: f64, deadline_s: f64) -> Device {
    Device {
        model: ModelProfile::alexnet_paper(),
        uplink: Uplink::from_distance(distance_m),
        deadline_s,
        risk: 0.05,
    }
}

fn fleet(distances: &[f64], bandwidth_hz: f64, deadline_s: f64) -> Scenario {
    Scenario {
        devices: distances.iter().map(|&d| device(d, deadline_s)).collect(),
        total_bandwidth_hz: bandwidth_hz,
    }
}

fn main() -> anyhow::Result<()> {
    let mut svc = PlannerService::new(ServiceOptions {
        shards: 4,
        queue_capacity: 8,
        load_factor: 1.25,
        ..ServiceOptions::default()
    })
    .map_err(|e| anyhow::anyhow!(e.to_string()))?;

    // Two independent tenants, routed device-by-device across the shards.
    let a = fleet(&[60.0, 120.0, 180.0, 240.0, 300.0], 14e6, 0.25);
    let b = fleet(&[90.0, 150.0, 210.0], 10e6, 0.28);
    let out_a = svc.admit_tenant(1, a).map_err(|e| anyhow::anyhow!(e.to_string()))?;
    let out_b = svc.admit_tenant(2, b).map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!("admitted tenant 1: energy {:.4} J over shards {:?}", out_a.energy_j,
        svc.device_shards(1).unwrap());
    println!("admitted tenant 2: energy {:.4} J over shards {:?}", out_b.energy_j,
        svc.device_shards(2).unwrap());
    println!("shard loads: {:?} (bound {})", svc.shard_loads(), svc.current_load_bound());

    // A burst of channel jitter + bandwidth renegotiation: the later
    // writes cover the earlier ones, so the drain coalesces the batch.
    let gain = svc.assembled_scenario(1).unwrap().devices[0].uplink;
    for delta in [
        ScenarioDelta::TotalBandwidth(12e6),
        ScenarioDelta::Channel { device: 0, uplink: Uplink::from_gain_db(gain.gain_db() - 0.5) },
        ScenarioDelta::TotalBandwidth(13e6),
        ScenarioDelta::Channel { device: 0, uplink: Uplink::from_gain_db(gain.gain_db() - 1.0) },
    ] {
        svc.submit(1, delta).map_err(|e| anyhow::anyhow!(e.to_string()))?;
    }
    let outs = svc.drain();
    let applied = outs.iter().filter(|o| o.shard_ops > 0).count();
    println!("burst of {} deltas drained as {} shard passes (coalesced {})",
        outs.len(), applied, outs.len() - applied);

    // Membership churn: joins spread by fingerprint + load bound.
    for step in 0..3 {
        let joiner = device(100.0 + 40.0 * step as f64, 0.25);
        svc.submit(1, ScenarioDelta::Join(joiner))
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    }
    for out in svc.drain() {
        println!("join → {:?}, tenant energy {:.4} J, {} newton iters",
            out.disposition, out.energy_j, out.newton_iters);
    }
    println!("shard loads after churn: {:?} (bound {})",
        svc.shard_loads(), svc.current_load_bound());

    // Backpressure: the bounded queue refuses loudly when full.
    let mut refused = 0;
    for i in 0..12 {
        match svc.submit(2, ScenarioDelta::TotalBandwidth(10e6 + i as f64 * 1e4)) {
            Ok(()) => {}
            Err(ServiceError::Backpressure { capacity }) => {
                refused += 1;
                if refused == 1 {
                    println!("queue full at capacity {capacity}: refusing (never dropping)");
                }
            }
            Err(e) => return Err(anyhow::anyhow!(e.to_string())),
        }
    }
    svc.drain();

    let s = svc.stats();
    let c = svc.cache_stats();
    println!(
        "stats: {} submitted, {} refused, {} superseded, {} shard ops \
         ({} replans, {} cache hits, {} rebases), {} rebalance moves",
        s.submitted, s.refused, s.superseded, s.shard_ops, s.replans, s.cache_hits,
        s.rebases, s.rebalance_moves
    );
    println!("aggregated plan caches: {} hits / {} misses ({} entries)", c.hits, c.misses, c.len);
    Ok(())
}
