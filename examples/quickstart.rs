//! Quickstart: plan a small edge-intelligence scenario with the robust
//! optimizer and sanity-check the probabilistic guarantee by Monte Carlo.
//!
//! ```bash
//! cargo run --release --example quickstart
//! ```

use ripra::engine::{PlanRequest, PlannerBuilder, Policy as PlanPolicy};
use ripra::models::ModelProfile;
use ripra::optim::{Policy, Scenario};
use ripra::sim::{self, SimOptions};
use ripra::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    // 6 mobile devices running AlexNet on (synthetic) Jetson CPUs, one
    // edge node, 10 MHz of uplink, 200 ms deadline, 5% tolerated risk.
    let model = ModelProfile::alexnet_paper();
    let mut rng = Rng::new(42);
    let sc = Scenario::uniform(&model, 6, 10e6, 0.20, 0.05, &mut rng);

    // The engine facade runs Algorithm 2 (CCP/ECR + interior-point
    // resources + PCCP partitioning) behind one entrypoint.
    let mut planner = PlannerBuilder::new().build();
    let result = planner
        .plan(&PlanRequest::new(sc.clone(), PlanPolicy::Robust))
        .map_err(|e| anyhow::anyhow!(e.to_string()))?;
    println!("expected total device energy: {:.4} J", result.energy);
    println!("converged in {} outer iterations; trajectory: {:?}",
        result.diagnostics.outer_iters,
        result.diagnostics.trajectory.iter().map(|e| format!("{e:.3}")).collect::<Vec<_>>());

    println!("\n dev   partition m   bandwidth    frequency   ECR margin");
    for i in 0..sc.n() {
        let d = &sc.devices[i];
        let (m, f, b) =
            (result.plan.partition[i], result.plan.freq_ghz[i], result.plan.bandwidth_hz[i]);
        println!(
            "  {:>2}   {:>11}   {:>7.3} MHz   {:>6.3} GHz   {:>7.2} ms",
            i,
            m,
            b / 1e6,
            f,
            d.deadline_margin(m, f, b, Policy::ROBUST) * 1e3
        );
    }

    // The guarantee: P{latency > D} <= eps for ANY distribution with the
    // profiled mean/variance.  Check empirically on three families.
    println!("\nMonte-Carlo check (20k trials per distribution):");
    for dist in [
        ripra::profile::Dist::Lognormal,
        ripra::profile::Dist::Gamma,
        ripra::profile::Dist::ShiftedExp,
    ] {
        let rep = sim::evaluate(
            &sc,
            &result.plan,
            &SimOptions { trials: 20_000, dist, seed: 1 },
        );
        println!(
            "  {dist:?}: worst violation {:.4} (risk level {}), mean energy {:.4} J",
            rep.worst_violation, sc.devices[0].risk, rep.mean_energy
        );
        assert!(rep.worst_violation <= sc.devices[0].risk);
    }
    println!("\nguarantee holds: violation <= risk level on every family");
    Ok(())
}
