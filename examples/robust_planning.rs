//! Policy comparison across risk levels: the paper's central trade-off.
//!
//! Sweeps ε and compares the robust policy against the worst-case and
//! mean-only baselines on (a) planned energy and (b) empirical violation
//! probability — i.e. a compact reproduction of Fig. 13(a)+(c) with all
//! three policies on one axis.
//!
//! ```bash
//! cargo run --release --example robust_planning
//! ```

use ripra::engine::{PlanRequest, PlannerBuilder, Policy};
use ripra::models::ModelProfile;
use ripra::optim::Scenario;
use ripra::sim::{self, SimOptions};
use ripra::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let model = ModelProfile::alexnet_paper();
    println!("AlexNet, N=10, B=10 MHz, D=190 ms — energy & violation vs risk level\n");
    println!(
        "{:>6} | {:>10} {:>10} {:>10} | {:>9} {:>9} {:>9}",
        "eps", "robust_J", "worst_J", "mean_J", "viol_rob", "viol_wc", "viol_mean"
    );
    // One planner dispatches all three policies through the same path.
    let mut planner = PlannerBuilder::new().build();
    for eps in [0.02, 0.04, 0.06, 0.08] {
        let mut rng = Rng::new(7);
        let sc = Scenario::uniform(&model, 10, 10e6, 0.19, eps, &mut rng);
        let mut plan_with = |policy: Policy| {
            planner
                .plan(&PlanRequest::new(sc.clone(), policy))
                .map_err(|e| anyhow::anyhow!(e.to_string()))
        };
        let rob = plan_with(Policy::Robust)?;
        let wc = plan_with(Policy::WorstCase)?;
        let mean = plan_with(Policy::MeanOnly)?;

        let opts = SimOptions { trials: 10_000, ..Default::default() };
        let v_rob = sim::evaluate(&sc, &rob.plan, &opts).worst_violation;
        let v_wc = sim::evaluate(&sc, &wc.plan, &opts).worst_violation;
        let v_mean = sim::evaluate(&sc, &mean.plan, &opts).worst_violation;

        println!(
            "{:>6} | {:>10.4} {:>10.4} {:>10.4} | {:>9.4} {:>9.4} {:>9.4}",
            eps, rob.energy, wc.energy, mean.energy, v_rob, v_wc, v_mean
        );
        assert!(v_rob <= eps, "robust guarantee broken");
    }
    println!(
        "\nreading: mean-only is cheapest but violates deadlines freely;\n\
         worst-case never violates but wastes energy; the robust policy\n\
         pays exactly for the guarantee the user asked for (viol <= eps)."
    );
    Ok(())
}
