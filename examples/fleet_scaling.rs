//! Fleet scaling: how the planner and its decisions behave as the device
//! population grows (Fig. 11/12 flavour, plus decision-mix reporting that
//! the paper doesn't show but operators want).
//!
//! ```bash
//! cargo run --release --example fleet_scaling
//! ```

use ripra::engine::{PlanRequest, PlannerBuilder, Policy};
use ripra::models::ModelProfile;
use ripra::optim::Scenario;
use ripra::util::rng::Rng;

fn main() -> anyhow::Result<()> {
    let model = ModelProfile::alexnet_paper();
    println!("AlexNet, D=200 ms, eps=0.02, B scales as N/12 * 10 MHz\n");
    println!(
        "{:>4} {:>10} {:>10} {:>12} {:>10} {:>24}",
        "N", "energy_J", "J_per_dev", "runtime_s", "pccp_iter", "partition histogram"
    );
    // One long-lived planner for the whole fleet sweep: its Newton
    // workspace stays warm across scales.
    let mut planner = PlannerBuilder::new().build();
    for n in [4, 8, 12, 16, 20, 24, 30] {
        let b = 10e6 * (n as f64 / 12.0).max(1.0);
        let mut rng = Rng::new(5);
        let sc = Scenario::uniform(&model, n, b, 0.20, 0.02, &mut rng);
        let r = planner
            .plan(&PlanRequest::new(sc, Policy::Robust))
            .map_err(|e| anyhow::anyhow!(e.to_string()))?;

        let mut hist = vec![0usize; model.num_points()];
        for &m in &r.plan.partition {
            hist[m] += 1;
        }
        let hist_s = hist
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(m, c)| format!("m{m}:{c}"))
            .collect::<Vec<_>>()
            .join(" ");
        println!(
            "{:>4} {:>10.4} {:>10.4} {:>12.3} {:>10.2} {:>24}",
            n,
            r.energy,
            r.energy / n as f64,
            r.diagnostics.wall_time.as_secs_f64(),
            r.diagnostics.avg_pccp_iters,
            hist_s
        );
    }
    println!(
        "\nreading: runtime grows ~linearly in N (per-device PCCP + one joint\n\
         IPT), per-device energy stays flat once bandwidth scales with N."
    );
    Ok(())
}
