//! Fleet churn walkthrough: drive one long-lived planner through a
//! seeded stream of device joins/leaves, Gauss–Markov channel fades, and
//! deadline/risk renegotiations, and watch the engine's incremental
//! machinery (plan cache, warm replans, cold fallbacks) absorb them.
//!
//! ```bash
//! cargo run --release --example fleet_churn
//! ```
//!
//! Equivalent CLI: `ripra simulate --duration 20 --arrival-rate 0.4
//! --churn 1.5 --seed 7` (add `--json` for the machine-readable series).

use ripra::fleet::{self, FleetOptions};

fn main() -> anyhow::Result<()> {
    let opts = FleetOptions {
        n0: 5,
        duration_s: 20.0,
        arrival_rate_hz: 0.4,
        churn: 1.5,
        trials: 500,
        seed: 7,
        ..FleetOptions::default()
    };
    println!(
        "fleet churn: model={}, n0={}, {:.0}s, arrivals {:.1}/s, churn x{:.1}, seed {}\n",
        opts.model.name, opts.n0, opts.duration_s, opts.arrival_rate_hz, opts.churn, opts.seed
    );
    let rep = fleet::run(&opts).map_err(|e| anyhow::anyhow!(e.to_string()))?;

    println!(
        "{:>7}  {:<11} {:>3}  {:<10} {:>7} {:>10}  {:>9}",
        "t_s", "event", "n", "served by", "newton", "energy_J", "viol-eps"
    );
    let shown = 25usize;
    for st in rep.metrics.steps().iter().take(shown) {
        let served = if st.absorbed {
            "absorbed"
        } else if !st.accepted {
            "rejected"
        } else if st.cache_hit {
            "cache"
        } else if st.warm_started {
            "warm"
        } else {
            "cold"
        };
        let energy = st.energy_j.map_or("-".into(), |e| format!("{e:.4}"));
        let viol = st.violation_excess.map_or("-".into(), |v| format!("{v:+.4}"));
        println!(
            "{:>7.3}  {:<11} {:>3}  {:<10} {:>7} {:>10}  {:>9}",
            st.t_s, st.kind, st.n, served, st.newton_iters, energy, viol
        );
    }
    if rep.metrics.steps().len() > shown {
        println!("   ... {} more steps", rep.metrics.steps().len() - shown);
    }

    let s = rep.metrics.summary();
    println!(
        "\nsummary: {} events ({} accepted / {} rejected / {} absorbed); \
         {} cache hits + {} warm replans + {} cold solves",
        s.events, s.accepted, s.rejected, s.absorbed, s.cache_hits, s.warm_replans, s.cold_solves
    );
    println!(
        "cache hit rate {:.1}%; {} Newton iterations total; mean planned energy {:.4} J",
        100.0 * s.cache_hit_rate,
        s.newton_total,
        s.mean_energy_j
    );
    if let Some(w) = s.worst_violation_excess {
        println!(
            "Monte-Carlo: worst violation excess over eps {w:+.4} \
             (<= 0 means every device met its risk level)"
        );
    }
    println!(
        "\nreading: fades inside the fingerprint's 0.1 dB bucket are served\n\
         from the plan cache for free; the rest cost a few warm Newton\n\
         iterations; only infeasibility-triggering events pay a cold solve."
    );
    Ok(())
}
