//! Minimal JSON parser + writer (no serde available offline).
//!
//! Supports the full JSON grammar we emit from `python/compile/aot.py`
//! (objects, arrays, strings with escapes, numbers, bools, null) plus a
//! pretty writer used by the figures harness to dump results.  Object key
//! order is preserved (Vec of pairs) so emitted files diff cleanly.

use std::fmt;

/// A parsed JSON value.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

/// Parse error with byte offset context.
#[derive(Debug)]
pub struct ParseError {
    pub offset: usize,
    pub message: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.offset, self.message)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, ParseError> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- accessors ---------------------------------------------------------

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(pairs) => pairs.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest loading uses this so a
    /// schema drift fails loudly instead of silently defaulting.
    pub fn expect(&self, key: &str) -> Result<&Json, String> {
        self.get(key).ok_or_else(|| format!("missing key {key:?}"))
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        match self {
            // lint:allow(float-eq): fract() == 0.0 is an exact integrality test
            Json::Num(x) if *x >= 0.0 && x.fract() == 0.0 => Some(*x as usize),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(xs) => Some(xs),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&[(String, Json)]> {
        match self {
            Json::Obj(pairs) => Some(pairs),
            _ => None,
        }
    }

    pub fn usize_array(&self) -> Option<Vec<usize>> {
        self.as_arr()?.iter().map(Json::as_usize).collect()
    }

    pub fn f64_array(&self) -> Option<Vec<f64>> {
        self.as_arr()?.iter().map(Json::as_f64).collect()
    }

    // -- writer ------------------------------------------------------------

    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, true);
        out
    }

    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, 0, false);
        out
    }

    /// Compact serialization appended to a caller-owned buffer — the
    /// allocation-free sibling of [`Json::to_string_compact`] for hot
    /// paths that encode many values (the wire server reuses one buffer
    /// per connection).  `out` is *not* cleared first.
    pub fn write_compact_into(&self, out: &mut String) {
        self.write(out, 0, false);
    }

    fn write(&self, out: &mut String, indent: usize, pretty: bool) {
        let pad = |out: &mut String, n: usize| {
            if pretty {
                out.push('\n');
                for _ in 0..n {
                    out.push(' ');
                }
            }
        };
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                // lint:allow(float-eq): fract() is exact — this is the
                // standard integer-valued test, not a tolerance check.
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    out.push_str(&format!("{}", *x as i64));
                } else {
                    out.push_str(&format!("{x}"));
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(xs) => {
                out.push('[');
                for (i, x) in xs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    x.write(out, indent + 1, pretty);
                }
                if !xs.is_empty() {
                    pad(out, indent);
                }
                out.push(']');
            }
            Json::Obj(pairs) => {
                out.push('{');
                for (i, (k, v)) in pairs.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    pad(out, indent + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if pretty {
                        out.push(' ');
                    }
                    v.write(out, indent + 1, pretty);
                }
                if !pairs.is_empty() {
                    pad(out, indent);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { offset: self.pos, message: msg.to_string() }
    }

    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected {:?}", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected {word}")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a value")),
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.eat(b'[')?;
        let mut xs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(xs));
        }
        loop {
            self.skip_ws();
            xs.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(xs));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.eat(b'{')?;
        let mut pairs = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(pairs));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            self.skip_ws();
            let val = self.value()?;
            pairs.push((key, val));
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(pairs));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("unterminated string"))?;
            self.pos += 1;
            match c {
                b'"' => return Ok(s),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("bad escape"))?;
                    self.pos += 1;
                    match e {
                        b'"' => s.push('"'),
                        b'\\' => s.push('\\'),
                        b'/' => s.push('/'),
                        b'b' => s.push('\u{8}'),
                        b'f' => s.push('\u{c}'),
                        b'n' => s.push('\n'),
                        b'r' => s.push('\r'),
                        b't' => s.push('\t'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| self.err("short \\u escape"))?;
                            let code = u32::from_str_radix(
                                std::str::from_utf8(hex)
                                    .map_err(|_| self.err("bad \\u escape"))?,
                                16,
                            )
                            .map_err(|_| self.err("bad \\u escape"))?;
                            self.pos += 4;
                            // Surrogate pairs: JSON encodes astral chars as
                            // two \u escapes.
                            let ch = if (0xD800..0xDC00).contains(&code) {
                                if self.bytes.get(self.pos) == Some(&b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    let hex2 = self
                                        .bytes
                                        .get(self.pos + 2..self.pos + 6)
                                        .ok_or_else(|| self.err("short surrogate"))?;
                                    let low = u32::from_str_radix(
                                        std::str::from_utf8(hex2)
                                            .map_err(|_| self.err("bad surrogate"))?,
                                        16,
                                    )
                                    .map_err(|_| self.err("bad surrogate"))?;
                                    self.pos += 6;
                                    0x10000 + ((code - 0xD800) << 10) + (low - 0xDC00)
                                } else {
                                    return Err(self.err("lone surrogate"));
                                }
                            } else {
                                code
                            };
                            s.push(
                                char::from_u32(ch)
                                    .ok_or_else(|| self.err("invalid codepoint"))?,
                            );
                        }
                        _ => return Err(self.err("unknown escape")),
                    }
                }
                c => {
                    // Collect the full UTF-8 sequence.
                    let start = self.pos - 1;
                    let len = utf8_len(c);
                    self.pos = start + len;
                    let chunk = self
                        .bytes
                        .get(start..start + len)
                        .ok_or_else(|| self.err("truncated utf-8"))?;
                    s.push_str(
                        std::str::from_utf8(chunk)
                            .map_err(|_| self.err("invalid utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        // The scanned range is ASCII digits/signs/dots, but propagate
        // instead of unwrapping so the parser is panic-free end to end.
        std::str::from_utf8(&self.bytes[start..self.pos])
            .map_err(|_| self.err("invalid number"))?
            .parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("-2.5e3").unwrap(), Json::Num(-2500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("c").unwrap().as_str().unwrap(), "x\ny");
        let arr = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(arr[1].as_f64().unwrap(), 2.0);
        assert_eq!(arr[2].get("b").unwrap(), &Json::Null);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn roundtrip_pretty_and_compact() {
        let src = r#"{"m": [1, 2.5, true], "s": "a\"b", "n": null}"#;
        let v = Json::parse(src).unwrap();
        for text in [v.to_string_pretty(), v.to_string_compact()] {
            assert_eq!(Json::parse(&text).unwrap(), v);
        }
    }

    #[test]
    fn unicode_escapes() {
        let v = Json::parse(r#""é😀""#).unwrap();
        assert_eq!(v.as_str().unwrap(), "é😀");
        // literal utf-8 passes through
        let v = Json::parse("\"héllo\"").unwrap();
        assert_eq!(v.as_str().unwrap(), "héllo");
    }

    #[test]
    fn integers_print_without_fraction() {
        assert_eq!(Json::Num(3.0).to_string_compact(), "3");
        assert_eq!(Json::Num(3.25).to_string_compact(), "3.25");
    }

    #[test]
    fn parses_real_manifest_if_present() {
        let path = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts/manifest.json");
        if let Ok(text) = std::fs::read_to_string(path) {
            let v = Json::parse(&text).expect("manifest must parse");
            assert!(v.get("models").is_some());
        }
    }
}
