//! Scoped-thread fan-out for embarrassingly parallel planner loops
//! (per-device PCCP solves, the alternation's polish sweep).
//!
//! No external thread-pool crate is available offline, so this is a tiny
//! work-stealing harness on `std::thread::scope`: workers pull job
//! indices from a shared atomic counter and results land in pre-sized
//! slots, so the output order — and therefore every downstream fold — is
//! **deterministic**, independent of scheduling.  Worker panics propagate
//! to the caller.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Resolve a thread-count preference: 0 = all available cores, otherwise
/// the preference itself; never more threads than jobs, never zero.
pub fn threads_for(pref: usize, jobs: usize) -> usize {
    let hw = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let t = if pref == 0 { hw } else { pref };
    t.min(jobs).max(1)
}

/// Evaluate `f(0..jobs)` across `threads` scoped workers and return the
/// results in index order.  `threads <= 1` runs inline (no spawn), which
/// is also the reference sequential order — results are identical either
/// way because each job is independent and slot placement is by index.
pub fn par_map_indexed<T, F>(jobs: usize, threads: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    par_map_indexed_with(jobs, threads, || (), |_: &mut (), i| f(i))
}

/// [`par_map_indexed`] with per-worker scratch state: every worker calls
/// `init` once and threads the state through all jobs it steals (e.g. a
/// `NewtonWorkspace` reused across a sweep's barrier solves, making the
/// per-job hot path allocation-free after each worker's first job).
pub fn par_map_indexed_with<S, T, I, F>(jobs: usize, threads: usize, init: I, f: F) -> Vec<T>
where
    T: Send,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize) -> T + Sync,
{
    if threads <= 1 || jobs <= 1 {
        let mut state = init();
        return (0..jobs).map(|i| f(&mut state, i)).collect();
    }
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = Vec::with_capacity(jobs);
    slots.resize_with(jobs, || None);
    std::thread::scope(|scope| {
        let f = &f;
        let init = &init;
        let next = &next;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut state = init();
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs {
                            break;
                        }
                        out.push((i, f(&mut state, i)));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            // Re-raise a worker panic with its original payload so a
            // threaded failure diagnoses like the same failure inline.
            let worker = match h.join() {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            };
            for (i, v) in worker {
                slots[i] = Some(v);
            }
        }
    });
    // lint:allow(panic-path): every index 0..jobs is claimed exactly once
    // by a worker, so all slots are filled by construction.
    slots.into_iter().map(|v| v.expect("parallel slot unfilled")).collect()
}

/// Evaluate `f(&mut items[i], i)` for every `i` across `threads` scoped
/// workers and return the results in index order.  Each index is claimed
/// exactly once from a shared atomic counter, so every worker holds an
/// exclusive `&mut` to a distinct element — the service layer uses this
/// to fan independent planner shards out without wrapping them in locks.
/// `threads <= 1` runs inline in ascending index order, which is also the
/// reference order (jobs are independent, slots are placed by index).
pub fn par_map_indexed_mut<S, T, F>(items: &mut [S], threads: usize, f: F) -> Vec<T>
where
    S: Send,
    T: Send,
    F: Fn(&mut S, usize) -> T + Sync,
{
    let jobs = items.len();
    if threads <= 1 || jobs <= 1 {
        return items.iter_mut().enumerate().map(|(i, s)| f(s, i)).collect();
    }
    /// Shared base pointer into `items`; sound because the atomic counter
    /// hands each index to exactly one worker, so no element is ever
    /// aliased mutably.
    struct Base<S>(*mut S);
    unsafe impl<S: Send> Sync for Base<S> {}
    let base = Base(items.as_mut_ptr());
    let next = AtomicUsize::new(0);
    let mut slots: Vec<Option<T>> = Vec::with_capacity(jobs);
    slots.resize_with(jobs, || None);
    std::thread::scope(|scope| {
        let f = &f;
        let next = &next;
        let base = &base;
        let handles: Vec<_> = (0..threads)
            .map(|_| {
                scope.spawn(move || {
                    let mut out = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= jobs {
                            break;
                        }
                        // SAFETY: `i < jobs` and each `i` is produced by
                        // the counter exactly once, so this is the only
                        // live reference to `items[i]`.
                        let item = unsafe { &mut *base.0.add(i) };
                        out.push((i, f(item, i)));
                    }
                    out
                })
            })
            .collect();
        for h in handles {
            let worker = match h.join() {
                Ok(v) => v,
                Err(payload) => std::panic::resume_unwind(payload),
            };
            for (i, v) in worker {
                slots[i] = Some(v);
            }
        }
    });
    // lint:allow(panic-path): every index 0..jobs is claimed exactly once
    // by a worker, so all slots are filled by construction.
    slots.into_iter().map(|v| v.expect("parallel slot unfilled")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_are_in_index_order() {
        for threads in [1, 2, 4, 8] {
            let out = par_map_indexed(37, threads, |i| i * i);
            assert_eq!(out, (0..37).map(|i| i * i).collect::<Vec<_>>(), "threads={threads}");
        }
    }

    #[test]
    fn handles_empty_and_single() {
        assert!(par_map_indexed(0, 4, |i| i).is_empty());
        assert_eq!(par_map_indexed(1, 4, |i| i + 10), vec![10]);
    }

    #[test]
    fn per_worker_state_is_reused_within_a_worker() {
        // Each worker's state counts its own jobs; results stay indexed.
        for threads in [1, 3] {
            let out = par_map_indexed_with(
                20,
                threads,
                || 0usize,
                |seen, i| {
                    *seen += 1;
                    (i, *seen >= 1)
                },
            );
            assert_eq!(out.len(), 20, "threads={threads}");
            for (idx, (i, counted)) in out.iter().enumerate() {
                assert_eq!(*i, idx);
                assert!(counted);
            }
        }
    }

    #[test]
    fn mut_fan_out_mutates_every_item_exactly_once() {
        for threads in [1, 2, 4] {
            let mut items: Vec<u64> = (0..23).collect();
            let out = par_map_indexed_mut(&mut items, threads, |v, i| {
                *v += 100;
                (*v, i)
            });
            assert_eq!(items, (100..123).collect::<Vec<_>>(), "threads={threads}");
            for (idx, (v, i)) in out.iter().enumerate() {
                assert_eq!(*i, idx);
                assert_eq!(*v, 100 + idx as u64);
            }
        }
        let mut empty: Vec<u64> = Vec::new();
        assert!(par_map_indexed_mut(&mut empty, 4, |_, i| i).is_empty());
    }

    #[test]
    fn threads_for_clamps() {
        assert_eq!(threads_for(3, 100), 3);
        assert_eq!(threads_for(8, 2), 2);
        assert_eq!(threads_for(5, 0), 1);
        assert!(threads_for(0, 100) >= 1);
    }
}
