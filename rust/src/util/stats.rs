//! Statistics substrate: streaming moments, covariance, percentiles,
//! histograms.  Used by the profiler (§IV mean/var/cov estimation), the
//! Monte-Carlo simulator, and the serving metrics.

/// Welford streaming mean/variance accumulator (numerically stable).
#[derive(Clone, Debug, Default)]
pub struct Moments {
    n: u64,
    mean: f64,
    m2: f64,
    min: f64,
    max: f64,
}

impl Moments {
    pub fn new() -> Self {
        Moments { n: 0, mean: 0.0, m2: 0.0, min: f64::INFINITY, max: f64::NEG_INFINITY }
    }

    pub fn push(&mut self, x: f64) {
        self.n += 1;
        let d = x - self.mean;
        self.mean += d / self.n as f64;
        self.m2 += d * (x - self.mean);
        self.min = self.min.min(x);
        self.max = self.max.max(x);
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn mean(&self) -> f64 {
        self.mean
    }

    /// Population variance (the paper's v = E[(t - t̄)²]).
    pub fn variance(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.m2 / self.n as f64 }
    }

    /// Unbiased sample variance.
    pub fn sample_variance(&self) -> f64 {
        if self.n < 2 { 0.0 } else { self.m2 / (self.n - 1) as f64 }
    }

    pub fn std(&self) -> f64 {
        self.variance().sqrt()
    }

    pub fn min(&self) -> f64 {
        self.min
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    pub fn merge(&mut self, other: &Moments) {
        if other.n == 0 {
            return;
        }
        if self.n == 0 {
            *self = other.clone();
            return;
        }
        let n = (self.n + other.n) as f64;
        let d = other.mean - self.mean;
        let mean = self.mean + d * other.n as f64 / n;
        self.m2 += other.m2 + d * d * self.n as f64 * other.n as f64 / n;
        self.mean = mean;
        self.n += other.n;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// Streaming covariance of a pair (the paper's w_{m,m'} estimator, eq. 12).
#[derive(Clone, Debug, Default)]
pub struct Covariance {
    n: u64,
    mean_x: f64,
    mean_y: f64,
    cxy: f64,
}

impl Covariance {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn push(&mut self, x: f64, y: f64) {
        self.n += 1;
        let dx = x - self.mean_x;
        self.mean_x += dx / self.n as f64;
        self.mean_y += (y - self.mean_y) / self.n as f64;
        self.cxy += dx * (y - self.mean_y);
    }

    /// Population covariance E[xy] - E[x]E[y].
    pub fn covariance(&self) -> f64 {
        if self.n == 0 { 0.0 } else { self.cxy / self.n as f64 }
    }

    pub fn count(&self) -> u64 {
        self.n
    }
}

/// Percentile over a sample (linear interpolation, like numpy's default).
pub fn percentile(sorted: &[f64], q: f64) -> f64 {
    assert!(!sorted.is_empty(), "percentile of empty sample");
    assert!((0.0..=100.0).contains(&q));
    let h = (sorted.len() - 1) as f64 * q / 100.0;
    let lo = h.floor() as usize;
    let hi = h.ceil() as usize;
    sorted[lo] + (sorted[hi] - sorted[lo]) * (h - lo as f64)
}

/// Sort + percentile convenience.
pub fn percentile_of(xs: &[f64], q: f64) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    percentile(&s, q)
}

/// Fixed-bin histogram for latency reporting.
#[derive(Clone, Debug)]
pub struct Histogram {
    lo: f64,
    hi: f64,
    bins: Vec<u64>,
    under: u64,
    over: u64,
}

impl Histogram {
    pub fn new(lo: f64, hi: f64, nbins: usize) -> Self {
        assert!(hi > lo && nbins > 0);
        Histogram { lo, hi, bins: vec![0; nbins], under: 0, over: 0 }
    }

    pub fn push(&mut self, x: f64) {
        if x < self.lo {
            self.under += 1;
        } else if x >= self.hi {
            self.over += 1;
        } else {
            let n = self.bins.len();
            let idx = ((x - self.lo) / (self.hi - self.lo) * n as f64) as usize;
            self.bins[idx.min(n - 1)] += 1;
        }
    }

    pub fn total(&self) -> u64 {
        self.bins.iter().sum::<u64>() + self.under + self.over
    }

    pub fn bins(&self) -> &[u64] {
        &self.bins
    }

    pub fn overflow(&self) -> (u64, u64) {
        (self.under, self.over)
    }
}

/// Ordinary least squares for y = a + b x (used for sanity fits in figures).
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    assert_eq!(xs.len(), ys.len());
    let n = xs.len() as f64;
    let sx: f64 = xs.iter().sum();
    let sy: f64 = ys.iter().sum();
    let sxx: f64 = xs.iter().map(|x| x * x).sum();
    let sxy: f64 = xs.iter().zip(ys).map(|(x, y)| x * y).sum();
    let b = (n * sxy - sx * sy) / (n * sxx - sx * sx);
    let a = (sy - b * sx) / n;
    (a, b)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn moments_basic() {
        let mut m = Moments::new();
        for x in [2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0] {
            m.push(x);
        }
        assert_eq!(m.count(), 8);
        assert!((m.mean() - 5.0).abs() < 1e-12);
        assert!((m.variance() - 4.0).abs() < 1e-12);
        assert_eq!(m.min(), 2.0);
        assert_eq!(m.max(), 9.0);
    }

    #[test]
    fn moments_merge_equals_sequential() {
        let xs: Vec<f64> = (0..100).map(|i| (i as f64).sin() * 3.0 + 1.0).collect();
        let mut all = Moments::new();
        xs.iter().for_each(|&x| all.push(x));
        let mut a = Moments::new();
        let mut b = Moments::new();
        xs[..37].iter().for_each(|&x| a.push(x));
        xs[37..].iter().for_each(|&x| b.push(x));
        a.merge(&b);
        assert!((a.mean() - all.mean()).abs() < 1e-12);
        assert!((a.variance() - all.variance()).abs() < 1e-12);
    }

    #[test]
    fn covariance_matches_definition() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        let ys = [2.0, 4.0, 5.0, 9.0];
        let mut c = Covariance::new();
        for (x, y) in xs.iter().zip(&ys) {
            c.push(*x, *y);
        }
        let mx = xs.iter().sum::<f64>() / 4.0;
        let my = ys.iter().sum::<f64>() / 4.0;
        let want =
            xs.iter().zip(&ys).map(|(x, y)| (x - mx) * (y - my)).sum::<f64>() / 4.0;
        assert!((c.covariance() - want).abs() < 1e-12);
    }

    #[test]
    fn percentiles() {
        let xs = [1.0, 2.0, 3.0, 4.0, 5.0];
        assert_eq!(percentile(&xs, 0.0), 1.0);
        assert_eq!(percentile(&xs, 50.0), 3.0);
        assert_eq!(percentile(&xs, 100.0), 5.0);
        assert_eq!(percentile(&xs, 25.0), 2.0);
    }

    #[test]
    fn histogram_counts() {
        let mut h = Histogram::new(0.0, 10.0, 10);
        for x in [-1.0, 0.0, 0.5, 5.0, 9.99, 10.0, 42.0] {
            h.push(x);
        }
        assert_eq!(h.total(), 7);
        assert_eq!(h.overflow(), (1, 2));
        assert_eq!(h.bins()[0], 2);
        assert_eq!(h.bins()[5], 1);
        assert_eq!(h.bins()[9], 1);
    }

    #[test]
    fn linear_fit_recovers_line() {
        let xs: Vec<f64> = (0..20).map(|i| i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| 3.0 + 2.0 * x).collect();
        let (a, b) = linear_fit(&xs, &ys);
        assert!((a - 3.0).abs() < 1e-9 && (b - 2.0).abs() < 1e-9);
    }
}
