//! Criterion-like micro-bench harness (criterion is not available offline).
//!
//! Used by `rust/benches/*.rs` (compiled with `harness = false`): warm-up,
//! adaptive iteration count targeting a fixed measurement window, then
//! median / mean / p95 over per-iteration wall time.  Prints one line per
//! benchmark in a stable, grep-friendly format:
//!
//! `bench <name> ... median 1.234 ms  mean 1.300 ms  p95 1.600 ms  (n=1000)`

use std::time::{Duration, Instant};

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub min: Duration,
}

/// Bench runner with a fixed time budget per benchmark.
pub struct Bencher {
    warmup: Duration,
    window: Duration,
    max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            window: Duration::from_secs(1),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    /// Shorter windows for expensive end-to-end benches.
    pub fn with_window(mut self, warmup: Duration, window: Duration) -> Self {
        self.warmup = warmup;
        self.window = window;
        self
    }

    pub fn with_max_iters(mut self, n: u64) -> Self {
        self.max_iters = n;
        self
    }

    /// Measure `f`, using `black_box` on whatever it returns.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up phase (fills caches, triggers lazy init, JIT-ish effects).
        let start = Instant::now();
        let mut warm_iters: u64 = 0;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }

        // Measurement: per-iteration timing, capped by window + max_iters.
        let mut samples_ns: Vec<u64> = Vec::with_capacity(1024);
        let meas = Instant::now();
        while meas.elapsed() < self.window && (samples_ns.len() as u64) < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as u64);
        }
        if samples_ns.is_empty() {
            // pathological: one mandatory sample
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as u64);
        }
        samples_ns.sort_unstable();
        let n = samples_ns.len();
        let pick = |q: f64| samples_ns[((n - 1) as f64 * q) as usize];
        let mean_ns = samples_ns.iter().sum::<u64>() / n as u64;
        let result = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            median: Duration::from_nanos(pick(0.5)),
            mean: Duration::from_nanos(mean_ns),
            p95: Duration::from_nanos(pick(0.95)),
            min: Duration::from_nanos(samples_ns[0]),
        };
        println!(
            "bench {:<44} median {:>12}  mean {:>12}  p95 {:>12}  (n={})",
            result.name,
            fmt_dur(result.median),
            fmt_dur(result.mean),
            fmt_dur(result.p95),
            result.iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }
}

/// Human duration with 3 significant decimals and a sensible unit.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.3} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new().with_window(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let r = b
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..1000 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            })
            .clone();
        assert!(r.iters >= 1);
        assert!(r.median >= r.min);
        assert!(r.p95 >= r.median);
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.500 ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }
}
