//! Criterion-like micro-bench harness (criterion is not available offline).
//!
//! Used by `rust/benches/*.rs` (compiled with `harness = false`): warm-up,
//! adaptive iteration count targeting a fixed measurement window, then
//! median / mean / p95 over per-iteration wall time.  Prints one line per
//! benchmark in a stable, grep-friendly format:
//!
//! `bench <name> ... median 1.234 ms  mean 1.300 ms  p95 1.600 ms  (n=1000)`
//!
//! Results (plus any [`Bencher::attach`]ed scalars such as Newton/PCCP
//! iteration counts) can be merged into a machine-readable JSON file with
//! [`Bencher::write_json`] — `BENCH_planner.json` at the repo root is the
//! perf trajectory future PRs diff against (see EXPERIMENTS.md §Perf).

use std::path::Path;
use std::time::{Duration, Instant};

use super::json::Json;

/// One measured benchmark result.
#[derive(Clone, Debug)]
pub struct BenchResult {
    pub name: String,
    pub iters: u64,
    pub median: Duration,
    pub mean: Duration,
    pub p95: Duration,
    pub min: Duration,
    /// Attached scalars ((key, value), e.g. iteration counts) emitted
    /// alongside the timings in the JSON record.
    pub extra: Vec<(String, f64)>,
}

/// Bench runner with a fixed time budget per benchmark.
pub struct Bencher {
    warmup: Duration,
    window: Duration,
    max_iters: u64,
    results: Vec<BenchResult>,
}

impl Default for Bencher {
    fn default() -> Self {
        Self::new()
    }
}

impl Bencher {
    pub fn new() -> Self {
        Bencher {
            warmup: Duration::from_millis(200),
            window: Duration::from_secs(1),
            max_iters: 1_000_000,
            results: Vec::new(),
        }
    }

    /// Shorter windows for expensive end-to-end benches.  A no-op in
    /// smoke mode (see [`Bencher::smoke_requested`]), so bench mains can
    /// chain it unconditionally.
    pub fn with_window(mut self, warmup: Duration, window: Duration) -> Self {
        if self.max_iters == 1 {
            return self;
        }
        self.warmup = warmup;
        self.window = window;
        self
    }

    /// CI smoke mode was requested: the bench binary was invoked with
    /// `--test` (what `cargo bench -- --test` forwards) or with
    /// `BENCH_SMOKE=1` in the environment.
    pub fn smoke_requested() -> bool {
        std::env::args().any(|a| a == "--test") || std::env::var_os("BENCH_SMOKE").is_some()
    }

    /// A one-iteration bencher: no warm-up window, exactly one measured
    /// sample per benchmark.  Exercises every bench body and the JSON
    /// merge end-to-end in seconds — the numbers are not meaningful and
    /// CI's smoke artifact must not be merged into a real trajectory.
    pub fn smoke() -> Self {
        Bencher::new().with_window(Duration::ZERO, Duration::ZERO).with_max_iters(1)
    }

    /// [`Bencher::smoke`] when smoke mode is requested, otherwise a
    /// default bencher (tune it with [`Bencher::with_window`]).
    pub fn auto() -> Self {
        if Self::smoke_requested() {
            Self::smoke()
        } else {
            Self::new()
        }
    }

    pub fn with_max_iters(mut self, n: u64) -> Self {
        self.max_iters = n;
        self
    }

    /// Measure `f`, using `black_box` on whatever it returns.
    pub fn bench<T, F: FnMut() -> T>(&mut self, name: &str, mut f: F) -> &BenchResult {
        // Warm-up phase (fills caches, triggers lazy init, JIT-ish effects).
        let start = Instant::now();
        let mut warm_iters: u64 = 0;
        while start.elapsed() < self.warmup && warm_iters < self.max_iters {
            std::hint::black_box(f());
            warm_iters += 1;
        }

        // Measurement: per-iteration timing, capped by window + max_iters.
        let mut samples_ns: Vec<u64> = Vec::with_capacity(1024);
        let meas = Instant::now();
        while meas.elapsed() < self.window && (samples_ns.len() as u64) < self.max_iters
        {
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as u64);
        }
        if samples_ns.is_empty() {
            // pathological: one mandatory sample
            let t0 = Instant::now();
            std::hint::black_box(f());
            samples_ns.push(t0.elapsed().as_nanos() as u64);
        }
        samples_ns.sort_unstable();
        let n = samples_ns.len();
        let pick = |q: f64| samples_ns[((n - 1) as f64 * q) as usize];
        let mean_ns = samples_ns.iter().sum::<u64>() / n as u64;
        let result = BenchResult {
            name: name.to_string(),
            iters: n as u64,
            median: Duration::from_nanos(pick(0.5)),
            mean: Duration::from_nanos(mean_ns),
            p95: Duration::from_nanos(pick(0.95)),
            min: Duration::from_nanos(samples_ns[0]),
            extra: Vec::new(),
        };
        println!(
            "bench {:<44} median {:>12}  mean {:>12}  p95 {:>12}  (n={})",
            result.name,
            fmt_dur(result.median),
            fmt_dur(result.mean),
            fmt_dur(result.p95),
            result.iters
        );
        self.results.push(result);
        self.results.last().unwrap()
    }

    pub fn results(&self) -> &[BenchResult] {
        &self.results
    }

    /// Attach a named scalar (iteration count, configuration, derived
    /// metric) to the most recent result named `name`.
    pub fn attach(&mut self, name: &str, key: &str, value: f64) {
        if let Some(r) = self.results.iter_mut().rev().find(|r| r.name == name) {
            r.extra.push((key.to_string(), value));
        }
    }

    /// Merge every recorded result into a JSON file of the shape
    /// `{"benches": {"<name>": {"median_ns": …, …}}}`.
    ///
    /// Entries from previous runs (or from other bench binaries sharing
    /// the file) are preserved unless re-recorded here, so
    /// `cargo bench --bench solvers && cargo bench --bench planner_scaling`
    /// accumulate into one trajectory file.
    pub fn write_json(&self, path: &Path) -> std::io::Result<()> {
        // Round-trip the existing root object so sibling keys (commit/env
        // metadata added by other tooling) survive the merge.  An existing
        // file that fails to parse is an error, not a silent restart —
        // the file's purpose is cross-run accumulation.
        let mut root: Vec<(String, Json)> = match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(e),
            Ok(text) => {
                let invalid = |why: String| {
                    std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!(
                            "refusing to overwrite {}: {why}; delete it to start a fresh \
                             trajectory",
                            path.display()
                        ),
                    )
                };
                let parsed = Json::parse(&text)
                    .map_err(|e| invalid(format!("existing file is not valid JSON ({e})")))?;
                parsed
                    .as_obj()
                    .map(|o| o.to_vec())
                    .ok_or_else(|| invalid("existing JSON root is not an object".to_string()))?
            }
        };
        let mut entries: Vec<(String, Json)> = match root.iter().find(|(k, _)| k == "benches") {
            None => Vec::new(),
            Some((_, b)) => b.as_obj().map(|o| o.to_vec()).ok_or_else(|| {
                std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!(
                        "refusing to overwrite {}: existing \"benches\" value is not an object",
                        path.display()
                    ),
                )
            })?,
        };
        for r in &self.results {
            let mut obj = vec![
                ("median_ns".to_string(), Json::Num(r.median.as_nanos() as f64)),
                ("mean_ns".to_string(), Json::Num(r.mean.as_nanos() as f64)),
                ("p95_ns".to_string(), Json::Num(r.p95.as_nanos() as f64)),
                ("min_ns".to_string(), Json::Num(r.min.as_nanos() as f64)),
                ("iters".to_string(), Json::Num(r.iters as f64)),
            ];
            for (k, v) in &r.extra {
                obj.push((k.clone(), Json::Num(*v)));
            }
            let val = Json::Obj(obj);
            match entries.iter_mut().find(|(n, _)| *n == r.name) {
                Some(e) => e.1 = val,
                None => entries.push((r.name.clone(), val)),
            }
        }
        let benches = Json::Obj(entries);
        match root.iter_mut().find(|(k, _)| k == "benches") {
            Some(e) => e.1 = benches,
            None => root.push(("benches".to_string(), benches)),
        }
        std::fs::write(path, Json::Obj(root).to_string_pretty())
    }
}

/// Human duration with 3 significant decimals and a sensible unit.
pub fn fmt_dur(d: Duration) -> String {
    let ns = d.as_nanos() as f64;
    if ns < 1_000.0 {
        format!("{ns:.0} ns")
    } else if ns < 1_000_000.0 {
        format!("{:.3} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.3} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_measures_something() {
        let mut b = Bencher::new().with_window(
            Duration::from_millis(5),
            Duration::from_millis(20),
        );
        let r = b
            .bench("spin", || {
                let mut acc = 0u64;
                for i in 0..1000 {
                    acc = acc.wrapping_add(i * i);
                }
                acc
            })
            .clone();
        assert!(r.iters >= 1);
        assert!(r.median >= r.min);
        assert!(r.p95 >= r.median);
    }

    #[test]
    fn smoke_mode_takes_exactly_one_sample_and_ignores_window_tuning() {
        let win = Duration::from_secs(60);
        let mut b = Bencher::smoke().with_window(win, win);
        let t0 = Instant::now();
        let r = b.bench("one_shot", || 42u64).clone();
        assert_eq!(r.iters, 1);
        assert!(t0.elapsed() < Duration::from_secs(5), "smoke mode must not honor windows");
    }

    #[test]
    fn fmt_dur_units() {
        assert_eq!(fmt_dur(Duration::from_nanos(500)), "500 ns");
        assert_eq!(fmt_dur(Duration::from_micros(1500)), "1.500 ms");
        assert!(fmt_dur(Duration::from_secs(2)).ends_with(" s"));
    }

    #[test]
    fn write_json_merges_across_runs() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("ripra_bench_test_{}.json", std::process::id()));
        let _ = std::fs::remove_file(&path);

        let fast = Duration::from_millis(1);
        let mut b = Bencher::new().with_window(fast, fast).with_max_iters(3);
        b.bench("first", || 1u64);
        b.attach("first", "newton_iters", 42.0);
        b.write_json(&path).unwrap();

        let mut b2 = Bencher::new().with_window(fast, fast).with_max_iters(3);
        b2.bench("second", || 2u64);
        b2.write_json(&path).unwrap();

        let j = crate::util::json::Json::parse(&std::fs::read_to_string(&path).unwrap()).unwrap();
        let benches = j.get("benches").unwrap();
        let first = benches.get("first").unwrap();
        assert_eq!(first.get("newton_iters").and_then(|v| v.as_f64()), Some(42.0));
        assert!(first.get("median_ns").and_then(|v| v.as_f64()).is_some());
        assert!(benches.get("second").is_some());
        let _ = std::fs::remove_file(&path);
    }
}
