//! Tiny property-testing harness (proptest is not available offline).
//!
//! `forall` runs a property over `cases` randomly generated inputs; on
//! failure it performs a simple halving "shrink" over the generator seed
//! trail and reports the seed so the failure replays deterministically:
//!
//! ```
//! use ripra::util::check::forall;
//! forall("bandwidth conserved", 200, |rng| {
//!     let b = rng.range(0.1, 10.0);
//!     if !(b > 0.0) { return Err(format!("b={b}")); }
//!     Ok(())
//! });
//! ```

use super::rng::Rng;

/// Run `prop` over `cases` random inputs.  Panics (test failure) with the
/// failing seed + message on the first counterexample.
pub fn forall<F>(name: &str, cases: usize, mut prop: F)
where
    F: FnMut(&mut Rng) -> Result<(), String>,
{
    // Deterministic base seed per property name so failures reproduce
    // across runs without flag plumbing; override with RIPRA_CHECK_SEED.
    let base = std::env::var("RIPRA_CHECK_SEED")
        .ok()
        .and_then(|s| s.parse::<u64>().ok())
        .unwrap_or_else(|| fnv1a(name.as_bytes()));
    for case in 0..cases {
        let seed = base.wrapping_add(case as u64);
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng) {
            // lint:allow(panic-path): property-test harness — a
            // counterexample must abort the enclosing #[test].
            panic!(
                "property {name:?} failed at case {case}/{cases} \
                 (replay: RIPRA_CHECK_SEED={seed}): {msg}"
            );
        }
    }
}

/// FNV-1a — stable, dependency-free hash for seed derivation.
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x1000_0000_01b3);
    }
    h
}

/// Assert |a - b| <= atol + rtol*|b| with a useful message.
pub fn close(a: f64, b: f64, rtol: f64, atol: f64) -> Result<(), String> {
    if (a - b).abs() <= atol + rtol * b.abs() {
        Ok(())
    } else {
        Err(format!("{a} !~ {b} (diff {})", (a - b).abs()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn forall_passes_trivial_property() {
        forall("uniform in range", 100, |rng| {
            let x = rng.f64();
            if (0.0..1.0).contains(&x) {
                Ok(())
            } else {
                Err(format!("{x} out of range"))
            }
        });
    }

    #[test]
    #[should_panic(expected = "property")]
    fn forall_reports_counterexample() {
        forall("always fails eventually", 50, |rng| {
            if rng.f64() < 0.9 {
                Ok(())
            } else {
                Err("hit".into())
            }
        });
    }

    #[test]
    fn close_tolerances() {
        assert!(close(1.0, 1.0 + 1e-9, 1e-6, 0.0).is_ok());
        assert!(close(1.0, 1.1, 1e-6, 0.0).is_err());
        assert!(close(0.0, 1e-9, 0.0, 1e-6).is_ok());
    }

    #[test]
    fn fnv_is_stable() {
        assert_eq!(fnv1a(b"abc"), fnv1a(b"abc"));
        assert_ne!(fnv1a(b"abc"), fnv1a(b"abd"));
    }
}
