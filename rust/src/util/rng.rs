//! Deterministic PRNG + sampling substrate.
//!
//! No `rand` crate is available offline, so we carry our own generator:
//! xoshiro256++ seeded through SplitMix64 (the reference construction from
//! Blackman & Vigna).  Everything downstream (profiling jitter, Monte-Carlo
//! violation estimation, workload generation, property tests) draws from
//! this, so runs are reproducible from a single `u64` seed.

/// xoshiro256++ generator.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// Seed via SplitMix64 so that low-entropy seeds (0, 1, 2, ...) still
    /// produce well-mixed states.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E37_79B9_7F4A_7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    /// Derive an independent stream (used to give each device/agent its own
    /// generator without sharing state across threads).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = (self.s[0].wrapping_add(self.s[3]))
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1) with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free variant is overkill here;
        // the simple 128-bit multiply keeps bias < 2^-64.
        // lint:allow(rng-truncation): the shift keeps the high 64 bits —
        // a range reduction to [0, n), not a truncation of the draw.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal (Marsaglia polar method; cached second deviate
    /// intentionally dropped to keep the generator state minimal).
    pub fn normal(&mut self) -> f64 {
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                return u * (-2.0 * s.ln() / s).sqrt();
            }
        }
    }

    /// Normal with given mean/std.
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Log-normal with given *target* mean and variance of the resulting
    /// distribution (i.e. we solve for the underlying mu/sigma).
    pub fn lognormal_mv(&mut self, mean: f64, var: f64) -> f64 {
        debug_assert!(mean > 0.0 && var >= 0.0);
        // lint:allow(float-eq): var == 0.0 is an exact caller-passed
        // sentinel meaning "degenerate point mass", not a computed value.
        if var == 0.0 {
            return mean;
        }
        let sigma2 = (1.0 + var / (mean * mean)).ln();
        let mu = mean.ln() - 0.5 * sigma2;
        (mu + sigma2.sqrt() * self.normal()).exp()
    }

    /// Gamma(shape k, scale theta) via Marsaglia–Tsang.
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        debug_assert!(k > 0.0 && theta > 0.0);
        if k < 1.0 {
            // boost: Gamma(k) = Gamma(k+1) * U^{1/k}
            let u = self.f64().max(f64::MIN_POSITIVE);
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64();
            if u < 1.0 - 0.0331 * x.powi(4)
                || u.ln() < 0.5 * x * x + d * (1.0 - v + v.ln())
            {
                return d * v * theta;
            }
        }
    }

    /// Gamma parameterised by its mean and variance.
    pub fn gamma_mv(&mut self, mean: f64, var: f64) -> f64 {
        if var <= 0.0 {
            return mean;
        }
        let k = mean * mean / var;
        let theta = var / mean;
        self.gamma(k, theta)
    }

    /// Shuffle a slice in place (Fisher–Yates).
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            xs.swap(i, self.below(i + 1));
        }
    }

    /// Exponential with given rate.
    pub fn exponential(&mut self, rate: f64) -> f64 {
        debug_assert!(rate > 0.0);
        -(1.0 - self.f64()).ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_from_seed() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let (mut a, mut b) = (Rng::new(1), Rng::new(2));
        assert_ne!(
            (0..4).map(|_| a.next_u64()).collect::<Vec<_>>(),
            (0..4).map(|_| b.next_u64()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.005, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 0.005, "var={var}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(11);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean={mean}");
        assert!((var - 1.0).abs() < 0.02, "var={var}");
    }

    #[test]
    fn lognormal_matches_target_moments() {
        let mut r = Rng::new(13);
        let (m, v) = (5.0, 2.5);
        let n = 400_000;
        let xs: Vec<f64> = (0..n).map(|_| r.lognormal_mv(m, v)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - m).abs() / m < 0.02, "mean={mean}");
        assert!((var - v).abs() / v < 0.06, "var={var}");
        assert!(xs.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn gamma_matches_target_moments() {
        let mut r = Rng::new(17);
        let (m, v) = (3.0, 1.2);
        let n = 400_000;
        let xs: Vec<f64> = (0..n).map(|_| r.gamma_mv(m, v)).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - m).abs() / m < 0.02, "mean={mean}");
        assert!((var - v).abs() / v < 0.06, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Rng::new(23);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            seen[r.below(7)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(29);
        let n = 200_000;
        let s: f64 = (0..n).map(|_| r.exponential(2.0)).sum();
        assert!((s / n as f64 - 0.5).abs() < 0.01);
    }
}
