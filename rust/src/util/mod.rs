//! Shared substrate: PRNG, JSON, statistics, property-check harness, the
//! micro-bench runner, and the scoped-thread fan-out helper (offline
//! environment: no rand/serde/proptest/criterion/rayon crates — these
//! modules replace them).

pub mod bench;
pub mod check;
pub mod json;
pub mod par;
pub mod rng;
pub mod stats;
