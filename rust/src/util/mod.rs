//! Shared substrate: PRNG, JSON, statistics, property-check harness, and
//! the micro-bench runner (offline environment: no rand/serde/proptest/
//! criterion crates — these modules replace them).

pub mod bench;
pub mod check;
pub mod json;
pub mod rng;
pub mod stats;
