//! Lexical source model for `ripra-lint`.
//!
//! The lint deliberately avoids a real Rust parser (no new dependencies):
//! every rule works on a *stripped* view of the source in which comments,
//! string literals, and char literals are blanked out (replaced by spaces,
//! positions preserved), so token scans never fire inside prose or data.
//! On top of that the scanner tracks which lines live inside
//! `#[cfg(test)]` / `#[test]` items (rules exempt test code) and parses
//! the `// lint:allow(...)` suppression comments.
//!
//! The model is lexical, not syntactic: it understands nested block
//! comments, raw strings (`r#"..."#`), and the char-literal/lifetime
//! ambiguity, which is all the repo's rules need.

/// A parsed `lint:allow` comment (well-formed or not).
#[derive(Clone, Debug)]
pub struct Allow {
    /// Rule ids named in the comment.
    pub rules: Vec<String>,
    /// Mandatory justification after the `:`.
    pub reason: String,
    /// 1-based line of the comment itself.
    pub line: usize,
    /// 1-based line the allow applies to: the same line for a trailing
    /// comment, the next line containing code for a standalone one.
    /// Ignored for file-level allows.
    pub target: usize,
    /// `lint:allow-file(...)` — suppresses the rule for the whole file.
    pub file_level: bool,
    /// Set when the comment could not be parsed (missing reason, bad
    /// syntax); the `bad-allow` rule reports these.
    pub malformed: Option<String>,
}

/// One source file with its stripped view and test-span map.
pub struct SourceFile {
    /// Path relative to the scan root, `/`-separated (e.g.
    /// `fleet/driver.rs`).
    pub path: String,
    /// Raw source lines (used by extraction helpers that need string
    /// literal *contents*, e.g. the CLI-flag registry).
    pub raw: Vec<String>,
    /// Comment- and literal-stripped lines, same length and column
    /// positions as `raw`.
    pub code: Vec<String>,
    /// `true` for lines inside a `#[cfg(test)]` / `#[test]` item.
    pub in_test: Vec<bool>,
    /// All `lint:allow` comments found in the file.
    pub allows: Vec<Allow>,
}

impl SourceFile {
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let raw: Vec<String> = text.lines().map(str::to_string).collect();
        let (code, comments) = strip(text);
        debug_assert_eq!(raw.len(), code.len());
        let in_test = test_spans(&code);
        let mut allows = Vec::new();
        for (idx, comment) in comments {
            if let Some(a) = parse_allow(&comment, idx + 1, &code) {
                allows.push(a);
            }
        }
        SourceFile { path: path.to_string(), raw, code, in_test, allows }
    }

    /// Stripped line by 1-based number (empty when out of range).
    pub fn code_line(&self, line: usize) -> &str {
        self.code.get(line - 1).map(String::as_str).unwrap_or("")
    }

    /// Is the 1-based line inside test code?
    pub fn is_test_line(&self, line: usize) -> bool {
        self.in_test.get(line - 1).copied().unwrap_or(false)
    }
}

/// Strip comments and literals.  Returns the stripped lines plus every
/// `//` comment's text keyed by 0-based line (for allow parsing).
fn strip(text: &str) -> (Vec<String>, Vec<(usize, String)>) {
    enum Mode {
        Code,
        Block(usize),  // nested depth
        Str,           // regular "..."
        RawStr(usize), // r#"..."# with N hashes
    }
    let mut out: Vec<String> = Vec::new();
    let mut comments: Vec<(usize, String)> = Vec::new();
    let mut mode = Mode::Code;
    for (lno, line) in text.lines().enumerate() {
        let b: Vec<char> = line.chars().collect();
        let mut stripped = String::with_capacity(b.len());
        let mut i = 0;
        while i < b.len() {
            match mode {
                Mode::Code => {
                    let c = b[i];
                    if c == '/' && b.get(i + 1) == Some(&'/') {
                        let ctext: String = b[i..].iter().collect();
                        comments.push((lno, ctext));
                        for _ in i..b.len() {
                            stripped.push(' ');
                        }
                        i = b.len();
                    } else if c == '/' && b.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(1);
                        stripped.push_str("  ");
                        i += 2;
                    } else if c == '"' {
                        // Raw-string openers were consumed at the `r`.
                        mode = Mode::Str;
                        stripped.push(' ');
                        i += 1;
                    } else if (c == 'r' || c == 'b')
                        && !prev_is_ident(&b, i)
                        && raw_open(&b, i).is_some()
                    {
                        if let Some((hashes, skip)) = raw_open(&b, i) {
                            mode = Mode::RawStr(hashes);
                            for _ in 0..skip {
                                stripped.push(' ');
                            }
                            i += skip;
                        }
                    } else if c == '\'' {
                        match char_literal_len(&b, i) {
                            Some(len) => {
                                // Blank the whole literal inline.
                                for _ in 0..len {
                                    stripped.push(' ');
                                    i += 1;
                                }
                            }
                            None => {
                                // Lifetime: keep the tick, scan on.
                                stripped.push(c);
                                i += 1;
                            }
                        }
                    } else {
                        stripped.push(c);
                        i += 1;
                    }
                }
                Mode::Block(depth) => {
                    if b[i] == '*' && b.get(i + 1) == Some(&'/') {
                        mode = if depth == 1 { Mode::Code } else { Mode::Block(depth - 1) };
                        stripped.push_str("  ");
                        i += 2;
                    } else if b[i] == '/' && b.get(i + 1) == Some(&'*') {
                        mode = Mode::Block(depth + 1);
                        stripped.push_str("  ");
                        i += 2;
                    } else {
                        stripped.push(' ');
                        i += 1;
                    }
                }
                Mode::Str => {
                    if b[i] == '\\' {
                        stripped.push_str(&" ".repeat(2.min(b.len() - i)));
                        i += 2;
                    } else if b[i] == '"' {
                        mode = Mode::Code;
                        stripped.push(' ');
                        i += 1;
                    } else {
                        stripped.push(' ');
                        i += 1;
                    }
                }
                Mode::RawStr(hashes) => {
                    if b[i] == '"' && closes_raw(&b, i, hashes) {
                        mode = Mode::Code;
                        for _ in 0..=hashes {
                            stripped.push(' ');
                        }
                        i += 1 + hashes;
                    } else {
                        stripped.push(' ');
                        i += 1;
                    }
                }
            }
        }
        // A `\`-escape split across the line end inside Mode::Str is not
        // handled specially: multi-line strings stay in Str mode, which
        // is what we want.
        out.push(stripped);
    }
    (out, comments)
}

fn prev_is_ident(b: &[char], i: usize) -> bool {
    i > 0 && (b[i - 1].is_alphanumeric() || b[i - 1] == '_')
}

/// `r"`, `r#"`, `br"`, `br##"` at position `i` → (hash count, opener len).
fn raw_open(b: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if b.get(j) == Some(&'b') {
        j += 1;
    }
    if b.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while b.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if b.get(j) == Some(&'"') {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

fn closes_raw(b: &[char], i: usize, hashes: usize) -> bool {
    (1..=hashes).all(|k| b.get(i + k) == Some(&'#'))
}

/// If position `i` (a `'`) starts a char literal, its total length in
/// chars (including both quotes); `None` for lifetimes / loop labels.
fn char_literal_len(b: &[char], i: usize) -> Option<usize> {
    if b.get(i + 1) == Some(&'\\') {
        // Escape: find the closing quote.
        let mut j = i + 2;
        while j < b.len() && b[j] != '\'' {
            j += 1;
        }
        (j < b.len()).then_some(j + 1 - i)
    } else if b.get(i + 2) == Some(&'\'') && b.get(i + 1) != Some(&'\'') {
        Some(3)
    } else {
        None
    }
}

/// Mark lines inside `#[cfg(test)]` / `#[test]` items by brace counting
/// on the stripped source.
fn test_spans(code: &[String]) -> Vec<bool> {
    let mut flags = vec![false; code.len()];
    let mut depth: i64 = 0;
    let mut pending = false; // saw the attribute, waiting for the `{`
    let mut active: Option<i64> = None; // depth the test item opened at
    for (idx, line) in code.iter().enumerate() {
        if pending || active.is_some() {
            flags[idx] = true;
        }
        if line.contains("#[cfg(test)]") || line.contains("#[test]") {
            if active.is_none() {
                pending = true;
            }
            flags[idx] = true;
        }
        for c in line.chars() {
            match c {
                '{' => {
                    if pending && active.is_none() {
                        active = Some(depth);
                        pending = false;
                    }
                    depth += 1;
                }
                '}' => {
                    depth -= 1;
                    if active == Some(depth) {
                        active = None;
                    }
                }
                _ => {}
            }
        }
    }
    flags
}

/// Parse one `//` comment for a `lint:allow` directive.  Doc comments
/// (`///`, `//!`) are prose — documentation may *mention* the directive
/// syntax without enacting it.
fn parse_allow(comment: &str, line: usize, code: &[String]) -> Option<Allow> {
    if comment.starts_with("///") || comment.starts_with("//!") {
        return None;
    }
    let (file_level, rest) = if let Some(r) = comment.split_once("lint:allow-file(") {
        (true, r.1)
    } else if let Some(r) = comment.split_once("lint:allow(") {
        (false, r.1)
    } else {
        return None;
    };
    let trailing = code
        .get(line - 1)
        .map(|c| !c.trim().is_empty())
        .unwrap_or(false);
    // A standalone allow covers the next line with actual code, so a
    // multi-line justification comment between allow and code is fine.
    let target = if trailing {
        line
    } else {
        let mut t = line + 1;
        while t <= code.len() && code[t - 1].trim().is_empty() {
            t += 1;
        }
        t
    };
    let malformed = |msg: &str| Allow {
        rules: Vec::new(),
        reason: String::new(),
        line,
        target,
        file_level,
        malformed: Some(msg.to_string()),
    };
    let Some((ids, tail)) = rest.split_once(')') else {
        return Some(malformed("missing `)` in lint:allow"));
    };
    let rules: Vec<String> = ids
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    if rules.is_empty() {
        return Some(malformed("lint:allow names no rules"));
    }
    let Some(reason) = tail.trim_start().strip_prefix(':') else {
        return Some(malformed("lint:allow requires `: reason`"));
    };
    let reason = reason.trim().to_string();
    if reason.is_empty() {
        return Some(malformed("lint:allow reason is empty"));
    }
    Some(Allow { rules, reason, line, target, file_level, malformed: None })
}

/// Find the 1-based line range `[open..=close]` of the brace-delimited
/// block whose opening `{` is at or after 1-based `start` (inclusive of
/// the line carrying the `{`).  Returns `None` if no block is found.
pub fn brace_span(code: &[String], start: usize) -> Option<(usize, usize)> {
    let mut depth: i64 = 0;
    let mut open_line = None;
    for (idx, line) in code.iter().enumerate().skip(start.saturating_sub(1)) {
        for c in line.chars() {
            match c {
                '{' => {
                    if open_line.is_none() {
                        open_line = Some(idx + 1);
                    }
                    depth += 1;
                }
                '}' => {
                    if let Some(open) = open_line {
                        depth -= 1;
                        if depth == 0 {
                            return Some((open, idx + 1));
                        }
                    }
                }
                _ => {}
            }
        }
    }
    None
}
