//! `ripra-lint`: repo-local static analysis for the invariants the test
//! suite cannot see (and, on toolchain-less containers, cannot run).
//!
//! The planner's headline guarantee — same seed ⇒ byte-identical JSON at
//! any thread/shard count, fault-free traces unchanged by fault-code
//! additions — rests on conventions that are easy to break silently: a
//! stray `Instant` in a serialized path, a `HashMap` iteration feeding an
//! aggregate, a new RNG stream forked *before* existing ones, an event
//! kind missing from the metrics registries.  This module turns those
//! conventions into machine-checked rules.
//!
//! * [`analyze_root`] walks a source tree (normally `rust/src`) and runs
//!   every rule; the `ripra-lint` binary wraps it for CI.
//! * [`analyze_files`] runs the same rules over in-memory files so tests
//!   can feed fixture snippets.
//! * Suppression is only via `// lint:allow(rule-id): reason` (same or
//!   next line), `// lint:allow-file(rule-id): reason` (whole file) — a
//!   missing reason is itself a violation (`bad-allow`), and allows that
//!   suppress nothing are reported as stale.
//!
//! Rule catalog and policy: EXPERIMENTS.md §Static analysis.

use std::fs;
use std::io;
use std::path::Path;

pub mod report;
pub mod rules;
pub mod scan;

pub use rules::{RuleInfo, RULES};

/// An in-memory file for [`analyze_files`] (fixture tests).
pub struct LintFile {
    /// Root-relative `/`-separated path, e.g. `fleet/driver.rs`.  Rules
    /// with path registries (robustness modules, fork streams) key off
    /// this.
    pub path: String,
    pub text: String,
}

/// One rule hit, before or after suppression.
#[derive(Clone, Debug)]
pub struct Violation {
    pub rule: &'static str,
    pub family: &'static str,
    pub path: String,
    pub line: usize,
    pub message: String,
    /// Covered by a well-formed `lint:allow`.
    pub suppressed: bool,
    /// The allow's reason, when suppressed.
    pub reason: Option<String>,
}

/// A well-formed allow that suppressed nothing (warning, not failure —
/// it usually means the underlying code was fixed).
#[derive(Clone, Debug)]
pub struct StaleAllow {
    pub path: String,
    pub line: usize,
    pub rules: String,
}

/// Full lint result.
pub struct Report {
    pub files: usize,
    pub violations: Vec<Violation>,
    pub stale_allows: Vec<StaleAllow>,
}

impl Report {
    /// Unsuppressed violations — what fails CI.
    pub fn active(&self) -> Vec<&Violation> {
        self.violations.iter().filter(|v| !v.suppressed).collect()
    }

    pub fn suppressed_count(&self) -> usize {
        self.violations.iter().filter(|v| v.suppressed).count()
    }

    pub fn is_clean(&self) -> bool {
        self.active().is_empty()
    }
}

/// Run every rule over in-memory files and apply allow-suppression.
pub fn analyze_files(files: &[LintFile]) -> Report {
    let parsed: Vec<scan::SourceFile> =
        files.iter().map(|f| scan::SourceFile::parse(&f.path, &f.text)).collect();
    let mut violations = rules::run_all(&parsed);
    // Deterministic report order regardless of rule execution order.
    violations.sort_by(|a, b| (&a.path, a.line, a.rule).cmp(&(&b.path, b.line, b.rule)));
    let mut stale_allows = Vec::new();
    for sf in &parsed {
        let mut used = vec![false; sf.allows.len()];
        for v in violations.iter_mut() {
            if v.path != sf.path || v.suppressed || v.rule == "bad-allow" {
                continue;
            }
            for (ai, allow) in sf.allows.iter().enumerate() {
                if allow.malformed.is_some() || !allow.rules.iter().any(|r| r == v.rule) {
                    continue;
                }
                if allow.file_level || allow.target == v.line {
                    v.suppressed = true;
                    v.reason = Some(allow.reason.clone());
                    used[ai] = true;
                    break;
                }
            }
        }
        for (ai, allow) in sf.allows.iter().enumerate() {
            let well_formed = allow.malformed.is_none()
                && allow.rules.iter().all(|r| rules::rule_family(r).is_some());
            if well_formed && !used[ai] {
                stale_allows.push(StaleAllow {
                    path: sf.path.clone(),
                    line: allow.line,
                    rules: allow.rules.join(", "),
                });
            }
        }
    }
    Report { files: parsed.len(), violations, stale_allows }
}

/// Walk `root` (normally `rust/src`), parse every `.rs` file, and run
/// the rules.  Files are visited in sorted order so reports are
/// byte-stable.
pub fn analyze_root(root: &Path) -> io::Result<Report> {
    let mut files = Vec::new();
    walk(root, root, &mut files)?;
    files.sort_by(|a, b| a.path.cmp(&b.path));
    Ok(analyze_files(&files))
}

fn walk(root: &Path, dir: &Path, out: &mut Vec<LintFile>) -> io::Result<()> {
    let mut entries: Vec<_> = fs::read_dir(dir)?.collect::<io::Result<Vec<_>>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            walk(root, &path, out)?;
        } else if path.extension().map(|e| e == "rs").unwrap_or(false) {
            let rel = path
                .strip_prefix(root)
                .unwrap_or(&path)
                .components()
                .map(|c| c.as_os_str().to_string_lossy().into_owned())
                .collect::<Vec<_>>()
                .join("/");
            out.push(LintFile { path: rel, text: fs::read_to_string(&path)? });
        }
    }
    Ok(())
}
