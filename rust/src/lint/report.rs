//! Report rendering for `ripra-lint`: machine-readable JSON (CI
//! artifact) and a human-readable table.

use crate::util::json::Json;

use super::{Report, Violation};

/// Machine-readable report.  Key order is fixed (the JSON writer
/// preserves insertion order) so the artifact is byte-stable.
pub fn to_json(report: &Report) -> Json {
    let violations: Vec<Json> = report.violations.iter().map(violation_json).collect();
    let stale: Vec<Json> = report
        .stale_allows
        .iter()
        .map(|s| {
            Json::Obj(vec![
                ("path".to_string(), Json::Str(s.path.clone())),
                ("line".to_string(), Json::Num(s.line as f64)),
                ("rules".to_string(), Json::Str(s.rules.clone())),
            ])
        })
        .collect();
    Json::Obj(vec![
        ("tool".to_string(), Json::Str("ripra-lint".to_string())),
        ("files".to_string(), Json::Num(report.files as f64)),
        ("active".to_string(), Json::Num(report.active().len() as f64)),
        ("suppressed".to_string(), Json::Num(report.suppressed_count() as f64)),
        ("clean".to_string(), Json::Bool(report.is_clean())),
        ("violations".to_string(), Json::Arr(violations)),
        ("stale_allows".to_string(), Json::Arr(stale)),
    ])
}

fn violation_json(v: &Violation) -> Json {
    let reason = match &v.reason {
        Some(r) => Json::Str(r.clone()),
        None => Json::Null,
    };
    Json::Obj(vec![
        ("rule".to_string(), Json::Str(v.rule.to_string())),
        ("family".to_string(), Json::Str(v.family.to_string())),
        ("path".to_string(), Json::Str(v.path.clone())),
        ("line".to_string(), Json::Num(v.line as f64)),
        ("message".to_string(), Json::Str(v.message.clone())),
        ("suppressed".to_string(), Json::Bool(v.suppressed)),
        ("reason".to_string(), reason),
    ])
}

/// Human table: active violations first, then a one-line summary (and
/// stale-allow warnings when present).
pub fn table(report: &Report) -> String {
    let mut out = String::new();
    let active = report.active();
    if !active.is_empty() {
        let loc_w = active
            .iter()
            .map(|v| v.path.len() + 1 + digits(v.line))
            .max()
            .unwrap_or(8)
            .max("location".len());
        let rule_w = active.iter().map(|v| v.rule.len()).max().unwrap_or(4).max("rule".len());
        out.push_str(&format!("{:<loc_w$}  {:<rule_w$}  message\n", "location", "rule"));
        for v in &active {
            let loc = format!("{}:{}", v.path, v.line);
            out.push_str(&format!("{loc:<loc_w$}  {:<rule_w$}  {}\n", v.rule, v.message));
        }
    }
    for s in &report.stale_allows {
        out.push_str(&format!(
            "warning: stale lint:allow({}) at {}:{} suppresses nothing\n",
            s.rules, s.path, s.line
        ));
    }
    out.push_str(&format!(
        "ripra-lint: {} file(s), {} active violation(s), {} suppressed\n",
        report.files,
        active.len(),
        report.suppressed_count()
    ));
    out
}

fn digits(mut n: usize) -> usize {
    let mut d = 1;
    while n >= 10 {
        n /= 10;
        d += 1;
    }
    d
}
