//! Rule implementations and repo registries for `ripra-lint`.
//!
//! Four families (see EXPERIMENTS.md §Static analysis for the catalog):
//!
//! * **determinism** — `wall-clock`, `hash-order`, `ambient-rng`,
//!   `rng-truncation`: nothing order- or clock-dependent may feed the
//!   serialized outputs that the byte-identical-JSON contract covers.
//! * **rng-stream** — `fork-tag-dup`, `fork-order`: literal
//!   [`Rng::fork`](crate::util::rng::Rng::fork) tags are unique
//!   repo-wide and appear in the registered declaration order, so new
//!   streams never perturb pre-existing ones.
//! * **structural** — `event-kinds`, `error-display`, `cli-flags`:
//!   cross-file contracts (event-kind registries, `Display` coverage,
//!   CLI flag parity) that runtime tests cannot see when they cannot
//!   run.
//! * **robustness** — `panic-path`, `float-eq`: library modules return
//!   errors instead of panicking and never compare floats with `==`.
//!
//! Plus the meta rule `bad-allow` for malformed suppression comments.
//! All checks are lexical (token scans over comment/string-stripped
//! lines — see [`scan`](super::scan)), which is exactly as much parser
//! as the repo's conventions need.

use super::scan::{brace_span, SourceFile};
use super::Violation;

/// Catalog entry for one rule.
pub struct RuleInfo {
    pub id: &'static str,
    pub family: &'static str,
    pub desc: &'static str,
}

/// The full rule catalog (ids are what `lint:allow(...)` names).
pub const RULES: &[RuleInfo] = &[
    RuleInfo {
        id: "wall-clock",
        family: "determinism",
        desc: "Instant/SystemTime outside the allowlisted bench / Diagnostics.wall_time paths",
    },
    RuleInfo {
        id: "hash-order",
        family: "determinism",
        desc: "HashMap/HashSet (iteration order feeds JSON or aggregates); use BTreeMap",
    },
    RuleInfo {
        id: "ambient-rng",
        family: "determinism",
        desc: "ambient randomness (thread_rng/rand::random/OsRng); all draws flow from the seed",
    },
    RuleInfo {
        id: "rng-truncation",
        family: "determinism",
        desc: "narrowing `as` cast of a raw RNG draw on the same line as next_u64()",
    },
    RuleInfo {
        id: "fork-tag-dup",
        family: "rng-stream",
        desc: "literal Rng fork tag reused; every stream tag must be unique repo-wide",
    },
    RuleInfo {
        id: "fork-order",
        family: "rng-stream",
        desc: "literal fork tags must match the registered declaration order (new streams last)",
    },
    RuleInfo {
        id: "event-kinds",
        family: "structural",
        desc: "FleetEvent variants / kind() tags / DELTA_KINDS / FAULT_KINDS out of sync",
    },
    RuleInfo {
        id: "error-display",
        family: "structural",
        desc: "error enum variant missing from its Display impl",
    },
    RuleInfo {
        id: "cli-flags",
        family: "structural",
        desc: "CLI_FLAGS entry with no matching parse arm in main.rs",
    },
    RuleInfo {
        id: "panic-path",
        family: "robustness",
        desc: "unwrap()/expect()/panic! in a library module; return an error instead",
    },
    RuleInfo {
        id: "float-eq",
        family: "robustness",
        desc: "float compared with ==/!= against a literal outside pinning tests",
    },
    RuleInfo {
        id: "bad-allow",
        family: "meta",
        desc: "malformed lint:allow comment (unknown rule id, missing reason, bad syntax)",
    },
];

pub fn rule_family(id: &str) -> Option<&'static str> {
    RULES.iter().find(|r| r.id == id).map(|r| r.family)
}

/// Modules held to the robustness rules (`panic-path`, `float-eq`).
/// `main.rs`, tests, benches, and the lint itself are exempt.
pub const CHECKED_MODULES: &[&str] =
    &["optim/", "engine/", "fleet/", "service/", "risk/", "fault/", "util/"];

/// Files exempt from `wall-clock` and `panic-path` by design: the
/// micro-bench harness measures wall time and aborts on setup failure.
pub const BENCH_FILES: &[&str] = &["util/bench.rs"];

/// Canonical RNG stream order.  Appending a stream is fine; inserting
/// or reordering shifts every later stream and silently changes traces,
/// which is exactly what `fork-order` exists to catch.
pub const FORK_STREAMS: &[(&str, &[u64])] = &[
    ("fleet/driver.rs", &[0xA1, 0xDE, 0x10C, 0xC4, 0x5E, 0xB0]),
    ("fault/mod.rs", &[0xFA01, 0xFA02, 0xFA03, 0xFA04]),
    ("fleet/loadgen.rs", &[0x1D01, 0x1D02, 0x1D03]),
];

/// `FleetEvent::kind()` tags that are renamed before reaching the
/// metrics registries (everything else must appear verbatim in
/// `DELTA_KINDS`).
pub const EVENT_DELTA_MAP: &[(&str, &[&str])] = &[
    ("arrival", &["join"]),
    ("departure", &["leave"]),
    ("fade", &["channel"]),
    ("renegotiate", &["deadline", "risk"]),
];

/// Error types whose `Display` must cover every variant (structs only
/// need the impl to exist).
pub const ERROR_DISPLAY: &[(&str, &str)] = &[
    ("PlanError", "engine/outcome.rs"),
    ("ServiceError", "service/mod.rs"),
    ("BaselineError", "optim/baselines.rs"),
    ("WireError", "service/wire.rs"),
];

/// Files declaring a `CLI_FLAGS` registry that `main.rs` must parse.
pub const CLI_FLAG_TABLES: &[&str] = &["engine/request.rs", "fleet/driver.rs"];

fn in_checked_module(path: &str) -> bool {
    CHECKED_MODULES.iter().any(|m| path.starts_with(m)) && !BENCH_FILES.contains(&path)
}

/// Token occurrence with identifier-boundary checks on both ends (only
/// where the token itself starts/ends with an identifier char).
fn has_token(line: &str, token: &str) -> bool {
    let ident = |c: char| c.is_alphanumeric() || c == '_';
    let first = token.chars().next().map(ident).unwrap_or(false);
    let last = token.chars().last().map(ident).unwrap_or(false);
    let mut from = 0;
    while let Some(pos) = line[from..].find(token) {
        let at = from + pos;
        let ok_before = !first || !line[..at].chars().next_back().map(ident).unwrap_or(false);
        let after = line[at + token.len()..].chars().next();
        let ok_after = !last || !after.map(ident).unwrap_or(false);
        if ok_before && ok_after {
            return true;
        }
        from = at + token.len();
    }
    false
}

/// Run every rule over the parsed files; returns raw (pre-suppression)
/// violations.
pub fn run_all(files: &[SourceFile]) -> Vec<Violation> {
    let mut out = Vec::new();
    for sf in files {
        per_line_rules(sf, &mut out);
    }
    fork_rules(files, &mut out);
    event_kind_rules(files, &mut out);
    error_display_rules(files, &mut out);
    cli_flag_rules(files, &mut out);
    out
}

fn push(out: &mut Vec<Violation>, rule: &'static str, sf: &SourceFile, line: usize, msg: String) {
    out.push(Violation {
        rule,
        family: rule_family(rule).unwrap_or("meta"),
        path: sf.path.clone(),
        line,
        message: msg,
        suppressed: false,
        reason: None,
    });
}

fn per_line_rules(sf: &SourceFile, out: &mut Vec<Violation>) {
    let checked = in_checked_module(&sf.path);
    let bench = BENCH_FILES.contains(&sf.path.as_str());
    for (idx, line) in sf.code.iter().enumerate() {
        let lno = idx + 1;
        let test = sf.is_test_line(lno);
        // determinism -------------------------------------------------
        if !test && !bench {
            for tok in ["Instant", "SystemTime"] {
                if has_token(line, tok) {
                    push(out, "wall-clock", sf, lno, format!("`{tok}` in non-test code"));
                }
            }
        }
        if !test {
            for tok in ["HashMap", "HashSet", "RandomState"] {
                if has_token(line, tok) {
                    push(out, "hash-order", sf, lno, format!("`{tok}` in non-test code"));
                }
            }
            if has_token(line, "next_u64(") && narrowing_cast(line) {
                push(
                    out,
                    "rng-truncation",
                    sf,
                    lno,
                    "narrowing cast of a raw RNG draw".to_string(),
                );
            }
        }
        for tok in ["thread_rng", "rand::random", "from_entropy", "OsRng", "getrandom"] {
            if has_token(line, tok) {
                push(out, "ambient-rng", sf, lno, format!("ambient randomness `{tok}`"));
            }
        }
        // robustness --------------------------------------------------
        if checked && !test {
            for tok in [".unwrap()", ".expect(", "panic!(", "todo!(", "unimplemented!("] {
                if has_token(line, tok) {
                    push(out, "panic-path", sf, lno, format!("`{tok}` in a library module"));
                }
            }
            if let Some(op) = float_literal_cmp(line) {
                push(out, "float-eq", sf, lno, format!("float literal compared with `{op}`"));
            }
        }
        // meta --------------------------------------------------------
    }
    for allow in &sf.allows {
        if let Some(msg) = &allow.malformed {
            push(out, "bad-allow", sf, allow.line, msg.clone());
        } else {
            for id in &allow.rules {
                if rule_family(id).is_none() {
                    push(out, "bad-allow", sf, allow.line, format!("unknown rule id `{id}`"));
                } else if id == "bad-allow" {
                    let msg = "bad-allow is not suppressible".to_string();
                    push(out, "bad-allow", sf, allow.line, msg);
                }
            }
        }
    }
}

/// `... as usize` / `as u32` / ... on the line (narrowing targets only;
/// `as f64` is how draws become uniforms and is fine).
fn narrowing_cast(line: &str) -> bool {
    ["usize", "u32", "u16", "u8", "i64", "i32", "i16", "i8", "isize"]
        .iter()
        .any(|t| has_token(line, &format!("as {t}")))
}

/// Does the line compare a float literal with `==` / `!=`?  Returns the
/// operator for the message.
fn float_literal_cmp(line: &str) -> Option<&'static str> {
    let b: Vec<char> = line.chars().collect();
    for i in 0..b.len().saturating_sub(1) {
        let op = match (b[i], b[i + 1]) {
            ('=', '=') => "==",
            ('!', '=') => "!=",
            _ => continue,
        };
        // Exclude <= >= == != += etc. around the match.
        if i > 0 && is_op_char(b[i - 1]) {
            continue;
        }
        if b.get(i + 2) == Some(&'=') {
            continue;
        }
        let left: String = b[..i].iter().collect();
        let right: String = b[i + 2..].iter().collect();
        if is_float_literal(last_token(&left)) || is_float_literal(first_token(&right)) {
            return Some(op);
        }
    }
    None
}

fn is_op_char(c: char) -> bool {
    "<>=!+-*/%&|^".contains(c)
}

fn last_token(s: &str) -> &str {
    let t = s.trim_end();
    let cut = t
        .rfind(|c: char| !(c.is_alphanumeric() || c == '_' || c == '.'))
        .map(|p| p + 1)
        .unwrap_or(0);
    &t[cut..]
}

fn first_token(s: &str) -> &str {
    let t = s.trim_start();
    let cut = t
        .find(|c: char| !(c.is_alphanumeric() || c == '_' || c == '.'))
        .unwrap_or(t.len());
    &t[..cut]
}

fn is_float_literal(tok: &str) -> bool {
    !tok.is_empty()
        && tok.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(false)
        && tok.contains('.')
        && tok.parse::<f64>().is_ok()
}

// --- rng-stream family ---------------------------------------------------

/// Literal fork tags in declaration order: `(line, tag)`.
fn literal_forks(sf: &SourceFile) -> Vec<(usize, u64)> {
    let mut tags = Vec::new();
    for (idx, line) in sf.code.iter().enumerate() {
        if sf.is_test_line(idx + 1) {
            continue;
        }
        let mut from = 0;
        while let Some(pos) = line[from..].find(".fork(") {
            let at = from + pos + ".fork(".len();
            from = at;
            let Some(close) = line[at..].find(')') else { continue };
            let arg = line[at..at + close].trim();
            let parsed = if let Some(hex) = arg.strip_prefix("0x") {
                u64::from_str_radix(&hex.replace('_', ""), 16).ok()
            } else {
                arg.replace('_', "").parse::<u64>().ok()
            };
            if let Some(tag) = parsed {
                tags.push((idx + 1, tag));
            }
        }
    }
    tags
}

fn fork_rules(files: &[SourceFile], out: &mut Vec<Violation>) {
    let mut seen: Vec<(u64, String)> = Vec::new();
    for sf in files {
        let forks = literal_forks(sf);
        for &(line, tag) in &forks {
            if let Some((_, first)) = seen.iter().find(|(t, _)| *t == tag) {
                push(
                    out,
                    "fork-tag-dup",
                    sf,
                    line,
                    format!("fork tag {tag:#x} already used in {first}"),
                );
            } else {
                seen.push((tag, sf.path.clone()));
            }
        }
        let registered = FORK_STREAMS.iter().find(|(p, _)| *p == sf.path);
        match registered {
            Some((_, order)) => {
                let got: Vec<u64> = forks.iter().map(|&(_, t)| t).collect();
                if got.as_slice() != *order {
                    let line = forks.first().map(|&(l, _)| l).unwrap_or(1);
                    push(
                        out,
                        "fork-order",
                        sf,
                        line,
                        format!(
                            "fork tags {} do not match the registered stream order {} \
                             (append new streams after all existing ones and update \
                             FORK_STREAMS)",
                            fmt_tags(&got),
                            fmt_tags(order),
                        ),
                    );
                }
            }
            None => {
                for &(line, tag) in &forks {
                    push(
                        out,
                        "fork-order",
                        sf,
                        line,
                        format!(
                            "literal fork tag {tag:#x} in a file with no FORK_STREAMS \
                             registration"
                        ),
                    );
                }
            }
        }
    }
}

fn fmt_tags(tags: &[u64]) -> String {
    let parts: Vec<String> = tags.iter().map(|t| format!("{t:#x}")).collect();
    format!("[{}]", parts.join(", "))
}

// --- structural family ---------------------------------------------------

fn by_path<'a>(files: &'a [SourceFile], path: &str) -> Option<&'a SourceFile> {
    files.iter().find(|f| f.path == path)
}

/// Variant names of `enum <name>` (stripped view; attr lines skipped).
fn enum_variants(sf: &SourceFile, name: &str) -> Option<(usize, Vec<String>)> {
    let decl = sf
        .code
        .iter()
        .position(|l| has_token(l, &format!("enum {name}")))?;
    let (open, close) = brace_span(&sf.code, decl + 1)?;
    let mut variants = Vec::new();
    let mut depth = 0i64;
    for lno in open..=close {
        let line = sf.code_line(lno);
        let at_top = depth == 1;
        for c in line.chars() {
            match c {
                '{' => depth += 1,
                '}' => depth -= 1,
                _ => {}
            }
        }
        let trimmed = line.trim();
        let candidate = if lno == open {
            trimmed.split_once('{').map(|(_, rest)| rest.trim()).unwrap_or("")
        } else if at_top {
            trimmed
        } else {
            ""
        };
        if candidate.is_empty() || candidate.starts_with("#[") {
            continue;
        }
        let ident: String = candidate
            .chars()
            .take_while(|c| c.is_alphanumeric() || *c == '_')
            .collect();
        if ident.chars().next().map(|c| c.is_ascii_uppercase()).unwrap_or(false) {
            variants.push(ident);
        }
    }
    Some((decl + 1, variants))
}

/// String literals of a `const NAME: [&str; N] = [...]` registry, plus
/// the declared arity when present.
fn str_array(sf: &SourceFile, name: &str) -> Option<(usize, Vec<String>, Option<usize>)> {
    let decl = sf
        .code
        .iter()
        .position(|l| has_token(l, name) && l.contains("const"))?;
    let arity = {
        let code = sf.code_line(decl + 1);
        code.split_once("[&str;")
            .and_then(|(_, rest)| rest.split(']').next())
            .and_then(|n| n.trim().parse::<usize>().ok())
    };
    let mut strings = Vec::new();
    for lno in decl + 1..=sf.raw.len() {
        strings.extend(quoted_strings(&sf.raw[lno - 1]));
        // `];` closes the initializer (the `;` inside `[&str; N]` does
        // not match).
        if sf.code_line(lno).contains("];") {
            break;
        }
    }
    Some((decl + 1, strings, arity))
}

/// Double-quoted literals in a raw line (no escape handling — registry
/// tags are plain idents).
fn quoted_strings(raw: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut parts = raw.split('"');
    // Odd-indexed segments are inside quotes.
    while let (Some(_), Some(inside)) = (parts.next(), parts.next()) {
        out.push(inside.to_string());
    }
    out
}

/// `FleetEvent::kind()` arms: variant name → tag string.
fn kind_arms(sf: &SourceFile) -> Vec<(String, String)> {
    let Some(decl) = sf.code.iter().position(|l| l.contains("fn kind")) else {
        return Vec::new();
    };
    let Some((open, close)) = brace_span(&sf.code, decl + 1) else {
        return Vec::new();
    };
    let mut arms = Vec::new();
    for lno in open..=close {
        let code = sf.code_line(lno);
        if let Some(pos) = code.find("FleetEvent::") {
            let after = &code[pos + "FleetEvent::".len()..];
            let variant: String = after
                .chars()
                .take_while(|c| c.is_alphanumeric() || *c == '_')
                .collect();
            // The tag is the first string literal at or after the arm.
            for tag_line in lno..=close {
                if let Some(tag) = quoted_strings(&sf.raw[tag_line - 1]).into_iter().next() {
                    arms.push((variant, tag));
                    break;
                }
            }
        }
    }
    arms
}

fn event_kind_rules(files: &[SourceFile], out: &mut Vec<Violation>) {
    // Fixture sets without the fleet files have nothing to check.
    let Some(events) = by_path(files, "fleet/events.rs") else { return };
    let Some(metrics) = by_path(files, "fleet/metrics.rs") else { return };
    let Some((decl, variants)) = enum_variants(events, "FleetEvent") else {
        push(out, "event-kinds", events, 1, "enum FleetEvent not found".into());
        return;
    };
    let arms = kind_arms(events);
    let deltas = str_array(metrics, "DELTA_KINDS");
    let faults = str_array(metrics, "FAULT_KINDS");
    for (name, arr, line) in [("DELTA_KINDS", &deltas, 1), ("FAULT_KINDS", &faults, 1)] {
        match arr {
            None => push(out, "event-kinds", metrics, line, format!("{name} not found")),
            Some((decl, strings, arity)) => {
                if let Some(n) = arity {
                    if strings.len() != *n {
                        push(
                            out,
                            "event-kinds",
                            metrics,
                            *decl,
                            format!("{name} declares {n} entries but lists {}", strings.len()),
                        );
                    }
                }
            }
        }
    }
    let (Some((ddecl, delta_kinds, _)), Some((_, fault_kinds, _))) = (deltas, faults) else {
        return;
    };
    for k in &fault_kinds {
        if !delta_kinds.contains(k) {
            push(
                out,
                "event-kinds",
                metrics,
                ddecl,
                format!("FAULT_KINDS entry \"{k}\" missing from DELTA_KINDS"),
            );
        }
    }
    for v in &variants {
        let Some((_, tag)) = arms.iter().find(|(n, _)| n == v) else {
            push(
                out,
                "event-kinds",
                events,
                decl,
                format!("FleetEvent::{v} has no kind() arm"),
            );
            continue;
        };
        let mapped = EVENT_DELTA_MAP.iter().find(|(t, _)| t == tag);
        let targets: Vec<&str> = match mapped {
            Some((_, ds)) => ds.to_vec(),
            None => vec![tag.as_str()],
        };
        for d in targets {
            if !delta_kinds.iter().any(|k| k == d) {
                push(
                    out,
                    "event-kinds",
                    events,
                    decl,
                    format!(
                        "FleetEvent::{v} (kind \"{tag}\") maps to \"{d}\" which is not in \
                         DELTA_KINDS"
                    ),
                );
            }
        }
    }
}

fn error_display_rules(files: &[SourceFile], out: &mut Vec<Violation>) {
    for &(ty, path) in ERROR_DISPLAY {
        let Some(sf) = by_path(files, path) else { continue };
        let display_decl = sf.code.iter().position(|l| {
            l.contains("impl") && l.contains("Display") && has_token(l, &format!("for {ty}"))
        });
        let Some(ddecl) = display_decl else {
            push(out, "error-display", sf, 1, format!("no Display impl for {ty}"));
            continue;
        };
        let Some((open, close)) = brace_span(&sf.code, ddecl + 1) else { continue };
        // Struct errors (e.g. BaselineError) only need the impl to
        // exist; enums must cover every variant.
        if let Some((edecl, variants)) = enum_variants(sf, ty) {
            for v in &variants {
                let covered = (open..=close).any(|lno| {
                    let code = sf.code_line(lno);
                    has_token(code, &format!("{ty}::{v}")) || has_token(code, &format!("Self::{v}"))
                });
                if !covered {
                    push(
                        out,
                        "error-display",
                        sf,
                        edecl,
                        format!("{ty}::{v} is not covered in the Display impl"),
                    );
                }
            }
        }
    }
}

fn cli_flag_rules(files: &[SourceFile], out: &mut Vec<Violation>) {
    let Some(main) = by_path(files, "main.rs") else { return };
    let main_text = main.raw.join("\n");
    for &path in CLI_FLAG_TABLES {
        let Some(sf) = by_path(files, path) else { continue };
        let Some(decl) = sf.code.iter().position(|l| has_token(l, "CLI_FLAGS")) else {
            push(out, "cli-flags", sf, 1, "CLI_FLAGS registry not found".into());
            continue;
        };
        let mut names: Vec<(usize, String)> = Vec::new();
        for lno in decl + 1..=sf.raw.len() {
            let raw = &sf.raw[lno - 1];
            let mut from = 0;
            while let Some(pos) = raw[from..].find("name: \"") {
                let at = from + pos + "name: \"".len();
                from = at;
                if let Some(end) = raw[at..].find('"') {
                    names.push((lno, raw[at..at + end].to_string()));
                }
            }
            if sf.code_line(lno).contains("];") {
                break;
            }
        }
        if names.is_empty() {
            push(out, "cli-flags", sf, decl + 1, "CLI_FLAGS lists no flag names".into());
        }
        for (lno, name) in names {
            if !main_text.contains(&format!("\"{name}\"")) {
                push(
                    out,
                    "cli-flags",
                    sf,
                    lno,
                    format!("flag \"--{name}\" has no parse arm in main.rs"),
                );
            }
        }
    }
}
