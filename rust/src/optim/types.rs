//! Planner data model: scenarios (devices + shared uplink budget),
//! decisions (partition / bandwidth / frequency), and policies.

use crate::channel::Uplink;
use crate::energy;
use crate::models::ModelProfile;
use crate::risk::{self, RiskBound};
use crate::util::rng::Rng;

use super::ecr;

/// Decision policy under inference-time uncertainty (§VI benchmarks).
///
/// Since the risk-bound refactor this is **policy × bound**: the robust
/// family carries a pluggable [`RiskBound`] selecting *which*
/// chance-constraint transform turns ε into a deterministic margin
/// (the pre-refactor unit variant `Policy::Robust` is now
/// [`Policy::ROBUST`] = `Policy::Robust(RiskBound::Ecr)`, bit-identical
/// margins).  Every bound's margin is constant per partition point, so
/// the convexity of the resource subproblem is independent of the bound
/// in play (see the `crate::risk` module docs).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Policy {
    /// The paper's proposal: chance-constrained deadline, transformed by
    /// the carried bound (eq. 22/28 with the default [`RiskBound::Ecr`]).
    Robust(RiskBound),
    /// Baseline 1: upper-bound times, hard deadline (no violations
    /// tolerated) — margin is the empirical max deviation observed in
    /// profiling: `worst_dev_factor`·√v^loc + 3.5·√v^vm (the VM is far
    /// more regular than the device; see models::ModelProfile).
    WorstCase,
    /// Baseline 3: ignore uncertainty entirely (margin 0) — used to show
    /// why robustness is needed in the violation-probability figures.
    MeanOnly,
}

impl Policy {
    /// Back-compat spelling of the pre-refactor `Policy::Robust` unit
    /// variant: the robust policy under the default ECR/Cantelli bound.
    pub const ROBUST: Policy = Policy::Robust(RiskBound::Ecr);

    /// The robust policy's bound, if this is the robust family.
    pub fn bound(&self) -> Option<RiskBound> {
        match self {
            Policy::Robust(b) => Some(*b),
            _ => None,
        }
    }

    /// Swap the bound on a robust policy (no-op for the baselines, whose
    /// margins are not parameterized by a bound).
    pub fn with_bound(self, bound: RiskBound) -> Policy {
        match self {
            Policy::Robust(_) => Policy::Robust(bound),
            other => other,
        }
    }
}

/// One mobile device: its DNN/hardware profile, uplink, and task QoS.
#[derive(Clone, Debug)]
pub struct Device {
    pub model: ModelProfile,
    pub uplink: Uplink,
    /// Task deadline D_n, seconds.
    pub deadline_s: f64,
    /// Risk level ε_n (tolerated violation probability).
    pub risk: f64,
}

impl Device {
    /// σ_n = √((1−ε)/ε) (Theorem 1).
    pub fn sigma(&self) -> f64 {
        ecr::sigma(self.risk)
    }

    /// Structured validation of the device's QoS parameters — the
    /// engine's `PlanRequest::validate` maps an `Err` to
    /// `PlanError::InvalidRisk`, so a bad ε is a clean API error instead
    /// of an `assert!` panic deep inside a solver thread.
    pub fn validate(&self) -> Result<(), String> {
        risk::validate_risk(self.risk)?;
        if !(self.deadline_s.is_finite() && self.deadline_s > 0.0) {
            return Err(format!("deadline must be positive, got {}", self.deadline_s));
        }
        Ok(())
    }

    /// Uncertainty margin at partition point m under `policy` (the second
    /// term on the LHS of (22), or its baseline analogue).  The robust
    /// family dispatches through its carried [`RiskBound`].
    pub fn margin(&self, m: usize, policy: Policy) -> f64 {
        match policy {
            Policy::Robust(bound) => bound.margin(&self.model, m, self.risk),
            Policy::WorstCase => {
                let vl = self.model.v_loc(m);
                let vv = self.model.v_vm(m);
                self.model.worst_dev_factor * vl.sqrt() + 3.5 * vv.sqrt()
            }
            Policy::MeanOnly => 0.0,
        }
    }

    /// D′_n(m): deadline budget left for local + offload after the VM mean
    /// and the uncertainty margin are reserved.
    pub fn deadline_slack(&self, m: usize, policy: Policy) -> f64 {
        self.deadline_s - self.model.t_vm_mean(m) - self.margin(m, policy)
    }

    /// Mean total time at (m, f, b) — eq. 7 with eq. 10/(3)/(5) means.
    pub fn t_total_mean(&self, m: usize, f_ghz: f64, b_hz: f64) -> f64 {
        self.model.t_loc_mean(m, f_ghz)
            + self.uplink.t_off(self.model.d_bits(m), b_hz)
            + self.model.t_vm_mean(m)
    }

    /// Expected device energy at (m, f, b) — eq. 6 with (2)/(4).
    pub fn energy_mean(&self, m: usize, f_ghz: f64, b_hz: f64) -> f64 {
        let p = &self.model.points[m];
        energy::e_loc_mean(self.model.device.kappa, f_ghz, p.w_gflops, p.g_flops_cycle)
            + self.uplink.e_off(self.model.d_bits(m), b_hz)
    }

    /// Feasibility-friendliest partition point: minimum margin-adjusted
    /// mean total time at f_max and bandwidth `b_hz`.  The one shared
    /// implementation behind every heuristic start (Algorithm 2's, the
    /// enumeration baselines', and the engine's joiner fallback) so the
    /// selection rule cannot drift between them.  Ties keep `min_by`'s
    /// last-minimum semantics (bit-compatible with the historical code).
    pub(crate) fn min_margin_time_point(&self, b_hz: f64, policy: Policy) -> usize {
        let f = self.model.device.f_max_ghz;
        (0..self.model.num_points())
            .min_by(|&a, &b| {
                let ta = self.t_total_mean(a, f, b_hz) + self.margin(a, policy);
                let tb = self.t_total_mean(b, f, b_hz) + self.margin(b, policy);
                // total_cmp: same order as partial_cmp for the non-NaN
                // times produced here, and panic-free.
                ta.total_cmp(&tb)
            })
            .unwrap_or(0)
    }

    /// Deterministic (ECR-transformed) deadline test at (m, f, b) —
    /// constraint (22) and its baseline analogues.
    pub fn deadline_ok(&self, m: usize, f_ghz: f64, b_hz: f64, policy: Policy) -> bool {
        // Small numerical tolerance: interior-point solutions sit on the
        // boundary to within solver tolerance.
        self.deadline_margin(m, f_ghz, b_hz, policy) >= -1e-7 * self.deadline_s
    }

    /// D_n − LHS of (22): ≥ 0 iff the deterministic constraint holds.
    pub fn deadline_margin(&self, m: usize, f_ghz: f64, b_hz: f64, policy: Policy) -> f64 {
        self.deadline_s - self.t_total_mean(m, f_ghz, b_hz) - self.margin(m, policy)
    }
}

/// A multi-device scenario (problem (9) instance).
#[derive(Clone, Debug)]
pub struct Scenario {
    pub devices: Vec<Device>,
    /// Total uplink bandwidth B, Hz.
    pub total_bandwidth_hz: f64,
}

impl Scenario {
    pub fn n(&self) -> usize {
        self.devices.len()
    }

    /// The paper's §VI-A setup: N devices uniform in the 400 m square, all
    /// running `model` with common deadline/risk, bandwidth B.
    pub fn uniform(
        model: &ModelProfile,
        n: usize,
        total_bandwidth_hz: f64,
        deadline_s: f64,
        risk: f64,
        rng: &mut Rng,
    ) -> Scenario {
        let dists = crate::channel::random_distances(n, rng);
        Scenario {
            devices: dists
                .into_iter()
                .map(|r| Device {
                    model: model.clone(),
                    uplink: Uplink::from_distance(r),
                    deadline_s,
                    risk,
                })
                .collect(),
            total_bandwidth_hz,
        }
    }
}

/// A complete decision for a scenario.
#[derive(Clone, Debug, PartialEq)]
pub struct Plan {
    /// Partition point m_n per device.
    pub partition: Vec<usize>,
    /// Uplink bandwidth b_n per device, Hz.
    pub bandwidth_hz: Vec<f64>,
    /// Local CPU/GPU frequency f_n per device, GHz.
    pub freq_ghz: Vec<f64>,
}

impl Plan {
    /// Σ_n E[E_n] — objective (9a).
    pub fn expected_energy(&self, sc: &Scenario) -> f64 {
        sc.devices
            .iter()
            .enumerate()
            .map(|(i, d)| d.energy_mean(self.partition[i], self.freq_ghz[i], self.bandwidth_hz[i]))
            .sum()
    }

    /// All deterministic deadline constraints hold under `policy`.
    pub fn feasible(&self, sc: &Scenario, policy: Policy) -> bool {
        self.violations(sc, policy).is_empty()
    }

    /// Indices of devices whose ECR constraint is violated.
    pub fn violations(&self, sc: &Scenario, policy: Policy) -> Vec<usize> {
        sc.devices
            .iter()
            .enumerate()
            .filter(|(i, d)| {
                !d.deadline_ok(self.partition[*i], self.freq_ghz[*i], self.bandwidth_hz[*i], policy)
            })
            .map(|(i, _)| i)
            .collect()
    }

    /// Bandwidth conservation: Σ b_n ≤ B (constraint (9d)).
    pub fn bandwidth_ok(&self, sc: &Scenario) -> bool {
        self.bandwidth_hz.iter().sum::<f64>() <= sc.total_bandwidth_hz * (1.0 + 1e-9)
    }

    /// Frequency bounds (9g).
    pub fn freq_ok(&self, sc: &Scenario) -> bool {
        self.freq_ghz.iter().zip(&sc.devices).all(|(&f, d)| {
            f >= d.model.device.f_min_ghz - 1e-9 && f <= d.model.device.f_max_ghz + 1e-9
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn device(deadline: f64, risk: f64) -> Device {
        Device {
            model: ModelProfile::alexnet_paper(),
            uplink: Uplink::from_distance(100.0),
            deadline_s: deadline,
            risk,
        }
    }

    #[test]
    fn margins_ordered_by_policy() {
        let d = device(0.2, 0.05);
        for m in 0..d.model.num_points() {
            let robust = d.margin(m, Policy::ROBUST);
            let worst = d.margin(m, Policy::WorstCase);
            let mean = d.margin(m, Policy::MeanOnly);
            assert_eq!(mean, 0.0);
            assert!(robust >= 0.0);
            if m > 0 {
                // AlexNet/CPU: worst factor 8 > σ(0.05) ≈ 4.36, so the
                // worst-case margin dominates the robust one.
                assert!(worst > robust);
            }
        }
    }

    #[test]
    fn deadline_margin_sign_matches_ok() {
        let d = device(0.2, 0.05);
        for m in [0, 4, 8] {
            for policy in [Policy::ROBUST, Policy::WorstCase, Policy::MeanOnly] {
                let margin = d.deadline_margin(m, 1.0, 1e6, policy);
                assert_eq!(margin >= 0.0, d.deadline_ok(m, 1.0, 1e6, policy));
            }
        }
    }

    #[test]
    fn robust_margin_dispatches_through_the_bound() {
        let d = device(0.2, 0.05);
        for m in 0..d.model.num_points() {
            // Back-compat pin: Policy::ROBUST carries RiskBound::Ecr and
            // reproduces the pre-refactor margin bit-for-bit.
            let legacy = d.sigma() * (d.model.v_loc(m) + d.model.v_vm(m)).sqrt();
            assert_eq!(d.margin(m, Policy::ROBUST).to_bits(), legacy.to_bits());
            // Tighter bounds never exceed the ECR margin.
            let gauss = d.margin(m, Policy::Robust(RiskBound::Gaussian));
            let bern = d.margin(m, Policy::Robust(RiskBound::Bernstein));
            assert!(gauss <= legacy + 1e-15 && bern <= legacy + 1e-15);
        }
        assert_eq!(Policy::ROBUST.bound(), Some(RiskBound::Ecr));
        assert_eq!(
            Policy::ROBUST.with_bound(RiskBound::Gaussian),
            Policy::Robust(RiskBound::Gaussian)
        );
        assert_eq!(Policy::MeanOnly.with_bound(RiskBound::Gaussian), Policy::MeanOnly);
    }

    #[test]
    fn device_validation_rejects_bad_qos() {
        assert!(device(0.2, 0.05).validate().is_ok());
        assert!(device(0.2, 0.0).validate().is_err());
        assert!(device(0.2, 1.0).validate().is_err());
        assert!(device(0.2, f64::NAN).validate().is_err());
        assert!(device(-0.1, 0.05).validate().is_err());
    }

    #[test]
    fn energy_splits_local_and_offload() {
        let d = device(0.2, 0.05);
        // m = 0: pure offload (no local energy)
        let e0 = d.energy_mean(0, 0.1, 1e6);
        assert_eq!(e0, d.uplink.e_off(d.model.d_bits(0), 1e6));
        // m = M: tiny offload, dominated by local compute at high f
        let e_full = d.energy_mean(8, 1.2, 1e6);
        assert!(e_full > 0.2, "e_full={e_full}");
    }

    #[test]
    fn scenario_uniform_shapes() {
        let mut rng = Rng::new(3);
        let sc = Scenario::uniform(&ModelProfile::alexnet_paper(), 12, 10e6, 0.18, 0.02, &mut rng);
        assert_eq!(sc.n(), 12);
        assert!(sc.devices.iter().all(|d| d.deadline_s == 0.18));
    }

    #[test]
    fn plan_checks() {
        let mut rng = Rng::new(4);
        let sc = Scenario::uniform(&ModelProfile::alexnet_paper(), 3, 10e6, 0.25, 0.05, &mut rng);
        let plan = Plan {
            partition: vec![2, 2, 2],
            bandwidth_hz: vec![3e6, 3e6, 3e6],
            freq_ghz: vec![1.0, 1.0, 1.0],
        };
        assert!(plan.bandwidth_ok(&sc));
        assert!(plan.freq_ok(&sc));
        assert!(plan.expected_energy(&sc) > 0.0);
        let over = Plan { bandwidth_hz: vec![5e6, 5e6, 5e6], ..plan.clone() };
        assert!(!over.bandwidth_ok(&sc));
    }
}
