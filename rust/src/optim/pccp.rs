//! DNN-partitioning subproblem via the Penalty Convex-Concave Procedure
//! (paper §V-C, Algorithm 1).
//!
//! Given resources (b, f) from the resource subproblem, problem (24)
//! selects the partition x.  The chance constraint becomes the
//! deterministic (28) through the ECR, the binary x is relaxed to [0,1]
//! with the DC constraint x(1−x) ≤ 0 (eqs. 30/31), and the variance term
//! is linearized through the auxiliary y (eq. 32), yielding the DC
//! program (33).  Algorithm 1 solves the sequence of convexified penalty
//! problems (36), growing ρ ← min(νρ, ρ_max) until ‖x⁽ⁱ⁾−x⁽ⁱ⁻¹⁾‖ < θ.
//!
//! Key structural fact exploited here: given (b, f), problem (36) is
//! **separable per device** — the objective is a sum of per-device terms
//! and every constraint involves a single device (constraint (24d) is
//! constant once (24c) holds, because Σ_m x_{n,m} b_n = b_n).  So we run
//! Algorithm 1 on each device's own (2M+5)-variable program instead of
//! one N(2M+5)-variable monolith; the iterates are identical to the
//! joint algorithm's (the joint Newton system is block-diagonal) and the
//! wall-clock is linear in N — this is what Fig. 11 measures.  The same
//! separability makes the scenario-level [`solve`] embarrassingly
//! parallel: devices fan out over scoped worker threads (deterministic
//! per-device slots, see `util::par`), dividing the linear-in-N
//! wall-clock by the core count.

//! ## Risk bounds inside the DC program
//!
//! The deadline constraint (33c) is written `Σ x·t̄ + k·y ≤ D` with `y`
//! linearizing `√(xᵀWx)`.  Bounds that are a pure multiple of the total
//! standard deviation ([`RiskBound::std_factor`]: ECR, Gaussian,
//! Calibrated) plug their coefficient in as `k` — for the default ECR
//! bound this is exactly the paper's σ_n and the iterates are
//! bit-identical to the pre-refactor code.  Bounds with a different
//! shape (Bernstein) instead fold their per-point margin into the
//! linear mean-time coefficients (`t̄_m + margin_m`, `k = 0`): linear in
//! x, exact at the one-hot vertices the relaxation is rounded to, and
//! the margin stays constant per partition point so nothing about the
//! program's convexity analysis changes.

use crate::linalg::Matrix;
use crate::risk::RiskBound;
use crate::solver::{self, BarrierOptions, ConvexProgram};

use super::types::{Device, Policy, Scenario};

/// Algorithm 1 knobs (paper: ρ⁰ > 0, ν > 1, ρ_max, θ_err).
#[derive(Clone, Debug)]
pub struct PccpOptions {
    pub rho0: f64,
    pub rho_max: f64,
    pub nu: f64,
    pub theta_err: f64,
    pub max_iters: usize,
    /// Interior-point options for the inner convex solves.
    pub barrier: BarrierOptions,
    /// Worker threads for the per-device fan-out in [`solve`]
    /// (0 = one per available core, 1 = sequential).  Devices are
    /// independent subproblems, so the thread count never changes the
    /// result — only the wall-clock.
    pub threads: usize,
}

impl Default for PccpOptions {
    fn default() -> Self {
        PccpOptions {
            rho0: 1.0,
            rho_max: 1e6,
            nu: 4.0,
            theta_err: 1e-4,
            max_iters: 60,
            barrier: BarrierOptions { tol: 1e-7, ..BarrierOptions::default() },
            threads: 0,
        }
    }
}

/// Per-device PCCP outcome.
#[derive(Clone, Debug)]
pub struct PccpDeviceResult {
    /// Chosen partition point (rounded from the relaxed stationary x).
    pub m: usize,
    /// Relaxed solution x (diagnostic: should be near one-hot).
    pub x_relaxed: Vec<f64>,
    /// Algorithm-1 outer iterations (Fig. 9's metric).
    pub iters: usize,
    /// Total inner Newton iterations.
    pub newton_iters: usize,
}

/// Whole-scenario outcome.
#[derive(Clone, Debug)]
pub struct PccpResult {
    pub partition: Vec<usize>,
    /// Per-device relaxed iterates — Algorithm 2 feeds these back as the
    /// next outer iteration's warm start.
    pub x_relaxed: Vec<Vec<f64>>,
    /// Mean Algorithm-1 iterations across devices (Fig. 9).
    pub avg_iters: f64,
    pub newton_iters: usize,
}

#[derive(Debug, Clone)]
pub enum PccpError {
    /// No partition point satisfies (28) for this device at the given
    /// resources.
    Infeasible { device: usize },
    Solver(String),
}

impl std::fmt::Display for PccpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PccpError::Infeasible { device } => {
                write!(f, "no feasible partition point for device {device}")
            }
            PccpError::Solver(e) => write!(f, "solver failure: {e}"),
        }
    }
}

impl std::error::Error for PccpError {}

/// Per-device data for problem (36).
struct DeviceProblem {
    /// Energy coefficient per point (objective (24a) terms at fixed f, b).
    cost: Vec<f64>,
    /// Mean total time per point t̄_{n,m} (eq. 26) — plus the per-point
    /// linear margin when the active bound is not std-shaped (see the
    /// module docs; zero extra term for ECR, so bit-identical there).
    t_mean: Vec<f64>,
    /// Covariance diagonal w_{n,m,m} (eq. 27).
    w_diag: Vec<f64>,
    /// Coefficient on the linearized std-dev y: σ_n for the default ECR
    /// bound (Theorem 1), the bound's `std_factor` otherwise, 0 for
    /// linear-margin bounds.
    sigma: f64,
    /// Deadline D_n.
    deadline: f64,
    /// Linearization point from the previous PCCP iterate.
    x_prev: Vec<f64>,
    y_prev: f64,
    /// Penalty ρ⁽ⁱ⁻¹⁾.
    rho: f64,
    /// Strictly feasible start for the inner barrier.
    start: Vec<f64>,
}

// Variable layout: z = [x_0..x_M, y, alpha, beta, gamma_0..gamma_M]
// sizes:            M+1,          1,  1,    1,     M+1        => 2M+5
//
// Inequalities:
//   0..=M        : -x_m ≤ 0
//   M+1..=2M+1   : x_m − 1 ≤ 0
//   2M+2         : Σ x t̄ + σ y − D ≤ 0                      (33c)
//   2M+3         : −y ≤ 0                                    (33g)
//   2M+4         : Σ w x² − y_prev(2y − y_prev) − α ≤ 0      (36c)
//   2M+5         : y² − Σ w x_prev(2x − x_prev) − β ≤ 0      (36d)
//   2M+6..=3M+6  : x_m(1−2x_prev) + x_prev² − γ_m ≤ 0        (36e)
//   3M+7         : −α ≤ 0
//   3M+8         : −β ≤ 0
//   3M+9..=4M+9  : −γ_m ≤ 0
// Equality: Σ x_m = 1 (24c).
impl DeviceProblem {
    fn mp1(&self) -> usize {
        self.cost.len()
    }

    fn idx_y(&self) -> usize {
        self.mp1()
    }

    fn idx_alpha(&self) -> usize {
        self.mp1() + 1
    }

    fn idx_beta(&self) -> usize {
        self.mp1() + 2
    }

    fn idx_gamma(&self, m: usize) -> usize {
        self.mp1() + 3 + m
    }
}

impl ConvexProgram for DeviceProblem {
    fn num_vars(&self) -> usize {
        2 * self.mp1() + 3
    }

    fn num_ineq(&self) -> usize {
        4 * self.mp1() + 6
    }

    fn objective(&self, z: &[f64]) -> f64 {
        let mut v = 0.0;
        for m in 0..self.mp1() {
            v += self.cost[m] * z[m] + self.rho * z[self.idx_gamma(m)];
        }
        v + self.rho * (z[self.idx_alpha()] + z[self.idx_beta()])
    }

    fn gradient(&self, z: &[f64], g: &mut [f64]) {
        g.iter_mut().for_each(|v| *v = 0.0);
        let _ = z;
        for m in 0..self.mp1() {
            g[m] = self.cost[m];
            g[self.idx_gamma(m)] = self.rho;
        }
        g[self.idx_alpha()] = self.rho;
        g[self.idx_beta()] = self.rho;
    }

    fn hessian_accum(&self, _z: &[f64], _scale: f64, _h: &mut Matrix) {
        // linear objective
    }

    fn constraint(&self, c: usize, z: &[f64]) -> f64 {
        let mp1 = self.mp1();
        let y = z[self.idx_y()];
        if c <= mp1 - 1 {
            return -z[c];
        }
        if c <= 2 * mp1 - 1 {
            return z[c - mp1] - 1.0;
        }
        let c = c - 2 * mp1;
        match c {
            0 => {
                let mut v = self.sigma * y - self.deadline;
                for m in 0..mp1 {
                    v += z[m] * self.t_mean[m];
                }
                v
            }
            1 => -y,
            2 => {
                let mut v = -self.y_prev * (2.0 * y - self.y_prev) - z[self.idx_alpha()];
                for m in 0..mp1 {
                    v += self.w_diag[m] * z[m] * z[m];
                }
                v
            }
            3 => {
                let mut v = y * y - z[self.idx_beta()];
                for m in 0..mp1 {
                    v -= self.w_diag[m] * self.x_prev[m] * (2.0 * z[m] - self.x_prev[m]);
                }
                v
            }
            c if c <= mp1 + 3 => {
                let m = c - 4;
                z[m] * (1.0 - 2.0 * self.x_prev[m]) + self.x_prev[m] * self.x_prev[m]
                    - z[self.idx_gamma(m)]
            }
            c if c == mp1 + 4 => -z[self.idx_alpha()],
            c if c == mp1 + 5 => -z[self.idx_beta()],
            c => -z[self.idx_gamma(c - mp1 - 6)],
        }
    }

    fn constraint_grad(&self, c: usize, z: &[f64], g: &mut [f64]) {
        g.iter_mut().for_each(|v| *v = 0.0);
        let mp1 = self.mp1();
        if c <= mp1 - 1 {
            g[c] = -1.0;
            return;
        }
        if c <= 2 * mp1 - 1 {
            g[c - mp1] = 1.0;
            return;
        }
        let c = c - 2 * mp1;
        match c {
            0 => {
                for m in 0..mp1 {
                    g[m] = self.t_mean[m];
                }
                g[self.idx_y()] = self.sigma;
            }
            1 => g[self.idx_y()] = -1.0,
            2 => {
                for m in 0..mp1 {
                    g[m] = 2.0 * self.w_diag[m] * z[m];
                }
                g[self.idx_y()] = -2.0 * self.y_prev;
                g[self.idx_alpha()] = -1.0;
            }
            3 => {
                for m in 0..mp1 {
                    g[m] = -2.0 * self.w_diag[m] * self.x_prev[m];
                }
                g[self.idx_y()] = 2.0 * z[self.idx_y()];
                g[self.idx_beta()] = -1.0;
            }
            c if c <= mp1 + 3 => {
                let m = c - 4;
                g[m] = 1.0 - 2.0 * self.x_prev[m];
                g[self.idx_gamma(m)] = -1.0;
            }
            c if c == mp1 + 4 => g[self.idx_alpha()] = -1.0,
            c if c == mp1 + 5 => g[self.idx_beta()] = -1.0,
            c => g[self.idx_gamma(c - mp1 - 6)] = -1.0,
        }
    }

    fn constraint_hess_accum(&self, c: usize, _z: &[f64], scale: f64, h: &mut Matrix) {
        let mp1 = self.mp1();
        if c < 2 * mp1 {
            return;
        }
        match c - 2 * mp1 {
            2 => {
                for m in 0..mp1 {
                    h[(m, m)] += scale * 2.0 * self.w_diag[m];
                }
            }
            3 => {
                let y = self.idx_y();
                h[(y, y)] += scale * 2.0;
            }
            _ => {}
        }
    }

    fn equalities(&self) -> Option<(Matrix, Vec<f64>)> {
        let mut a = Matrix::zeros(1, self.num_vars());
        for m in 0..self.mp1() {
            a[(0, m)] = 1.0;
        }
        Some((a, vec![1.0]))
    }

    fn initial_point(&self) -> Vec<f64> {
        self.start.clone()
    }
}

/// Build a strictly feasible inner start around a given relaxed x.
/// Tries progressively smaller clamping floors so that even a start
/// sitting within 0.1% of the (relaxed) deadline boundary admits a
/// strictly interior point.
fn feasible_start(p: &mut DeviceProblem, x: &[f64]) -> bool {
    // Blend toward the argmax vertex: at a deadline-tight iterate only a
    // nearly pure one-hot admits strict interiority, so shrink the mixing
    // mass until the start fits (θ = 1 keeps x as-is).
    let argmax = x
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(m, _)| m)
        .unwrap_or(0);
    for theta in [1.0, 0.3, 0.03, 3e-3, 3e-4, 3e-5] {
        let blended: Vec<f64> = x
            .iter()
            .enumerate()
            .map(|(m, &v)| {
                let vertex = if m == argmax { 1.0 } else { 0.0 };
                (1.0 - theta) * vertex + theta * v
            })
            .collect();
        for floor in [1e-4, 1e-7, 1e-9] {
            if theta < 1.0 && floor > theta * 1e-2 {
                continue; // floor would undo the blend
            }
            if feasible_start_clamped(p, &blended, floor) {
                return true;
            }
        }
    }
    false
}

fn feasible_start_clamped(p: &mut DeviceProblem, x: &[f64], floor: f64) -> bool {
    let mp1 = p.mp1();
    // Clamp x inside the open simplex.
    let mut xs: Vec<f64> = x.iter().map(|&v| v.clamp(floor, 1.0 - floor)).collect();
    let s: f64 = xs.iter().sum();
    xs.iter_mut().for_each(|v| *v /= s);

    // (33c) must hold strictly with y near √(Σ w x²).
    let y0 = xs
        .iter()
        .zip(&p.w_diag)
        .map(|(x, w)| w * x * x)
        .sum::<f64>()
        .sqrt()
        .max(1e-9);
    let lhs: f64 =
        xs.iter().zip(&p.t_mean).map(|(x, t)| x * t).sum::<f64>() + p.sigma * y0;
    if lhs >= p.deadline * (1.0 - 1e-9) {
        return false;
    }

    let mut z = vec![0.0; 2 * mp1 + 3];
    z[..mp1].copy_from_slice(&xs);
    z[mp1] = y0;
    // Slacks: strictly above current constraint values.
    let margin = 1e-3;
    let quad: f64 = xs.iter().zip(&p.w_diag).map(|(x, w)| w * x * x).sum();
    z[mp1 + 1] = (quad - p.y_prev * (2.0 * y0 - p.y_prev)).max(0.0) + margin; // alpha
    let lin: f64 = p
        .x_prev
        .iter()
        .zip(&xs)
        .zip(&p.w_diag)
        .map(|((xp, x), w)| w * xp * (2.0 * x - xp))
        .sum();
    z[mp1 + 2] = (y0 * y0 - lin).max(0.0) + margin; // beta
    for m in 0..mp1 {
        let v = xs[m] * (1.0 - 2.0 * p.x_prev[m]) + p.x_prev[m] * p.x_prev[m];
        z[mp1 + 3 + m] = v.max(0.0) + margin; // gamma
    }
    p.start = z;
    true
}

/// Assemble the per-device problem data at fixed resources under the
/// given risk bound.
fn device_problem(
    dev: &Device,
    m_pts: usize,
    f_ghz: f64,
    b_hz: f64,
    rho: f64,
    bound: RiskBound,
) -> DeviceProblem {
    let cost: Vec<f64> = (0..m_pts).map(|m| dev.energy_mean(m, f_ghz, b_hz)).collect();
    let w_diag: Vec<f64> = (0..m_pts).map(|m| dev.model.w_diag(m)).collect();
    // std-shaped bounds keep the exact σ·√(xᵀWx) coupling; the rest
    // enter as a linear per-point margin on the mean-time coefficients.
    let (sigma, t_mean): (f64, Vec<f64>) = match bound.std_factor(dev.risk) {
        Some(k) => (k, (0..m_pts).map(|m| dev.t_total_mean(m, f_ghz, b_hz)).collect()),
        None => (
            0.0,
            (0..m_pts)
                .map(|m| dev.t_total_mean(m, f_ghz, b_hz) + bound.margin(&dev.model, m, dev.risk))
                .collect(),
        ),
    };
    DeviceProblem {
        cost,
        t_mean,
        w_diag,
        sigma,
        // Relax the inner deadline by 0.1%: the resource step leaves (22)
        // *active* at the current point (energy is decreasing in slack),
        // so the exact-deadline relaxation has no strict interior there.
        // Rounding checks against the true deadline, so no violation can
        // leak into the final plan.
        deadline: dev.deadline_s * (1.0 + 1e-3),
        x_prev: vec![1.0 / m_pts as f64; m_pts],
        y_prev: 1e-3,
        rho,
        start: vec![],
    }
}

/// Feasible one-hot candidates under (28) at the given resources.
fn feasible_points(dev: &Device, f_ghz: f64, b_hz: f64, policy: Policy) -> Vec<usize> {
    (0..dev.model.num_points())
        .filter(|&m| dev.deadline_ok(m, f_ghz, b_hz, policy))
        .collect()
}

/// Run Algorithm 1 for one device under `bound`.  `x_init` seeds the
/// first linearization (Algorithm 2 passes the previous outer iterate
/// for warm starting).
pub fn solve_device(
    dev: &Device,
    f_ghz: f64,
    b_hz: f64,
    opts: &PccpOptions,
    x_init: Option<&[f64]>,
    bound: RiskBound,
) -> Result<PccpDeviceResult, PccpError> {
    let mp1 = dev.model.num_points();
    let feas = feasible_points(dev, f_ghz, b_hz, Policy::Robust(bound));
    if feas.is_empty() {
        return Err(PccpError::Infeasible { device: usize::MAX });
    }

    // Initial relaxed x: warm start if provided, else mass on the cheapest
    // feasible one-hot point (smoothed into the simplex interior).
    let seed = match x_init {
        Some(x) if x.len() == mp1 => x.to_vec(),
        _ => {
            let best = *feas
                .iter()
                .min_by(|&&a, &&b| {
                    dev.energy_mean(a, f_ghz, b_hz).total_cmp(&dev.energy_mean(b, f_ghz, b_hz))
                })
                // lint:allow(panic-path): feas verified non-empty at entry
                .unwrap();
            let mut x = vec![0.02 / (mp1 - 1) as f64; mp1];
            x[best] = 0.98;
            x
        }
    };

    let mut rho = opts.rho0;
    let mut x = seed;
    let mut y = x
        .iter()
        .enumerate()
        .map(|(m, &v)| dev.model.w_diag(m) * v * v)
        .sum::<f64>()
        .sqrt()
        .max(1e-7);
    let mut newton_total = 0;
    let mut iters = 0;

    // The problem data (cost / t̄ / w) is fixed across Algorithm-1
    // iterations — only the linearization point (x_prev, y_prev) and the
    // penalty ρ move — so build it once and update in place.  One Newton
    // workspace serves every inner barrier solve of this device.
    let mut prob = device_problem(dev, mp1, f_ghz, b_hz, rho, bound);
    let mut ws = solver::NewtonWorkspace::new();

    for i in 0..opts.max_iters {
        iters = i + 1;
        prob.rho = rho;
        prob.x_prev.copy_from_slice(&x);
        prob.y_prev = y;
        if !feasible_start(&mut prob, &x) {
            // The relaxed iterate drifted infeasible for (33c) — restart
            // the linearization from the cheapest feasible one-hot.
            let best = feas[0];
            let mut xr = vec![0.02 / (mp1 - 1) as f64; mp1];
            xr[best] = 0.98;
            prob.x_prev.copy_from_slice(&xr);
            prob.y_prev = (dev.model.w_diag(best)).sqrt().max(1e-7);
            if !feasible_start(&mut prob, &xr) {
                return Err(PccpError::Infeasible { device: usize::MAX });
            }
        }
        let sol = solver::solve_with(&prob, &opts.barrier, &mut ws)
            .map_err(|e| PccpError::Solver(e.to_string()))?;
        newton_total += sol.newton_iters;
        let x_new = sol.x[..mp1].to_vec();
        let y_new = sol.x[mp1];

        let delta: f64 = x_new
            .iter()
            .zip(&x)
            .map(|(a, b)| (a - b) * (a - b))
            .sum::<f64>()
            .sqrt();
        x = x_new;
        y = y_new.max(1e-9);
        rho = (rho * opts.nu).min(opts.rho_max);
        if delta < opts.theta_err && i > 0 {
            break;
        }
    }

    // Round to one-hot; fall back to the best feasible point if the argmax
    // violates (28) (can happen when the relaxation is loose).
    let argmax = x
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(m, _)| m)
        .unwrap_or(0);
    let m_final = if feas.contains(&argmax) {
        argmax
    } else {
        *feas
            .iter()
            .min_by(|&&a, &&b| {
                dev.energy_mean(a, f_ghz, b_hz).total_cmp(&dev.energy_mean(b, f_ghz, b_hz))
            })
            // lint:allow(panic-path): feas verified non-empty at entry
            .unwrap()
    };

    Ok(PccpDeviceResult { m: m_final, x_relaxed: x, iters, newton_iters: newton_total })
}

/// Run Algorithm 1 across a scenario at fixed resources (the partitioning
/// half of Algorithm 2's alternation).
///
/// The per-device subproblems are independent (see the module docs), so
/// they fan out over `opts.threads` scoped workers.  Results land in
/// per-device slots and are folded in device order, so the outcome —
/// including which device's error is reported — is identical to the
/// sequential path at any thread count.
pub fn solve(
    sc: &Scenario,
    freq_ghz: &[f64],
    bandwidth_hz: &[f64],
    opts: &PccpOptions,
    warm: Option<&[Vec<f64>]>,
    bound: RiskBound,
) -> Result<PccpResult, PccpError> {
    let n = sc.n();
    // Cheap O(N·M) pre-scan for the dominant error mode so a
    // deadline-infeasible device short-circuits before the fan-out pays
    // for the other devices' full Algorithm-1 runs.  Reports the lowest
    // infeasible device index; a rarer in-solve failure (numerical error
    // on an earlier device) is surfaced by the index-ordered fold below.
    for (i, dev) in sc.devices.iter().enumerate() {
        if feasible_points(dev, freq_ghz[i], bandwidth_hz[i], Policy::Robust(bound)).is_empty() {
            return Err(PccpError::Infeasible { device: i });
        }
    }
    let threads = crate::util::par::threads_for(opts.threads, n);
    let results = crate::util::par::par_map_indexed(n, threads, |i| {
        let w = warm.and_then(|w| w.get(i)).map(|v| v.as_slice());
        solve_device(&sc.devices[i], freq_ghz[i], bandwidth_hz[i], opts, w, bound).map_err(
            |e| match e {
                PccpError::Infeasible { .. } => PccpError::Infeasible { device: i },
                e => e,
            },
        )
    });
    let mut partition = Vec::with_capacity(n);
    let mut x_relaxed = Vec::with_capacity(n);
    let mut iter_sum = 0usize;
    let mut newton = 0usize;
    for r in results {
        let PccpDeviceResult { m, x_relaxed: xr, iters, newton_iters } = r?;
        iter_sum += iters;
        newton += newton_iters;
        partition.push(m);
        x_relaxed.push(xr);
    }
    Ok(PccpResult {
        partition,
        x_relaxed,
        avg_iters: iter_sum as f64 / n as f64,
        newton_iters: newton,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelProfile;
    use crate::util::rng::Rng;

    fn scenario(n: usize, deadline: f64, risk: f64, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), n, 10e6, deadline, risk, &mut rng)
    }

    #[test]
    fn device_problem_constraint_gradients_match_fd() {
        // Finite-difference check of every constraint gradient at a
        // feasible interior point.
        let sc = scenario(1, 0.25, 0.05, 1);
        let dev = &sc.devices[0];
        let mp1 = dev.model.num_points();
        let mut p = device_problem(dev, mp1, 1.0, 2e6, 3.0, RiskBound::Ecr);
        let x0 = vec![1.0 / mp1 as f64; mp1];
        assert!(feasible_start(&mut p, &x0));
        let z = p.initial_point();
        let mut g = vec![0.0; p.num_vars()];
        for c in 0..p.num_ineq() {
            p.constraint_grad(c, &z, &mut g);
            for j in 0..p.num_vars() {
                let h = 1e-7;
                let mut zp = z.clone();
                zp[j] += h;
                let mut zm = z.clone();
                zm[j] -= h;
                let fd = (p.constraint(c, &zp) - p.constraint(c, &zm)) / (2.0 * h);
                assert!(
                    (fd - g[j]).abs() < 1e-5 * (1.0 + fd.abs()),
                    "constraint {c} var {j}: fd={fd} analytic={}",
                    g[j]
                );
            }
        }
    }

    #[test]
    fn pccp_returns_feasible_onehot() {
        let sc = scenario(6, 0.22, 0.05, 2);
        let f: Vec<f64> = vec![1.1; 6];
        let b: Vec<f64> = vec![10e6 / 6.0; 6];
        let r = solve(&sc, &f, &b, &PccpOptions::default(), None, RiskBound::Ecr).unwrap();
        assert_eq!(r.partition.len(), 6);
        for (i, (&m, dev)) in r.partition.iter().zip(&sc.devices).enumerate() {
            assert!(
                dev.deadline_ok(m, f[i], b[i], Policy::ROBUST),
                "device {i} point {m} violates (28)"
            );
        }
        assert!(r.avg_iters >= 1.0);
    }

    #[test]
    fn relaxed_solution_is_near_binary() {
        let sc = scenario(1, 0.25, 0.05, 3);
        let r =
            solve_device(&sc.devices[0], 1.0, 3e6, &PccpOptions::default(), None, RiskBound::Ecr)
                .unwrap();
        // penalty should push x to a vertex: max component > 0.9
        let mx = r.x_relaxed.iter().cloned().fold(0.0, f64::max);
        assert!(mx > 0.9, "x_relaxed={:?}", r.x_relaxed);
        let sum: f64 = r.x_relaxed.iter().sum();
        assert!((sum - 1.0).abs() < 1e-5);
    }

    #[test]
    fn pccp_tracks_energy_tradeoff() {
        // With a generous deadline and scarce bandwidth, full offload
        // (m = 0, big raw transfer) should not be chosen when a cheaper
        // intermediate point exists; with a huge bandwidth and a short
        // deadline, offloading early becomes attractive.  We only assert
        // the PCCP choice is no worse than exhaustive per-device search.
        let sc = scenario(4, 0.22, 0.04, 4);
        let f = vec![1.0; 4];
        let b = vec![2.5e6; 4];
        let r = solve(&sc, &f, &b, &PccpOptions::default(), None, RiskBound::Ecr).unwrap();
        for (i, dev) in sc.devices.iter().enumerate() {
            let best = feasible_points(dev, f[i], b[i], Policy::ROBUST)
                .into_iter()
                .min_by(|&a, &b2| {
                    dev.energy_mean(a, f[i], b[i])
                        .partial_cmp(&dev.energy_mean(b2, f[i], b[i]))
                        .unwrap()
                })
                .unwrap();
            let e_pccp = dev.energy_mean(r.partition[i], f[i], b[i]);
            let e_best = dev.energy_mean(best, f[i], b[i]);
            assert!(
                e_pccp <= e_best * 1.05 + 1e-9,
                "device {i}: pccp point {} ({e_pccp}) vs best {best} ({e_best})",
                r.partition[i]
            );
        }
    }

    #[test]
    fn infeasible_when_no_point_fits() {
        let sc = scenario(1, 0.002, 0.05, 5); // 2 ms deadline: impossible
        let r = solve(&sc, &[1.2], &[10e6], &PccpOptions::default(), None, RiskBound::Ecr);
        assert!(matches!(r, Err(PccpError::Infeasible { device: 0 })));
    }

    #[test]
    fn parallel_matches_sequential() {
        // 12 devices solved sequentially and with the thread-pool fan-out
        // must agree exactly: same partitions, bitwise-equal relaxed
        // iterates, same iteration accounting.
        let sc = scenario(12, 0.25, 0.05, 21);
        let f = vec![1.1; 12];
        let b = vec![10e6 / 6.0; 12];
        let seq_opts = PccpOptions { threads: 1, ..PccpOptions::default() };
        let par_opts = PccpOptions { threads: 4, ..PccpOptions::default() };
        let seq = solve(&sc, &f, &b, &seq_opts, None, RiskBound::Ecr).unwrap();
        let par = solve(&sc, &f, &b, &par_opts, None, RiskBound::Ecr).unwrap();
        assert_eq!(seq.partition, par.partition);
        assert_eq!(seq.newton_iters, par.newton_iters);
        assert_eq!(seq.avg_iters, par.avg_iters);
        for (i, (a, b)) in seq.x_relaxed.iter().zip(&par.x_relaxed).enumerate() {
            assert_eq!(a, b, "device {i} relaxed iterate differs");
        }
    }

    #[test]
    fn linear_margin_bound_returns_feasible_onehot() {
        // Bernstein takes the sigma = 0 / per-point-margin path through
        // the DC program; the rounded answer must satisfy (28) under its
        // own margins and be no worse than exact per-device enumeration.
        let sc = scenario(4, 0.22, 0.04, 14);
        let f = vec![1.0; 4];
        let b = vec![2.5e6; 4];
        let pol = Policy::Robust(RiskBound::Bernstein);
        let r = solve(&sc, &f, &b, &PccpOptions::default(), None, RiskBound::Bernstein).unwrap();
        for (i, (&m, dev)) in r.partition.iter().zip(&sc.devices).enumerate() {
            assert!(dev.deadline_ok(m, f[i], b[i], pol), "device {i} point {m} violates (28)");
            let best = feasible_points(dev, f[i], b[i], pol)
                .into_iter()
                .min_by(|&a, &b2| {
                    dev.energy_mean(a, f[i], b[i])
                        .partial_cmp(&dev.energy_mean(b2, f[i], b[i]))
                        .unwrap()
                })
                .unwrap();
            let e_pccp = dev.energy_mean(r.partition[i], f[i], b[i]);
            let e_best = dev.energy_mean(best, f[i], b[i]);
            assert!(e_pccp <= e_best * 1.05 + 1e-9, "device {i}: {e_pccp} vs {e_best}");
        }
    }

    #[test]
    fn warm_start_converges_fast() {
        let sc = scenario(1, 0.22, 0.05, 6);
        let cold =
            solve_device(&sc.devices[0], 1.0, 3e6, &PccpOptions::default(), None, RiskBound::Ecr)
                .unwrap();
        let warm = solve_device(
            &sc.devices[0],
            1.0,
            3e6,
            &PccpOptions::default(),
            Some(&cold.x_relaxed),
            RiskBound::Ecr,
        )
        .unwrap();
        assert_eq!(warm.m, cold.m);
        assert!(warm.iters <= cold.iters);
    }
}
