//! Benchmark policies (§VI-A):
//!
//! * **worst-case** — plans with the empirical upper bound of the
//!   inference time and allows no deadline violation (Policy::WorstCase
//!   margins inside the same alternation skeleton);
//! * **optimal** — exhaustive search over partition assignments with a
//!   full resource solve per assignment (complexity O(Mᴺ), like the
//!   paper's optimal policy; only run for small N) plus a polynomial
//!   multi-start refinement used at larger N where Mᴺ is intractable;
//! * **mean-only** — ignores uncertainty (margin 0); the violation
//!   figures use it to show why robustness is needed.
//!
//! The partitioning step of the baselines is *exact per-device
//! enumeration*: at fixed (b, f) the partition problem decomposes per
//! device, so enumerating the M+1 points per device is the optimal
//! coordinate step (no relaxation needed — this is the advantage the
//! baselines get over PCCP, paid for with the stronger margins).

use super::resource::{self, ResourceError};
use super::types::{Plan, Policy, Scenario};
use crate::solver;
use crate::util::rng::Rng;

/// Outcome of a baseline policy.
#[derive(Clone, Debug)]
pub struct BaselinePlan {
    pub plan: Plan,
    pub energy: f64,
    pub outer_iters: usize,
    /// Total Newton iterations across every resource solve the policy
    /// issued (the engine facade reports this in its diagnostics).
    pub newton_iters: usize,
}

/// Baseline failure.  `infeasible` distinguishes "no decision satisfies
/// the deadlines" from a numerical solver breakdown — carried
/// structurally so downstream classification (`engine::PlanError`) never
/// depends on message wording.
#[derive(Debug, Clone)]
pub struct BaselineError {
    /// Human-readable detail.
    pub message: String,
    /// The failure is an infeasibility, not a solver error.
    pub infeasible: bool,
}

impl BaselineError {
    fn infeasibility(message: impl Into<String>) -> BaselineError {
        BaselineError { message: message.into(), infeasible: true }
    }
}

impl From<ResourceError> for BaselineError {
    fn from(e: ResourceError) -> BaselineError {
        BaselineError {
            message: e.to_string(),
            infeasible: matches!(e, ResourceError::Infeasible { .. }),
        }
    }
}

impl std::fmt::Display for BaselineError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "baseline failed: {}", self.message)
    }
}

impl std::error::Error for BaselineError {}

/// Per-device optimal point at fixed resources under `policy` (also the
/// engine's replan-refinement step — shared so the accept logic cannot
/// drift between the two).
pub(crate) fn best_point(
    sc: &Scenario,
    i: usize,
    f_ghz: f64,
    b_hz: f64,
    policy: Policy,
) -> Option<usize> {
    let d = &sc.devices[i];
    (0..d.model.num_points())
        .filter(|&m| d.deadline_ok(m, f_ghz, b_hz, policy))
        .min_by(|&a, &b| d.energy_mean(a, f_ghz, b_hz).total_cmp(&d.energy_mean(b, f_ghz, b_hz)))
}

/// Feasibility-friendly start under `policy` (minimum margin-adjusted
/// total time at f_max, equal bandwidth split).
fn start_partition(sc: &Scenario, policy: Policy) -> Vec<usize> {
    let b_each = sc.total_bandwidth_hz / sc.n() as f64;
    sc.devices.iter().map(|d| d.min_margin_time_point(b_each, policy)).collect()
}

/// Alternation with exact per-device enumeration for the partition step.
pub fn alternate_enumeration(
    sc: &Scenario,
    policy: Policy,
    init: Option<Vec<usize>>,
    max_outer: usize,
) -> Result<BaselinePlan, BaselineError> {
    alternate_enumeration_core(sc, policy, init, max_outer, &mut solver::NewtonWorkspace::new())
}

/// [`alternate_enumeration`] with a caller-owned Newton workspace (the
/// engine facade threads its long-lived workspace through; every
/// resource solve stays cold-started so iterates match the legacy path
/// bit-for-bit).
pub(crate) fn alternate_enumeration_core(
    sc: &Scenario,
    policy: Policy,
    init: Option<Vec<usize>>,
    max_outer: usize,
    ws: &mut solver::NewtonWorkspace,
) -> Result<BaselinePlan, BaselineError> {
    let mut partition = init.unwrap_or_else(|| start_partition(sc, policy));
    let mut newton = 0usize;
    let mut res = match resource::solve_warm_with(sc, &partition, policy, None, ws) {
        Ok(r) => r,
        Err(_) => {
            partition = start_partition(sc, policy);
            resource::solve_warm_with(sc, &partition, policy, None, ws)
                .map_err(BaselineError::from)?
        }
    };
    newton += res.newton_iters;
    let mut outer = 0;
    for k in 0..max_outer {
        outer = k + 1;
        let new_partition: Vec<usize> = (0..sc.n())
            .map(|i| {
                best_point(sc, i, res.freq_ghz[i], res.bandwidth_hz[i], policy)
                    .unwrap_or(partition[i])
            })
            .collect();
        if new_partition == partition {
            break;
        }
        match resource::solve_warm_with(sc, &new_partition, policy, None, ws) {
            Ok(r) if r.energy <= res.energy * (1.0 + 1e-9) => {
                newton += r.newton_iters;
                partition = new_partition;
                res = r;
            }
            Ok(r) => {
                newton += r.newton_iters;
                break;
            }
            Err(_) => break,
        }
    }
    Ok(BaselinePlan {
        plan: Plan {
            partition,
            bandwidth_hz: res.bandwidth_hz,
            freq_ghz: res.freq_ghz,
        },
        energy: res.energy,
        outer_iters: outer,
        newton_iters: newton,
    })
}

/// Worst-case policy (§VI-A benchmark 1).
#[deprecated(note = "construct an engine::Planner and call plan() with engine::Policy::WorstCase")]
pub fn worst_case(sc: &Scenario) -> Result<BaselinePlan, BaselineError> {
    alternate_enumeration(sc, Policy::WorstCase, None, 20)
}

/// Mean-only policy (no uncertainty margin).
#[deprecated(note = "construct an engine::Planner and call plan() with engine::Policy::MeanOnly")]
pub fn mean_only(sc: &Scenario) -> Result<BaselinePlan, BaselineError> {
    alternate_enumeration(sc, Policy::MeanOnly, None, 20)
}

/// True exhaustive optimal: every xᴺ assignment with a resource solve.
/// O((M+1)ᴺ·IPT) — callable only for tiny N (tests / Fig. 12 left edge).
#[deprecated(note = "construct an engine::Planner and call plan() with engine::Policy::Exhaustive")]
pub fn exhaustive_optimal(sc: &Scenario) -> Result<BaselinePlan, BaselineError> {
    exhaustive_core(sc, Policy::ROBUST, &mut solver::NewtonWorkspace::new())
}

/// [`exhaustive_optimal`]'s implementation with a caller-owned workspace
/// and an explicit margin policy (the engine passes the request's risk
/// bound through here, so the exhaustive benchmark is comparable to the
/// robust plan under the same transform).
pub(crate) fn exhaustive_core(
    sc: &Scenario,
    policy: Policy,
    ws: &mut solver::NewtonWorkspace,
) -> Result<BaselinePlan, BaselineError> {
    let mp1: Vec<usize> = sc.devices.iter().map(|d| d.model.num_points()).collect();
    let total: usize = mp1.iter().product();
    assert!(total <= 1_000_000, "exhaustive search over {total} assignments refused");
    let mut best: Option<BaselinePlan> = None;
    let mut newton = 0usize;
    let mut assignment = vec![0usize; sc.n()];
    for idx in 0..total {
        let mut rem = idx;
        for i in 0..sc.n() {
            assignment[i] = rem % mp1[i];
            rem /= mp1[i];
        }
        if let Ok(r) = resource::solve_warm_with(sc, &assignment, policy, None, ws) {
            newton += r.newton_iters;
            if best.as_ref().map_or(true, |b| r.energy < b.energy) {
                best = Some(BaselinePlan {
                    plan: Plan {
                        partition: assignment.clone(),
                        bandwidth_hz: r.bandwidth_hz,
                        freq_ghz: r.freq_ghz,
                    },
                    energy: r.energy,
                    outer_iters: 1,
                    newton_iters: 0,
                });
            }
        }
    }
    best.map(|mut b| {
        // the search's total interior-point work, not just the winner's
        b.newton_iters = newton;
        b
    })
    .ok_or_else(|| BaselineError::infeasibility("no assignment satisfies the deadlines"))
}

/// Practical "optimal" at larger N: multi-start alternation with exact
/// enumeration steps, keeping the best of `restarts` random initial
/// partitions (documented substitution for Mᴺ search — see DESIGN.md).
pub fn multistart_optimal(
    sc: &Scenario,
    restarts: usize,
    seed: u64,
) -> Result<BaselinePlan, BaselineError> {
    let mut rng = Rng::new(seed);
    let mut best: Option<BaselinePlan> = None;
    for r in 0..restarts.max(1) {
        let init = if r == 0 {
            None
        } else {
            Some(
                sc.devices
                    .iter()
                    .map(|d| rng.below(d.model.num_points()))
                    .collect::<Vec<_>>(),
            )
        };
        if let Ok(p) = alternate_enumeration(sc, Policy::ROBUST, init, 20) {
            if best.as_ref().map_or(true, |b| p.energy < b.energy) {
                best = Some(p);
            }
        }
    }
    best.ok_or_else(|| BaselineError::infeasibility("all restarts infeasible"))
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ResourceFeasibility {
    Feasible,
    Infeasible,
}

/// Quick feasibility probe for a policy (used by figures to annotate
/// regimes where the worst-case baseline cannot operate at all).
pub fn policy_feasible(sc: &Scenario, policy: Policy) -> ResourceFeasibility {
    match resource::solve(sc, &start_partition(sc, policy), policy) {
        Ok(_) => ResourceFeasibility::Feasible,
        Err(ResourceError::Infeasible { .. }) | Err(ResourceError::Solver(_)) => {
            ResourceFeasibility::Infeasible
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the legacy entry points stay covered until removal

    use super::*;
    use crate::models::ModelProfile;
    use crate::optim::alternating::{self, AlternatingOptions};

    fn scenario(n: usize, d: f64, eps: f64, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), n, 10e6, d, eps, &mut rng)
    }

    #[test]
    fn worst_case_plan_is_feasible_under_its_policy() {
        let sc = scenario(6, 0.22, 0.02, 1);
        let r = worst_case(&sc).unwrap();
        assert!(r.plan.feasible(&sc, Policy::WorstCase));
        assert!(r.plan.bandwidth_ok(&sc));
    }

    #[test]
    fn robust_saves_energy_vs_worst_case_alexnet() {
        // Fig. 13(a)'s headline: at ε = 0.02 the proposal already beats
        // the worst-case policy on AlexNet.
        let sc = scenario(8, 0.20, 0.02, 2);
        let robust = alternating::solve(&sc, &AlternatingOptions::default(), None).unwrap();
        let worst = worst_case(&sc).unwrap();
        assert!(
            robust.energy < worst.energy,
            "robust {} !< worst {}",
            robust.energy,
            worst.energy
        );
    }

    #[test]
    fn mean_only_is_cheapest() {
        let sc = scenario(6, 0.20, 0.04, 3);
        let robust = alternating::solve(&sc, &AlternatingOptions::default(), None).unwrap();
        let mean = mean_only(&sc).unwrap();
        assert!(mean.energy <= robust.energy * (1.0 + 1e-6));
    }

    #[test]
    fn pccp_close_to_exhaustive_optimal_small_n() {
        // Fig. 12's claim: the PCCP pipeline is near the exhaustive
        // optimum.
        let sc = scenario(2, 0.22, 0.04, 4);
        let opt = exhaustive_optimal(&sc).unwrap();
        let robust =
            alternating::solve_multistart(&sc, &AlternatingOptions::default(), &[]).unwrap();
        assert!(
            robust.energy <= opt.energy * 1.15 + 1e-9,
            "pccp {} vs optimal {}",
            robust.energy,
            opt.energy
        );
        // and the optimum is no worse than the PCCP plan by definition
        assert!(opt.energy <= robust.energy * (1.0 + 1e-9));
    }

    #[test]
    fn multistart_matches_exhaustive_small_n() {
        let sc = scenario(2, 0.24, 0.05, 5);
        let a = exhaustive_optimal(&sc).unwrap();
        let b = multistart_optimal(&sc, 6, 123).unwrap();
        assert!(
            (b.energy - a.energy) / a.energy < 0.03,
            "multistart {} vs exhaustive {}",
            b.energy,
            a.energy
        );
    }

    #[test]
    fn feasibility_probe() {
        let sc = scenario(4, 0.25, 0.05, 6);
        assert_eq!(policy_feasible(&sc, Policy::ROBUST), ResourceFeasibility::Feasible);
        let tight = scenario(4, 0.002, 0.05, 6);
        assert_eq!(policy_feasible(&tight, Policy::ROBUST), ResourceFeasibility::Infeasible);
    }
}
