//! Cohort-compressed planning: solve fingerprint-equivalence classes,
//! not devices.
//!
//! A million-device fleet does not contain a million *distinct* planning
//! problems.  Devices whose quantized parameters agree — same model,
//! deadline within 0.1 ms, risk within 1e-4, channel within 0.1 dB,
//! transmit power within 1 mW — admit identical per-device optima, and
//! [`crate::engine::device_fingerprint`] already defines exactly those
//! equivalence classes (the plan cache and the service's device→shard
//! routing key on the same hash, so there is one definition of "the same
//! device" across the whole stack).  This module buckets a scenario into
//! those classes ("cohorts"), solves one representative per cohort with
//! its member count as a weight on the shared bandwidth budget, and
//! replicates the representative decision across the members with a
//! per-device feasibility re-check.
//!
//! The per-cohort solve is a two-stage warm start in the style of the
//! classic delay-constrained offloading decomposition (discrete stage +
//! closed-form continuous stage) feeding a PCCP polish:
//!
//! 1. **Grouped knapsack (discrete).**  For each cohort × partition
//!    point, compute the *minimum* bandwidth `b_req` that keeps the
//!    margin-adjusted deadline feasible at `f_max` (bisection on the
//!    monotone rate curve; points whose remaining delay budget
//!    `E = D′ − t_loc` is non-positive are filtered out, as are points
//!    whose required rate exceeds the channel's `b → ∞` rate asymptote).
//!    Each cohort picks its cheapest feasible point; a deterministic
//!    repair loop trades energy for bandwidth (cheapest Δenergy/Δb swap
//!    first) until the weighted demand `Σ w_c · b_req` fits inside `B`.
//! 2. **Closed-form Lagrangian split (continuous, O(1) per cohort).**
//!    The leftover budget `B − Σ w_c·b_req` is spread by the square-root
//!    rule `b_c ∝ √(p·d_c/η_c)` — the stationarity condition of
//!    `min Σ w_c·p·d_c/(η_c b_c)` s.t. `Σ w_c·b_c = B` (the same
//!    `α = (B+√(BC))/E` shape the two-zone closed form takes for two
//!    cohorts).  No iteration, no solver.
//! 3. **PCCP polish.**  Algorithm 1 runs once per *cohort* (not per
//!    device) at the stage-2 bandwidths, warm-started from the stage-1
//!    point, and may move the partition point.  The local frequency is
//!    then closed-form: the minimum `f` meeting the margin-adjusted
//!    deadline (energy is increasing in `f`, so minimal feasible is
//!    optimal), clamped to the hardware box.
//!
//! **Replication re-check.**  Members of a cohort differ from their
//! representative by strictly sub-quantum parameter differences (< 0.1 dB
//! of gain, < 0.1 ms of deadline, ...), but "sub-quantum" is not "zero":
//! the representative's decision is re-checked against every member's
//! *actual* parameters, and a member whose margin-adjusted deadline
//! fails gets its frequency raised to its own minimum-feasible value
//! (bandwidth is never changed by the repair, so `Σ b ≤ B` survives
//! replication untouched).  If even `f_max` cannot repair a member the
//! scenario is reported infeasible rather than silently violated.
//!
//! **Gap bound.**  The solve reports
//! `gap = |E_replicated − E_representative| / E_representative`, where
//! `E_representative = Σ_c w_c · E(rep_c)` prices every member at its
//! representative's energy and `E_replicated` prices the actual plan on
//! the actual devices.  Sub-quantum parameter drift and the re-check's
//! frequency bumps are the *only* sources of difference, so the gap is a
//! computable upper bound on the energy cost of compression for this
//! scenario (see EXPERIMENTS.md §Cohorts for the methodology and the
//! measured cohort-vs-exact gap, which also includes the two-stage
//! warm start's distance from the full Algorithm-2 fixed point).

use crate::risk::RiskBound;

use super::alternating::{AlternatingOptions, PlanError};
use super::pccp;
use super::types::{Device, Plan, Policy, Scenario};

/// Fingerprint-equivalence classes of a scenario, in first-seen device
/// order (deterministic for a fixed device order, independent of any
/// hash-iteration order — the map below is only ever *probed*).
#[derive(Clone, Debug)]
pub struct Cohorts {
    /// Representative device index per cohort (its first member).
    pub reps: Vec<usize>,
    /// Member count per cohort.
    pub weights: Vec<usize>,
    /// Cohort index per device.
    pub of_device: Vec<usize>,
}

impl Cohorts {
    /// Number of cohorts.
    pub fn len(&self) -> usize {
        self.reps.len()
    }

    /// True when the scenario has no devices (and hence no cohorts).
    pub fn is_empty(&self) -> bool {
        self.reps.is_empty()
    }
}

/// Bucket a scenario's devices by quantized fingerprint.
///
/// Two devices land in the same cohort iff
/// [`crate::engine::device_fingerprint`] agrees — the same equivalence
/// the plan cache and the shard router use, so cohorts never straddle
/// service shards (routing hashes the identical fingerprint).
pub fn bucket(sc: &Scenario) -> Cohorts {
    let mut index: std::collections::BTreeMap<u64, usize> = std::collections::BTreeMap::new();
    let mut reps = Vec::new();
    let mut weights: Vec<usize> = Vec::new();
    let mut of_device = Vec::with_capacity(sc.n());
    for (i, d) in sc.devices.iter().enumerate() {
        let fp = crate::engine::device_fingerprint(d);
        let c = *index.entry(fp).or_insert_with(|| {
            reps.push(i);
            weights.push(0);
            reps.len() - 1
        });
        weights[c] += 1;
        of_device.push(c);
    }
    Cohorts { reps, weights, of_device }
}

/// Cohort-compressed solve outcome (the engine folds this into a
/// [`crate::engine::PlanOutcome`]).
#[derive(Clone, Debug)]
pub struct CohortPlan {
    /// Full n-device plan (representative decisions replicated and
    /// re-checked per member).
    pub plan: Plan,
    /// `plan.expected_energy(sc)` — the replicated plan priced on the
    /// actual devices.
    pub energy: f64,
    /// Number of cohorts solved.
    pub cohorts: usize,
    /// Replication-drift bound: `|energy − Σ w_c·E(rep_c)| / Σ w_c·E(rep_c)`.
    pub gap_bound: f64,
    /// Mean Algorithm-1 iterations per cohort.
    pub avg_pccp_iters: f64,
    /// Total inner Newton iterations across the per-cohort polishes.
    pub newton_iters: usize,
}

/// Bisection iteration count for the minimum-bandwidth solve; the rate
/// curve is smooth and monotone, so a fixed count keeps the result
/// bit-deterministic across platforms and inputs.
const BISECT_ITERS: usize = 80;

/// Minimum bandwidth at which `dev` meets its margin-adjusted deadline
/// at partition point `m` and `f_max`, or `None` when no finite
/// bandwidth can.  `Some(0.0)` means the point needs no uplink.
fn min_bandwidth(dev: &Device, m: usize, mpol: Policy) -> Option<f64> {
    let f_max = dev.model.device.f_max_ghz;
    // Remaining delay budget after the VM mean, the risk margin, and the
    // local compute at f_max (the two-stage literature's E = Dmax − A).
    let rem = dev.deadline_slack(m, mpol) - dev.model.t_loc_mean(m, f_max);
    let d_bits = dev.model.d_bits(m);
    // lint:allow(float-eq): exact m = 0 no-uplink sentinel (d_bits is a
    // sum of zero terms, never a rounded value)
    if d_bits == 0.0 {
        return (rem >= 0.0).then_some(0.0);
    }
    if rem <= 0.0 {
        return None;
    }
    // Required rate, against the channel's b → ∞ rate asymptote
    // p·g/(n0·ln2): beyond it no bandwidth is enough.
    let need = d_bits / rem * (1.0 + 1e-9);
    let asymptote = dev.uplink.p_tx * dev.uplink.gain / (dev.uplink.n0 * std::f64::consts::LN_2);
    if need >= asymptote {
        return None;
    }
    // Bracket then bisect the monotone rate curve.
    let mut hi = 1.0;
    while dev.uplink.rate_bps(hi) < need {
        hi *= 2.0;
        if hi > 1e15 {
            return None;
        }
    }
    let mut lo = 0.0;
    for _ in 0..BISECT_ITERS {
        let mid = 0.5 * (lo + hi);
        if dev.uplink.rate_bps(mid) < need {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    Some(hi)
}

/// Minimum frequency at which `dev` meets its margin-adjusted deadline
/// at `(m, b)`, clamped to the hardware box; `None` when even `f_max`
/// misses.  Minimal feasible is energy-optimal (E_loc ∝ f²).
fn min_frequency(dev: &Device, m: usize, b_hz: f64, mpol: Policy) -> Option<f64> {
    let hw = &dev.model.device;
    let p = &dev.model.points[m];
    let rem = dev.deadline_slack(m, mpol) - dev.uplink.t_off(dev.model.d_bits(m), b_hz);
    // lint:allow(float-eq): exact all-offload sentinel (w_gflops is set
    // to literal 0.0 at the remote-everything point, never computed)
    let f = if p.w_gflops == 0.0 {
        hw.f_min_ghz
    } else {
        if rem <= 0.0 {
            return None;
        }
        (p.w_gflops / (p.g_flops_cycle * rem)).clamp(hw.f_min_ghz, hw.f_max_ghz)
    };
    dev.deadline_ok(m, f, b_hz, mpol).then_some(f)
}

/// One stage-1 knapsack item: a feasible partition point with its
/// minimum bandwidth and its energy at `(f_max, b_req)`.
#[derive(Clone, Copy, Debug)]
struct Item {
    m: usize,
    b_req: f64,
    energy: f64,
}

/// Solve the scenario cohort-compressed.  `opts.pccp` configures the
/// per-cohort Algorithm-1 polish; everything else in `opts` is unused
/// here (there is no outer alternation — the two-stage warm start plus
/// one polish per cohort is the whole solve).
pub fn solve(
    sc: &Scenario,
    cohorts: &Cohorts,
    opts: &AlternatingOptions,
    bound: RiskBound,
) -> Result<CohortPlan, PlanError> {
    let mpol = Policy::Robust(bound);
    let c_n = cohorts.len();
    if c_n == 0 {
        return Err(PlanError::Infeasible("empty scenario".into()));
    }

    // -- stage 1: grouped knapsack over cohort × partition point ----------
    let mut items: Vec<Vec<Item>> = Vec::with_capacity(c_n);
    for (&rep, &w) in cohorts.reps.iter().zip(&cohorts.weights) {
        let dev = &sc.devices[rep];
        let f_max = dev.model.device.f_max_ghz;
        let mut list: Vec<Item> = (0..dev.model.num_points())
            .filter_map(|m| {
                min_bandwidth(dev, m, mpol)
                    .map(|b| Item { m, b_req: b, energy: dev.energy_mean(m, f_max, b) })
            })
            .collect();
        if list.is_empty() {
            return Err(PlanError::Infeasible(format!(
                "cohort of device {rep} ({w} members) has no feasible partition point \
                 at any bandwidth"
            )));
        }
        // Keep only Pareto-optimal (b_req, energy) items: sorted by
        // bandwidth, an item dominated on both axes never helps the
        // knapsack or its repair loop.
        list.sort_by(|a, b| a.b_req.total_cmp(&b.b_req).then(a.energy.total_cmp(&b.energy)));
        let mut pareto: Vec<Item> = Vec::with_capacity(list.len());
        for it in list {
            if pareto.last().is_none_or(|p| it.energy < p.energy) {
                pareto.push(it);
            }
        }
        items.push(pareto);
    }

    // Unconstrained pick: each cohort's minimum-energy item.
    let mut pick: Vec<usize> = items
        .iter()
        .map(|list| {
            list.iter()
                .enumerate()
                .min_by(|a, b| a.1.energy.total_cmp(&b.1.energy))
                .map(|(k, _)| k)
                // lint:allow(panic-path): every list verified non-empty above
                .unwrap()
        })
        .collect();
    let weighted_demand = |pick: &[usize]| -> f64 {
        pick.iter()
            .zip(&items)
            .zip(&cohorts.weights)
            .map(|((&k, list), &w)| w as f64 * list[k].b_req)
            .sum()
    };
    // Repair toward the budget: repeatedly apply the cheapest
    // energy-per-bandwidth swap (deterministic total_cmp argmin; the
    // Pareto lists guarantee lower-index items need strictly less
    // bandwidth).  Falls out with Infeasible when every cohort already
    // sits at its least-bandwidth item and the budget still overflows.
    let budget = sc.total_bandwidth_hz;
    while weighted_demand(&pick) > budget {
        let mut best: Option<(usize, usize, f64)> = None; // (cohort, item, Δe/Δb)
        for (c, list) in items.iter().enumerate() {
            let cur = list[pick[c]];
            for (k, alt) in list.iter().enumerate().take(pick[c]) {
                let db = cohorts.weights[c] as f64 * (cur.b_req - alt.b_req);
                if db <= 0.0 {
                    continue;
                }
                let de = cohorts.weights[c] as f64 * (alt.energy - cur.energy);
                let ratio = de / db;
                if best.is_none_or(|(_, _, r)| ratio < r) {
                    best = Some((c, k, ratio));
                }
            }
        }
        match best {
            Some((c, k, _)) => pick[c] = k,
            None => {
                return Err(PlanError::Infeasible(format!(
                    "weighted minimum bandwidth demand {:.3e} Hz exceeds the budget {budget:.3e} Hz \
                     even at the least-bandwidth partition points",
                    weighted_demand(&pick)
                )))
            }
        }
    }

    // -- stage 2: closed-form square-root split of the leftover ----------
    let mut b_c: Vec<f64> = pick.iter().zip(&items).map(|(&k, list)| list[k].b_req).collect();
    let used: f64 = weighted_demand(&pick);
    let leftover = (budget - used).max(0.0);
    // b ∝ √(p·d/η): stationarity of Σ w·p·d/(η·b) under Σ w·b = leftover,
    // with η frozen at the equal-share operating point.
    let b_ref = budget / sc.n() as f64;
    let score: Vec<f64> = cohorts
        .reps
        .iter()
        .zip(&pick)
        .zip(&items)
        .map(|((&rep, &k), list)| {
            let dev = &sc.devices[rep];
            let d_bits = dev.model.d_bits(list[k].m);
            // lint:allow(float-eq): exact m = 0 no-uplink sentinel (see
            // min_bandwidth)
            if d_bits == 0.0 {
                0.0
            } else {
                (dev.uplink.p_tx * d_bits / dev.uplink.spectral_efficiency(b_ref)).sqrt()
            }
        })
        .collect();
    let norm: f64 = score.iter().zip(&cohorts.weights).map(|(s, &w)| w as f64 * s).sum();
    if norm > 0.0 && leftover > 0.0 {
        for (b, s) in b_c.iter_mut().zip(&score) {
            *b += leftover * s / norm;
        }
    }

    // -- stage 3: one PCCP polish per cohort + closed-form frequency -----
    let mut m_c: Vec<usize> = pick.iter().zip(&items).map(|(&k, list)| list[k].m).collect();
    let mut f_c: Vec<f64> = vec![0.0; c_n];
    let mut pccp_iters = 0usize;
    let mut newton = 0usize;
    for c in 0..c_n {
        let dev = &sc.devices[cohorts.reps[c]];
        let f_max = dev.model.device.f_max_ghz;
        let mp1 = dev.model.num_points();
        // Smoothed one-hot warm start at the stage-1 point (the same
        // interior seeding Algorithm 1 uses for its own cold starts).
        let mut seed = vec![0.02 / (mp1 - 1) as f64; mp1];
        seed[m_c[c]] = 0.98;
        match pccp::solve_device(dev, f_max, b_c[c], &opts.pccp, Some(&seed), bound) {
            Ok(r) => {
                pccp_iters += r.iters;
                newton += r.newton_iters;
                m_c[c] = r.m;
            }
            // The stage-1 point stays feasible at b_c ≥ b_req, so an
            // infeasibility here is a numerical corner: keep the warm
            // start rather than fail the whole fleet.
            Err(pccp::PccpError::Infeasible { .. }) => {}
            Err(pccp::PccpError::Solver(e)) => return Err(PlanError::Solver(e)),
        }
        f_c[c] = match min_frequency(dev, m_c[c], b_c[c], mpol) {
            Some(f) => f,
            None => {
                // PCCP moved to a point the closed form cannot price
                // (boundary arithmetic): fall back to the stage-1 point,
                // which min_bandwidth certified feasible at f_max.
                m_c[c] = items[c][pick[c]].m;
                min_frequency(dev, m_c[c], b_c[c], mpol).unwrap_or(f_max)
            }
        };
    }

    // -- replication with the per-member feasibility re-check ------------
    let n = sc.n();
    let mut partition = Vec::with_capacity(n);
    let mut bandwidth = Vec::with_capacity(n);
    let mut freq = Vec::with_capacity(n);
    for (i, d) in sc.devices.iter().enumerate() {
        let c = cohorts.of_device[i];
        let (m, b) = (m_c[c], b_c[c]);
        let mut f = f_c[c];
        if !d.deadline_ok(m, f, b, mpol) {
            // Sub-quantum drift from the representative: repair with this
            // member's own minimum-feasible frequency (never its
            // bandwidth — Σ b ≤ B must survive replication).
            f = min_frequency(d, m, b, mpol).ok_or_else(|| {
                PlanError::Infeasible(format!(
                    "device {i} cannot meet its deadline on its cohort's decision \
                     (point {m}, {b:.0} Hz) even at f_max"
                ))
            })?;
        }
        partition.push(m);
        bandwidth.push(b);
        freq.push(f);
    }
    let plan = Plan { partition, bandwidth_hz: bandwidth, freq_ghz: freq };
    debug_assert!(plan.bandwidth_ok(sc));

    // -- energies and the replication-drift bound ------------------------
    let rep_energy: f64 = (0..c_n)
        .map(|c| {
            cohorts.weights[c] as f64
                * sc.devices[cohorts.reps[c]].energy_mean(m_c[c], f_c[c], b_c[c])
        })
        .sum();
    let energy = plan.expected_energy(sc);
    let gap_bound = (energy - rep_energy).abs() / rep_energy.max(f64::MIN_POSITIVE);

    Ok(CohortPlan {
        plan,
        energy,
        cohorts: c_n,
        gap_bound,
        avg_pccp_iters: pccp_iters as f64 / c_n as f64,
        newton_iters: newton,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Uplink;
    use crate::models::ModelProfile;
    use crate::util::rng::Rng;

    fn uniform(n: usize, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), n, 10e6, 0.25, 0.05, &mut rng)
    }

    /// k distinct channel classes replicated `reps` times each.
    fn clustered(classes: usize, reps: usize) -> Scenario {
        let model = ModelProfile::alexnet_paper();
        let devices = (0..classes)
            .flat_map(|c| {
                let gain_db = -80.0 - 5.0 * c as f64;
                (0..reps).map(move |_| (gain_db,))
            })
            .map(|(gain_db,)| Device {
                model: model.clone(),
                uplink: Uplink::from_gain_db(gain_db),
                deadline_s: 0.25,
                risk: 0.05,
            })
            .collect();
        Scenario { devices, total_bandwidth_hz: 10e6 }
    }

    #[test]
    fn bucket_groups_identical_devices() {
        let sc = clustered(3, 5);
        let c = bucket(&sc);
        assert_eq!(c.len(), 3);
        assert_eq!(c.weights, vec![5, 5, 5]);
        assert_eq!(c.reps, vec![0, 5, 10]);
        for (i, &ci) in c.of_device.iter().enumerate() {
            assert_eq!(ci, i / 5);
        }
    }

    #[test]
    fn bucket_keeps_unique_devices_apart() {
        let sc = uniform(12, 3);
        let c = bucket(&sc);
        assert_eq!(c.len(), 12, "random geometry should give unique fingerprints");
        assert!(c.weights.iter().all(|&w| w == 1));
    }

    #[test]
    fn min_bandwidth_meets_the_deadline_exactly() {
        let sc = uniform(4, 9);
        let mpol = Policy::ROBUST;
        for d in &sc.devices {
            let f_max = d.model.device.f_max_ghz;
            for m in 0..d.model.num_points() {
                if let Some(b) = min_bandwidth(d, m, mpol) {
                    assert!(d.deadline_ok(m, f_max, b, mpol), "m={m} b={b}");
                }
            }
        }
    }

    #[test]
    fn min_frequency_is_feasible_and_minimal() {
        let sc = uniform(4, 11);
        let mpol = Policy::ROBUST;
        let d = &sc.devices[0];
        let b = 2e6;
        for m in 0..d.model.num_points() {
            if let Some(f) = min_frequency(d, m, b, mpol) {
                assert!(d.deadline_ok(m, f, b, mpol), "m={m}");
                let hw = &d.model.device;
                if f > hw.f_min_ghz + 1e-9 && d.model.points[m].w_gflops > 0.0 {
                    // Just below the minimum the deadline must fail
                    // (modulo the deadline_ok tolerance band).
                    assert!(
                        d.deadline_margin(m, f * 0.999, b, mpol)
                            < d.deadline_margin(m, f, b, mpol),
                        "m={m}"
                    );
                }
            }
        }
    }

    #[test]
    fn clustered_fleet_solves_with_bounded_gap() {
        let sc = clustered(4, 25);
        let c = bucket(&sc);
        let r = solve(&sc, &c, &AlternatingOptions::default(), RiskBound::Ecr).unwrap();
        assert_eq!(r.cohorts, 4);
        assert!(r.plan.feasible(&sc, Policy::ROBUST));
        assert!(r.plan.bandwidth_ok(&sc));
        assert!(r.plan.freq_ok(&sc));
        // Identical members ⇒ replication drift is exactly zero.
        assert!(r.gap_bound < 1e-12, "gap={}", r.gap_bound);
        // All members of a cohort share the decision.
        for (i, &ci) in c.of_device.iter().enumerate() {
            assert_eq!(r.plan.partition[i], r.plan.partition[c.reps[ci]]);
            assert_eq!(r.plan.bandwidth_hz[i].to_bits(), r.plan.bandwidth_hz[c.reps[ci]].to_bits());
        }
    }

    #[test]
    fn infeasible_deadline_is_an_error_not_a_panic() {
        let mut sc = clustered(2, 3);
        for d in &mut sc.devices {
            d.deadline_s = 0.004;
        }
        let c = bucket(&sc);
        assert!(matches!(
            solve(&sc, &c, &AlternatingOptions::default(), RiskBound::Ecr),
            Err(PlanError::Infeasible(_))
        ));
    }

    #[test]
    fn bandwidth_repair_respects_the_budget() {
        // Starve the budget so the unconstrained picks must be repaired.
        let mut sc = clustered(3, 40);
        sc.total_bandwidth_hz = 2e6;
        for d in &mut sc.devices {
            d.deadline_s = 2.0; // all-local must stay reachable
        }
        let c = bucket(&sc);
        let r = solve(&sc, &c, &AlternatingOptions::default(), RiskBound::Ecr).unwrap();
        assert!(r.plan.bandwidth_ok(&sc));
        assert!(r.plan.feasible(&sc, Policy::ROBUST));
    }
}
