//! Resource-allocation subproblem (paper problem (23)): given a fixed
//! partitioning decision, jointly optimize uplink bandwidth b and local
//! frequency f.
//!
//! The CCP/ECR transform (Theorem 1) turns the chance constraint (16b)
//! into the deterministic (22); after reserving the VM mean and the
//! uncertainty margin from the deadline, each device's constraint is
//!
//! ```text
//!   L_n / f_n  +  T^off_n(b_n)  ≤  D′_n ,      L_n = w_{n,m}/g_{n,m}
//! ```
//!
//! with objective Σ_n A_n f_n² + p_n·T^off_n(b_n) (eq. 23a).  The problem
//! is convex (T^off is the reciprocal of a concave rate — see `channel`);
//! we solve it two ways:
//!
//! * [`solve`] — a joint log-barrier interior point over the scaled
//!   variables (u = b/B, f), the reference implementation whose Newton
//!   iteration counts feed Fig. 9/11;
//! * [`solve_dual`] — a fast O(N·log²) dual decomposition: bisection on
//!   the bandwidth price with per-device 1-D convex subproblems.  Used as
//!   an ablation (see `benches/ablation_resource.rs`) and cross-checked
//!   against the barrier solution in tests.
//!
//! **Risk-bound invariant:** whichever `RiskBound` the policy carries,
//! the uncertainty margin is a constant per partition point — it enters
//! this subproblem only through the fixed budget D′ (`deadline_slack`),
//! never through (b, f) — so the program stays convex and both solvers
//! apply unchanged for every bound in the family.

use crate::linalg::Matrix;
use crate::solver::{self, BarrierOptions, ConvexProgram};

use super::types::{Policy, Scenario};

/// Lower bound on the bandwidth fraction (keeps the barrier away from the
/// rate singularity at b = 0).
const U_MIN: f64 = 1e-6;

/// Outcome of the resource subproblem.
#[derive(Clone, Debug)]
pub struct ResourceSolution {
    pub bandwidth_hz: Vec<f64>,
    pub freq_ghz: Vec<f64>,
    /// Optimal expected energy (objective (23a)).
    pub energy: f64,
    /// Newton iterations spent (phase-I + phase-II).
    pub newton_iters: usize,
}

#[derive(Debug, Clone)]
pub enum ResourceError {
    /// No (b, f) satisfies the deterministic deadlines — the partition is
    /// too aggressive for this bandwidth/deadline/risk combination.
    Infeasible { worst_device: usize, slack: f64 },
    Solver(String),
}

impl std::fmt::Display for ResourceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ResourceError::Infeasible { worst_device, slack } => write!(
                f,
                "resource problem infeasible (device {worst_device}, phase-I slack {slack:.4})"
            ),
            ResourceError::Solver(e) => write!(f, "solver failure: {e}"),
        }
    }
}

impl std::error::Error for ResourceError {}

/// Per-device constants extracted from the scenario for a fixed partition.
struct DeviceData {
    /// Local-energy coefficient: E_loc = a_e f² (f in GHz).
    a_e: f64,
    /// Local Giga-cycles: t_loc = l / f.
    l: f64,
    /// Offloaded bits.
    d_bits: f64,
    /// Deadline budget D′ for local + offload.
    slack: f64,
    f_min: f64,
    f_max: f64,
    uplink: crate::channel::Uplink,
}

/// The convex program over z = [u_0..u_{N-1}, f_0..f_{N-1}]
/// (+ an optional phase-I slack variable appended at the end).
struct ResourceProgram {
    dev: Vec<DeviceData>,
    b_total: f64,
    /// Phase-I mode: minimize s with deadlines relaxed by s.
    phase1: bool,
    /// Feasible start to use.
    start: Vec<f64>,
}

impl ResourceProgram {
    fn n(&self) -> usize {
        self.dev.len()
    }

    #[inline]
    fn t_off(&self, i: usize, u: f64) -> f64 {
        self.dev[i].uplink.t_off(self.dev[i].d_bits, u * self.b_total)
    }

    /// First and second derivatives of t_off w.r.t. the fraction u
    /// (analytic — see channel::Uplink; chain rule adds B and B²).
    fn t_off_d(&self, i: usize, u: f64) -> (f64, f64) {
        let b = u * self.b_total;
        let d1 = self.dev[i].uplink.t_off_derivative(self.dev[i].d_bits, b) * self.b_total;
        let d2 = self.dev[i].uplink.t_off_second_derivative(self.dev[i].d_bits, b)
            * self.b_total
            * self.b_total;
        (d1, d2)
    }
}

// Constraint layout:
//   0                      : Σu − 1 ≤ 0
//   1 + 6i + 0             : deadline_i  (− s in phase-I)
//   1 + 6i + 1..=2         : f bounds (min, max)
//   1 + 6i + 3..=4         : u bounds (U_MIN, 1)
//   1 + 6i + 5             : spare — u_i ≤ 1 kept explicit for barrier
// phase-I adds no extra inequality on s (s free, minimized).
impl ConvexProgram for ResourceProgram {
    fn num_vars(&self) -> usize {
        2 * self.n() + usize::from(self.phase1)
    }

    fn num_ineq(&self) -> usize {
        1 + 5 * self.n()
    }

    fn objective(&self, z: &[f64]) -> f64 {
        if self.phase1 {
            return z[2 * self.n()];
        }
        let n = self.n();
        let mut e = 0.0;
        for i in 0..n {
            let (u, f) = (z[i], z[n + i]);
            e += self.dev[i].a_e * f * f + self.dev[i].uplink.p_tx * self.t_off(i, u);
        }
        e
    }

    fn gradient(&self, z: &[f64], g: &mut [f64]) {
        g.iter_mut().for_each(|v| *v = 0.0);
        let n = self.n();
        if self.phase1 {
            g[2 * n] = 1.0;
            return;
        }
        for i in 0..n {
            let (u, f) = (z[i], z[n + i]);
            let (d1, _) = self.t_off_d(i, u);
            g[i] = self.dev[i].uplink.p_tx * d1;
            g[n + i] = 2.0 * self.dev[i].a_e * f;
        }
    }

    fn hessian_accum(&self, z: &[f64], scale: f64, h: &mut Matrix) {
        if self.phase1 {
            return;
        }
        let n = self.n();
        for i in 0..n {
            let (u, _f) = (z[i], z[n + i]);
            let (_, d2) = self.t_off_d(i, u);
            h[(i, i)] += scale * self.dev[i].uplink.p_tx * d2;
            h[(n + i, n + i)] += scale * 2.0 * self.dev[i].a_e;
        }
    }

    fn constraint(&self, c: usize, z: &[f64]) -> f64 {
        let n = self.n();
        if c == 0 {
            return z[..n].iter().sum::<f64>() - 1.0;
        }
        let i = (c - 1) / 5;
        let kind = (c - 1) % 5;
        let (u, f) = (z[i], z[n + i]);
        let d = &self.dev[i];
        match kind {
            0 => {
                // lint:allow(float-eq): l is exactly 0.0 at m = 0 (never
                // computed; w_gflops == 0 sentinel) — guards 0/0.
                let t_loc = if d.l == 0.0 { 0.0 } else { d.l / f };
                let mut v = t_loc + self.t_off(i, u) - d.slack;
                if self.phase1 {
                    v -= z[2 * n];
                }
                v
            }
            1 => d.f_min - f,
            2 => f - d.f_max,
            3 => U_MIN - u,
            _ => u - 1.0,
        }
    }

    fn constraint_grad(&self, c: usize, z: &[f64], g: &mut [f64]) {
        g.iter_mut().for_each(|v| *v = 0.0);
        let n = self.n();
        if c == 0 {
            g[..n].iter_mut().for_each(|v| *v = 1.0);
            return;
        }
        let i = (c - 1) / 5;
        let kind = (c - 1) % 5;
        let (u, f) = (z[i], z[n + i]);
        let d = &self.dev[i];
        match kind {
            0 => {
                // lint:allow(float-eq): exact m = 0 sentinel (see above)
                if d.l != 0.0 {
                    g[n + i] = -d.l / (f * f);
                }
                let (d1, _) = self.t_off_d(i, u);
                g[i] = d1;
                if self.phase1 {
                    g[2 * n] = -1.0;
                }
            }
            1 => g[n + i] = -1.0,
            2 => g[n + i] = 1.0,
            3 => g[i] = -1.0,
            _ => g[i] = 1.0,
        }
    }

    fn constraint_hess_accum(&self, c: usize, z: &[f64], scale: f64, h: &mut Matrix) {
        if c == 0 {
            return;
        }
        let n = self.n();
        let i = (c - 1) / 5;
        if (c - 1) % 5 != 0 {
            return;
        }
        let (u, f) = (z[i], z[n + i]);
        let d = &self.dev[i];
        // lint:allow(float-eq): exact m = 0 sentinel (see above)
        if d.l != 0.0 {
            h[(n + i, n + i)] += scale * 2.0 * d.l / (f * f * f);
        }
        let (_, d2) = self.t_off_d(i, u);
        h[(i, i)] += scale * d2;
    }

    fn initial_point(&self) -> Vec<f64> {
        self.start.clone()
    }
}

fn device_data(sc: &Scenario, partition: &[usize], policy: Policy) -> Vec<DeviceData> {
    sc.devices
        .iter()
        .zip(partition)
        .map(|(d, &m)| {
            let p = &d.model.points[m];
            DeviceData {
                a_e: crate::energy::e_loc_mean(
                    d.model.device.kappa,
                    1.0,
                    p.w_gflops,
                    if m == 0 { 1.0 } else { p.g_flops_cycle },
                ),
                l: if m == 0 { 0.0 } else { p.w_gflops / p.g_flops_cycle },
                d_bits: d.model.d_bits(m),
                slack: d.deadline_slack(m, policy),
                f_min: d.model.device.f_min_ghz,
                f_max: d.model.device.f_max_ghz,
                uplink: d.uplink,
            }
        })
        .collect()
}

/// Heuristic strictly-feasible start: f at max (fastest local), bandwidth
/// split ∝ offload demand.  Returns None if it is not strictly feasible.
fn heuristic_start(prog: &ResourceProgram) -> Option<Vec<f64>> {
    let n = prog.n();
    let demand: Vec<f64> = prog.dev.iter().map(|d| d.d_bits.max(1.0)).collect();
    let total: f64 = demand.iter().sum();
    let mut z = vec![0.0; 2 * n];
    for i in 0..n {
        z[i] = (0.95 * demand[i] / total).max(2.0 * U_MIN);
        z[n + i] = prog.dev[i].f_max * 0.999;
    }
    if z[..n].iter().sum::<f64>() >= 1.0 {
        return None;
    }
    let feasible = (0..prog.num_ineq()).all(|c| prog.constraint(c, &z) < -1e-12);
    feasible.then_some(z)
}

/// Phase-I: minimize s with deadlines relaxed by s; returns a strictly
/// feasible phase-II start or an infeasibility certificate.
fn phase1_start(
    dev: Vec<DeviceData>,
    b_total: f64,
    opts: &BarrierOptions,
    ws: &mut solver::NewtonWorkspace,
) -> Result<(Vec<f64>, usize), ResourceError> {
    let n = dev.len();
    let mut start = vec![0.0; 2 * n + 1];
    for i in 0..n {
        start[i] = 0.9 / n as f64;
        start[n + i] = 0.5 * (dev[i].f_min + dev[i].f_max);
    }
    let prog = ResourceProgram { dev, b_total, phase1: true, start: vec![] };
    // s0 = max violation + margin
    let mut s0 = 0.0f64;
    for c in 0..prog.num_ineq() {
        // deadline constraints only; bounds are satisfied by construction
        if c >= 1 && (c - 1) % 5 == 0 {
            let i = (c - 1) / 5;
            // lint:allow(float-eq): exact m = 0 sentinel (see above)
            let t_loc = if prog.dev[i].l == 0.0 { 0.0 } else { prog.dev[i].l / start[n + i] };
            s0 = s0.max(t_loc + prog.t_off(i, start[i]) - prog.dev[i].slack);
        }
    }
    start[2 * n] = s0 + 1.0;
    let prog = ResourceProgram { start, ..prog };
    let sol =
        solver::solve_with(&prog, opts, ws).map_err(|e| ResourceError::Solver(e.to_string()))?;
    let s_star = sol.x[2 * n];
    if s_star >= -1e-9 {
        // find the tightest device for the error message
        let worst = (0..n)
            .min_by(|&a, &b| prog.dev[a].slack.total_cmp(&prog.dev[b].slack))
            .unwrap_or(0);
        return Err(ResourceError::Infeasible { worst_device: worst, slack: s_star });
    }
    Ok((sol.x[..2 * n].to_vec(), sol.newton_iters))
}

/// Solve problem (23) with the joint barrier interior point.
pub fn solve(
    sc: &Scenario,
    partition: &[usize],
    policy: Policy,
) -> Result<ResourceSolution, ResourceError> {
    solve_warm(sc, partition, policy, None)
}

/// [`solve`] with an optional warm start from a previous solution
/// (Algorithm 2 passes the last outer iteration's (b, f)).  The previous
/// point is used only when it is strictly feasible for the *new*
/// partition's deadlines; otherwise the cold-start ladder (heuristic,
/// then phase-I) runs as usual, so a warm start can never change
/// feasibility — only skip the phase-I solve and shorten centering.
pub fn solve_warm(
    sc: &Scenario,
    partition: &[usize],
    policy: Policy,
    warm: Option<&ResourceSolution>,
) -> Result<ResourceSolution, ResourceError> {
    let mut ws = solver::NewtonWorkspace::new();
    solve_warm_with(sc, partition, policy, warm, &mut ws)
}

/// [`solve_warm`] with a caller-owned Newton workspace.  The alternation
/// and its polish sweep issue many resource solves of identical shape, so
/// holding one workspace per caller (or per sweep worker) makes every
/// solve after the first allocation-free inside the centering loop.
pub fn solve_warm_with(
    sc: &Scenario,
    partition: &[usize],
    policy: Policy,
    warm: Option<&ResourceSolution>,
    ws: &mut solver::NewtonWorkspace,
) -> Result<ResourceSolution, ResourceError> {
    assert_eq!(partition.len(), sc.n());
    let opts = BarrierOptions::default();
    let dev = device_data(sc, partition, policy);
    let n = sc.n();

    // Quick per-device infeasibility check: even with all bandwidth and
    // max frequency the deadline cannot be met.
    for (i, d) in dev.iter().enumerate() {
        // lint:allow(float-eq): exact m = 0 sentinel (see above)
        let best = (if d.l == 0.0 { 0.0 } else { d.l / d.f_max })
            + d.uplink.t_off(d.d_bits, sc.total_bandwidth_hz);
        if best >= d.slack {
            return Err(ResourceError::Infeasible { worst_device: i, slack: best - d.slack });
        }
    }

    let mut prog =
        ResourceProgram { dev, b_total: sc.total_bandwidth_hz, phase1: false, start: vec![] };
    let mut extra_iters = 0;

    // Warm start: the previous solution scaled back to fractions, if it
    // is strictly interior for the new partition.
    let warm_z = warm.and_then(|w| {
        if w.bandwidth_hz.len() != n || w.freq_ghz.len() != n {
            return None;
        }
        let mut z = vec![0.0; 2 * n];
        for i in 0..n {
            z[i] = (w.bandwidth_hz[i] / sc.total_bandwidth_hz).clamp(2.0 * U_MIN, 1.0);
            z[n + i] = w.freq_ghz[i];
        }
        let strictly_feasible = (0..prog.num_ineq()).all(|c| prog.constraint(c, &z) < -1e-12);
        strictly_feasible.then_some(z)
    });

    prog.start = match warm_z.or_else(|| heuristic_start(&prog)) {
        Some(z) => z,
        None => {
            let dev2 = device_data(sc, partition, policy);
            let (z, it) = phase1_start(dev2, sc.total_bandwidth_hz, &opts, ws)?;
            extra_iters = it;
            z
        }
    };

    let sol =
        solver::solve_with(&prog, &opts, ws).map_err(|e| ResourceError::Solver(e.to_string()))?;
    Ok(ResourceSolution {
        bandwidth_hz: sol.x[..n].iter().map(|u| u * sc.total_bandwidth_hz).collect(),
        freq_ghz: sol.x[n..2 * n].to_vec(),
        energy: sol.objective,
        newton_iters: sol.newton_iters + extra_iters,
    })
}

// ---------------------------------------------------------------------------
// Dual decomposition fast path
// ---------------------------------------------------------------------------

/// Solve problem (23) by dual bisection on the bandwidth price λ:
/// `L(λ) = Σ_n min_{f,b} [E_n + λ b_n] − λB`; Σb*(λ) is decreasing in λ,
/// so bisection finds the market-clearing price.  Per-device subproblems
/// are 1-D convex solves (golden-section over f with b eliminated through
/// the deadline).
pub fn solve_dual(
    sc: &Scenario,
    partition: &[usize],
    policy: Policy,
) -> Result<ResourceSolution, ResourceError> {
    let dev = device_data(sc, partition, policy);
    let b_total = sc.total_bandwidth_hz;
    for (i, d) in dev.iter().enumerate() {
        // lint:allow(float-eq): exact m = 0 sentinel (see above)
        let t_loc = if d.l == 0.0 { 0.0 } else { d.l / d.f_max };
        let best = t_loc + d.uplink.t_off(d.d_bits, b_total);
        if best >= d.slack {
            return Err(ResourceError::Infeasible { worst_device: i, slack: best - d.slack });
        }
    }

    // Per-device best response to a price: returns (b, f, energy).
    let best_response = |d: &DeviceData, lambda: f64| -> (f64, f64) {
        // For fixed f, the deadline leaves T_off ≤ r(f) = slack − l/f; the
        // cheapest b satisfying it balances p·T_off' + λ = 0 unless the
        // deadline binds first.  We search over f by golden section on the
        // (convex) reduced cost  q(f) = a f² + p·T_off(b*(f,λ)) + λ b*(f,λ).
        let b_for = |f: f64| -> f64 {
            // lint:allow(float-eq): exact m = 0 sentinel (see above)
            let r = d.slack - if d.l == 0.0 { 0.0 } else { d.l / f };
            if r <= 0.0 {
                return f64::INFINITY; // infeasible at this f
            }
            // unconstrained minimizer of p·T_off(b) + λ b  (T_off' = −λ/p)
            let mut lo = 1.0f64; // 1 Hz
            let mut hi = b_total * 4.0;
            // 48 bisection steps resolve b to ~1e-13 of the range
            // T_off' is negative increasing (convex T_off); find where
            // p·T_off'(b) = −λ by bisection.
            let target = -lambda / d.uplink.p_tx;
            let b_uncon = if lambda <= 0.0 {
                hi
            } else {
                for _ in 0..48 {
                    let mid = 0.5 * (lo + hi);
                    if d.uplink.t_off_derivative(d.d_bits, mid) < target {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                0.5 * (lo + hi)
            };
            // deadline floor: smallest b with T_off(b) ≤ r
            let need = if d.uplink.t_off(d.d_bits, b_uncon) <= r {
                b_uncon
            } else {
                let (mut lo, mut hi) = (1.0f64, b_total * 4.0);
                for _ in 0..48 {
                    let mid = 0.5 * (lo + hi);
                    if d.uplink.t_off(d.d_bits, mid) > r {
                        lo = mid;
                    } else {
                        hi = mid;
                    }
                }
                0.5 * (lo + hi)
            };
            need
        };
        let cost = |f: f64| -> f64 {
            let b = b_for(f);
            if !b.is_finite() {
                return f64::INFINITY;
            }
            d.a_e * f * f + d.uplink.p_tx * d.uplink.t_off(d.d_bits, b) + lambda * b
        };
        // Golden-section over f in [f_min, f_max].
        let gr = (5f64.sqrt() - 1.0) / 2.0;
        let (mut a, mut b) = (d.f_min, d.f_max);
        let (mut x1, mut x2) = (b - gr * (b - a), a + gr * (b - a));
        let (mut c1, mut c2) = (cost(x1), cost(x2));
        for _ in 0..40 {
            if c1 < c2 {
                b = x2;
                x2 = x1;
                c2 = c1;
                x1 = b - gr * (b - a);
                c1 = cost(x1);
            } else {
                a = x1;
                x1 = x2;
                c1 = c2;
                x2 = a + gr * (b - a);
                c2 = cost(x2);
            }
        }
        let f = 0.5 * (a + b);
        (b_for(f), f)
    };

    // Bisection on λ ≥ 0 for Σ b*(λ) = B (or λ = 0 if under-subscribed).
    let total_at = |lambda: f64, dev: &[DeviceData]| -> (f64, Vec<f64>, Vec<f64>) {
        let mut bs = Vec::with_capacity(dev.len());
        let mut fs = Vec::with_capacity(dev.len());
        for d in dev {
            let (b, f) = best_response(d, lambda);
            bs.push(b);
            fs.push(f);
        }
        (bs.iter().sum(), bs, fs)
    };

    let (sum0, bs0, fs0) = total_at(0.0, &dev);
    let (bs, fs) = if sum0 <= b_total {
        (bs0, fs0)
    } else {
        let (mut lo, mut hi) = (0.0f64, 1e-6);
        while total_at(hi, &dev).0 > b_total {
            hi *= 4.0;
            if hi > 1e6 {
                break;
            }
        }
        let mut best = None;
        for _ in 0..40 {
            let mid = 0.5 * (lo + hi);
            let (s, bs, fs) = total_at(mid, &dev);
            if s > b_total {
                lo = mid;
            } else {
                best = Some((bs, fs));
                hi = mid;
            }
        }
        best.unwrap_or_else(|| {
            let (_, bs, fs) = total_at(hi, &dev);
            (bs, fs)
        })
    };

    // Rescale a hair under B to guard the constraint against bisection
    // residue.
    let sum: f64 = bs.iter().sum();
    let scale = if sum > b_total { b_total / sum * (1.0 - 1e-9) } else { 1.0 };
    let bs: Vec<f64> = bs.iter().map(|b| b * scale).collect();

    let energy = dev
        .iter()
        .zip(bs.iter().zip(&fs))
        .map(|(d, (&b, &f))| d.a_e * f * f + d.uplink.p_tx * d.uplink.t_off(d.d_bits, b))
        .sum();
    Ok(ResourceSolution { bandwidth_hz: bs, freq_ghz: fs, energy, newton_iters: 0 })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelProfile;
    use crate::optim::types::{Plan, Scenario};
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    fn scenario(n: usize, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), n, 10e6, 0.20, 0.05, &mut rng)
    }

    fn plan_of(sc: &Scenario, partition: Vec<usize>, r: &ResourceSolution) -> Plan {
        assert_eq!(partition.len(), sc.n());
        Plan {
            partition,
            bandwidth_hz: r.bandwidth_hz.clone(),
            freq_ghz: r.freq_ghz.clone(),
        }
    }

    #[test]
    fn solves_and_is_feasible() {
        let sc = scenario(6, 1);
        let partition = vec![2; 6];
        let r = solve(&sc, &partition, Policy::ROBUST).unwrap();
        let plan = plan_of(&sc, partition, &r);
        assert!(plan.bandwidth_ok(&sc));
        assert!(plan.freq_ok(&sc));
        assert!(plan.feasible(&sc, Policy::ROBUST), "{:?}", plan.violations(&sc, Policy::ROBUST));
        assert!(r.energy > 0.0 && r.energy.is_finite());
    }

    #[test]
    fn matches_plan_energy_accounting() {
        let mut rng = Rng::new(2);
        let sc =
            Scenario::uniform(&ModelProfile::alexnet_paper(), 4, 10e6, 0.26, 0.05, &mut rng);
        let partition = vec![0, 2, 5, 7];
        let r = solve(&sc, &partition, Policy::ROBUST).unwrap();
        let plan = plan_of(&sc, partition, &r);
        let e = plan.expected_energy(&sc);
        assert!((e - r.energy).abs() / e < 1e-6, "{e} vs {}", r.energy);
    }

    #[test]
    fn warm_start_agrees_with_cold() {
        let sc = scenario(6, 8);
        let p1 = vec![2; 6];
        let cold = solve(&sc, &p1, Policy::ROBUST).unwrap();
        // Warm start from the optimum of the same partition.
        let warm = solve_warm(&sc, &p1, Policy::ROBUST, Some(&cold)).unwrap();
        crate::util::check::close(warm.energy, cold.energy, 1e-5, 1e-9).unwrap();
        let plan = plan_of(&sc, p1, &warm);
        assert!(plan.feasible(&sc, Policy::ROBUST) && plan.bandwidth_ok(&sc));
        // Warm start across a partition change: the stale point may be
        // infeasible for the new deadlines — the solve must fall back and
        // still match the cold answer.
        let p2 = vec![5; 6];
        let w2 = solve_warm(&sc, &p2, Policy::ROBUST, Some(&cold)).unwrap();
        let c2 = solve(&sc, &p2, Policy::ROBUST).unwrap();
        crate::util::check::close(w2.energy, c2.energy, 1e-5, 1e-9).unwrap();
        let plan2 = plan_of(&sc, p2, &w2);
        assert!(plan2.feasible(&sc, Policy::ROBUST) && plan2.bandwidth_ok(&sc));
    }

    #[test]
    fn infeasible_when_deadline_impossible() {
        let mut sc = scenario(3, 3);
        for d in &mut sc.devices {
            d.deadline_s = 0.001; // 1 ms: impossible
        }
        assert!(matches!(
            solve(&sc, &vec![4; 3], Policy::ROBUST),
            Err(ResourceError::Infeasible { .. })
        ));
    }

    #[test]
    fn energy_decreases_with_looser_deadline() {
        let partition = vec![7; 5];
        let mut last = f64::INFINITY;
        for deadline in [0.16, 0.20, 0.26, 0.34] {
            let mut rng = Rng::new(9);
            let sc = Scenario::uniform(
                &ModelProfile::alexnet_paper(),
                5,
                10e6,
                deadline,
                0.05,
                &mut rng,
            );
            let r = solve(&sc, &partition, Policy::ROBUST).unwrap();
            assert!(
                r.energy <= last * (1.0 + 1e-6),
                "deadline {deadline}: {} > {last}",
                r.energy
            );
            last = r.energy;
        }
    }

    #[test]
    fn energy_decreases_with_higher_risk() {
        let partition = vec![4; 5];
        let mut last = f64::INFINITY;
        for risk in [0.02, 0.04, 0.06, 0.08] {
            let mut rng = Rng::new(11);
            let sc =
                Scenario::uniform(&ModelProfile::alexnet_paper(), 5, 10e6, 0.19, risk, &mut rng);
            let r = solve(&sc, &partition, Policy::ROBUST).unwrap();
            assert!(r.energy <= last * (1.0 + 1e-6), "risk {risk}");
            last = r.energy;
        }
    }

    #[test]
    fn replay_negative_pivot_case() {
        // Regression: partition [1,6,7] on seed ...362 drove the barrier
        // into a non-PSD Hessian via the phase-I path.
        let mut rng = Rng::new(14484861180009783362u64);
        let n = 2 + rng.below(5);
        let mut srng = Rng::new(rng.next_u64());
        let sc = Scenario::uniform(
            &ModelProfile::alexnet_paper(),
            n,
            10e6,
            rng.range(0.18, 0.3),
            rng.range(0.02, 0.1),
            &mut srng,
        );
        let partition: Vec<usize> =
            (0..n).map(|_| rng.below(sc.devices[0].model.num_points())).collect();
        let dev = device_data(&sc, &partition, Policy::ROBUST);
        let mut prog =
            ResourceProgram { dev, b_total: sc.total_bandwidth_hz, phase1: false, start: vec![] };
        let heur = heuristic_start(&prog);
        eprintln!("heuristic_start present: {}", heur.is_some());
        if let Some(z) = &heur {
            prog.start = z.clone();
            for c in 0..prog.num_ineq() {
                let v = prog.constraint(c, z);
                assert!(v < 0.0, "constraint {c} = {v}");
            }
        }
        // probe the phase-I Hessian assembly at its start point
        let dev2 = device_data(&sc, &partition, Policy::ROBUST);
        let n = dev2.len();
        let mut start = vec![0.0; 2 * n + 1];
        for i in 0..n {
            start[i] = 0.9 / n as f64;
            start[n + i] = 0.5 * (dev2[i].f_min + dev2[i].f_max);
        }
        let p1 = ResourceProgram { dev: dev2, b_total: sc.total_bandwidth_hz, phase1: true, start: vec![] };
        let mut s0 = 0.0f64;
        for i in 0..n {
            let t_loc = if p1.dev[i].l == 0.0 { 0.0 } else { p1.dev[i].l / start[n + i] };
            s0 = s0.max(t_loc + p1.t_off(i, start[i]) - p1.dev[i].slack);
        }
        start[2 * n] = s0 + 1.0;
        let mut h = crate::linalg::Matrix::zeros(2 * n + 1, 2 * n + 1);
        let mut cg = vec![0.0; 2 * n + 1];
        for c in 0..p1.num_ineq() {
            let gi = p1.constraint(c, &start);
            eprintln!("c={c} g={gi:.4e}");
            assert!(gi < 0.0, "phase-I start infeasible at {c}");
            p1.constraint_grad(c, &start, &mut cg);
            h.rank1_update(1.0 / (gi * gi), &cg);
            p1.constraint_hess_accum(c, &start, -1.0 / gi, &mut h);
        }
        for i in 0..2 * n + 1 {
            eprintln!("H[{i}][{i}] = {:.4e}", h[(i, i)]);
        }
        let r = solve(&sc, &partition, Policy::ROBUST);
        assert!(r.is_ok(), "{:?}", r.err().map(|e| e.to_string()));
    }

    #[test]
    fn dual_matches_barrier() {
        forall("dual == barrier on random scenarios", 8, |rng| {
            let n = 2 + rng.below(5);
            let mut srng = Rng::new(rng.next_u64());
            let sc = Scenario::uniform(
                &ModelProfile::alexnet_paper(),
                n,
                10e6,
                rng.range(0.18, 0.3),
                rng.range(0.02, 0.1),
                &mut srng,
            );
            let partition: Vec<usize> =
                (0..n).map(|_| rng.below(sc.devices[0].model.num_points())).collect();
            let a = solve(&sc, &partition, Policy::ROBUST);
            let b = solve_dual(&sc, &partition, Policy::ROBUST);
            match (a, b) {
                (Ok(a), Ok(b)) => {
                    crate::util::check::close(b.energy, a.energy, 2e-2, 1e-6)
                        .map_err(|e| format!("energy mismatch: {e}"))?;
                    let plan = Plan {
                        partition,
                        bandwidth_hz: b.bandwidth_hz,
                        freq_ghz: b.freq_ghz,
                    };
                    if !plan.bandwidth_ok(&sc) {
                        return Err("dual exceeded bandwidth".into());
                    }
                    if !plan.feasible(&sc, Policy::ROBUST) {
                        return Err("dual infeasible".into());
                    }
                    Ok(())
                }
                (Err(_), Err(_)) => Ok(()),
                (a, b) => Err(format!(
                    "feasibility disagreement: barrier ok={} dual ok={}",
                    a.is_ok(),
                    b.is_ok()
                )),
            }
        });
    }

    #[test]
    fn full_offload_uses_min_frequency_energy() {
        // m = 0 everywhere: local energy must be ~0 and all energy offload.
        let sc = scenario(3, 5);
        let r = solve(&sc, &vec![0; 3], Policy::ROBUST).unwrap();
        for (i, d) in sc.devices.iter().enumerate() {
            let e_loc = d.energy_mean(0, r.freq_ghz[i], r.bandwidth_hz[i])
                - d.uplink.e_off(d.model.d_bits(0), r.bandwidth_hz[i]);
            assert!(e_loc.abs() < 1e-12);
        }
    }

    #[test]
    fn worst_case_policy_is_costlier() {
        let sc = scenario(5, 6);
        let partition = vec![2; 5];
        let robust = solve(&sc, &partition, Policy::ROBUST).unwrap();
        let worst = solve(&sc, &partition, Policy::WorstCase).unwrap();
        let mean = solve(&sc, &partition, Policy::MeanOnly).unwrap();
        // tighter margins cost energy: mean-only <= robust <= worst-case
        assert!(mean.energy <= robust.energy * (1.0 + 1e-9));
        assert!(robust.energy <= worst.energy * (1.0 + 1e-9));
    }
}
