//! Robust DNN partitioning + resource allocation (paper §V).
//!
//! Pipeline (Fig. 8): problem (9) → Tammer decomposition into the
//! resource subproblem (13)/(16) and the partitioning subproblem
//! (14)/(24) → CCP/ECR transform (Theorem 1, [`ecr`]) → convex
//! interior-point for resources ([`resource`]) and PCCP for partitioning
//! ([`pccp`]) → alternation ([`alternating`], Algorithm 2).  Benchmark
//! policies live in [`baselines`].
//!
//! The preferred entry point to this pipeline is the [`crate::engine`]
//! facade (`PlannerBuilder` → `Planner::plan`); the free functions here
//! remain as deprecated shims for one release.

pub mod alternating;
pub mod baselines;
pub mod cohort;
pub mod ecr;
pub mod pccp;
pub mod resource;
pub mod types;

#[allow(deprecated)] // legacy re-export kept for one release
pub use alternating::{solve as plan, AlternatingOptions, RobustPlan, SolverBudget};
pub use types::{Device, Plan, Policy, Scenario};
