//! Robust DNN partitioning + resource allocation (paper §V).
//!
//! Pipeline (Fig. 8): problem (9) → Tammer decomposition into the
//! resource subproblem (13)/(16) and the partitioning subproblem
//! (14)/(24) → CCP/ECR transform (Theorem 1, [`ecr`]) → convex
//! interior-point for resources ([`resource`]) and PCCP for partitioning
//! ([`pccp`]) → alternation ([`alternating`], Algorithm 2).  Benchmark
//! policies live in [`baselines`].

pub mod alternating;
pub mod baselines;
pub mod ecr;
pub mod pccp;
pub mod resource;
pub mod types;

pub use alternating::{solve as plan, AlternatingOptions, RobustPlan};
pub use types::{Device, Plan, Policy, Scenario};
