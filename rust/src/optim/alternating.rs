//! Algorithm 2: alternate the resource-allocation subproblem (16)/(23)
//! and the PCCP partitioning subproblem (24)/(36) until the objective of
//! problem (9) converges.
//!
//! Properties used by the figures:
//! * Fig. 9 — `avg_pccp_iters` (Algorithm-1 iterations per device);
//! * Fig. 10 — `trajectory` (objective after each outer iteration, from
//!   arbitrary initial partitions);
//! * Fig. 11 — wall-clock of [`solve`] vs N;
//! * Fig. 12–14 — `energy` of the returned plan.

use crate::risk::RiskBound;

use super::pccp::{self, PccpOptions};
use super::resource::{self, ResourceError};
use super::types::{Plan, Policy, Scenario};

/// Hard iteration/time budgets for one Algorithm-2 solve.  `0` (or
/// `None` for the wall clock) means unlimited — the [`Default`] budget
/// changes nothing.  When a budget runs out while the alternation holds
/// a feasible iterate, the solve returns that best-feasible-so-far plan
/// with [`RobustPlan::degraded`] set instead of spinning; it only errors
/// if no feasible iterate was ever reached.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct SolverBudget {
    /// Cap on outer alternation rounds (tighter of this and
    /// [`AlternatingOptions::max_outer`] wins).
    pub max_outer: usize,
    /// Cap on total Algorithm-1 (PCCP) iterations summed over devices
    /// and rounds.
    pub max_pccp: usize,
    /// Cap on total Newton iterations across every inner solve.
    pub max_newton: usize,
    /// Wall-clock cap for the whole solve.  **Non-deterministic**: the
    /// returned plan then depends on machine speed, so the fleet
    /// simulator and anything pinning byte-identical traces must leave
    /// this `None` and rely on the iteration caps.
    pub max_wall: Option<std::time::Duration>,
}

impl SolverBudget {
    /// No budget at all (the default).
    pub const UNLIMITED: SolverBudget =
        SolverBudget { max_outer: 0, max_pccp: 0, max_newton: 0, max_wall: None };

    /// True when no cap is set at all.
    pub fn is_unlimited(&self) -> bool {
        *self == SolverBudget::UNLIMITED
    }
}

/// Algorithm 2 knobs.
#[derive(Clone, Debug)]
pub struct AlternatingOptions {
    pub max_outer: usize,
    /// Relative objective-change stopping threshold θ_err.
    pub theta_err: f64,
    pub pccp: PccpOptions,
    /// Use the O(N) dual-decomposition resource solver instead of the
    /// joint barrier (ablation; default false = paper's IPT).
    pub dual_resource: bool,
    /// Post-convergence single-device local search: try moving each device
    /// to every alternative point with a resource re-solve and accept
    /// improvements.  Escapes the alternation's coordinate-descent traps
    /// so runs from different initial points converge to nearly the same
    /// objective (the paper's Fig. 10 behaviour).  Costs O(N·M) barrier
    /// solves per round (the joint barrier is ~0.5 ms at N=12 — measured
    /// faster than the dual decomposition at every N we run, see
    /// EXPERIMENTS.md §Perf); the candidate sweep fans out over
    /// [`AlternatingOptions::threads`] workers.
    pub polish: bool,
    /// Warm-start each outer iteration: seed every device's Algorithm-1
    /// linearization with its previous relaxed iterate, and start the
    /// resource barrier from the previous (b, f) when it is still
    /// strictly feasible.  (The paper re-initializes Algorithm 1 each
    /// call; warm starting converges to the same fixed points — the
    /// iterates only skip the re-discovery of the previous basin.)
    pub warm_start: bool,
    /// Worker threads for the polish candidate sweep (0 = one per
    /// available core, 1 = sequential).  Candidate evaluation is
    /// side-effect-free and the accept loop is sequential in a fixed
    /// order, so the thread count never changes the returned plan.
    pub threads: usize,
    /// Hard solve budget; [`SolverBudget::UNLIMITED`] by default.
    pub budget: SolverBudget,
}

impl Default for AlternatingOptions {
    fn default() -> Self {
        AlternatingOptions {
            max_outer: 20,
            theta_err: 1e-4,
            pccp: PccpOptions::default(),
            dual_resource: false,
            polish: true,
            warm_start: true,
            threads: 0,
            budget: SolverBudget::UNLIMITED,
        }
    }
}

/// Algorithm 2 outcome.
#[derive(Clone, Debug)]
pub struct RobustPlan {
    pub plan: Plan,
    /// Final expected total energy (objective (9a)).
    pub energy: f64,
    pub outer_iters: usize,
    /// Objective value after each outer iteration (Fig. 10).
    pub trajectory: Vec<f64>,
    /// Mean Algorithm-1 iterations per device, averaged over outer
    /// iterations (Fig. 9).
    pub avg_pccp_iters: f64,
    /// Total Newton iterations across every inner solve.
    pub newton_iters: usize,
    /// A [`SolverBudget`] ran out before the alternation converged; the
    /// plan is the best feasible iterate held at that moment (still a
    /// valid, feasibility-checked decision — just not polished to the
    /// usual fixed point).
    pub degraded: bool,
}

#[derive(Debug, Clone)]
pub enum PlanError {
    /// No partition assignment admits feasible resources.
    Infeasible(String),
    Solver(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Infeasible(s) => write!(f, "scenario infeasible: {s}"),
            PlanError::Solver(s) => write!(f, "solver failure: {s}"),
        }
    }
}

impl std::error::Error for PlanError {}

/// Heuristic initial partition: per device, the point minimizing the mean
/// total time at f_max with an equal bandwidth share — the most
/// feasibility-friendly start (used when the caller gives none).
pub fn heuristic_partition(sc: &Scenario) -> Vec<usize> {
    heuristic_partition_for(sc, RiskBound::Ecr)
}

/// [`heuristic_partition`] under an explicit risk bound (the margin
/// shifts which point looks feasibility-friendliest).
pub fn heuristic_partition_for(sc: &Scenario, bound: RiskBound) -> Vec<usize> {
    let b_each = sc.total_bandwidth_hz / sc.n() as f64;
    sc.devices.iter().map(|d| d.min_margin_time_point(b_each, Policy::Robust(bound))).collect()
}

/// Run Algorithm 2.  `init_partition` overrides the heuristic start
/// (Fig. 10 sweeps it).
#[deprecated(note = "construct an engine::Planner and call plan() with engine::Policy::Robust")]
pub fn solve(
    sc: &Scenario,
    opts: &AlternatingOptions,
    init_partition: Option<Vec<usize>>,
) -> Result<RobustPlan, PlanError> {
    solve_core(sc, opts, init_partition, RiskBound::Ecr, &mut crate::solver::NewtonWorkspace::new())
}

/// Algorithm 2 with a caller-owned Newton workspace for every resource
/// solve the alternation itself issues (the polish sweep's workers hold
/// their own).  The engine facade threads its long-lived workspace
/// through here; results are bit-identical at any workspace history.
pub(crate) fn solve_core(
    sc: &Scenario,
    opts: &AlternatingOptions,
    init_partition: Option<Vec<usize>>,
    bound: RiskBound,
    res_ws: &mut crate::solver::NewtonWorkspace,
) -> Result<RobustPlan, PlanError> {
    let mpol = Policy::Robust(bound);
    let mut partition = init_partition.unwrap_or_else(|| heuristic_partition_for(sc, bound));
    assert_eq!(partition.len(), sc.n());

    let mut resource_solve = |x: &[usize],
                              warm: Option<&resource::ResourceSolution>|
     -> Result<resource::ResourceSolution, ResourceError> {
        if opts.dual_resource {
            resource::solve_dual(sc, x, mpol)
        } else {
            resource::solve_warm_with(
                sc,
                x,
                mpol,
                if opts.warm_start { warm } else { None },
                &mut *res_ws,
            )
        }
    };

    // Initial resources; if the starting partition is infeasible fall back
    // to the fastest-time heuristic, then fail.
    let mut res = match resource_solve(&partition, None) {
        Ok(r) => r,
        Err(_) => {
            partition = heuristic_partition_for(sc, bound);
            resource_solve(&partition, None).map_err(|e| PlanError::Infeasible(e.to_string()))?
        }
    };

    let mut trajectory = vec![res.energy];
    let mut newton = res.newton_iters;
    let mut pccp_iter_sum = 0.0;
    let mut outer = 0;
    // Previous relaxed PCCP iterates: Algorithm 1's warm start for the
    // next outer iteration (each device resumes from its own basin).
    let mut warm_x: Option<Vec<Vec<f64>>> = None;

    // Budget bookkeeping.  `degraded` flips only on *budget* truncation,
    // never on ordinary `max_outer` exhaustion (hitting the configured
    // round cap is legacy behaviour, not degradation).  The wall clock is
    // sampled only when a wall cap is actually set, so budget-free and
    // iteration-budgeted solves stay bit-deterministic.
    let budget = opts.budget;
    // lint:allow(wall-clock): sampled only when a wall cap is set, and
    // budget-degraded outcomes are never cached or serialized as plans.
    let started = budget.max_wall.map(|_| std::time::Instant::now());
    let mut degraded = false;
    let outer_cap = if budget.max_outer > 0 {
        opts.max_outer.min(budget.max_outer)
    } else {
        opts.max_outer
    };
    let mut pccp_total = 0.0; // Algorithm-1 iterations summed over devices

    for k in 0..outer_cap {
        if let (Some(t0), Some(cap)) = (started, budget.max_wall) {
            if t0.elapsed() > cap {
                degraded = true;
                break;
            }
        }
        outer = k + 1;
        // -- partitioning step (Algorithm 1 at fixed resources) ------------
        let warm_ref = if opts.warm_start { warm_x.as_deref() } else { None };
        let part = pccp::solve(sc, &res.freq_ghz, &res.bandwidth_hz, &opts.pccp, warm_ref, bound)
            .map_err(|e| PlanError::Solver(e.to_string()))?;
        pccp_iter_sum += part.avg_iters;
        pccp_total += part.avg_iters * sc.n() as f64;
        newton += part.newton_iters;

        // -- resource step at the new partition ----------------------------
        let new_res = match resource_solve(&part.partition, Some(&res)) {
            Ok(r) => r,
            // PCCP's rounding can rarely produce a jointly infeasible
            // bandwidth demand; keep the previous iterate and stop.
            Err(_) => break,
        };

        // lint:allow(panic-path): trajectory is seeded with the start
        // energy before the loop, so last() always exists.
        let prev = *trajectory.last().unwrap();
        let changed = part.partition != partition;
        partition = part.partition;
        if opts.warm_start {
            warm_x = Some(part.x_relaxed);
        }
        res = new_res;
        newton += res.newton_iters;
        trajectory.push(res.energy);

        let rel = (prev - res.energy).abs() / prev.abs().max(1e-12);
        if !changed || rel < opts.theta_err {
            break;
        }
        // Converged rounds above exit clean; from here the round budget
        // and the work budgets decide whether the *next* round may run.
        if budget.max_outer > 0 && outer >= budget.max_outer {
            degraded = true;
            break;
        }
        if budget.max_newton > 0 && newton >= budget.max_newton {
            degraded = true;
            break;
        }
        if budget.max_pccp > 0 && pccp_total >= budget.max_pccp as f64 {
            degraded = true;
            break;
        }
    }

    // -- polish: single-device improvement moves ---------------------------
    // The sequential polish's candidate walk, with the O(N·M) evaluation
    // parallelized as a *resumable chunked sweep*: fan a chunk of
    // candidates out against the current partition, accept the first
    // improving one, then resume after it with a fresh fan-out (results
    // are stale once a move lands — moves interact through the shared
    // bandwidth).  Every candidate is judged against the exact partition
    // of its walk position, so the accepted sequence is the sequential
    // walk's and the outcome is identical at any thread count; each
    // chunk's wall-clock divides by the core count, and every sweep
    // worker holds its own Newton workspace.
    // A budget-truncated solve skips the polish: its whole point is to
    // stop spending, and the held iterate is already feasible.
    if opts.polish && !degraded {
        let mut rounds = 0;
        loop {
            rounds += 1;
            let mut cands: Vec<(usize, usize)> = Vec::new();
            for i in 0..sc.n() {
                for m in 0..sc.devices[i].model.num_points() {
                    if m != partition[i] {
                        cands.push((i, m));
                    }
                }
            }
            let mut improved = false;
            let threads = crate::util::par::threads_for(opts.threads, cands.len());
            if threads <= 1 {
                // Lazy sequential walk (the pre-PR loop) with one hoisted
                // workspace across every candidate solve.
                let mut ws = crate::solver::NewtonWorkspace::new();
                for &(i, m) in &cands {
                    if partition[i] == m {
                        continue;
                    }
                    let mut cand = partition.clone();
                    cand[i] = m;
                    if let Ok(r) = resource::solve_warm_with(sc, &cand, mpol, None, &mut ws) {
                        if r.energy < res.energy * (1.0 - 1e-6) {
                            partition = cand;
                            res = r;
                            improved = true;
                        }
                    }
                }
            } else {
                // Chunked fan-out: the speculative work discarded on an
                // acceptance is bounded by one chunk (~4 solves/worker).
                let chunk = threads * 4;
                let mut start = 0;
                while start < cands.len() {
                    let seg = &cands[start..(start + chunk).min(cands.len())];
                    let base = &partition;
                    let sweep: Vec<Option<resource::ResourceSolution>> =
                        crate::util::par::par_map_indexed_with(
                            seg.len(),
                            threads.min(seg.len()),
                            crate::solver::NewtonWorkspace::new,
                            |ws, k| {
                                let (i, m) = seg[k];
                                if base[i] == m {
                                    return None; // device already moved
                                }
                                let mut cand = base.clone();
                                cand[i] = m;
                                resource::solve_warm_with(sc, &cand, mpol, None, ws).ok()
                            },
                        );
                    let mut accepted = None;
                    for (k, &(i, m)) in seg.iter().enumerate() {
                        if partition[i] == m {
                            continue;
                        }
                        let Some(r0) = &sweep[k] else { continue };
                        if r0.energy < res.energy * (1.0 - 1e-6) {
                            let mut cand = partition.clone();
                            cand[i] = m;
                            partition = cand;
                            res = r0.clone();
                            improved = true;
                            accepted = Some(k);
                            break;
                        }
                    }
                    // Resume after the accepted candidate (the rest of the
                    // chunk is stale), or after the whole clean chunk.
                    start += match accepted {
                        Some(k) => k + 1,
                        None => seg.len(),
                    };
                }
            }
            if improved {
                trajectory.push(res.energy);
            }
            if !improved || rounds >= 5 {
                break;
            }
        }
        // Final high-precision resource solve at the polished partition.
        if let Ok(r) = resource_solve(&partition, Some(&res)) {
            if r.energy <= res.energy * (1.0 + 1e-6) {
                res = r;
            }
        }
    }

    let plan = Plan {
        partition,
        bandwidth_hz: res.bandwidth_hz.clone(),
        freq_ghz: res.freq_ghz.clone(),
    };
    debug_assert!(plan.bandwidth_ok(sc));
    Ok(RobustPlan {
        energy: res.energy,
        plan,
        outer_iters: outer,
        avg_pccp_iters: if outer > 0 { pccp_iter_sum / outer as f64 } else { 0.0 },
        trajectory,
        newton_iters: newton,
        degraded,
    })
}

/// Run Algorithm 2 from several structurally different initial partitions
/// and keep the best plan.  Algorithm 2 is a coordinate-descent scheme, so
/// individual runs can stop at local optima; a handful of starts recovers
/// the near-optimal behaviour the paper reports in Fig. 12 while staying
/// polynomial (starts × Algorithm-2 cost).
#[deprecated(note = "construct an engine::Planner and call plan() with engine::Policy::Multistart")]
pub fn solve_multistart(
    sc: &Scenario,
    opts: &AlternatingOptions,
    extra_starts: &[Vec<usize>],
) -> Result<RobustPlan, PlanError> {
    solve_multistart_core(
        sc,
        opts,
        extra_starts,
        RiskBound::Ecr,
        &mut crate::solver::NewtonWorkspace::new(),
    )
}

/// [`solve_multistart`]'s implementation with a caller-owned workspace.
pub(crate) fn solve_multistart_core(
    sc: &Scenario,
    opts: &AlternatingOptions,
    extra_starts: &[Vec<usize>],
    bound: RiskBound,
    res_ws: &mut crate::solver::NewtonWorkspace,
) -> Result<RobustPlan, PlanError> {
    let mut inits: Vec<Option<Vec<usize>>> = vec![
        None,                       // heuristic (fastest margin-adjusted time)
        Some(vec![0; sc.n()]),      // full offload
    ];
    // cheapest feasible one-hot per device at equal share / f_max
    let b_each = sc.total_bandwidth_hz / sc.n() as f64;
    let cheap: Vec<usize> = sc
        .devices
        .iter()
        .map(|d| {
            let f = d.model.device.f_max_ghz;
            (0..d.model.num_points())
                .filter(|&m| d.deadline_ok(m, f, b_each, Policy::Robust(bound)))
                .min_by(|&a, &b| d.energy_mean(a, f, b_each).total_cmp(&d.energy_mean(b, f, b_each)))
                .unwrap_or(0)
        })
        .collect();
    inits.push(Some(cheap));
    inits.extend(extra_starts.iter().cloned().map(Some));

    let mut best: Option<RobustPlan> = None;
    let mut last_err: Option<PlanError> = None;
    for init in inits {
        match solve_core(sc, opts, init, bound, res_ws) {
            Ok(p) => {
                if best.as_ref().map_or(true, |b| p.energy < b.energy) {
                    best = Some(p);
                }
            }
            Err(e) => last_err = Some(e),
        }
    }
    best.ok_or_else(|| last_err.unwrap_or_else(|| PlanError::Infeasible("no start".into())))
}

#[cfg(test)]
mod tests {
    #![allow(deprecated)] // the legacy entry points stay covered until removal

    use super::*;
    use crate::models::ModelProfile;
    use crate::util::rng::Rng;

    fn scenario(model: &ModelProfile, n: usize, b: f64, d: f64, eps: f64, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(model, n, b, d, eps, &mut rng)
    }

    #[test]
    fn alexnet_paper_setting_solves() {
        // Fig. 13 setting: N=12, B=10 MHz, D=180 ms, ε=0.02.
        let sc = scenario(&ModelProfile::alexnet_paper(), 12, 10e6, 0.18, 0.02, 7);
        let r = solve(&sc, &AlternatingOptions::default(), None).unwrap();
        assert!(r.plan.feasible(&sc, Policy::ROBUST));
        assert!(r.plan.bandwidth_ok(&sc));
        assert!(r.plan.freq_ok(&sc));
        assert!(r.energy > 0.0 && r.energy < 10.0, "energy={}", r.energy);
    }

    #[test]
    fn resnet_paper_setting_solves() {
        // Fig. 14 setting (deadline shifted 120→150 ms: our VM/channel
        // substrate makes 120 ms infeasible — see EXPERIMENTS.md).
        let sc = scenario(&ModelProfile::resnet152_paper(), 12, 30e6, 0.15, 0.04, 8);
        let r = solve(&sc, &AlternatingOptions::default(), None).unwrap();
        assert!(r.plan.feasible(&sc, Policy::ROBUST));
        assert!(r.energy > 0.0, "energy={}", r.energy);
    }

    #[test]
    fn objective_trajectory_is_nonincreasing_after_first_step() {
        let sc = scenario(&ModelProfile::alexnet_paper(), 8, 10e6, 0.2, 0.04, 9);
        let r = solve(&sc, &AlternatingOptions::default(), None).unwrap();
        for w in r.trajectory.windows(2).skip(1) {
            assert!(w[1] <= w[0] * (1.0 + 1e-6), "trajectory={:?}", r.trajectory);
        }
    }

    #[test]
    fn different_initial_points_converge_close() {
        // Fig. 10's claim: Algorithm 2 converges to (almost) the same
        // objective from different initial partitions.
        let sc = scenario(&ModelProfile::alexnet_paper(), 6, 10e6, 0.22, 0.02, 10);
        let m = sc.devices[0].model.num_points();
        let energies: Vec<f64> = [3usize, 7, 8]
            .iter()
            .map(|&p| {
                solve(&sc, &AlternatingOptions::default(), Some(vec![p.min(m - 1); 6]))
                    .unwrap()
                    .energy
            })
            .collect();
        let min = energies.iter().cloned().fold(f64::INFINITY, f64::min);
        let max = energies.iter().cloned().fold(0.0, f64::max);
        // Fig. 10's qualitative claim; coordinate descent admits a small
        // spread between basins on random geometry.
        assert!(
            (max - min) / min < 0.25,
            "initial-point sensitivity too high: {energies:?}"
        );
    }

    #[test]
    fn solve_is_deterministic_with_threads() {
        // The fan-out writes into pre-sized per-device slots and the
        // polish accepts in fixed order, so repeated runs — and runs at
        // different thread counts — must return the identical plan.
        let sc = scenario(&ModelProfile::alexnet_paper(), 12, 10e6, 0.18, 0.02, 77);
        let par = AlternatingOptions {
            threads: 4,
            pccp: PccpOptions { threads: 4, ..PccpOptions::default() },
            ..Default::default()
        };
        let seq = AlternatingOptions {
            threads: 1,
            pccp: PccpOptions { threads: 1, ..PccpOptions::default() },
            ..Default::default()
        };
        let a = solve(&sc, &par, None).unwrap();
        let b = solve(&sc, &par, None).unwrap();
        let c = solve(&sc, &seq, None).unwrap();
        assert_eq!(a.plan, b.plan);
        assert!(a.energy == b.energy, "{} vs {}", a.energy, b.energy);
        assert_eq!(a.newton_iters, b.newton_iters);
        assert_eq!(a.plan, c.plan, "thread count changed the plan");
        assert!(a.energy == c.energy, "{} vs {}", a.energy, c.energy);
    }

    #[test]
    fn warm_start_toggle_reaches_similar_energy() {
        // Warm starting accelerates the alternation; it must not change
        // the quality of the fixed point materially.
        let sc = scenario(&ModelProfile::alexnet_paper(), 8, 10e6, 0.2, 0.04, 78);
        let warm = solve(&sc, &AlternatingOptions::default(), None).unwrap();
        let cold = solve(
            &sc,
            &AlternatingOptions { warm_start: false, ..Default::default() },
            None,
        )
        .unwrap();
        assert!(
            (warm.energy - cold.energy).abs() / cold.energy < 0.05,
            "warm {} vs cold {}",
            warm.energy,
            cold.energy
        );
    }

    #[test]
    fn unlimited_budget_never_degrades() {
        let sc = scenario(&ModelProfile::alexnet_paper(), 8, 10e6, 0.2, 0.04, 21);
        let r = solve(&sc, &AlternatingOptions::default(), None).unwrap();
        assert!(!r.degraded);
        assert!(SolverBudget::default().is_unlimited());
    }

    #[test]
    fn outer_budget_returns_best_feasible_so_far_flagged_degraded() {
        // Force the start far from the optimum so one round cannot
        // converge; the budgeted solve must still return a feasible plan.
        let sc = scenario(&ModelProfile::alexnet_paper(), 8, 10e6, 0.22, 0.02, 22);
        let opts = AlternatingOptions {
            budget: SolverBudget { max_outer: 1, ..SolverBudget::UNLIMITED },
            ..Default::default()
        };
        let r = solve(&sc, &opts, Some(vec![0; 8])).unwrap();
        assert!(r.degraded, "1-round budget from a bad start should truncate");
        assert!(r.outer_iters <= 1);
        assert!(r.plan.feasible(&sc, Policy::ROBUST));
        assert!(r.plan.bandwidth_ok(&sc));
        // The full solve from the same start must do at least as well.
        let full = solve(&sc, &AlternatingOptions::default(), Some(vec![0; 8])).unwrap();
        assert!(full.energy <= r.energy * (1.0 + 1e-9));
    }

    #[test]
    fn newton_budget_truncates_deterministically() {
        let sc = scenario(&ModelProfile::alexnet_paper(), 8, 10e6, 0.22, 0.02, 23);
        let opts = AlternatingOptions {
            budget: SolverBudget { max_newton: 1, ..SolverBudget::UNLIMITED },
            ..Default::default()
        };
        let a = solve(&sc, &opts, Some(vec![0; 8])).unwrap();
        let b = solve(&sc, &opts, Some(vec![0; 8])).unwrap();
        assert!(a.degraded);
        assert!(a.plan.feasible(&sc, Policy::ROBUST));
        assert_eq!(a.plan, b.plan, "budgeted solves must stay deterministic");
        assert_eq!(a.newton_iters, b.newton_iters);
    }

    #[test]
    fn infeasible_scenario_reports_error() {
        let sc = scenario(&ModelProfile::alexnet_paper(), 6, 10e6, 0.004, 0.02, 11);
        assert!(matches!(
            solve(&sc, &AlternatingOptions::default(), None),
            Err(PlanError::Infeasible(_))
        ));
    }

    #[test]
    fn dual_resource_variant_agrees() {
        let sc = scenario(&ModelProfile::alexnet_paper(), 6, 10e6, 0.22, 0.04, 12);
        let a = solve(&sc, &AlternatingOptions::default(), None).unwrap();
        let b = solve(
            &sc,
            &AlternatingOptions { dual_resource: true, ..Default::default() },
            None,
        )
        .unwrap();
        assert!(
            (a.energy - b.energy).abs() / a.energy < 0.05,
            "barrier {} vs dual {}",
            a.energy,
            b.energy
        );
    }

    #[test]
    fn energy_monotone_in_deadline() {
        let mut last = f64::INFINITY;
        for d in [0.17, 0.20, 0.24, 0.28] {
            let sc = scenario(&ModelProfile::alexnet_paper(), 8, 10e6, d, 0.02, 13);
            let r = solve(&sc, &AlternatingOptions::default(), None).unwrap();
            assert!(r.energy <= last * 1.02, "D={d}: {} > {last}", r.energy);
            last = r.energy;
        }
    }
}
