//! Exact Conic Reformulation of chance constraints (Theorem 1, from Li et
//! al., "Coping uncertainty in coexistence via exploitation of
//! interference threshold violation", MobiHoc'19).
//!
//! For a random vector λ with known mean λ̄ and covariance C (distribution
//! unknown),
//!
//! ```text
//!   P{ aᵀλ ≤ z } ≥ 1 − ε    ⟺    aᵀλ̄ + √((1−ε)/ε) · √(aᵀCa) ≤ z
//! ```
//!
//! where the ⟸ direction holds for *every* distribution with those
//! moments (one-sided Chebyshev / Cantelli), and ⟹ holds because the
//! bound is achieved by a worst-case two-point distribution — hence
//! "exact": no conservatism is added in the optimization space beyond
//! what moment information alone permits.

/// σ(ε) = √((1−ε)/ε).
///
/// Total: risk levels are validated at the API boundary
/// (`Device::validate` / `PlanRequest::validate` →
/// `engine::PlanError::InvalidRisk`), so a pathological ε reaching this
/// depth is clamped to the representable range instead of panicking
/// inside a solver thread (the historical `assert!` here was the
/// engine's one hidden panic path).
pub fn sigma(eps: f64) -> f64 {
    let eps = crate::risk::clamp_risk(eps);
    ((1.0 - eps) / eps).sqrt()
}

/// LHS of the deterministic reformulation: aᵀλ̄ + σ(ε)·√(aᵀCa) for the
/// already-aggregated scalars (mean of the sum, variance of the sum).
pub fn ecr_lhs(mean_sum: f64, var_sum: f64, eps: f64) -> f64 {
    mean_sum + sigma(eps) * var_sum.max(0.0).sqrt()
}

/// The deterministic constraint (18): `ecr_lhs ≤ z`.
pub fn ecr_holds(mean_sum: f64, var_sum: f64, eps: f64, z: f64) -> bool {
    ecr_lhs(mean_sum, var_sum, eps) <= z
}

/// Cantelli bound: for any distribution with the given moments,
/// P{X > z} ≤ var / (var + (z − mean)²) when z > mean.  This is the
/// guarantee the ECR constraint enforces; the Monte-Carlo tests check
/// empirical violation probabilities against it.
pub fn cantelli_violation_bound(mean: f64, var: f64, z: f64) -> f64 {
    if z <= mean {
        return 1.0;
    }
    let d = z - mean;
    (var / (var + d * d)).min(1.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn sigma_reference_values() {
        // ε = 0.02 → σ = √49 = 7;  ε = 0.5 → σ = 1.
        assert!((sigma(0.02) - 7.0).abs() < 1e-12);
        assert!((sigma(0.5) - 1.0).abs() < 1e-12);
        assert!((sigma(0.1) - 3.0).abs() < 1e-12);
    }

    #[test]
    fn sigma_is_total_on_pathological_risk() {
        // Validation happens at the API boundary (PlanError::InvalidRisk);
        // here the transform clamps instead of panicking (the historical
        // assert! was the solver's one hidden panic path).
        assert_eq!(sigma(0.0), sigma(crate::risk::MIN_RISK));
        assert_eq!(sigma(1.0), sigma(crate::risk::MAX_RISK));
        assert!(sigma(f64::NAN).is_finite());
        assert!(sigma(0.0).is_finite() && sigma(0.0) > 1e4);
    }

    #[test]
    fn ecr_iff_cantelli_threshold() {
        // ECR holds exactly when the Cantelli violation bound ≤ ε.
        forall("ECR <-> Cantelli", 500, |rng| {
            let mean = rng.range(0.01, 1.0);
            let var = rng.range(1e-6, 0.05);
            let z = rng.range(0.01, 2.0);
            let eps = rng.range(0.005, 0.3);
            let lhs_ok = ecr_holds(mean, var, eps, z);
            let cantelli_ok = cantelli_violation_bound(mean, var, z) <= eps + 1e-12;
            if lhs_ok == cantelli_ok {
                Ok(())
            } else {
                Err(format!(
                    "mismatch: ecr={lhs_ok} cantelli={cantelli_ok} \
                     (mean={mean} var={var} z={z} eps={eps})"
                ))
            }
        });
    }

    #[test]
    fn empirical_violation_below_risk_when_ecr_holds() {
        // Sample from several mean/variance-matching distributions; when
        // the ECR constraint holds, the empirical violation must be ≤ ε.
        let trials = 40_000;
        forall("ECR guarantee", 12, |rng| {
            let mean = rng.range(0.05, 0.3);
            let var = rng.range(1e-5, 2e-3);
            let eps = rng.range(0.02, 0.2);
            // choose z exactly at the ECR boundary + small slack
            let z = ecr_lhs(mean, var, eps) * 1.001;
            let kind = rng.below(3);
            let mut viol = 0u32;
            for _ in 0..trials {
                let t = match kind {
                    0 => rng.lognormal_mv(mean, var),
                    1 => rng.gamma_mv(mean, var),
                    _ => {
                        let sd = var.sqrt();
                        let shift = (mean - sd).max(0.0);
                        shift + rng.exponential(1.0 / (mean - shift))
                    }
                };
                if t > z {
                    viol += 1;
                }
            }
            let p = viol as f64 / trials as f64;
            if p <= eps {
                Ok(())
            } else {
                Err(format!("violation {p} > eps {eps} (kind={kind})"))
            }
        });
    }

    #[test]
    fn ecr_is_tight_for_two_point_distribution() {
        // The worst-case two-point distribution achieves the bound: mass
        // 1−ε at a, mass ε at b with matching moments violates z just at ε.
        let (mean, var, eps) = (0.1, 4e-4, 0.05);
        let s = sigma(eps);
        // two-point: a = mean − √(var·ε/(1−ε)), b = mean + √(var(1−ε)/ε)
        let a = mean - (var * eps / (1.0 - eps)).sqrt();
        let b = mean + (var * (1.0 - eps) / eps).sqrt();
        // check moments
        let m = (1.0 - eps) * a + eps * b;
        let v = (1.0 - eps) * (a - m).powi(2) + eps * (b - m).powi(2);
        assert!((m - mean).abs() < 1e-12);
        assert!((v - var).abs() < 1e-12);
        // b sits exactly at the ECR threshold mean + σ√var
        assert!((b - (mean + s * var.sqrt())).abs() < 1e-12);
        // so any z < b is violated with probability exactly ε:
        let mut rng = Rng::new(1);
        let z = b - 1e-9;
        let trials = 200_000;
        let viol = (0..trials)
            .filter(|_| (if rng.f64() < eps { b } else { a }) > z)
            .count() as f64
            / trials as f64;
        assert!((viol - eps).abs() < 0.004, "viol={viol}");
    }
}
