//! One-sided Bernstein tail margin for bounded jitter.
//!
//! For a zero-mean deviation X with variance v and |X| ≤ M almost
//! surely, Bernstein's inequality gives
//!
//! ```text
//!   P{ X > t } ≤ exp( −t² / (2(v + M·t/3)) ).
//! ```
//!
//! Setting the right-hand side to ε and solving the resulting quadratic
//! for t yields the closed-form margin below: with L = ln(1/ε),
//!
//! ```text
//!   t(ε) = L·M/3 + √( (L·M/3)² + 2·v·L ).
//! ```
//!
//! The margin grows like √(2·v·ln(1/ε)) when variance dominates and
//! like M·ln(1/ε) when the support does — both logarithmic in 1/ε,
//! versus Cantelli's √((1−ε)/ε) ≈ 1/√ε, which is why Bernstein wins at
//! small risk levels when the jitter is genuinely bounded.  (For a sum
//! of independent per-component deviations the inequality holds with
//! M = the largest component bound; using the *sum* of the component
//! bounds, as the caller does, is strictly conservative.)

use super::clamp_risk;

/// Smallest t with the Bernstein tail ≤ ε, for variance `v` and support
/// bound `support` (both ≥ 0).
pub fn margin(v: f64, support: f64, eps: f64) -> f64 {
    let l = (1.0 / clamp_risk(eps)).ln();
    let a = support.max(0.0) * l / 3.0;
    a + (a * a + 2.0 * v.max(0.0) * l).sqrt()
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The closed form really inverts the tail: plugging t(ε) back into
    /// the Bernstein exponent recovers ε.
    #[test]
    fn margin_inverts_the_tail_bound() {
        for (v, m, eps) in [(1e-4, 0.05, 0.01), (4e-6, 0.01, 0.05), (2.5e-3, 0.3, 0.001)] {
            let t = margin(v, m, eps);
            let tail = (-(t * t) / (2.0 * (v + m * t / 3.0))).exp();
            assert!((tail - eps).abs() < 1e-12 * (1.0 + eps), "v={v} m={m}: {tail} vs {eps}");
        }
    }

    #[test]
    fn margin_monotone_in_risk_and_support() {
        let v = 1e-4;
        assert!(margin(v, 0.02, 0.01) > margin(v, 0.02, 0.05));
        assert!(margin(v, 0.05, 0.01) > margin(v, 0.02, 0.01));
        // No support: reduces to the sub-Gaussian-style √(2·v·ln(1/ε)).
        let eps = 0.02;
        let want = (2.0 * v * (1.0f64 / eps).ln()).sqrt();
        assert!((margin(v, 0.0, eps) - want).abs() < 1e-15);
    }

    #[test]
    fn degenerate_inputs_are_total() {
        assert_eq!(margin(0.0, 0.0, 0.05), 0.0);
        assert!(margin(1e-4, 0.02, 0.0).is_finite(), "eps clamped, not panicked");
        assert!(margin(-1.0, -1.0, 0.5) >= 0.0);
    }
}
