//! Standard-normal quantile (inverse CDF) for the Gaussian risk bound.
//!
//! Acklam's rational approximation: two tail regimes plus a central
//! regime, relative error below 1.15e-9 over (0, 1) — far inside the
//! Monte-Carlo noise every consumer of these margins operates under,
//! and dependency-free (this crate vendors no libm extensions).

use super::clamp_risk;

/// Break-point between the central and tail rational approximations.
const P_LOW: f64 = 0.02425;

/// Φ⁻¹(p) for p ∈ (0, 1) (Acklam).  Inputs outside (0, 1) are clamped
/// to the representable risk range first.
pub fn inv_norm_cdf(p: f64) -> f64 {
    let p = clamp_risk(p);
    // Coefficients from Acklam's algorithm (lower-tail form).
    const A: [f64; 6] = [
        -3.969_683_028_665_376e1,
        2.209_460_984_245_205e2,
        -2.759_285_104_469_687e2,
        1.383_577_518_672_690e2,
        -3.066_479_806_614_716e1,
        2.506_628_277_459_239,
    ];
    const B: [f64; 5] = [
        -5.447_609_879_822_406e1,
        1.615_858_368_580_409e2,
        -1.556_989_798_598_866e2,
        6.680_131_188_771_972e1,
        -1.328_068_155_288_572e1,
    ];
    const C: [f64; 6] = [
        -7.784_894_002_430_293e-3,
        -3.223_964_580_411_365e-1,
        -2.400_758_277_161_838,
        -2.549_732_539_343_734,
        4.374_664_141_464_968,
        2.938_163_982_698_783,
    ];
    const D: [f64; 4] = [
        7.784_695_709_041_462e-3,
        3.224_671_290_700_398e-1,
        2.445_134_137_142_996,
        3.754_408_661_907_416,
    ];
    if p < P_LOW {
        let q = (-2.0 * p.ln()).sqrt();
        (((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    } else if p <= 1.0 - P_LOW {
        let q = p - 0.5;
        let r = q * q;
        (((((A[0] * r + A[1]) * r + A[2]) * r + A[3]) * r + A[4]) * r + A[5]) * q
            / (((((B[0] * r + B[1]) * r + B[2]) * r + B[3]) * r + B[4]) * r + 1.0)
    } else {
        let q = (-2.0 * (1.0 - p).ln()).sqrt();
        -(((((C[0] * q + C[1]) * q + C[2]) * q + C[3]) * q + C[4]) * q + C[5])
            / ((((D[0] * q + D[1]) * q + D[2]) * q + D[3]) * q + 1.0)
    }
}

/// z(ε) = Φ⁻¹(1−ε), floored at 0: the Gaussian margin coefficient.
/// (For ε ≥ 0.5 the raw quantile is ≤ 0; a negative margin would plan
/// *inside* the mean, so the floor degrades gracefully to mean-only.)
pub fn z(eps: f64) -> f64 {
    inv_norm_cdf(1.0 - clamp_risk(eps)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_quantiles() {
        // Textbook values to 4+ decimals.
        for (p, want) in [
            (0.975, 1.959_964),
            (0.95, 1.644_854),
            (0.99, 2.326_348),
            (0.5, 0.0),
            (0.025, -1.959_964),
            (0.001, -3.090_232),
        ] {
            let got = inv_norm_cdf(p);
            assert!((got - want).abs() < 1e-5, "p={p}: {got} vs {want}");
        }
    }

    #[test]
    fn z_is_monotone_decreasing_and_floored() {
        let mut last = f64::INFINITY;
        for eps in [0.001, 0.01, 0.05, 0.1, 0.3, 0.49] {
            let v = z(eps);
            assert!(v < last, "z not decreasing at {eps}");
            assert!(v > 0.0);
            last = v;
        }
        assert_eq!(z(0.5), 0.0);
        assert_eq!(z(0.9), 0.0, "margins never go negative");
    }

    #[test]
    fn z_below_cantelli_sigma_for_small_eps() {
        for eps in [0.005, 0.01, 0.05, 0.1, 0.2, 0.3, 0.49] {
            let sigma = crate::optim::ecr::sigma(eps);
            assert!(z(eps) < sigma, "eps={eps}: z {} !< sigma {sigma}", z(eps));
        }
    }

    #[test]
    fn symmetry_of_the_tails() {
        for p in [0.001, 0.01, 0.2] {
            assert!((inv_norm_cdf(p) + inv_norm_cdf(1.0 - p)).abs() < 1e-9);
        }
    }
}
