//! Online conformal calibration of the [`super::RiskBound::Calibrated`]
//! margin scale.
//!
//! The Cantelli/ECR margin is distribution-free and therefore usually
//! conservative: on a long-lived fleet the observed violation frequency
//! sits far below ε, and every unit of unneeded margin is energy spent.
//! [`Calibration`] closes the loop in the style of adaptive conformal
//! inference (Gibbs & Candès 2021): after each Monte-Carlo evaluation
//! of an executed plan, the controller nudges a multiplicative scale on
//! the Cantelli quantile —
//!
//! * observed violation **under** budget → the scale decays by a factor
//!   `1 − γ·(ε − p̂)/ε` (slow, proportional to the unused budget);
//! * observed violation **over** budget → the scale inflates 8× faster
//!   (asymmetry keeps the guarantee side sticky).
//!
//! The scale is floored at [`floor_scale`]: the smallest multiple of
//! σ(ε) at which both the Gaussian quantile and a slightly inflated
//! exponential tail still stay under ε.  The controller therefore
//! converges, on well-behaved jitter, to margins near the
//! Gaussian/exponential optimum without ever descending into the regime
//! where moment-matching families are known to violate — which is what
//! keeps the fleet's empirical violation ≤ ε + sampling slack during
//! calibration, not just after it.
//!
//! Everything here is deterministic: same observation sequence ⇒ same
//! scale trajectory ⇒ same (quantized) [`super::RiskBound`] sequence,
//! preserving the fleet simulator's byte-identical-trace contract.

use super::{clamp_risk, gauss, RiskBound};
use crate::optim::ecr;

/// Default decay rate γ (fraction of the unused risk budget converted
/// into margin shrinkage per observation).
const DEFAULT_GAMMA: f64 = 0.08;

/// Inflation asymmetry: over-budget observations move the scale this
/// many times faster than under-budget ones shrink it.
const INFLATE_FACTOR: f64 = 8.0;

/// Hard ceiling on the conformal scale (2× Cantelli is already far past
/// any useful margin; beyond it the scenario is simply infeasible).
const MAX_SCALE: f64 = 2.0;

/// Smallest safe conformal scale at risk level ε: the larger of the
/// Gaussian quantile and the inflated exponential quantile
/// `ln(1/ε) − 0.9`, expressed as a fraction of σ(ε) (capped at 1 — the
/// calibrated bound never plans looser than plain ECR needs).
pub fn floor_scale(eps: f64) -> f64 {
    let eps = clamp_risk(eps);
    let u = gauss::z(eps).max((1.0 / eps).ln() - 0.9);
    (u / ecr::sigma(eps)).min(1.0)
}

/// Online conformal controller for the calibrated bound's scale.
#[derive(Clone, Debug)]
pub struct Calibration {
    /// Continuous scale state (the emitted bound quantizes it).
    scale: f64,
    /// Decay rate γ.
    gamma: f64,
    /// Monte-Carlo observations folded in so far.
    observations: u64,
}

impl Default for Calibration {
    fn default() -> Self {
        Calibration::new()
    }
}

impl Calibration {
    /// A fresh calibrator at scale 1 (margins identical to ECR).
    pub fn new() -> Calibration {
        Calibration::with_scale(1.0)
    }

    /// Seed the scale explicitly (e.g. from a parsed `calibrated:0.8`).
    pub fn with_scale(scale: f64) -> Calibration {
        let scale =
            if scale.is_finite() { scale.clamp(super::SCALE_QUANTUM, MAX_SCALE) } else { 1.0 };
        Calibration { scale, gamma: DEFAULT_GAMMA, observations: 0 }
    }

    pub fn scale(&self) -> f64 {
        self.scale
    }

    pub fn observations(&self) -> u64 {
        self.observations
    }

    /// The (quantized) bound the current state corresponds to.
    pub fn bound(&self) -> RiskBound {
        RiskBound::calibrated(self.scale)
    }

    /// Fold in one Monte-Carlo check: `excess` is the worst observed
    /// `violation probability − ε` over the fleet (the simulator's
    /// per-step metric) and `eps` the risk level it was measured
    /// against.  Returns the updated quantized bound.
    pub fn observe(&mut self, excess: f64, eps: f64) -> RiskBound {
        let eps = clamp_risk(eps);
        self.observations += 1;
        let p = (eps + excess).max(0.0);
        let step = if p > eps {
            (self.gamma * INFLATE_FACTOR * ((p - eps) / eps)).min(0.5)
        } else {
            -self.gamma * ((eps - p) / eps).min(1.0)
        };
        self.scale = (self.scale * (1.0 + step)).clamp(floor_scale(eps), MAX_SCALE);
        self.bound()
    }

    /// Snap the continuous state back to an applied bound — the fleet
    /// driver calls this when a recalibration is rejected (an inflating
    /// re-plan turned out infeasible), so the controller does not keep
    /// proposing the refused scale.
    pub fn reset_to(&mut self, bound: RiskBound) {
        if let Some(s) = bound.scale() {
            self.scale = s;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clean_observations_shrink_toward_the_floor() {
        let eps = 0.05;
        let mut c = Calibration::new();
        let mut last = c.scale();
        for _ in 0..200 {
            c.observe(-eps, eps); // zero observed violation
            assert!(c.scale() <= last + 1e-15, "scale must be non-increasing");
            last = c.scale();
        }
        let floor = floor_scale(eps);
        assert!((c.scale() - floor).abs() < 1e-12, "{} vs floor {floor}", c.scale());
        assert!(floor < 1.0 && floor > 0.0);
        assert_eq!(c.observations(), 200);
    }

    #[test]
    fn violations_inflate_faster_than_calm_shrinks() {
        let eps = 0.05;
        let mut c = Calibration::with_scale(0.6);
        let s0 = c.scale();
        c.observe(0.02, eps); // p̂ = 0.07 > ε
        let up = c.scale() - s0;
        let mut d = Calibration::with_scale(0.6);
        d.observe(-0.02, eps); // p̂ = 0.03 < ε
        let down = s0 - d.scale();
        assert!(up > 0.0 && down > 0.0);
        assert!(up > down, "inflation {up} must outpace decay {down}");
        // and never above the hard ceiling
        let mut e = Calibration::with_scale(1.9);
        for _ in 0..50 {
            e.observe(0.5, eps);
        }
        assert!(e.scale() <= MAX_SCALE + 1e-12);
    }

    #[test]
    fn floor_keeps_the_exponential_tail_under_eps() {
        // At the floor, margin = u·σ_dev with u = max(z, ln(1/ε) − 0.9);
        // a shifted-exponential deviation exceeds mean + u·sd with
        // probability exp(−(1+u)), which must stay below ε.
        for eps in [0.01, 0.02, 0.05, 0.1, 0.2, 0.3] {
            let u = floor_scale(eps) * ecr::sigma(eps);
            let exp_tail = (-(1.0 + u)).exp();
            assert!(exp_tail <= eps, "eps={eps}: exp tail {exp_tail} > eps at the floor");
            let gauss_ok = gauss::z(eps) <= u + 1e-12;
            assert!(gauss_ok, "eps={eps}: floor sits below the Gaussian quantile");
        }
    }

    #[test]
    fn reset_to_snaps_the_state() {
        let mut c = Calibration::with_scale(0.4);
        c.reset_to(RiskBound::calibrated(0.9));
        assert!((c.scale() - 0.9).abs() < 1e-12);
        c.reset_to(RiskBound::Ecr); // scale-free bound: no-op
        assert!((c.scale() - 0.9).abs() < 1e-12);
    }

    #[test]
    fn trajectory_is_deterministic() {
        let run = || {
            let mut c = Calibration::new();
            (0..50)
                .map(|i| c.observe(if i % 7 == 0 { 0.01 } else { -0.03 }, 0.04))
                .collect::<Vec<_>>()
        };
        assert_eq!(run(), run());
    }
}
