//! First-class risk-bound layer: pluggable chance-constraint transforms.
//!
//! The paper turns the probabilistic deadline `P{T_n ≤ D_n} ≥ 1 − ε_n`
//! into a deterministic margin using only the mean and variance of the
//! inference time (Theorem 1, eq. 22/28).  That transform — "reserve
//! `margin(ε)` of the deadline for jitter" — is one point in a design
//! space: with more distributional knowledge a tighter margin buys the
//! same guarantee for less energy.  [`RiskBound`] makes the transform a
//! first-class, pluggable value threaded through every layer
//! (`optim → engine → fleet → service → CLI`):
//!
//! | bound | margin at point m | assumption | when to pick it |
//! |---|---|---|---|
//! | [`RiskBound::Ecr`] | σ(ε)·√(v_loc+v_vm), σ = √((1−ε)/ε) | mean + variance only (Cantelli, distribution-free) | the default; the paper's Theorem 1 |
//! | [`RiskBound::Gaussian`] | Φ⁻¹(1−ε)·√(v_loc+v_vm) | jitter ≈ normal | tightest margins when profiling shows near-normal residuals |
//! | [`RiskBound::Bernstein`] | min(Bernstein tail, ECR, support) | bounded jitter (support from `worst_dev_factor`) | small ε with bounded outliers: log(1/ε) growth beats Cantelli's 1/√ε |
//! | [`RiskBound::Calibrated`] | scale·σ(ε)·√(v_loc+v_vm) | none a priori; scale learned online | long-lived fleets: conformal feedback shrinks the Cantelli margin toward what the observed violations justify |
//!
//! # Convexity invariant
//!
//! Every bound's margin is a **constant per partition point m** — it
//! depends on the model profile and ε, never on the resource variables
//! `(b, f)`.  The resource subproblem (23) therefore sees the margin
//! only through the constant deadline budget `D′ = D − t̄_vm − margin`,
//! and its convexity (and the interior-point machinery built on it) is
//! untouched no matter which bound is active.  The partitioning
//! subproblem stays a DC program: bounds that are a pure multiple of the
//! total standard deviation ([`RiskBound::std_factor`]) reuse the
//! paper's exact `σ·√(xᵀWx)` coupling, and the rest enter as a linear
//! per-point margin `Σ_m x_m·margin_m` (exact at the one-hot vertices
//! the relaxation is rounded to).
//!
//! Risk levels are validated at the API boundary
//! ([`validate_risk`] → `engine::PlanError::InvalidRisk`), so the
//! margin math here is total: pathological ε are clamped, never
//! panicked on.

pub mod bernstein;
pub mod conformal;
pub mod gauss;

pub use conformal::Calibration;

use crate::models::ModelProfile;
use crate::optim::ecr;

/// Smallest representable risk level; ε below this is clamped (σ(1e-9)
/// ≈ 3.2e4 — a margin so conservative it rejects almost everything,
/// which is the right failure mode for a nonsensical request that
/// slipped past validation).
pub const MIN_RISK: f64 = 1e-9;

/// Largest representable risk level (1 − [`MIN_RISK`]).
pub const MAX_RISK: f64 = 1.0 - 1e-9;

/// Quantization grid for the calibrated bound's conformal scale: scales
/// agreeing to 1e-3 compare equal, hash equal, and fingerprint equal,
/// so online calibration cannot thrash the plan cache with sub-visible
/// scale moves.
pub const SCALE_QUANTUM: f64 = 1e-3;

/// Clamp ε into the open interval the transforms are defined on.
pub fn clamp_risk(eps: f64) -> f64 {
    if eps.is_finite() {
        eps.clamp(MIN_RISK, MAX_RISK)
    } else {
        // NaN / ±inf: fall to the most conservative representable level.
        MIN_RISK
    }
}

/// Structured risk validation shared by `Device`, `PlanRequest`, the
/// scenario deltas, and the fleet options (the engine maps an `Err` to
/// `PlanError::InvalidRisk` instead of panicking deep in a solver).
pub fn validate_risk(eps: f64) -> Result<(), String> {
    if eps.is_finite() && eps > 0.0 && eps < 1.0 {
        Ok(())
    } else {
        Err(format!("risk level must be in (0, 1), got {eps}"))
    }
}

/// A chance-constraint transform: deadline margin as a function of the
/// model profile, the partition point, and the risk level ε.
///
/// `Copy`/`Eq`/`Hash` are deliberate: the bound travels inside
/// `optim::Policy`, keys the engine's plan-cache fingerprint, and is
/// compared across fleet recalibrations — the calibrated scale is
/// stored pre-quantized (units of [`SCALE_QUANTUM`]) to keep all three
/// exact.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub enum RiskBound {
    /// Theorem 1's Exact Conic Reformulation (Cantelli):
    /// σ(ε)·√(v_loc+v_vm).  Distribution-free and the repo default —
    /// bit-identical to the pre-refactor `Policy::Robust` margins.
    #[default]
    Ecr,
    /// Gaussian quantile Φ⁻¹(1−ε)·√(v_loc+v_vm): exact when jitter is
    /// normal, strictly below ECR for every ε < 0.5.  Heavier-tailed
    /// jitter (e.g. the shifted-exponential stress family) can exceed ε
    /// by a bounded amount — see EXPERIMENTS.md §Risk bounds.
    Gaussian,
    /// One-sided Bernstein bound with the profiled support
    /// (`worst_dev_factor`·√v_loc + 3.5·√v_vm): the smallest of the
    /// Bernstein tail, the ECR margin, and the support itself, so it is
    /// never worse than ECR and wins at small ε when jitter is bounded.
    Bernstein,
    /// Conformally calibrated Cantelli: `scale`·σ(ε)·√(v_loc+v_vm) with
    /// the scale learned online from observed violations (see
    /// [`Calibration`]).  Starts at scale 1 (= ECR) and shrinks while
    /// the empirical violation stays under ε.
    Calibrated {
        /// Conformal scale in units of [`SCALE_QUANTUM`] (so 1000 = ×1.0).
        scale_q: u16,
    },
}

impl RiskBound {
    /// The calibrated bound at a given conformal scale (quantized to
    /// [`SCALE_QUANTUM`]; clamped to (0, ~65.5]).
    pub fn calibrated(scale: f64) -> RiskBound {
        let q = if scale.is_finite() { (scale / SCALE_QUANTUM).round() } else { 1.0 };
        RiskBound::Calibrated { scale_q: q.clamp(1.0, u16::MAX as f64) as u16 }
    }

    /// The conformal scale of a calibrated bound (`None` otherwise).
    pub fn scale(&self) -> Option<f64> {
        match self {
            RiskBound::Calibrated { scale_q } => Some(*scale_q as f64 * SCALE_QUANTUM),
            _ => None,
        }
    }

    /// Stable lowercase name (CLI / JSON encoding).
    pub fn name(&self) -> &'static str {
        match self {
            RiskBound::Ecr => "ecr",
            RiskBound::Gaussian => "gauss",
            RiskBound::Bernstein => "bernstein",
            RiskBound::Calibrated { .. } => "calibrated",
        }
    }

    /// Parse a CLI spelling.  `calibrated` starts at scale 1 (= ECR);
    /// `calibrated:0.8` seeds the conformal scale explicitly.
    pub fn parse(s: &str) -> Option<RiskBound> {
        match s {
            "ecr" | "cantelli" => Some(RiskBound::Ecr),
            "gauss" | "gaussian" | "normal" => Some(RiskBound::Gaussian),
            "bernstein" => Some(RiskBound::Bernstein),
            "calibrated" | "conformal" => Some(RiskBound::calibrated(1.0)),
            _ => {
                let scale = s.strip_prefix("calibrated:")?.parse::<f64>().ok()?;
                (scale.is_finite() && scale > 0.0).then_some(RiskBound::calibrated(scale))
            }
        }
    }

    /// Stable discriminant for fingerprint mixing (the engine also mixes
    /// the raw `scale_q`, so two calibrated bounds with different scales
    /// never alias in the plan cache).
    pub fn tag(&self) -> u8 {
        match self {
            RiskBound::Ecr => 0,
            RiskBound::Gaussian => 1,
            RiskBound::Bernstein => 2,
            RiskBound::Calibrated { .. } => 3,
        }
    }

    /// Raw quantized scale for fingerprinting (0 for scale-free bounds).
    pub fn scale_q(&self) -> u16 {
        match self {
            RiskBound::Calibrated { scale_q } => *scale_q,
            _ => 0,
        }
    }

    /// Coefficient k such that `margin = k·√(v_loc+v_vm)` — `Some` for
    /// the bounds that are a pure multiple of the total standard
    /// deviation (ECR / Gaussian / Calibrated), which lets the PCCP
    /// partitioning subproblem keep the paper's exact `k·√(xᵀWx)`
    /// variance coupling.  `None` for Bernstein, which enters the DC
    /// program as a linear per-point margin instead.
    pub fn std_factor(&self, eps: f64) -> Option<f64> {
        match self {
            RiskBound::Ecr => Some(ecr::sigma(eps)),
            RiskBound::Gaussian => Some(gauss::z(eps)),
            RiskBound::Calibrated { scale_q } => {
                // Same arithmetic as `scale()`, with the variant's own
                // payload so the arm is panic-free by construction.
                Some(*scale_q as f64 * SCALE_QUANTUM * ecr::sigma(eps))
            }
            RiskBound::Bernstein => None,
        }
    }

    /// Uncertainty margin at partition point `m` for risk level `eps` —
    /// the second term on the LHS of (22) under this transform.
    pub fn margin(&self, model: &ModelProfile, m: usize, eps: f64) -> f64 {
        let vl = model.v_loc(m);
        let vv = model.v_vm(m);
        match self {
            // Must stay bit-identical to the pre-refactor Policy::Robust
            // margin: same operand order, same intermediates.
            RiskBound::Ecr => ecr::sigma(eps) * (vl + vv).sqrt(),
            RiskBound::Gaussian => gauss::z(eps) * (vl + vv).sqrt(),
            RiskBound::Calibrated { scale_q } => {
                // `(scale_q·Q)·σ·√v` — identical association to the old
                // `scale()·σ·√v`, so margins stay bit-identical.
                *scale_q as f64 * SCALE_QUANTUM * ecr::sigma(eps) * (vl + vv).sqrt()
            }
            RiskBound::Bernstein => {
                let v = vl + vv;
                // Support of the deviation: the profiled worst-case
                // excursion per component (the same numbers the
                // worst-case baseline plans with).
                let support = model.worst_dev_factor * vl.sqrt() + 3.5 * vv.sqrt();
                // All three are valid margins under the bounded-support
                // assumption, so the minimum is too — and min(·, ECR)
                // guarantees Bernstein is never looser than the default.
                bernstein::margin(v, support, eps)
                    .min(ecr::sigma(eps) * v.sqrt())
                    .min(support)
            }
        }
    }
}

impl std::fmt::Display for RiskBound {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self.scale() {
            Some(s) => write!(f, "{}(x{s:.3})", self.name()),
            None => write!(f, "{}", self.name()),
        }
    }
}

/// All scale-free bounds plus the unit-scale calibrated bound, in CLI
/// order — the sweep the benches and figures iterate.
pub const BOUND_FAMILY: [RiskBound; 4] = [
    RiskBound::Ecr,
    RiskBound::Gaussian,
    RiskBound::Bernstein,
    RiskBound::Calibrated { scale_q: 1000 },
];

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibrated_scale_quantizes_and_roundtrips() {
        let b = RiskBound::calibrated(0.8004);
        assert_eq!(b, RiskBound::Calibrated { scale_q: 800 });
        assert!((b.scale().unwrap() - 0.8).abs() < 1e-12);
        // Sub-quantum moves compare equal; a full quantum does not.
        assert_eq!(RiskBound::calibrated(0.8001), RiskBound::calibrated(0.8004));
        assert_ne!(RiskBound::calibrated(0.800), RiskBound::calibrated(0.802));
        // Degenerate inputs clamp instead of panicking.
        assert_eq!(RiskBound::calibrated(0.0), RiskBound::Calibrated { scale_q: 1 });
        assert_eq!(RiskBound::calibrated(f64::NAN), RiskBound::Calibrated { scale_q: 1 });
    }

    #[test]
    fn parse_and_name_roundtrip() {
        for (s, name) in [
            ("ecr", "ecr"),
            ("cantelli", "ecr"),
            ("gauss", "gauss"),
            ("gaussian", "gauss"),
            ("bernstein", "bernstein"),
            ("calibrated", "calibrated"),
        ] {
            assert_eq!(RiskBound::parse(s).unwrap().name(), name);
        }
        assert_eq!(RiskBound::parse("calibrated:0.75"), Some(RiskBound::calibrated(0.75)));
        assert!(RiskBound::parse("bogus").is_none());
        assert!(RiskBound::parse("calibrated:-1").is_none());
    }

    #[test]
    fn unit_scale_calibrated_equals_ecr_margin_exactly() {
        let model = ModelProfile::alexnet_paper();
        let cal = RiskBound::calibrated(1.0);
        for m in 0..model.num_points() {
            for eps in [0.01, 0.05, 0.2] {
                // ×1.0 is exact in IEEE arithmetic.
                assert_eq!(
                    cal.margin(&model, m, eps).to_bits(),
                    RiskBound::Ecr.margin(&model, m, eps).to_bits()
                );
            }
        }
    }

    #[test]
    fn gaussian_and_bernstein_never_exceed_ecr() {
        for model in [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()] {
            for m in 0..model.num_points() {
                for eps in [0.01, 0.02, 0.05, 0.1, 0.2, 0.3] {
                    let e = RiskBound::Ecr.margin(&model, m, eps);
                    let g = RiskBound::Gaussian.margin(&model, m, eps);
                    let b = RiskBound::Bernstein.margin(&model, m, eps);
                    assert!(g <= e + 1e-15, "{} m={m} eps={eps}: gauss {g} > ecr {e}", model.name);
                    assert!(b <= e + 1e-15, "{} m={m} eps={eps}: bern {b} > ecr {e}", model.name);
                    assert!(g >= 0.0 && b >= 0.0);
                }
            }
        }
    }

    #[test]
    fn risk_validation_and_clamp() {
        assert!(validate_risk(0.05).is_ok());
        for bad in [0.0, 1.0, -0.1, f64::NAN, f64::INFINITY] {
            assert!(validate_risk(bad).is_err(), "{bad}");
        }
        assert_eq!(clamp_risk(0.05), 0.05);
        assert_eq!(clamp_risk(0.0), MIN_RISK);
        assert_eq!(clamp_risk(2.0), MAX_RISK);
        assert_eq!(clamp_risk(f64::NAN), MIN_RISK);
    }

    #[test]
    fn std_factor_matches_margin_for_variance_shaped_bounds() {
        let model = ModelProfile::resnet152_paper();
        let eps = 0.04;
        for bound in [RiskBound::Ecr, RiskBound::Gaussian, RiskBound::calibrated(0.6)] {
            let k = bound.std_factor(eps).unwrap();
            for m in 0..model.num_points() {
                let v = model.v_loc(m) + model.v_vm(m);
                let direct = bound.margin(&model, m, eps);
                assert!(
                    (direct - k * v.sqrt()).abs() <= 1e-12 * (1.0 + direct),
                    "m={m}: {direct} vs {}",
                    k * v.sqrt()
                );
            }
        }
        assert!(RiskBound::Bernstein.std_factor(eps).is_none());
    }
}
