//! Seeded, replayable fault injection for the fleet simulator.
//!
//! Three fault families, mirroring what real edge deployments survive:
//!
//! * **edge-server outages** — the whole edge is unreachable for a
//!   window; every device degrades to the engine's all-local fallback
//!   plan and re-offloads under exponential backoff when the window
//!   ends;
//! * **uplink blackouts** — one device's channel gain collapses far
//!   beyond ordinary shadow fading (tunnel, deep indoor) for a window;
//! * **delta-delivery faults** — renegotiation and bandwidth deltas in
//!   flight to the planner are delayed or dropped.
//!
//! Like [`crate::channel::GaussMarkov`], every draw comes from streams
//! forked off the fleet seed ([`FaultStreams::fork_off`]), so a fault
//! schedule is a pure function of the seed: same seed ⇒ byte-identical
//! fleet trace, at any thread or shard count.  The streams are forked
//! *after* every pre-existing stream of the fleet driver, so runs with
//! faults disabled consume nothing from them and stay byte-identical to
//! fault-free runs of earlier revisions.

use crate::util::rng::Rng;

/// Configuration of the fault schedule (all rates at churn 1; the fleet
/// driver does not scale them with churn — faults are exogenous).
#[derive(Clone, Debug)]
pub struct FaultOptions {
    /// Master switch; when `false` no fault stream is even forked.
    pub enabled: bool,
    /// Edge-server outage arrival rate, Hz (exponential inter-arrival,
    /// measured from the end of the previous outage — windows never
    /// overlap).
    pub outage_rate_hz: f64,
    /// Mean outage window length, seconds (exponential).
    pub outage_mean_s: f64,
    /// Per-fleet uplink-blackout arrival rate, Hz (each event picks one
    /// victim device).
    pub blackout_rate_hz: f64,
    /// Mean blackout window length, seconds (exponential).
    pub blackout_mean_s: f64,
    /// Gain collapse a blacked-out device suffers, dB (applied on top of
    /// its Gauss–Markov fading state).
    pub blackout_depth_db: f64,
    /// Probability a renegotiation/bandwidth delta is dropped in flight.
    pub drop_prob: f64,
    /// Probability a (non-dropped) delta is delayed in flight.
    pub delay_prob: f64,
    /// Mean in-flight delay, seconds (exponential).
    pub delay_mean_s: f64,
    /// Base re-offload backoff after an outage ends, seconds; attempt
    /// `k` waits `base · 2^k`, jittered by ±25 % from the backoff
    /// stream.
    pub backoff_base_s: f64,
}

impl Default for FaultOptions {
    fn default() -> Self {
        FaultOptions {
            enabled: false,
            outage_rate_hz: 0.05,
            outage_mean_s: 2.5,
            blackout_rate_hz: 0.08,
            blackout_mean_s: 1.5,
            blackout_depth_db: 25.0,
            drop_prob: 0.05,
            delay_prob: 0.10,
            delay_mean_s: 0.4,
            backoff_base_s: 0.25,
        }
    }
}

impl FaultOptions {
    /// Validate the schedule parameters (only consulted when `enabled`).
    pub fn validate(&self) -> Result<(), String> {
        for (name, v) in [
            ("outage-rate", self.outage_rate_hz),
            ("outage-mean", self.outage_mean_s),
            ("blackout-rate", self.blackout_rate_hz),
            ("blackout-mean", self.blackout_mean_s),
            ("blackout-depth", self.blackout_depth_db),
            ("delay-mean", self.delay_mean_s),
            ("backoff-base", self.backoff_base_s),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return Err(format!("--{name} must be finite and non-negative, got {v}"));
            }
        }
        for (name, p) in [("drop-prob", self.drop_prob), ("delay-prob", self.delay_prob)] {
            if !(p.is_finite() && (0.0..=1.0).contains(&p)) {
                return Err(format!("--{name} must be a probability in [0, 1], got {p}"));
            }
        }
        if self.drop_prob + self.delay_prob > 1.0 {
            return Err(format!(
                "drop-prob + delay-prob must not exceed 1, got {} + {}",
                self.drop_prob, self.delay_prob
            ));
        }
        if self.outage_mean_s <= 0.0 && self.outage_rate_hz > 0.0 {
            return Err("outage-mean must be positive when outages are on".into());
        }
        if self.blackout_mean_s <= 0.0 && self.blackout_rate_hz > 0.0 {
            return Err("blackout-mean must be positive when blackouts are on".into());
        }
        Ok(())
    }
}

/// Fate of one delta in flight to the planner.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Delivery {
    /// Delivered immediately (the overwhelmingly common case).
    OnTime,
    /// Delivered after the carried delay, seconds (quantized draw kept
    /// as `f64` simulation time — the event queue orders on it).
    Delayed(f64),
    /// Lost in flight; the planner never sees it.
    Dropped,
}

/// The four independent random streams the fault schedule draws from,
/// each forked off the fleet master seed in fixed order so a schedule
/// replays exactly.
#[derive(Debug)]
pub struct FaultStreams {
    outages: Rng,
    blackouts: Rng,
    delivery: Rng,
    backoff: Rng,
}

impl FaultStreams {
    /// Fork the four fault streams off `master` (fixed tag order — part
    /// of the determinism contract).
    pub fn fork_off(master: &mut Rng) -> FaultStreams {
        FaultStreams {
            outages: master.fork(0xFA01),
            blackouts: master.fork(0xFA02),
            delivery: master.fork(0xFA03),
            backoff: master.fork(0xFA04),
        }
    }

    /// Wait until the next edge outage begins, seconds.
    pub fn outage_wait_s(&mut self, opts: &FaultOptions) -> f64 {
        self.outages.exponential(opts.outage_rate_hz)
    }

    /// Length of an outage window, seconds.
    pub fn outage_len_s(&mut self, opts: &FaultOptions) -> f64 {
        self.outages.exponential(1.0 / opts.outage_mean_s)
    }

    /// Wait until the next uplink blackout begins, seconds.
    pub fn blackout_wait_s(&mut self, opts: &FaultOptions) -> f64 {
        self.blackouts.exponential(opts.blackout_rate_hz)
    }

    /// Length of a blackout window, seconds.
    pub fn blackout_len_s(&mut self, opts: &FaultOptions) -> f64 {
        self.blackouts.exponential(1.0 / opts.blackout_mean_s)
    }

    /// Pick a blackout victim among `n` devices (uniform).
    pub fn blackout_victim(&mut self, n: usize) -> usize {
        self.blackouts.below(n)
    }

    /// Fate of one in-flight delta.  One uniform draw decides drop vs
    /// delay vs on-time so the stream advances identically regardless of
    /// the outcome probabilities' order.
    pub fn delivery(&mut self, opts: &FaultOptions) -> Delivery {
        let u = self.delivery.f64();
        if u < opts.drop_prob {
            Delivery::Dropped
        } else if u < opts.drop_prob + opts.delay_prob {
            let d = self.delivery.exponential(1.0 / opts.delay_mean_s.max(1e-9));
            Delivery::Delayed(d)
        } else {
            Delivery::OnTime
        }
    }

    /// Jittered exponential backoff before re-offload attempt `attempt`
    /// (0-based): `base · 2^attempt · U[0.75, 1.25)`.  Deterministic per
    /// stream state; the jitter de-synchronizes devices so outage
    /// recovery never replans the whole fleet in one burst.
    pub fn backoff_s(&mut self, opts: &FaultOptions, attempt: u32) -> f64 {
        let base = opts.backoff_base_s.max(1e-6);
        base * f64::from(2u32.saturating_pow(attempt.min(16))) * self.backoff.range(0.75, 1.25)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn opts() -> FaultOptions {
        FaultOptions { enabled: true, ..FaultOptions::default() }
    }

    #[test]
    fn schedule_is_deterministic_per_seed() {
        let draw = |seed: u64| {
            let mut master = Rng::new(seed);
            let mut fs = FaultStreams::fork_off(&mut master);
            let o = opts();
            let mut out = Vec::new();
            for k in 0..50 {
                out.push(fs.outage_wait_s(&o).to_bits());
                out.push(fs.outage_len_s(&o).to_bits());
                out.push(fs.blackout_wait_s(&o).to_bits());
                out.push(fs.blackout_victim(7) as u64);
                out.push(fs.backoff_s(&o, k % 5).to_bits());
                out.push(match fs.delivery(&o) {
                    Delivery::OnTime => 0,
                    Delivery::Delayed(d) => d.to_bits(),
                    Delivery::Dropped => u64::MAX,
                });
            }
            out
        };
        assert_eq!(draw(7), draw(7), "same seed must replay the schedule exactly");
        assert_ne!(draw(7), draw(8), "different seeds must diverge");
    }

    #[test]
    fn streams_are_independent_of_draw_interleaving() {
        // Consuming only the delivery stream must not disturb the outage
        // stream: each family forks its own generator.
        let o = opts();
        let mut m1 = Rng::new(11);
        let mut a = FaultStreams::fork_off(&mut m1);
        let mut m2 = Rng::new(11);
        let mut b = FaultStreams::fork_off(&mut m2);
        for _ in 0..100 {
            let _ = b.delivery(&o);
        }
        assert_eq!(a.outage_wait_s(&o).to_bits(), b.outage_wait_s(&o).to_bits());
    }

    #[test]
    fn backoff_grows_exponentially_with_attempt() {
        let o = opts();
        let mut master = Rng::new(3);
        let mut fs = FaultStreams::fork_off(&mut master);
        // Jitter is ±25 %, growth is ×2 per attempt, so consecutive
        // attempts are strictly ordered despite the jitter.
        for k in 0..8u32 {
            let lo = fs.backoff_s(&o, k);
            let hi = fs.backoff_s(&o, k + 1);
            assert!(hi > lo, "attempt {k}: {hi} <= {lo}");
            assert!(lo >= o.backoff_base_s * f64::from(2u32.pow(k)) * 0.75);
            assert!(lo <= o.backoff_base_s * f64::from(2u32.pow(k)) * 1.25);
        }
    }

    #[test]
    fn delivery_outcomes_cover_all_variants_at_cranked_probs() {
        let o = FaultOptions { drop_prob: 0.3, delay_prob: 0.4, ..opts() };
        let mut master = Rng::new(5);
        let mut fs = FaultStreams::fork_off(&mut master);
        let (mut on, mut delayed, mut dropped) = (0, 0, 0);
        for _ in 0..2000 {
            match fs.delivery(&o) {
                Delivery::OnTime => on += 1,
                Delivery::Delayed(d) => {
                    assert!(d.is_finite() && d > 0.0);
                    delayed += 1;
                }
                Delivery::Dropped => dropped += 1,
            }
        }
        assert!(on > 0 && delayed > 0 && dropped > 0, "{on}/{delayed}/{dropped}");
        // Rough frequency sanity (±5 σ): the single-uniform split must
        // respect the configured probabilities.
        assert!((dropped as f64 / 2000.0 - 0.3).abs() < 0.06, "dropped={dropped}");
        assert!((delayed as f64 / 2000.0 - 0.4).abs() < 0.06, "delayed={delayed}");
    }

    #[test]
    fn validate_accepts_defaults_and_rejects_bad_values() {
        assert!(FaultOptions::default().validate().is_ok());
        assert!(opts().validate().is_ok());
        for bad in [
            FaultOptions { drop_prob: 1.5, ..opts() },
            FaultOptions { delay_prob: -0.1, ..opts() },
            FaultOptions { outage_rate_hz: f64::NAN, ..opts() },
            FaultOptions { outage_mean_s: 0.0, outage_rate_hz: 0.1, ..opts() },
            FaultOptions { blackout_depth_db: f64::INFINITY, ..opts() },
            FaultOptions { drop_prob: 0.6, delay_prob: 0.6, ..opts() },
        ] {
            assert!(bad.validate().is_err(), "{bad:?} should be rejected");
        }
    }
}
