//! Wireless uplink substrate (FDMA, §III + §VI-A of the paper).
//!
//! Path loss `h_n = 38 + 30 log10(r_n)` dB (3GPP TR 36.931 pico cell),
//! spectral efficiency `η = log2(1 + p h / (b N0))` — note the SNR depends
//! on the allocated bandwidth `b` because the fixed transmit power is
//! spread over the band, which is what makes `t_off = d / (b η(b))`
//! strictly convex in `b` (perspective of a concave rate function).

/// Physical-layer constants (paper §VI-A).
pub const TX_POWER_W: f64 = 1.0;
/// Noise PSD: −174 dBm/Hz in W/Hz.
pub fn noise_psd_w_per_hz() -> f64 {
    1e-3 * 10f64.powf(-174.0 / 10.0)
}

/// One device's uplink.
#[derive(Clone, Copy, Debug)]
pub struct Uplink {
    /// Transmit power, W.
    pub p_tx: f64,
    /// Linear channel gain (not dB).
    pub gain: f64,
    /// Noise PSD, W/Hz.
    pub n0: f64,
}

impl Uplink {
    /// Build from a device↔edge distance using the paper's path-loss model.
    pub fn from_distance(r_m: f64) -> Self {
        assert!(r_m > 0.0);
        let pl_db = 38.0 + 30.0 * r_m.log10();
        Uplink::from_gain_db(-pl_db)
    }

    /// Build from a channel gain on the dB scale (negative for path loss),
    /// with the paper's transmit power and noise floor.  This is the entry
    /// point fading processes use: they evolve the gain in dB and rebuild
    /// the uplink each step.
    pub fn from_gain_db(gain_db: f64) -> Self {
        Uplink { p_tx: TX_POWER_W, gain: 10f64.powf(gain_db / 10.0), n0: noise_psd_w_per_hz() }
    }

    /// Channel gain on the dB scale (the inverse of [`Uplink::from_gain_db`]).
    pub fn gain_db(&self) -> f64 {
        10.0 * self.gain.log10()
    }

    /// SNR at bandwidth b (Hz).
    pub fn snr(&self, b_hz: f64) -> f64 {
        self.p_tx * self.gain / (b_hz * self.n0)
    }

    /// Spectral efficiency η(b) = log2(1 + SNR), bits/s/Hz.
    pub fn spectral_efficiency(&self, b_hz: f64) -> f64 {
        (1.0 + self.snr(b_hz)).log2()
    }

    /// Uplink rate b·η(b), bits/s.
    pub fn rate_bps(&self, b_hz: f64) -> f64 {
        b_hz * self.spectral_efficiency(b_hz)
    }

    /// Offload time for `d_bits` at bandwidth b (eq. 3).
    ///
    /// `b_hz <= 0` encodes "no uplink in use" (the engine's all-local
    /// fallback plan when the edge server is unreachable): nothing is
    /// transmitted, so the offload time is 0 rather than the NaN the
    /// rate formula would produce at b = 0.
    pub fn t_off(&self, d_bits: f64, b_hz: f64) -> f64 {
        if d_bits == 0.0 || b_hz <= 0.0 {
            return 0.0;
        }
        d_bits / self.rate_bps(b_hz)
    }

    /// Offload energy p · t_off (eq. 4).
    pub fn e_off(&self, d_bits: f64, b_hz: f64) -> f64 {
        self.p_tx * self.t_off(d_bits, b_hz)
    }

    /// d/dB of t_off — used by the fast dual-bisection resource solver.
    /// t_off(b) = d / (b η(b));   d t_off/d b  < 0 (more bandwidth, faster).
    pub fn t_off_derivative(&self, d_bits: f64, b_hz: f64) -> f64 {
        // closed form: rate' = η(b) + b η'(b),
        // η'(b) = -snr / (b (1+snr) ln 2).
        let snr = self.snr(b_hz);
        let eta = (1.0 + snr).log2();
        let eta_p = -snr / (b_hz * (1.0 + snr) * std::f64::consts::LN_2);
        let rate = b_hz * eta;
        let rate_p = eta + b_hz * eta_p;
        -d_bits * rate_p / (rate * rate)
    }

    /// d²/dB² of t_off — strictly positive (t_off is convex in b).
    /// With c = p·gain/N0:  rate(b) = b·ln(1+c/b)/ln2,
    /// rate'' = −c² / (b (b+c)² ln2),  and
    /// t_off'' = d·(2·rate'² − rate·rate'') / rate³.
    /// The analytic form matters: a finite difference of `t_off_derivative`
    /// cancels catastrophically at small b and can go (wrongly) negative,
    /// which breaks the Newton Hessian's positive-definiteness.
    pub fn t_off_second_derivative(&self, d_bits: f64, b_hz: f64) -> f64 {
        let c = self.p_tx * self.gain / self.n0;
        let snr = c / b_hz;
        let ln2 = std::f64::consts::LN_2;
        let eta = (1.0 + snr).log2();
        let rate = b_hz * eta;
        let rate_p = eta - snr / ((1.0 + snr) * ln2);
        let rate_pp = -c * c / (b_hz * (b_hz + c) * (b_hz + c) * ln2);
        d_bits * (2.0 * rate_p * rate_p - rate * rate_pp) / (rate * rate * rate)
    }
}

/// First-order Gauss–Markov (AR(1)) shadowing process on the dB scale,
/// the standard temporally correlated fading model for mobile users:
///
/// ```text
///   g_{k+1} = μ + α (g_k − μ) + √(1 − α²) · σ · w_k ,   w_k ~ N(0, 1)
/// ```
///
/// where `μ` is the path-loss mean from the device's position, `σ` the
/// stationary shadowing standard deviation, and `α ∈ [0, 1)` the memory.
/// The innovation scaling keeps the *stationary* distribution at
/// N(μ, σ²) for any α, so the per-step move size and the long-run spread
/// can be chosen independently.  The process starts at its mean.
#[derive(Clone, Debug)]
pub struct GaussMarkov {
    /// Stationary mean gain, dB (the path-loss value).
    pub mean_db: f64,
    /// Stationary shadowing standard deviation, dB.
    pub sigma_db: f64,
    /// AR(1) memory coefficient in [0, 1).
    pub alpha: f64,
    state_db: f64,
}

impl GaussMarkov {
    /// Start the process at its stationary mean.
    pub fn new(mean_db: f64, sigma_db: f64, alpha: f64) -> GaussMarkov {
        assert!(sigma_db >= 0.0, "sigma_db must be non-negative");
        assert!((0.0..1.0).contains(&alpha), "alpha must be in [0, 1)");
        GaussMarkov { mean_db, sigma_db, alpha, state_db: mean_db }
    }

    /// Current gain, dB.
    pub fn gain_db(&self) -> f64 {
        self.state_db
    }

    /// Advance one step, drawing the innovation from `rng`; returns the
    /// new *linear* gain (what [`Uplink::from_gain_db`] consumes).
    pub fn step(&mut self, rng: &mut crate::util::rng::Rng) -> f64 {
        let innovation = (1.0 - self.alpha * self.alpha).sqrt() * self.sigma_db * rng.normal();
        self.state_db = self.mean_db + self.alpha * (self.state_db - self.mean_db) + innovation;
        10f64.powf(self.state_db / 10.0)
    }
}

/// Place N devices uniformly at random in the paper's 400 m × 400 m square
/// with the edge node at the center; returns device↔edge distances
/// (min-clamped to 1 m so path loss stays finite).
pub fn random_distances(n: usize, rng: &mut crate::util::rng::Rng) -> Vec<f64> {
    (0..n)
        .map(|_| {
            let x = rng.range(-200.0, 200.0);
            let y = rng.range(-200.0, 200.0);
            (x * x + y * y).sqrt().max(1.0)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;
    use crate::util::rng::Rng;

    #[test]
    fn path_loss_reference_value() {
        // r = 100 m: PL = 38 + 60 = 98 dB.
        let u = Uplink::from_distance(100.0);
        assert!((u.gain.log10() + 9.8).abs() < 1e-12);
    }

    #[test]
    fn rate_scale_sanity() {
        // 100 m, 1 MHz: SNR ≈ 4e4, η ≈ 15.3 b/s/Hz, rate ≈ 15 Mbps.
        let u = Uplink::from_distance(100.0);
        let rate = u.rate_bps(1e6);
        assert!(rate > 10e6 && rate < 20e6, "rate={rate}");
    }

    #[test]
    fn t_off_monotone_decreasing_in_bandwidth() {
        let u = Uplink::from_distance(150.0);
        let d = 0.18 * 8e6; // AlexNet point 2
        let mut last = f64::INFINITY;
        for b in [0.2e6, 0.5e6, 1e6, 2e6, 5e6, 10e6] {
            let t = u.t_off(d, b);
            assert!(t < last, "b={b} t={t}");
            last = t;
        }
    }

    #[test]
    fn t_off_convex_in_bandwidth() {
        forall("t_off convex in b", 200, |rng| {
            let u = Uplink::from_distance(rng.range(5.0, 280.0));
            let d = rng.range(1e3, 3e7);
            let b1 = rng.range(1e4, 2e7);
            let b2 = rng.range(1e4, 2e7);
            let lam = rng.f64();
            let mid = lam * b1 + (1.0 - lam) * b2;
            let lhs = u.t_off(d, mid);
            let rhs = lam * u.t_off(d, b1) + (1.0 - lam) * u.t_off(d, b2);
            if lhs <= rhs + 1e-9 * rhs.abs() + 1e-12 {
                Ok(())
            } else {
                Err(format!("convexity violated: {lhs} > {rhs}"))
            }
        });
    }

    #[test]
    fn derivative_matches_finite_difference() {
        forall("t_off derivative", 100, |rng| {
            let u = Uplink::from_distance(rng.range(10.0, 250.0));
            let d = rng.range(1e4, 1e7);
            let b = rng.range(1e5, 1e7);
            let h = b * 1e-6;
            let fd = (u.t_off(d, b + h) - u.t_off(d, b - h)) / (2.0 * h);
            crate::util::check::close(u.t_off_derivative(d, b), fd, 1e-4, 1e-12)
        });
    }

    #[test]
    fn second_derivative_matches_finite_difference() {
        forall("t_off second derivative", 100, |rng| {
            let u = Uplink::from_distance(rng.range(10.0, 250.0));
            let d = rng.range(1e4, 1e7);
            let b = rng.range(1e5, 1e7);
            let h = b * 1e-4;
            let fd = (u.t_off_derivative(d, b + h) - u.t_off_derivative(d, b - h)) / (2.0 * h);
            crate::util::check::close(u.t_off_second_derivative(d, b), fd, 1e-3, 1e-18)
        });
    }

    #[test]
    fn second_derivative_positive_even_at_tiny_bandwidth() {
        // The convexity must hold numerically down to the barrier's
        // b -> 0 region (this is where finite differences used to break).
        let u = Uplink::from_distance(150.0);
        for b in [1.0, 10.0, 1e3, 1e5, 1e7, 1e9] {
            assert!(u.t_off_second_derivative(4e6, b) > 0.0, "b={b}");
        }
    }

    #[test]
    fn energy_is_power_times_time() {
        let u = Uplink::from_distance(75.0);
        assert_eq!(u.e_off(1e6, 2e6), u.p_tx * u.t_off(1e6, 2e6));
    }

    #[test]
    fn zero_payload_is_free() {
        let u = Uplink::from_distance(75.0);
        assert_eq!(u.t_off(0.0, 1e6), 0.0);
        assert_eq!(u.e_off(0.0, 1e6), 0.0);
    }

    #[test]
    fn zero_bandwidth_encodes_no_uplink_use() {
        // The all-local fallback plan carries b = 0 with a non-zero
        // payload at the last partition point; t_off/e_off must be 0
        // (and in particular finite), not NaN via rate_bps(0).
        let u = Uplink::from_distance(75.0);
        assert_eq!(u.t_off(8e3, 0.0), 0.0);
        assert_eq!(u.e_off(8e3, 0.0), 0.0);
        assert_eq!(u.t_off(8e3, -1.0), 0.0);
    }

    #[test]
    fn gain_db_roundtrips_from_gain_db() {
        for db in [-120.0, -98.0, -60.0, 0.0, 3.0] {
            let u = Uplink::from_gain_db(db);
            assert!((u.gain_db() - db).abs() < 1e-9, "db={db}");
        }
        // from_distance agrees with the explicit dB constructor.
        let a = Uplink::from_distance(100.0);
        let b = Uplink::from_gain_db(-98.0);
        assert!((a.gain - b.gain).abs() / b.gain < 1e-12);
    }

    #[test]
    fn gauss_markov_is_stationary_with_target_moments() {
        let mut rng = Rng::new(31);
        let (mu, sigma, alpha) = (-95.0, 2.0, 0.9);
        let mut gm = GaussMarkov::new(mu, sigma, alpha);
        // Burn in past the deterministic start, then measure.
        for _ in 0..200 {
            gm.step(&mut rng);
        }
        let n = 200_000;
        let xs: Vec<f64> = (0..n)
            .map(|_| {
                gm.step(&mut rng);
                gm.gain_db()
            })
            .collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - mu).abs() < 0.05, "mean={mean}");
        assert!((var - sigma * sigma).abs() / (sigma * sigma) < 0.05, "var={var}");
    }

    #[test]
    fn gauss_markov_is_deterministic_per_seed_and_step_returns_linear_gain() {
        let mut a = GaussMarkov::new(-98.0, 2.0, 0.99);
        let mut b = GaussMarkov::new(-98.0, 2.0, 0.99);
        let (mut ra, mut rb) = (Rng::new(9), Rng::new(9));
        for _ in 0..50 {
            let ga = a.step(&mut ra);
            let gb = b.step(&mut rb);
            assert_eq!(ga.to_bits(), gb.to_bits());
            assert!((10.0 * ga.log10() - a.gain_db()).abs() < 1e-9);
        }
    }

    #[test]
    fn gauss_markov_high_alpha_moves_little_per_step() {
        // The fleet fingerprint buckets gains at 0.1 dB; with α = 0.992 and
        // σ = 2 dB the per-step move is ≈ 0.25 dB, so a fair share of steps
        // stay inside one bucket (those replans become plan-cache hits).
        let mut gm = GaussMarkov::new(-98.0, 2.0, 0.992);
        let mut rng = Rng::new(77);
        let mut within = 0usize;
        let steps = 2000;
        for _ in 0..steps {
            let before = gm.gain_db();
            gm.step(&mut rng);
            if ((gm.gain_db() / 0.1).round() - (before / 0.1).round()).abs() < 0.5 {
                within += 1;
            }
        }
        let frac = within as f64 / steps as f64;
        assert!(frac > 0.05 && frac < 0.9, "same-bucket fraction {frac}");
    }

    #[test]
    fn distances_within_square() {
        let mut rng = Rng::new(5);
        let ds = random_distances(1000, &mut rng);
        let max = 200.0f64 * std::f64::consts::SQRT_2;
        assert!(ds.iter().all(|&d| d >= 1.0 && d <= max + 1e-9));
    }
}
