//! DVFS energy model (§III-B of the paper).
//!
//! Dynamic CMOS power is `α c V² f` with `V ≈ k f` in the non-low
//! frequency range, giving power `κ f³` and local inference energy
//! `e^loc = κ f³ t^loc` (eq. 2) with κ the chip-dependent coefficient
//! measured via Tegrastats (0.8e-27 CPU / 2.8e-27 GPU, in W/(cycle/s)³ —
//! so `f` enters in cycles/s, i.e. GHz × 1e9).

/// Local compute power at frequency f (GHz): κ (f·1e9)³ watts.
pub fn local_power_w(kappa: f64, f_ghz: f64) -> f64 {
    let f_hz = f_ghz * 1e9;
    kappa * f_hz * f_hz * f_hz
}

/// Local inference energy κ f³ t (eq. 2).
pub fn e_loc(kappa: f64, f_ghz: f64, t_loc_s: f64) -> f64 {
    local_power_w(kappa, f_ghz) * t_loc_s
}

/// Expected local energy with the eq-10 mean time model: κ f³ · w/(g f)
/// = κ f² w/g — the f² form that appears in objectives (15)/(23a).
pub fn e_loc_mean(kappa: f64, f_ghz: f64, w_gflops: f64, g_flops_cycle: f64) -> f64 {
    if w_gflops == 0.0 {
        return 0.0;
    }
    let f_hz = f_ghz * 1e9;
    // t = w·1e9 / (g · f_hz); e = κ f³ t = κ f² · (w·1e9/g)
    kappa * f_hz * f_hz * (w_gflops * 1e9 / g_flops_cycle)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn jetson_cpu_power_scale() {
        // κ = 0.8e-27, f = 1.2 GHz -> κ f³ ≈ 1.38 W (realistic CPU power).
        let p = local_power_w(0.8e-27, 1.2);
        assert!((p - 1.3824).abs() < 1e-3, "p={p}");
    }

    #[test]
    fn jetson_gpu_power_scale() {
        // κ = 2.8e-27, f = 0.8 GHz -> ≈ 1.43 W.
        let p = local_power_w(2.8e-27, 0.8);
        assert!((p - 1.43360).abs() < 1e-3, "p={p}");
    }

    #[test]
    fn mean_energy_equals_power_times_mean_time() {
        forall("e_loc_mean = κf³ · w/(g f)", 200, |rng| {
            let kappa = rng.range(0.1e-27, 5e-27);
            let f = rng.range(0.1, 2.0);
            let w = rng.range(0.01, 30.0);
            let g = rng.range(1.0, 400.0);
            let t = w * 1e9 / (g * f * 1e9);
            crate::util::check::close(
                e_loc_mean(kappa, f, w, g),
                e_loc(kappa, f, t),
                1e-12,
                1e-18,
            )
        });
    }

    #[test]
    fn energy_monotone_in_frequency() {
        // e ∝ f²: raising f always costs energy (the deadline is why you
        // would).
        let mut last = 0.0;
        for i in 1..=12 {
            let f = 0.1 * i as f64;
            let e = e_loc_mean(0.8e-27, f, 1.4214, 7.1037);
            assert!(e > last);
            last = e;
        }
    }

    #[test]
    fn zero_workload_costs_nothing() {
        assert_eq!(e_loc_mean(0.8e-27, 1.0, 0.0, 0.0), 0.0);
    }
}
