//! Deterministic discrete-event substrate for the fleet simulator.
//!
//! A binary-heap future-event list ordered by `(time, insertion seq)` —
//! simultaneous events pop in insertion order regardless of heap
//! internals — plus the event vocabulary the driver consumes.  The queue
//! itself draws no randomness: all stochastic times are sampled by the
//! driver from forked [`crate::util::rng::Rng`] streams, so an event
//! trace is a pure function of the fleet seed at any thread count.

use std::cmp::Ordering;
use std::collections::BinaryHeap;

/// One thing that can happen to the fleet at a scheduled instant.
///
/// Each variant maps to one [`crate::engine::ScenarioDelta`] family in
/// the driver: `Arrival` → `Join`, `Departure` → `Leave`, `Fade` →
/// `Channel`, `Renegotiate` → `Deadline` or `Risk`, `Bandwidth` →
/// `TotalBandwidth` — together they exercise every delta variant.  The
/// fault vocabulary (`EdgeDown`/`EdgeUp`, `Blackout`/`BlackoutEnd`,
/// `Reoffload`, `Deliver`) is scheduled only when
/// [`crate::fault::FaultOptions::enabled`] is set.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum FleetEvent {
    /// A new device requests admission to the fleet.
    Arrival,
    /// The device with stable id `id` departs (skipped by the driver if
    /// it already left or was never admitted).
    Departure {
        /// Stable device id assigned at creation (scenario indices shift
        /// as devices leave; ids never do).
        id: u64,
    },
    /// Gauss–Markov fading tick for device `id`.
    Fade {
        /// Stable device id (same id space as `Departure`).
        id: u64,
    },
    /// Some device renegotiates its deadline or risk level.
    Renegotiate,
    /// The shared uplink budget changes.
    Bandwidth,
    /// The edge server becomes unreachable: the whole fleet degrades to
    /// the planner's all-local fallback until the matching [`EdgeUp`].
    ///
    /// [`EdgeUp`]: FleetEvent::EdgeUp
    EdgeDown,
    /// The edge server is reachable again; devices re-offload under
    /// jittered exponential backoff ([`Reoffload`]), not in one burst.
    ///
    /// [`Reoffload`]: FleetEvent::Reoffload
    EdgeUp,
    /// An uplink blackout begins on a victim device chosen from the
    /// blackout stream (gain collapse far beyond ordinary shadow
    /// fading).
    Blackout,
    /// The blackout on device `id` ends.
    BlackoutEnd {
        /// Stable device id (same id space as `Departure`).
        id: u64,
    },
    /// Post-outage re-offload attempt `attempt` (0-based) for device
    /// `id`, scheduled at a backoff-jittered time.
    Reoffload {
        /// Stable device id.
        id: u64,
        /// 0-based attempt counter; each retry doubles the backoff.
        attempt: u32,
    },
    /// A delayed delta arrives; `ticket` indexes the driver's pending
    /// in-flight list (kept driver-side so the event stays `Eq`).
    Deliver {
        /// Index into the driver's pending-delivery list.
        ticket: usize,
    },
}

impl FleetEvent {
    /// Stable lowercase tag for logs (`arrival`, `departure`, `fade`,
    /// `renegotiate`, `bandwidth`, `edge-down`, `edge-up`, `blackout`,
    /// `blackout-end`, `reoffload`, `deliver`).
    pub fn kind(&self) -> &'static str {
        match self {
            FleetEvent::Arrival => "arrival",
            FleetEvent::Departure { .. } => "departure",
            FleetEvent::Fade { .. } => "fade",
            FleetEvent::Renegotiate => "renegotiate",
            FleetEvent::Bandwidth => "bandwidth",
            FleetEvent::EdgeDown => "edge-down",
            FleetEvent::EdgeUp => "edge-up",
            FleetEvent::Blackout => "blackout",
            FleetEvent::BlackoutEnd { .. } => "blackout-end",
            FleetEvent::Reoffload { .. } => "reoffload",
            FleetEvent::Deliver { .. } => "deliver",
        }
    }
}

/// Heap entry; the manual `Ord` below inverts the comparison so the
/// *earliest* `(time, seq)` pops first from `std`'s max-heap.
#[derive(Clone, Debug)]
struct Scheduled {
    time_s: f64,
    seq: u64,
    event: FleetEvent,
}

impl PartialEq for Scheduled {
    fn eq(&self, other: &Self) -> bool {
        self.time_s.total_cmp(&other.time_s) == Ordering::Equal && self.seq == other.seq
    }
}

impl Eq for Scheduled {}

impl PartialOrd for Scheduled {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for Scheduled {
    fn cmp(&self, other: &Self) -> Ordering {
        other
            .time_s
            .total_cmp(&self.time_s)
            .then_with(|| other.seq.cmp(&self.seq))
    }
}

/// Deterministic future-event list (min-ordered by time, FIFO on ties).
#[derive(Debug, Default)]
pub struct EventQueue {
    heap: BinaryHeap<Scheduled>,
    seq: u64,
}

impl EventQueue {
    /// An empty queue.
    pub fn new() -> EventQueue {
        EventQueue::default()
    }

    /// Schedule `event` at absolute simulation time `time_s` (finite).
    pub fn push(&mut self, time_s: f64, event: FleetEvent) {
        debug_assert!(time_s.is_finite(), "event time must be finite, got {time_s}");
        let seq = self.seq;
        self.seq += 1;
        self.heap.push(Scheduled { time_s, seq, event });
    }

    /// Pop the earliest event; simultaneous events pop in the order they
    /// were pushed.
    pub fn pop(&mut self) -> Option<(f64, FleetEvent)> {
        self.heap.pop().map(|s| (s.time_s, s.event))
    }

    /// Number of scheduled events.
    pub fn len(&self) -> usize {
        self.heap.len()
    }

    /// Whether nothing is scheduled.
    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pops_in_time_order() {
        let mut q = EventQueue::new();
        q.push(3.0, FleetEvent::Arrival);
        q.push(1.0, FleetEvent::Bandwidth);
        q.push(2.0, FleetEvent::Renegotiate);
        let times: Vec<f64> = std::iter::from_fn(|| q.pop().map(|(t, _)| t)).collect();
        assert_eq!(times, vec![1.0, 2.0, 3.0]);
        assert!(q.is_empty());
    }

    #[test]
    fn ties_pop_in_insertion_order() {
        let mut q = EventQueue::new();
        q.push(1.0, FleetEvent::Fade { id: 0 });
        q.push(1.0, FleetEvent::Fade { id: 1 });
        q.push(1.0, FleetEvent::Fade { id: 2 });
        let ids: Vec<u64> = std::iter::from_fn(|| {
            q.pop().map(|(_, e)| match e {
                FleetEvent::Fade { id } => id,
                _ => unreachable!(),
            })
        })
        .collect();
        assert_eq!(ids, vec![0, 1, 2]);
    }

    #[test]
    fn interleaved_push_pop_keeps_order() {
        let mut q = EventQueue::new();
        q.push(5.0, FleetEvent::Arrival);
        q.push(1.0, FleetEvent::Arrival);
        assert_eq!(q.pop().unwrap().0, 1.0);
        q.push(2.0, FleetEvent::Bandwidth);
        q.push(0.5, FleetEvent::Renegotiate);
        assert_eq!(q.pop().unwrap().0, 0.5);
        assert_eq!(q.pop().unwrap().0, 2.0);
        assert_eq!(q.pop().unwrap().0, 5.0);
        assert_eq!(q.len(), 0);
    }

    #[test]
    fn kinds_are_stable() {
        assert_eq!(FleetEvent::Arrival.kind(), "arrival");
        assert_eq!(FleetEvent::Departure { id: 7 }.kind(), "departure");
        assert_eq!(FleetEvent::Fade { id: 7 }.kind(), "fade");
        assert_eq!(FleetEvent::Renegotiate.kind(), "renegotiate");
        assert_eq!(FleetEvent::Bandwidth.kind(), "bandwidth");
        assert_eq!(FleetEvent::EdgeDown.kind(), "edge-down");
        assert_eq!(FleetEvent::EdgeUp.kind(), "edge-up");
        assert_eq!(FleetEvent::Blackout.kind(), "blackout");
        assert_eq!(FleetEvent::BlackoutEnd { id: 7 }.kind(), "blackout-end");
        assert_eq!(FleetEvent::Reoffload { id: 7, attempt: 2 }.kind(), "reoffload");
        assert_eq!(FleetEvent::Deliver { ticket: 0 }.kind(), "deliver");
    }
}
