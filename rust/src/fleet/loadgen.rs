//! `ripra loadgen` — deterministic, seed-replayable wire traffic for the
//! TCP planner frontend ([`crate::service::server`]).
//!
//! The generator converts the fleet simulator's event vocabulary
//! (channel fades, QoS renegotiation, bandwidth changes, join/leave)
//! into a **script**: a fixed sequence of [`WireRequest`]s computed
//! entirely up front from the seed, with no dependence on the server,
//! the clock, or socket timing.  Same seed ⇒ the same script ⇒
//! byte-identical frames on the wire ([`encode_script`]) — and since the
//! server is deterministic for a single sequential connection, the same
//! response transcript too.  `rust/tests/serve.rs` pins both halves of
//! that contract, and EXPERIMENTS.md §Serving specifies it.
//!
//! [`run`] plays a script against a live server, pacing at a target
//! request rate and measuring *client-side* service latency per request
//! (the only wall-clock in this module — it feeds the report, never the
//! request stream).  [`LoadGenReport::write_bench_rows`] merges
//! `serve_p50_us` / `serve_p99_us` / `shed_rate` into BENCH_planner.json
//! alongside the in-process planner benches.

// lint:allow-file(wall-clock): client-side latency measurement only —
// the request stream is precomputed by `script` before any clock is
// read, so timing can never alter generated traffic or the transcript.

use std::io::Write as _;
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::channel::{GaussMarkov, Uplink};
use crate::engine::ScenarioDelta;
use crate::models::ModelProfile;
use crate::optim::types::{Device, Scenario};
use crate::risk::RiskBound;
use crate::service::wire::{self, WireRequest, WireResponse};
use crate::service::TenantId;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Stationary shadowing σ of the fading process, dB (matches the fleet
/// driver so loadgen channels look like simulator channels).
const SHADOW_SIGMA_DB: f64 = 2.0;

/// AR(1) memory of the fading process (matches the fleet driver).
const GM_ALPHA: f64 = 0.992;

/// Risk renegotiation multipliers (matches the fleet driver's steps).
const RISK_STEPS: [f64; 3] = [0.5, 1.0, 2.0];

/// Configuration for [`script`] / [`run`].
#[derive(Clone, Debug)]
pub struct LoadGenOptions {
    /// DNN/hardware profile every generated device runs.
    pub model: ModelProfile,
    /// Tenant fleets to admit (ids 1..=tenants).
    pub tenants: usize,
    /// Initial devices per tenant.
    pub devices: usize,
    /// Delta events to generate after admission.
    pub events: usize,
    /// Target request rate on the wire, requests/second (0 = unpaced).
    pub rate_hz: f64,
    /// Interleave a `plan` + `stats` probe after every this many deltas
    /// (0 disables probes; the final sweep still runs).
    pub probe_every: usize,
    /// Per-tenant total uplink budget, Hz.
    pub total_bandwidth_hz: f64,
    /// Base per-task deadline, seconds (renegotiations scale it).
    pub deadline_s: f64,
    /// Base tolerated violation probability.
    pub risk: f64,
    /// Risk bound every tenant admits under.
    pub bound: RiskBound,
    /// Master seed: the *entire* request stream is a function of it.
    pub seed: u64,
}

impl Default for LoadGenOptions {
    fn default() -> Self {
        LoadGenOptions {
            model: ModelProfile::alexnet_paper(),
            tenants: 2,
            devices: 4,
            events: 64,
            rate_hz: 200.0,
            probe_every: 8,
            total_bandwidth_hz: 12e6,
            deadline_s: 0.25,
            risk: 0.05,
            bound: RiskBound::Ecr,
            seed: 7,
        }
    }
}

/// Mutable per-tenant view the generator tracks while scripting (the
/// same state the server will reconstruct from the deltas).
struct TenantSim {
    id: TenantId,
    /// One fading process per live device, tenant device order.
    gms: Vec<GaussMarkov>,
}

/// Place one device like the fleet driver does: uniform in the 400 m
/// square, path-loss mean gain, fading started at the mean.
fn place_device(
    opts: &LoadGenOptions,
    placement: &mut Rng,
) -> (GaussMarkov, Device) {
    let x = placement.range(-200.0, 200.0);
    let y = placement.range(-200.0, 200.0);
    let r = (x * x + y * y).sqrt().max(1.0);
    let mean_db = -(38.0 + 30.0 * r.log10());
    let gm = GaussMarkov::new(mean_db, SHADOW_SIGMA_DB, GM_ALPHA);
    let dev = Device {
        model: opts.model.clone(),
        uplink: Uplink::from_gain_db(gm.gain_db()),
        deadline_s: opts.deadline_s,
        risk: opts.risk,
    };
    (gm, dev)
}

/// Build the deterministic request script: admissions, a seeded mix of
/// deltas (25 % deadline, 25 % risk, 30 % channel fade, 10 % bandwidth,
/// 5 % join, 5 % leave), periodic `plan`/`stats` probes, and a final
/// per-tenant plan sweep ending in `shutdown`.
///
/// Three RNG streams fork off the master seed — placement, channel
/// innovations, event mix — so, e.g., adding a tenant shifts placements
/// without rewriting the whole event sequence.
pub fn script(opts: &LoadGenOptions) -> Vec<WireRequest> {
    let mut master = Rng::new(opts.seed);
    let mut placement = master.fork(0x1D01);
    let mut channels = master.fork(0x1D02);
    let mut events = master.fork(0x1D03);

    let tenants = opts.tenants.max(1);
    let n0 = opts.devices.max(1);
    let mut reqs = Vec::new();
    let mut sims: Vec<TenantSim> = Vec::new();
    for t in 1..=tenants as TenantId {
        let mut gms = Vec::with_capacity(n0);
        let mut devices = Vec::with_capacity(n0);
        for _ in 0..n0 {
            let (gm, dev) = place_device(opts, &mut placement);
            gms.push(gm);
            devices.push(dev);
        }
        reqs.push(WireRequest::Admit {
            tenant: t,
            scenario: Scenario { devices, total_bandwidth_hz: opts.total_bandwidth_hz },
            bound: opts.bound,
        });
        sims.push(TenantSim { id: t, gms });
    }

    for e in 0..opts.events {
        let s = events.below(sims.len());
        let tenant = sims[s].id;
        let n = sims[s].gms.len();
        let u = events.f64();
        let delta = if u < 0.25 {
            let device = events.below(n);
            let deadline_s = opts.deadline_s * events.range(0.85, 1.4);
            ScenarioDelta::Deadline { device: Some(device), deadline_s }
        } else if u < 0.50 {
            let device = events.below(n);
            let step = RISK_STEPS[events.below(RISK_STEPS.len())];
            ScenarioDelta::Risk { device: Some(device), risk: (opts.risk * step).clamp(1e-3, 0.5) }
        } else if u < 0.80 || (u >= 0.95 && n <= 1) {
            // Channel fade (also the fallback when a leave would empty
            // the fleet — the service rejects removing the last device).
            let device = events.below(n);
            sims[s].gms[device].step(&mut channels);
            ScenarioDelta::Channel {
                device,
                uplink: Uplink::from_gain_db(sims[s].gms[device].gain_db()),
            }
        } else if u < 0.90 {
            ScenarioDelta::TotalBandwidth(opts.total_bandwidth_hz * events.range(0.8, 1.25))
        } else if u < 0.95 {
            let (gm, dev) = place_device(opts, &mut placement);
            sims[s].gms.push(gm);
            ScenarioDelta::Join(dev)
        } else {
            let device = events.below(n);
            sims[s].gms.remove(device);
            ScenarioDelta::Leave(device)
        };
        reqs.push(WireRequest::Delta { tenant, delta });
        if opts.probe_every > 0 && (e + 1) % opts.probe_every == 0 {
            reqs.push(WireRequest::Plan { tenant });
            reqs.push(WireRequest::Stats);
        }
    }

    for sim in &sims {
        reqs.push(WireRequest::Plan { tenant: sim.id });
    }
    reqs.push(WireRequest::Stats);
    reqs.push(WireRequest::Shutdown);
    reqs
}

/// Encode a script as the exact bytes it puts on the wire: concatenated
/// length-prefixed frames.  Two equal-seed scripts encode to identical
/// byte strings — the replay artifact the determinism pin compares.
pub fn encode_script(reqs: &[WireRequest]) -> Vec<u8> {
    let mut out = Vec::new();
    for r in reqs {
        out.extend_from_slice(&wire::encode_frame(r.to_json().to_string_compact().as_bytes()));
    }
    out
}

/// What one [`run`] measured.
#[derive(Clone, Debug)]
pub struct LoadGenReport {
    /// Requests sent (== responses received).
    pub requests: usize,
    /// Responses that were `shed`.
    pub sheds: usize,
    /// Responses that were `error`.
    pub errors: usize,
    /// Median client-observed service latency, µs.
    pub p50_us: f64,
    /// 99th-percentile client-observed service latency, µs.
    pub p99_us: f64,
    /// Mean client-observed service latency, µs.
    pub mean_us: f64,
    /// `sheds / requests` (0 when nothing was sent).
    pub shed_rate: f64,
    /// Compact JSON of every response, arrival order — the transcript
    /// two equal-seed runs must reproduce verbatim.
    pub transcript: Vec<String>,
}

impl LoadGenReport {
    /// Human-readable summary (what `ripra loadgen` prints).
    pub fn summary(&self) -> String {
        format!(
            "loadgen: {} requests, {} shed ({:.3} rate), {} errors; \
             latency p50 {:.1} us, p99 {:.1} us, mean {:.1} us",
            self.requests, self.sheds, self.shed_rate, self.errors, self.p50_us, self.p99_us,
            self.mean_us
        )
    }

    /// Machine-readable report (the `--json` payload; the transcript is
    /// included so replay checks can diff runs without a bench file).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("requests".into(), Json::Num(self.requests as f64)),
            ("sheds".into(), Json::Num(self.sheds as f64)),
            ("errors".into(), Json::Num(self.errors as f64)),
            ("serve_p50_us".into(), Json::Num(self.p50_us)),
            ("serve_p99_us".into(), Json::Num(self.p99_us)),
            ("serve_mean_us".into(), Json::Num(self.mean_us)),
            ("shed_rate".into(), Json::Num(self.shed_rate)),
            (
                "transcript".into(),
                Json::Arr(self.transcript.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
        ])
    }

    /// Merge the serve rows into a BENCH_planner.json-style file under
    /// `benches.serve_wire`, preserving sibling keys — the same
    /// read-merge-write contract as
    /// [`crate::util::bench::Bencher::write_json`] (an existing file
    /// that fails to parse is an error, never silently replaced).
    pub fn write_bench_rows(&self, path: &Path) -> Result<(), String> {
        let mut root: Vec<(String, Json)> = match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(format!("read {}: {e}", path.display())),
            Ok(text) => {
                let parsed = Json::parse(&text).map_err(|e| {
                    format!(
                        "refusing to overwrite {}: existing file is not valid JSON ({e})",
                        path.display()
                    )
                })?;
                parsed
                    .as_obj()
                    .map(|o| o.to_vec())
                    .ok_or_else(|| {
                        format!(
                            "refusing to overwrite {}: existing JSON root is not an object",
                            path.display()
                        )
                    })?
            }
        };
        let mut entries: Vec<(String, Json)> = match root.iter().find(|(k, _)| k == "benches") {
            None => Vec::new(),
            Some((_, b)) => b.as_obj().map(|o| o.to_vec()).ok_or_else(|| {
                format!(
                    "refusing to overwrite {}: existing \"benches\" value is not an object",
                    path.display()
                )
            })?,
        };
        let row = Json::Obj(vec![
            ("serve_p50_us".into(), Json::Num(self.p50_us)),
            ("serve_p99_us".into(), Json::Num(self.p99_us)),
            ("serve_mean_us".into(), Json::Num(self.mean_us)),
            ("shed_rate".into(), Json::Num(self.shed_rate)),
            ("requests".into(), Json::Num(self.requests as f64)),
            ("sheds".into(), Json::Num(self.sheds as f64)),
            ("errors".into(), Json::Num(self.errors as f64)),
        ]);
        match entries.iter_mut().find(|(n, _)| n == "serve_wire") {
            Some(e) => e.1 = row,
            None => entries.push(("serve_wire".into(), row)),
        }
        let benches = Json::Obj(entries);
        match root.iter_mut().find(|(k, _)| k == "benches") {
            Some(e) => e.1 = benches,
            None => root.push(("benches".into(), benches)),
        }
        std::fs::write(path, Json::Obj(root).to_string_pretty())
            .map_err(|e| format!("write {}: {e}", path.display()))
    }
}

/// Nearest-rank percentile over an unsorted latency sample (same index
/// rule as the bench harness: `round((n-1)·q)` into the sorted sample).
fn percentile_us(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let idx = (((sorted.len() - 1) as f64) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// Play a prebuilt script against a live server and measure it.
///
/// One sequential connection: send a frame, block for the response,
/// record the elapsed service latency, then sleep out the rest of the
/// pacing interval (`1 / rate_hz`).  Pacing changes *when* requests are
/// sent, never *what* is sent — the transcript stays a pure function of
/// the script.
pub fn run_script(addr: &str, reqs: &[WireRequest], rate_hz: f64) -> Result<LoadGenReport, String> {
    let mut stream =
        TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
    let pace = if rate_hz > 0.0 { Some(Duration::from_secs_f64(1.0 / rate_hz)) } else { None };

    let mut latencies_us = Vec::with_capacity(reqs.len());
    let mut transcript = Vec::with_capacity(reqs.len());
    let (mut sheds, mut errors) = (0usize, 0usize);
    for req in reqs {
        let body = req.to_json().to_string_compact();
        let sent = Instant::now();
        wire::write_frame(&mut stream, body.as_bytes()).map_err(|e| format!("send: {e}"))?;
        let resp = match wire::read_json(&mut stream).map_err(|e| format!("recv: {e}"))? {
            Some(j) => j,
            None => return Err("server closed mid-script".into()),
        };
        let elapsed = sent.elapsed();
        latencies_us.push(elapsed.as_secs_f64() * 1e6);
        match WireResponse::from_json(&resp) {
            Ok(WireResponse::Shed { .. }) => sheds += 1,
            Ok(WireResponse::Error { .. }) => errors += 1,
            Ok(_) => {}
            Err(e) => return Err(format!("undecodable response: {e}")),
        }
        transcript.push(resp.to_string_compact());
        if let Some(p) = pace {
            if elapsed < p {
                std::thread::sleep(p - elapsed);
            }
        }
    }
    let _ = stream.flush();

    let requests = latencies_us.len();
    let mean_us = if requests == 0 {
        0.0
    } else {
        latencies_us.iter().sum::<f64>() / requests as f64
    };
    Ok(LoadGenReport {
        requests,
        sheds,
        errors,
        p50_us: percentile_us(&latencies_us, 0.50),
        p99_us: percentile_us(&latencies_us, 0.99),
        mean_us,
        shed_rate: if requests == 0 { 0.0 } else { sheds as f64 / requests as f64 },
        transcript,
    })
}

/// Build the script from `opts` and play it ([`script`] +
/// [`run_script`]).
pub fn run(addr: &str, opts: &LoadGenOptions) -> Result<LoadGenReport, String> {
    run_script(addr, &script(opts), opts.rate_hz)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_scripts_encode_identically() {
        let opts = LoadGenOptions { events: 40, ..LoadGenOptions::default() };
        let a = encode_script(&script(&opts));
        let b = encode_script(&script(&opts));
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed must produce byte-identical wire streams");
        let other = LoadGenOptions { seed: 8, ..opts };
        assert_ne!(a, encode_script(&script(&other)), "a different seed must change the stream");
    }

    #[test]
    fn script_shape_admissions_probes_and_shutdown() {
        let opts =
            LoadGenOptions { tenants: 3, events: 16, probe_every: 4, ..LoadGenOptions::default() };
        let reqs = script(&opts);
        let kinds: Vec<&str> = reqs.iter().map(|r| r.kind()).collect();
        assert_eq!(&kinds[..3], &["admit", "admit", "admit"]);
        assert_eq!(kinds.last().copied(), Some("shutdown"));
        assert_eq!(kinds.iter().filter(|k| **k == "admit").count(), 3);
        // 16 deltas probed every 4 → 4 probe pairs; final sweep adds 3
        // plans and 1 stats.
        assert_eq!(kinds.iter().filter(|k| **k == "delta").count(), 16);
        assert_eq!(kinds.iter().filter(|k| **k == "plan").count(), 4 + 3);
        assert_eq!(kinds.iter().filter(|k| **k == "stats").count(), 4 + 1);
    }

    #[test]
    fn leave_never_empties_a_fleet() {
        // With 1 initial device per tenant every would-be leave must be
        // rewritten into a channel fade; decode-level invariant: no
        // Leave targets a sole surviving device.
        let opts = LoadGenOptions {
            tenants: 1,
            devices: 1,
            events: 200,
            probe_every: 0,
            ..LoadGenOptions::default()
        };
        let mut live = 1i64;
        for r in script(&opts) {
            if let WireRequest::Delta { delta, .. } = r {
                match delta {
                    ScenarioDelta::Join(_) => live += 1,
                    ScenarioDelta::Leave(_) => {
                        assert!(live > 1, "leave generated against a sole device");
                        live -= 1;
                    }
                    _ => {}
                }
            }
        }
        assert!(live >= 1);
    }

    #[test]
    fn percentile_index_rule() {
        let xs = vec![5.0, 1.0, 4.0, 2.0, 3.0];
        // lint:allow(float-eq): exact values by construction
        assert_eq!(percentile_us(&xs, 0.5), 3.0);
        // lint:allow(float-eq): exact values by construction
        assert_eq!(percentile_us(&xs, 1.0), 5.0);
        // lint:allow(float-eq): exact values by construction
        assert_eq!(percentile_us(&[], 0.5), 0.0);
    }
}
