//! `ripra loadgen` — deterministic, seed-replayable wire traffic for the
//! TCP planner frontend ([`crate::service::server`]).
//!
//! The generator converts the fleet simulator's event vocabulary
//! (channel fades, QoS renegotiation, bandwidth changes, join/leave)
//! into a **script**: a fixed sequence of [`WireRequest`]s computed
//! entirely up front from the seed, with no dependence on the server,
//! the clock, or socket timing.  Same seed ⇒ the same script ⇒
//! byte-identical frames on the wire ([`encode_script`]) — and since the
//! server is deterministic for a single sequential connection, the same
//! response transcript too.  `rust/tests/serve.rs` pins both halves of
//! that contract, and EXPERIMENTS.md §Serving specifies it.
//!
//! [`run`] plays a script against a live server, pacing at a target
//! request rate and measuring *client-side* service latency per request
//! (the only wall-clock in this module — it feeds the report, never the
//! request stream).  [`LoadGenReport::write_bench_rows`] merges
//! `serve_p50_us` / `serve_p99_us` / `shed_rate` into BENCH_planner.json
//! alongside the in-process planner benches.
//!
//! **Throughput mode** (`--connections C`, C > 1) measures the sharded
//! server's aggregate event rate.  The canonical script is *partitioned
//! by tenant* across C sockets ([`split_script`]) — each connection
//! carries a deterministic, connection-disjoint sub-script, so no new
//! RNG streams are forked and the per-connection byte streams stay pure
//! functions of the seed.  Consecutive requests on each connection are
//! coalesced into [`WireRequest::Batch`] frames ([`batch_script`]) to
//! amortize framing.  The run first plays an unbatched single-connection
//! baseline against the same server (disjoint tenant ids), then the
//! concurrent batched phase, and reports both rates side by side:
//! `serve_single_epm`, `serve_throughput_epm`, and their ratio
//! `serve_speedup` land in BENCH_planner.json together.

// lint:allow-file(wall-clock): client-side latency measurement only —
// the request stream is precomputed by `script` before any clock is
// read, so timing can never alter generated traffic or the transcript.

use std::io::Write as _;
use std::net::TcpStream;
use std::path::Path;
use std::time::{Duration, Instant};

use crate::channel::{GaussMarkov, Uplink};
use crate::engine::ScenarioDelta;
use crate::models::ModelProfile;
use crate::optim::types::{Device, Scenario};
use crate::risk::RiskBound;
use crate::service::wire::{self, WireRequest, WireResponse};
use crate::service::TenantId;
use crate::util::json::Json;
use crate::util::rng::Rng;

/// Stationary shadowing σ of the fading process, dB (matches the fleet
/// driver so loadgen channels look like simulator channels).
const SHADOW_SIGMA_DB: f64 = 2.0;

/// AR(1) memory of the fading process (matches the fleet driver).
const GM_ALPHA: f64 = 0.992;

/// Risk renegotiation multipliers (matches the fleet driver's steps).
const RISK_STEPS: [f64; 3] = [0.5, 1.0, 2.0];

/// Configuration for [`script`] / [`run`].
#[derive(Clone, Debug)]
pub struct LoadGenOptions {
    /// DNN/hardware profile every generated device runs.
    pub model: ModelProfile,
    /// Tenant fleets to admit (ids 1..=tenants).
    pub tenants: usize,
    /// Initial devices per tenant.
    pub devices: usize,
    /// Delta events to generate after admission.
    pub events: usize,
    /// Target request rate on the wire, requests/second (0 = unpaced).
    pub rate_hz: f64,
    /// Interleave a `plan` + `stats` probe after every this many deltas
    /// (0 disables probes; the final sweep still runs).
    pub probe_every: usize,
    /// Per-tenant total uplink budget, Hz.
    pub total_bandwidth_hz: f64,
    /// Base per-task deadline, seconds (renegotiations scale it).
    pub deadline_s: f64,
    /// Base tolerated violation probability.
    pub risk: f64,
    /// Risk bound every tenant admits under.
    pub bound: RiskBound,
    /// Master seed: the *entire* request stream is a function of it.
    pub seed: u64,
    /// Concurrent connections to stripe the script over (1 = the classic
    /// sequential replay; >1 enables throughput mode with a baseline
    /// comparison phase).
    pub connections: usize,
    /// Coalesce up to this many consecutive requests per frame as a
    /// [`WireRequest::Batch`] (0 or 1 = unbatched; throughput mode
    /// defaults 0 to 16).
    pub batch: usize,
    /// First tenant id to admit (ids `first_tenant..first_tenant+tenants`).
    /// The default 1 reproduces the historical `1..=tenants` ids byte for
    /// byte; throughput mode offsets it so the baseline and concurrent
    /// phases admit disjoint tenants on one server.
    pub first_tenant: TenantId,
}

impl Default for LoadGenOptions {
    fn default() -> Self {
        LoadGenOptions {
            model: ModelProfile::alexnet_paper(),
            tenants: 2,
            devices: 4,
            events: 64,
            rate_hz: 200.0,
            probe_every: 8,
            total_bandwidth_hz: 12e6,
            deadline_s: 0.25,
            risk: 0.05,
            bound: RiskBound::Ecr,
            seed: 7,
            connections: 1,
            batch: 0,
            first_tenant: 1,
        }
    }
}

/// Mutable per-tenant view the generator tracks while scripting (the
/// same state the server will reconstruct from the deltas).
struct TenantSim {
    id: TenantId,
    /// One fading process per live device, tenant device order.
    gms: Vec<GaussMarkov>,
}

/// Place one device like the fleet driver does: uniform in the 400 m
/// square, path-loss mean gain, fading started at the mean.
fn place_device(
    opts: &LoadGenOptions,
    placement: &mut Rng,
) -> (GaussMarkov, Device) {
    let x = placement.range(-200.0, 200.0);
    let y = placement.range(-200.0, 200.0);
    let r = (x * x + y * y).sqrt().max(1.0);
    let mean_db = -(38.0 + 30.0 * r.log10());
    let gm = GaussMarkov::new(mean_db, SHADOW_SIGMA_DB, GM_ALPHA);
    let dev = Device {
        model: opts.model.clone(),
        uplink: Uplink::from_gain_db(gm.gain_db()),
        deadline_s: opts.deadline_s,
        risk: opts.risk,
    };
    (gm, dev)
}

/// Build the deterministic request script: admissions, a seeded mix of
/// deltas (25 % deadline, 25 % risk, 30 % channel fade, 10 % bandwidth,
/// 5 % join, 5 % leave), periodic `plan`/`stats` probes, and a final
/// per-tenant plan sweep ending in `shutdown`.
///
/// Three RNG streams fork off the master seed — placement, channel
/// innovations, event mix — so, e.g., adding a tenant shifts placements
/// without rewriting the whole event sequence.
pub fn script(opts: &LoadGenOptions) -> Vec<WireRequest> {
    let mut master = Rng::new(opts.seed);
    let mut placement = master.fork(0x1D01);
    let mut channels = master.fork(0x1D02);
    let mut events = master.fork(0x1D03);

    let tenants = opts.tenants.max(1);
    let n0 = opts.devices.max(1);
    let mut reqs = Vec::new();
    let mut sims: Vec<TenantSim> = Vec::new();
    for k in 0..tenants as TenantId {
        let t = opts.first_tenant + k;
        let mut gms = Vec::with_capacity(n0);
        let mut devices = Vec::with_capacity(n0);
        for _ in 0..n0 {
            let (gm, dev) = place_device(opts, &mut placement);
            gms.push(gm);
            devices.push(dev);
        }
        reqs.push(WireRequest::Admit {
            tenant: t,
            scenario: Scenario { devices, total_bandwidth_hz: opts.total_bandwidth_hz },
            bound: opts.bound,
        });
        sims.push(TenantSim { id: t, gms });
    }

    for e in 0..opts.events {
        let s = events.below(sims.len());
        let tenant = sims[s].id;
        let n = sims[s].gms.len();
        let u = events.f64();
        let delta = if u < 0.25 {
            let device = events.below(n);
            let deadline_s = opts.deadline_s * events.range(0.85, 1.4);
            ScenarioDelta::Deadline { device: Some(device), deadline_s }
        } else if u < 0.50 {
            let device = events.below(n);
            let step = RISK_STEPS[events.below(RISK_STEPS.len())];
            ScenarioDelta::Risk { device: Some(device), risk: (opts.risk * step).clamp(1e-3, 0.5) }
        } else if u < 0.80 || (u >= 0.95 && n <= 1) {
            // Channel fade (also the fallback when a leave would empty
            // the fleet — the service rejects removing the last device).
            let device = events.below(n);
            sims[s].gms[device].step(&mut channels);
            ScenarioDelta::Channel {
                device,
                uplink: Uplink::from_gain_db(sims[s].gms[device].gain_db()),
            }
        } else if u < 0.90 {
            ScenarioDelta::TotalBandwidth(opts.total_bandwidth_hz * events.range(0.8, 1.25))
        } else if u < 0.95 {
            let (gm, dev) = place_device(opts, &mut placement);
            sims[s].gms.push(gm);
            ScenarioDelta::Join(dev)
        } else {
            let device = events.below(n);
            sims[s].gms.remove(device);
            ScenarioDelta::Leave(device)
        };
        reqs.push(WireRequest::Delta { tenant, delta });
        if opts.probe_every > 0 && (e + 1) % opts.probe_every == 0 {
            reqs.push(WireRequest::Plan { tenant });
            reqs.push(WireRequest::Stats);
        }
    }

    for sim in &sims {
        reqs.push(WireRequest::Plan { tenant: sim.id });
    }
    reqs.push(WireRequest::Stats);
    reqs.push(WireRequest::Shutdown);
    reqs
}

/// Encode a script as the exact bytes it puts on the wire: concatenated
/// length-prefixed frames.  Two equal-seed scripts encode to identical
/// byte strings — the replay artifact the determinism pin compares.
pub fn encode_script(reqs: &[WireRequest]) -> Vec<u8> {
    let mut out = Vec::new();
    for r in reqs {
        out.extend_from_slice(&wire::encode_frame(r.to_json().to_string_compact().as_bytes()));
    }
    out
}

/// Partition a script over `connections` sockets **by tenant** (tenant
/// id modulo connection count — the same striping the server's submit
/// shards use).  Each tenant's admission, deltas, and plan probes stay
/// on one connection in script order, so per-tenant causality (admit
/// before delta before plan) is preserved by socket FIFO alone.
/// Tenant-less `stats` probes ride connection 0; `shutdown` is stripped
/// entirely — the concurrent runner sends it on a dedicated closer
/// connection after every worker has drained.
pub fn split_script(reqs: &[WireRequest], connections: usize) -> Vec<Vec<WireRequest>> {
    let c = connections.max(1);
    let mut out: Vec<Vec<WireRequest>> = vec![Vec::new(); c];
    for r in reqs {
        match r {
            WireRequest::Shutdown => {}
            WireRequest::Admit { tenant, .. }
            | WireRequest::Delta { tenant, .. }
            | WireRequest::Plan { tenant } => {
                out[(*tenant as usize) % c].push(r.clone());
            }
            // stats and anything already batched have no owning tenant
            _ => out[0].push(r.clone()),
        }
    }
    out
}

/// Coalesce consecutive requests into [`WireRequest::Batch`] frames of
/// at most `batch` inner requests (0 or 1 leaves the script unbatched).
/// Order is preserved exactly — the server executes a batch as the same
/// sequential singles — so batching changes framing, never semantics.
pub fn batch_script(reqs: &[WireRequest], batch: usize) -> Vec<WireRequest> {
    if batch <= 1 {
        return reqs.to_vec();
    }
    reqs.chunks(batch)
        .map(|chunk| {
            if chunk.len() == 1 {
                chunk[0].clone()
            } else {
                WireRequest::Batch(chunk.to_vec())
            }
        })
        .collect()
}

/// What one [`run`] measured.
#[derive(Clone, Debug)]
pub struct LoadGenReport {
    /// Requests sent (== responses received).
    pub requests: usize,
    /// Responses that were `shed`.
    pub sheds: usize,
    /// Responses that were `error`.
    pub errors: usize,
    /// Median client-observed service latency, µs.
    pub p50_us: f64,
    /// 99th-percentile client-observed service latency, µs.
    pub p99_us: f64,
    /// Mean client-observed service latency, µs.
    pub mean_us: f64,
    /// `sheds / requests` (0 when nothing was sent).
    pub shed_rate: f64,
    /// Connections the measured phase used (1 = sequential replay).
    pub connections: usize,
    /// Wall-clock seconds of the measured phase.
    pub wall_s: f64,
    /// Aggregate throughput, *events per minute* (batch inner requests
    /// count individually): `requests · 60 / wall_s`.
    pub throughput_epm: f64,
    /// 99th-percentile client latency of `batch` frames only, µs (0 when
    /// the run sent no batches).
    pub batch_p99_us: f64,
    /// Single-connection unbatched baseline, events per minute, from the
    /// comparison phase throughput mode runs against the same server
    /// (0 when no baseline phase ran).
    pub single_epm: f64,
    /// Compact JSON of every response, arrival order — the transcript
    /// two equal-seed runs must reproduce verbatim.  In throughput mode
    /// the per-connection transcripts are concatenated in connection
    /// order (each one individually deterministic; interleaving across
    /// connections intentionally is not recorded).
    pub transcript: Vec<String>,
}

impl LoadGenReport {
    /// Human-readable summary (what `ripra loadgen` prints).
    pub fn summary(&self) -> String {
        let mut s = format!(
            "loadgen: {} requests, {} shed ({:.3} rate), {} errors; \
             latency p50 {:.1} us, p99 {:.1} us, mean {:.1} us; \
             {} connection(s), {:.0} events/min",
            self.requests,
            self.sheds,
            self.shed_rate,
            self.errors,
            self.p50_us,
            self.p99_us,
            self.mean_us,
            self.connections,
            self.throughput_epm
        );
        if self.single_epm > 0.0 {
            s.push_str(&format!(
                " (baseline {:.0} events/min, speedup {:.2}x)",
                self.single_epm,
                self.throughput_epm / self.single_epm
            ));
        }
        s
    }

    /// Machine-readable report (the `--json` payload; the transcript is
    /// included so replay checks can diff runs without a bench file).
    pub fn to_json(&self) -> Json {
        Json::Obj(vec![
            ("requests".into(), Json::Num(self.requests as f64)),
            ("sheds".into(), Json::Num(self.sheds as f64)),
            ("errors".into(), Json::Num(self.errors as f64)),
            ("serve_p50_us".into(), Json::Num(self.p50_us)),
            ("serve_p99_us".into(), Json::Num(self.p99_us)),
            ("serve_mean_us".into(), Json::Num(self.mean_us)),
            ("shed_rate".into(), Json::Num(self.shed_rate)),
            ("serve_connections".into(), Json::Num(self.connections as f64)),
            ("serve_wall_s".into(), Json::Num(self.wall_s)),
            ("serve_throughput_epm".into(), Json::Num(self.throughput_epm)),
            ("serve_batch_p99_us".into(), Json::Num(self.batch_p99_us)),
            ("serve_single_epm".into(), Json::Num(self.single_epm)),
            (
                "transcript".into(),
                Json::Arr(self.transcript.iter().map(|s| Json::Str(s.clone())).collect()),
            ),
        ])
    }

    /// Merge the serve rows into a BENCH_planner.json-style file under
    /// `benches.serve_wire`, preserving sibling keys — the same
    /// read-merge-write contract as
    /// [`crate::util::bench::Bencher::write_json`] (an existing file
    /// that fails to parse is an error, never silently replaced).
    pub fn write_bench_rows(&self, path: &Path) -> Result<(), String> {
        let mut root: Vec<(String, Json)> = match std::fs::read_to_string(path) {
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => Vec::new(),
            Err(e) => return Err(format!("read {}: {e}", path.display())),
            Ok(text) => {
                let parsed = Json::parse(&text).map_err(|e| {
                    format!(
                        "refusing to overwrite {}: existing file is not valid JSON ({e})",
                        path.display()
                    )
                })?;
                parsed
                    .as_obj()
                    .map(|o| o.to_vec())
                    .ok_or_else(|| {
                        format!(
                            "refusing to overwrite {}: existing JSON root is not an object",
                            path.display()
                        )
                    })?
            }
        };
        let mut entries: Vec<(String, Json)> = match root.iter().find(|(k, _)| k == "benches") {
            None => Vec::new(),
            Some((_, b)) => b.as_obj().map(|o| o.to_vec()).ok_or_else(|| {
                format!(
                    "refusing to overwrite {}: existing \"benches\" value is not an object",
                    path.display()
                )
            })?,
        };
        let mut fields = vec![
            ("serve_p50_us".into(), Json::Num(self.p50_us)),
            ("serve_p99_us".into(), Json::Num(self.p99_us)),
            ("serve_mean_us".into(), Json::Num(self.mean_us)),
            ("shed_rate".into(), Json::Num(self.shed_rate)),
            ("requests".into(), Json::Num(self.requests as f64)),
            ("sheds".into(), Json::Num(self.sheds as f64)),
            ("errors".into(), Json::Num(self.errors as f64)),
            ("serve_connections".into(), Json::Num(self.connections as f64)),
            ("serve_throughput_epm".into(), Json::Num(self.throughput_epm)),
            ("serve_batch_p99_us".into(), Json::Num(self.batch_p99_us)),
        ];
        if self.single_epm > 0.0 {
            fields.push(("serve_single_epm".into(), Json::Num(self.single_epm)));
            fields.push((
                "serve_speedup".into(),
                Json::Num(self.throughput_epm / self.single_epm),
            ));
        }
        let row = Json::Obj(fields);
        match entries.iter_mut().find(|(n, _)| n == "serve_wire") {
            Some(e) => e.1 = row,
            None => entries.push(("serve_wire".into(), row)),
        }
        let benches = Json::Obj(entries);
        match root.iter_mut().find(|(k, _)| k == "benches") {
            Some(e) => e.1 = benches,
            None => root.push(("benches".into(), benches)),
        }
        std::fs::write(path, Json::Obj(root).to_string_pretty())
            .map_err(|e| format!("write {}: {e}", path.display()))
    }
}

/// Nearest-rank percentile over an unsorted latency sample (same index
/// rule as the bench harness: `round((n-1)·q)` into the sorted sample).
fn percentile_us(samples: &[f64], q: f64) -> f64 {
    if samples.is_empty() {
        return 0.0;
    }
    let mut sorted = samples.to_vec();
    sorted.sort_by(f64::total_cmp);
    let idx = (((sorted.len() - 1) as f64) * q).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// What one connection's replay measured.  `singles` counts events —
/// each inner request of a [`WireRequest::Batch`] individually — while
/// the latency samples are per *frame* (a batch frame contributes one
/// round-trip sample covering all its events).
struct ConnOutcome {
    singles: usize,
    sheds: usize,
    errors: usize,
    /// Per-frame round-trip latency, µs, send order.
    frame_latencies_us: Vec<f64>,
    /// Round-trip latency of `batch` frames only, µs.
    batch_latencies_us: Vec<f64>,
    /// Compact JSON of each response frame, arrival order.
    transcript: Vec<String>,
}

/// Tally one decoded response frame into the outcome (recursing one
/// level for batches — the wire layer guarantees they never nest).
fn tally(resp: &WireResponse, out: &mut ConnOutcome) {
    match resp {
        WireResponse::Batch(inner) => {
            for r in inner {
                tally(r, out);
            }
        }
        WireResponse::Shed { .. } => {
            out.singles += 1;
            out.sheds += 1;
        }
        WireResponse::Error { .. } => {
            out.singles += 1;
            out.errors += 1;
        }
        _ => out.singles += 1,
    }
}

/// Replay one script on one sequential connection: send a frame, block
/// for the response, record the round trip, then sleep out the rest of
/// the pacing interval (`1 / rate_hz`).  Pacing changes *when* frames
/// are sent, never *what* is sent — the transcript stays a pure
/// function of the script.
fn replay_conn(addr: &str, reqs: &[WireRequest], rate_hz: f64) -> Result<ConnOutcome, String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream.set_nodelay(true).map_err(|e| format!("set_nodelay: {e}"))?;
    let pace = if rate_hz > 0.0 { Some(Duration::from_secs_f64(1.0 / rate_hz)) } else { None };

    let mut out = ConnOutcome {
        singles: 0,
        sheds: 0,
        errors: 0,
        frame_latencies_us: Vec::with_capacity(reqs.len()),
        batch_latencies_us: Vec::new(),
        transcript: Vec::with_capacity(reqs.len()),
    };
    for req in reqs {
        let body = req.to_json().to_string_compact();
        let sent = Instant::now();
        wire::write_frame(&mut stream, body.as_bytes()).map_err(|e| format!("send: {e}"))?;
        let resp = match wire::read_json(&mut stream).map_err(|e| format!("recv: {e}"))? {
            Some(j) => j,
            None => return Err("server closed mid-script".into()),
        };
        let elapsed = sent.elapsed();
        let us = elapsed.as_secs_f64() * 1e6;
        out.frame_latencies_us.push(us);
        if matches!(req, WireRequest::Batch(_)) {
            out.batch_latencies_us.push(us);
        }
        match WireResponse::from_json(&resp) {
            Ok(decoded) => tally(&decoded, &mut out),
            Err(e) => return Err(format!("undecodable response: {e}")),
        }
        out.transcript.push(resp.to_string_compact());
        if let Some(p) = pace {
            if elapsed < p {
                std::thread::sleep(p - elapsed);
            }
        }
    }
    let _ = stream.flush();
    Ok(out)
}

/// Fold connection outcomes (connection order) into a report.  `wall_s`
/// is the caller's measurement around the whole phase; throughput
/// counts events (batch inner requests individually).
fn report_of(outcomes: Vec<ConnOutcome>, connections: usize, wall_s: f64) -> LoadGenReport {
    let mut singles = 0;
    let mut sheds = 0;
    let mut errors = 0;
    let mut frames: Vec<f64> = Vec::new();
    let mut batches: Vec<f64> = Vec::new();
    let mut transcript: Vec<String> = Vec::new();
    for mut o in outcomes {
        singles += o.singles;
        sheds += o.sheds;
        errors += o.errors;
        frames.append(&mut o.frame_latencies_us);
        batches.append(&mut o.batch_latencies_us);
        transcript.append(&mut o.transcript);
    }
    let mean_us =
        if frames.is_empty() { 0.0 } else { frames.iter().sum::<f64>() / frames.len() as f64 };
    LoadGenReport {
        requests: singles,
        sheds,
        errors,
        p50_us: percentile_us(&frames, 0.50),
        p99_us: percentile_us(&frames, 0.99),
        mean_us,
        shed_rate: if singles == 0 { 0.0 } else { sheds as f64 / singles as f64 },
        connections,
        wall_s,
        throughput_epm: if wall_s > 0.0 { singles as f64 * 60.0 / wall_s } else { 0.0 },
        batch_p99_us: percentile_us(&batches, 0.99),
        single_epm: 0.0,
        transcript,
    }
}

/// Play a prebuilt script against a live server on one sequential
/// connection and measure it (the classic replay entry point; the
/// determinism pins in `rust/tests/serve.rs` go through here).
pub fn run_script(addr: &str, reqs: &[WireRequest], rate_hz: f64) -> Result<LoadGenReport, String> {
    let started = Instant::now();
    let outcome = replay_conn(addr, reqs, rate_hz)?;
    Ok(report_of(vec![outcome], 1, started.elapsed().as_secs_f64()))
}

/// Send one `shutdown` on a dedicated connection (throughput mode's
/// closer, after every worker has drained its sub-script).
fn send_shutdown(addr: &str) -> Result<(), String> {
    let _ = replay_conn(addr, &[WireRequest::Shutdown], 0.0)?;
    Ok(())
}

/// Build the script from `opts` and play it.
///
/// With `connections <= 1` this is [`script`] + optional
/// [`batch_script`] + [`run_script`].  With `connections > 1` it runs
/// the **two-phase throughput comparison** against one server:
///
/// 1. *Baseline*: the sequential, unbatched script (tenants
///    `first_tenant..`), shutdown stripped — measured exactly like the
///    single-connection mode and recorded as `single_epm`.
/// 2. *Concurrent*: a second script with disjoint tenant ids (offset by
///    `tenants`) and a decorrelated seed, partitioned by tenant over C
///    connections and coalesced into batch frames (`batch`, default 16),
///    played by C threads and wall-clocked end to end.
///
/// The returned report describes the concurrent phase, with the
/// baseline rate alongside; a final closer connection shuts the server
/// down.  `rate_hz` paces each connection independently.
pub fn run(addr: &str, opts: &LoadGenOptions) -> Result<LoadGenReport, String> {
    let c = opts.connections.max(1);
    if c == 1 {
        let reqs = batch_script(&script(opts), opts.batch);
        return run_script(addr, &reqs, opts.rate_hz);
    }

    // Phase 1: sequential unbatched baseline, same server, no shutdown.
    let mut base_reqs = script(opts);
    base_reqs.retain(|r| !matches!(r, WireRequest::Shutdown));
    let base_started = Instant::now();
    let base = replay_conn(addr, &base_reqs, opts.rate_hz)?;
    let base_wall = base_started.elapsed().as_secs_f64();
    let single_epm = if base_wall > 0.0 { base.singles as f64 * 60.0 / base_wall } else { 0.0 };

    // Phase 2: disjoint tenants, decorrelated seed (so the concurrent
    // phase cannot ride the baseline's warm plan caches), split by
    // tenant, batched.
    let conc_opts = LoadGenOptions {
        first_tenant: opts.first_tenant + opts.tenants.max(1) as TenantId,
        seed: opts.seed.wrapping_add(1),
        ..opts.clone()
    };
    let batch = if opts.batch == 0 { 16 } else { opts.batch };
    let scripts: Vec<Vec<WireRequest>> = split_script(&script(&conc_opts), c)
        .into_iter()
        .map(|s| batch_script(&s, batch))
        .collect();

    let started = Instant::now();
    let outcomes: Vec<Result<ConnOutcome, String>> = std::thread::scope(|scope| {
        let handles: Vec<_> = scripts
            .iter()
            .map(|reqs| scope.spawn(move || replay_conn(addr, reqs, opts.rate_hz)))
            .collect();
        handles
            .into_iter()
            .map(|h| match h.join() {
                Ok(r) => r,
                Err(_) => Err("connection worker panicked".into()),
            })
            .collect()
    });
    let wall_s = started.elapsed().as_secs_f64();
    send_shutdown(addr)?;

    let mut collected = Vec::with_capacity(outcomes.len());
    for o in outcomes {
        collected.push(o?);
    }
    let mut report = report_of(collected, c, wall_s);
    report.single_epm = single_epm;
    Ok(report)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_scripts_encode_identically() {
        let opts = LoadGenOptions { events: 40, ..LoadGenOptions::default() };
        let a = encode_script(&script(&opts));
        let b = encode_script(&script(&opts));
        assert!(!a.is_empty());
        assert_eq!(a, b, "same seed must produce byte-identical wire streams");
        let other = LoadGenOptions { seed: 8, ..opts };
        assert_ne!(a, encode_script(&script(&other)), "a different seed must change the stream");
    }

    #[test]
    fn script_shape_admissions_probes_and_shutdown() {
        let opts =
            LoadGenOptions { tenants: 3, events: 16, probe_every: 4, ..LoadGenOptions::default() };
        let reqs = script(&opts);
        let kinds: Vec<&str> = reqs.iter().map(|r| r.kind()).collect();
        assert_eq!(&kinds[..3], &["admit", "admit", "admit"]);
        assert_eq!(kinds.last().copied(), Some("shutdown"));
        assert_eq!(kinds.iter().filter(|k| **k == "admit").count(), 3);
        // 16 deltas probed every 4 → 4 probe pairs; final sweep adds 3
        // plans and 1 stats.
        assert_eq!(kinds.iter().filter(|k| **k == "delta").count(), 16);
        assert_eq!(kinds.iter().filter(|k| **k == "plan").count(), 4 + 3);
        assert_eq!(kinds.iter().filter(|k| **k == "stats").count(), 4 + 1);
    }

    #[test]
    fn leave_never_empties_a_fleet() {
        // With 1 initial device per tenant every would-be leave must be
        // rewritten into a channel fade; decode-level invariant: no
        // Leave targets a sole surviving device.
        let opts = LoadGenOptions {
            tenants: 1,
            devices: 1,
            events: 200,
            probe_every: 0,
            ..LoadGenOptions::default()
        };
        let mut live = 1i64;
        for r in script(&opts) {
            if let WireRequest::Delta { delta, .. } = r {
                match delta {
                    ScenarioDelta::Join(_) => live += 1,
                    ScenarioDelta::Leave(_) => {
                        assert!(live > 1, "leave generated against a sole device");
                        live -= 1;
                    }
                    _ => {}
                }
            }
        }
        assert!(live >= 1);
    }

    #[test]
    fn split_preserves_per_tenant_order_and_strips_shutdown() {
        let opts =
            LoadGenOptions { tenants: 5, events: 40, probe_every: 4, ..LoadGenOptions::default() };
        let reqs = script(&opts);
        let parts = split_script(&reqs, 3);
        assert_eq!(parts.len(), 3);
        let total: usize = parts.iter().map(Vec::len).sum();
        let stats = reqs.iter().filter(|r| r.kind() == "stats").count();
        // everything except shutdown survives the split, exactly once
        assert_eq!(total, reqs.len() - 1);
        assert!(parts.iter().flatten().all(|r| r.kind() != "shutdown"));
        // stats probes all ride connection 0
        assert_eq!(parts[0].iter().filter(|r| r.kind() == "stats").count(), stats);
        // per connection, the tenant-tagged sub-sequence preserves the
        // canonical script order (socket FIFO is the only causality)
        for (c, part) in parts.iter().enumerate() {
            let tenant_of = |r: &WireRequest| match r {
                WireRequest::Admit { tenant, .. }
                | WireRequest::Delta { tenant, .. }
                | WireRequest::Plan { tenant } => Some(*tenant),
                _ => None,
            };
            for t in part.iter().filter_map(&tenant_of) {
                assert_eq!(t as usize % 3, c, "tenant routed to the wrong connection");
            }
            let want: Vec<String> = reqs
                .iter()
                .filter(|r| tenant_of(r).is_some_and(|t| t as usize % 3 == c))
                .map(|r| r.to_json().to_string_compact())
                .collect();
            let got: Vec<String> = part
                .iter()
                .filter(|r| tenant_of(r).is_some())
                .map(|r| r.to_json().to_string_compact())
                .collect();
            assert_eq!(got, want, "split must not reorder a tenant's requests");
        }
    }

    #[test]
    fn batching_reframes_without_reordering() {
        let opts = LoadGenOptions { events: 17, probe_every: 0, ..LoadGenOptions::default() };
        let reqs = script(&opts);
        let batched = batch_script(&reqs, 4);
        // flattening the batches reproduces the original script exactly
        let mut flat = Vec::new();
        for r in &batched {
            match r {
                WireRequest::Batch(inner) => {
                    assert!(inner.len() >= 2 && inner.len() <= 4);
                    flat.extend(inner.iter().cloned());
                }
                other => flat.push(other.clone()),
            }
        }
        assert_eq!(encode_script(&flat), encode_script(&reqs));
        // batch 0 and 1 are the identity
        assert_eq!(encode_script(&batch_script(&reqs, 0)), encode_script(&reqs));
        assert_eq!(encode_script(&batch_script(&reqs, 1)), encode_script(&reqs));
    }

    #[test]
    fn first_tenant_offsets_ids_without_touching_the_event_stream() {
        let a = LoadGenOptions { tenants: 2, events: 20, ..LoadGenOptions::default() };
        let b = LoadGenOptions { first_tenant: 11, ..a.clone() };
        let sa = script(&a);
        let sb = script(&b);
        assert_eq!(sa.len(), sb.len());
        for (ra, rb) in sa.iter().zip(&sb) {
            let ta = ra.to_json().to_string_compact();
            let tb = rb.to_json().to_string_compact();
            // identical apart from the tenant ids (1,2) -> (11,12)
            assert_eq!(
                ta.replace("\"tenant\":1,", "\"tenant\":11,")
                    .replace("\"tenant\":2,", "\"tenant\":12,")
                    .replace("\"tenant\":1}", "\"tenant\":11}")
                    .replace("\"tenant\":2}", "\"tenant\":12}"),
                tb
            );
        }
    }

    #[test]
    fn percentile_index_rule() {
        let xs = vec![5.0, 1.0, 4.0, 2.0, 3.0];
        // lint:allow(float-eq): exact values by construction
        assert_eq!(percentile_us(&xs, 0.5), 3.0);
        // lint:allow(float-eq): exact values by construction
        assert_eq!(percentile_us(&xs, 1.0), 5.0);
        // lint:allow(float-eq): exact values by construction
        assert_eq!(percentile_us(&[], 0.5), 0.0);
    }
}
