//! The fleet driver: maps discrete events to [`ScenarioDelta`]s, drives
//! a long-lived planning backend — one bare [`Planner`], or a sharded
//! [`PlannerService`] when [`FleetOptions::shards`] ≥ 1 — through the
//! resulting stream, and validates every accepted plan with the
//! Monte-Carlo simulator.
//!
//! Per popped event the driver
//!
//! 1. translates it to a `ScenarioDelta` and applies it to the current
//!    scenario;
//! 2. probes the plan cache ([`Planner::plan_cached`]) — sub-quantum
//!    jitter (a fade inside the fingerprint's 0.1 dB bucket, a risk
//!    renegotiation back to its previous value) is served without any
//!    solver work;
//! 3. on a miss calls [`Planner::replan`], whose warm path costs a few
//!    Newton iterations and which falls back to a cold solve when the
//!    adapted decision is infeasible;
//! 4. if even the cold fallback is infeasible, *negotiable* events
//!    (join/leave, deadline/risk renegotiation) are **rejected** —
//!    admission control: the request is refused and nothing rolls
//!    forward — while *environmental* events (channel fade, uplink
//!    budget) are **absorbed**: the scenario rolls forward via
//!    [`Planner::rebase`], the fleet keeps executing its old plan, and
//!    the step records the violation excess that plan now incurs;
//! 5. on acceptance runs [`sim::evaluate`] (distribution family rotating
//!    over lognormal / gamma / shifted-exponential) and records the
//!    worst empirical violation excess over the per-device risk levels.
//!
//! Determinism: every random draw comes from a stream forked off the
//! fleet seed (arrivals, lifetimes, placement, per-device fading,
//! renegotiation, bandwidth, Monte-Carlo), so the full event trace, the
//! metrics JSON, and the final fleet state are byte-identical for a
//! given seed at any `util::par` thread count.

use crate::channel::{GaussMarkov, Uplink};
use crate::engine::{
    CacheStats, CliFlag, Diagnostics, PlanError, PlanOutcome, PlanRequest, Planner,
    PlannerBuilder, Policy, RiskBound, ScenarioDelta,
};
use crate::fault::{Delivery, FaultOptions, FaultStreams};
use crate::models::ModelProfile;
use crate::optim::types::{Device, Plan, Scenario};
use crate::profile::Dist;
use crate::risk::Calibration;
use crate::service::{Disposition, PlannerService, ServiceError, ServiceOptions, TenantId};
use crate::sim::{self, SimOptions};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::events::{EventQueue, FleetEvent};
use super::metrics::{FleetMetrics, StepRecord, INITIAL_KIND, RECALIBRATE_KIND};

/// Stationary shadowing standard deviation of the Gauss–Markov gain
/// process, dB (urban shadowing scale).
const SHADOW_SIGMA_DB: f64 = 2.0;

/// AR(1) memory of the fading process.  With σ = 2 dB this yields a
/// per-tick move of ≈ 0.25 dB, so a meaningful share of fades stays
/// inside the plan fingerprint's 0.1 dB bucket (those replans become
/// plan-cache hits) while the rest genuinely moves the channel.
const GM_ALPHA: f64 = 0.992;

/// Renegotiation events per second at churn 1.
const RENEGOTIATE_RATE_HZ: f64 = 0.15;

/// Bandwidth-change events per second at churn 1.
const BANDWIDTH_RATE_HZ: f64 = 0.08;

/// Fading-tick interval per device at churn 1, seconds.
const FADE_INTERVAL_S: f64 = 2.0;

/// Risk multipliers a renegotiation draws from (×1 returns a device to
/// its base risk — when nothing else changed, that replan is an exact
/// fingerprint repeat and is served from the plan cache).
const RISK_STEPS: [f64; 3] = [0.5, 1.0, 2.0];

/// Re-offload attempts a device makes after an outage before giving up
/// and waiting for ordinary churn to trigger the next replan.
const MAX_REOFFLOAD_ATTEMPTS: u32 = 6;

/// Cap on chained conformal recalibrations triggered by one fleet step
/// (each applied recalibration is Monte-Carlo-checked and may justify
/// the next; the conformal scale moves monotonically toward its floor
/// on clean observations, so the cap only guards pathological
/// oscillation).
const MAX_RECAL_CHAIN: usize = 16;

/// Configuration for one simulated fleet run.
///
/// `threads` is deliberately excluded from [`FleetOptions::to_json`]:
/// thread count never changes results (PR 1's determinism contract), so
/// the exported config — like every other exported field — identifies
/// the trace.
#[derive(Clone, Debug)]
pub struct FleetOptions {
    /// DNN/hardware profile every device runs.
    pub model: ModelProfile,
    /// Initial fleet size (≥ 1).
    pub n0: usize,
    /// Simulated duration, seconds.
    pub duration_s: f64,
    /// Poisson device-arrival rate, Hz.
    pub arrival_rate_hz: f64,
    /// Churn multiplier: scales departure, fading-tick, renegotiation,
    /// and bandwidth-change rates together (0 freezes all of them).
    pub churn: f64,
    /// Initial total uplink bandwidth, Hz.
    pub total_bandwidth_hz: f64,
    /// Base per-task deadline, seconds (renegotiations jitter around it).
    pub deadline_s: f64,
    /// Base risk level ε (renegotiations step it by ×{0.5, 1, 2}).
    pub risk: f64,
    /// Monte-Carlo trials per accepted step (0 disables the check).
    pub trials: usize,
    /// Seed for every event stream.
    pub seed: u64,
    /// Planner worker threads (0 = one per core; never changes results).
    pub threads: usize,
    /// Planner-service shards: 0 drives one bare [`Planner`] (the
    /// serial path), K ≥ 1 drives a [`PlannerService`] with K shards.
    /// Unlike `threads`, the shard count *does* change results (it
    /// partitions the bandwidth budget), so it is part of the exported
    /// config; a one-shard service is bit-identical to the serial path.
    pub shards: usize,
    /// Chance-constraint transform every robust plan in the run uses
    /// (default [`RiskBound::Ecr`]).  A calibrated bound additionally
    /// turns on the online conformal stream: after each Monte-Carlo
    /// check the scale is updated from the observed violations and, when
    /// the quantized bound moves, a fleet-wide
    /// [`ScenarioDelta::Bound`] recalibration is driven through the
    /// backend (recorded as a `"recalibrate"` step).
    pub bound: RiskBound,
    /// Fault schedule (edge outages, uplink blackouts, delta delivery
    /// faults).  Disabled by default; when disabled the fault streams
    /// are never forked, so a fault-free trace is unaffected by this
    /// field's parameters.
    pub faults: FaultOptions,
    /// Cohort-compressed robust solves ([`crate::optim::cohort`]) on
    /// every planner the backend builds — the path that makes
    /// million-device bootstraps tractable.  Off by default; an off run
    /// is byte-identical to the pre-cohort driver.
    pub cohorts: bool,
}

impl Default for FleetOptions {
    fn default() -> Self {
        FleetOptions {
            model: ModelProfile::alexnet_paper(),
            n0: 6,
            duration_s: 30.0,
            arrival_rate_hz: 0.2,
            churn: 1.0,
            total_bandwidth_hz: 12.5e6,
            deadline_s: 0.20,
            risk: 0.02,
            trials: 1000,
            seed: 7,
            threads: 0,
            shards: 0,
            bound: RiskBound::Ecr,
            faults: FaultOptions::default(),
            cohorts: false,
        }
    }
}

impl FleetOptions {
    /// Flags the `ripra simulate` subcommand exposes; `main.rs` derives
    /// its usage text and parser from this table, exactly as `ripra
    /// plan` does from [`PlanRequest::CLI_FLAGS`].
    pub const CLI_FLAGS: &[CliFlag] = &[
        CliFlag { name: "model", value: Some("alexnet|resnet152"), help: "DNN/hardware profile" },
        CliFlag { name: "n", value: Some("N"), help: "initial fleet size (default 6)" },
        CliFlag { name: "devices", value: Some("N"), help: "alias for --n (initial fleet size)" },
        CliFlag {
            name: "duration",
            value: Some("S"),
            help: "simulated time, seconds (default 30)",
        },
        CliFlag {
            name: "arrival-rate",
            value: Some("HZ"),
            help: "Poisson device-arrival rate (default 0.2)",
        },
        CliFlag {
            name: "churn",
            value: Some("X"),
            help: "churn multiplier: departures, fades, renegotiations (default 1)",
        },
        CliFlag { name: "bandwidth", value: Some("HZ"), help: "initial total uplink bandwidth" },
        CliFlag { name: "deadline", value: Some("S"), help: "base per-task deadline, seconds" },
        CliFlag { name: "risk", value: Some("E"), help: "base tolerated violation probability" },
        CliFlag {
            name: "trials",
            value: Some("T"),
            help: "Monte-Carlo trials per replan (0 disables)",
        },
        CliFlag { name: "seed", value: Some("S"), help: "event-stream seed" },
        CliFlag {
            name: "shards",
            value: Some("K"),
            help: "planner-service shards (0 = one serial planner)",
        },
        CliFlag {
            name: "bound",
            value: Some("ecr|gauss|bernstein|calibrated[:S]"),
            help: "chance-constraint transform (default ecr; calibrated learns online)",
        },
        CliFlag {
            name: "cohorts",
            value: None,
            help: "cohort-compressed planning (solve fingerprint classes, not devices)",
        },
        CliFlag { name: "json", value: None, help: "emit the metrics time series as JSON" },
        CliFlag {
            name: "faults",
            value: None,
            help: "enable the seeded fault schedule (outages, blackouts, delivery faults)",
        },
        CliFlag {
            name: "outage-rate",
            value: Some("HZ"),
            help: "edge-outage arrival rate (default 0.05)",
        },
        CliFlag {
            name: "outage-mean",
            value: Some("S"),
            help: "mean edge-outage length, seconds (default 2.5)",
        },
        CliFlag {
            name: "blackout-rate",
            value: Some("HZ"),
            help: "uplink-blackout arrival rate (default 0.08)",
        },
        CliFlag {
            name: "blackout-mean",
            value: Some("S"),
            help: "mean blackout length, seconds (default 1.5)",
        },
        CliFlag {
            name: "blackout-depth",
            value: Some("DB"),
            help: "gain collapse during a blackout, dB (default 25)",
        },
        CliFlag {
            name: "drop-prob",
            value: Some("P"),
            help: "chance a negotiable/bandwidth delta is dropped (default 0.05)",
        },
        CliFlag {
            name: "delay-prob",
            value: Some("P"),
            help: "chance such a delta is delayed in flight (default 0.10)",
        },
        CliFlag {
            name: "delay-mean",
            value: Some("S"),
            help: "mean in-flight delay, seconds (default 0.4)",
        },
        CliFlag {
            name: "backoff",
            value: Some("S"),
            help: "base re-offload backoff after an outage (default 0.25)",
        },
    ];

    /// Per-device departure rate targeting an equilibrium fleet size of
    /// roughly `n0 / churn` (arrivals λ balance departures n·μ there).
    fn departure_rate_per_device(&self) -> f64 {
        self.churn * self.arrival_rate_hz / self.n0.max(1) as f64
    }

    fn fade_interval_s(&self) -> Option<f64> {
        if self.churn > 0.0 {
            Some(FADE_INTERVAL_S / self.churn)
        } else {
            None
        }
    }

    fn renegotiate_rate_hz(&self) -> f64 {
        RENEGOTIATE_RATE_HZ * self.churn
    }

    fn bandwidth_rate_hz(&self) -> f64 {
        BANDWIDTH_RATE_HZ * self.churn
    }

    fn validate(&self) -> Result<(), PlanError> {
        let bad = |msg: String| Err(PlanError::InvalidRequest(msg));
        if self.n0 == 0 {
            return bad("fleet needs at least one initial device".into());
        }
        if !(self.duration_s.is_finite() && self.duration_s > 0.0) {
            return bad(format!("duration must be positive, got {}", self.duration_s));
        }
        for (name, v) in [
            ("arrival-rate", self.arrival_rate_hz),
            ("churn", self.churn),
            ("bandwidth", self.total_bandwidth_hz),
            ("deadline", self.deadline_s),
        ] {
            if !(v.is_finite() && v >= 0.0) {
                return bad(format!("{name} must be finite and non-negative, got {v}"));
            }
        }
        if self.total_bandwidth_hz <= 0.0 || self.deadline_s <= 0.0 {
            return bad("bandwidth and deadline must be positive".into());
        }
        crate::risk::validate_risk(self.risk).map_err(PlanError::InvalidRisk)?;
        if self.faults.enabled {
            self.faults.validate().map_err(PlanError::InvalidRequest)?;
        }
        Ok(())
    }

    /// Config block of the metrics JSON (deterministic; excludes
    /// `threads`, which never changes results).  `shards` is exported as
    /// the *effective* shard count — the serial path is one shard — so a
    /// `shards = 0` run and a one-shard service run, which are
    /// bit-identical by contract, also export identical configs.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("model".into(), Json::Str(self.model.name.clone())),
            ("n0".into(), Json::Num(self.n0 as f64)),
            ("duration_s".into(), Json::Num(self.duration_s)),
            ("arrival_rate_hz".into(), Json::Num(self.arrival_rate_hz)),
            ("churn".into(), Json::Num(self.churn)),
            ("bandwidth_hz".into(), Json::Num(self.total_bandwidth_hz)),
            ("deadline_s".into(), Json::Num(self.deadline_s)),
            ("risk".into(), Json::Num(self.risk)),
            ("trials".into(), Json::Num(self.trials as f64)),
            ("seed".into(), Json::Num(self.seed as f64)),
            ("shards".into(), Json::Num(self.shards.max(1) as f64)),
            ("bound".into(), Json::Str(self.bound.name().into())),
            (
                "bound_scale".into(),
                self.bound.scale().map(Json::Num).unwrap_or(Json::Null),
            ),
        ];
        // Only cohort runs carry the key: cohorts=off configs stay
        // byte-identical to the pre-cohort export.
        if self.cohorts {
            fields.push(("cohorts".into(), Json::Bool(true)));
        }
        fields.push((
            "faults".into(),
            Json::Obj(vec![
                ("enabled".into(), Json::Bool(self.faults.enabled)),
                ("outage_rate_hz".into(), Json::Num(self.faults.outage_rate_hz)),
                ("outage_mean_s".into(), Json::Num(self.faults.outage_mean_s)),
                ("blackout_rate_hz".into(), Json::Num(self.faults.blackout_rate_hz)),
                ("blackout_mean_s".into(), Json::Num(self.faults.blackout_mean_s)),
                ("blackout_depth_db".into(), Json::Num(self.faults.blackout_depth_db)),
                ("drop_prob".into(), Json::Num(self.faults.drop_prob)),
                ("delay_prob".into(), Json::Num(self.faults.delay_prob)),
                ("delay_mean_s".into(), Json::Num(self.faults.delay_mean_s)),
                ("backoff_base_s".into(), Json::Num(self.faults.backoff_base_s)),
            ]),
        ));
        Json::Obj(fields)
    }
}

/// Driver-side state of one admitted device.
struct DeviceState {
    id: u64,
    gm: GaussMarkov,
    /// Per-device stream for fading innovations and tick stagger.
    rng: Rng,
}

/// The one tenant id a fleet run occupies on the service backend.
const FLEET_TENANT: TenantId = 0;

/// Cost and provenance of an accepted planning step.
struct Applied {
    energy_j: f64,
    newton_iters: usize,
    outer_iters: usize,
    cache_hit: bool,
    warm_started: bool,
    /// The accepted plan is a degraded one (all-local fallback during an
    /// edge outage, or a budget-truncated solve).
    degraded: bool,
}

/// What one fleet event cost the planning backend.
enum StepResult {
    /// A plan exists for the changed scenario.
    Applied(Applied),
    /// Environmental infeasibility absorbed: scenario adopted, old plan
    /// kept, energy re-priced.
    Absorbed { energy_j: f64 },
    /// Negotiable request refused; nothing rolled forward.
    Rejected,
}

/// The planning backend a fleet run drives: one bare [`Planner`]
/// (`shards = 0`), or a [`PlannerService`] hosting the fleet as one
/// tenant (`shards ≥ 1`).  Both expose the same probe → warm-replan →
/// absorb/reject step, so the event loop is backend-agnostic; a
/// one-shard service is bit-identical to the serial path (pinned by
/// `rust/tests/service.rs`).
// One Backend exists per fleet run, so the variant-size asymmetry is
// irrelevant and boxing would only add indirection.
#[allow(clippy::large_enum_variant)]
enum Backend {
    Serial { planner: Planner, outcome: PlanOutcome },
    Service(PlannerService),
}

impl Backend {
    /// Build the backend and cold-plan the initial scenario.
    fn bootstrap(opts: &FleetOptions, sc: &Scenario) -> Result<(Backend, Applied), PlanError> {
        if opts.shards == 0 {
            let mut planner =
                PlannerBuilder::new().threads(opts.threads).cohorts(opts.cohorts).build();
            let outcome = planner
                .plan(&PlanRequest::new(sc.clone(), Policy::Robust).with_bound(opts.bound))?;
            let applied = Applied {
                energy_j: outcome.energy,
                newton_iters: outcome.diagnostics.newton_iters,
                outer_iters: outcome.diagnostics.outer_iters,
                cache_hit: false,
                warm_started: false,
                degraded: outcome.diagnostics.degraded,
            };
            Ok((Backend::Serial { planner, outcome }, applied))
        } else {
            let mut svc = PlannerService::new(ServiceOptions {
                shards: opts.shards,
                threads: opts.threads,
                cohorts: opts.cohorts,
                ..ServiceOptions::default()
            })
            .map_err(|e| PlanError::InvalidRequest(e.to_string()))?;
            let out = match svc.admit_tenant_with(FLEET_TENANT, sc.clone(), opts.bound) {
                Ok(o) => o,
                Err(ServiceError::Plan(e)) => return Err(e),
                Err(e) => return Err(PlanError::InvalidRequest(e.to_string())),
            };
            let applied = Applied {
                energy_j: out.energy_j,
                newton_iters: out.newton_iters,
                outer_iters: out.outer_iters,
                cache_hit: false,
                warm_started: false,
                degraded: out.degraded,
            };
            Ok((Backend::Service(svc), applied))
        }
    }

    /// Drive one event's delta through the backend (`new_sc` is the
    /// already-validated changed scenario): plan-cache probe first, warm
    /// replan next; on infeasibility, environmental deltas are absorbed
    /// and negotiable ones rejected.
    fn step(
        &mut self,
        delta: &ScenarioDelta,
        new_sc: &Scenario,
        environmental: bool,
        req_bound: RiskBound,
    ) -> StepResult {
        match self {
            Backend::Serial { planner, outcome } => {
                // Borrow-only cache probe: no scenario clone unless it
                // actually hits.
                let out = match planner.plan_cached_for(new_sc, &Policy::Robust, req_bound) {
                    Some(hit) => hit,
                    None => match planner.replan(delta) {
                        Ok(o) => o,
                        Err(_) => {
                            if environmental {
                                if let Ok(energy) = planner.rebase(new_sc) {
                                    outcome.energy = energy;
                                    return StepResult::Absorbed { energy_j: energy };
                                }
                            }
                            return StepResult::Rejected;
                        }
                    },
                };
                // A cache hit carries the *original* solve's diagnostics;
                // the step itself cost no solver work, so its per-step
                // iteration counts are zero (keeps newton_total
                // comparable across runs with different hit rates).
                let (newton_iters, outer_iters) = if out.diagnostics.cache_hit {
                    (0, 0)
                } else {
                    (out.diagnostics.newton_iters, out.diagnostics.outer_iters)
                };
                let applied = Applied {
                    energy_j: out.energy,
                    newton_iters,
                    outer_iters,
                    cache_hit: out.diagnostics.cache_hit,
                    warm_started: out.diagnostics.warm_started,
                    degraded: out.diagnostics.degraded,
                };
                *outcome = out;
                StepResult::Applied(applied)
            }
            Backend::Service(svc) => {
                // lint:allow(panic-path): the driver submits exactly one
                // delta per drain, so the queue can never back-pressure.
                svc.submit(FLEET_TENANT, delta.clone()).expect("driver drains every event");
                // lint:allow(panic-path): one submit ⇒ exactly one result
                let out = svc.drain().pop().expect("one request per drain");
                match out.disposition {
                    Disposition::Applied => StepResult::Applied(Applied {
                        energy_j: out.energy_j,
                        newton_iters: out.newton_iters,
                        outer_iters: out.outer_iters,
                        cache_hit: out.cache_hit,
                        warm_started: out.warm_started,
                        degraded: out.degraded,
                    }),
                    Disposition::Absorbed => StepResult::Absorbed { energy_j: out.energy_j },
                    Disposition::Rejected => StepResult::Rejected,
                    Disposition::Superseded => {
                        unreachable!("single-request drains never coalesce")
                    }
                }
            }
        }
    }

    /// Mark the edge server reachable/unreachable on every planner this
    /// backend drives (all shards on the service path).
    fn set_edge_available(&mut self, up: bool) {
        match self {
            Backend::Serial { planner, .. } => planner.set_edge_available(up),
            Backend::Service(svc) => svc.set_edge_available(up),
        }
    }

    /// The decision the fleet is currently executing (assembled across
    /// shards on the service backend).
    fn current_plan(&self) -> Plan {
        match self {
            Backend::Serial { outcome, .. } => outcome.plan.clone(),
            Backend::Service(svc) => {
                // lint:allow(panic-path): tenant admitted in Backend::new
                svc.assembled_plan(FLEET_TENANT).expect("fleet tenant admitted")
            }
        }
    }

    /// Plan-cache counters (aggregated over shards on the service path).
    fn cache_stats(&self) -> CacheStats {
        match self {
            Backend::Serial { planner, .. } => planner.cache_stats(),
            Backend::Service(svc) => svc.cache_stats(),
        }
    }

    /// The last decision as a [`PlanOutcome`] for the report.
    fn final_outcome(&self, bound: RiskBound) -> PlanOutcome {
        match self {
            Backend::Serial { outcome, .. } => outcome.clone(),
            Backend::Service(svc) => PlanOutcome {
                // lint:allow(panic-path): tenant admitted in Backend::new
                plan: svc.assembled_plan(FLEET_TENANT).expect("fleet tenant admitted"),
                energy: svc.tenant_energy(FLEET_TENANT).unwrap_or(0.0),
                policy: Policy::Robust,
                bound,
                diagnostics: Diagnostics::default(),
            },
        }
    }
}

/// Everything a fleet run produces.
pub struct FleetReport {
    /// The options the run was configured with.
    pub options: FleetOptions,
    /// Per-step time series + aggregates.
    pub metrics: FleetMetrics,
    /// Fleet scenario at the end of the run.
    pub final_scenario: Scenario,
    /// Last accepted plan outcome (on the service backend: the decision
    /// assembled across shards, with default diagnostics).
    pub final_outcome: PlanOutcome,
    /// Risk bound in force at the end of the run — differs from
    /// `options.bound` only when online calibration moved the scale.
    pub final_bound: RiskBound,
}

impl FleetReport {
    /// Full machine-readable encoding: `{"config", "metrics", "final"}`.
    /// Byte-identical for identical seeds (see module docs).
    pub fn to_json(&self) -> Json {
        let partition = Json::Arr(
            self.final_outcome.plan.partition.iter().map(|&m| Json::Num(m as f64)).collect(),
        );
        Json::Obj(vec![
            ("config".into(), self.options.to_json()),
            ("metrics".into(), self.metrics.to_json()),
            (
                "final".into(),
                Json::Obj(vec![
                    ("n".into(), Json::Num(self.final_scenario.n() as f64)),
                    (
                        "total_bandwidth_hz".into(),
                        Json::Num(self.final_scenario.total_bandwidth_hz),
                    ),
                    ("energy_j".into(), Json::Num(self.final_outcome.energy)),
                    ("partition".into(), partition),
                    ("bound".into(), Json::Str(self.final_bound.name().into())),
                    (
                        "bound_scale".into(),
                        self.final_bound.scale().map(Json::Num).unwrap_or(Json::Null),
                    ),
                ]),
            ),
        ])
    }
}

/// Create a device at a uniform position in the paper's 400 m square,
/// with its Gauss–Markov fading process started at the path-loss mean
/// and its own innovation stream forked off `channels`.
fn new_device(
    opts: &FleetOptions,
    placement: &mut Rng,
    channels: &mut Rng,
    next_id: &mut u64,
) -> (DeviceState, Device) {
    let id = *next_id;
    *next_id += 1;
    let x = placement.range(-200.0, 200.0);
    let y = placement.range(-200.0, 200.0);
    let r = (x * x + y * y).sqrt().max(1.0);
    let mean_db = -(38.0 + 30.0 * r.log10());
    let gm = GaussMarkov::new(mean_db, SHADOW_SIGMA_DB, GM_ALPHA);
    let dev = Device {
        model: opts.model.clone(),
        uplink: Uplink::from_gain_db(gm.gain_db()),
        deadline_s: opts.deadline_s,
        risk: opts.risk,
    };
    (DeviceState { id, gm, rng: channels.fork(id) }, dev)
}

fn index_of(states: &[DeviceState], id: u64) -> Option<usize> {
    states.iter().position(|s| s.id == id)
}

/// Run one simulated fleet.  Errors only if the *initial* scenario is
/// unplannable or the options are malformed; later infeasible events are
/// rejected and recorded, not fatal.
pub fn run(opts: &FleetOptions) -> Result<FleetReport, PlanError> {
    opts.validate()?;
    let mut master = Rng::new(opts.seed);
    // One independent stream per event source, forked in fixed order so
    // the trace is a pure function of the seed.
    let mut arrivals = master.fork(0xA1);
    let mut lifetimes = master.fork(0xDE);
    let mut placement = master.fork(0x10C);
    let mut channels = master.fork(0xC4);
    let mut reneg = master.fork(0x5E);
    let mut bw = master.fork(0xB0);
    let mc_base = master.next_u64();
    // Fault streams fork strictly *after* every fault-free stream (and
    // only when faults are on), so enabling them never perturbs the
    // fault-free trace of the same seed.
    let mut fstreams: Option<FaultStreams> =
        if opts.faults.enabled { Some(FaultStreams::fork_off(&mut master)) } else { None };

    let mut next_id: u64 = 0;
    let mut states: Vec<DeviceState> = Vec::new();
    let mut devices: Vec<Device> = Vec::new();
    for _ in 0..opts.n0 {
        let (st, dev) = new_device(opts, &mut placement, &mut channels, &mut next_id);
        states.push(st);
        devices.push(dev);
    }
    let mut sc = Scenario { devices, total_bandwidth_hz: opts.total_bandwidth_hz };

    let (mut backend, boot) = Backend::bootstrap(opts, &sc)?;

    let mut metrics = FleetMetrics::new();
    let mut step_no: u64 = 0;
    // Fault bookkeeping.  `degraded_ids` holds the devices currently
    // executing the all-local fallback; the whole fleet enters it on an
    // edge outage and leaves it at the first successful post-outage
    // replan (the planner is joint, so one accepted replan restores
    // every device — the backoff paces *requests*, not plan content).
    let mut edge_down = false;
    let mut last_outage_end = 0.0_f64;
    let mut degraded_ids: Vec<u64> = Vec::new();
    let mut blacked: Vec<u64> = Vec::new();
    let mut pending: Vec<Option<(ScenarioDelta, bool)>> = Vec::new();
    let mut current_energy = boot.energy_j;
    // Active risk bound + the conformal controller (calibrated runs
    // only): every accepted step's Monte-Carlo excess feeds the
    // controller, and quantized scale moves become fleet-wide
    // ScenarioDelta::Bound recalibrations.
    let mut bound = opts.bound;
    let mut calib: Option<Calibration> = match opts.bound {
        RiskBound::Calibrated { scale_q } => {
            // Dequantize from the variant's own payload (same arithmetic
            // as `RiskBound::scale`), so the arm cannot panic.
            Some(Calibration::with_scale(scale_q as f64 * crate::risk::SCALE_QUANTUM))
        }
        _ => None,
    };
    let mc_excess = |sc: &Scenario, plan: &Plan, step_no: u64| {
        (opts.trials > 0).then(|| {
            let dist = match step_no % 3 {
                0 => Dist::Lognormal,
                1 => Dist::Gamma,
                _ => Dist::ShiftedExp,
            };
            let seed = mc_base ^ step_no.wrapping_mul(0x9E37_79B9_7F4A_7C15);
            let rep = sim::evaluate(sc, plan, &SimOptions { trials: opts.trials, dist, seed });
            rep.violation_prob
                .iter()
                .zip(&sc.devices)
                .map(|(&v, d)| v - d.risk)
                .fold(f64::NEG_INFINITY, f64::max)
        })
    };

    let boot_excess = mc_excess(&sc, &backend.current_plan(), step_no);
    metrics.record(StepRecord {
        t_s: 0.0,
        kind: INITIAL_KIND,
        n: sc.n(),
        accepted: true,
        absorbed: false,
        cache_hit: false,
        warm_started: false,
        energy_j: Some(boot.energy_j),
        newton_iters: boot.newton_iters,
        outer_iters: boot.outer_iters,
        violation_excess: boot_excess,
        degraded: boot.degraded,
        degraded_devices: 0,
    });
    recalibrate(
        opts,
        &mut backend,
        &mut metrics,
        &mut calib,
        &mut bound,
        &sc,
        0.0,
        &mut step_no,
        boot_excess,
        &mc_excess,
    );

    // Seed the event streams.
    let mut queue = EventQueue::new();
    if opts.arrival_rate_hz > 0.0 {
        queue.push(arrivals.exponential(opts.arrival_rate_hz), FleetEvent::Arrival);
    }
    let dep_rate = opts.departure_rate_per_device();
    if dep_rate > 0.0 {
        for st in &states {
            queue.push(lifetimes.exponential(dep_rate), FleetEvent::Departure { id: st.id });
        }
    }
    let fade_dt = opts.fade_interval_s();
    if let Some(dt) = fade_dt {
        for st in &mut states {
            // Stagger first ticks so devices don't all fade at once.
            queue.push(st.rng.f64() * dt, FleetEvent::Fade { id: st.id });
        }
    }
    if opts.renegotiate_rate_hz() > 0.0 {
        queue.push(reneg.exponential(opts.renegotiate_rate_hz()), FleetEvent::Renegotiate);
    }
    if opts.bandwidth_rate_hz() > 0.0 {
        queue.push(bw.exponential(opts.bandwidth_rate_hz()), FleetEvent::Bandwidth);
    }
    if let Some(fs) = fstreams.as_mut() {
        if opts.faults.outage_rate_hz > 0.0 {
            queue.push(fs.outage_wait_s(&opts.faults), FleetEvent::EdgeDown);
        }
        if opts.faults.blackout_rate_hz > 0.0 {
            queue.push(fs.blackout_wait_s(&opts.faults), FleetEvent::Blackout);
        }
    }

    while let Some((t, ev)) = queue.pop() {
        if t > opts.duration_s {
            break;
        }
        // Translate the event to a delta; recurring sources reschedule
        // themselves here whether or not the delta is later accepted.
        // The trailing bool is the delta's *environmental* flag (an
        // environmental fact cannot be refused, only absorbed); it is
        // carried explicitly because delayed deliveries replay a delta
        // under the "deliver" kind.
        let mut reoffload_ctx: Option<(u64, u32)> = None;
        let translated: Option<(&'static str, ScenarioDelta, Option<DeviceState>, bool)> = match ev
        {
            FleetEvent::Arrival => {
                queue.push(t + arrivals.exponential(opts.arrival_rate_hz), FleetEvent::Arrival);
                let (st, dev) = new_device(opts, &mut placement, &mut channels, &mut next_id);
                Some(("join", ScenarioDelta::Join(dev), Some(st), false))
            }
            FleetEvent::Departure { id } => {
                index_of(&states, id).map(|i| ("leave", ScenarioDelta::Leave(i), None, false))
            }
            FleetEvent::Fade { id } => match index_of(&states, id) {
                // Device already left: drop the tick and stop rescheduling.
                None => None,
                Some(i) => {
                    let st = &mut states[i];
                    let mut gain = st.gm.step(&mut st.rng);
                    if let Some(dt) = fade_dt {
                        queue.push(t + dt, FleetEvent::Fade { id });
                    }
                    // A blacked-out device publishes its collapsed gain:
                    // the blackout depth rides on top of the fading state.
                    if blacked.contains(&id) {
                        gain = 10f64
                            .powf((st.gm.gain_db() - opts.faults.blackout_depth_db) / 10.0);
                    }
                    let cur = sc.devices[i].uplink;
                    let uplink = Uplink { p_tx: cur.p_tx, gain, n0: cur.n0 };
                    Some(("channel", ScenarioDelta::Channel { device: i, uplink }, None, true))
                }
            },
            FleetEvent::Renegotiate => {
                let next = t + reneg.exponential(opts.renegotiate_rate_hz());
                queue.push(next, FleetEvent::Renegotiate);
                let i = reneg.below(sc.n());
                if reneg.f64() < 0.5 {
                    let deadline_s = opts.deadline_s * reneg.range(0.85, 1.4);
                    let delta = ScenarioDelta::Deadline { device: Some(i), deadline_s };
                    Some(("deadline", delta, None, false))
                } else {
                    let step = RISK_STEPS[reneg.below(RISK_STEPS.len())];
                    let risk = (opts.risk * step).clamp(1e-3, 0.5);
                    Some(("risk", ScenarioDelta::Risk { device: Some(i), risk }, None, false))
                }
            }
            FleetEvent::Bandwidth => {
                queue.push(t + bw.exponential(opts.bandwidth_rate_hz()), FleetEvent::Bandwidth);
                let b = opts.total_bandwidth_hz * bw.range(0.8, 1.25);
                Some(("bandwidth", ScenarioDelta::TotalBandwidth(b), None, true))
            }
            FleetEvent::EdgeDown => {
                // lint:allow(panic-path): edge events are only scheduled
                // when fault streams were forked at boot
                let fs = fstreams.as_mut().expect("edge events only exist with faults on");
                queue.push(t + fs.outage_len_s(&opts.faults), FleetEvent::EdgeUp);
                edge_down = true;
                backend.set_edge_available(false);
                degraded_ids = states.iter().map(|s| s.id).collect();
                // A no-op environmental delta forces one replan so the
                // fleet actually switches to the all-local fallback.
                let b = sc.total_bandwidth_hz;
                Some(("edge-down", ScenarioDelta::TotalBandwidth(b), None, true))
            }
            FleetEvent::EdgeUp => {
                // lint:allow(panic-path): edge events are only scheduled
                // when fault streams were forked at boot
                let fs = fstreams.as_mut().expect("edge events only exist with faults on");
                queue.push(t + fs.outage_wait_s(&opts.faults), FleetEvent::EdgeDown);
                edge_down = false;
                last_outage_end = t;
                backend.set_edge_available(true);
                // Bookkeeping step (no backend call): the outage ended,
                // but every device keeps executing the fallback until its
                // backoff-paced re-offload lands.
                metrics.record(StepRecord {
                    t_s: t,
                    kind: "edge-up",
                    n: sc.n(),
                    accepted: false,
                    absorbed: true,
                    cache_hit: false,
                    warm_started: false,
                    energy_j: Some(current_energy),
                    newton_iters: 0,
                    outer_iters: 0,
                    violation_excess: None,
                    degraded: !degraded_ids.is_empty(),
                    degraded_devices: degraded_ids.len(),
                });
                // Deterministic jittered exponential backoff, one stream
                // of draws in stable device order: no thundering herd.
                for st in &states {
                    let wait = fs.backoff_s(&opts.faults, 0);
                    queue.push(t + wait, FleetEvent::Reoffload { id: st.id, attempt: 0 });
                }
                None
            }
            FleetEvent::Blackout => {
                // lint:allow(panic-path): blackouts are only scheduled
                // when fault streams were forked at boot
                let fs = fstreams.as_mut().expect("blackout events only exist with faults on");
                queue.push(t + fs.blackout_wait_s(&opts.faults), FleetEvent::Blackout);
                let i = fs.blackout_victim(states.len());
                let id = states[i].id;
                if blacked.contains(&id) {
                    // Already blacked out: the new blackout is subsumed.
                    None
                } else {
                    blacked.push(id);
                    queue.push(t + fs.blackout_len_s(&opts.faults), FleetEvent::BlackoutEnd { id });
                    let gain =
                        10f64.powf((states[i].gm.gain_db() - opts.faults.blackout_depth_db) / 10.0);
                    let cur = sc.devices[i].uplink;
                    let uplink = Uplink { p_tx: cur.p_tx, gain, n0: cur.n0 };
                    Some(("blackout", ScenarioDelta::Channel { device: i, uplink }, None, true))
                }
            }
            FleetEvent::BlackoutEnd { id } => {
                blacked.retain(|&b| b != id);
                match index_of(&states, id) {
                    // During an outage the restored gain is published by
                    // the device's own re-offload, not here.
                    Some(i) if !edge_down => {
                        let gain = 10f64.powf(states[i].gm.gain_db() / 10.0);
                        let cur = sc.devices[i].uplink;
                        let uplink = Uplink { p_tx: cur.p_tx, gain, n0: cur.n0 };
                        Some((
                            "blackout-end",
                            ScenarioDelta::Channel { device: i, uplink },
                            None,
                            true,
                        ))
                    }
                    _ => None,
                }
            }
            FleetEvent::Reoffload { id, attempt } => {
                if edge_down || degraded_ids.is_empty() {
                    // A fresh outage began, or an earlier replan already
                    // recovered the whole fleet.
                    None
                } else {
                    match index_of(&states, id) {
                        None => None,
                        Some(i) => {
                            reoffload_ctx = Some((id, attempt));
                            let mut db = states[i].gm.gain_db();
                            if blacked.contains(&id) {
                                db -= opts.faults.blackout_depth_db;
                            }
                            let gain = 10f64.powf(db / 10.0);
                            let cur = sc.devices[i].uplink;
                            let uplink = Uplink { p_tx: cur.p_tx, gain, n0: cur.n0 };
                            Some((
                                "reoffload",
                                ScenarioDelta::Channel { device: i, uplink },
                                None,
                                true,
                            ))
                        }
                    }
                }
            }
            FleetEvent::Deliver { ticket } => pending
                .get_mut(ticket)
                .and_then(|slot| slot.take())
                .map(|(delta, env)| ("deliver", delta, None, env)),
        };
        // In-flight delivery faults apply to message-like deltas only
        // (renegotiations and bandwidth changes travel to the planner;
        // channel fades are local observations and membership changes
        // are handled at admission).
        let translated = match (translated, fstreams.as_mut()) {
            (Some((kind @ ("deadline" | "risk" | "bandwidth"), delta, joiner, env)), Some(fs)) => {
                match fs.delivery(&opts.faults) {
                    Delivery::OnTime => Some((kind, delta, joiner, env)),
                    Delivery::Dropped => {
                        metrics.record(StepRecord {
                            t_s: t,
                            kind: "drop",
                            n: sc.n(),
                            accepted: false,
                            absorbed: false,
                            cache_hit: false,
                            warm_started: false,
                            energy_j: None,
                            newton_iters: 0,
                            outer_iters: 0,
                            violation_excess: None,
                            degraded: edge_down || !degraded_ids.is_empty(),
                            degraded_devices: degraded_ids.len(),
                        });
                        None
                    }
                    Delivery::Delayed(d) => {
                        pending.push(Some((delta, env)));
                        queue.push(t + d, FleetEvent::Deliver { ticket: pending.len() - 1 });
                        None
                    }
                }
            }
            (tr, _) => tr,
        };
        let Some((kind, delta, joiner, environmental)) = translated else { continue };
        step_no += 1;

        let fleet_degraded = edge_down || !degraded_ids.is_empty();
        let n_degraded = degraded_ids.len();
        let rejected = |metrics: &mut FleetMetrics, n: usize| {
            metrics.record(StepRecord {
                t_s: t,
                kind,
                n,
                accepted: false,
                absorbed: false,
                cache_hit: false,
                warm_started: false,
                energy_j: None,
                newton_iters: 0,
                outer_iters: 0,
                violation_excess: None,
                degraded: fleet_degraded,
                degraded_devices: n_degraded,
            });
        };

        let new_sc = match delta.apply(&sc) {
            Ok(s) => s,
            // e.g. a departure would empty the fleet: refuse it, but
            // reschedule the departure so the device isn't immortal.
            Err(_) => {
                if let ScenarioDelta::Leave(i) = &delta {
                    if dep_rate > 0.0 {
                        let id = states[*i].id;
                        let at = t + lifetimes.exponential(dep_rate);
                        queue.push(at, FleetEvent::Departure { id });
                    }
                }
                rejected(&mut metrics, sc.n());
                continue;
            }
        };
        // Negotiable requests are refused (admission control);
        // environmental facts cannot be — they are absorbed: the
        // scenario rolls forward, the fleet keeps its old plan, and the
        // step records what that plan now incurs.
        match backend.step(&delta, &new_sc, environmental, bound) {
            StepResult::Applied(a) => {
                // Commit fleet bookkeeping only for accepted membership
                // changes.
                match &delta {
                    ScenarioDelta::Join(_) => {
                        // lint:allow(panic-path): Join deltas are built
                        // with their joiner a few lines above
                        let st = joiner.expect("join events carry their device state");
                        let id = st.id;
                        if dep_rate > 0.0 {
                            let at = t + lifetimes.exponential(dep_rate);
                            queue.push(at, FleetEvent::Departure { id });
                        }
                        states.push(st);
                        if let Some(dt) = fade_dt {
                            // lint:allow(panic-path): pushed just above
                            let stagger = states.last_mut().expect("just pushed").rng.f64() * dt;
                            queue.push(t + stagger, FleetEvent::Fade { id });
                        }
                    }
                    ScenarioDelta::Leave(i) => {
                        let gone = states.remove(*i);
                        blacked.retain(|&b| b != gone.id);
                        degraded_ids.retain(|&d| d != gone.id);
                    }
                    _ => {}
                }
                sc = new_sc;
                current_energy = a.energy_j;
                if a.degraded {
                    // The accepted plan is the fleet-wide fallback: every
                    // current device is executing it.
                    degraded_ids = states.iter().map(|s| s.id).collect();
                } else if !degraded_ids.is_empty() {
                    // First healthy accepted plan after an outage: the
                    // planner is joint, so it recovers every device at
                    // once.  Time-to-recovery is measured from the
                    // outage's end, per device.
                    for _ in 0..degraded_ids.len() {
                        metrics.record_recovery(t - last_outage_end);
                    }
                    degraded_ids.clear();
                }
                let excess = mc_excess(&sc, &backend.current_plan(), step_no);
                metrics.record(StepRecord {
                    t_s: t,
                    kind,
                    n: sc.n(),
                    accepted: true,
                    absorbed: false,
                    cache_hit: a.cache_hit,
                    warm_started: a.warm_started,
                    energy_j: Some(a.energy_j),
                    newton_iters: a.newton_iters,
                    outer_iters: a.outer_iters,
                    violation_excess: excess,
                    degraded: a.degraded,
                    degraded_devices: degraded_ids.len(),
                });
                // Degraded steps skip recalibration: fallback violations
                // would pollute the conformal stream with excesses the
                // bound cannot fix.
                if !a.degraded {
                    recalibrate(
                        opts,
                        &mut backend,
                        &mut metrics,
                        &mut calib,
                        &mut bound,
                        &sc,
                        t,
                        &mut step_no,
                        excess,
                        &mc_excess,
                    );
                }
            }
            StepResult::Absorbed { energy_j } => {
                sc = new_sc;
                current_energy = energy_j;
                // An absorbed re-offload means the fleet is still on the
                // fallback: back off and retry (bounded).
                if let (Some((id, attempt)), Some(fs)) = (reoffload_ctx, fstreams.as_mut()) {
                    if attempt < MAX_REOFFLOAD_ATTEMPTS {
                        let wait = fs.backoff_s(&opts.faults, attempt + 1);
                        queue.push(t + wait, FleetEvent::Reoffload { id, attempt: attempt + 1 });
                    }
                }
                metrics.record(StepRecord {
                    t_s: t,
                    kind,
                    n: sc.n(),
                    accepted: false,
                    absorbed: true,
                    cache_hit: false,
                    warm_started: false,
                    energy_j: Some(energy_j),
                    newton_iters: 0,
                    outer_iters: 0,
                    violation_excess: mc_excess(&sc, &backend.current_plan(), step_no),
                    degraded: edge_down || !degraded_ids.is_empty(),
                    degraded_devices: degraded_ids.len(),
                });
            }
            StepResult::Rejected => {
                // A refused departure must still happen eventually:
                // reschedule it so the device doesn't become immortal.
                if let ScenarioDelta::Leave(i) = &delta {
                    if dep_rate > 0.0 {
                        let id = states[*i].id;
                        let at = t + lifetimes.exponential(dep_rate);
                        queue.push(at, FleetEvent::Departure { id });
                    }
                }
                if let (Some((id, attempt)), Some(fs)) = (reoffload_ctx, fstreams.as_mut()) {
                    if attempt < MAX_REOFFLOAD_ATTEMPTS {
                        let wait = fs.backoff_s(&opts.faults, attempt + 1);
                        queue.push(t + wait, FleetEvent::Reoffload { id, attempt: attempt + 1 });
                    }
                }
                rejected(&mut metrics, sc.n());
            }
        }
    }

    metrics.set_cache_stats(backend.cache_stats());
    Ok(FleetReport {
        options: opts.clone(),
        metrics,
        final_scenario: sc,
        final_outcome: backend.final_outcome(bound),
        final_bound: bound,
    })
}

/// Drive the conformal-calibration stream after one Monte-Carlo-checked
/// accepted step: feed the observed excess to the controller and, while
/// the quantized bound moves, broadcast a fleet-wide
/// [`ScenarioDelta::Bound`] through the backend.  Each applied
/// recalibration is itself Monte-Carlo-checked (its excess feeds the
/// next observation), so on a quiet fleet the scale walks to its floor
/// without waiting for churn; a rejected recalibration (an inflating
/// re-plan turned out infeasible) snaps the controller back to the
/// applied bound.  No-op unless the run was configured with a
/// calibrated bound and Monte-Carlo checks are on.
#[allow(clippy::too_many_arguments)] // driver-internal plumbing, not API
fn recalibrate(
    opts: &FleetOptions,
    backend: &mut Backend,
    metrics: &mut FleetMetrics,
    calib: &mut Option<Calibration>,
    bound: &mut RiskBound,
    sc: &Scenario,
    t: f64,
    step_no: &mut u64,
    excess: Option<f64>,
    mc_excess: &dyn Fn(&Scenario, &Plan, u64) -> Option<f64>,
) {
    let Some(cal) = calib.as_mut() else { return };
    let Some(mut excess) = excess else { return };
    for _ in 0..MAX_RECAL_CHAIN {
        let next = cal.observe(excess, opts.risk);
        if next == *bound {
            break;
        }
        *step_no += 1;
        let delta = ScenarioDelta::Bound(next);
        match backend.step(&delta, sc, false, next) {
            StepResult::Applied(a) => {
                *bound = next;
                let ve = mc_excess(sc, &backend.current_plan(), *step_no);
                metrics.record(StepRecord {
                    t_s: t,
                    kind: RECALIBRATE_KIND,
                    n: sc.n(),
                    accepted: true,
                    absorbed: false,
                    cache_hit: a.cache_hit,
                    warm_started: a.warm_started,
                    energy_j: Some(a.energy_j),
                    newton_iters: a.newton_iters,
                    outer_iters: a.outer_iters,
                    violation_excess: ve,
                    degraded: false,
                    degraded_devices: 0,
                });
                match ve {
                    Some(e) => excess = e,
                    None => break,
                }
            }
            // A bound change is negotiable; the backend never absorbs it.
            StepResult::Rejected | StepResult::Absorbed { .. } => {
                cal.reset_to(*bound);
                metrics.record(StepRecord {
                    t_s: t,
                    kind: RECALIBRATE_KIND,
                    n: sc.n(),
                    accepted: false,
                    absorbed: false,
                    cache_hit: false,
                    warm_started: false,
                    energy_j: None,
                    newton_iters: 0,
                    outer_iters: 0,
                    violation_excess: None,
                    degraded: false,
                    degraded_devices: 0,
                });
                break;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_opts(seed: u64) -> FleetOptions {
        FleetOptions {
            n0: 3,
            duration_s: 2.5,
            arrival_rate_hz: 0.8,
            churn: 1.5,
            total_bandwidth_hz: 10e6,
            deadline_s: 0.22,
            risk: 0.06,
            trials: 120,
            seed,
            threads: 1,
            ..FleetOptions::default()
        }
    }

    #[test]
    fn short_run_is_deterministic_and_well_formed() {
        let a = run(&tiny_opts(5)).unwrap();
        let b = run(&tiny_opts(5)).unwrap();
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "same seed must produce byte-identical metrics JSON"
        );
        let s = a.metrics.summary();
        assert!(s.events > 1, "expected events beyond the bootstrap solve");
        assert_eq!(s.events, s.accepted + s.rejected + s.absorbed);
        assert_eq!(a.final_scenario.n(), a.final_outcome.plan.partition.len());
        // Plan invariants hold at the end of the run — unless an
        // absorbed environmental event deliberately left the old plan
        // in violation of the new scenario (documented semantics).
        if s.absorbed == 0 {
            assert!(a.final_outcome.plan.bandwidth_ok(&a.final_scenario));
            assert!(a.final_outcome.plan.freq_ok(&a.final_scenario));
        }
    }

    #[test]
    fn different_seeds_produce_different_traces() {
        let a = run(&tiny_opts(1)).unwrap();
        let b = run(&tiny_opts(2)).unwrap();
        assert_ne!(a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
    }

    #[test]
    fn no_event_sources_leaves_only_the_bootstrap_step() {
        let opts = FleetOptions {
            churn: 0.0,
            arrival_rate_hz: 0.0,
            duration_s: 5.0,
            trials: 0,
            n0: 2,
            threads: 1,
            ..FleetOptions::default()
        };
        let rep = run(&opts).unwrap();
        // Only the bootstrap step: no event source is active.
        assert_eq!(rep.metrics.summary().events, 1);
        assert_eq!(rep.final_scenario.n(), 2);
    }

    #[test]
    fn sharded_backend_runs_deterministically_and_respects_the_budget() {
        let opts = FleetOptions { shards: 3, ..tiny_opts(9) };
        let a = run(&opts).unwrap();
        let b = run(&opts).unwrap();
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "sharded runs must be byte-identical for the same seed"
        );
        let s = a.metrics.summary();
        assert_eq!(s.events, s.accepted + s.rejected + s.absorbed);
        assert_eq!(a.final_scenario.n(), a.final_outcome.plan.partition.len());
        // Shard shares sum to the budget, so the assembled plan respects
        // Σb ≤ B whenever no absorbed share update is outstanding.
        if s.absorbed == 0 {
            assert!(a.final_outcome.plan.bandwidth_ok(&a.final_scenario));
            assert!(a.final_outcome.plan.freq_ok(&a.final_scenario));
        }
    }

    /// Tiny faulted run: cranked rates so outages and blackouts land
    /// inside the short horizon, and a deadline generous enough that the
    /// all-local fallback is deterministically feasible.
    fn faulty_opts(seed: u64) -> FleetOptions {
        FleetOptions {
            deadline_s: 2.0,
            duration_s: 6.0,
            faults: FaultOptions {
                enabled: true,
                outage_rate_hz: 2.0,
                outage_mean_s: 0.5,
                blackout_rate_hz: 1.0,
                blackout_mean_s: 0.4,
                ..FaultOptions::default()
            },
            ..tiny_opts(seed)
        }
    }

    #[test]
    fn faulted_run_is_deterministic_and_accounts_degradation() {
        let a = run(&faulty_opts(13)).unwrap();
        let b = run(&faulty_opts(13)).unwrap();
        assert_eq!(
            a.to_json().to_string_pretty(),
            b.to_json().to_string_pretty(),
            "same seed + same fault schedule must produce byte-identical JSON"
        );
        let s = a.metrics.summary();
        assert_eq!(s.events, s.accepted + s.rejected + s.absorbed);
        // λT = 12 outage arrivals expected over the horizon: the seeded
        // schedule contains at least one outage, so degradation and the
        // fallback's energy premium are actually exercised.
        assert!(s.degraded_steps > 0, "cranked fault schedule must degrade some steps");
        assert!(s.max_degraded_devices > 0);
        // Degraded steps are excluded from the violation-guarantee
        // aggregates by construction; the summary only counts them in
        // the dedicated fault fields.
        assert!(s.violations_while_degraded <= s.degraded_steps);
        if s.recoveries > 0 {
            let mean = s.mean_time_to_recovery_s.expect("recoveries imply a mean TTR");
            let max = s.max_time_to_recovery_s.expect("recoveries imply a max TTR");
            assert!(mean >= 0.0 && max >= mean);
        }
    }

    #[test]
    fn fault_free_trace_is_unchanged_by_fault_parameters() {
        // Parameters of a *disabled* schedule must not leak into the
        // trace: the streams are never forked.
        let base = run(&tiny_opts(5)).unwrap();
        let mut opts = tiny_opts(5);
        opts.faults = FaultOptions { enabled: false, outage_rate_hz: 99.0, ..FaultOptions::default() };
        let tweaked = run(&opts).unwrap();
        assert_eq!(
            base.metrics.to_json().to_string_pretty(),
            tweaked.metrics.to_json().to_string_pretty(),
        );
    }

    #[test]
    fn malformed_options_are_rejected_cleanly() {
        for bad in [
            FleetOptions { n0: 0, ..FleetOptions::default() },
            FleetOptions { duration_s: -1.0, ..FleetOptions::default() },
            FleetOptions { churn: f64::NAN, ..FleetOptions::default() },
            FleetOptions {
                faults: FaultOptions {
                    enabled: true,
                    drop_prob: 0.9,
                    delay_prob: 0.9,
                    ..FaultOptions::default()
                },
                ..FleetOptions::default()
            },
        ] {
            assert!(matches!(run(&bad), Err(PlanError::InvalidRequest(_))));
        }
        // Risk gets the structured error (shared with PlanRequest
        // validation), not a generic InvalidRequest.
        for bad_risk in [0.0, 1.0, f64::NAN] {
            let opts = FleetOptions { risk: bad_risk, ..FleetOptions::default() };
            assert!(matches!(run(&opts), Err(PlanError::InvalidRisk(_))));
        }
    }
}
