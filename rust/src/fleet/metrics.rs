//! Time-series metrics for the fleet simulator.
//!
//! Accumulates one [`StepRecord`] per popped event and exports the whole
//! run — per-step series plus aggregate summary — through
//! [`crate::util::json::Json`].  **Every exported field is a
//! deterministic function of the fleet seed**: wall-clock durations are
//! deliberately excluded so that same-seed runs produce byte-identical
//! JSON at any `util::par` thread count (the determinism contract pinned
//! by `rust/tests/fleet.rs`).

use crate::engine::CacheStats;
use crate::util::json::Json;

/// The `ScenarioDelta` kinds a fleet run can exercise, in the stable
/// order used by the JSON export's `delta_counts` object
/// (`"recalibrate"` only fires on runs configured with a calibrated
/// risk bound; the [`FAULT_KINDS`] tail only fires on runs with
/// `--faults` enabled).
pub const DELTA_KINDS: [&str; 14] = [
    "join",
    "leave",
    "deadline",
    "risk",
    "channel",
    "bandwidth",
    "recalibrate",
    "edge-down",
    "edge-up",
    "blackout",
    "blackout-end",
    "reoffload",
    "deliver",
    "drop",
];

/// The step kinds only a fault schedule produces (a strict subset of
/// [`DELTA_KINDS`]): edge outage begin/end, uplink blackout begin/end,
/// post-outage re-offload, delayed delta arrival, and in-flight drop.
pub const FAULT_KINDS: [&str; 7] =
    ["edge-down", "edge-up", "blackout", "blackout-end", "reoffload", "deliver", "drop"];

/// Tag for the driver's one cold bootstrap solve (not a delta).
pub const INITIAL_KIND: &str = "initial";

/// Tag for a conformal risk-bound recalibration step (a fleet-wide
/// `ScenarioDelta::Bound` emitted by the driver's calibration stream).
pub const RECALIBRATE_KIND: &str = "recalibrate";

/// One planner interaction: the outcome of one popped fleet event (or of
/// the initial cold solve).
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// Simulation time of the triggering event, seconds.
    pub t_s: f64,
    /// Delta kind — one of [`DELTA_KINDS`], or [`INITIAL_KIND`] for the
    /// bootstrap solve.
    pub kind: &'static str,
    /// Fleet size after the step (unchanged when rejected).
    pub n: usize,
    /// The planner produced a plan for the changed scenario; `false`
    /// means no new plan exists: the event was rejected (negotiable
    /// request refused) or absorbed (environmental fact adopted with the
    /// old plan kept — see [`StepRecord::absorbed`]).
    pub accepted: bool,
    /// An infeasible *environmental* event (channel fade, uplink-budget
    /// change) that cannot be refused: the scenario rolled forward, the
    /// fleet keeps executing its previous plan, and `violation_excess`
    /// reports what that plan now incurs.  Always `false` when
    /// `accepted`.
    pub absorbed: bool,
    /// Served straight from the plan cache (sub-quantum scenario jitter).
    pub cache_hit: bool,
    /// Produced by the warm incremental replan path.
    pub warm_started: bool,
    /// Planned expected energy after the step, J: the new plan's when
    /// accepted, the old plan re-priced under the new scenario when
    /// absorbed, `None` when rejected.
    pub energy_j: Option<f64>,
    /// Newton iterations this step cost (0 for cache hits / rejections).
    pub newton_iters: usize,
    /// Outer (refinement / alternation) iterations this step cost.
    pub outer_iters: usize,
    /// Monte-Carlo check: max over devices of (empirical violation
    /// probability − ε_n).  ≤ 0 means every device met its risk level;
    /// `None` when the check is disabled or the event was rejected.  On
    /// absorbed steps this measures the *old* plan against the *new*
    /// environment and may legitimately exceed 0.
    pub violation_excess: Option<f64>,
    /// The step ran in degraded mode: the edge was unreachable (the
    /// fleet executes the all-local fallback) or the planner's solve
    /// budget truncated.  Degraded steps are excluded from the
    /// violation-guarantee aggregates and counted separately
    /// ([`FleetSummary::violations_while_degraded`]).
    pub degraded: bool,
    /// Devices still executing the all-local fallback after this step
    /// (0 when the fleet is healthy).
    pub degraded_devices: usize,
}

/// Aggregates over one run; all fields deterministic per seed.
#[derive(Clone, Debug)]
pub struct FleetSummary {
    /// Planner interactions recorded (including the bootstrap solve).
    pub events: usize,
    /// Steps that produced a plan.
    pub accepted: usize,
    /// Negotiable events refused for infeasibility.
    pub rejected: usize,
    /// Environmental events adopted without a new plan (old plan kept).
    pub absorbed: usize,
    /// Accepted steps served from the plan cache.
    pub cache_hits: usize,
    /// Accepted steps served by the warm incremental replan path.
    pub warm_replans: usize,
    /// Accepted steps that needed a cold solve (bootstrap + feasibility
    /// fallbacks inside `replan`).
    pub cold_solves: usize,
    /// Planner-cache hit rate over all lookups (hits / (hits + misses)).
    pub cache_hit_rate: f64,
    /// Total Newton iterations across the run.
    pub newton_total: usize,
    /// Mean planned energy over accepted steps, J (0 if none).
    pub mean_energy_j: f64,
    /// Worst Monte-Carlo violation excess over *accepted* steps — the
    /// probabilistic-guarantee metric (`None` if never checked).
    /// Absorbed steps are excluded: their old-plan-vs-new-environment
    /// excess is reported per step, not against the guarantee.
    pub worst_violation_excess: Option<f64>,
    /// Mean Monte-Carlo violation excess over the checked accepted
    /// steps — read next to the configured bound, this is the
    /// empirical-violation-vs-ε record that lets runs under different
    /// bounds (or different conformal scales) be compared directly.
    ///
    /// Both violation aggregates exclude degraded steps: a fallback plan
    /// issued during an outage makes no probabilistic promise, so its
    /// violations must not be read against the bound's guarantee (they
    /// are counted in [`FleetSummary::violations_while_degraded`]).
    pub mean_violation_excess: Option<f64>,
    /// Steps recorded while degraded (edge down or budget-truncated).
    pub degraded_steps: usize,
    /// Peak simultaneous devices on the all-local fallback.
    pub max_degraded_devices: usize,
    /// Checked degraded steps whose Monte-Carlo violation excess was
    /// positive — the deadline violations incurred *while* degraded.
    pub violations_while_degraded: usize,
    /// Completed per-device recoveries (outage-end → successful
    /// re-offload replan).
    pub recoveries: usize,
    /// Mean time-to-recovery over completed recoveries, seconds
    /// (simulation time, so deterministic per seed); `None` when no
    /// recovery completed.
    pub mean_time_to_recovery_s: Option<f64>,
    /// Worst time-to-recovery, seconds; `None` when no recovery
    /// completed.
    pub max_time_to_recovery_s: Option<f64>,
    /// Energy premium of local-only fallback: Σ over accepted degraded
    /// steps of `max(0, step energy − last healthy accepted energy)`, J.
    pub fallback_energy_premium_j: f64,
}

/// Accumulator for a fleet run's records plus the planner's final cache
/// counters.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    steps: Vec<StepRecord>,
    cache: CacheStats,
    /// Completed time-to-recovery samples, seconds (simulation time).
    recoveries: Vec<f64>,
}

impl FleetMetrics {
    /// An empty accumulator.
    pub fn new() -> FleetMetrics {
        FleetMetrics::default()
    }

    /// Append one step record.
    pub fn record(&mut self, step: StepRecord) {
        self.steps.push(step);
    }

    /// Snapshot the planner's cache counters (called once at run end).
    pub fn set_cache_stats(&mut self, stats: CacheStats) {
        self.cache = stats;
    }

    /// Record one completed device recovery: `ttr_s` is the simulation
    /// time from the outage's end to the device's first successful
    /// re-offload replan (deterministic per seed — no wall clock).
    pub fn record_recovery(&mut self, ttr_s: f64) {
        debug_assert!(ttr_s.is_finite() && ttr_s >= 0.0, "bad time-to-recovery {ttr_s}");
        self.recoveries.push(ttr_s);
    }

    /// Completed time-to-recovery samples, in completion order.
    pub fn recoveries(&self) -> &[f64] {
        &self.recoveries
    }

    /// All recorded steps in event order.
    pub fn steps(&self) -> &[StepRecord] {
        &self.steps
    }

    /// The planner's cache counters at run end.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache
    }

    /// How many recorded steps carry `kind` (accepted or not).
    pub fn count_of(&self, kind: &str) -> usize {
        self.steps.iter().filter(|s| s.kind == kind).count()
    }

    /// Aggregate the recorded series.
    ///
    /// Served-path classification is priority-ordered: a step is a cache
    /// hit first (even if the *cached* outcome was originally produced by
    /// a warm replan and still carries `warm_started`), a warm replan
    /// second, and a cold solve otherwise — so the three counts always
    /// partition the accepted steps.
    pub fn summary(&self) -> FleetSummary {
        let accepted: Vec<&StepRecord> = self.steps.iter().filter(|s| s.accepted).collect();
        let absorbed = self.steps.iter().filter(|s| s.absorbed).count();
        let cache_hits = accepted.iter().filter(|s| s.cache_hit).count();
        let warm_replans = accepted.iter().filter(|s| !s.cache_hit && s.warm_started).count();
        let cold_solves = accepted.len() - cache_hits - warm_replans;
        let lookups = self.cache.hits + self.cache.misses;
        let energies: Vec<f64> = accepted.iter().filter_map(|s| s.energy_j).collect();
        let mean_energy_j = if energies.is_empty() {
            0.0
        } else {
            energies.iter().sum::<f64>() / energies.len() as f64
        };
        // The guarantee metrics read only healthy accepted steps; the
        // degraded tail is accounted separately below.
        let worst_violation_excess = accepted
            .iter()
            .filter(|s| !s.degraded)
            .filter_map(|s| s.violation_excess)
            .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))));
        let checked: Vec<f64> =
            accepted.iter().filter(|s| !s.degraded).filter_map(|s| s.violation_excess).collect();
        let mean_violation_excess = if checked.is_empty() {
            None
        } else {
            Some(checked.iter().sum::<f64>() / checked.len() as f64)
        };
        let degraded_steps = self.steps.iter().filter(|s| s.degraded).count();
        let max_degraded_devices =
            self.steps.iter().map(|s| s.degraded_devices).max().unwrap_or(0);
        let violations_while_degraded = self
            .steps
            .iter()
            .filter(|s| s.degraded && s.violation_excess.map_or(false, |v| v > 0.0))
            .count();
        let (mean_ttr, max_ttr) = if self.recoveries.is_empty() {
            (None, None)
        } else {
            let sum: f64 = self.recoveries.iter().sum();
            let max = self.recoveries.iter().cloned().fold(0.0, f64::max);
            (Some(sum / self.recoveries.len() as f64), Some(max))
        };
        // Energy premium of local-only fallback: each accepted degraded
        // step pays against the last healthy accepted energy before it.
        let mut fallback_energy_premium_j = 0.0;
        let mut last_healthy: Option<f64> = None;
        for s in &self.steps {
            if !s.accepted {
                continue;
            }
            match (s.degraded, s.energy_j, last_healthy) {
                (false, Some(e), _) => last_healthy = Some(e),
                (true, Some(e), Some(h)) => fallback_energy_premium_j += (e - h).max(0.0),
                _ => {}
            }
        }
        FleetSummary {
            events: self.steps.len(),
            accepted: accepted.len(),
            rejected: self.steps.len() - accepted.len() - absorbed,
            absorbed,
            cache_hits,
            warm_replans,
            cold_solves,
            cache_hit_rate: if lookups == 0 {
                0.0
            } else {
                self.cache.hits as f64 / lookups as f64
            },
            newton_total: self.steps.iter().map(|s| s.newton_iters).sum(),
            mean_energy_j,
            worst_violation_excess,
            mean_violation_excess,
            degraded_steps,
            max_degraded_devices,
            violations_while_degraded,
            recoveries: self.recoveries.len(),
            mean_time_to_recovery_s: mean_ttr,
            max_time_to_recovery_s: max_ttr,
            fallback_energy_premium_j,
        }
    }

    /// Machine-readable encoding: `{"summary": .., "delta_counts": ..,
    /// "cache": .., "steps": [..]}` — byte-identical for identical seeds.
    pub fn to_json(&self) -> Json {
        let s = self.summary();
        let opt = |x: Option<f64>| x.map(Json::Num).unwrap_or(Json::Null);
        let summary = Json::Obj(vec![
            ("events".into(), Json::Num(s.events as f64)),
            ("accepted".into(), Json::Num(s.accepted as f64)),
            ("rejected".into(), Json::Num(s.rejected as f64)),
            ("absorbed".into(), Json::Num(s.absorbed as f64)),
            ("cache_hits".into(), Json::Num(s.cache_hits as f64)),
            ("warm_replans".into(), Json::Num(s.warm_replans as f64)),
            ("cold_solves".into(), Json::Num(s.cold_solves as f64)),
            ("cache_hit_rate".into(), Json::Num(s.cache_hit_rate)),
            ("newton_total".into(), Json::Num(s.newton_total as f64)),
            ("mean_energy_j".into(), Json::Num(s.mean_energy_j)),
            ("worst_violation_excess".into(), opt(s.worst_violation_excess)),
            ("mean_violation_excess".into(), opt(s.mean_violation_excess)),
            ("degraded_steps".into(), Json::Num(s.degraded_steps as f64)),
            ("max_degraded_devices".into(), Json::Num(s.max_degraded_devices as f64)),
            (
                "violations_while_degraded".into(),
                Json::Num(s.violations_while_degraded as f64),
            ),
            ("recoveries".into(), Json::Num(s.recoveries as f64)),
            ("mean_time_to_recovery_s".into(), opt(s.mean_time_to_recovery_s)),
            ("max_time_to_recovery_s".into(), opt(s.max_time_to_recovery_s)),
            ("fallback_energy_premium_j".into(), Json::Num(s.fallback_energy_premium_j)),
        ]);
        let delta_counts = Json::Obj(
            DELTA_KINDS
                .iter()
                .map(|&k| (k.to_string(), Json::Num(self.count_of(k) as f64)))
                .collect(),
        );
        let cache = Json::Obj(vec![
            ("hits".into(), Json::Num(self.cache.hits as f64)),
            ("misses".into(), Json::Num(self.cache.misses as f64)),
            ("len".into(), Json::Num(self.cache.len as f64)),
            ("capacity".into(), Json::Num(self.cache.capacity as f64)),
        ]);
        let steps = Json::Arr(
            self.steps
                .iter()
                .map(|st| {
                    Json::Obj(vec![
                        ("t_s".into(), Json::Num(st.t_s)),
                        ("kind".into(), Json::Str(st.kind.into())),
                        ("n".into(), Json::Num(st.n as f64)),
                        ("accepted".into(), Json::Bool(st.accepted)),
                        ("absorbed".into(), Json::Bool(st.absorbed)),
                        ("cache_hit".into(), Json::Bool(st.cache_hit)),
                        ("warm_started".into(), Json::Bool(st.warm_started)),
                        ("energy_j".into(), opt(st.energy_j)),
                        ("newton_iters".into(), Json::Num(st.newton_iters as f64)),
                        ("outer_iters".into(), Json::Num(st.outer_iters as f64)),
                        ("violation_excess".into(), opt(st.violation_excess)),
                        ("degraded".into(), Json::Bool(st.degraded)),
                        ("degraded_devices".into(), Json::Num(st.degraded_devices as f64)),
                    ])
                })
                .collect(),
        );
        Json::Obj(vec![
            ("summary".into(), summary),
            ("delta_counts".into(), delta_counts),
            ("cache".into(), cache),
            ("steps".into(), steps),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(kind: &'static str, accepted: bool, cache_hit: bool, warm: bool) -> StepRecord {
        StepRecord {
            t_s: 1.0,
            kind,
            n: 3,
            accepted,
            absorbed: false,
            cache_hit,
            warm_started: warm,
            energy_j: accepted.then_some(2.0),
            newton_iters: if accepted && !cache_hit { 10 } else { 0 },
            outer_iters: 1,
            violation_excess: accepted.then_some(-0.03),
            degraded: false,
            degraded_devices: 0,
        }
    }

    #[test]
    fn summary_partitions_served_paths() {
        let mut m = FleetMetrics::new();
        m.record(step(INITIAL_KIND, true, false, false)); // cold
        m.record(step("channel", true, true, false)); // cache hit
        m.record(step("join", true, false, true)); // warm replan
        // A cached outcome originally produced by a warm replan still
        // carries warm_started: it must classify as a cache hit, not both.
        m.record(step("channel", true, true, true));
        m.record(step("leave", false, false, false)); // rejected
        // Absorbed environmental event: old plan now violates (+0.02),
        // but the guarantee metric only aggregates accepted steps.
        m.record(StepRecord {
            absorbed: true,
            energy_j: Some(3.0),
            violation_excess: Some(0.02),
            ..step("channel", false, false, false)
        });
        m.set_cache_stats(CacheStats { hits: 1, misses: 3, len: 2, capacity: 32 });
        let s = m.summary();
        assert_eq!((s.events, s.accepted, s.rejected, s.absorbed), (6, 4, 1, 1));
        assert_eq!((s.cache_hits, s.warm_replans, s.cold_solves), (2, 1, 1));
        assert_eq!(s.newton_total, 20);
        assert!((s.cache_hit_rate - 0.25).abs() < 1e-12);
        // mean energy and worst violation are over accepted steps only
        assert!((s.mean_energy_j - 2.0).abs() < 1e-12);
        assert_eq!(s.worst_violation_excess, Some(-0.03));
        assert_eq!(s.mean_violation_excess, Some(-0.03));
        assert_eq!(m.count_of("join"), 1);
        assert_eq!(m.count_of("bandwidth"), 0);
    }

    #[test]
    fn degraded_accounting_is_separate_from_the_guarantee_metrics() {
        let mut m = FleetMetrics::new();
        // Healthy baseline at 2.0 J with a clean violation record.
        m.record(step(INITIAL_KIND, true, false, false));
        // Outage: two accepted degraded fallback steps at 5.0 J, one of
        // which violates its (unpromised) probabilistic deadline.
        m.record(StepRecord {
            degraded: true,
            degraded_devices: 3,
            energy_j: Some(5.0),
            violation_excess: Some(0.04),
            ..step("edge-down", true, false, false)
        });
        m.record(StepRecord {
            degraded: true,
            degraded_devices: 2,
            energy_j: Some(5.0),
            violation_excess: Some(-0.01),
            ..step("reoffload", true, false, true)
        });
        // An in-flight drop records a rejected-shaped step.
        m.record(StepRecord { energy_j: None, ..step("drop", false, false, false) });
        m.record_recovery(0.5);
        m.record_recovery(1.5);

        let s = m.summary();
        assert_eq!(s.degraded_steps, 2);
        assert_eq!(s.max_degraded_devices, 3);
        assert_eq!(s.violations_while_degraded, 1);
        assert_eq!(s.recoveries, 2);
        assert_eq!(s.mean_time_to_recovery_s, Some(1.0));
        assert_eq!(s.max_time_to_recovery_s, Some(1.5));
        // Premium: two degraded steps at 5.0 J over the 2.0 J baseline.
        assert!((s.fallback_energy_premium_j - 6.0).abs() < 1e-12);
        // The guarantee metrics never see the degraded +0.04 excess.
        assert_eq!(s.worst_violation_excess, Some(-0.03));
        assert_eq!(m.count_of("drop"), 1);
        assert!(FAULT_KINDS.iter().all(|k| DELTA_KINDS.contains(k)));

        // And all of it lands in the JSON export.
        let back = Json::parse(&m.to_json().to_string_pretty()).unwrap();
        let sum = back.get("summary").unwrap();
        assert_eq!(sum.get("degraded_steps").unwrap().as_usize().unwrap(), 2);
        assert_eq!(sum.get("recoveries").unwrap().as_usize().unwrap(), 2);
        assert!((sum.get("fallback_energy_premium_j").unwrap().as_f64().unwrap() - 6.0).abs()
            < 1e-12);
        let steps = back.get("steps").unwrap().as_arr().unwrap();
        assert!(steps[1].get("degraded").unwrap().as_bool().unwrap());
        assert_eq!(steps[1].get("degraded_devices").unwrap().as_usize().unwrap(), 3);
    }

    #[test]
    fn json_is_parseable_and_null_encodes_disabled_checks() {
        let mut m = FleetMetrics::new();
        let mut st = step("risk", false, false, false);
        st.violation_excess = None;
        st.energy_j = None;
        m.record(st);
        let j = m.to_json();
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        let steps = back.get("steps").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].get("violation_excess").unwrap(), &Json::Null);
        assert_eq!(steps[0].get("energy_j").unwrap(), &Json::Null);
        assert_eq!(
            back.get("summary").unwrap().get("worst_violation_excess").unwrap(),
            &Json::Null
        );
        let counts = back.get("delta_counts").unwrap();
        assert_eq!(counts.get("risk").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn empty_metrics_summarize_to_zeroes() {
        let s = FleetMetrics::new().summary();
        assert_eq!(s.events, 0);
        assert_eq!(s.cache_hit_rate, 0.0);
        assert_eq!(s.mean_energy_j, 0.0);
        assert!(s.worst_violation_excess.is_none());
        assert_eq!((s.degraded_steps, s.recoveries, s.violations_while_degraded), (0, 0, 0));
        assert!(s.mean_time_to_recovery_s.is_none());
        assert_eq!(s.fallback_energy_premium_j, 0.0);
    }
}
