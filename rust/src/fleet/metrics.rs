//! Time-series metrics for the fleet simulator.
//!
//! Accumulates one [`StepRecord`] per popped event and exports the whole
//! run — per-step series plus aggregate summary — through
//! [`crate::util::json::Json`].  **Every exported field is a
//! deterministic function of the fleet seed**: wall-clock durations are
//! deliberately excluded so that same-seed runs produce byte-identical
//! JSON at any `util::par` thread count (the determinism contract pinned
//! by `rust/tests/fleet.rs`).

use crate::engine::CacheStats;
use crate::util::json::Json;

/// The `ScenarioDelta` kinds a fleet run can exercise, in the stable
/// order used by the JSON export's `delta_counts` object
/// (`"recalibrate"` only fires on runs configured with a calibrated
/// risk bound).
pub const DELTA_KINDS: [&str; 7] =
    ["join", "leave", "deadline", "risk", "channel", "bandwidth", "recalibrate"];

/// Tag for the driver's one cold bootstrap solve (not a delta).
pub const INITIAL_KIND: &str = "initial";

/// Tag for a conformal risk-bound recalibration step (a fleet-wide
/// `ScenarioDelta::Bound` emitted by the driver's calibration stream).
pub const RECALIBRATE_KIND: &str = "recalibrate";

/// One planner interaction: the outcome of one popped fleet event (or of
/// the initial cold solve).
#[derive(Clone, Debug)]
pub struct StepRecord {
    /// Simulation time of the triggering event, seconds.
    pub t_s: f64,
    /// Delta kind — one of [`DELTA_KINDS`], or [`INITIAL_KIND`] for the
    /// bootstrap solve.
    pub kind: &'static str,
    /// Fleet size after the step (unchanged when rejected).
    pub n: usize,
    /// The planner produced a plan for the changed scenario; `false`
    /// means no new plan exists: the event was rejected (negotiable
    /// request refused) or absorbed (environmental fact adopted with the
    /// old plan kept — see [`StepRecord::absorbed`]).
    pub accepted: bool,
    /// An infeasible *environmental* event (channel fade, uplink-budget
    /// change) that cannot be refused: the scenario rolled forward, the
    /// fleet keeps executing its previous plan, and `violation_excess`
    /// reports what that plan now incurs.  Always `false` when
    /// `accepted`.
    pub absorbed: bool,
    /// Served straight from the plan cache (sub-quantum scenario jitter).
    pub cache_hit: bool,
    /// Produced by the warm incremental replan path.
    pub warm_started: bool,
    /// Planned expected energy after the step, J: the new plan's when
    /// accepted, the old plan re-priced under the new scenario when
    /// absorbed, `None` when rejected.
    pub energy_j: Option<f64>,
    /// Newton iterations this step cost (0 for cache hits / rejections).
    pub newton_iters: usize,
    /// Outer (refinement / alternation) iterations this step cost.
    pub outer_iters: usize,
    /// Monte-Carlo check: max over devices of (empirical violation
    /// probability − ε_n).  ≤ 0 means every device met its risk level;
    /// `None` when the check is disabled or the event was rejected.  On
    /// absorbed steps this measures the *old* plan against the *new*
    /// environment and may legitimately exceed 0.
    pub violation_excess: Option<f64>,
}

/// Aggregates over one run; all fields deterministic per seed.
#[derive(Clone, Debug)]
pub struct FleetSummary {
    /// Planner interactions recorded (including the bootstrap solve).
    pub events: usize,
    /// Steps that produced a plan.
    pub accepted: usize,
    /// Negotiable events refused for infeasibility.
    pub rejected: usize,
    /// Environmental events adopted without a new plan (old plan kept).
    pub absorbed: usize,
    /// Accepted steps served from the plan cache.
    pub cache_hits: usize,
    /// Accepted steps served by the warm incremental replan path.
    pub warm_replans: usize,
    /// Accepted steps that needed a cold solve (bootstrap + feasibility
    /// fallbacks inside `replan`).
    pub cold_solves: usize,
    /// Planner-cache hit rate over all lookups (hits / (hits + misses)).
    pub cache_hit_rate: f64,
    /// Total Newton iterations across the run.
    pub newton_total: usize,
    /// Mean planned energy over accepted steps, J (0 if none).
    pub mean_energy_j: f64,
    /// Worst Monte-Carlo violation excess over *accepted* steps — the
    /// probabilistic-guarantee metric (`None` if never checked).
    /// Absorbed steps are excluded: their old-plan-vs-new-environment
    /// excess is reported per step, not against the guarantee.
    pub worst_violation_excess: Option<f64>,
    /// Mean Monte-Carlo violation excess over the checked accepted
    /// steps — read next to the configured bound, this is the
    /// empirical-violation-vs-ε record that lets runs under different
    /// bounds (or different conformal scales) be compared directly.
    pub mean_violation_excess: Option<f64>,
}

/// Accumulator for a fleet run's records plus the planner's final cache
/// counters.
#[derive(Clone, Debug, Default)]
pub struct FleetMetrics {
    steps: Vec<StepRecord>,
    cache: CacheStats,
}

impl FleetMetrics {
    /// An empty accumulator.
    pub fn new() -> FleetMetrics {
        FleetMetrics::default()
    }

    /// Append one step record.
    pub fn record(&mut self, step: StepRecord) {
        self.steps.push(step);
    }

    /// Snapshot the planner's cache counters (called once at run end).
    pub fn set_cache_stats(&mut self, stats: CacheStats) {
        self.cache = stats;
    }

    /// All recorded steps in event order.
    pub fn steps(&self) -> &[StepRecord] {
        &self.steps
    }

    /// The planner's cache counters at run end.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache
    }

    /// How many recorded steps carry `kind` (accepted or not).
    pub fn count_of(&self, kind: &str) -> usize {
        self.steps.iter().filter(|s| s.kind == kind).count()
    }

    /// Aggregate the recorded series.
    ///
    /// Served-path classification is priority-ordered: a step is a cache
    /// hit first (even if the *cached* outcome was originally produced by
    /// a warm replan and still carries `warm_started`), a warm replan
    /// second, and a cold solve otherwise — so the three counts always
    /// partition the accepted steps.
    pub fn summary(&self) -> FleetSummary {
        let accepted: Vec<&StepRecord> = self.steps.iter().filter(|s| s.accepted).collect();
        let absorbed = self.steps.iter().filter(|s| s.absorbed).count();
        let cache_hits = accepted.iter().filter(|s| s.cache_hit).count();
        let warm_replans = accepted.iter().filter(|s| !s.cache_hit && s.warm_started).count();
        let cold_solves = accepted.len() - cache_hits - warm_replans;
        let lookups = self.cache.hits + self.cache.misses;
        let energies: Vec<f64> = accepted.iter().filter_map(|s| s.energy_j).collect();
        let mean_energy_j = if energies.is_empty() {
            0.0
        } else {
            energies.iter().sum::<f64>() / energies.len() as f64
        };
        let worst_violation_excess = accepted
            .iter()
            .filter_map(|s| s.violation_excess)
            .fold(None, |acc: Option<f64>, v| Some(acc.map_or(v, |a| a.max(v))));
        let checked: Vec<f64> = accepted.iter().filter_map(|s| s.violation_excess).collect();
        let mean_violation_excess = if checked.is_empty() {
            None
        } else {
            Some(checked.iter().sum::<f64>() / checked.len() as f64)
        };
        FleetSummary {
            events: self.steps.len(),
            accepted: accepted.len(),
            rejected: self.steps.len() - accepted.len() - absorbed,
            absorbed,
            cache_hits,
            warm_replans,
            cold_solves,
            cache_hit_rate: if lookups == 0 {
                0.0
            } else {
                self.cache.hits as f64 / lookups as f64
            },
            newton_total: self.steps.iter().map(|s| s.newton_iters).sum(),
            mean_energy_j,
            worst_violation_excess,
            mean_violation_excess,
        }
    }

    /// Machine-readable encoding: `{"summary": .., "delta_counts": ..,
    /// "cache": .., "steps": [..]}` — byte-identical for identical seeds.
    pub fn to_json(&self) -> Json {
        let s = self.summary();
        let opt = |x: Option<f64>| x.map(Json::Num).unwrap_or(Json::Null);
        let summary = Json::Obj(vec![
            ("events".into(), Json::Num(s.events as f64)),
            ("accepted".into(), Json::Num(s.accepted as f64)),
            ("rejected".into(), Json::Num(s.rejected as f64)),
            ("absorbed".into(), Json::Num(s.absorbed as f64)),
            ("cache_hits".into(), Json::Num(s.cache_hits as f64)),
            ("warm_replans".into(), Json::Num(s.warm_replans as f64)),
            ("cold_solves".into(), Json::Num(s.cold_solves as f64)),
            ("cache_hit_rate".into(), Json::Num(s.cache_hit_rate)),
            ("newton_total".into(), Json::Num(s.newton_total as f64)),
            ("mean_energy_j".into(), Json::Num(s.mean_energy_j)),
            ("worst_violation_excess".into(), opt(s.worst_violation_excess)),
            ("mean_violation_excess".into(), opt(s.mean_violation_excess)),
        ]);
        let delta_counts = Json::Obj(
            DELTA_KINDS
                .iter()
                .map(|&k| (k.to_string(), Json::Num(self.count_of(k) as f64)))
                .collect(),
        );
        let cache = Json::Obj(vec![
            ("hits".into(), Json::Num(self.cache.hits as f64)),
            ("misses".into(), Json::Num(self.cache.misses as f64)),
            ("len".into(), Json::Num(self.cache.len as f64)),
            ("capacity".into(), Json::Num(self.cache.capacity as f64)),
        ]);
        let steps = Json::Arr(
            self.steps
                .iter()
                .map(|st| {
                    Json::Obj(vec![
                        ("t_s".into(), Json::Num(st.t_s)),
                        ("kind".into(), Json::Str(st.kind.into())),
                        ("n".into(), Json::Num(st.n as f64)),
                        ("accepted".into(), Json::Bool(st.accepted)),
                        ("absorbed".into(), Json::Bool(st.absorbed)),
                        ("cache_hit".into(), Json::Bool(st.cache_hit)),
                        ("warm_started".into(), Json::Bool(st.warm_started)),
                        ("energy_j".into(), opt(st.energy_j)),
                        ("newton_iters".into(), Json::Num(st.newton_iters as f64)),
                        ("outer_iters".into(), Json::Num(st.outer_iters as f64)),
                        ("violation_excess".into(), opt(st.violation_excess)),
                    ])
                })
                .collect(),
        );
        Json::Obj(vec![
            ("summary".into(), summary),
            ("delta_counts".into(), delta_counts),
            ("cache".into(), cache),
            ("steps".into(), steps),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn step(kind: &'static str, accepted: bool, cache_hit: bool, warm: bool) -> StepRecord {
        StepRecord {
            t_s: 1.0,
            kind,
            n: 3,
            accepted,
            absorbed: false,
            cache_hit,
            warm_started: warm,
            energy_j: accepted.then_some(2.0),
            newton_iters: if accepted && !cache_hit { 10 } else { 0 },
            outer_iters: 1,
            violation_excess: accepted.then_some(-0.03),
        }
    }

    #[test]
    fn summary_partitions_served_paths() {
        let mut m = FleetMetrics::new();
        m.record(step(INITIAL_KIND, true, false, false)); // cold
        m.record(step("channel", true, true, false)); // cache hit
        m.record(step("join", true, false, true)); // warm replan
        // A cached outcome originally produced by a warm replan still
        // carries warm_started: it must classify as a cache hit, not both.
        m.record(step("channel", true, true, true));
        m.record(step("leave", false, false, false)); // rejected
        // Absorbed environmental event: old plan now violates (+0.02),
        // but the guarantee metric only aggregates accepted steps.
        m.record(StepRecord {
            absorbed: true,
            energy_j: Some(3.0),
            violation_excess: Some(0.02),
            ..step("channel", false, false, false)
        });
        m.set_cache_stats(CacheStats { hits: 1, misses: 3, len: 2, capacity: 32 });
        let s = m.summary();
        assert_eq!((s.events, s.accepted, s.rejected, s.absorbed), (6, 4, 1, 1));
        assert_eq!((s.cache_hits, s.warm_replans, s.cold_solves), (2, 1, 1));
        assert_eq!(s.newton_total, 20);
        assert!((s.cache_hit_rate - 0.25).abs() < 1e-12);
        // mean energy and worst violation are over accepted steps only
        assert!((s.mean_energy_j - 2.0).abs() < 1e-12);
        assert_eq!(s.worst_violation_excess, Some(-0.03));
        assert_eq!(s.mean_violation_excess, Some(-0.03));
        assert_eq!(m.count_of("join"), 1);
        assert_eq!(m.count_of("bandwidth"), 0);
    }

    #[test]
    fn json_is_parseable_and_null_encodes_disabled_checks() {
        let mut m = FleetMetrics::new();
        let mut st = step("risk", false, false, false);
        st.violation_excess = None;
        st.energy_j = None;
        m.record(st);
        let j = m.to_json();
        let text = j.to_string_pretty();
        let back = Json::parse(&text).unwrap();
        let steps = back.get("steps").unwrap().as_arr().unwrap();
        assert_eq!(steps.len(), 1);
        assert_eq!(steps[0].get("violation_excess").unwrap(), &Json::Null);
        assert_eq!(steps[0].get("energy_j").unwrap(), &Json::Null);
        assert_eq!(
            back.get("summary").unwrap().get("worst_violation_excess").unwrap(),
            &Json::Null
        );
        let counts = back.get("delta_counts").unwrap();
        assert_eq!(counts.get("risk").unwrap().as_usize().unwrap(), 1);
    }

    #[test]
    fn empty_metrics_summarize_to_zeroes() {
        let s = FleetMetrics::new().summary();
        assert_eq!(s.events, 0);
        assert_eq!(s.cache_hit_rate, 0.0);
        assert_eq!(s.mean_energy_j, 0.0);
        assert!(s.worst_violation_excess.is_none());
    }
}
