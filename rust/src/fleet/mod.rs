//! Discrete-event fleet simulator: sustained churn for the planning
//! engine.
//!
//! The paper's premise is that inference time and the wireless
//! environment are *uncertain and time-varying*, but a single
//! [`crate::engine::Planner::plan`] call only ever sees a static
//! snapshot.  This module closes that gap: it feeds one long-lived
//! planner a **seeded, reproducible stream of scenario changes** —
//! Poisson device arrivals and departures, per-device Gauss–Markov
//! channel fading, deadline/risk renegotiations, uplink-budget changes —
//! and measures how the engine's incremental machinery (plan cache, warm
//! replans, cold feasibility fallbacks) behaves over time, validating
//! every accepted plan against the Monte-Carlo uncertainty simulator.
//! With `--faults` the stream additionally carries a seeded
//! [`crate::fault`] schedule — edge outages (all-local degradation +
//! backoff-paced recovery), uplink blackouts, and delta delivery
//! faults — without disturbing the fault-free trace.
//!
//! Layout:
//!
//! * [`events`] — the deterministic binary-heap event queue and the
//!   event vocabulary;
//! * [`driver`] — maps events to [`crate::engine::ScenarioDelta`]s,
//!   drives [`crate::engine::Planner::replan`] (cache probe first, cold
//!   fallback last), refuses infeasible *negotiable* events (admission
//!   control) and absorbs infeasible *environmental* ones via
//!   [`crate::engine::Planner::rebase`];
//! * [`metrics`] — the per-step time series and aggregate summary, with
//!   deterministic JSON export (same seed ⇒ byte-identical output at
//!   any thread count).
//!
//! Entry points: [`run`] / [`FleetOptions`] from Rust, `ripra simulate`
//! from the CLI, `benches/fleet_churn.rs` for the perf trajectory, and
//! `examples/fleet_churn.rs` for a narrated walkthrough.
//!
//! The same event vocabulary also drives the serving stack over a real
//! socket: [`loadgen`] converts a seeded churn mix into
//! [`crate::service::wire`] traffic for `ripra serve --listen`
//! (byte-identical per seed — the replay contract EXPERIMENTS.md
//! §Serving specifies).

pub mod driver;
pub mod events;
pub mod loadgen;
pub mod metrics;

pub use driver::{run, FleetOptions, FleetReport};
pub use loadgen::{LoadGenOptions, LoadGenReport};
pub use events::{EventQueue, FleetEvent};
pub use metrics::{
    FleetMetrics, FleetSummary, StepRecord, DELTA_KINDS, FAULT_KINDS, INITIAL_KIND,
    RECALIBRATE_KIND,
};
