//! LRU plan cache keyed by scenario fingerprints.
//!
//! Capacities are small (a planner serves one coordinator; distinct
//! scenario fingerprints number in the tens), so the cache is a recency
//! ordered `Vec` — linear probes beat a hash map + separate recency list
//! at this size and keep the engine dependency-free.

use super::outcome::PlanOutcome;

/// Hit/miss counters plus occupancy, exposed by
/// [`super::Planner::cache_stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct CacheStats {
    /// Probes answered from the cache.
    pub hits: u64,
    /// Probes that found nothing.
    pub misses: u64,
    /// Entries currently stored.
    pub len: usize,
    /// Maximum entries (0 = caching disabled).
    pub capacity: usize,
}

impl CacheStats {
    /// Fold another cache's counters into this one — how the service
    /// layer aggregates its per-shard planner caches into the single
    /// fleet-level cache block of the metrics JSON.
    pub fn absorb(&mut self, other: &CacheStats) {
        self.hits += other.hits;
        self.misses += other.misses;
        self.len += other.len;
        self.capacity += other.capacity;
    }
}

/// Bounded LRU store: most-recently-used entry last.
pub(crate) struct PlanCache {
    capacity: usize,
    entries: Vec<(u64, PlanOutcome)>,
    hits: u64,
    misses: u64,
}

impl PlanCache {
    /// `capacity = 0` disables caching entirely.
    pub fn new(capacity: usize) -> PlanCache {
        PlanCache { capacity, entries: Vec::new(), hits: 0, misses: 0 }
    }

    pub fn get(&mut self, key: u64) -> Option<PlanOutcome> {
        match self.entries.iter().position(|(k, _)| *k == key) {
            Some(i) => {
                self.hits += 1;
                // refresh recency: move to the back
                let entry = self.entries.remove(i);
                let out = entry.1.clone();
                self.entries.push(entry);
                Some(out)
            }
            None => {
                self.misses += 1;
                None
            }
        }
    }

    pub fn insert(&mut self, key: u64, outcome: PlanOutcome) {
        if self.capacity == 0 {
            return;
        }
        if let Some(i) = self.entries.iter().position(|(k, _)| *k == key) {
            self.entries.remove(i);
        }
        self.entries.push((key, outcome));
        if self.entries.len() > self.capacity {
            self.entries.remove(0); // least-recently-used
        }
    }

    pub fn clear(&mut self) {
        self.entries.clear();
    }

    pub fn stats(&self) -> CacheStats {
        CacheStats {
            hits: self.hits,
            misses: self.misses,
            len: self.entries.len(),
            capacity: self.capacity,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::super::outcome::Diagnostics;
    use super::super::request::Policy;
    use super::*;
    use crate::optim::types::Plan;

    fn outcome(energy: f64) -> PlanOutcome {
        PlanOutcome {
            plan: Plan { partition: vec![1], bandwidth_hz: vec![1e6], freq_ghz: vec![1.0] },
            energy,
            policy: Policy::Robust,
            bound: crate::risk::RiskBound::Ecr,
            diagnostics: Diagnostics::default(),
        }
    }

    #[test]
    fn hit_miss_and_counters() {
        let mut c = PlanCache::new(4);
        assert!(c.get(1).is_none());
        c.insert(1, outcome(1.0));
        assert_eq!(c.get(1).unwrap().energy, 1.0);
        let s = c.stats();
        assert_eq!((s.hits, s.misses, s.len, s.capacity), (1, 1, 1, 4));
    }

    #[test]
    fn evicts_least_recently_used() {
        let mut c = PlanCache::new(2);
        c.insert(1, outcome(1.0));
        c.insert(2, outcome(2.0));
        assert!(c.get(1).is_some()); // 1 is now the most recent
        c.insert(3, outcome(3.0)); // evicts 2
        assert!(c.get(2).is_none());
        assert!(c.get(1).is_some());
        assert!(c.get(3).is_some());
        assert_eq!(c.stats().len, 2);
    }

    #[test]
    fn reinsert_replaces_without_growing() {
        let mut c = PlanCache::new(2);
        c.insert(1, outcome(1.0));
        c.insert(1, outcome(9.0));
        assert_eq!(c.stats().len, 1);
        assert_eq!(c.get(1).unwrap().energy, 9.0);
    }

    #[test]
    fn zero_capacity_disables() {
        let mut c = PlanCache::new(0);
        c.insert(1, outcome(1.0));
        assert!(c.get(1).is_none());
        assert_eq!(c.stats().len, 0);
    }

    #[test]
    fn stats_absorb_sums_every_counter() {
        let mut a = CacheStats { hits: 1, misses: 2, len: 3, capacity: 4 };
        a.absorb(&CacheStats { hits: 10, misses: 20, len: 30, capacity: 40 });
        assert_eq!(a, CacheStats { hits: 11, misses: 22, len: 33, capacity: 44 });
    }

    #[test]
    fn clear_keeps_counters() {
        let mut c = PlanCache::new(2);
        c.insert(1, outcome(1.0));
        c.get(1);
        c.clear();
        assert_eq!(c.stats().len, 0);
        assert_eq!(c.stats().hits, 1);
    }
}
