//! Planning requests: the policy enum, the [`PlanRequest`] the engine's
//! single entrypoint consumes, the scenario fingerprint that keys the
//! plan cache, and the [`ScenarioDelta`]s incremental replanning accepts.

use crate::channel::Uplink;
use crate::optim::types::{Device, Scenario};
use crate::risk::RiskBound;

use super::outcome::PlanError;

/// Planning policy — the engine's single dispatch axis, covering the
/// paper's proposal and every §VI benchmark.
#[derive(Clone, Debug, PartialEq)]
pub enum Policy {
    /// Algorithm 2 (CCP/ECR + interior point + PCCP) — the paper's
    /// proposal.
    Robust,
    /// Benchmark 1: upper-bound inference times, no violation tolerated.
    WorstCase,
    /// Benchmark 3: ignore uncertainty entirely (margin 0).
    MeanOnly,
    /// Exhaustive (M+1)^N search with a resource solve per assignment —
    /// only viable for tiny N.
    Exhaustive,
    /// Algorithm 2 from several structurally different initial
    /// partitions, keeping the best plan; `extra_starts` adds
    /// caller-provided initial partitions to the built-in ones.
    Multistart { extra_starts: Vec<Vec<usize>> },
}

impl Policy {
    /// Stable lowercase name (CLI / JSON encoding).
    pub fn name(&self) -> &'static str {
        match self {
            Policy::Robust => "robust",
            Policy::WorstCase => "worst-case",
            Policy::MeanOnly => "mean-only",
            Policy::Exhaustive => "exhaustive",
            Policy::Multistart { .. } => "multistart",
        }
    }

    /// Parse a CLI spelling (accepts the legacy short names).
    pub fn parse(s: &str) -> Option<Policy> {
        match s {
            "robust" => Some(Policy::Robust),
            "worst" | "worst-case" | "worstcase" => Some(Policy::WorstCase),
            "mean" | "mean-only" | "meanonly" => Some(Policy::MeanOnly),
            "exhaustive" | "optimal" => Some(Policy::Exhaustive),
            "multistart" => Some(Policy::Multistart { extra_starts: Vec::new() }),
            _ => None,
        }
    }

    /// The deadline-margin policy this planning policy evaluates
    /// constraints under: the robust family (Robust / Multistart /
    /// Exhaustive) applies the request's risk bound, the baselines keep
    /// their own fixed margins.
    pub fn margin_policy(&self, bound: RiskBound) -> crate::optim::Policy {
        match self {
            Policy::WorstCase => crate::optim::Policy::WorstCase,
            Policy::MeanOnly => crate::optim::Policy::MeanOnly,
            _ => crate::optim::Policy::Robust(bound),
        }
    }

    fn tag(&self) -> u8 {
        match self {
            Policy::Robust => 0,
            Policy::WorstCase => 1,
            Policy::MeanOnly => 2,
            Policy::Exhaustive => 3,
            Policy::Multistart { .. } => 4,
        }
    }
}

/// One CLI flag binding for a [`PlanRequest`] field; `main.rs` derives
/// the `ripra plan` usage text and its flag parser from
/// [`PlanRequest::CLI_FLAGS`] so the CLI can never drift from the API.
#[derive(Clone, Copy, Debug)]
pub struct CliFlag {
    /// Flag name without the leading `--`.
    pub name: &'static str,
    /// Value placeholder; `None` marks a boolean flag.
    pub value: Option<&'static str>,
    /// One-line usage text.
    pub help: &'static str,
}

/// A planning request: scenario + policy × bound (+ optional overrides).
#[derive(Clone, Debug)]
pub struct PlanRequest {
    /// The multi-device problem instance to solve.
    pub scenario: Scenario,
    /// Planning policy (robust / baselines / search variants).
    pub policy: Policy,
    /// Chance-constraint transform for the robust policy family
    /// (default [`RiskBound::Ecr`], the paper's Theorem 1 — back-compat
    /// with every pre-refactor request).  Part of the cache fingerprint,
    /// so plans never leak across bounds.
    pub bound: RiskBound,
    /// Initial partition override for the alternation (Fig. 10 sweeps
    /// this); `None` uses the feasibility-friendly heuristic start.
    pub init_partition: Option<Vec<usize>>,
    /// Consult/populate the planner's LRU cache (default true; timing
    /// harnesses turn it off).
    pub use_cache: bool,
}

impl PlanRequest {
    /// Flags the `ripra plan` subcommand exposes (scenario fields first,
    /// then output controls).
    pub const CLI_FLAGS: &[CliFlag] = &[
        CliFlag { name: "model", value: Some("alexnet|resnet152"), help: "DNN/hardware profile" },
        CliFlag { name: "n", value: Some("N"), help: "number of devices (default 12)" },
        CliFlag { name: "bandwidth", value: Some("HZ"), help: "total uplink bandwidth" },
        CliFlag { name: "deadline", value: Some("S"), help: "per-task deadline, seconds" },
        CliFlag { name: "risk", value: Some("E"), help: "tolerated violation probability" },
        CliFlag {
            name: "policy",
            value: Some("robust|worst|mean|exhaustive|multistart"),
            help: "planning policy (default robust)",
        },
        CliFlag {
            name: "bound",
            value: Some("ecr|gauss|bernstein|calibrated[:S]"),
            help: "chance-constraint transform (default ecr)",
        },
        CliFlag { name: "seed", value: Some("S"), help: "device-placement seed" },
        CliFlag { name: "trials", value: Some("T"), help: "Monte-Carlo trials (0 disables)" },
        CliFlag { name: "no-cache", value: None, help: "bypass the plan cache" },
        CliFlag { name: "json", value: None, help: "emit the PlanOutcome as JSON" },
    ];

    /// A request with the default bound (ECR), no init-partition
    /// override, and caching on.
    pub fn new(scenario: Scenario, policy: Policy) -> PlanRequest {
        PlanRequest {
            scenario,
            policy,
            bound: RiskBound::Ecr,
            init_partition: None,
            use_cache: true,
        }
    }

    /// Select the chance-constraint transform for the robust family.
    pub fn with_bound(mut self, bound: RiskBound) -> PlanRequest {
        self.bound = bound;
        self
    }

    /// Override the initial partition.
    pub fn with_init(mut self, init: Vec<usize>) -> PlanRequest {
        self.init_partition = Some(init);
        self
    }

    /// Bypass the plan cache for this request.
    pub fn without_cache(mut self) -> PlanRequest {
        self.use_cache = false;
        self
    }

    pub(crate) fn validate(&self) -> Result<(), PlanError> {
        if self.scenario.n() == 0 {
            return Err(PlanError::InvalidRequest("scenario has no devices".into()));
        }
        // QoS parameters are validated here, at the API boundary, so the
        // margin transforms deep inside the solvers are total (the
        // historical failure mode was an assert! panic in ecr::sigma).
        // A failure is classified by *which* parameter is bad, so a bad
        // ε always surfaces as the structured InvalidRisk.
        for (i, d) in self.scenario.devices.iter().enumerate() {
            if let Err(e) = d.validate() {
                return Err(if crate::risk::validate_risk(d.risk).is_err() {
                    PlanError::InvalidRisk(format!("device {i}: {e}"))
                } else {
                    PlanError::InvalidRequest(format!("device {i}: {e}"))
                });
            }
        }
        if self.policy == Policy::Exhaustive {
            // Mirror the search's own refusal limit so an oversized
            // request is a clean error, not a downstream panic (and
            // checked_mul guards the (M+1)^N product against overflow).
            let mut total = 1usize;
            for d in &self.scenario.devices {
                total = total
                    .checked_mul(d.model.num_points())
                    .filter(|&t| t <= EXHAUSTIVE_LIMIT)
                    .ok_or_else(|| {
                        PlanError::InvalidRequest(format!(
                            "exhaustive search over (M+1)^N assignments exceeds {EXHAUSTIVE_LIMIT}; \
                             use Policy::Multistart for this N"
                        ))
                    })?;
            }
        }
        if let Some(init) = &self.init_partition {
            if init.len() != self.scenario.n() {
                return Err(PlanError::InvalidRequest(format!(
                    "init partition has {} entries for {} devices",
                    init.len(),
                    self.scenario.n()
                )));
            }
            for (i, (&m, d)) in init.iter().zip(&self.scenario.devices).enumerate() {
                if m >= d.model.num_points() {
                    return Err(PlanError::InvalidRequest(format!(
                        "init partition point {m} out of range for device {i}"
                    )));
                }
            }
        }
        Ok(())
    }

    /// Cache key: policy + bound + init + quantized scenario fingerprint.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.u8(self.policy.tag());
        // The bound (and, for the calibrated bound, its quantized scale)
        // keys the cache too: a cached plan must never be served across
        // bounds, whose margins differ.
        h.u8(self.bound.tag());
        h.usize(self.bound.scale_q() as usize);
        if let Policy::Multistart { extra_starts } = &self.policy {
            h.usize(extra_starts.len());
            for s in extra_starts {
                h.usize(s.len());
                for &m in s {
                    h.usize(m);
                }
            }
        }
        match &self.init_partition {
            None => h.u8(0),
            Some(init) => {
                h.u8(1);
                for &m in init {
                    h.usize(m);
                }
            }
        }
        hash_scenario(&mut h, &self.scenario);
        h.finish()
    }
}

/// Assignment-count cap for [`Policy::Exhaustive`] (the same refusal
/// limit the search itself enforces).
const EXHAUSTIVE_LIMIT: usize = 1_000_000;

/// Quantization grid for the scenario fingerprint: two scenarios whose
/// parameters agree to within these quanta hash identically, so channel
/// jitter below the planner's own sensitivity reuses cached plans.
mod quanta {
    /// Total/per-device bandwidth, Hz.
    pub const BANDWIDTH_HZ: f64 = 1e3;
    /// Deadlines, seconds (0.1 ms).
    pub const DEADLINE_S: f64 = 1e-4;
    /// Risk level ε.
    pub const RISK: f64 = 1e-4;
    /// Channel gain, dB (0.1 dB steps on the path-loss scale).
    pub const GAIN_DB: f64 = 0.1;
    /// Transmit power, W.
    pub const POWER_W: f64 = 1e-3;
}

fn hash_scenario(h: &mut Fnv, sc: &Scenario) {
    h.usize(sc.n());
    h.q(sc.total_bandwidth_hz, quanta::BANDWIDTH_HZ);
    for d in &sc.devices {
        hash_device(h, d);
    }
}

fn hash_device(h: &mut Fnv, d: &Device) {
    h.bytes(d.model.name.as_bytes());
    h.q(d.deadline_s, quanta::DEADLINE_S);
    h.q(d.risk, quanta::RISK);
    h.q(10.0 * d.uplink.gain.log10(), quanta::GAIN_DB);
    h.q(d.uplink.p_tx, quanta::POWER_W);
    // noise PSD on the same dB grid as the gain — all three Uplink
    // fields shape the rate, so all three key the cache
    h.q(10.0 * d.uplink.n0.log10(), quanta::GAIN_DB);
}

/// Fingerprint of a bare scenario under a policy and the default ECR
/// bound (what `replan` inserts its warm results under, so a follow-up
/// `plan` for the same scenario hits the cache).
pub fn scenario_fingerprint(sc: &Scenario, policy: &Policy) -> u64 {
    scenario_fingerprint_with(sc, policy, RiskBound::Ecr)
}

/// [`scenario_fingerprint`] under an explicit risk bound.
///
/// Borrow-only: hashes in exactly [`PlanRequest::fingerprint`]'s field
/// order (with no init-partition override) without materializing a
/// request, so the per-event probe/insert paths of the fleet driver and
/// the service shards never clone the scenario just to key the cache.
pub fn scenario_fingerprint_with(sc: &Scenario, policy: &Policy, bound: RiskBound) -> u64 {
    let mut h = Fnv::new();
    h.u8(policy.tag());
    h.u8(bound.tag());
    h.usize(bound.scale_q() as usize);
    if let Policy::Multistart { extra_starts } = policy {
        h.usize(extra_starts.len());
        for s in extra_starts {
            h.usize(s.len());
            for &m in s {
                h.usize(m);
            }
        }
    }
    h.u8(0); // no init-partition override
    hash_scenario(&mut h, sc);
    h.finish()
}

/// Fingerprint of one device on the same quantization grid the plan
/// cache uses (model, deadline ±0.1 ms, risk ±1e-4, channel ±0.1 dB,
/// power ±1 mW).  The service layer keys its device→shard routing on
/// this, so routing inherits the cache's sub-quantum insensitivity and
/// there is exactly one definition of "the same device".
pub fn device_fingerprint(d: &Device) -> u64 {
    let mut h = Fnv::new();
    hash_device(&mut h, d);
    h.finish()
}

/// FNV-1a, 64-bit — tiny, dependency-free, stable across runs.
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn bytes(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
    }

    fn u8(&mut self, v: u8) {
        self.bytes(&[v]);
    }

    fn usize(&mut self, v: usize) {
        self.bytes(&(v as u64).to_le_bytes());
    }

    /// Hash `x` rounded to the nearest multiple of `quantum`.
    fn q(&mut self, x: f64, quantum: f64) {
        let q = (x / quantum).round();
        // Canonicalize -0.0 and keep non-finite values distinct.
        // lint:allow(float-eq): exact ±0.0 canonicalization for the
        // fingerprint — a tolerance here would alias distinct scenarios.
        let bits = if q == 0.0 { 0u64 } else { q.to_bits() };
        self.bytes(&bits.to_le_bytes());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

/// An incremental change to the last-planned scenario, consumed by
/// [`super::Planner::replan`].
#[derive(Clone, Debug)]
pub enum ScenarioDelta {
    /// A new device joins (appended at index N).
    Join(Device),
    /// Device `i` leaves.
    Leave(usize),
    /// Deadline change (one device, or all when `device` is `None`).
    Deadline { device: Option<usize>, deadline_s: f64 },
    /// Risk-level change (one device, or all when `device` is `None`).
    Risk { device: Option<usize>, risk: f64 },
    /// Channel change for one device (e.g. it moved).
    Channel { device: usize, uplink: Uplink },
    /// Total uplink budget change.
    TotalBandwidth(f64),
    /// Fleet-wide risk-bound change (e.g. an online conformal
    /// recalibration).  The bound lives in the planning policy, not the
    /// scenario, so `apply` is the identity on the scenario — the
    /// planner's `replan` swaps the bound on its stored policy and
    /// re-prices under the new margins.
    Bound(RiskBound),
}

impl ScenarioDelta {
    /// Apply the delta to a scenario, validating indices and ranges.
    pub fn apply(&self, sc: &Scenario) -> Result<Scenario, PlanError> {
        let check = |i: usize| -> Result<(), PlanError> {
            if i < sc.n() {
                Ok(())
            } else {
                Err(PlanError::InvalidRequest(format!(
                    "device index {i} out of range (n = {})",
                    sc.n()
                )))
            }
        };
        let mut out = sc.clone();
        match self {
            ScenarioDelta::Join(dev) => out.devices.push(dev.clone()),
            ScenarioDelta::Leave(i) => {
                check(*i)?;
                if sc.n() == 1 {
                    return Err(PlanError::InvalidRequest(
                        "cannot remove the last device".into(),
                    ));
                }
                out.devices.remove(*i);
            }
            ScenarioDelta::Deadline { device, deadline_s } => {
                if !deadline_s.is_finite() || *deadline_s <= 0.0 {
                    return Err(PlanError::InvalidRequest(format!(
                        "deadline must be positive, got {deadline_s}"
                    )));
                }
                match device {
                    Some(i) => {
                        check(*i)?;
                        out.devices[*i].deadline_s = *deadline_s;
                    }
                    None => out.devices.iter_mut().for_each(|d| d.deadline_s = *deadline_s),
                }
            }
            ScenarioDelta::Risk { device, risk } => {
                crate::risk::validate_risk(*risk).map_err(PlanError::InvalidRisk)?;
                match device {
                    Some(i) => {
                        check(*i)?;
                        out.devices[*i].risk = *risk;
                    }
                    None => out.devices.iter_mut().for_each(|d| d.risk = *risk),
                }
            }
            ScenarioDelta::Channel { device, uplink } => {
                check(*device)?;
                out.devices[*device].uplink = *uplink;
            }
            ScenarioDelta::TotalBandwidth(b) => {
                if !b.is_finite() || *b <= 0.0 {
                    return Err(PlanError::InvalidRequest(format!(
                        "bandwidth must be positive, got {b}"
                    )));
                }
                out.total_bandwidth_hz = *b;
            }
            // The bound is planner state, not scenario state.
            ScenarioDelta::Bound(_) => {}
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelProfile;
    use crate::util::rng::Rng;

    fn scenario(seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), 4, 10e6, 0.2, 0.05, &mut rng)
    }

    #[test]
    fn fingerprint_is_deterministic_and_policy_sensitive() {
        let sc = scenario(1);
        let a = PlanRequest::new(sc.clone(), Policy::Robust).fingerprint();
        let b = PlanRequest::new(sc.clone(), Policy::Robust).fingerprint();
        let c = PlanRequest::new(sc.clone(), Policy::MeanOnly).fingerprint();
        let d = PlanRequest::new(sc.clone(), Policy::Robust).with_init(vec![0; 4]).fingerprint();
        assert_eq!(a, b);
        assert_ne!(a, c);
        assert_ne!(a, d);
        // The bound keys the fingerprint: different bounds — and
        // different calibrated scales — never alias, while the default
        // bound is exactly RiskBound::Ecr.
        let ecr = PlanRequest::new(sc.clone(), Policy::Robust)
            .with_bound(RiskBound::Ecr)
            .fingerprint();
        assert_eq!(a, ecr);
        for bound in [RiskBound::Gaussian, RiskBound::Bernstein, RiskBound::calibrated(1.0)] {
            let other =
                PlanRequest::new(sc.clone(), Policy::Robust).with_bound(bound).fingerprint();
            assert_ne!(a, other, "{bound} must not alias ecr");
        }
        let s1 = PlanRequest::new(sc.clone(), Policy::Robust)
            .with_bound(RiskBound::calibrated(0.8))
            .fingerprint();
        let s2 = PlanRequest::new(sc, Policy::Robust)
            .with_bound(RiskBound::calibrated(0.9))
            .fingerprint();
        assert_ne!(s1, s2, "calibrated scales must not alias");
    }

    #[test]
    fn borrowed_fingerprint_matches_request_fingerprint() {
        // The borrow-only helper must key the cache bit-identically to
        // the owning PlanRequest path for every policy × bound shape.
        let sc = scenario(5);
        for bound in [RiskBound::Ecr, RiskBound::Gaussian, RiskBound::calibrated(0.9)] {
            let via_req =
                PlanRequest::new(sc.clone(), Policy::Robust).with_bound(bound).fingerprint();
            assert_eq!(scenario_fingerprint_with(&sc, &Policy::Robust, bound), via_req);
        }
        let ms = Policy::Multistart { extra_starts: vec![vec![1, 2, 0, 3]] };
        let via_req = PlanRequest::new(sc.clone(), ms.clone()).fingerprint();
        assert_eq!(scenario_fingerprint_with(&sc, &ms, RiskBound::Ecr), via_req);
        assert_eq!(
            scenario_fingerprint(&sc, &Policy::Robust),
            scenario_fingerprint_with(&sc, &Policy::Robust, RiskBound::Ecr)
        );
    }

    #[test]
    fn bad_risk_is_a_structured_error() {
        let mut sc = scenario(8);
        sc.devices[1].risk = 0.0;
        assert!(matches!(
            PlanRequest::new(sc, Policy::Robust).validate(),
            Err(PlanError::InvalidRisk(_))
        ));
    }

    #[test]
    fn fingerprint_quantizes_sub_grid_jitter_but_sees_real_changes() {
        let sc = scenario(2);
        let base = PlanRequest::new(sc.clone(), Policy::Robust).fingerprint();
        // sub-quantum jitter: identical key
        let mut jig = sc.clone();
        jig.total_bandwidth_hz += 1.0; // << 1 kHz quantum
        jig.devices[0].deadline_s += 1e-6; // << 0.1 ms quantum
        assert_eq!(base, PlanRequest::new(jig, Policy::Robust).fingerprint());
        // real changes: different keys
        let mut moved = sc.clone();
        moved.devices[1].uplink = Uplink::from_distance(250.0);
        assert_ne!(base, PlanRequest::new(moved, Policy::Robust).fingerprint());
        let mut tighter = sc;
        tighter.devices[2].deadline_s -= 0.01;
        assert_ne!(base, PlanRequest::new(tighter, Policy::Robust).fingerprint());
    }

    #[test]
    fn validate_rejects_oversized_exhaustive() {
        // 12 AlexNet devices: 9^12 assignments — must be a clean error,
        // not a panic (or an overflowing product) in the search itself.
        let mut rng = Rng::new(9);
        let sc = Scenario::uniform(&ModelProfile::alexnet_paper(), 12, 10e6, 0.2, 0.05, &mut rng);
        assert!(matches!(
            PlanRequest::new(sc.clone(), Policy::Exhaustive).validate(),
            Err(PlanError::InvalidRequest(_))
        ));
        assert!(PlanRequest::new(sc, Policy::Robust).validate().is_ok());
    }

    #[test]
    fn fingerprint_sees_noise_floor_changes() {
        let sc = scenario(7);
        let base = PlanRequest::new(sc.clone(), Policy::Robust).fingerprint();
        let mut noisy = sc;
        noisy.devices[0].uplink.n0 *= 10.0;
        assert_ne!(base, PlanRequest::new(noisy, Policy::Robust).fingerprint());
    }

    #[test]
    fn validate_rejects_bad_init() {
        let sc = scenario(3);
        let m = sc.devices[0].model.num_points();
        assert!(PlanRequest::new(sc.clone(), Policy::Robust).validate().is_ok());
        assert!(matches!(
            PlanRequest::new(sc.clone(), Policy::Robust).with_init(vec![0; 3]).validate(),
            Err(PlanError::InvalidRequest(_))
        ));
        assert!(matches!(
            PlanRequest::new(sc, Policy::Robust).with_init(vec![m; 4]).validate(),
            Err(PlanError::InvalidRequest(_))
        ));
    }

    #[test]
    fn deltas_apply_and_validate() {
        let sc = scenario(4);
        let joined = ScenarioDelta::Join(sc.devices[0].clone()).apply(&sc).unwrap();
        assert_eq!(joined.n(), 5);
        let left = ScenarioDelta::Leave(2).apply(&sc).unwrap();
        assert_eq!(left.n(), 3);
        assert!(ScenarioDelta::Leave(9).apply(&sc).is_err());
        let slow = ScenarioDelta::Deadline { device: None, deadline_s: 0.3 }.apply(&sc).unwrap();
        assert!(slow.devices.iter().all(|d| d.deadline_s == 0.3));
        assert!(ScenarioDelta::Deadline { device: None, deadline_s: -1.0 }.apply(&sc).is_err());
        assert!(ScenarioDelta::Risk { device: Some(1), risk: 0.08 }.apply(&sc).is_ok());
        assert!(matches!(
            ScenarioDelta::Risk { device: None, risk: 1.5 }.apply(&sc),
            Err(PlanError::InvalidRisk(_))
        ));
        let rebound = ScenarioDelta::Bound(RiskBound::Gaussian).apply(&sc).unwrap();
        assert_eq!(rebound.n(), sc.n(), "a bound change leaves the scenario untouched");
        let wider = ScenarioDelta::TotalBandwidth(20e6).apply(&sc).unwrap();
        assert_eq!(wider.total_bandwidth_hz, 20e6);
    }

    #[test]
    fn policy_parse_and_names_roundtrip() {
        for (s, name) in [
            ("robust", "robust"),
            ("worst", "worst-case"),
            ("mean", "mean-only"),
            ("exhaustive", "exhaustive"),
            ("multistart", "multistart"),
        ] {
            assert_eq!(Policy::parse(s).unwrap().name(), name);
        }
        assert!(Policy::parse("bogus").is_none());
    }
}
