//! The [`Planner`] facade: one long-lived engine object that owns the
//! solver workspaces, the thread-fan-out configuration, and the plan
//! cache, and dispatches every policy through a single `plan` entrypoint
//! plus an incremental `replan` path.

// lint:allow-file(wall-clock): this is THE allowlisted wall-time source —
// Diagnostics.wall_time only; the fleet JSON exporter excludes it.
use std::time::Instant;

use crate::optim::types::{Plan, Policy as MarginPolicy, Scenario};
use crate::optim::{alternating, baselines, cohort, resource, AlternatingOptions, SolverBudget};
use crate::risk::RiskBound;
use crate::solver::NewtonWorkspace;

use super::cache::{CacheStats, PlanCache};
use super::outcome::{Diagnostics, PlanError, PlanOutcome};
use super::request::{scenario_fingerprint_with, PlanRequest, Policy, ScenarioDelta};

/// Bound on the enumeration-refinement rounds a warm replan runs; each
/// round costs one warm-started resource solve, so the replan's total
/// interior-point work stays far below a cold Algorithm-2 run.
const REPLAN_REFINE_ROUNDS: usize = 3;

/// Default LRU capacity (distinct scenario fingerprints a coordinator
/// juggles at once are typically few).
const DEFAULT_CACHE_CAPACITY: usize = 32;

/// Configures and builds a [`Planner`].
///
/// # Example
///
/// ```
/// use ripra::engine::{PlannerBuilder, PlanRequest, Policy};
/// use ripra::models::ModelProfile;
/// use ripra::optim::Scenario;
/// use ripra::util::rng::Rng;
///
/// let mut rng = Rng::new(7);
/// let sc = Scenario::uniform(&ModelProfile::alexnet_paper(), 2, 10e6, 0.25, 0.05, &mut rng);
/// let mut planner = PlannerBuilder::new().threads(1).cache_capacity(8).build();
///
/// let out = planner.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
/// assert!(out.energy > 0.0 && !out.diagnostics.cache_hit);
///
/// // The identical request is served from the LRU cache.
/// let hit = planner.plan(&PlanRequest::new(sc, Policy::Robust)).unwrap();
/// assert!(hit.diagnostics.cache_hit);
/// assert_eq!(hit.plan, out.plan);
/// ```
#[derive(Clone, Debug)]
pub struct PlannerBuilder {
    opts: AlternatingOptions,
    cache_capacity: usize,
    cohorts: bool,
}

impl Default for PlannerBuilder {
    fn default() -> Self {
        Self::new()
    }
}

impl PlannerBuilder {
    /// Start from the default configuration (default Algorithm-2
    /// options, default cache capacity, cohorts off).
    pub fn new() -> PlannerBuilder {
        PlannerBuilder {
            opts: AlternatingOptions::default(),
            cache_capacity: DEFAULT_CACHE_CAPACITY,
            cohorts: false,
        }
    }

    /// Replace the full Algorithm-2 option set (convergence thresholds,
    /// PCCP knobs, warm-start toggle, ...).  Call before [`Self::threads`]
    /// if combining both — `threads` overrides the option set's worker
    /// counts.
    pub fn alternating(mut self, opts: AlternatingOptions) -> PlannerBuilder {
        self.opts = opts;
        self
    }

    /// Worker threads for the per-device PCCP fan-out and the polish
    /// sweep (0 = one per core, 1 = sequential).  Thread count never
    /// changes results, only wall-clock.
    pub fn threads(mut self, n: usize) -> PlannerBuilder {
        self.opts.threads = n;
        self.opts.pccp.threads = n;
        self
    }

    /// Toggle Algorithm-2 warm starts between outer iterations.
    pub fn warm_start(mut self, on: bool) -> PlannerBuilder {
        self.opts.warm_start = on;
        self
    }

    /// Plan-cache capacity in entries; 0 disables caching.
    pub fn cache_capacity(mut self, n: usize) -> PlannerBuilder {
        self.cache_capacity = n;
        self
    }

    /// Hard solve budget (outer/PCCP/Newton iteration caps plus an
    /// optional wall-clock cap).  A budgeted solve that runs out while
    /// holding a feasible iterate returns it flagged
    /// `diagnostics.degraded` instead of spinning; degraded outcomes are
    /// never cached.  Default [`SolverBudget::UNLIMITED`].
    pub fn budget(mut self, budget: SolverBudget) -> PlannerBuilder {
        self.opts.budget = budget;
        self
    }

    /// Cohort-compressed robust solves ([`crate::optim::cohort`]): bucket
    /// devices by quantized fingerprint, solve one representative per
    /// cohort, replicate with a per-member feasibility re-check.  Only
    /// the `Robust` policy without an init-partition override dispatches
    /// through cohorts, and only when bucketing actually compresses
    /// (fewer cohorts than devices) — otherwise, and whenever this is
    /// `false` (the default), every solve is byte-identical to the
    /// per-device path.
    pub fn cohorts(mut self, on: bool) -> PlannerBuilder {
        self.cohorts = on;
        self
    }

    /// Construct the [`Planner`] (fresh cache, fresh workspace, edge
    /// marked reachable).
    pub fn build(self) -> Planner {
        Planner {
            opts: self.opts,
            cache: PlanCache::new(self.cache_capacity),
            ws: NewtonWorkspace::new(),
            last: None,
            edge_available: true,
            cohorts: self.cohorts,
        }
    }
}

/// The last successful solve, kept for incremental replanning.
struct LastSolve {
    scenario: Scenario,
    policy: Policy,
    outcome: PlanOutcome,
}

/// Long-lived planning engine: the one entrypoint every caller
/// (CLI, figures, coordinator, benches) goes through.
///
/// Owns a reusable [`NewtonWorkspace`] (so repeated solves stay
/// allocation-free in the barrier hot path), the fan-out thread
/// configuration, and an LRU plan cache keyed by a quantized scenario
/// fingerprint.  Construct with [`PlannerBuilder`].
pub struct Planner {
    opts: AlternatingOptions,
    cache: PlanCache,
    ws: NewtonWorkspace,
    last: Option<LastSolve>,
    /// Edge-server reachability ([`Planner::set_edge_available`]).
    /// While `false`, every plan/replan degrades to the all-local
    /// fallback and the cache is never consulted or populated.
    edge_available: bool,
    /// Cohort-compressed robust solves ([`PlannerBuilder::cohorts`]).
    cohorts: bool,
}

impl Default for Planner {
    fn default() -> Self {
        PlannerBuilder::new().build()
    }
}

impl Planner {
    /// Shorthand for [`PlannerBuilder::new`].
    pub fn builder() -> PlannerBuilder {
        PlannerBuilder::new()
    }

    /// The Algorithm-2 options this planner solves with.
    pub fn options(&self) -> &AlternatingOptions {
        &self.opts
    }

    /// Plan-cache hit/miss counters and occupancy.
    pub fn cache_stats(&self) -> CacheStats {
        self.cache.stats()
    }

    /// Mark the edge server reachable (`true`, the initial state) or
    /// unreachable (`false`).
    ///
    /// While unreachable, [`Planner::plan`] and [`Planner::replan`]
    /// return the guaranteed all-local fallback (every device computes
    /// its whole chain on-device at `f_max`, zero uplink) flagged
    /// `diagnostics.degraded`, [`Planner::plan_cached`] and
    /// [`Planner::plan_cached_for`] always miss without touching the
    /// cache counters, and nothing is inserted into the cache — cached
    /// plans assume an edge to offload to and must not be poisoned by
    /// (or served during) an outage.
    pub fn set_edge_available(&mut self, up: bool) {
        self.edge_available = up;
    }

    /// Current edge reachability (see [`Planner::set_edge_available`]).
    pub fn edge_available(&self) -> bool {
        self.edge_available
    }

    /// Drop every cached plan (counters are kept).
    pub fn clear_cache(&mut self) {
        self.cache.clear();
    }

    /// Scenario of the last successful `plan`/`replan`, if any.
    pub fn last_scenario(&self) -> Option<&Scenario> {
        self.last.as_ref().map(|l| &l.scenario)
    }

    /// Outcome of the last successful `plan`/`replan`, if any.
    pub fn last_outcome(&self) -> Option<&PlanOutcome> {
        self.last.as_ref().map(|l| &l.outcome)
    }

    /// Install `(scenario, outcome)` as the planner's replan base without
    /// solving — the multiplexing primitive a shard planner hosting
    /// several tenants uses to switch which sub-fleet a follow-up
    /// [`Planner::replan`]/[`Planner::rebase`] continues from.
    ///
    /// Deliberately touches nothing but the base: the plan cache, its
    /// hit/miss counters, and the Newton workspace are untouched, so a
    /// base restore between tenants cannot perturb any cached or counted
    /// state.  Errors when the outcome's decision shape doesn't fit the
    /// scenario.
    pub fn set_base(&mut self, scenario: Scenario, outcome: PlanOutcome) -> Result<(), PlanError> {
        let n = scenario.n();
        if outcome.plan.partition.len() != n
            || outcome.plan.bandwidth_hz.len() != n
            || outcome.plan.freq_ghz.len() != n
        {
            return Err(PlanError::InvalidRequest(format!(
                "cannot set a {}-device plan as the base for {n} devices",
                outcome.plan.partition.len()
            )));
        }
        let policy = outcome.policy.clone();
        self.last = Some(LastSolve { scenario, policy, outcome });
        Ok(())
    }

    /// Plan a scenario under a policy.
    ///
    /// On a cache-miss this solves cold and the result is bit-identical
    /// to the corresponding legacy free function (same options, same
    /// arithmetic — the shared workspace only changes where intermediates
    /// live).  On a hit the cached outcome is returned with
    /// `diagnostics.cache_hit = true`.
    pub fn plan(&mut self, req: &PlanRequest) -> Result<PlanOutcome, PlanError> {
        req.validate()?;
        if !self.edge_available {
            let out = self.fallback_outcome(&req.scenario, &req.policy, req.bound)?;
            self.remember(req.scenario.clone(), req.policy.clone(), &out);
            return Ok(out);
        }
        // One implementation of the hit path: the probe marks the hit,
        // counts it, and registers history.
        if let Some(hit) = self.plan_cached(req) {
            return Ok(hit);
        }
        let t0 = Instant::now();
        let mut outcome = self.solve_cold(req)?;
        outcome.diagnostics.wall_time = t0.elapsed();
        // Degraded (budget-truncated) outcomes are never cached: a later
        // identical request with slack to solve properly must not be
        // served the truncated plan.
        if req.use_cache && !outcome.diagnostics.degraded {
            self.cache.insert(req.fingerprint(), outcome.clone());
        }
        self.remember(req.scenario.clone(), req.policy.clone(), &outcome);
        Ok(outcome)
    }

    /// Probe the plan cache without ever solving.
    ///
    /// Returns the cached outcome for the request's quantized fingerprint
    /// (marked `cache_hit`) and registers it as the planner's last solve,
    /// so a follow-up [`Planner::replan`] continues from it — or `None`
    /// on a miss (counted in [`Planner::cache_stats`]), leaving the
    /// planner untouched.  Online drivers use this to serve sub-quantum
    /// scenario jitter (e.g. channel fades below the fingerprint's 0.1 dB
    /// bucket) from the cache and fall back to `replan`/`plan` only when
    /// the scenario has genuinely moved.
    pub fn plan_cached(&mut self, req: &PlanRequest) -> Option<PlanOutcome> {
        if !self.edge_available || !req.use_cache || req.validate().is_err() {
            return None;
        }
        let mut hit = self.cache.get(req.fingerprint())?;
        hit.diagnostics.cache_hit = true;
        self.remember(req.scenario.clone(), req.policy.clone(), &hit);
        Some(hit)
    }

    /// Borrow-only [`Planner::plan_cached`]: probe the cache for a bare
    /// `scenario × policy × bound` key (no init-partition override, as
    /// on every online replan path) without materializing a
    /// [`PlanRequest`] — the scenario is cloned into the replan base
    /// only on a hit.  Same hit/miss counting and history registration
    /// as the request-based probe; assumes a pre-validated scenario.
    pub fn plan_cached_for(
        &mut self,
        sc: &Scenario,
        policy: &Policy,
        bound: RiskBound,
    ) -> Option<PlanOutcome> {
        if !self.edge_available {
            return None;
        }
        let mut hit = self.cache.get(scenario_fingerprint_with(sc, policy, bound))?;
        hit.diagnostics.cache_hit = true;
        self.remember(sc.clone(), policy.clone(), &hit);
        Some(hit)
    }

    /// Adopt `scenario` as the planner's current state while keeping the
    /// previous decision — no solve happens.
    ///
    /// An environmental change that admits no feasible plan (a deep
    /// fade, an uplink-budget collapse) is a fact, not a request that
    /// can be refused: the fleet keeps executing its old decision, and
    /// subsequent [`Planner::replan`] deltas must apply to reality, not
    /// to the last plannable scenario.  Rebase re-prices the old plan's
    /// energy under the new scenario and moves the replan base forward;
    /// nothing is inserted into the plan cache (the outcome was not
    /// produced by a solve, and the old plan may violate the new
    /// scenario's constraints).  Returns the kept plan's re-priced
    /// energy; errors without history or when the plan's shape doesn't
    /// fit the scenario.
    ///
    /// Borrows the scenario: the hot per-event rebase path of the fleet
    /// driver and the service shards adopts it via `clone_from`, which
    /// reuses the base's existing allocations instead of cloning a fresh
    /// scenario per event.
    pub fn rebase(&mut self, scenario: &Scenario) -> Result<f64, PlanError> {
        let last = self.last.as_mut().ok_or_else(|| {
            PlanError::InvalidRequest("rebase requires a previous plan() on this planner".into())
        })?;
        if last.outcome.plan.partition.len() != scenario.n() {
            return Err(PlanError::InvalidRequest(format!(
                "cannot rebase a {}-device plan onto {} devices",
                last.outcome.plan.partition.len(),
                scenario.n()
            )));
        }
        let energy = last.outcome.plan.expected_energy(scenario);
        last.outcome.energy = energy;
        last.scenario.clone_from(scenario);
        Ok(energy)
    }

    /// Incrementally replan after a scenario change, warm-starting from
    /// the last plan.
    ///
    /// The warm path keeps the previous partition (adapted to the delta:
    /// a leaver's entries dropped, a joiner assigned its cheapest
    /// feasible point at an equal bandwidth share), re-solves resources
    /// from the previous `(b, f)`, and runs a few exact per-device
    /// enumeration refinement rounds — orders of magnitude fewer Newton
    /// iterations than a cold Algorithm-2 run.  The path is
    /// feasibility-gated: if the adapted decision admits no feasible
    /// resources, the planner falls back to a cold [`Planner::plan`] of
    /// the new scenario (and only errors if that fails too).
    pub fn replan(&mut self, delta: &ScenarioDelta) -> Result<PlanOutcome, PlanError> {
        let (prev_sc, policy, prev_plan, prev_bound) = match &self.last {
            Some(l) => {
                (l.scenario.clone(), l.policy.clone(), l.outcome.plan.clone(), l.outcome.bound)
            }
            None => {
                return Err(PlanError::InvalidRequest(
                    "replan requires a previous plan() on this planner".into(),
                ))
            }
        };
        // A Bound delta swaps the chance-constraint transform in place
        // (the scenario itself is untouched); every other delta keeps
        // planning under the bound of the last solve.
        let bound = match delta {
            ScenarioDelta::Bound(b) => *b,
            _ => prev_bound,
        };
        let new_sc = delta.apply(&prev_sc)?;
        if !self.edge_available {
            // Outage discipline: adopt the delta (it is a fact about the
            // world) but answer with the all-local fallback — nothing is
            // cached, so recovery replans resolve from clean state.
            let out = self.fallback_outcome(&new_sc, &policy, bound)?;
            self.remember(new_sc, policy, &out);
            return Ok(out);
        }
        let mpol = policy.margin_policy(bound);
        let t0 = Instant::now();

        let (mut partition, warm) = adapt_decision(delta, &prev_sc, &prev_plan, &new_sc, mpol);
        let first =
            resource::solve_warm_with(&new_sc, &partition, mpol, warm.as_ref(), &mut self.ws);
        let mut res = match first {
            Ok(r) => r,
            // Feasibility gate: the adapted decision cannot be repaired
            // by resources alone — solve the new scenario cold.
            Err(_) => return self.plan(&PlanRequest::new(new_sc, policy).with_bound(bound)),
        };

        let mut newton = res.newton_iters;
        let mut outer = 0;
        let mut trajectory = vec![res.energy];
        for _ in 0..REPLAN_REFINE_ROUNDS {
            outer += 1;
            let refined: Vec<usize> = (0..new_sc.n())
                .map(|i| {
                    baselines::best_point(&new_sc, i, res.freq_ghz[i], res.bandwidth_hz[i], mpol)
                        .unwrap_or(partition[i])
                })
                .collect();
            if refined == partition {
                break;
            }
            match resource::solve_warm_with(&new_sc, &refined, mpol, Some(&res), &mut self.ws) {
                Ok(r) if r.energy <= res.energy * (1.0 + 1e-9) => {
                    newton += r.newton_iters;
                    partition = refined;
                    res = r;
                    trajectory.push(res.energy);
                }
                Ok(r) => {
                    newton += r.newton_iters;
                    break;
                }
                Err(_) => break,
            }
        }

        let plan = Plan {
            partition,
            bandwidth_hz: res.bandwidth_hz.clone(),
            freq_ghz: res.freq_ghz.clone(),
        };
        let margins_s = margins_of(&new_sc, &plan, mpol);
        let outcome = PlanOutcome {
            plan,
            energy: res.energy,
            policy: policy.clone(),
            bound,
            diagnostics: Diagnostics {
                outer_iters: outer,
                newton_iters: newton,
                trajectory,
                wall_time: t0.elapsed(),
                warm_started: true,
                margins_s,
                ..Default::default()
            },
        };
        // A follow-up plan() of the same scenario (under the same
        // bound) now hits the cache.
        self.cache.insert(scenario_fingerprint_with(&new_sc, &policy, bound), outcome.clone());
        self.remember(new_sc, policy, &outcome);
        Ok(outcome)
    }

    fn remember(&mut self, scenario: Scenario, policy: Policy, outcome: &PlanOutcome) {
        self.last = Some(LastSolve { scenario, policy, outcome: outcome.clone() });
    }

    /// The guaranteed all-local fallback: every device computes its whole
    /// chain on-device at `f_max` with zero uplink bandwidth (the b = 0
    /// encoding [`crate::channel::Uplink::t_off`] maps to "no uplink in
    /// use").  No solver runs; the outcome is flagged
    /// `diagnostics.degraded` and is never cached.  Feasibility is
    /// checked against each device's *deterministic* (mean) inference
    /// time — during an outage the chance-constraint margin cannot be
    /// bought with offloading, so violations of the probabilistic
    /// deadline are possible and are accounted separately by the fleet
    /// metrics (`violations_while_degraded`).  Errors
    /// [`PlanError::Unavailable`] when some device cannot meet even the
    /// deterministic deadline at `f_max`.
    fn fallback_outcome(
        &self,
        sc: &Scenario,
        policy: &Policy,
        bound: RiskBound,
    ) -> Result<PlanOutcome, PlanError> {
        let n = sc.n();
        let mut partition = Vec::with_capacity(n);
        let mut freq = Vec::with_capacity(n);
        for (i, d) in sc.devices.iter().enumerate() {
            let m_local = d.model.num_points() - 1;
            let f_max = d.model.device.f_max_ghz;
            if d.t_total_mean(m_local, f_max, 0.0) > d.deadline_s {
                return Err(PlanError::Unavailable(format!(
                    "device {i} cannot meet its {:.4} s deadline fully on-device at f_max; \
                     no plan exists until the edge returns",
                    d.deadline_s
                )));
            }
            partition.push(m_local);
            freq.push(f_max);
        }
        let plan = Plan { partition, bandwidth_hz: vec![0.0; n], freq_ghz: freq };
        let energy = plan.expected_energy(sc);
        let margins_s = margins_of(sc, &plan, policy.margin_policy(bound));
        Ok(PlanOutcome {
            plan,
            energy,
            policy: policy.clone(),
            bound,
            diagnostics: Diagnostics { degraded: true, margins_s, ..Default::default() },
        })
    }

    fn solve_cold(&mut self, req: &PlanRequest) -> Result<PlanOutcome, PlanError> {
        let sc = &req.scenario;
        let mut out = match &req.policy {
            Policy::Robust => {
                // Cohort dispatch: only when enabled, only without an
                // init-partition override (its length is per-device), and
                // only when bucketing compresses — an all-unique fleet
                // falls through to the exact path, so cohorts=on is
                // bit-identical to cohorts=off there.  A cohort-solver
                // error also falls through: the two-stage warm start is a
                // heuristic and must not reject scenarios Algorithm 2
                // can solve.
                let compressed = if self.cohorts && req.init_partition.is_none() {
                    let ch = cohort::bucket(sc);
                    if ch.len() < sc.n() {
                        cohort::solve(sc, &ch, &self.opts, req.bound).ok()
                    } else {
                        None
                    }
                } else {
                    None
                };
                match compressed {
                    Some(r) => cohort_outcome(r, req.bound),
                    None => {
                        let init = req.init_partition.clone();
                        let r =
                            alternating::solve_core(sc, &self.opts, init, req.bound, &mut self.ws)?;
                        robust_outcome(r, Policy::Robust, req.bound)
                    }
                }
            }
            Policy::Multistart { extra_starts } => {
                let r = alternating::solve_multistart_core(
                    sc,
                    &self.opts,
                    extra_starts,
                    req.bound,
                    &mut self.ws,
                )?;
                robust_outcome(r, req.policy.clone(), req.bound)
            }
            Policy::WorstCase | Policy::MeanOnly => {
                let r = baselines::alternate_enumeration_core(
                    sc,
                    req.policy.margin_policy(req.bound),
                    req.init_partition.clone(),
                    20,
                    &mut self.ws,
                )?;
                baseline_outcome(r, req.policy.clone(), req.bound)
            }
            Policy::Exhaustive => {
                let r =
                    baselines::exhaustive_core(sc, MarginPolicy::Robust(req.bound), &mut self.ws)?;
                baseline_outcome(r, Policy::Exhaustive, req.bound)
            }
        };
        out.diagnostics.margins_s = margins_of(sc, &out.plan, req.policy.margin_policy(req.bound));
        Ok(out)
    }
}

/// Applied per-device margin at the chosen partition points — the
/// diagnostics slice that lets tooling attribute energy differences
/// between bounds to the margins they charged.
fn margins_of(sc: &Scenario, plan: &Plan, mpol: MarginPolicy) -> Vec<f64> {
    sc.devices.iter().zip(&plan.partition).map(|(d, &m)| d.margin(m, mpol)).collect()
}

fn robust_outcome(r: alternating::RobustPlan, policy: Policy, bound: RiskBound) -> PlanOutcome {
    PlanOutcome {
        plan: r.plan,
        energy: r.energy,
        policy,
        bound,
        diagnostics: Diagnostics {
            outer_iters: r.outer_iters,
            avg_pccp_iters: r.avg_pccp_iters,
            newton_iters: r.newton_iters,
            trajectory: r.trajectory,
            degraded: r.degraded,
            ..Default::default()
        },
    }
}

fn cohort_outcome(r: cohort::CohortPlan, bound: RiskBound) -> PlanOutcome {
    PlanOutcome {
        plan: r.plan,
        energy: r.energy,
        policy: Policy::Robust,
        bound,
        diagnostics: Diagnostics {
            outer_iters: 1,
            avg_pccp_iters: r.avg_pccp_iters,
            newton_iters: r.newton_iters,
            trajectory: vec![r.energy],
            cohorts: r.cohorts,
            cohort_gap: r.gap_bound,
            ..Default::default()
        },
    }
}

fn baseline_outcome(r: baselines::BaselinePlan, policy: Policy, bound: RiskBound) -> PlanOutcome {
    PlanOutcome {
        plan: r.plan,
        energy: r.energy,
        policy,
        bound,
        diagnostics: Diagnostics {
            outer_iters: r.outer_iters,
            newton_iters: r.newton_iters,
            ..Default::default()
        },
    }
}

/// Adapt the previous (partition, bandwidth, frequency) to a delta: the
/// returned partition seeds the warm resource solve, and the returned
/// resource guess is used only if strictly feasible for the new scenario
/// (`resource::solve_warm_with` checks and otherwise cold-starts).
fn adapt_decision(
    delta: &ScenarioDelta,
    prev_sc: &Scenario,
    prev: &Plan,
    new_sc: &Scenario,
    mpol: MarginPolicy,
) -> (Vec<usize>, Option<resource::ResourceSolution>) {
    let warm_of = |b: Vec<f64>, f: Vec<f64>| {
        Some(resource::ResourceSolution {
            bandwidth_hz: b,
            freq_ghz: f,
            energy: 0.0,
            newton_iters: 0,
        })
    };
    match delta {
        ScenarioDelta::Leave(i) => {
            let mut part = prev.partition.clone();
            let mut b = prev.bandwidth_hz.clone();
            let mut f = prev.freq_ghz.clone();
            part.remove(*i);
            b.remove(*i);
            f.remove(*i);
            (part, warm_of(b, f))
        }
        ScenarioDelta::Join(_) => {
            let n_new = new_sc.n();
            let joiner = &new_sc.devices[n_new - 1];
            let b_each = new_sc.total_bandwidth_hz / n_new as f64;
            let f_max = joiner.model.device.f_max_ghz;
            let m_new = baselines::best_point(new_sc, n_new - 1, f_max, b_each, mpol)
                .unwrap_or_else(|| joiner.min_margin_time_point(b_each, mpol));
            let mut part = prev.partition.clone();
            part.push(m_new);
            // Shrink the incumbents' shares to fund the joiner while
            // keeping Σb strictly under B.
            let shrink = (n_new as f64 - 1.0) / n_new as f64;
            let mut b: Vec<f64> = prev.bandwidth_hz.iter().map(|&x| x * shrink).collect();
            let mut f = prev.freq_ghz.clone();
            b.push(0.95 * b_each);
            f.push(joiner.model.device.f_max_ghz * 0.999);
            (part, warm_of(b, f))
        }
        ScenarioDelta::TotalBandwidth(b_new) => {
            let scale = if *b_new < prev_sc.total_bandwidth_hz {
                b_new / prev_sc.total_bandwidth_hz
            } else {
                1.0
            };
            let b = prev.bandwidth_hz.iter().map(|&x| x * scale).collect();
            (prev.partition.clone(), warm_of(b, prev.freq_ghz.clone()))
        }
        // Deadline/risk/channel changes keep the whole previous decision
        // as the warm start; the solver's strict-feasibility check gates
        // its reuse.
        _ => (
            prev.partition.clone(),
            warm_of(prev.bandwidth_hz.clone(), prev.freq_ghz.clone()),
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::ModelProfile;
    use crate::util::rng::Rng;

    fn scenario(n: usize, d: f64, eps: f64, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), n, 10e6, d, eps, &mut rng)
    }

    #[test]
    fn plan_caches_and_reports_hits() {
        let sc = scenario(4, 0.22, 0.05, 1);
        let mut p = Planner::default();
        let a = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        assert!(!a.diagnostics.cache_hit);
        let b = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        assert!(b.diagnostics.cache_hit);
        assert_eq!(a.plan, b.plan);
        assert!(a.energy.to_bits() == b.energy.to_bits());
        assert_eq!(p.cache_stats().hits, 1);
        // bypass flag skips both lookup and insert
        let c = p.plan(&PlanRequest::new(sc, Policy::Robust).without_cache()).unwrap();
        assert!(!c.diagnostics.cache_hit);
        assert_eq!(p.cache_stats().hits, 1);
    }

    #[test]
    fn plan_cached_probes_without_solving_and_seeds_replan() {
        let sc = scenario(4, 0.22, 0.05, 8);
        let mut p = Planner::default();
        // Cold cache: probe misses, planner state untouched.
        assert!(p.plan_cached(&PlanRequest::new(sc.clone(), Policy::Robust)).is_none());
        assert!(p.last_scenario().is_none());
        assert_eq!(p.cache_stats().misses, 1);

        let cold = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        // Warm cache: probe hits bit-identically and registers history...
        let hit = p.plan_cached(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        assert!(hit.diagnostics.cache_hit);
        assert_eq!(hit.plan, cold.plan);
        assert_eq!(hit.energy.to_bits(), cold.energy.to_bits());
        // ...so replan can continue from the probed outcome.
        let re = p.replan(&ScenarioDelta::Leave(0)).unwrap();
        assert_eq!(re.plan.partition.len(), 3);
        // A different policy misses (fingerprint includes the policy tag).
        assert!(p.plan_cached(&PlanRequest::new(sc.clone(), Policy::MeanOnly)).is_none());
        // The bypass flag skips the probe entirely (no miss counted).
        let misses = p.cache_stats().misses;
        assert!(p.plan_cached(&PlanRequest::new(sc, Policy::Robust).without_cache()).is_none());
        assert_eq!(p.cache_stats().misses, misses);
    }

    #[test]
    fn bound_delta_replans_in_place_and_shrinks_energy() {
        let sc = scenario(5, 0.22, 0.05, 21);
        let mut p = Planner::default();
        let ecr = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        assert_eq!(ecr.bound, RiskBound::Ecr);
        assert_eq!(ecr.diagnostics.margins_s.len(), sc.n());
        // Swap to the tighter Gaussian bound: same scenario, smaller
        // margins, so the warm replan must keep feasibility under the
        // new policy×bound and can only save energy.
        let re = p.replan(&ScenarioDelta::Bound(RiskBound::Gaussian)).unwrap();
        assert_eq!(re.bound, RiskBound::Gaussian);
        assert!(re.plan.feasible(&sc, MarginPolicy::Robust(RiskBound::Gaussian)));
        assert!(re.energy <= ecr.energy * (1.0 + 1e-9), "{} vs {}", re.energy, ecr.energy);
        // The recorded diagnostics are the Gaussian margins at the
        // replanned partition points, bit-for-bit.
        for (i, (d, &m)) in sc.devices.iter().zip(&re.plan.partition).enumerate() {
            let want = d.margin(m, MarginPolicy::Robust(RiskBound::Gaussian));
            assert_eq!(re.diagnostics.margins_s[i].to_bits(), want.to_bits(), "device {i}");
        }
        // The replanned outcome is cached under the *new* bound...
        let gauss_req =
            PlanRequest::new(sc.clone(), Policy::Robust).with_bound(RiskBound::Gaussian);
        let hit = p.plan_cached(&gauss_req).unwrap();
        assert!(hit.diagnostics.cache_hit);
        // ...and a follow-up replan continues under it.
        let re2 = p.replan(&ScenarioDelta::TotalBandwidth(sc.total_bandwidth_hz * 1.1)).unwrap();
        assert_eq!(re2.bound, RiskBound::Gaussian);
    }

    #[test]
    fn replan_without_history_is_rejected() {
        let mut p = Planner::default();
        assert!(matches!(
            p.replan(&ScenarioDelta::TotalBandwidth(5e6)),
            Err(PlanError::InvalidRequest(_))
        ));
    }

    #[test]
    fn replan_leave_warm_starts_and_stays_feasible() {
        let sc = scenario(6, 0.22, 0.05, 2);
        let mut p = Planner::default();
        let cold = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        let re = p.replan(&ScenarioDelta::Leave(3)).unwrap();
        assert!(re.diagnostics.warm_started);
        assert_eq!(re.plan.partition.len(), 5);
        let smaller = p.last_scenario().unwrap().clone();
        assert_eq!(smaller.n(), 5);
        assert!(re.plan.feasible(&smaller, MarginPolicy::ROBUST));
        assert!(re.plan.bandwidth_ok(&smaller));
        assert!(re.energy <= cold.energy * (1.0 + 1e-6), "leaving cannot cost energy");
        // a follow-up plan() of the replanned scenario hits the cache
        let again = p.plan(&PlanRequest::new(smaller, Policy::Robust)).unwrap();
        assert!(again.diagnostics.cache_hit);
    }

    #[test]
    fn rebase_moves_the_replan_base_without_solving() {
        use crate::channel::Uplink;
        let sc = scenario(4, 0.22, 0.05, 12);
        // No history: a fresh planner refuses to rebase.
        let mut fresh = Planner::default();
        assert!(matches!(fresh.rebase(&sc), Err(PlanError::InvalidRequest(_))));

        let mut p = Planner::default();
        p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        // The environment shifts (3 dB fade on device 0): adopt it.
        let mut faded = sc.clone();
        faded.devices[0].uplink = Uplink::from_gain_db(faded.devices[0].uplink.gain_db() - 3.0);
        assert!(p.rebase(&faded).unwrap() > 0.0, "rebase re-prices the kept plan");
        let adopted = p.last_scenario().unwrap();
        assert_eq!(
            adopted.devices[0].uplink.gain.to_bits(),
            faded.devices[0].uplink.gain.to_bits()
        );
        // A follow-up replan applies its delta to the rebased scenario.
        let re = p.replan(&ScenarioDelta::TotalBandwidth(sc.total_bandwidth_hz * 2.0)).unwrap();
        assert_eq!(re.plan.partition.len(), 4);
        let after = p.last_scenario().unwrap();
        assert_eq!(
            after.devices[0].uplink.gain.to_bits(),
            faded.devices[0].uplink.gain.to_bits(),
            "replan must build on the rebased channel, not the stale one"
        );
        // Shape mismatch is rejected.
        let mut smaller = faded;
        smaller.devices.pop();
        assert!(matches!(p.rebase(&smaller), Err(PlanError::InvalidRequest(_))));
    }

    #[test]
    fn unavailable_edge_degrades_to_the_all_local_fallback() {
        // Deadline generous enough that fully-local execution is
        // deterministically feasible at f_max.
        let sc = scenario(4, 2.0, 0.05, 31);
        let mut p = Planner::default();
        let healthy = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        assert!(!healthy.diagnostics.degraded);

        p.set_edge_available(false);
        assert!(!p.edge_available());
        // The cache holds the healthy plan but must not serve it.
        assert!(p.plan_cached(&PlanRequest::new(sc.clone(), Policy::Robust)).is_none());
        assert!(p.plan_cached_for(&sc, &Policy::Robust, RiskBound::Ecr).is_none());

        let out = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        assert!(out.diagnostics.degraded);
        for (i, d) in sc.devices.iter().enumerate() {
            assert_eq!(out.plan.partition[i], d.model.num_points() - 1, "fully on-device");
            assert_eq!(out.plan.bandwidth_hz[i], 0.0, "zero uplink");
            assert_eq!(out.plan.freq_ghz[i], d.model.device.f_max_ghz);
        }
        assert!(out.energy > 0.0 && out.energy.is_finite());
        assert!(out.energy >= healthy.energy, "local-only must cost an energy premium");

        // replan during the outage adopts the delta but stays degraded...
        let re = p.replan(&ScenarioDelta::Leave(0)).unwrap();
        assert!(re.diagnostics.degraded);
        assert_eq!(re.plan.partition.len(), 3);

        // ...and recovery serves real plans again (the cache was neither
        // consulted nor poisoned while down).
        p.set_edge_available(true);
        let back = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        assert!(back.diagnostics.cache_hit, "the pre-outage entry must survive");
        assert_eq!(back.plan, healthy.plan);
    }

    #[test]
    fn unavailable_edge_with_impossible_deadline_is_a_structured_error() {
        // 4 ms deadline: AlexNet cannot run fully on-device that fast.
        let sc = scenario(3, 0.004, 0.05, 32);
        let mut p = Planner::default();
        p.set_edge_available(false);
        assert!(matches!(
            p.plan(&PlanRequest::new(sc, Policy::Robust)),
            Err(PlanError::Unavailable(_))
        ));
    }

    #[test]
    fn plan_cached_for_matches_the_request_probe() {
        let sc = scenario(4, 0.22, 0.05, 33);
        let mut p = Planner::default();
        assert!(p.plan_cached_for(&sc, &Policy::Robust, RiskBound::Ecr).is_none());
        assert_eq!(p.cache_stats().misses, 1);
        let cold = p.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).unwrap();
        let hit = p.plan_cached_for(&sc, &Policy::Robust, RiskBound::Ecr).unwrap();
        assert!(hit.diagnostics.cache_hit);
        assert_eq!(hit.plan, cold.plan);
        assert_eq!(hit.energy.to_bits(), cold.energy.to_bits());
        // The probe registers history, so replan continues from it.
        let re = p.replan(&ScenarioDelta::Leave(0)).unwrap();
        assert_eq!(re.plan.partition.len(), 3);
        // A different bound misses.
        assert!(p.plan_cached_for(&sc, &Policy::Robust, RiskBound::Gaussian).is_none());
    }

    #[test]
    fn budgeted_planner_degrades_and_skips_the_cache() {
        use crate::optim::SolverBudget;
        let sc = scenario(6, 0.22, 0.02, 34);
        let mut p = Planner::builder()
            .budget(SolverBudget { max_outer: 1, ..SolverBudget::UNLIMITED })
            .build();
        let req = PlanRequest::new(sc, Policy::Robust).with_init(vec![0; 6]);
        let out = p.plan(&req).unwrap();
        assert!(out.diagnostics.degraded, "1-round budget from full offload should truncate");
        // Degraded outcomes are never cached.
        assert!(p.plan_cached(&req).is_none());
        assert_eq!(p.cache_stats().hits, 0);
    }

    #[test]
    fn replan_falls_back_cold_when_warm_path_is_infeasible() {
        let sc = scenario(5, 0.22, 0.05, 3);
        let mut p = Planner::default();
        p.plan(&PlanRequest::new(sc, Policy::Robust)).unwrap();
        // Crushing the deadline makes every warm/cold path infeasible:
        // the error must be the cold solver's, not a panic.
        assert!(matches!(
            p.replan(&ScenarioDelta::Deadline { device: None, deadline_s: 0.003 }),
            Err(PlanError::Infeasible(_))
        ));
    }
}
