//! Unified planner result types: every policy returns the same
//! [`PlanOutcome`] (plan + objective + solver diagnostics) and fails with
//! the same [`PlanError`], replacing the three incompatible result types
//! (`RobustPlan`, `BaselinePlan`, bare `Plan`) of the legacy free
//! functions.

use std::time::Duration;

use crate::optim::types::Plan;
use crate::risk::RiskBound;
use crate::util::json::Json;

use super::request::Policy;

/// Solver-side diagnostics attached to every [`PlanOutcome`].
///
/// Counter semantics per policy: `avg_pccp_iters` and `trajectory` are
/// only populated by the PCCP-based policies (`Robust`, `Multistart`);
/// the enumeration baselines report `outer_iters` (alternation rounds)
/// and `newton_iters` (interior-point work inside their resource
/// solves).
#[derive(Clone, Debug, Default)]
pub struct Diagnostics {
    /// Outer (Algorithm-2 alternation / enumeration) iterations.
    pub outer_iters: usize,
    /// Mean Algorithm-1 iterations per device (Fig. 9's metric).
    pub avg_pccp_iters: f64,
    /// Total Newton iterations across every inner interior-point solve.
    pub newton_iters: usize,
    /// Objective after each outer iteration (Fig. 10's trajectory).
    pub trajectory: Vec<f64>,
    /// Wall-clock of the solve that produced this outcome.  A cache hit
    /// reports the original solve's wall time, not the lookup's.
    pub wall_time: Duration,
    /// The outcome was served from the planner's LRU cache.
    pub cache_hit: bool,
    /// The outcome was produced by [`super::Planner::replan`]'s
    /// warm-started path (not a cold solve).
    pub warm_started: bool,
    /// The outcome is a *degraded* best-effort decision: either the
    /// solver budget ran out before convergence (best-feasible-so-far
    /// returned instead of spinning) or the edge was unreachable and the
    /// guaranteed all-local fallback plan was issued.  Degraded outcomes
    /// are never cached.
    pub degraded: bool,
    /// Applied per-device uncertainty margin at the chosen partition
    /// point, seconds — the slice of each deadline the active risk
    /// bound reserved for jitter.  Lets BENCH/figure tooling attribute
    /// energy differences between bounds to the margins they charged.
    pub margins_s: Vec<f64>,
    /// Number of fingerprint cohorts the plan was solved over
    /// ([`crate::optim::cohort`]); 0 when the solve was per-device (the
    /// cohort path was off or would not compress anything).
    pub cohorts: usize,
    /// Replication-drift bound of a cohort-compressed solve: relative
    /// energy difference between pricing every member at its
    /// representative's decision and pricing the replicated plan on the
    /// actual devices.  0 when `cohorts` is 0.
    pub cohort_gap: f64,
}

/// One unified outcome for every planning policy.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    /// The decision: partition point, bandwidth, and frequency per device.
    pub plan: Plan,
    /// Expected total device energy of `plan` (objective (9a)).
    pub energy: f64,
    /// Policy that produced the plan.
    pub policy: Policy,
    /// Chance-constraint transform the deadline margins were computed
    /// under (meaningful for the robust policy family; the baselines
    /// carry the request's bound through unchanged).
    pub bound: RiskBound,
    /// Solve-cost and provenance counters (iterations, wall time,
    /// cache/warm-start/degraded flags).
    pub diagnostics: Diagnostics,
}

impl PlanOutcome {
    /// Machine-readable encoding (the `ripra plan --json` payload).
    pub fn to_json(&self) -> Json {
        let nums = |v: &[f64]| Json::Arr(v.iter().map(|&x| Json::Num(x)).collect());
        Json::Obj(vec![
            ("policy".into(), Json::Str(self.policy.name().into())),
            ("bound".into(), Json::Str(self.bound.name().into())),
            (
                "bound_scale".into(),
                self.bound.scale().map(Json::Num).unwrap_or(Json::Null),
            ),
            ("energy_j".into(), Json::Num(self.energy)),
            (
                "partition".into(),
                Json::Arr(self.plan.partition.iter().map(|&m| Json::Num(m as f64)).collect()),
            ),
            ("bandwidth_hz".into(), nums(&self.plan.bandwidth_hz)),
            ("freq_ghz".into(), nums(&self.plan.freq_ghz)),
            ("margin_s".into(), nums(&self.diagnostics.margins_s)),
            (
                "diagnostics".into(),
                Json::Obj({
                    let mut d = vec![
                        ("outer_iters".into(), Json::Num(self.diagnostics.outer_iters as f64)),
                        ("avg_pccp_iters".into(), Json::Num(self.diagnostics.avg_pccp_iters)),
                        ("newton_iters".into(), Json::Num(self.diagnostics.newton_iters as f64)),
                        ("wall_time_s".into(), Json::Num(self.diagnostics.wall_time.as_secs_f64())),
                        ("cache_hit".into(), Json::Bool(self.diagnostics.cache_hit)),
                        ("warm_started".into(), Json::Bool(self.diagnostics.warm_started)),
                        ("degraded".into(), Json::Bool(self.diagnostics.degraded)),
                    ];
                    // Cohort keys only when the cohort path actually ran:
                    // cohorts=off payloads stay byte-identical to the
                    // pre-cohort encoding.
                    if self.diagnostics.cohorts > 0 {
                        d.push(("cohorts".into(), Json::Num(self.diagnostics.cohorts as f64)));
                        d.push(("cohort_gap".into(), Json::Num(self.diagnostics.cohort_gap)));
                    }
                    d.push(("trajectory".into(), nums(&self.diagnostics.trajectory)));
                    d
                }),
            ),
        ])
    }
}

/// Unified planner failure.
#[derive(Debug, Clone)]
pub enum PlanError {
    /// No feasible decision exists for the scenario under the policy.
    Infeasible(String),
    /// An inner solver failed numerically.
    Solver(String),
    /// The request itself is malformed (empty scenario, bad delta index,
    /// mismatched initial partition, ...).
    InvalidRequest(String),
    /// A risk level ε is outside (0, 1) — caught at request/delta
    /// validation so the transforms deep inside the solvers never see
    /// it (`risk::validate_risk`; historically this was an `assert!`
    /// panic in `ecr::sigma`).
    InvalidRisk(String),
    /// A solver budget was exhausted and no feasible decision had been
    /// reached yet — the degraded best-effort path could not even
    /// produce a fallback (budgeted solves that *do* hold a feasible
    /// iterate return it with `Diagnostics::degraded` instead).
    Degraded(String),
    /// The edge server is marked unreachable and the all-local fallback
    /// is itself infeasible (some device cannot meet its deadline at
    /// `f_max` without offloading): no plan can exist until the edge
    /// returns.
    Unavailable(String),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::Infeasible(s) => write!(f, "scenario infeasible: {s}"),
            PlanError::Solver(s) => write!(f, "solver failure: {s}"),
            PlanError::InvalidRequest(s) => write!(f, "invalid request: {s}"),
            PlanError::InvalidRisk(s) => write!(f, "invalid risk: {s}"),
            PlanError::Degraded(s) => write!(f, "degraded: {s}"),
            PlanError::Unavailable(s) => write!(f, "edge unavailable: {s}"),
        }
    }
}

impl std::error::Error for PlanError {}

impl From<crate::optim::alternating::PlanError> for PlanError {
    fn from(e: crate::optim::alternating::PlanError) -> Self {
        match e {
            crate::optim::alternating::PlanError::Infeasible(s) => PlanError::Infeasible(s),
            crate::optim::alternating::PlanError::Solver(s) => PlanError::Solver(s),
        }
    }
}

impl From<crate::optim::baselines::BaselineError> for PlanError {
    fn from(e: crate::optim::baselines::BaselineError) -> Self {
        // The baseline error carries its kind structurally, so this
        // classification cannot drift with message wording.
        if e.infeasible {
            PlanError::Infeasible(e.message)
        } else {
            PlanError::Solver(e.message)
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_roundtrips_and_carries_fields() {
        let out = PlanOutcome {
            plan: Plan {
                partition: vec![2, 0],
                bandwidth_hz: vec![3e6, 4e6],
                freq_ghz: vec![1.0, 0.5],
            },
            energy: 1.25,
            policy: Policy::Robust,
            bound: RiskBound::calibrated(0.85),
            diagnostics: Diagnostics {
                outer_iters: 3,
                newton_iters: 120,
                cache_hit: true,
                margins_s: vec![0.011, 0.007],
                ..Default::default()
            },
        };
        let j = out.to_json();
        let back = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(back.get("policy").unwrap().as_str().unwrap(), "robust");
        assert_eq!(back.get("bound").unwrap().as_str().unwrap(), "calibrated");
        assert!((back.get("bound_scale").unwrap().as_f64().unwrap() - 0.85).abs() < 1e-12);
        assert_eq!(back.get("margin_s").unwrap().as_arr().unwrap().len(), 2);
        assert_eq!(back.get("energy_j").unwrap().as_f64().unwrap(), 1.25);
        assert_eq!(back.get("partition").unwrap().usize_array().unwrap(), vec![2, 0]);
        let d = back.get("diagnostics").unwrap();
        assert_eq!(d.get("newton_iters").unwrap().as_usize().unwrap(), 120);
        assert!(d.get("cache_hit").unwrap().as_bool().unwrap());
    }

    #[test]
    fn error_display_tags_kind() {
        assert!(PlanError::Infeasible("x".into()).to_string().contains("infeasible"));
        assert!(PlanError::InvalidRequest("y".into()).to_string().contains("invalid"));
        assert!(PlanError::InvalidRisk("z".into()).to_string().contains("invalid risk"));
        assert!(PlanError::Degraded("w".into()).to_string().contains("degraded"));
        assert!(PlanError::Unavailable("v".into()).to_string().contains("unavailable"));
    }

    #[test]
    fn plan_error_works_with_question_mark_across_layers() {
        // std::error::Error + Display let fault-handling code use `?`
        // through anyhow-style boxes instead of ad-hoc matching.
        fn f() -> Result<(), Box<dyn std::error::Error>> {
            Err(PlanError::Unavailable("edge down".into()))?
        }
        let e = f().unwrap_err();
        assert!(e.to_string().contains("edge down"));
    }

    #[test]
    fn degraded_flag_lands_in_the_json_diagnostics() {
        let out = PlanOutcome {
            plan: Plan { partition: vec![5], bandwidth_hz: vec![0.0], freq_ghz: vec![1.2] },
            energy: 0.5,
            policy: Policy::Robust,
            bound: RiskBound::Ecr,
            diagnostics: Diagnostics { degraded: true, ..Default::default() },
        };
        let back = Json::parse(&out.to_json().to_string_pretty()).unwrap();
        let d = back.get("diagnostics").unwrap();
        assert!(d.get("degraded").unwrap().as_bool().unwrap());
    }
}
