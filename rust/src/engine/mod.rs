//! The planning engine facade: **one entrypoint for every policy**, plan
//! caching, and incremental replanning.
//!
//! The paper's Algorithm 2 and its §VI benchmarks used to be exposed as
//! scattered free functions with three incompatible result types; this
//! module replaces them with a long-lived [`Planner`] built from a
//! [`PlannerBuilder`]:
//!
//! * [`Planner::plan`] dispatches [`Policy::Robust`],
//!   [`Policy::WorstCase`], [`Policy::MeanOnly`], [`Policy::Exhaustive`]
//!   and [`Policy::Multistart`] through a single code path and returns a
//!   unified [`PlanOutcome`] (plan + energy + [`Diagnostics`]: outer
//!   iterations, PCCP/Newton counts, wall time, cache/warm-start flags).
//! * The planner owns long-lived state — a reusable
//!   [`crate::solver::NewtonWorkspace`], the thread fan-out
//!   configuration from [`crate::util::par`], and an LRU plan cache
//!   keyed by a quantized scenario fingerprint (model, N, bandwidth,
//!   deadlines, risk levels, channel gains) — so repeated planning is a
//!   service call, not a per-request cold start.
//! * [`Planner::replan`] consumes a [`ScenarioDelta`] (device
//!   join/leave, channel, deadline, risk, bandwidth, or risk-bound
//!   change) and warm-starts from the cached plan, falling back to a
//!   cold solve when the adapted decision is infeasible — replanning
//!   for an online fleet costs a few warm resource solves instead of a
//!   fresh MINLP run.
//! * Requests carry a pluggable chance-constraint transform
//!   ([`RiskBound`], default the paper's ECR/Cantelli bound):
//!   `PlanRequest::with_bound` selects it, the plan-cache fingerprint
//!   isolates it, and [`PlanOutcome`] reports the applied per-device
//!   margins.
//!
//! ```
//! use ripra::engine::{PlannerBuilder, PlanRequest, Policy, ScenarioDelta};
//! use ripra::models::ModelProfile;
//! use ripra::optim::Scenario;
//! use ripra::util::rng::Rng;
//!
//! let mut rng = Rng::new(3);
//! let sc = Scenario::uniform(&ModelProfile::alexnet_paper(), 3, 10e6, 0.25, 0.05, &mut rng);
//! let mut planner = PlannerBuilder::new().threads(1).build();
//! let out = planner.plan(&PlanRequest::new(sc, Policy::Robust)).unwrap();
//!
//! // A device leaves: incremental replan, warm-started from `out`.
//! let re = planner.replan(&ScenarioDelta::Leave(1)).unwrap();
//! assert!(re.diagnostics.warm_started);
//! assert!(re.energy <= out.energy * (1.0 + 1e-6));
//! ```
//!
//! The legacy free functions (`optim::alternating::solve`,
//! `optim::baselines::worst_case`, ...) remain as thin `#[deprecated]`
//! shims for one release; new code should construct a planner.

#![warn(missing_docs)]

pub mod cache;
pub mod outcome;
pub mod planner;
pub mod request;

pub use cache::CacheStats;
pub use outcome::{Diagnostics, PlanError, PlanOutcome};
pub use planner::{Planner, PlannerBuilder};
pub use request::{
    device_fingerprint, scenario_fingerprint, scenario_fingerprint_with, CliFlag, PlanRequest,
    Policy, ScenarioDelta,
};
// The risk-bound layer is part of the engine's request surface
// (`PlanRequest::with_bound`, `ScenarioDelta::Bound`), so re-export it.
pub use crate::risk::RiskBound;
