//! Model registry: the paper's study DNNs and their per-partition-point
//! parameters.
//!
//! Two sources feed this registry:
//!
//! 1. **Paper tables** — Tables III & IV give, for every partition point
//!    `m`, the offload size `d_{n,m}` (MB), cumulative local workload
//!    `w_{n,m}` (GFLOPs), fitted throughput `g_{n,m}` (FLOPs/cycle, eq. 10)
//!    and the max-over-frequency local-time variance `v^loc_{n,m}` (ms²,
//!    eq. 11).  Table II fixes the hardware pairing: AlexNet on the
//!    Jetson Xavier NX *CPU* (f ∈ [0.1, 1.2] GHz, κ = 0.8e-27), ResNet152
//!    on the Jetson *GPU* (f ∈ [0.2, 0.8] GHz, κ = 2.8e-27), VM = RTX 4080.
//! 2. **AOT manifest** — `artifacts/manifest.json` describes the real
//!    CIFAR-scale chains compiled by `python/compile/aot.py`; the serving
//!    runtime uses those, with this registry translating manifest entries
//!    into the same `ModelProfile` shape (see `manifest.rs`).
//!
//! Unit conventions (everything SI internally): times s, variances s²,
//! data bits, frequency GHz for `f` but Hz inside energy (κ·f³ wants
//! cycle/s), bandwidth Hz.

pub mod manifest;

/// Per-partition-point parameters (paper Tables III/IV rows).
#[derive(Clone, Debug)]
pub struct PointParams {
    /// Offloaded data size at this point, MB (d_{n,m}).
    pub d_mb: f64,
    /// Cumulative local workload of blocks 1..m, GFLOPs (w_{n,m}).
    pub w_gflops: f64,
    /// Fitted throughput g_{n,m}, FLOPs/cycle (eq. 10); 0 for m = 0
    /// (no local compute — never dereferenced).
    pub g_flops_cycle: f64,
    /// Max-over-frequency variance of the cumulative local time, s²
    /// (eq. 11; paper reports ms²).
    pub v_loc_s2: f64,
}

/// Local processor model (Table II row).
#[derive(Clone, Copy, Debug)]
pub struct DeviceHw {
    pub f_min_ghz: f64,
    pub f_max_ghz: f64,
    /// Energy coefficient κ in W/(cycle/s)³ (§VI-A: 0.8e-27 CPU, 2.8e-27 GPU).
    pub kappa: f64,
}

/// Edge VM model (RTX 4080 stand-in): effective sustained throughput and a
/// coefficient of variation for its inference-time jitter.  The paper
/// measures t̄^vm / v^vm online; we derive them from this profile (see
/// DESIGN.md §3 Hardware-Adaptation).
#[derive(Clone, Copy, Debug)]
pub struct VmProfile {
    /// Effective sustained GFLOPs/s for the remaining blocks.
    pub gflops_per_sec: f64,
    /// Coefficient of variation of the edge inference time.
    pub time_cv: f64,
}

/// A block-chain DNN + its hardware pairing: everything the optimizer
/// needs about one device's model.
#[derive(Clone, Debug)]
pub struct ModelProfile {
    pub name: String,
    /// Index m = 0..=M.
    pub points: Vec<PointParams>,
    pub device: DeviceHw,
    pub vm: VmProfile,
    /// Empirical (max − mean)/σ of the local inference time observed over
    /// the paper-style 500-trial profiling run — the number the worst-case
    /// baseline plans with.  Real platforms show rare large outliers
    /// (Fig. 1/5: I/O, scheduler, thermal events), so this is far above
    /// the Gaussian ~3.5: CPU (AlexNet) ≈ 8, GPU (ResNet152) ≈ 5.5.  The
    /// synthetic hardware's spike mixture (`profile::SyntheticHardware`)
    /// reproduces it.
    pub worst_dev_factor: f64,
}

impl ModelProfile {
    /// Number of partition points (M + 1).
    pub fn num_points(&self) -> usize {
        self.points.len()
    }

    /// Number of blocks M.
    pub fn num_blocks(&self) -> usize {
        self.points.len() - 1
    }

    /// Mean local inference time at point m and frequency f (GHz) — eq. 10.
    pub fn t_loc_mean(&self, m: usize, f_ghz: f64) -> f64 {
        let p = &self.points[m];
        if p.w_gflops == 0.0 {
            0.0
        } else {
            p.w_gflops / (p.g_flops_cycle * f_ghz)
        }
    }

    /// Local-time variance at point m, s² (eq. 11 max rule, from tables).
    pub fn v_loc(&self, m: usize) -> f64 {
        self.points[m].v_loc_s2
    }

    /// Mean edge (VM) inference time for the remaining blocks after m.
    pub fn t_vm_mean(&self, m: usize) -> f64 {
        let w_rest = self.points[self.num_blocks()].w_gflops - self.points[m].w_gflops;
        w_rest.max(0.0) / self.vm.gflops_per_sec
    }

    /// Edge-time variance at point m, s².
    pub fn v_vm(&self, m: usize) -> f64 {
        let t = self.t_vm_mean(m);
        (t * self.vm.time_cv).powi(2)
    }

    /// Offloaded data in bits at point m (d in MB, 1 MB = 8e6 bits — the
    /// paper's decimal-MB convention).
    pub fn d_bits(&self, m: usize) -> f64 {
        self.points[m].d_mb * 8e6
    }

    /// Diagonal element w_{n,m,m} of the covariance matrix W_n (eq. 27):
    /// variance of the *total* time at point m.  Local and VM components
    /// are independent executions, and t^off is deterministic given b, so
    /// the diagonal is the sum of the two variances (matches V_n of
    /// eq. 21 summed, as used in constraints (22)/(28)).
    pub fn w_diag(&self, m: usize) -> f64 {
        self.v_loc(m) + self.v_vm(m)
    }

    /// Worst-case (upper bound) local time at point m and frequency f,
    /// used by the worst-case baseline policy: mean + the empirical
    /// max-deviation factor (`worst_dev_factor`) times σ.
    pub fn t_loc_worst(&self, m: usize, f_ghz: f64) -> f64 {
        self.t_loc_mean(m, f_ghz) + self.worst_dev_factor * self.v_loc(m).sqrt()
    }

    /// Worst-case VM time at point m.
    pub fn t_vm_worst(&self, m: usize) -> f64 {
        self.t_vm_mean(m) + 3.5 * self.v_vm(m).sqrt()
    }

    // -- paper-table constructors -------------------------------------------

    /// Table III: AlexNet on Jetson Xavier NX CPU.
    pub fn alexnet_paper() -> Self {
        let ms2 = 1e-6; // ms² -> s²
        let rows: [(f64, f64, f64, f64); 9] = [
            // d_MB,  w_GFLOPs, g_FLOPs/cyc, v_loc (ms²)
            (0.574, 0.0, 0.0, 0.0),
            (0.74, 0.1407, 6.8994, 37.341),
            (0.18, 0.1411, 6.3283, 43.084),
            (0.53, 0.5891, 13.6064, 59.616),
            (0.12, 0.5894, 13.1861, 63.942),
            (0.25, 0.8137, 14.6624, 74.801),
            (0.17, 1.3122, 16.4237, 95.073),
            (0.04, 1.3123, 16.1219, 98.876),
            (0.001, 1.4214, 7.1037, 105.886),
        ];
        ModelProfile {
            name: "alexnet".into(),
            points: rows
                .iter()
                .map(|&(d, w, g, v)| PointParams {
                    d_mb: d,
                    w_gflops: w,
                    g_flops_cycle: g,
                    v_loc_s2: v * ms2,
                })
                .collect(),
            device: DeviceHw { f_min_ghz: 0.1, f_max_ghz: 1.2, kappa: 0.8e-27 },
            // Full AlexNet on the VM ≈ 8 ms (Fig. 5 RTX-4080 scale).
            vm: VmProfile { gflops_per_sec: 178.0, time_cv: 0.05 },
            worst_dev_factor: 8.0,
        }
    }

    /// Table IV: ResNet152 on Jetson Xavier NX GPU.
    pub fn resnet152_paper() -> Self {
        let ms2 = 1e-6;
        let rows: [(f64, f64, f64, f64); 10] = [
            (0.574, 0.0, 0.0, 0.0),
            (3.06, 0.2392, 315.4525, 0.097),
            (0.77, 1.4864, 309.6695, 1.310),
            (1.53, 3.6585, 323.7640, 5.677),
            (0.38, 5.3099, 329.8090, 13.934),
            (0.19, 9.9984, 325.6815, 14.076),
            (0.19, 13.9389, 324.1615, 15.881),
            (0.19, 17.8794, 322.7340, 23.408),
            (0.1, 21.9228, 318.6457, 32.256),
            (0.001, 23.1064, 307.6753, 32.727),
        ];
        ModelProfile {
            name: "resnet152".into(),
            points: rows
                .iter()
                .map(|&(d, w, g, v)| PointParams {
                    d_mb: d,
                    w_gflops: w,
                    g_flops_cycle: g,
                    v_loc_s2: v * ms2,
                })
                .collect(),
            device: DeviceHw { f_min_ghz: 0.2, f_max_ghz: 0.8, kappa: 2.8e-27 },
            // Full ResNet152 on the VM ≈ 20 ms.
            vm: VmProfile { gflops_per_sec: 1155.0, time_cv: 0.05 },
            worst_dev_factor: 5.5,
        }
    }

    pub fn by_name(name: &str) -> Option<Self> {
        match name {
            "alexnet" => Some(Self::alexnet_paper()),
            "resnet152" => Some(Self::resnet152_paper()),
            _ => None,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_iii_shape() {
        let m = ModelProfile::alexnet_paper();
        assert_eq!(m.num_points(), 9);
        assert_eq!(m.num_blocks(), 8);
        // Spot-check a couple of Table III cells.
        assert_eq!(m.points[2].d_mb, 0.18);
        assert_eq!(m.points[8].g_flops_cycle, 7.1037);
        assert!((m.v_loc(1) - 37.341e-6).abs() < 1e-12);
    }

    #[test]
    fn table_iv_shape() {
        let m = ModelProfile::resnet152_paper();
        assert_eq!(m.num_points(), 10);
        assert_eq!(m.points[1].d_mb, 3.06);
        assert_eq!(m.points[9].w_gflops, 23.1064);
    }

    #[test]
    fn eq10_units_check() {
        // AlexNet full model at 1.2 GHz: 1.4214/(7.1037*1.2) ≈ 166.7 ms.
        let m = ModelProfile::alexnet_paper();
        let t = m.t_loc_mean(8, 1.2);
        assert!((t - 0.1667).abs() < 1e-3, "t={t}");
        // m=0 must be exactly zero regardless of f.
        assert_eq!(m.t_loc_mean(0, 0.3), 0.0);
    }

    #[test]
    fn workload_monotone_in_m() {
        for m in [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()] {
            for i in 1..m.num_points() {
                assert!(m.points[i].w_gflops >= m.points[i - 1].w_gflops);
                assert!(m.v_loc(i) >= m.v_loc(i - 1), "{} point {i}", m.name);
            }
        }
    }

    #[test]
    fn vm_time_decreases_with_m() {
        let m = ModelProfile::resnet152_paper();
        for i in 1..m.num_points() {
            assert!(m.t_vm_mean(i) <= m.t_vm_mean(i - 1));
        }
        assert_eq!(m.t_vm_mean(m.num_blocks()), 0.0);
        assert_eq!(m.v_vm(m.num_blocks()), 0.0);
    }

    #[test]
    fn vm_full_model_scale() {
        // DESIGN.md: full AlexNet ≈ 8 ms, full ResNet152 ≈ 20 ms on the VM.
        let a = ModelProfile::alexnet_paper();
        assert!((a.t_vm_mean(0) - 0.008).abs() < 5e-4, "{}", a.t_vm_mean(0));
        let r = ModelProfile::resnet152_paper();
        assert!((r.t_vm_mean(0) - 0.020).abs() < 1e-3, "{}", r.t_vm_mean(0));
    }

    #[test]
    fn worst_case_dominates_mean() {
        let m = ModelProfile::alexnet_paper();
        for i in 0..m.num_points() {
            assert!(m.t_loc_worst(i, 0.6) >= m.t_loc_mean(i, 0.6));
            assert!(m.t_vm_worst(i) >= m.t_vm_mean(i));
        }
    }

    #[test]
    fn by_name_roundtrip() {
        assert!(ModelProfile::by_name("alexnet").is_some());
        assert!(ModelProfile::by_name("resnet152").is_some());
        assert!(ModelProfile::by_name("vgg").is_none());
    }
}
