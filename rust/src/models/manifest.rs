//! AOT artifact manifest loader.
//!
//! `python/compile/aot.py` emits `artifacts/manifest.json` describing every
//! lowered partition side (HLO text path, shapes, weight tensor names) plus
//! the per-point `d_bytes` / cumulative-GFLOPs tables of the real compiled
//! chains.  This module parses it (with the in-crate JSON parser) into
//! typed structs for the runtime and the serving coordinator, and can
//! translate a manifest model into a `ModelProfile` so the optimizer can
//! plan directly against the real artifacts.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

use super::{DeviceHw, ModelProfile, PointParams, VmProfile};

/// One lowered partition side.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub role: Role,
    pub m: usize,
    pub batch: usize,
    /// HLO text path relative to the artifacts dir.
    pub hlo: String,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    /// Weight tensor names (order = parameter order after the activation).
    pub weight_names: Vec<String>,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Role {
    Device,
    Edge,
}

/// Partition-point row from the manifest (real compiled chain).
#[derive(Clone, Debug)]
pub struct ManifestPoint {
    pub m: usize,
    pub d_bytes: usize,
    pub w_gflops: f64,
    pub feat_shape: Vec<usize>,
}

/// One model's manifest entry.
#[derive(Clone, Debug)]
pub struct ManifestModel {
    pub name: String,
    pub num_blocks: usize,
    pub input_shape: Vec<usize>,
    pub weights_path: String,
    pub points: Vec<ManifestPoint>,
    pub artifacts: Vec<ArtifactEntry>,
    pub block_gflops: Vec<f64>,
    pub block_names: Vec<String>,
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    /// Keyed by model name; `BTreeMap` so `keys()` / error listings /
    /// any future serialization iterate in name order (determinism).
    pub models: BTreeMap<String, ManifestModel>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> Result<Manifest, String> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let root = Json::parse(&text).map_err(|e| e.to_string())?;
        let mut models = BTreeMap::new();
        for (name, entry) in root.expect("models")?.as_obj().ok_or("models not an object")? {
            models.insert(name.clone(), parse_model(name, entry)?);
        }
        Ok(Manifest { dir: dir.to_path_buf(), models })
    }

    /// Default artifacts dir: `$RIPRA_ARTIFACTS` or `<crate>/artifacts`.
    pub fn default_dir() -> PathBuf {
        std::env::var_os("RIPRA_ARTIFACTS")
            .map(PathBuf::from)
            .unwrap_or_else(|| Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts"))
    }

    pub fn model(&self, name: &str) -> Result<&ManifestModel, String> {
        self.models.get(name).ok_or_else(|| {
            format!("model {name:?} not in manifest (have: {:?})",
                self.models.keys().collect::<Vec<_>>())
        })
    }
}

impl ManifestModel {
    /// Find a lowered artifact by (role, m, batch).
    pub fn artifact(&self, role: Role, m: usize, batch: usize) -> Option<&ArtifactEntry> {
        self.artifacts
            .iter()
            .find(|a| a.role == role && a.m == m && a.batch == batch)
    }

    /// Edge batch sizes available for point m.
    pub fn edge_batches(&self, m: usize) -> Vec<usize> {
        let mut bs: Vec<usize> = self
            .artifacts
            .iter()
            .filter(|a| a.role == Role::Edge && a.m == m)
            .map(|a| a.batch)
            .collect();
        bs.sort_unstable();
        bs
    }

    /// Translate into an optimizer-facing `ModelProfile`.
    ///
    /// The real chains are CIFAR-scale, so their absolute GFLOPs are tiny;
    /// the profile keeps the real `d` and `w` shapes while hardware
    /// throughput/variance are taken from the given device/vm profiles
    /// (the planner only ever consumes mean/variance, so this is exactly
    /// the paper's information model).
    pub fn to_profile(&self, device: DeviceHw, vm: VmProfile, g_flops_cycle: f64,
                      v_loc_full_s2: f64) -> ModelProfile {
        let w_full = self.points.last().map(|p| p.w_gflops).max_by_or_zero();
        let points = self
            .points
            .iter()
            .map(|p| PointParams {
                d_mb: p.d_bytes as f64 / 1e6,
                w_gflops: p.w_gflops,
                g_flops_cycle: if p.m == 0 { 0.0 } else { g_flops_cycle },
                // Variance grows with the local share of the workload
                // (same monotone trend as Tables III/IV).
                v_loc_s2: if w_full > 0.0 {
                    v_loc_full_s2 * p.w_gflops / w_full
                } else {
                    0.0
                },
            })
            .collect();
        ModelProfile {
            name: self.name.clone(),
            points,
            device,
            vm,
            worst_dev_factor: 8.0,
        }
    }
}

trait MaxByOrZero {
    fn max_by_or_zero(self) -> f64;
}

impl MaxByOrZero for Option<f64> {
    fn max_by_or_zero(self) -> f64 {
        self.unwrap_or(0.0)
    }
}

fn parse_model(name: &str, entry: &Json) -> Result<ManifestModel, String> {
    let num_blocks = entry
        .expect("num_blocks")?
        .as_usize()
        .ok_or("num_blocks not an int")?;
    let input_shape = entry
        .expect("input_shape")?
        .usize_array()
        .ok_or("bad input_shape")?;
    let weights_path = entry
        .expect("weights")?
        .as_str()
        .ok_or("weights not a string")?
        .to_string();

    let mut points = Vec::new();
    for p in entry.expect("points")?.as_arr().ok_or("points not an array")? {
        points.push(ManifestPoint {
            m: p.expect("m")?.as_usize().ok_or("bad m")?,
            d_bytes: p.expect("d_bytes")?.as_usize().ok_or("bad d_bytes")?,
            w_gflops: p.expect("w_gflops")?.as_f64().ok_or("bad w_gflops")?,
            feat_shape: p.expect("feat_shape")?.usize_array().ok_or("bad feat_shape")?,
        });
    }
    if points.len() != num_blocks + 1 {
        return Err(format!(
            "model {name}: {} points but {num_blocks} blocks",
            points.len()
        ));
    }

    let mut artifacts = Vec::new();
    for a in entry.expect("artifacts")?.as_arr().ok_or("artifacts not an array")? {
        let role = match a.expect("role")?.as_str() {
            Some("device") => Role::Device,
            Some("edge") => Role::Edge,
            other => return Err(format!("bad role {other:?}")),
        };
        let weight_names = a
            .expect("weight_names")?
            .as_arr()
            .ok_or("weight_names not an array")?
            .iter()
            .map(|x| x.as_str().map(str::to_string).ok_or("bad weight name"))
            .collect::<Result<Vec<_>, _>>()?;
        artifacts.push(ArtifactEntry {
            role,
            m: a.expect("m")?.as_usize().ok_or("bad m")?,
            batch: a.expect("batch")?.as_usize().ok_or("bad batch")?,
            hlo: a.expect("hlo")?.as_str().ok_or("hlo not a string")?.to_string(),
            input_shape: a.expect("input_shape")?.usize_array().ok_or("bad input_shape")?,
            output_shape: a
                .expect("output_shape")?
                .usize_array()
                .ok_or("bad output_shape")?,
            weight_names,
        });
    }

    let mut block_gflops = Vec::new();
    let mut block_names = Vec::new();
    for b in entry.expect("blocks")?.as_arr().ok_or("blocks not an array")? {
        block_gflops.push(b.expect("gflops")?.as_f64().ok_or("bad gflops")?);
        block_names.push(
            b.expect("name")?.as_str().ok_or("bad block name")?.to_string(),
        );
    }

    Ok(ManifestModel {
        name: name.to_string(),
        num_blocks,
        input_shape,
        weights_path,
        points,
        artifacts,
        block_gflops,
        block_names,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Option<Manifest> {
        let dir = Manifest::default_dir();
        dir.join("manifest.json").exists().then(|| Manifest::load(&dir).unwrap())
    }

    #[test]
    fn loads_real_manifest() {
        let Some(m) = manifest() else { return };
        assert!(m.models.contains_key("alexnet"));
        assert!(m.models.contains_key("resnet152"));
        let a = m.model("alexnet").unwrap();
        assert_eq!(a.num_blocks, 8);
        assert_eq!(a.points.len(), 9);
        assert_eq!(a.input_shape, vec![1, 32, 32, 3]);
    }

    #[test]
    fn artifact_coverage_real_manifest() {
        let Some(m) = manifest() else { return };
        for model in m.models.values() {
            for pt in 1..=model.num_blocks {
                assert!(
                    model.artifact(Role::Device, pt, 1).is_some(),
                    "{} device m={pt}",
                    model.name
                );
            }
            for pt in 0..model.num_blocks {
                assert!(!model.edge_batches(pt).is_empty());
            }
        }
    }

    #[test]
    fn points_tables_are_consistent() {
        let Some(m) = manifest() else { return };
        for model in m.models.values() {
            assert_eq!(model.points[0].w_gflops, 0.0);
            for (i, p) in model.points.iter().enumerate() {
                assert_eq!(p.m, i);
                assert!(p.d_bytes > 0);
            }
            // cumulative gflops must match block sums
            let total: f64 = model.block_gflops.iter().sum();
            let last = model.points.last().unwrap().w_gflops;
            assert!((total - last).abs() < 1e-9);
        }
    }

    #[test]
    fn to_profile_shapes() {
        let Some(m) = manifest() else { return };
        let a = m.model("alexnet").unwrap();
        let prof = a.to_profile(
            super::super::DeviceHw { f_min_ghz: 0.1, f_max_ghz: 1.2, kappa: 0.8e-27 },
            super::super::VmProfile { gflops_per_sec: 100.0, time_cv: 0.05 },
            7.0,
            1e-4,
        );
        assert_eq!(prof.num_points(), a.points.len());
        assert_eq!(prof.points[0].w_gflops, 0.0);
        // variance monotone (same property as the paper tables)
        for i in 1..prof.num_points() {
            assert!(prof.v_loc(i) >= prof.v_loc(i - 1));
        }
    }

    #[test]
    fn missing_dir_errors() {
        assert!(Manifest::load(Path::new("/nonexistent/nowhere")).is_err());
    }
}
