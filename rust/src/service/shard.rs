//! One planner shard: an independent [`Planner`] (own LRU cache, own
//! Newton workspace) plus the per-tenant sub-fleets it hosts.
//!
//! Every shard op drives the planner exactly like the serial fleet
//! driver drives its single planner — plan-cache probe first, warm
//! replan next, rebase-absorb or reject last — so a one-shard service is
//! bit-identical to the bare-planner path.  A shard hosting several
//! tenants multiplexes them through [`Planner::set_base`], which swaps
//! the replan base without touching any cached or counted state.

use crate::engine::{
    PlanError, PlanOutcome, PlanRequest, Planner, Policy, RiskBound, ScenarioDelta,
};
use crate::optim::types::{Device, Scenario};

use super::{Disposition, TenantId};

/// One tenant's sub-fleet on one shard.
#[derive(Clone, Debug)]
pub(crate) struct SubFleet {
    /// Tenant-level device indices in local (slot) order.
    pub members: Vec<usize>,
    /// The sub-scenario this shard plans: the member devices plus this
    /// shard's bandwidth share of the tenant's budget.
    pub scenario: Scenario,
    /// Last accepted/absorbed outcome for the sub-fleet.
    pub outcome: PlanOutcome,
}

/// Result of one (or, after [`merge`], several) planner-facing shard
/// operations.  The `ops`/`replans`/`hits`/`rebases` counters are exact
/// per-op counts so aggregated stats never undercount merged ops.
#[derive(Clone, Debug)]
pub(crate) struct ShardOpResult {
    pub disposition: Disposition,
    /// Newton iterations this op cost (0 when served from the cache,
    /// matching the fleet driver's per-step accounting).
    pub newton_iters: usize,
    pub outer_iters: usize,
    /// Every folded-in op was served from a plan cache.
    pub cache_hit: bool,
    pub warm_started: bool,
    /// Planner-facing ops folded in (0 for pure-bookkeeping results and
    /// rejects that never reached the planner).
    pub ops: usize,
    /// Ops that invoked [`Planner::replan`] (whatever the outcome).
    pub replans: usize,
    /// Ops served from a plan cache.
    pub hits: usize,
    /// Ops that fell back to [`Planner::rebase`] (absorbed).
    pub rebases: usize,
    /// Some folded-in op produced a degraded plan (all-local fallback or
    /// budget-truncated solve).
    pub degraded: bool,
}

impl ShardOpResult {
    pub fn rejected() -> ShardOpResult {
        ShardOpResult {
            disposition: Disposition::Rejected,
            newton_iters: 0,
            outer_iters: 0,
            cache_hit: false,
            warm_started: false,
            ops: 0,
            replans: 0,
            hits: 0,
            rebases: 0,
            degraded: false,
        }
    }

    /// An op that needed no planner work at all (e.g. dropping a
    /// sub-fleet whose last member left).
    fn free() -> ShardOpResult {
        ShardOpResult { disposition: Disposition::Applied, ..ShardOpResult::rejected() }
    }

    /// Identity element for [`merge`]: zero cost, `cache_hit = true` so
    /// the all-ops-hit conjunction starts true.  Callers must merge at
    /// least one real op into it before reporting.
    pub fn neutral() -> ShardOpResult {
        ShardOpResult { cache_hit: true, ..ShardOpResult::free() }
    }
}

/// One planner shard and the sub-fleets it hosts (in admission order —
/// iteration order is part of the determinism contract, so tenants live
/// in a `Vec`, never a hash map).
pub(crate) struct Shard {
    pub planner: Planner,
    pub tenants: Vec<(TenantId, SubFleet)>,
}

impl Shard {
    pub fn new(planner: Planner) -> Shard {
        Shard { planner, tenants: Vec::new() }
    }

    /// Devices hosted across every tenant.
    pub fn load(&self) -> usize {
        self.tenants.iter().map(|(_, s)| s.members.len()).sum()
    }

    pub fn sub(&self, tenant: TenantId) -> Option<&SubFleet> {
        self.tenants.iter().find(|(t, _)| *t == tenant).map(|(_, s)| s)
    }

    pub fn sub_mut(&mut self, tenant: TenantId) -> Option<&mut SubFleet> {
        self.tenants.iter_mut().find(|(t, _)| *t == tenant).map(|(_, s)| s)
    }

    pub fn remove_sub(&mut self, tenant: TenantId) -> Option<SubFleet> {
        let i = self.tenants.iter().position(|(t, _)| *t == tenant)?;
        Some(self.tenants.remove(i).1)
    }

    /// Restore a snapshot taken before a speculative op (`None` = the
    /// sub-fleet did not exist).  Planner caches are left as-is: they are
    /// fingerprint-keyed values, so stale entries are harmless.
    pub fn restore_sub(&mut self, tenant: TenantId, snapshot: Option<SubFleet>) {
        match (self.tenants.iter().position(|(t, _)| *t == tenant), snapshot) {
            (Some(i), Some(s)) => self.tenants[i].1 = s,
            (Some(i), None) => {
                self.tenants.remove(i);
            }
            (None, Some(s)) => self.tenants.push((tenant, s)),
            (None, None) => {}
        }
    }

    /// Cold-plan a brand-new sub-fleet (tenant admission, or a join that
    /// opens a new shard for the tenant).  On success the sub-fleet is
    /// installed; on failure nothing is.
    pub fn cold_admit(
        &mut self,
        tenant: TenantId,
        members: Vec<usize>,
        scenario: Scenario,
        bound: RiskBound,
    ) -> Result<ShardOpResult, PlanError> {
        debug_assert_eq!(members.len(), scenario.n());
        let outcome = self
            .planner
            .plan(&PlanRequest::new(scenario.clone(), Policy::Robust).with_bound(bound))?;
        let hit = outcome.diagnostics.cache_hit;
        let result = ShardOpResult {
            disposition: Disposition::Applied,
            newton_iters: outcome.diagnostics.newton_iters,
            outer_iters: outcome.diagnostics.outer_iters,
            cache_hit: hit,
            warm_started: outcome.diagnostics.warm_started,
            ops: 1,
            replans: 0,
            hits: usize::from(hit),
            rebases: 0,
            degraded: outcome.diagnostics.degraded,
        };
        self.tenants.push((tenant, SubFleet { members, scenario, outcome }));
        Ok(result)
    }

    /// Apply one local (shard-indexed) parameter delta for `tenant`:
    /// cache probe → warm replan → absorb (environmental) or reject
    /// (negotiable).  The caller guarantees the sub-fleet exists.
    pub fn apply_param(
        &mut self,
        tenant: TenantId,
        delta: &ScenarioDelta,
        environmental: bool,
    ) -> ShardOpResult {
        // lint:allow(panic-path): documented precondition — the service
        // routes apply_param only to shards hosting the tenant
        let sub = self.sub(tenant).expect("apply_param requires a hosted sub-fleet");
        let (base_sc, base_out) = (sub.scenario.clone(), sub.outcome.clone());
        let new_sc = match delta.apply(&base_sc) {
            Ok(s) => s,
            Err(_) => return ShardOpResult::rejected(),
        };
        // The sub-fleet's active bound rides on its last outcome; a
        // Bound delta probes/replans under the *new* bound it installs.
        let bound = match delta {
            ScenarioDelta::Bound(b) => *b,
            _ => base_out.bound,
        };
        // lint:allow(panic-path): the base pair was produced by this same
        // planner, so its shape check cannot fail
        self.planner.set_base(base_sc, base_out).expect("sub-fleet base shape is consistent");
        // Borrow-only cache probe (no scenario clone unless it hits) —
        // the same call the serial fleet driver makes, so the shards=1 ≡
        // serial byte-parity pin holds op for op.
        if let Some(hit) = self.planner.plan_cached_for(&new_sc, &Policy::Robust, bound) {
            // The hit carries the original solve's diagnostics; report
            // its warm_started flag exactly like the serial driver does.
            let warm_started = hit.diagnostics.warm_started;
            let degraded = hit.diagnostics.degraded;
            // lint:allow(panic-path): sub() succeeded at entry
            let sub = self.sub_mut(tenant).expect("checked above");
            sub.scenario = new_sc;
            sub.outcome = hit;
            return ShardOpResult {
                disposition: Disposition::Applied,
                newton_iters: 0,
                outer_iters: 0,
                cache_hit: true,
                warm_started,
                ops: 1,
                replans: 0,
                hits: 1,
                rebases: 0,
                degraded,
            };
        }
        match self.planner.replan(delta) {
            Ok(out) => {
                let result = ShardOpResult {
                    disposition: Disposition::Applied,
                    newton_iters: out.diagnostics.newton_iters,
                    outer_iters: out.diagnostics.outer_iters,
                    cache_hit: false,
                    warm_started: out.diagnostics.warm_started,
                    ops: 1,
                    replans: 1,
                    hits: 0,
                    rebases: 0,
                    degraded: out.diagnostics.degraded,
                };
                // lint:allow(panic-path): sub() succeeded at entry
                let sub = self.sub_mut(tenant).expect("checked above");
                sub.scenario = new_sc;
                sub.outcome = out;
                result
            }
            Err(_) if environmental => match self.planner.rebase(&new_sc) {
                Ok(energy) => {
                    // lint:allow(panic-path): sub() succeeded at entry
                    let sub = self.sub_mut(tenant).expect("checked above");
                    sub.scenario = new_sc;
                    sub.outcome.energy = energy;
                    let degraded = sub.outcome.diagnostics.degraded;
                    ShardOpResult {
                        disposition: Disposition::Absorbed,
                        newton_iters: 0,
                        outer_iters: 0,
                        cache_hit: false,
                        warm_started: false,
                        ops: 1,
                        replans: 1,
                        hits: 0,
                        rebases: 1,
                        degraded,
                    }
                }
                Err(_) => {
                    let mut r = ShardOpResult::rejected();
                    r.ops = 1;
                    r.replans = 1;
                    r
                }
            },
            Err(_) => {
                let mut r = ShardOpResult::rejected();
                r.ops = 1;
                r.replans = 1;
                r
            }
        }
    }

    /// Admit a joining device (tenant index `tenant_idx`) into this
    /// shard's existing sub-fleet at bandwidth share `share_hz`.  The
    /// share grows (or stays equal) on a join, so it is applied before
    /// the membership change; the whole op rolls back on rejection.
    pub fn apply_join(
        &mut self,
        tenant: TenantId,
        tenant_idx: usize,
        dev: Device,
        share_hz: f64,
    ) -> ShardOpResult {
        // lint:allow(panic-path): documented precondition — cold joins go
        // through cold_admit, not here
        let sub = self.sub(tenant).expect("apply_join requires a hosted sub-fleet");
        let snapshot = Some(sub.clone());
        let current_share = sub.scenario.total_bandwidth_hz;
        let mut acc = ShardOpResult::neutral();
        if share_hz != current_share {
            let grow = self.apply_param(tenant, &ScenarioDelta::TotalBandwidth(share_hz), false);
            if grow.disposition != Disposition::Applied {
                self.restore_sub(tenant, snapshot);
                return ShardOpResult::rejected();
            }
            merge(&mut acc, &grow);
        }
        let join = self.apply_param(tenant, &ScenarioDelta::Join(dev), false);
        if join.disposition != Disposition::Applied {
            self.restore_sub(tenant, snapshot);
            return ShardOpResult::rejected();
        }
        merge(&mut acc, &join);
        // lint:allow(panic-path): the join applied, so the sub-fleet exists
        self.sub_mut(tenant).expect("join succeeded").members.push(tenant_idx);
        acc
    }

    /// Remove local member `local_idx` and then shrink the shard's share
    /// to `share_after_hz`.  A sub-fleet losing its last member is
    /// dropped outright (no planner work).  The leave itself is
    /// negotiable (reject ⇒ rollback); the post-accept share shrink is
    /// environmental and may be absorbed.
    pub fn apply_leave(
        &mut self,
        tenant: TenantId,
        local_idx: usize,
        share_after_hz: f64,
    ) -> ShardOpResult {
        // lint:allow(panic-path): documented precondition — the service
        // locates the leaving device on this shard before calling in
        let sub = self.sub(tenant).expect("apply_leave requires a hosted sub-fleet");
        if sub.members.len() == 1 {
            self.remove_sub(tenant);
            return ShardOpResult::free();
        }
        let snapshot = Some(sub.clone());
        let current_share = sub.scenario.total_bandwidth_hz;
        let leave = self.apply_param(tenant, &ScenarioDelta::Leave(local_idx), false);
        if leave.disposition != Disposition::Applied {
            self.restore_sub(tenant, snapshot);
            return ShardOpResult::rejected();
        }
        let mut acc = ShardOpResult::neutral();
        merge(&mut acc, &leave);
        // lint:allow(panic-path): >1 member before the leave, so the
        // sub-fleet survives it
        self.sub_mut(tenant).expect("leave succeeded").members.remove(local_idx);
        if share_after_hz != current_share {
            // The leave is already committed, so an infeasible shrink is
            // absorbed by apply_param; the aggregate stays `Applied` and
            // the `rebases` count records the absorption.
            let shrink =
                self.apply_param(tenant, &ScenarioDelta::TotalBandwidth(share_after_hz), true);
            merge(&mut acc, &shrink);
        }
        acc
    }
}

/// Fold one op's counters into an accumulator (disposition keeps the
/// accumulator's value; callers decide the aggregate disposition).
pub(crate) fn merge(acc: &mut ShardOpResult, op: &ShardOpResult) {
    acc.newton_iters += op.newton_iters;
    acc.outer_iters += op.outer_iters;
    acc.cache_hit = acc.cache_hit && op.cache_hit;
    acc.warm_started = acc.warm_started || op.warm_started;
    acc.ops += op.ops;
    acc.replans += op.replans;
    acc.hits += op.hits;
    acc.rebases += op.rebases;
    acc.degraded = acc.degraded || op.degraded;
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::PlannerBuilder;
    use crate::models::ModelProfile;
    use crate::util::rng::Rng;

    fn scenario(n: usize, seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), n, 10e6, 0.25, 0.05, &mut rng)
    }

    fn shard() -> Shard {
        Shard::new(PlannerBuilder::new().threads(1).build())
    }

    #[test]
    fn cold_admit_installs_and_load_counts() {
        let mut s = shard();
        let sc = scenario(3, 1);
        let r = s.cold_admit(7, vec![0, 1, 2], sc, RiskBound::Ecr).unwrap();
        assert_eq!(r.disposition, Disposition::Applied);
        assert!(r.newton_iters > 0);
        assert_eq!(s.load(), 3);
        assert_eq!(s.sub(7).unwrap().members, vec![0, 1, 2]);
    }

    #[test]
    fn multiplexes_two_tenants_through_set_base() {
        let mut s = shard();
        s.cold_admit(1, vec![0, 1], scenario(2, 2), RiskBound::Ecr).unwrap();
        s.cold_admit(2, vec![0, 1, 2], scenario(3, 3), RiskBound::Ecr).unwrap();
        // Interleave replans: each must apply to its own tenant's base.
        let a = s.apply_param(1, &ScenarioDelta::TotalBandwidth(12e6), true);
        let b = s.apply_param(2, &ScenarioDelta::TotalBandwidth(9e6), true);
        let a2 = s.apply_param(1, &ScenarioDelta::Risk { device: Some(0), risk: 0.08 }, false);
        for r in [&a, &b, &a2] {
            assert_ne!(r.disposition, Disposition::Rejected);
        }
        assert_eq!(s.sub(1).unwrap().scenario.total_bandwidth_hz, 12e6);
        assert_eq!(s.sub(2).unwrap().scenario.total_bandwidth_hz, 9e6);
        assert_eq!(s.sub(1).unwrap().scenario.devices[0].risk, 0.08);
        assert_eq!(s.sub(1).unwrap().scenario.n(), 2);
        assert_eq!(s.sub(2).unwrap().scenario.n(), 3);
    }

    #[test]
    fn join_and_leave_maintain_members() {
        let mut s = shard();
        let sc = scenario(2, 4);
        let joiner = sc.devices[0].clone();
        s.cold_admit(1, vec![0, 1], sc, RiskBound::Ecr).unwrap();
        let r = s.apply_join(1, 2, joiner, 10e6);
        assert_eq!(r.disposition, Disposition::Applied);
        assert_eq!(s.sub(1).unwrap().members, vec![0, 1, 2]);
        assert_eq!(s.load(), 3);
        let r = s.apply_leave(1, 1, 10e6);
        assert_eq!(r.disposition, Disposition::Applied);
        assert_eq!(s.sub(1).unwrap().members, vec![0, 2]);
    }

    #[test]
    fn last_member_leave_drops_the_sub_fleet_for_free() {
        let mut s = shard();
        s.cold_admit(1, vec![5], scenario(1, 5), RiskBound::Ecr).unwrap();
        let r = s.apply_leave(1, 0, 0.0);
        assert_eq!(r.disposition, Disposition::Applied);
        assert_eq!(r.newton_iters, 0);
        assert!(s.sub(1).is_none());
        assert_eq!(s.load(), 0);
    }

    #[test]
    fn rejected_join_rolls_back() {
        let mut s = shard();
        let sc = scenario(2, 6);
        let mut impossible = sc.devices[0].clone();
        impossible.deadline_s = 1e-4; // unmeetable
        s.cold_admit(1, vec![0, 1], sc, RiskBound::Ecr).unwrap();
        let before = s.sub(1).unwrap().clone();
        let r = s.apply_join(1, 2, impossible, 10e6);
        assert_eq!(r.disposition, Disposition::Rejected);
        let after = s.sub(1).unwrap();
        assert_eq!(after.members, before.members);
        assert_eq!(after.scenario.n(), before.scenario.n());
        assert_eq!(after.outcome.energy.to_bits(), before.outcome.energy.to_bits());
    }

    #[test]
    fn bound_delta_switches_the_sub_fleets_margins() {
        let mut s = shard();
        s.cold_admit(1, vec![0, 1], scenario(2, 9), RiskBound::Ecr).unwrap();
        let ecr_energy = s.sub(1).unwrap().outcome.energy;
        let r = s.apply_param(1, &ScenarioDelta::Bound(RiskBound::Gaussian), false);
        assert_eq!(r.disposition, Disposition::Applied);
        let sub = s.sub(1).unwrap();
        assert_eq!(sub.outcome.bound, RiskBound::Gaussian);
        assert!(sub.outcome.energy <= ecr_energy * (1.0 + 1e-9), "tighter margins cannot cost");
        // Follow-up parameter deltas keep planning under the new bound.
        let r2 = s.apply_param(1, &ScenarioDelta::TotalBandwidth(11e6), true);
        assert_ne!(r2.disposition, Disposition::Rejected);
        assert_eq!(s.sub(1).unwrap().outcome.bound, RiskBound::Gaussian);
    }

    #[test]
    fn environmental_infeasibility_is_absorbed() {
        let mut s = shard();
        s.cold_admit(1, vec![0, 1, 2], scenario(3, 7), RiskBound::Ecr).unwrap();
        let energy_before = s.sub(1).unwrap().outcome.energy;
        // Crush the shared uplink budget: no feasible replan exists, but
        // the fact is environmental, so the scenario must roll forward
        // with the old plan kept.
        let r = s.apply_param(1, &ScenarioDelta::TotalBandwidth(1e3), true);
        assert_eq!(r.disposition, Disposition::Absorbed);
        assert_eq!(r.rebases, 1);
        let sub = s.sub(1).unwrap();
        assert_eq!(sub.scenario.total_bandwidth_hz, 1e3);
        // Re-priced energy differs from the planned one in general; the
        // plan itself is unchanged.
        assert_eq!(sub.outcome.plan.partition.len(), 3);
        assert!(sub.outcome.energy.is_finite());
        let _ = energy_before;
    }
}
