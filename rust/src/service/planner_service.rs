//! The [`PlannerService`]: K planner shards behind a bounded request
//! queue, with deterministic device→shard routing, batched coalescing
//! drains, load-factor rebalancing, and aggregated metrics.
//!
//! Every result-affecting iteration walks `Vec`s in fixed order (tenants
//! in admission order, shards ascending) and the drain fan-out places
//! results in index-ordered slots, so for a given request sequence the
//! service's output is bit-identical at any thread count.

use crate::engine::{
    device_fingerprint, CacheStats, PlanError, PlannerBuilder, RiskBound, ScenarioDelta,
};
use crate::optim::types::{Device, Plan, Scenario};
use crate::util::par::{par_map_indexed_mut, threads_for};

use super::queue::{is_membership, superseded_by, DeltaQueue, Request};
use super::shard::{merge, Shard, ShardOpResult, SubFleet};
use super::{Disposition, ServiceError, ServiceOutcome, ServiceStats, TenantId};

/// Configuration for a [`PlannerService`].
#[derive(Clone, Debug)]
pub struct ServiceOptions {
    /// Number of independent planner shards (K ≥ 1).
    pub shards: usize,
    /// Bounded request-queue capacity (≥ 1); a full queue refuses
    /// submissions with [`ServiceError::Backpressure`].
    pub queue_capacity: usize,
    /// Load-factor bound: every shard's device count stays ≤
    /// `max(1, ceil(load_factor · total / K))` (rebalancing moves devices
    /// when membership churn violates it).  Must be ≥ 1.
    pub load_factor: f64,
    /// Worker threads for the drain's shard fan-out and each planner's
    /// per-device fan-out (0 = one per core; never changes results).
    pub threads: usize,
    /// Per-shard planner LRU cache capacity.
    pub cache_capacity: usize,
    /// Per-tenant circuit breaker: this many *consecutive* rejected
    /// requests (planner failures / infeasibility) open the tenant's
    /// breaker, after which [`PlannerService::submit`] refuses with
    /// [`ServiceError::CircuitOpen`] until the cooldown elapses and a
    /// half-open probe succeeds.  `0` disables the breaker entirely
    /// (the default — and what the fleet driver uses, preserving the
    /// shards = 1 ≡ serial byte-parity contract).
    pub breaker_threshold: usize,
    /// Drains an open breaker stays open before going half-open.
    pub breaker_cooldown: usize,
    /// Cohort-compressed robust solves on every shard planner
    /// ([`crate::optim::cohort`]).  Cohorts never straddle shards by
    /// construction: routing keys on the same [`device_fingerprint`]
    /// that defines cohort membership, so equal-fingerprint devices land
    /// on the same shard (only a load-bound overflow spill can separate
    /// them, and correctness never depends on co-location — compression
    /// is per shard and each member is feasibility-re-checked).  Off by
    /// default; an off service is byte-identical to the pre-cohort one.
    pub cohorts: bool,
}

impl Default for ServiceOptions {
    fn default() -> Self {
        ServiceOptions {
            shards: 4,
            queue_capacity: 256,
            load_factor: 1.25,
            threads: 0,
            cache_capacity: 32,
            breaker_threshold: 0,
            breaker_cooldown: 2,
            cohorts: false,
        }
    }
}

impl ServiceOptions {
    fn validate(&self) -> Result<(), ServiceError> {
        if self.shards == 0 {
            return Err(ServiceError::InvalidOptions("shards must be >= 1".into()));
        }
        if !(self.load_factor.is_finite() && self.load_factor >= 1.0) {
            return Err(ServiceError::InvalidOptions(format!(
                "load_factor must be >= 1, got {}",
                self.load_factor
            )));
        }
        Ok(())
    }
}

/// Circuit-breaker state of one tenant (see
/// [`ServiceOptions::breaker_threshold`]).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Breaker {
    /// Requests flow normally.
    Closed,
    /// Submissions refused for `remaining` more drains.
    Open { remaining: usize },
    /// Cooldown elapsed: requests flow again as probes — one success
    /// closes the breaker, one rejection re-opens it.
    HalfOpen,
}

/// Tenant-level bookkeeping (the authoritative per-device state lives in
/// the shards' sub-fleets).
struct TenantState {
    id: TenantId,
    total_bandwidth_hz: f64,
    devices: usize,
    /// Consecutive rejected requests (resets on any success).
    failures: usize,
    breaker: Breaker,
}

/// One parameter op scheduled onto a shard during a drain wave.
struct WaveOp {
    req: usize,
    tenant: TenantId,
    delta: ScenarioDelta,
    environmental: bool,
}

/// The sharded multi-tenant planning service (see the module docs of
/// [`crate::service`] for the full protocol).
pub struct PlannerService {
    opts: ServiceOptions,
    shards: Vec<Shard>,
    tenants: Vec<TenantState>,
    queue: DeltaQueue,
    stats: ServiceStats,
}

/// Mix a tenant id into a device fingerprint so two tenants' identical
/// devices spread independently.
fn route_mix(tenant: TenantId, dev: &Device) -> u64 {
    device_fingerprint(dev) ^ tenant.wrapping_mul(0x9E37_79B9_7F4A_7C15)
}

/// Bandwidth share of a shard holding `k` of the tenant's `n` devices.
/// The sole-shard case returns the budget exactly (no roundtrip through
/// `b·k/n`), which is what makes a one-shard service bit-identical to
/// the serial planner path.
fn share_hz(b: f64, k: usize, n: usize) -> f64 {
    if k == n {
        b
    } else {
        b * k as f64 / n as f64
    }
}

fn argmin(loads: &[usize]) -> usize {
    let mut best = 0;
    for (i, &l) in loads.iter().enumerate() {
        if l < loads[best] {
            best = i;
        }
    }
    best
}

fn argmax(loads: &[usize]) -> usize {
    let mut best = 0;
    for (i, &l) in loads.iter().enumerate() {
        if l > loads[best] {
            best = i;
        }
    }
    best
}

impl PlannerService {
    /// Build a service with `opts.shards` independent shard planners and
    /// an empty bounded queue.  Fails with
    /// [`ServiceError::InvalidOptions`] on a zero shard count or a load
    /// factor below 1.
    pub fn new(opts: ServiceOptions) -> Result<PlannerService, ServiceError> {
        opts.validate()?;
        let shards = (0..opts.shards)
            .map(|_| {
                Shard::new(
                    PlannerBuilder::new()
                        .threads(opts.threads)
                        .cache_capacity(opts.cache_capacity)
                        .cohorts(opts.cohorts)
                        .build(),
                )
            })
            .collect();
        let queue = DeltaQueue::new(opts.queue_capacity);
        let stats = ServiceStats::default();
        Ok(PlannerService { opts, shards, tenants: Vec::new(), queue, stats })
    }

    // ---- accessors --------------------------------------------------------

    /// The options the service was built with.
    pub fn options(&self) -> &ServiceOptions {
        &self.opts
    }

    /// Number of shard planners (fixed at construction).
    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    /// Devices hosted per shard, ascending shard order.
    pub fn shard_loads(&self) -> Vec<usize> {
        self.shards.iter().map(|s| s.load()).collect()
    }

    /// The load bound at the current total device count.
    pub fn current_load_bound(&self) -> usize {
        self.load_bound(self.shard_loads().iter().sum())
    }

    fn load_bound(&self, total: usize) -> usize {
        let k = self.shards.len() as f64;
        ((self.opts.load_factor * total as f64 / k).ceil() as usize).max(1)
    }

    /// Number of currently admitted tenants.
    pub fn tenant_count(&self) -> usize {
        self.tenants.len()
    }

    fn tenant_index(&self, id: TenantId) -> Option<usize> {
        self.tenants.iter().position(|t| t.id == id)
    }

    /// Device count of an admitted tenant (`None` if un-admitted).
    pub fn tenant_devices(&self, id: TenantId) -> Option<usize> {
        self.tenant_index(id).map(|t| self.tenants[t].devices)
    }

    /// Total bandwidth budget of an admitted tenant, Hz (`None` if
    /// un-admitted).
    pub fn tenant_bandwidth(&self, id: TenantId) -> Option<f64> {
        self.tenant_index(id).map(|t| self.tenants[t].total_bandwidth_hz)
    }

    /// The tenant's nearest (smallest) device deadline, seconds, across
    /// every shard-hosted sub-fleet — the key [`PlannerService::drain`]
    /// uses for SLO-aware scheduling (`None` if un-admitted).
    pub fn tenant_nearest_deadline(&self, id: TenantId) -> Option<f64> {
        self.tenant_index(id)?;
        let mut nearest = f64::INFINITY;
        for shard in &self.shards {
            if let Some(sub) = shard.sub(id) {
                for d in &sub.scenario.devices {
                    if d.deadline_s < nearest {
                        nearest = d.deadline_s;
                    }
                }
            }
        }
        Some(nearest)
    }

    /// Tenant-wide planned energy: Σ over shards of the sub-fleet's last
    /// outcome energy (ascending shard order — deterministic summation).
    pub fn tenant_energy(&self, id: TenantId) -> Option<f64> {
        self.tenant_index(id)?;
        let mut e = 0.0;
        for shard in &self.shards {
            if let Some(sub) = shard.sub(id) {
                e += sub.outcome.energy;
            }
        }
        Some(e)
    }

    /// The tenant's fleet-wide decision, assembled from the shard plans
    /// (device `i`'s row comes from the shard hosting it).  Shard shares
    /// sum to the tenant budget, so the assembled plan satisfies
    /// Σ b ≤ B whenever no absorbed share update is outstanding.
    pub fn assembled_plan(&self, id: TenantId) -> Option<Plan> {
        let t = self.tenant_index(id)?;
        let n = self.tenants[t].devices;
        let mut plan = Plan {
            partition: vec![0; n],
            bandwidth_hz: vec![0.0; n],
            freq_ghz: vec![0.0; n],
        };
        for shard in &self.shards {
            if let Some(sub) = shard.sub(id) {
                for (l, &i) in sub.members.iter().enumerate() {
                    plan.partition[i] = sub.outcome.plan.partition[l];
                    plan.bandwidth_hz[i] = sub.outcome.plan.bandwidth_hz[l];
                    plan.freq_ghz[i] = sub.outcome.plan.freq_ghz[l];
                }
            }
        }
        Some(plan)
    }

    /// The tenant's fleet-wide scenario view (devices in tenant order,
    /// total bandwidth = the tenant's full budget).
    pub fn assembled_scenario(&self, id: TenantId) -> Option<Scenario> {
        let t = self.tenant_index(id)?;
        let n = self.tenants[t].devices;
        let mut devices: Vec<Option<Device>> = vec![None; n];
        for shard in &self.shards {
            if let Some(sub) = shard.sub(id) {
                for (l, &i) in sub.members.iter().enumerate() {
                    devices[i] = Some(sub.scenario.devices[l].clone());
                }
            }
        }
        Some(Scenario {
            // lint:allow(panic-path): routing invariant — every tenant
            // device index is hosted by exactly one shard
            devices: devices.into_iter().map(|d| d.expect("every device is hosted")).collect(),
            total_bandwidth_hz: self.tenants[t].total_bandwidth_hz,
        })
    }

    /// Shard hosting each of the tenant's devices, by tenant index.
    pub fn device_shards(&self, id: TenantId) -> Option<Vec<usize>> {
        let t = self.tenant_index(id)?;
        let n = self.tenants[t].devices;
        let mut out = vec![usize::MAX; n];
        for (s, shard) in self.shards.iter().enumerate() {
            if let Some(sub) = shard.sub(id) {
                for &i in &sub.members {
                    out[i] = s;
                }
            }
        }
        Some(out)
    }

    /// Deterministic service counters (includes queue refusals).
    pub fn stats(&self) -> ServiceStats {
        ServiceStats { refused: self.queue.refused(), ..self.stats }
    }

    /// Plan-cache counters aggregated over every shard planner.
    pub fn cache_stats(&self) -> CacheStats {
        let mut agg = CacheStats::default();
        for shard in &self.shards {
            agg.absorb(&shard.planner.cache_stats());
        }
        agg
    }

    /// Per-shard plan-cache counters, ascending shard order.
    pub fn shard_cache_stats(&self) -> Vec<CacheStats> {
        self.shards.iter().map(|s| s.planner.cache_stats()).collect()
    }

    /// Pending requests in the bounded queue.
    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    /// Capacity of the bounded queue (fixed at construction, minimum 1).
    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    // ---- admission --------------------------------------------------------

    /// Admit a tenant fleet: route every device to a shard (fingerprint
    /// hash, overflow to the least-loaded shard when the load bound would
    /// be violated), split the bandwidth budget proportionally, and
    /// cold-plan every sub-fleet in parallel.  All-or-nothing: if any
    /// sub-fleet is unplannable the tenant is not admitted and the first
    /// shard error (ascending order) is returned.
    pub fn admit_tenant(
        &mut self,
        id: TenantId,
        scenario: Scenario,
    ) -> Result<ServiceOutcome, ServiceError> {
        self.admit_tenant_with(id, scenario, RiskBound::Ecr)
    }

    /// [`PlannerService::admit_tenant`] under an explicit risk bound —
    /// every sub-fleet of the tenant plans with it, and a later
    /// fleet-wide [`ScenarioDelta::Bound`] broadcast can change it
    /// transactionally.
    pub fn admit_tenant_with(
        &mut self,
        id: TenantId,
        scenario: Scenario,
        bound: RiskBound,
    ) -> Result<ServiceOutcome, ServiceError> {
        if self.tenant_index(id).is_some() {
            return Err(ServiceError::DuplicateTenant(id));
        }
        let n = scenario.n();
        if n == 0 {
            return Err(ServiceError::Plan(PlanError::InvalidRequest(
                "tenant scenario has no devices".into(),
            )));
        }
        let b = scenario.total_bandwidth_hz;
        let k = self.shards.len();
        let mut loads: Vec<usize> = self.shards.iter().map(|s| s.load()).collect();
        let load_cap = self.load_bound(loads.iter().sum::<usize>() + n);
        let mut members: Vec<Vec<usize>> = vec![Vec::new(); k];
        for (i, d) in scenario.devices.iter().enumerate() {
            let mut s = (route_mix(id, d) % k as u64) as usize;
            if loads[s] + 1 > load_cap {
                s = argmin(&loads);
            }
            loads[s] += 1;
            members[s].push(i);
        }
        let subs: Vec<Option<(Vec<usize>, Scenario)>> = members
            .into_iter()
            .map(|m| {
                if m.is_empty() {
                    return None;
                }
                let devices = m.iter().map(|&i| scenario.devices[i].clone()).collect();
                let share = share_hz(b, m.len(), n);
                Some((m, Scenario { devices, total_bandwidth_hz: share }))
            })
            .collect();
        let threads = threads_for(self.opts.threads, k);
        let results: Vec<Option<Result<ShardOpResult, PlanError>>> = {
            let subs = &subs;
            par_map_indexed_mut(&mut self.shards, threads, |shard, s| {
                subs[s].clone().map(|(m, sc)| shard.cold_admit(id, m, sc, bound))
            })
        };
        let mut err: Option<PlanError> = None;
        let mut acc = ShardOpResult::neutral();
        for r in results {
            match r {
                None => {}
                Some(Ok(op)) => {
                    self.note_op(&op);
                    merge(&mut acc, &op);
                }
                Some(Err(e)) => err = err.or(Some(e)),
            }
        }
        if let Some(e) = err {
            for shard in &mut self.shards {
                shard.remove_sub(id);
            }
            return Err(ServiceError::Plan(e));
        }
        self.tenants.push(TenantState {
            id,
            total_bandwidth_hz: b,
            devices: n,
            failures: 0,
            breaker: Breaker::Closed,
        });
        Ok(self.outcome_of(id, Disposition::Applied, acc))
    }

    /// Evict a tenant and drop its sub-fleets (no planner work; cached
    /// plans age out of the LRUs naturally).
    pub fn remove_tenant(&mut self, id: TenantId) -> bool {
        let Some(t) = self.tenant_index(id) else { return false };
        self.tenants.remove(t);
        for shard in &mut self.shards {
            shard.remove_sub(id);
        }
        true
    }

    // ---- request intake ---------------------------------------------------

    /// Enqueue one tenant-level delta.  Refuses with
    /// [`ServiceError::Backpressure`] when the bounded queue is full and
    /// with [`ServiceError::UnknownTenant`] for un-admitted tenants;
    /// nothing is ever dropped silently.
    pub fn submit(&mut self, tenant: TenantId, delta: ScenarioDelta) -> Result<(), ServiceError> {
        let Some(t) = self.tenant_index(tenant) else {
            return Err(ServiceError::UnknownTenant(tenant));
        };
        if matches!(self.tenants[t].breaker, Breaker::Open { .. }) {
            return Err(ServiceError::CircuitOpen(tenant));
        }
        self.queue.submit(Request { tenant, delta })?;
        self.stats.submitted += 1;
        Ok(())
    }

    /// Record one load-shed refusal that bypassed [`PlannerService::submit`].
    ///
    /// The lock-sharded wire server ([`crate::service::server`]) bounds
    /// intake with an atomic reservation over its per-shard submit
    /// queues, so an over-capacity delta is dropped before it ever
    /// reaches this service.  Counting the drop here keeps the
    /// `refused` stat — and every `stats` wire response built from it —
    /// byte-identical to the single-lock serving path, where the same
    /// overload would have been refused by the bounded queue itself.
    pub fn record_shed(&mut self) {
        self.queue.record_refusal();
    }

    /// [`PlannerService::submit`] with bounded retry on
    /// [`ServiceError::Backpressure`]: each refusal triggers one
    /// [`PlannerService::drain`] (freeing the queue) whose outcomes are
    /// returned so the caller never loses them, then the submission is
    /// retried — at most `max_retries` times.  Other errors (unknown
    /// tenant, open breaker) are returned immediately; retrying cannot
    /// help them.
    pub fn submit_with_retry(
        &mut self,
        tenant: TenantId,
        delta: ScenarioDelta,
        max_retries: usize,
    ) -> Result<Vec<ServiceOutcome>, ServiceError> {
        let mut drained = Vec::new();
        for attempt in 0..=max_retries {
            match self.submit(tenant, delta.clone()) {
                Ok(()) => return Ok(drained),
                Err(ServiceError::Backpressure { capacity }) => {
                    if attempt == max_retries {
                        return Err(ServiceError::Backpressure { capacity });
                    }
                    drained.extend(self.drain());
                }
                Err(e) => return Err(e),
            }
        }
        unreachable!("loop returns on the last attempt")
    }

    /// Mark the edge server reachable/unreachable on every shard
    /// planner: while unreachable each sub-fleet degrades to the
    /// engine's all-local fallback (see
    /// [`crate::engine::Planner::set_edge_available`]).
    pub fn set_edge_available(&mut self, up: bool) {
        for shard in &mut self.shards {
            shard.planner.set_edge_available(up);
        }
    }

    /// Whether the tenant's circuit breaker is currently open (`None`
    /// for un-admitted tenants).
    pub fn breaker_open(&self, tenant: TenantId) -> Option<bool> {
        self.tenant_index(tenant)
            .map(|t| matches!(self.tenants[t].breaker, Breaker::Open { .. }))
    }

    /// Process every pending request and return one [`ServiceOutcome`]
    /// per request, in **SLO order**: the batch is stable-sorted by the
    /// submitting tenant's nearest device deadline
    /// ([`PlannerService::tenant_nearest_deadline`], read *before* any
    /// delta in the batch applies), so the tenant closest to missing its
    /// SLO replans first and its requests head the returned outcomes.
    /// Requests from the same tenant keep their submission order.
    ///
    /// Within the batch, later deltas supersede earlier covered ones
    /// (see [`crate::service::queue`]); surviving parameter deltas are
    /// grouped by shard and the shards run in parallel with index-ordered
    /// result slots (fleet-wide deadline/risk writes are transactional —
    /// a rejection on any shard rolls every shard back); membership
    /// changes are barriers handled one at a time (owner shard decides
    /// admission, then the bandwidth-share rebroadcast fans out, then
    /// rebalancing runs).
    pub fn drain(&mut self) -> Vec<ServiceOutcome> {
        // Open breakers cool down one notch per drain; at zero they go
        // half-open and the tenant's next submissions act as probes.
        for t in &mut self.tenants {
            if let Breaker::Open { remaining } = t.breaker {
                t.breaker = match remaining {
                    0 => Breaker::HalfOpen,
                    r => Breaker::Open { remaining: r - 1 },
                };
            }
        }
        let drained = self.queue.drain();
        let reqs = self.slo_order(drained);
        let superseded = superseded_by(&reqs);
        let mut results: Vec<Option<ServiceOutcome>> = (0..reqs.len()).map(|_| None).collect();
        let mut i = 0;
        while i < reqs.len() {
            if is_membership(&reqs[i].delta) {
                results[i] = Some(self.apply_membership(&reqs[i]));
                i += 1;
            } else {
                let mut j = i;
                while j < reqs.len() && !is_membership(&reqs[j].delta) {
                    j += 1;
                }
                self.apply_param_wave(&reqs, &superseded, i, j, &mut results);
                i = j;
            }
        }
        let out: Vec<ServiceOutcome> =
            // lint:allow(panic-path): the drain loop walks every index,
            // so each request slot receives exactly one disposition
            results.into_iter().map(|r| r.expect("every request is disposed")).collect();
        for o in &out {
            self.note_breaker(o.tenant, o.disposition);
        }
        out
    }

    // ---- internals --------------------------------------------------------

    /// Stable-sort a drained batch so the tenant with the nearest device
    /// deadline goes first (unknown tenants sort last and are rejected
    /// downstream).  Stability keeps each tenant's requests in
    /// submission order, which is what the queue's coalescing
    /// (`superseded_by`) and membership barriers assume — both are
    /// intra-tenant relations, so reordering across tenants is safe.
    /// Deadlines are read once, before any delta in the batch applies:
    /// the schedule depends only on pre-drain state.
    fn slo_order(&self, mut reqs: Vec<Request>) -> Vec<Request> {
        let keys: Vec<(TenantId, f64)> = {
            let mut seen: Vec<(TenantId, f64)> = Vec::new();
            for r in &reqs {
                if !seen.iter().any(|(t, _)| *t == r.tenant) {
                    let d = self.tenant_nearest_deadline(r.tenant).unwrap_or(f64::INFINITY);
                    seen.push((r.tenant, d));
                }
            }
            seen
        };
        let key_of = |tenant: TenantId| -> f64 {
            keys.iter()
                .find(|(t, _)| *t == tenant)
                .map(|(_, d)| *d)
                .unwrap_or(f64::INFINITY)
        };
        reqs.sort_by(|a, b| key_of(a.tenant).total_cmp(&key_of(b.tenant)));
        reqs
    }

    /// Feed one disposed request into the tenant's circuit breaker.
    /// No-op when the breaker is disabled (`breaker_threshold == 0`).
    fn note_breaker(&mut self, tenant: TenantId, disposition: Disposition) {
        if self.opts.breaker_threshold == 0 {
            return;
        }
        let Some(t) = self.tenant_index(tenant) else { return };
        let ts = &mut self.tenants[t];
        match disposition {
            Disposition::Applied | Disposition::Absorbed => {
                ts.failures = 0;
                if ts.breaker == Breaker::HalfOpen {
                    ts.breaker = Breaker::Closed;
                }
            }
            Disposition::Rejected => {
                ts.failures += 1;
                let trip = ts.breaker == Breaker::HalfOpen
                    || (ts.breaker == Breaker::Closed
                        && ts.failures >= self.opts.breaker_threshold);
                if trip {
                    ts.breaker = Breaker::Open { remaining: self.opts.breaker_cooldown };
                    self.stats.breaker_trips += 1;
                }
            }
            Disposition::Superseded => {}
        }
    }

    fn note_op(&mut self, op: &ShardOpResult) {
        self.stats.shard_ops += op.ops as u64;
        self.stats.replans += op.replans as u64;
        self.stats.cache_hits += op.hits as u64;
        self.stats.rebases += op.rebases as u64;
    }

    fn outcome_of(
        &self,
        tenant: TenantId,
        disposition: Disposition,
        acc: ShardOpResult,
    ) -> ServiceOutcome {
        let energy_j = match disposition {
            Disposition::Applied | Disposition::Absorbed => {
                self.tenant_energy(tenant).unwrap_or(0.0)
            }
            _ => 0.0,
        };
        ServiceOutcome {
            tenant,
            disposition,
            energy_j,
            newton_iters: acc.newton_iters,
            outer_iters: acc.outer_iters,
            cache_hit: acc.ops > 0 && acc.cache_hit,
            warm_started: acc.warm_started,
            shard_ops: acc.ops,
            degraded: acc.degraded,
        }
    }

    fn idle_outcome(&self, tenant: TenantId, disposition: Disposition) -> ServiceOutcome {
        ServiceOutcome {
            tenant,
            disposition,
            energy_j: 0.0,
            newton_iters: 0,
            outer_iters: 0,
            cache_hit: false,
            warm_started: false,
            shard_ops: 0,
            degraded: false,
        }
    }

    fn locate(&self, id: TenantId, dev_idx: usize) -> Option<(usize, usize)> {
        for (s, shard) in self.shards.iter().enumerate() {
            if let Some(sub) = shard.sub(id) {
                if let Some(l) = sub.members.iter().position(|&m| m == dev_idx) {
                    return Some((s, l));
                }
            }
        }
        None
    }

    fn hosting_shards(&self, id: TenantId) -> Vec<usize> {
        (0..self.shards.len()).filter(|&s| self.shards[s].sub(id).is_some()).collect()
    }

    /// The tenant's active risk bound: every sub-fleet carries it on its
    /// last outcome and fleet-wide Bound broadcasts keep them in
    /// lock-step, so the first hosting shard is authoritative (deriving
    /// it from shard state — instead of a tenant-level field — makes the
    /// transactional rollback of a rejected Bound broadcast free: the
    /// sub-fleet snapshots carry the old bound back).
    pub fn tenant_bound(&self, id: TenantId) -> Option<RiskBound> {
        self.tenant_index(id)?;
        self.shards
            .iter()
            .find_map(|shard| shard.sub(id).map(|sub| sub.outcome.bound))
    }

    /// Translate one tenant-level parameter delta into per-shard local
    /// ops.  `Err(())` = reject without any planner work (bad index /
    /// bad value), mirroring the serial driver's pre-validation.
    fn route_param(&mut self, req: &Request) -> Result<Vec<(usize, ScenarioDelta, bool)>, ()> {
        let t = self.tenant_index(req.tenant).ok_or(())?;
        let n = self.tenants[t].devices;
        match &req.delta {
            ScenarioDelta::Channel { device, uplink } => {
                let (s, l) = self.locate(req.tenant, *device).ok_or(())?;
                Ok(vec![(s, ScenarioDelta::Channel { device: l, uplink: *uplink }, true)])
            }
            ScenarioDelta::Deadline { device: Some(i), deadline_s } => {
                let (s, l) = self.locate(req.tenant, *i).ok_or(())?;
                Ok(vec![(
                    s,
                    ScenarioDelta::Deadline { device: Some(l), deadline_s: *deadline_s },
                    false,
                )])
            }
            ScenarioDelta::Risk { device: Some(i), risk } => {
                let (s, l) = self.locate(req.tenant, *i).ok_or(())?;
                Ok(vec![(s, ScenarioDelta::Risk { device: Some(l), risk: *risk }, false)])
            }
            // Fleet-wide writes: deadline/risk broadcasts and risk-bound
            // recalibrations are transactional across the tenant's
            // shards (negotiable — a rejection on any shard rolls every
            // shard back).
            ScenarioDelta::Deadline { device: None, .. }
            | ScenarioDelta::Risk { device: None, .. }
            | ScenarioDelta::Bound(_) => Ok(self
                .hosting_shards(req.tenant)
                .into_iter()
                .map(|s| (s, req.delta.clone(), false))
                .collect()),
            ScenarioDelta::TotalBandwidth(b) => {
                if !(b.is_finite() && *b > 0.0) {
                    return Err(());
                }
                self.tenants[t].total_bandwidth_hz = *b;
                Ok(self
                    .hosting_shards(req.tenant)
                    .into_iter()
                    .map(|s| {
                        // lint:allow(panic-path): s comes from hosting_shards
                        let k_s = self.shards[s].sub(req.tenant).expect("hosting").members.len();
                        (s, ScenarioDelta::TotalBandwidth(share_hz(*b, k_s, n)), true)
                    })
                    .collect())
            }
            ScenarioDelta::Join(_) | ScenarioDelta::Leave(_) => {
                unreachable!("membership requests are handled as barriers")
            }
        }
    }

    /// One drain wave of parameter requests `[lo, hi)`: group surviving
    /// ops by shard, fan the shards out in parallel, merge per-request
    /// results in ascending shard order.
    fn apply_param_wave(
        &mut self,
        reqs: &[Request],
        superseded: &[Option<usize>],
        lo: usize,
        hi: usize,
        results: &mut [Option<ServiceOutcome>],
    ) {
        let k = self.shards.len();
        let mut ops: Vec<Vec<WaveOp>> = (0..k).map(|_| Vec::new()).collect();
        // Multi-shard *negotiable* broadcasts (fleet-wide deadline/risk
        // writes) are transactional: snapshot every touched sub-fleet so
        // a rejection on any shard rolls the others back instead of
        // leaving the tenant half-committed.  Environmental broadcasts
        // never reject (rebase absorbs them), so they need no snapshot.
        let mut rollbacks: Vec<(usize, Vec<(usize, SubFleet)>)> = Vec::new();
        for r in lo..hi {
            let req = &reqs[r];
            if superseded[r].is_some() {
                self.stats.superseded += 1;
                results[r] = Some(self.idle_outcome(req.tenant, Disposition::Superseded));
                continue;
            }
            match self.route_param(req) {
                Err(()) => {
                    self.stats.rejected += 1;
                    results[r] = Some(self.idle_outcome(req.tenant, Disposition::Rejected));
                }
                Ok(list) => {
                    if list.len() > 1 && list.iter().any(|(_, _, env)| !env) {
                        let snaps = list
                            .iter()
                            .map(|&(s, ..)| {
                                // lint:allow(panic-path): route_param only
                                // emits shards that host the tenant
                                let sub = self.shards[s].sub(req.tenant).expect("hosting");
                                (s, sub.clone())
                            })
                            .collect();
                        rollbacks.push((r, snaps));
                    }
                    for (s, delta, environmental) in list {
                        ops[s].push(WaveOp { req: r, tenant: req.tenant, delta, environmental });
                    }
                }
            }
        }
        if ops.iter().all(|o| o.is_empty()) {
            return;
        }
        let threads = threads_for(self.opts.threads, k);
        let shard_results: Vec<Vec<(usize, ShardOpResult)>> = {
            let ops = &ops;
            par_map_indexed_mut(&mut self.shards, threads, |shard, s| {
                ops[s]
                    .iter()
                    .map(|op| (op.req, shard.apply_param(op.tenant, &op.delta, op.environmental)))
                    .collect()
            })
        };
        let mut acc: Vec<Option<ShardOpResult>> = (lo..hi).map(|_| None).collect();
        for per_shard in shard_results {
            for (r, op) in per_shard {
                self.note_op(&op);
                let slot = &mut acc[r - lo];
                match slot {
                    None => *slot = Some(op),
                    Some(a) => {
                        // Any shard rejection dominates, then absorption.
                        let d = match (a.disposition, op.disposition) {
                            (Disposition::Rejected, _) | (_, Disposition::Rejected) => {
                                Disposition::Rejected
                            }
                            (Disposition::Absorbed, _) | (_, Disposition::Absorbed) => {
                                Disposition::Absorbed
                            }
                            _ => Disposition::Applied,
                        };
                        merge(a, &op);
                        a.disposition = d;
                    }
                }
            }
        }
        for (off, slot) in acc.into_iter().enumerate() {
            if let Some(a) = slot {
                let tenant = reqs[lo + off].tenant;
                let disposition = a.disposition;
                if disposition == Disposition::Rejected {
                    self.stats.rejected += 1;
                }
                results[lo + off] = Some(self.outcome_of(tenant, disposition, a));
            }
        }
        // Undo partially-committed negotiable broadcasts.
        for (r, snaps) in rollbacks {
            let rejected = results[r]
                .as_ref()
                .is_some_and(|o| o.disposition == Disposition::Rejected);
            if rejected {
                let tenant = reqs[r].tenant;
                for (s, snap) in snaps {
                    self.shards[s].restore_sub(tenant, Some(snap));
                }
            }
        }
    }

    fn apply_membership(&mut self, req: &Request) -> ServiceOutcome {
        match &req.delta {
            ScenarioDelta::Join(dev) => self.member_join(req.tenant, dev.clone()),
            ScenarioDelta::Leave(i) => self.member_leave(req.tenant, *i),
            _ => unreachable!("only membership deltas reach apply_membership"),
        }
    }

    /// Apply one environmental local delta per listed shard in parallel
    /// (the bandwidth-share rebroadcast after a membership change).
    /// Returns results in ascending shard order.
    fn broadcast(
        &mut self,
        tenant: TenantId,
        ops: Vec<(usize, ScenarioDelta)>,
    ) -> Vec<ShardOpResult> {
        if ops.is_empty() {
            return Vec::new();
        }
        let k = self.shards.len();
        let mut by_shard: Vec<Option<ScenarioDelta>> = (0..k).map(|_| None).collect();
        for (s, d) in ops {
            by_shard[s] = Some(d);
        }
        let threads = threads_for(self.opts.threads, k);
        let results = {
            let by_shard = &by_shard;
            par_map_indexed_mut(&mut self.shards, threads, |shard, s| {
                by_shard[s].as_ref().map(|d| shard.apply_param(tenant, d, true))
            })
        };
        let out: Vec<ShardOpResult> = results.into_iter().flatten().collect();
        for op in &out {
            self.note_op(op);
        }
        out
    }

    /// Share updates for every hosting shard except `skip`, given the new
    /// tenant device count `n_new`.  Shares whose value is unchanged are
    /// dropped (no planner work for an exact no-op).
    fn share_updates(
        &self,
        tenant: TenantId,
        skip: usize,
        n_new: usize,
    ) -> Vec<(usize, ScenarioDelta)> {
        // lint:allow(panic-path): both callers resolve the tenant first
        let t = self.tenant_index(tenant).expect("caller validated tenant");
        let b = self.tenants[t].total_bandwidth_hz;
        let mut out = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            if s == skip {
                continue;
            }
            if let Some(sub) = shard.sub(tenant) {
                let share = share_hz(b, sub.members.len(), n_new);
                if share != sub.scenario.total_bandwidth_hz {
                    out.push((s, ScenarioDelta::TotalBandwidth(share)));
                }
            }
        }
        out
    }

    fn member_join(&mut self, tenant: TenantId, dev: Device) -> ServiceOutcome {
        let Some(t) = self.tenant_index(tenant) else {
            self.stats.rejected += 1;
            return self.idle_outcome(tenant, Disposition::Rejected);
        };
        let n = self.tenants[t].devices;
        let b = self.tenants[t].total_bandwidth_hz;
        let k = self.shards.len();
        let loads = self.shard_loads();
        let bound = self.load_bound(loads.iter().sum::<usize>() + 1);
        let mut s = (route_mix(tenant, &dev) % k as u64) as usize;
        if loads[s] + 1 > bound {
            s = argmin(&loads);
        }
        let k_s = self.shards[s].sub(tenant).map(|x| x.members.len()).unwrap_or(0);
        let share_s = share_hz(b, k_s + 1, n + 1);
        let owner = if k_s == 0 {
            let tb = self.tenant_bound(tenant).unwrap_or_default();
            let sc = Scenario { devices: vec![dev], total_bandwidth_hz: share_s };
            match self.shards[s].cold_admit(tenant, vec![n], sc, tb) {
                Ok(op) => op,
                Err(_) => ShardOpResult::rejected(),
            }
        } else {
            self.shards[s].apply_join(tenant, n, dev, share_s)
        };
        self.note_op(&owner);
        if owner.disposition == Disposition::Rejected {
            self.stats.rejected += 1;
            return self.idle_outcome(tenant, Disposition::Rejected);
        }
        self.tenants[t].devices = n + 1;
        let mut acc = ShardOpResult::neutral();
        merge(&mut acc, &owner);
        let updates = self.share_updates(tenant, s, n + 1);
        for op in self.broadcast(tenant, updates) {
            merge(&mut acc, &op);
        }
        merge(&mut acc, &self.rebalance());
        self.outcome_of(tenant, Disposition::Applied, acc)
    }

    fn member_leave(&mut self, tenant: TenantId, i: usize) -> ServiceOutcome {
        let Some(t) = self.tenant_index(tenant) else {
            self.stats.rejected += 1;
            return self.idle_outcome(tenant, Disposition::Rejected);
        };
        let n = self.tenants[t].devices;
        if n <= 1 || i >= n {
            // Mirrors ScenarioDelta::apply's tenant-level validation: the
            // last device cannot leave and the index must be in range.
            self.stats.rejected += 1;
            return self.idle_outcome(tenant, Disposition::Rejected);
        }
        let b = self.tenants[t].total_bandwidth_hz;
        // lint:allow(panic-path): i < n was checked above, and shard
        // membership sums to the tenant device count by construction
        let (s, l) = self.locate(tenant, i).expect("tenant device counts are consistent");
        // lint:allow(panic-path): locate returned this shard
        let k_s = self.shards[s].sub(tenant).expect("located").members.len();
        let share_after = if k_s >= 2 { share_hz(b, k_s - 1, n - 1) } else { 0.0 };
        let owner = self.shards[s].apply_leave(tenant, l, share_after);
        self.note_op(&owner);
        if owner.disposition == Disposition::Rejected {
            self.stats.rejected += 1;
            return self.idle_outcome(tenant, Disposition::Rejected);
        }
        self.tenants[t].devices = n - 1;
        for shard in &mut self.shards {
            if let Some(sub) = shard.sub_mut(tenant) {
                for m in &mut sub.members {
                    if *m > i {
                        *m -= 1;
                    }
                }
            }
        }
        let mut acc = ShardOpResult::neutral();
        merge(&mut acc, &owner);
        let updates = self.share_updates(tenant, s, n - 1);
        for op in self.broadcast(tenant, updates) {
            merge(&mut acc, &op);
        }
        merge(&mut acc, &self.rebalance());
        self.outcome_of(tenant, Disposition::Applied, acc)
    }

    /// Move devices from overloaded shards to the least-loaded one until
    /// every shard satisfies the load bound (or a move fails — the bound
    /// is best-effort under infeasibility).  All choices are
    /// deterministic: most-loaded shard (lowest index on ties), its
    /// largest hosted tenant (admission order on ties), that tenant's
    /// most recently assigned device.
    fn rebalance(&mut self) -> ShardOpResult {
        let mut acc = ShardOpResult::neutral();
        let k = self.shards.len();
        if k <= 1 {
            return acc;
        }
        let mut guard = 0;
        loop {
            let loads = self.shard_loads();
            let total: usize = loads.iter().sum();
            if total == 0 {
                break;
            }
            let bound = self.load_bound(total);
            let src = argmax(&loads);
            if loads[src] <= bound {
                break;
            }
            let dst = argmin(&loads);
            if dst == src {
                break;
            }
            guard += 1;
            if guard > 2 * k {
                break;
            }
            match self.move_one(src, dst) {
                Some(op) => {
                    merge(&mut acc, &op);
                    self.stats.rebalance_moves += 1;
                }
                None => break,
            }
        }
        acc
    }

    /// Move one device from shard `src` to shard `dst` (destination join
    /// first, then source leave; both snapshots restored on failure).
    /// Returns `None` when the move is cancelled.
    fn move_one(&mut self, src: usize, dst: usize) -> Option<ShardOpResult> {
        let tenant = {
            let mut best: Option<(TenantId, usize)> = None;
            for (tid, sub) in &self.shards[src].tenants {
                if best.map_or(true, |(_, c)| sub.members.len() > c) {
                    best = Some((*tid, sub.members.len()));
                }
            }
            best?.0
        };
        // lint:allow(panic-path): shards only host admitted tenants
        let t = self.tenant_index(tenant).expect("hosted tenant is admitted");
        let n = self.tenants[t].devices;
        let b = self.tenants[t].total_bandwidth_hz;
        let src_snapshot = self.shards[src].sub(tenant).cloned();
        let dst_snapshot = self.shards[dst].sub(tenant).cloned();
        let k_src = src_snapshot.as_ref().map(|s| s.members.len())?;
        let k_dst = dst_snapshot.as_ref().map(|s| s.members.len()).unwrap_or(0);
        let (tenant_idx, dev) = {
            // lint:allow(panic-path): k_src above proved the snapshot is Some
            let sub = src_snapshot.as_ref().expect("checked above");
            (*sub.members.last()?, sub.scenario.devices.last()?.clone())
        };
        let share_dst = share_hz(b, k_dst + 1, n);
        let dst_op = if k_dst == 0 {
            let bound = self.tenant_bound(tenant).unwrap_or_default();
            let sc = Scenario { devices: vec![dev], total_bandwidth_hz: share_dst };
            match self.shards[dst].cold_admit(tenant, vec![tenant_idx], sc, bound) {
                Ok(op) => op,
                Err(_) => return None,
            }
        } else {
            let op = self.shards[dst].apply_join(tenant, tenant_idx, dev, share_dst);
            if op.disposition == Disposition::Rejected {
                return None; // apply_join rolled itself back
            }
            op
        };
        self.note_op(&dst_op);
        let share_src_after = if k_src >= 2 { share_hz(b, k_src - 1, n) } else { 0.0 };
        let src_op = self.shards[src].apply_leave(tenant, k_src - 1, share_src_after);
        self.note_op(&src_op);
        if src_op.disposition == Disposition::Rejected {
            self.shards[dst].restore_sub(tenant, dst_snapshot);
            self.shards[src].restore_sub(tenant, src_snapshot);
            return None;
        }
        let mut acc = ShardOpResult::neutral();
        merge(&mut acc, &dst_op);
        merge(&mut acc, &src_op);
        Some(acc)
    }
}
