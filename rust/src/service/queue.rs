//! Bounded request queue and batch-coalescing analysis.
//!
//! The queue is the service's admission-control point: a full queue
//! refuses new requests with [`ServiceError::Backpressure`] instead of
//! dropping anything silently.  Coalescing runs at drain time over the
//! whole batch: a later delta supersedes an earlier one it fully covers,
//! so a burst of channel jitter or repeated renegotiations for the same
//! device costs one replan instead of many.

use std::collections::VecDeque;

use crate::engine::ScenarioDelta;

use super::{ServiceError, TenantId};

/// One queued request: a tenant-level delta awaiting a drain.
#[derive(Clone, Debug)]
pub struct Request {
    /// The submitting tenant.
    pub tenant: TenantId,
    /// The change to apply at the next drain.
    pub delta: ScenarioDelta,
}

/// Bounded FIFO of pending requests.
#[derive(Debug)]
pub struct DeltaQueue {
    capacity: usize,
    pending: VecDeque<Request>,
    refused: u64,
}

impl DeltaQueue {
    /// `capacity` is clamped to at least 1 (a zero-capacity queue could
    /// never accept anything).
    pub fn new(capacity: usize) -> DeltaQueue {
        DeltaQueue { capacity: capacity.max(1), pending: VecDeque::new(), refused: 0 }
    }

    /// Enqueue, or refuse with [`ServiceError::Backpressure`] when full.
    /// A refused request is never partially recorded.
    pub fn submit(&mut self, req: Request) -> Result<(), ServiceError> {
        if self.pending.len() >= self.capacity {
            self.refused += 1;
            return Err(ServiceError::Backpressure { capacity: self.capacity });
        }
        self.pending.push_back(req);
        Ok(())
    }

    /// Take every pending request, in submission order.
    pub fn drain(&mut self) -> Vec<Request> {
        self.pending.drain(..).collect()
    }

    /// Pending request count.
    pub fn len(&self) -> usize {
        self.pending.len()
    }

    /// `true` when nothing is pending.
    pub fn is_empty(&self) -> bool {
        self.pending.is_empty()
    }

    /// The fixed capacity (≥ 1).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Requests refused for backpressure since construction.
    pub fn refused(&self) -> u64 {
        self.refused
    }

    /// Count one refusal that happened *outside* [`DeltaQueue::submit`].
    /// The sharded wire server bounds its per-shard submit queues with a
    /// shared atomic reservation and drops overloads before they reach
    /// this queue; recording the refusal here keeps the `refused`
    /// counter (and therefore `stats` responses) identical to the
    /// single-lock path.
    pub fn record_refusal(&mut self) {
        self.refused += 1;
    }
}

/// The parameter slot a delta writes, used to decide supersession.
/// Membership changes have no slot: they are barriers.
#[derive(PartialEq, Eq)]
enum Slot {
    Bandwidth,
    Channel(usize),
    Deadline(Option<usize>),
    Risk(Option<usize>),
    /// The fleet-wide risk-bound slot: like deadline/risk broadcasts, a
    /// later bound write fully covers an earlier one.
    Bound,
}

fn slot_of(delta: &ScenarioDelta) -> Option<Slot> {
    match delta {
        ScenarioDelta::TotalBandwidth(_) => Some(Slot::Bandwidth),
        ScenarioDelta::Channel { device, .. } => Some(Slot::Channel(*device)),
        ScenarioDelta::Deadline { device, .. } => Some(Slot::Deadline(*device)),
        ScenarioDelta::Risk { device, .. } => Some(Slot::Risk(*device)),
        ScenarioDelta::Bound(_) => Some(Slot::Bound),
        ScenarioDelta::Join(_) | ScenarioDelta::Leave(_) => None,
    }
}

/// `later` fully covers `earlier`: applying `later` afterwards leaves no
/// trace of `earlier` in the scenario.
fn covers(later: &Slot, earlier: &Slot) -> bool {
    match (later, earlier) {
        (Slot::Bandwidth, Slot::Bandwidth) => true,
        (Slot::Channel(a), Slot::Channel(b)) => a == b,
        // A fleet-wide deadline/risk write (device: None) covers any
        // earlier write; a single-device write covers only the same
        // device (an earlier fleet-wide write still matters elsewhere).
        (Slot::Deadline(a), Slot::Deadline(b)) => a.is_none() || a == b,
        (Slot::Risk(a), Slot::Risk(b)) => a.is_none() || a == b,
        (Slot::Bound, Slot::Bound) => true,
        _ => false,
    }
}

pub(crate) fn is_membership(delta: &ScenarioDelta) -> bool {
    matches!(delta, ScenarioDelta::Join(_) | ScenarioDelta::Leave(_))
}

/// For each request in the batch, the index of the later request that
/// supersedes it (`None` = the request survives and must be applied).
///
/// Supersession requires the same tenant, a covering parameter slot, and
/// **no membership change for that tenant in between** — a join/leave
/// re-indexes devices and re-routes shards, so nothing coalesces across
/// it.  Membership requests themselves are never superseded.
pub(crate) fn superseded_by(reqs: &[Request]) -> Vec<Option<usize>> {
    let mut out = vec![None; reqs.len()];
    for i in 0..reqs.len() {
        let Some(slot) = slot_of(&reqs[i].delta) else { continue };
        for (j, later) in reqs.iter().enumerate().skip(i + 1) {
            if later.tenant != reqs[i].tenant {
                continue;
            }
            if is_membership(&later.delta) {
                break; // barrier: nothing before it coalesces past it
            }
            if slot_of(&later.delta).is_some_and(|l| covers(&l, &slot)) {
                out[i] = Some(j);
                break;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::channel::Uplink;
    use crate::models::ModelProfile;
    use crate::optim::types::Device;

    fn req(tenant: TenantId, delta: ScenarioDelta) -> Request {
        Request { tenant, delta }
    }

    fn join() -> ScenarioDelta {
        ScenarioDelta::Join(Device {
            model: ModelProfile::alexnet_paper(),
            uplink: Uplink::from_distance(100.0),
            deadline_s: 0.2,
            risk: 0.05,
        })
    }

    #[test]
    fn bounded_queue_refuses_and_never_drops() {
        let mut q = DeltaQueue::new(2);
        q.submit(req(0, ScenarioDelta::TotalBandwidth(1e6))).unwrap();
        q.submit(req(0, ScenarioDelta::TotalBandwidth(2e6))).unwrap();
        assert!(matches!(
            q.submit(req(0, ScenarioDelta::TotalBandwidth(3e6))),
            Err(ServiceError::Backpressure { capacity: 2 })
        ));
        assert_eq!(q.refused(), 1);
        // Everything admitted is still there, in order.
        let drained = q.drain();
        assert_eq!(drained.len(), 2);
        assert!(matches!(drained[0].delta, ScenarioDelta::TotalBandwidth(b) if b == 1e6));
        assert!(matches!(drained[1].delta, ScenarioDelta::TotalBandwidth(b) if b == 2e6));
        // After the drain there is room again.
        q.submit(req(0, ScenarioDelta::TotalBandwidth(4e6))).unwrap();
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn zero_capacity_is_clamped() {
        let mut q = DeltaQueue::new(0);
        assert_eq!(q.capacity(), 1);
        q.submit(req(0, ScenarioDelta::TotalBandwidth(1e6))).unwrap();
    }

    #[test]
    fn later_same_slot_supersedes_earlier() {
        let reqs = vec![
            req(0, ScenarioDelta::TotalBandwidth(1e6)),
            req(0, ScenarioDelta::Risk { device: Some(1), risk: 0.1 }),
            req(0, ScenarioDelta::TotalBandwidth(2e6)),
            req(0, ScenarioDelta::Risk { device: Some(2), risk: 0.2 }),
        ];
        let s = superseded_by(&reqs);
        assert_eq!(s, vec![Some(2), None, None, None]);
    }

    #[test]
    fn membership_is_a_barrier_per_tenant() {
        let reqs = vec![
            req(0, ScenarioDelta::TotalBandwidth(1e6)),
            req(0, join()),
            req(0, ScenarioDelta::TotalBandwidth(2e6)),
            // tenant 1's joins don't block tenant 0, and vice versa
            req(1, ScenarioDelta::TotalBandwidth(5e6)),
            req(0, ScenarioDelta::TotalBandwidth(3e6)),
            req(1, ScenarioDelta::TotalBandwidth(6e6)),
        ];
        let s = superseded_by(&reqs);
        assert_eq!(s[0], None, "join barrier protects the earlier bandwidth write");
        assert_eq!(s[1], None, "membership is never superseded");
        assert_eq!(s[2], Some(4));
        assert_eq!(s[3], Some(5));
        assert_eq!(s[4], None);
        assert_eq!(s[5], None);
    }

    #[test]
    fn fleet_wide_write_covers_single_device_but_not_conversely() {
        let reqs = vec![
            req(0, ScenarioDelta::Deadline { device: Some(1), deadline_s: 0.2 }),
            req(0, ScenarioDelta::Deadline { device: None, deadline_s: 0.3 }),
            req(0, ScenarioDelta::Deadline { device: Some(2), deadline_s: 0.4 }),
        ];
        let s = superseded_by(&reqs);
        assert_eq!(s[0], Some(1), "fleet-wide deadline covers the single-device write");
        assert_eq!(s[1], None, "a single-device write cannot cover a fleet-wide one");
        assert_eq!(s[2], None);
    }

    #[test]
    fn later_bound_write_covers_earlier_one() {
        use crate::risk::RiskBound;
        let reqs = vec![
            req(0, ScenarioDelta::Bound(RiskBound::Gaussian)),
            req(0, ScenarioDelta::Risk { device: Some(1), risk: 0.1 }),
            req(0, ScenarioDelta::Bound(RiskBound::calibrated(0.7))),
        ];
        let s = superseded_by(&reqs);
        assert_eq!(s, vec![Some(2), None, None]);
    }

    #[test]
    fn different_tenants_never_coalesce() {
        let reqs = vec![
            req(0, ScenarioDelta::TotalBandwidth(1e6)),
            req(1, ScenarioDelta::TotalBandwidth(2e6)),
        ];
        assert_eq!(superseded_by(&reqs), vec![None, None]);
    }
}
