//! Lock-sharded TCP frontend for the [`PlannerService`] — `ripra serve
//! --listen <addr>`.
//!
//! One [`std::net::TcpListener`], one reader thread per connection.
//! The serve hot path is built for throughput (ROADMAP: millions of
//! events per minute) while keeping every determinism contract from the
//! single-lock design it replaced:
//!
//! * **Greedy frame batching** — each connection reads whatever the
//!   socket has buffered ([`wire::FrameBuffer`]), decodes *every*
//!   complete frame, executes the whole wave, and answers with one
//!   buffered write.  A frame may itself be a [`WireRequest::Batch`],
//!   amortizing framing across many events.  Encode/decode buffers are
//!   reused per connection, so the framing layer allocates nothing per
//!   event in steady state (`rust/tests/alloc_wire.rs` counts).
//! * **Lock sharding** — deltas (the overwhelming majority of traffic)
//!   never take the global service lock: a lock-free tenant-registry
//!   check, an atomic capacity reservation, and a push onto the owning
//!   submit shard's queue under that shard's lock.  The global lock is
//!   held only at the four deterministic drain points (`plan`, `stats`,
//!   `shutdown`, and load shedding), where the collector merges the
//!   shard queues back into global submission order (an atomic
//!   sequence number per delta) and feeds them through
//!   [`PlannerService::submit`] — so a drained batch is applied exactly
//!   as the single-lock server would have applied it.
//!
//! For a single sequential connection the response transcript is a pure
//! function of the request bytes — byte-identical to the pre-sharding
//! server (pinned in `rust/tests/serve.rs`).  Across connections each
//! transcript is deterministic per-connection for tenant-scoped
//! payloads (admission energies, plans) when tenants are
//! connection-disjoint; coordination fields (`depth`, `drained`, global
//! counters, back-off jitter) depend on interleaving by design.
//!
//! Deltas drain in **SLO order** (deadline-nearest tenant first, see
//! [`PlannerService::drain`]).  When intake is over capacity the server
//! answers [`WireResponse::Shed`] with a jittered exponential back-off
//! hint from [`crate::fault::FaultStreams::backoff_s`] — the request is
//! dropped (unlike in-process [`ServiceError::Backpressure`], which
//! leaves retry to the caller) and the backlog is drained so the
//! connection can make progress.  No wall-clock is read anywhere on the
//! serve path; latency is the *client's* measurement.

use std::io::Write as _;
use std::net::{Shutdown, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex, RwLock};

use crate::engine::ScenarioDelta;
use crate::fault::{FaultOptions, FaultStreams};
use crate::util::json::Json;
use crate::util::rng::Rng;

use super::planner_service::{PlannerService, ServiceOptions};
use super::queue::Request;
use super::wire::{self, FrameBuffer, WireError, WireRequest, WireResponse};
use super::{ServiceError, TenantId};

/// Configuration for [`Server::bind`].
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Address to listen on, e.g. `127.0.0.1:7700` (port 0 picks a free
    /// port; read it back with [`Server::local_addr`]).
    pub listen: String,
    /// Shard count for the underlying [`PlannerService`] (planner
    /// parallelism at drain time).
    pub shards: usize,
    /// Bounded delta-intake capacity; beyond it the server sheds.
    pub queue_capacity: usize,
    /// Submit-shard count: independent locks the delta fast path is
    /// striped over (tenant id modulo this count picks the shard).
    /// Orthogonal to `shards`, which parallelizes the drain.
    pub submit_shards: usize,
    /// Seed for the back-off jitter stream (the only randomness in the
    /// server, and it never touches planning state).
    pub seed: u64,
    /// Base back-off, seconds: shed attempt `k` hints
    /// `base · 2^k · U[0.75, 1.25]`.
    pub backoff_base_s: f64,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            listen: "127.0.0.1:0".into(),
            shards: 2,
            queue_capacity: 64,
            submit_shards: 16,
            seed: 7,
            backoff_base_s: 0.05,
        }
    }
}

/// State behind the **global** lock: the service plus the shed back-off
/// stream.  Held only at the four drain points, never on the delta fast
/// path.
struct Core {
    svc: PlannerService,
    faults: FaultOptions,
    backoff: FaultStreams,
}

/// One submit shard: pending deltas (tagged with their global sequence
/// number) plus the consecutive-shed counters for the tenants this
/// shard owns.  Each shard has its own lock; a delta touches exactly
/// one.
#[derive(Default)]
struct SubmitShard {
    queue: Vec<(u64, Request)>,
    /// Consecutive sheds per owned tenant; resets when a delta is
    /// accepted.
    shed_attempts: Vec<(TenantId, u32)>,
}

/// Everything the connection threads share.  Lock order is always
/// global-then-shard (the fast path takes one shard lock and nothing
/// else), so the pair can never deadlock.
struct Shared {
    core: Mutex<Core>,
    shards: Vec<Mutex<SubmitShard>>,
    /// Admitted tenants — the lock-free-read validation the fast path
    /// does instead of consulting the service.  Only ever appended to
    /// (the wire protocol has no tenant removal).
    tenants: RwLock<Vec<TenantId>>,
    /// Atomic reservation over the shard queues: a delta is accepted
    /// iff the pre-increment count is below `capacity`, which both
    /// bounds memory exactly and reproduces the single-lock
    /// `Queued { depth }` / shed points for a sequential client.
    pending_total: AtomicUsize,
    /// Global submission order across shards; [`Shared::collect`]
    /// merges by this.
    seq: AtomicU64,
    /// Mirror of the service queue's (clamped) capacity.
    capacity: usize,
    /// One `try_clone` per accepted connection, so shutdown can
    /// half-close every socket and no worker stays blocked in a read.
    conns: Mutex<Vec<TcpStream>>,
}

/// Lock a possibly-poisoned mutex: a panicking connection thread must
/// not wedge the whole server, and the service's transactional drains
/// keep its state coherent regardless.
fn lock<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

/// [`lock`] for the tenant registry's read side.
fn read_tenants(l: &RwLock<Vec<TenantId>>) -> std::sync::RwLockReadGuard<'_, Vec<TenantId>> {
    match l.read() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl Shared {
    fn shard_of(&self, tenant: TenantId) -> &Mutex<SubmitShard> {
        &self.shards[(tenant as usize) % self.shards.len()]
    }

    /// The delta fast path: registry check, atomic reservation, one
    /// shard lock.  Over capacity falls through to the shed path, which
    /// takes the global lock (shedding *is* a drain point).
    fn submit_delta(&self, tenant: TenantId, delta: ScenarioDelta) -> WireResponse {
        if !read_tenants(&self.tenants).contains(&tenant) {
            return error_response(&ServiceError::UnknownTenant(tenant));
        }
        let before = self.pending_total.fetch_add(1, Ordering::SeqCst);
        if before >= self.capacity {
            self.pending_total.fetch_sub(1, Ordering::SeqCst);
            let mut core = lock(&self.core);
            // Count the drop where the single-lock queue would have
            // (`stats.refused` parity), then hint, then free the
            // backlog — the same shed-drain-recover sequence as before.
            core.svc.record_shed();
            let attempt = self.bump_attempts(tenant);
            let backoff_s = core.backoff.backoff_s(&core.faults, attempt);
            let _ = self.collect_and_drain(&mut core);
            return WireResponse::Shed { backoff_s, attempt };
        }
        let seq = self.seq.fetch_add(1, Ordering::SeqCst);
        let mut shard = lock(self.shard_of(tenant));
        shard.shed_attempts.retain(|(t, _)| *t != tenant);
        shard.queue.push((seq, Request { tenant, delta }));
        WireResponse::Queued { depth: before + 1 }
    }

    /// Return this shed's 0-based attempt number and remember the next
    /// (stored on the tenant's owning shard, so accepted deltas can
    /// reset it without the global lock).
    fn bump_attempts(&self, tenant: TenantId) -> u32 {
        let mut shard = lock(self.shard_of(tenant));
        for (t, a) in &mut shard.shed_attempts {
            if *t == tenant {
                let now = *a;
                *a = a.saturating_add(1);
                return now;
            }
        }
        shard.shed_attempts.push((tenant, 1));
        0
    }

    /// Move every pending delta from the submit shards into the
    /// service's queue in global submission order.  Called only under
    /// the global lock (the four drain points), so the collected batch
    /// is applied exactly as the single-lock server applied its queue.
    fn collect(&self, core: &mut Core) {
        let mut merged: Vec<(u64, Request)> = Vec::new();
        for shard in &self.shards {
            let mut g = lock(shard);
            merged.append(&mut g.queue);
        }
        if merged.is_empty() {
            return;
        }
        merged.sort_by_key(|&(seq, _)| seq);
        self.pending_total.fetch_sub(merged.len(), Ordering::SeqCst);
        for (_, req) in merged {
            // Cannot refuse: reservations cap the batch at the service
            // queue's capacity, the registry guarantees the tenant is
            // admitted, and the server never enables circuit breakers
            // (`breaker_threshold` stays at its off default).
            let _ = core.svc.submit(req.tenant, req.delta);
        }
    }

    /// [`Shared::collect`] + [`PlannerService::drain`]; returns the
    /// drained-request count the `plan`/`stats` responses report.
    fn collect_and_drain(&self, core: &mut Core) -> usize {
        self.collect(core);
        core.svc.drain().len()
    }
}

/// Map a [`ServiceError`] onto a wire error response (its stable code
/// from [`wire::error_code`] plus the `Display` text).
fn error_response(e: &ServiceError) -> WireResponse {
    WireResponse::Error { code: wire::error_code(e).into(), message: format!("{e}") }
}

/// Execute one decoded top-level request.  A batch executes its inner
/// requests in order — each with exactly the sequential-singles
/// semantics — and answers one [`WireResponse::Batch`]; a shutdown
/// anywhere latches `stop_after` (the connection finishes writing the
/// wave first).
fn execute(shared: &Shared, req: WireRequest, stop_after: &mut bool) -> WireResponse {
    match req {
        WireRequest::Batch(inner) => {
            let mut resps = Vec::with_capacity(inner.len());
            for r in inner {
                resps.push(execute_single(shared, r, stop_after));
            }
            WireResponse::Batch(resps)
        }
        other => execute_single(shared, other, stop_after),
    }
}

fn execute_single(shared: &Shared, req: WireRequest, stop_after: &mut bool) -> WireResponse {
    match req {
        WireRequest::Admit { tenant, scenario, bound } => {
            let mut core = lock(&shared.core);
            match core.svc.admit_tenant_with(tenant, scenario, bound) {
                Ok(_) => {
                    let energy_j = core.svc.tenant_energy(tenant).unwrap_or(0.0);
                    // Registered before the core lock drops, so no delta
                    // can observe the service knowing a tenant the
                    // registry does not.
                    match shared.tenants.write() {
                        Ok(mut g) => g.push(tenant),
                        Err(poisoned) => poisoned.into_inner().push(tenant),
                    }
                    WireResponse::Admitted { tenant, energy_j }
                }
                Err(e) => error_response(&e),
            }
        }
        WireRequest::Delta { tenant, delta } => shared.submit_delta(tenant, delta),
        WireRequest::Plan { tenant } => {
            let mut core = lock(&shared.core);
            let drained = shared.collect_and_drain(&mut core);
            match (core.svc.assembled_plan(tenant), core.svc.tenant_energy(tenant)) {
                (Some(plan), Some(energy_j)) => {
                    WireResponse::PlanRow { tenant, drained, energy_j, plan }
                }
                _ => error_response(&ServiceError::UnknownTenant(tenant)),
            }
        }
        WireRequest::Stats => {
            let mut core = lock(&shared.core);
            let drained = shared.collect_and_drain(&mut core);
            WireResponse::StatsRow {
                drained,
                tenants: core.svc.tenant_count(),
                queue_len: core.svc.queue_len(),
                stats: core.svc.stats(),
            }
        }
        WireRequest::Shutdown => {
            let mut core = lock(&shared.core);
            let _ = shared.collect_and_drain(&mut core);
            *stop_after = true;
            WireResponse::Bye
        }
        // The decoder rejects nested batches; refuse defensively rather
        // than recurse.
        WireRequest::Batch(_) => WireResponse::Error {
            code: "bad-request".into(),
            message: "batch requests cannot nest".into(),
        },
    }
}

/// A bound TCP planner frontend; [`Server::run`] serves until a
/// `shutdown` request arrives.
pub struct Server {
    listener: TcpListener,
    shared: Arc<Shared>,
    stop: Arc<AtomicBool>,
}

impl Server {
    /// Bind the listener and build the shared service (no connections
    /// accepted yet).  Service construction errors (bad shard count)
    /// surface as plain errors here, before any socket traffic.
    pub fn bind(opts: &ServerOptions) -> Result<Server, String> {
        let svc = PlannerService::new(ServiceOptions {
            shards: opts.shards.max(1),
            queue_capacity: opts.queue_capacity,
            ..ServiceOptions::default()
        })
        .map_err(|e| format!("service: {e}"))?;
        let capacity = svc.queue_capacity();
        let listener =
            TcpListener::bind(&opts.listen).map_err(|e| format!("bind {}: {e}", opts.listen))?;
        let mut master = Rng::new(opts.seed);
        let core = Core {
            svc,
            faults: FaultOptions { backoff_base_s: opts.backoff_base_s, ..FaultOptions::default() },
            backoff: FaultStreams::fork_off(&mut master),
        };
        let shared = Shared {
            core: Mutex::new(core),
            shards: (0..opts.submit_shards.max(1))
                .map(|_| Mutex::new(SubmitShard::default()))
                .collect(),
            tenants: RwLock::new(Vec::new()),
            pending_total: AtomicUsize::new(0),
            seq: AtomicU64::new(0),
            capacity,
            conns: Mutex::new(Vec::new()),
        };
        Ok(Server {
            listener,
            shared: Arc::new(shared),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr, String> {
        self.listener.local_addr().map_err(|e| format!("local_addr: {e}"))
    }

    /// Accept connections until a `shutdown` request flips the stop
    /// flag; every connection gets a reader thread feeding the shared
    /// state.  Shutdown ordering: the accept loop exits *first*, then
    /// every registered connection is half-closed (so no worker stays
    /// blocked reading a socket nobody will write to again), and only
    /// then are the workers joined.
    pub fn run(self) -> Result<(), String> {
        let mut workers = Vec::new();
        let mut result = Ok(());
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let _ = stream.set_nodelay(true);
                    if let Ok(clone) = stream.try_clone() {
                        lock(&self.shared.conns).push(clone);
                    }
                    let shared = Arc::clone(&self.shared);
                    let stop = Arc::clone(&self.stop);
                    workers.push(std::thread::spawn(move || serve_conn(stream, &shared, &stop)));
                }
                Err(e) => {
                    if !self.stop.load(Ordering::SeqCst) {
                        result = Err(format!("accept: {e}"));
                    }
                    break;
                }
            }
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
        }
        // Shutting down an already-dead clone is a harmless error, so
        // this is safe no matter how far each worker got.
        for conn in lock(&self.shared.conns).drain(..) {
            let _ = conn.shutdown(Shutdown::Both);
        }
        for w in workers {
            let _ = w.join();
        }
        result
    }

    /// Convenience for tests: the stop flag shared with connections.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }
}

/// Wake the accept loop (blocked in `incoming()`) so it observes the
/// stop flag.  Best-effort and idempotent: failures are ignored, and a
/// duplicate poke just hands the exiting accept loop one more throwaway
/// connection to drop.
fn poke(addr: Option<std::net::SocketAddr>) {
    if let Some(addr) = addr {
        if let Ok(s) = TcpStream::connect(addr) {
            drop(s);
        }
    }
}

/// One decoded frame on its way to execution: a request, or the
/// `bad-request` response a schema-invalid body earns (the connection
/// stays open, matching the one-frame-at-a-time server).
enum Decoded {
    Req(WireRequest),
    Bad(WireResponse),
}

/// Decode one frame body.  `Err` carries the `bad-request` response for
/// *fatal* malformations (non-UTF-8, non-JSON) after which the
/// connection closes; schema violations on well-formed JSON come back
/// as [`Decoded::Bad`] and keep the connection usable.
fn decode_frame(frame: &[u8]) -> Result<Decoded, WireResponse> {
    let text = match std::str::from_utf8(frame) {
        Ok(t) => t,
        Err(e) => {
            return Err(bad_request(&WireError::Parse(format!("frame body is not UTF-8: {e}"))))
        }
    };
    let json = match Json::parse(text) {
        Ok(j) => j,
        Err(e) => return Err(bad_request(&WireError::Parse(format!("{e}")))),
    };
    match WireRequest::from_json(&json) {
        Ok(r) => Ok(Decoded::Req(r)),
        Err(e) => Ok(Decoded::Bad(bad_request(&e))),
    }
}

fn bad_request(e: &WireError) -> WireResponse {
    WireResponse::Error { code: "bad-request".into(), message: format!("{e}") }
}

/// Serve one connection, a wave at a time: one blocking read, *every*
/// complete frame buffered decoded and executed, one buffered write for
/// all the responses.  The decode buffer, the JSON encode buffer, and
/// the output buffer are all reused across waves — steady state, the
/// framing layer allocates nothing per event.
fn serve_conn(mut stream: TcpStream, shared: &Shared, stop: &AtomicBool) {
    // For an accepted socket the local address *is* the listener's —
    // where the shutdown poke must connect.
    let listener_addr = stream.local_addr().ok();
    let mut frames = FrameBuffer::new();
    let mut wave: Vec<Decoded> = Vec::new();
    let mut body = String::new();
    let mut out: Vec<u8> = Vec::new();
    loop {
        let got = match frames.fill_from(&mut stream) {
            Ok(n) => n,
            Err(_) => return,
        };
        if got == 0 {
            if frames.buffered() > 0 {
                // EOF mid-frame: best-effort truncation report.
                let e = WireError::Frame(format!(
                    "stream closed with {} bytes of a partial frame buffered",
                    frames.buffered()
                ));
                let _ = wire::write_json(&mut stream, &bad_request(&e).to_json());
            }
            return; // clean close at a frame boundary
        }

        // Drain every complete frame already buffered — before taking
        // any lock.
        wave.clear();
        let mut fatal: Option<WireResponse> = None;
        loop {
            match frames.next_frame() {
                Ok(None) => break,
                Ok(Some(frame)) => match decode_frame(frame) {
                    Ok(d) => wave.push(d),
                    Err(resp) => {
                        fatal = Some(resp);
                        break;
                    }
                },
                Err(e) => {
                    fatal = Some(bad_request(&e));
                    break;
                }
            }
        }

        // Execute the wave and encode every response into one buffer.
        out.clear();
        let mut stop_after = false;
        let mut encode_ok = true;
        for item in wave.drain(..) {
            let resp = match item {
                Decoded::Req(r) => execute(shared, r, &mut stop_after),
                Decoded::Bad(b) => b,
            };
            body.clear();
            resp.to_json().write_compact_into(&mut body);
            if wire::write_frame_into(&mut out, body.as_bytes()).is_err() {
                encode_ok = false;
                break;
            }
            if stop_after {
                // Frames after a shutdown are never executed — the
                // single-frame server closed before reading them.
                break;
            }
        }
        let close_after = fatal.is_some();
        if let Some(resp) = fatal.take() {
            body.clear();
            resp.to_json().write_compact_into(&mut body);
            let _ = wire::write_frame_into(&mut out, body.as_bytes());
        }
        let write_ok = stream.write_all(&out).and_then(|_| stream.flush()).is_ok();
        if stop_after {
            stop.store(true, Ordering::SeqCst);
            poke(listener_addr);
            return;
        }
        if close_after || !encode_ok || !write_ok {
            return;
        }
    }
}

/// CLI entry for `ripra serve --listen`: bind, print the resolved
/// address on stdout (so scripts against port 0 can find it), serve
/// until shutdown.
pub fn serve(opts: &ServerOptions) -> Result<(), String> {
    let server = Server::bind(opts)?;
    let addr = server.local_addr()?;
    println!(
        "ripra serve: listening on {addr} ({} shards, queue {}, {} submit shards)",
        opts.shards.max(1),
        opts.queue_capacity,
        opts.submit_shards.max(1)
    );
    server.run()?;
    println!("ripra serve: shutdown complete");
    Ok(())
}
