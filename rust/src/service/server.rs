//! TCP frontend for the sharded [`PlannerService`] — `ripra serve
//! --listen <addr>`.
//!
//! One [`std::net::TcpListener`], one reader thread per connection, one
//! shared service behind a mutex.  Each connection loops: read a frame
//! ([`crate::service::wire`]), decode the request, execute it against
//! the service, write exactly one response frame.  Requests therefore
//! pipeline per-connection (FIFO on the socket) while connections
//! interleave at request granularity — the mutex is the serialization
//! point, and because every handler is deterministic, a single-client
//! session's response transcript is a pure function of its request
//! bytes (the load generator's replay pin).
//!
//! Deltas go through the service's bounded coalescing queue and are
//! **drained in SLO order** (deadline-nearest tenant first, see
//! [`PlannerService::drain`]) at four deterministic trigger points:
//! `plan` and `stats` requests, `shutdown`, and load shedding.  When the
//! queue refuses a delta the server answers [`WireResponse::Shed`] with
//! a jittered exponential back-off hint from
//! [`crate::fault::FaultStreams::backoff_s`] — the request is dropped
//! (unlike in-process [`ServiceError::Backpressure`], which leaves retry
//! to the caller) and the backlog is drained so the connection can make
//! progress.  No wall-clock is read anywhere on the serve path; latency
//! is the *client's* measurement.

use std::io::Write as _;
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex};

use crate::fault::{FaultOptions, FaultStreams};
use crate::util::rng::Rng;

use super::planner_service::{PlannerService, ServiceOptions};
use super::wire::{self, WireError, WireRequest, WireResponse};
use super::{ServiceError, TenantId};

/// Configuration for [`Server::bind`].
#[derive(Clone, Debug)]
pub struct ServerOptions {
    /// Address to listen on, e.g. `127.0.0.1:7700` (port 0 picks a free
    /// port; read it back with [`Server::local_addr`]).
    pub listen: String,
    /// Shard count for the underlying [`PlannerService`].
    pub shards: usize,
    /// Bounded delta-queue capacity; beyond it the server sheds.
    pub queue_capacity: usize,
    /// Seed for the back-off jitter stream (the only randomness in the
    /// server, and it never touches planning state).
    pub seed: u64,
    /// Base back-off, seconds: shed attempt `k` hints
    /// `base · 2^k · U[0.75, 1.25]`.
    pub backoff_base_s: f64,
}

impl Default for ServerOptions {
    fn default() -> Self {
        ServerOptions {
            listen: "127.0.0.1:0".into(),
            shards: 2,
            queue_capacity: 64,
            seed: 7,
            backoff_base_s: 0.05,
        }
    }
}

/// Shared mutable state: the service plus the shed-back-off machinery.
struct ServerState {
    svc: PlannerService,
    faults: FaultOptions,
    backoff: FaultStreams,
    /// Consecutive sheds per tenant; resets when a delta is accepted.
    shed_attempts: Vec<(TenantId, u32)>,
}

impl ServerState {
    /// Execute one decoded request, returning the response and whether
    /// the server should stop afterwards.
    fn handle(&mut self, req: WireRequest) -> (WireResponse, bool) {
        match req {
            WireRequest::Admit { tenant, scenario, bound } => {
                match self.svc.admit_tenant_with(tenant, scenario, bound) {
                    Ok(_) => {
                        let energy_j = self.svc.tenant_energy(tenant).unwrap_or(0.0);
                        (WireResponse::Admitted { tenant, energy_j }, false)
                    }
                    Err(e) => (error_response(&e), false),
                }
            }
            WireRequest::Delta { tenant, delta } => match self.svc.submit(tenant, delta) {
                Ok(()) => {
                    self.reset_attempts(tenant);
                    (WireResponse::Queued { depth: self.svc.queue_len() }, false)
                }
                Err(ServiceError::Backpressure { .. }) => {
                    let attempt = self.bump_attempts(tenant);
                    let backoff_s = self.backoff.backoff_s(&self.faults, attempt);
                    // Shed, then drain: the dropped request's siblings
                    // apply now, so a client honouring the hint finds a
                    // free queue when it retries.
                    let _ = self.svc.drain();
                    (WireResponse::Shed { backoff_s, attempt }, false)
                }
                Err(e) => (error_response(&e), false),
            },
            WireRequest::Plan { tenant } => {
                let drained = self.svc.drain().len();
                match (self.svc.assembled_plan(tenant), self.svc.tenant_energy(tenant)) {
                    (Some(plan), Some(energy_j)) => {
                        (WireResponse::PlanRow { tenant, drained, energy_j, plan }, false)
                    }
                    _ => (error_response(&ServiceError::UnknownTenant(tenant)), false),
                }
            }
            WireRequest::Stats => {
                let drained = self.svc.drain().len();
                (
                    WireResponse::StatsRow {
                        drained,
                        tenants: self.svc.tenant_count(),
                        queue_len: self.svc.queue_len(),
                        stats: self.svc.stats(),
                    },
                    false,
                )
            }
            WireRequest::Shutdown => {
                let _ = self.svc.drain();
                (WireResponse::Bye, true)
            }
        }
    }

    fn reset_attempts(&mut self, tenant: TenantId) {
        self.shed_attempts.retain(|(t, _)| *t != tenant);
    }

    /// Return this shed's 0-based attempt number and remember the next.
    fn bump_attempts(&mut self, tenant: TenantId) -> u32 {
        for (t, a) in &mut self.shed_attempts {
            if *t == tenant {
                let now = *a;
                *a = a.saturating_add(1);
                return now;
            }
        }
        self.shed_attempts.push((tenant, 1));
        0
    }
}

/// Map a [`ServiceError`] onto a wire error response (its stable code
/// from [`wire::error_code`] plus the `Display` text).
fn error_response(e: &ServiceError) -> WireResponse {
    WireResponse::Error { code: wire::error_code(e).into(), message: format!("{e}") }
}

/// A bound TCP planner frontend; [`Server::run`] serves until a
/// `shutdown` request arrives.
pub struct Server {
    listener: TcpListener,
    state: Arc<Mutex<ServerState>>,
    stop: Arc<AtomicBool>,
}

/// Lock a possibly-poisoned mutex: a panicking connection thread must
/// not wedge the whole server, and the service's transactional drains
/// keep its state coherent regardless.
fn lock(state: &Mutex<ServerState>) -> std::sync::MutexGuard<'_, ServerState> {
    match state.lock() {
        Ok(g) => g,
        Err(poisoned) => poisoned.into_inner(),
    }
}

impl Server {
    /// Bind the listener and build the shared service (no connections
    /// accepted yet).  Service construction errors (bad shard count)
    /// surface as [`WireError::Frame`]-free plain errors here, before
    /// any socket traffic.
    pub fn bind(opts: &ServerOptions) -> Result<Server, String> {
        let svc = PlannerService::new(ServiceOptions {
            shards: opts.shards.max(1),
            queue_capacity: opts.queue_capacity,
            ..ServiceOptions::default()
        })
        .map_err(|e| format!("service: {e}"))?;
        let listener =
            TcpListener::bind(&opts.listen).map_err(|e| format!("bind {}: {e}", opts.listen))?;
        let mut master = Rng::new(opts.seed);
        let state = ServerState {
            svc,
            faults: FaultOptions { backoff_base_s: opts.backoff_base_s, ..FaultOptions::default() },
            backoff: FaultStreams::fork_off(&mut master),
            shed_attempts: Vec::new(),
        };
        Ok(Server {
            listener,
            state: Arc::new(Mutex::new(state)),
            stop: Arc::new(AtomicBool::new(false)),
        })
    }

    /// The bound address (resolves port 0 to the actual port).
    pub fn local_addr(&self) -> Result<std::net::SocketAddr, String> {
        self.listener.local_addr().map_err(|e| format!("local_addr: {e}"))
    }

    /// Accept connections until a `shutdown` request flips the stop
    /// flag; every connection gets a reader thread feeding the shared
    /// service.  Joins all connection threads before returning.
    pub fn run(self) -> Result<(), String> {
        let mut workers = Vec::new();
        for conn in self.listener.incoming() {
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
            match conn {
                Ok(stream) => {
                    let state = Arc::clone(&self.state);
                    let stop = Arc::clone(&self.stop);
                    workers.push(std::thread::spawn(move || serve_conn(stream, &state, &stop)));
                }
                Err(e) => {
                    if self.stop.load(Ordering::SeqCst) {
                        break;
                    }
                    return Err(format!("accept: {e}"));
                }
            }
            if self.stop.load(Ordering::SeqCst) {
                break;
            }
        }
        // Unblocking connect from `serve_conn` may still be queued;
        // nothing to do — dropping the listener closes it.
        for w in workers {
            let _ = w.join();
        }
        Ok(())
    }

    /// Convenience for tests: the stop flag shared with connections.
    pub fn stop_flag(&self) -> Arc<AtomicBool> {
        Arc::clone(&self.stop)
    }
}

/// Serve one connection: frame-decode requests, execute under the state
/// lock, answer each with exactly one frame.  Protocol errors answer a
/// `bad-request` error frame when possible, then close.
fn serve_conn(mut stream: TcpStream, state: &Mutex<ServerState>, stop: &AtomicBool) {
    let peer_addr = stream.local_addr().ok();
    loop {
        let msg = match wire::read_json(&mut stream) {
            Ok(Some(j)) => j,
            Ok(None) => return, // clean close
            Err(WireError::Io(_)) => return,
            Err(e) => {
                let resp = WireResponse::Error { code: "bad-request".into(), message: format!("{e}") };
                let _ = wire::write_json(&mut stream, &resp.to_json());
                return;
            }
        };
        let req = match WireRequest::from_json(&msg) {
            Ok(r) => r,
            Err(e) => {
                let resp = WireResponse::Error { code: "bad-request".into(), message: format!("{e}") };
                if wire::write_json(&mut stream, &resp.to_json()).is_err() {
                    return;
                }
                continue;
            }
        };
        let (resp, stop_now) = {
            let mut guard = lock(state);
            guard.handle(req)
        };
        let write_ok = wire::write_json(&mut stream, &resp.to_json()).is_ok();
        if stop_now {
            stop.store(true, Ordering::SeqCst);
            // The accept loop is blocked in `incoming()`; poke it with a
            // throwaway connection so it observes the flag and exits.
            if let Some(addr) = peer_addr {
                if let Ok(mut s) = TcpStream::connect(addr) {
                    let _ = s.flush();
                }
            }
            return;
        }
        if !write_ok {
            return;
        }
    }
}

/// CLI entry for `ripra serve --listen`: bind, print the resolved
/// address on stdout (so scripts against port 0 can find it), serve
/// until shutdown.
pub fn serve(opts: &ServerOptions) -> Result<(), String> {
    let server = Server::bind(opts)?;
    let addr = server.local_addr()?;
    println!("ripra serve: listening on {addr} ({} shards, queue {})", opts.shards.max(1), opts.queue_capacity);
    server.run()?;
    println!("ripra serve: shutdown complete");
    Ok(())
}
