//! Sharded multi-tenant planning service: the scaling layer between the
//! single-scenario [`crate::engine::Planner`] and heavy multi-fleet
//! traffic.
//!
//! A [`PlannerService`] owns **K independent planner shards**, each with
//! its own LRU plan cache and Newton workspace.  Tenants (independent
//! fleets, each with its own uplink budget) are spread across the shards
//! device-by-device: a device's shard is chosen by a deterministic hash
//! of `(tenant, device fingerprint)` — the same quantized fingerprint
//! the plan cache keys on (see [`crate::engine::device_fingerprint`]) —
//! and membership churn triggers rebalancing moves that keep every
//! shard's device count within a load-factor bound.  Each shard solves
//! its sub-fleet against a **bandwidth share proportional to its device
//! count**, so the assembled fleet-wide decision always respects the
//! tenant's total budget (Σ shares = B); sharding trades a bounded
//! amount of allocation optimality for K-way planning parallelism.
//!
//! Requests enter as `(tenant, ScenarioDelta)` pairs through a **bounded
//! queue**: when the queue is full, [`PlannerService::submit`] refuses
//! with [`ServiceError::Backpressure`] — admission control; a request is
//! never dropped silently.  [`PlannerService::drain`] then processes the
//! backlog in batches:
//!
//! 1. **Coalescing** — a later pending delta supersedes an earlier one
//!    that it fully covers (same tenant, same parameter slot: total
//!    bandwidth, or channel/deadline/risk on the same device) as long as
//!    no membership change for that tenant sits between them, so N
//!    queued deltas cost at most N (and often far fewer) replans.
//! 2. **Sharded fan-out** — surviving parameter deltas are grouped by
//!    shard and the shards run in parallel over
//!    [`crate::util::par::par_map_indexed_mut`] workers with
//!    index-ordered result slots, so the drain's outcome is
//!    **bit-identical at any thread count** (the same contract the fleet
//!    metrics pin).  Membership changes (join/leave) act as barriers:
//!    the owning shard decides admission sequentially, then the
//!    bandwidth-share rebroadcast to the tenant's other shards fans out
//!    in parallel.
//! 3. **Admission control** — per shard op the planner is driven exactly
//!    like the serial fleet driver: plan-cache probe first, warm
//!    [`crate::engine::Planner::replan`] next, and for *environmental*
//!    deltas (channel, bandwidth) an infeasible change is absorbed via
//!    [`crate::engine::Planner::rebase`] while *negotiable* requests
//!    (join/leave, deadline/risk) are rejected.
//!
//! With `shards = 1` the service reduces exactly to the serial driver
//! flow — one shard, the full bandwidth, the same planner-call sequence —
//! which `rust/tests/service.rs` pins byte-for-byte against the bare
//! [`crate::engine::Planner`] path.
//!
//! Draining is **SLO-aware**: a drained batch is stable-sorted so the
//! tenant with the nearest device deadline replans first (see
//! [`PlannerService::drain`]).
//!
//! The service also runs over a real wire: [`server`] is the TCP
//! frontend behind `ripra serve --listen`, speaking the length-prefixed
//! JSON protocol defined in [`wire`] (spec in EXPERIMENTS.md §Serving),
//! and `ripra loadgen` ([`crate::fleet::loadgen`]) replays deterministic
//! fleet traffic against it.  The frontend's hot path is built for
//! throughput: connections read greedily and answer whole *waves* of
//! frames with one buffered write, requests may arrive coalesced into
//! [`WireRequest::Batch`] frames, and delta intake is striped over
//! per-shard submit locks so the global service lock is held only at
//! the deterministic drain points — single-connection transcripts stay
//! a pure function of the request bytes (pinned in
//! `rust/tests/serve.rs`).

#![warn(missing_docs)]

pub mod planner_service;
pub mod queue;
pub mod server;
pub mod shard;
pub mod wire;

use crate::engine::PlanError;

pub use planner_service::{PlannerService, ServiceOptions};
pub use queue::{DeltaQueue, Request};
pub use server::{Server, ServerOptions};
pub use wire::{WireError, WireRequest, WireResponse};

/// Identifies one tenant fleet within a [`PlannerService`].
pub type TenantId = u64;

/// How the service disposed of one submitted request.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Disposition {
    /// A new plan exists for the changed scenario.
    Applied,
    /// An infeasible *environmental* change was adopted with the old
    /// plan kept (scenario rolled forward via rebase).
    Absorbed,
    /// A *negotiable* request was refused; nothing changed.
    Rejected,
    /// A later request in the same batch fully covered this one, so it
    /// was coalesced away without any planner work.
    Superseded,
}

/// Aggregate result of one submitted request across every shard op it
/// triggered (owner op, bandwidth-share rebroadcasts, rebalance moves).
#[derive(Clone, Debug)]
pub struct ServiceOutcome {
    /// The tenant whose request this outcome disposes.
    pub tenant: TenantId,
    /// How the request was disposed.
    pub disposition: Disposition,
    /// Tenant-wide planned energy after the request, J (meaningful for
    /// `Applied` / `Absorbed`; 0 otherwise).
    pub energy_j: f64,
    /// Newton iterations the request cost (cache-hit ops count 0).
    pub newton_iters: usize,
    /// Outer (refinement / alternation) iterations the request cost.
    pub outer_iters: usize,
    /// Every primary shard op was served from a plan cache.
    pub cache_hit: bool,
    /// Some shard op used the warm incremental replan path.
    pub warm_started: bool,
    /// Planner-facing shard operations this request triggered.
    pub shard_ops: usize,
    /// Some shard op returned a degraded plan (all-local fallback while
    /// the edge is unreachable, or a budget-truncated solve).
    pub degraded: bool,
}

/// Deterministic service-level counters (no wall clock), exposed by
/// [`PlannerService::stats`].
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ServiceStats {
    /// Requests accepted into the queue.
    pub submitted: u64,
    /// Requests refused with [`ServiceError::Backpressure`].
    pub refused: u64,
    /// Requests coalesced away by a covering later delta.
    pub superseded: u64,
    /// Planner-facing shard operations executed.
    pub shard_ops: u64,
    /// Shard ops that invoked [`crate::engine::Planner::replan`].
    pub replans: u64,
    /// Shard ops served entirely from a shard's plan cache.
    pub cache_hits: u64,
    /// Shard ops absorbed via [`crate::engine::Planner::rebase`].
    pub rebases: u64,
    /// Requests rejected by admission control.
    pub rejected: u64,
    /// Devices moved between shards by load-factor rebalancing.
    pub rebalance_moves: u64,
    /// Times a tenant's circuit breaker opened (consecutive-failure
    /// threshold reached; see [`ServiceOptions::breaker_threshold`]).
    ///
    /// [`ServiceOptions::breaker_threshold`]: planner_service::ServiceOptions::breaker_threshold
    pub breaker_trips: u64,
}

/// Service-level failure.
#[derive(Clone, Debug)]
pub enum ServiceError {
    /// The bounded request queue is full; the caller must retry after a
    /// drain.  Nothing was enqueued.
    Backpressure {
        /// The queue's capacity at refusal time.
        capacity: usize,
    },
    /// The tenant's circuit breaker is open after consecutive planner
    /// failures: requests are refused without reaching a planner until
    /// the half-open probe closes it.  Nothing was enqueued.
    CircuitOpen(TenantId),
    /// The tenant id is not admitted.
    UnknownTenant(TenantId),
    /// The tenant id is already admitted.
    DuplicateTenant(TenantId),
    /// The service configuration is malformed.
    InvalidOptions(String),
    /// A planner call failed (e.g. an unplannable initial scenario).
    Plan(PlanError),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::Backpressure { capacity } => {
                write!(f, "request queue full (capacity {capacity}); drain and retry")
            }
            ServiceError::CircuitOpen(t) => {
                write!(f, "circuit open for tenant {t}; draining half-open probes")
            }
            ServiceError::UnknownTenant(t) => write!(f, "unknown tenant {t}"),
            ServiceError::DuplicateTenant(t) => write!(f, "tenant {t} already admitted"),
            ServiceError::InvalidOptions(s) => write!(f, "invalid service options: {s}"),
            ServiceError::Plan(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for ServiceError {}

impl From<PlanError> for ServiceError {
    fn from(e: PlanError) -> Self {
        ServiceError::Plan(e)
    }
}
