//! Length-prefixed JSON wire protocol for the TCP planner frontend.
//!
//! `ripra serve --listen` and `ripra loadgen` speak this protocol over a
//! plain [`std::net::TcpStream`] — no new dependencies, no async
//! runtime.  Every message (request or response) is one **frame**:
//!
//! ```text
//! +----------------+---------------------------+
//! | length: u32 BE | body: `length` JSON bytes |
//! +----------------+---------------------------+
//! ```
//!
//! The body is compact JSON (the repo's own [`Json`] writer — stable key
//! order, no whitespace) so a request stream is a *byte-identical*
//! function of its inputs: the load generator's replay contract (same
//! seed ⇒ same bytes on the wire) rests on this module alone.  The full
//! frame and message grammar is specified in EXPERIMENTS.md §Serving.
//!
//! Two throughput paths share the codec with the simple one-frame
//! helpers ([`read_frame`]/[`write_frame`]):
//!
//! * **Batched frames** — [`WireRequest::Batch`] carries many requests
//!   in one frame and is answered by one [`WireResponse::Batch`] with
//!   one inner response per inner request, in order.  Batches never
//!   nest (a nested batch is a [`WireError::Parse`] schema error).
//! * **Buffered framing** — [`FrameBuffer`] accumulates socket reads
//!   and yields every *complete* frame already buffered without a
//!   per-frame allocation, and [`write_frame_into`] appends frames to a
//!   reusable output buffer so a wave of responses costs one syscall.
//!   Both validate announced lengths against [`MAX_FRAME_LEN`] before
//!   any body buffer grows, so a hostile 4-byte header can never force
//!   a giant allocation.
//!
//! Byte layout of the smallest request, `{"kind":"stats"}` (16 bytes):
//!
//! ```
//! use ripra::service::wire::{encode_frame, WireRequest};
//!
//! let frame = encode_frame(WireRequest::Stats.to_json().to_string_compact().as_bytes());
//! assert_eq!(&frame[..4], &[0x00, 0x00, 0x00, 0x10]); // 16, big-endian
//! assert_eq!(&frame[4..], br#"{"kind":"stats"}"#);
//! ```
//!
//! Requests round-trip through [`WireRequest::to_json`] /
//! [`WireRequest::from_json`] (responses likewise), and the decoder
//! rejects malformed frames with [`WireError`] instead of panicking:
//!
//! ```
//! use ripra::service::wire::WireRequest;
//! use ripra::util::json::Json;
//!
//! let req = WireRequest::Plan { tenant: 7 };
//! let body = req.to_json().to_string_compact();
//! assert_eq!(body, r#"{"kind":"plan","tenant":7}"#);
//! let back = WireRequest::from_json(&Json::parse(&body).unwrap()).unwrap();
//! assert!(matches!(back, WireRequest::Plan { tenant: 7 }));
//! ```

use std::io::{Read, Write};

use crate::channel::Uplink;
use crate::engine::ScenarioDelta;
use crate::models::ModelProfile;
use crate::optim::types::{Device, Plan, Scenario};
use crate::risk::RiskBound;
use crate::util::json::Json;

use super::{ServiceError, ServiceStats, TenantId};

/// Hard cap on one frame's body length (4 MiB).  A peer announcing a
/// larger frame is protocol-broken (or hostile); the reader refuses it
/// before allocating.
pub const MAX_FRAME_LEN: u32 = 4 << 20;

/// Wire-protocol failure: transport, framing, or message-schema errors.
///
/// Service-level refusals (unknown tenant, backpressure, …) are *not*
/// errors at this layer — they travel as [`WireResponse::Error`] /
/// [`WireResponse::Shed`] payloads.
#[derive(Debug)]
pub enum WireError {
    /// The underlying socket read/write failed.
    Io(std::io::Error),
    /// The frame itself is malformed: oversize announced length or a
    /// stream truncated mid-frame.
    Frame(String),
    /// The body is not valid JSON, or is valid JSON that does not match
    /// the request/response schema.
    Parse(String),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Io(e) => write!(f, "wire i/o error: {e}"),
            WireError::Frame(s) => write!(f, "bad frame: {s}"),
            WireError::Parse(s) => write!(f, "bad message: {s}"),
        }
    }
}

impl std::error::Error for WireError {}

impl From<std::io::Error> for WireError {
    fn from(e: std::io::Error) -> Self {
        WireError::Io(e)
    }
}

// ---- framing --------------------------------------------------------------

/// Assemble one frame: 4-byte big-endian length prefix + the body bytes.
pub fn encode_frame(body: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(4 + body.len());
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(body);
    out
}

/// Write one frame to `w` (single `write_all`, so a frame is never
/// interleaved with another writer's bytes on the same stream).
pub fn write_frame<W: Write>(w: &mut W, body: &[u8]) -> Result<(), WireError> {
    if body.len() as u64 > MAX_FRAME_LEN as u64 {
        return Err(WireError::Frame(format!(
            "frame body of {} bytes exceeds MAX_FRAME_LEN ({MAX_FRAME_LEN})",
            body.len()
        )));
    }
    w.write_all(&encode_frame(body))?;
    w.flush()?;
    Ok(())
}

/// Read one frame from `r`.  Returns `Ok(None)` on a clean EOF *at a
/// frame boundary* (the peer closed after a complete message); EOF
/// mid-frame is a [`WireError::Frame`] truncation error.
pub fn read_frame<R: Read>(r: &mut R) -> Result<Option<Vec<u8>>, WireError> {
    let mut len_buf = [0u8; 4];
    let mut got = 0;
    while got < 4 {
        match r.read(&mut len_buf[got..]) {
            Ok(0) if got == 0 => return Ok(None),
            Ok(0) => {
                return Err(WireError::Frame(format!(
                    "stream closed {got} bytes into the length prefix"
                )))
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    let len = u32::from_be_bytes(len_buf);
    if len > MAX_FRAME_LEN {
        return Err(WireError::Frame(format!(
            "announced body of {len} bytes exceeds MAX_FRAME_LEN ({MAX_FRAME_LEN})"
        )));
    }
    let mut body = vec![0u8; len as usize];
    let mut at = 0;
    while at < body.len() {
        match r.read(&mut body[at..]) {
            Ok(0) => {
                return Err(WireError::Frame(format!(
                    "stream closed {at} bytes into a {len}-byte body"
                )))
            }
            Ok(n) => at += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(WireError::Io(e)),
        }
    }
    Ok(Some(body))
}

/// Append one frame (length prefix + body) to a reusable output buffer
/// without flushing — the batched write path: encode a whole wave of
/// responses into one buffer, then hand it to the socket as a single
/// `write_all`.  Steady state this allocates nothing: the caller clears
/// and reuses `out`, whose capacity is retained.
pub fn write_frame_into(out: &mut Vec<u8>, body: &[u8]) -> Result<(), WireError> {
    if body.len() as u64 > MAX_FRAME_LEN as u64 {
        return Err(WireError::Frame(format!(
            "frame body of {} bytes exceeds MAX_FRAME_LEN ({MAX_FRAME_LEN})",
            body.len()
        )));
    }
    out.extend_from_slice(&(body.len() as u32).to_be_bytes());
    out.extend_from_slice(body);
    Ok(())
}

/// How many bytes one [`FrameBuffer::fill_from`] call asks the socket
/// for (64 KiB — comfortably above the typical request wave, far below
/// [`MAX_FRAME_LEN`]).
pub const FILL_CHUNK: usize = 64 * 1024;

/// Accumulating frame decoder for the greedy read path: append whatever
/// the socket has with [`FrameBuffer::fill_from`], then pull every
/// *complete* frame already buffered with [`FrameBuffer::next_frame`]
/// before taking any lock.  Extraction is zero-copy (the returned body
/// borrows the internal buffer) and, after warm-up, allocation-free:
/// the buffer compacts in place and its capacity is retained across
/// fills.
///
/// The announced length is validated against [`MAX_FRAME_LEN`] as soon
/// as the 4-byte header is visible — *before* any body bytes are waited
/// for and before any buffer grows toward it — so a hostile header
/// cannot trigger a giant allocation (the buffer only ever grows by
/// [`FILL_CHUNK`] per read, independent of what the peer announces).
#[derive(Debug, Default)]
pub struct FrameBuffer {
    /// Accumulated bytes; `..pos` is the consumed prefix of frames
    /// already handed out by [`FrameBuffer::next_frame`].
    buf: Vec<u8>,
    pos: usize,
}

impl FrameBuffer {
    /// An empty buffer (first fill sizes it to [`FILL_CHUNK`]).
    pub fn new() -> FrameBuffer {
        FrameBuffer::default()
    }

    /// Bytes buffered but not yet consumed — nonzero at EOF means the
    /// peer hung up mid-frame (a [`WireError::Frame`] truncation for
    /// the caller to report).
    pub fn buffered(&self) -> usize {
        self.buf.len() - self.pos
    }

    /// One (blocking) read appended to the buffer; returns the byte
    /// count (0 = EOF).  The consumed prefix is compacted away first,
    /// so memory stays bounded by one partial frame plus one chunk.
    /// Interrupted reads retry; any other I/O error is returned with
    /// the buffer unchanged.
    pub fn fill_from<R: Read>(&mut self, r: &mut R) -> Result<usize, WireError> {
        if self.pos > 0 {
            self.buf.drain(..self.pos);
            self.pos = 0;
        }
        let len = self.buf.len();
        self.buf.resize(len + FILL_CHUNK, 0);
        loop {
            match r.read(&mut self.buf[len..]) {
                Ok(n) => {
                    self.buf.truncate(len + n);
                    return Ok(n);
                }
                Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
                Err(e) => {
                    self.buf.truncate(len);
                    return Err(WireError::Io(e));
                }
            }
        }
    }

    /// Extract the next complete frame already buffered, zero-copy.
    /// `Ok(None)` means more bytes are needed (call
    /// [`FrameBuffer::fill_from`] again); the returned body slice is
    /// valid until the next `fill_from`.  An announced length beyond
    /// [`MAX_FRAME_LEN`] is rejected here, from the header alone.
    pub fn next_frame(&mut self) -> Result<Option<&[u8]>, WireError> {
        let avail = self.buf.len() - self.pos;
        if avail < 4 {
            return Ok(None);
        }
        let p = self.pos;
        let len =
            u32::from_be_bytes([self.buf[p], self.buf[p + 1], self.buf[p + 2], self.buf[p + 3]]);
        if len > MAX_FRAME_LEN {
            return Err(WireError::Frame(format!(
                "announced body of {len} bytes exceeds MAX_FRAME_LEN ({MAX_FRAME_LEN})"
            )));
        }
        let need = 4 + len as usize;
        if avail < need {
            return Ok(None);
        }
        self.pos += need;
        Ok(Some(&self.buf[p + 4..p + need]))
    }
}

/// Read one frame and parse its body as JSON.
pub fn read_json<R: Read>(r: &mut R) -> Result<Option<Json>, WireError> {
    let Some(body) = read_frame(r)? else { return Ok(None) };
    let text = String::from_utf8(body)
        .map_err(|e| WireError::Parse(format!("frame body is not UTF-8: {e}")))?;
    Json::parse(&text).map(Some).map_err(|e| WireError::Parse(format!("{e}")))
}

/// Serialize `j` compactly and write it as one frame.
pub fn write_json<W: Write>(w: &mut W, j: &Json) -> Result<(), WireError> {
    write_frame(w, j.to_string_compact().as_bytes())
}

// ---- shared field helpers -------------------------------------------------

fn want_f64(j: &Json, key: &str) -> Result<f64, WireError> {
    j.get(key)
        .and_then(Json::as_f64)
        .ok_or_else(|| WireError::Parse(format!("missing/non-numeric field {key:?}")))
}

fn want_str<'a>(j: &'a Json, key: &str) -> Result<&'a str, WireError> {
    j.get(key)
        .and_then(Json::as_str)
        .ok_or_else(|| WireError::Parse(format!("missing/non-string field {key:?}")))
}

fn want_usize(j: &Json, key: &str) -> Result<usize, WireError> {
    j.get(key)
        .and_then(Json::as_usize)
        .ok_or_else(|| WireError::Parse(format!("missing/non-integer field {key:?}")))
}

/// Tenant ids ride as JSON numbers, so the wire restricts them to the
/// exactly-representable range (< 2⁵³ — far beyond any fleet).
fn want_tenant(j: &Json) -> Result<TenantId, WireError> {
    let x = want_f64(j, "tenant")?;
    // lint:allow(float-eq): fract() != 0.0 is an exact integrality test
    if x.fract() != 0.0 || !(0.0..9.0e15).contains(&x) {
        return Err(WireError::Parse(format!("tenant id {x} is not a small non-negative integer")));
    }
    Ok(x as TenantId)
}

/// `device: i` or `device: null` (fleet-wide) for deadline/risk deltas.
fn opt_device(j: &Json) -> Result<Option<usize>, WireError> {
    match j.get("device") {
        None | Some(Json::Null) => Ok(None),
        Some(v) => v
            .as_usize()
            .map(Some)
            .ok_or_else(|| WireError::Parse("field \"device\" must be an index or null".into())),
    }
}

/// Encode a risk bound as its CLI spelling (`ecr`, `gauss`, `bernstein`,
/// `calibrated:SCALE`) so [`RiskBound::parse`] is the exact inverse.
pub fn bound_to_wire(b: RiskBound) -> String {
    match b.scale() {
        Some(s) => format!("calibrated:{s}"),
        None => b.name().to_string(),
    }
}

fn parse_bound(s: &str) -> Result<RiskBound, WireError> {
    RiskBound::parse(s).ok_or_else(|| WireError::Parse(format!("unknown risk bound {s:?}")))
}

// ---- scenario / delta encoding --------------------------------------------

/// One device as wire JSON.  The model travels by registry name
/// ([`ModelProfile::by_name`]), not by value: both peers share the
/// in-crate profile registry, so a name pins the full profile.
pub fn device_to_json(d: &Device) -> Json {
    Json::Obj(vec![
        ("model".into(), Json::Str(d.model.name.clone())),
        ("p_tx".into(), Json::Num(d.uplink.p_tx)),
        ("gain".into(), Json::Num(d.uplink.gain)),
        ("n0".into(), Json::Num(d.uplink.n0)),
        ("deadline_s".into(), Json::Num(d.deadline_s)),
        ("risk".into(), Json::Num(d.risk)),
    ])
}

/// Decode one wire device; unknown model names are schema errors.
pub fn device_from_json(j: &Json) -> Result<Device, WireError> {
    let name = want_str(j, "model")?;
    let model = ModelProfile::by_name(name)
        .ok_or_else(|| WireError::Parse(format!("unknown model {name:?}")))?;
    Ok(Device {
        model,
        uplink: Uplink {
            p_tx: want_f64(j, "p_tx")?,
            gain: want_f64(j, "gain")?,
            n0: want_f64(j, "n0")?,
        },
        deadline_s: want_f64(j, "deadline_s")?,
        risk: want_f64(j, "risk")?,
    })
}

/// A tenant fleet as wire JSON (`admit` payload).
pub fn scenario_to_json(sc: &Scenario) -> Json {
    Json::Obj(vec![
        ("total_bandwidth_hz".into(), Json::Num(sc.total_bandwidth_hz)),
        ("devices".into(), Json::Arr(sc.devices.iter().map(device_to_json).collect())),
    ])
}

/// Decode a wire scenario (at least one device required downstream; the
/// service validates that on admission).
pub fn scenario_from_json(j: &Json) -> Result<Scenario, WireError> {
    let devices = j
        .get("devices")
        .and_then(Json::as_arr)
        .ok_or_else(|| WireError::Parse("missing/non-array field \"devices\"".into()))?
        .iter()
        .map(device_from_json)
        .collect::<Result<Vec<_>, _>>()?;
    Ok(Scenario { devices, total_bandwidth_hz: want_f64(j, "total_bandwidth_hz")? })
}

/// A scenario delta as tagged wire JSON; kinds mirror the fleet metrics
/// vocabulary (`join`, `leave`, `deadline`, `risk`, `channel`,
/// `bandwidth`, `bound`).
pub fn delta_to_json(d: &ScenarioDelta) -> Json {
    let kind = |k: &str| ("kind".to_string(), Json::Str(k.into()));
    let dev = |i: &Option<usize>| match i {
        Some(i) => Json::Num(*i as f64),
        None => Json::Null,
    };
    match d {
        ScenarioDelta::Join(device) => {
            Json::Obj(vec![kind("join"), ("device".into(), device_to_json(device))])
        }
        ScenarioDelta::Leave(i) => {
            Json::Obj(vec![kind("leave"), ("device".into(), Json::Num(*i as f64))])
        }
        ScenarioDelta::Deadline { device, deadline_s } => Json::Obj(vec![
            kind("deadline"),
            ("device".into(), dev(device)),
            ("deadline_s".into(), Json::Num(*deadline_s)),
        ]),
        ScenarioDelta::Risk { device, risk } => Json::Obj(vec![
            kind("risk"),
            ("device".into(), dev(device)),
            ("risk".into(), Json::Num(*risk)),
        ]),
        ScenarioDelta::Channel { device, uplink } => Json::Obj(vec![
            kind("channel"),
            ("device".into(), Json::Num(*device as f64)),
            ("p_tx".into(), Json::Num(uplink.p_tx)),
            ("gain".into(), Json::Num(uplink.gain)),
            ("n0".into(), Json::Num(uplink.n0)),
        ]),
        ScenarioDelta::TotalBandwidth(b) => {
            Json::Obj(vec![kind("bandwidth"), ("total_bandwidth_hz".into(), Json::Num(*b))])
        }
        ScenarioDelta::Bound(b) => {
            Json::Obj(vec![kind("bound"), ("bound".into(), Json::Str(bound_to_wire(*b)))])
        }
    }
}

/// Decode a tagged wire delta (inverse of [`delta_to_json`]).
pub fn delta_from_json(j: &Json) -> Result<ScenarioDelta, WireError> {
    match want_str(j, "kind")? {
        "join" => {
            let d = j
                .get("device")
                .ok_or_else(|| WireError::Parse("join requires a \"device\" object".into()))?;
            Ok(ScenarioDelta::Join(device_from_json(d)?))
        }
        "leave" => Ok(ScenarioDelta::Leave(want_usize(j, "device")?)),
        "deadline" => Ok(ScenarioDelta::Deadline {
            device: opt_device(j)?,
            deadline_s: want_f64(j, "deadline_s")?,
        }),
        "risk" => Ok(ScenarioDelta::Risk { device: opt_device(j)?, risk: want_f64(j, "risk")? }),
        "channel" => Ok(ScenarioDelta::Channel {
            device: want_usize(j, "device")?,
            uplink: Uplink {
                p_tx: want_f64(j, "p_tx")?,
                gain: want_f64(j, "gain")?,
                n0: want_f64(j, "n0")?,
            },
        }),
        "bandwidth" => Ok(ScenarioDelta::TotalBandwidth(want_f64(j, "total_bandwidth_hz")?)),
        "bound" => Ok(ScenarioDelta::Bound(parse_bound(want_str(j, "bound")?)?)),
        other => Err(WireError::Parse(format!("unknown delta kind {other:?}"))),
    }
}

// ---- requests -------------------------------------------------------------

/// One client→server message.  The five kinds mirror the in-process
/// [`super::PlannerService`] API one-to-one.
#[derive(Clone, Debug)]
pub enum WireRequest {
    /// Admit a tenant fleet (maps to
    /// [`super::PlannerService::admit_tenant_with`]).
    Admit {
        /// Tenant id to admit under.
        tenant: TenantId,
        /// The tenant's initial fleet.
        scenario: Scenario,
        /// Risk bound every sub-fleet plans with.
        bound: RiskBound,
    },
    /// Enqueue one scenario delta (maps to
    /// [`super::PlannerService::submit`]); a full queue answers
    /// [`WireResponse::Shed`].
    Delta {
        /// Target tenant.
        tenant: TenantId,
        /// The change to apply at the next drain.
        delta: ScenarioDelta,
    },
    /// Drain the backlog, then return the tenant's assembled fleet-wide
    /// plan (maps to [`super::PlannerService::assembled_plan`]).
    Plan {
        /// Tenant whose plan to read.
        tenant: TenantId,
    },
    /// Drain the backlog, then return the service counters (maps to
    /// [`super::PlannerService::stats`]).
    Stats,
    /// Drain, answer [`WireResponse::Bye`], and stop the server.
    Shutdown,
    /// Many requests in one frame, answered by one
    /// [`WireResponse::Batch`] carrying one inner response per inner
    /// request, in order.  Execution is exactly the sequential-singles
    /// semantics (a shed inside a batch drops that delta and drains,
    /// just as a single shed would; a shutdown inside a batch stops the
    /// server *after* the full batch response is written).  Batches
    /// never nest.
    Batch(
        /// The inner requests, executed in order.
        Vec<WireRequest>,
    ),
}

impl WireRequest {
    /// Stable lowercase request tag (`admit`, `delta`, `plan`, `stats`,
    /// `shutdown`, `batch`).
    pub fn kind(&self) -> &'static str {
        match self {
            WireRequest::Admit { .. } => "admit",
            WireRequest::Delta { .. } => "delta",
            WireRequest::Plan { .. } => "plan",
            WireRequest::Stats => "stats",
            WireRequest::Shutdown => "shutdown",
            WireRequest::Batch(_) => "batch",
        }
    }

    /// Encode as wire JSON (compact serialization of this value is the
    /// exact frame body).
    pub fn to_json(&self) -> Json {
        let kind = ("kind".to_string(), Json::Str(self.kind().into()));
        match self {
            WireRequest::Admit { tenant, scenario, bound } => Json::Obj(vec![
                kind,
                ("tenant".into(), Json::Num(*tenant as f64)),
                ("bound".into(), Json::Str(bound_to_wire(*bound))),
                ("scenario".into(), scenario_to_json(scenario)),
            ]),
            WireRequest::Delta { tenant, delta } => Json::Obj(vec![
                kind,
                ("tenant".into(), Json::Num(*tenant as f64)),
                ("delta".into(), delta_to_json(delta)),
            ]),
            WireRequest::Plan { tenant } => {
                Json::Obj(vec![kind, ("tenant".into(), Json::Num(*tenant as f64))])
            }
            WireRequest::Stats | WireRequest::Shutdown => Json::Obj(vec![kind]),
            WireRequest::Batch(reqs) => Json::Obj(vec![
                kind,
                ("requests".into(), Json::Arr(reqs.iter().map(WireRequest::to_json).collect())),
            ]),
        }
    }

    /// Decode a wire request (inverse of [`WireRequest::to_json`]).
    pub fn from_json(j: &Json) -> Result<WireRequest, WireError> {
        match want_str(j, "kind")? {
            "admit" => Ok(WireRequest::Admit {
                tenant: want_tenant(j)?,
                bound: parse_bound(want_str(j, "bound")?)?,
                scenario: scenario_from_json(
                    j.get("scenario")
                        .ok_or_else(|| WireError::Parse("admit requires \"scenario\"".into()))?,
                )?,
            }),
            "delta" => Ok(WireRequest::Delta {
                tenant: want_tenant(j)?,
                delta: delta_from_json(
                    j.get("delta")
                        .ok_or_else(|| WireError::Parse("delta requires \"delta\"".into()))?,
                )?,
            }),
            "plan" => Ok(WireRequest::Plan { tenant: want_tenant(j)? }),
            "stats" => Ok(WireRequest::Stats),
            "shutdown" => Ok(WireRequest::Shutdown),
            "batch" => {
                let items = j.get("requests").and_then(Json::as_arr).ok_or_else(|| {
                    WireError::Parse("batch requires a \"requests\" array".into())
                })?;
                let mut reqs = Vec::with_capacity(items.len());
                for item in items {
                    let r = WireRequest::from_json(item)?;
                    if matches!(r, WireRequest::Batch(_)) {
                        return Err(WireError::Parse("batch requests cannot nest".into()));
                    }
                    reqs.push(r);
                }
                Ok(WireRequest::Batch(reqs))
            }
            other => Err(WireError::Parse(format!("unknown request kind {other:?}"))),
        }
    }
}

// ---- responses ------------------------------------------------------------

/// Stable error code for a [`ServiceError`] travelling in a
/// [`WireResponse::Error`] (the catalog is part of the wire spec in
/// EXPERIMENTS.md §Serving).  [`ServiceError::Backpressure`] never
/// reaches this mapping — a full queue answers with
/// [`WireResponse::Shed`] instead.
pub fn error_code(e: &ServiceError) -> &'static str {
    match e {
        ServiceError::Backpressure { .. } => "backpressure",
        ServiceError::CircuitOpen(_) => "circuit-open",
        ServiceError::UnknownTenant(_) => "unknown-tenant",
        ServiceError::DuplicateTenant(_) => "duplicate-tenant",
        ServiceError::InvalidOptions(_) => "invalid-options",
        ServiceError::Plan(_) => "plan",
    }
}

/// One server→client message.
#[derive(Clone, Debug)]
pub enum WireResponse {
    /// `admit` succeeded.
    Admitted {
        /// The admitted tenant.
        tenant: TenantId,
        /// Tenant-wide planned energy after admission, J.
        energy_j: f64,
    },
    /// `delta` was accepted into the bounded queue (it applies at the
    /// next drain).
    Queued {
        /// Queue depth after this request.
        depth: usize,
    },
    /// `delta` was **shed**: the queue was full, the request was
    /// dropped, and the server drained the backlog so the connection can
    /// make progress.  The client should wait `backoff_s` before
    /// retrying (jittered exponential hint from
    /// [`crate::fault::FaultStreams::backoff_s`]).
    Shed {
        /// Suggested client back-off, seconds.
        backoff_s: f64,
        /// Consecutive sheds for this tenant (0-based attempt counter
        /// feeding the exponential).
        attempt: u32,
    },
    /// `plan` result: the tenant's assembled fleet-wide decision.
    PlanRow {
        /// The tenant whose plan this is.
        tenant: TenantId,
        /// Requests drained (applied/absorbed/rejected/superseded)
        /// before assembling the plan.
        drained: usize,
        /// Tenant-wide planned energy, J.
        energy_j: f64,
        /// The assembled decision (partition / bandwidth / frequency per
        /// device, tenant device order).
        plan: Plan,
    },
    /// `stats` result: deterministic service counters plus queue state.
    StatsRow {
        /// Requests drained before reading the counters.
        drained: usize,
        /// Admitted tenants.
        tenants: usize,
        /// Pending requests left in the queue (0 after a drain).
        queue_len: usize,
        /// The service's cumulative counters.
        stats: ServiceStats,
    },
    /// The request was refused; `code` is from [`error_code`]'s catalog
    /// plus `"bad-request"` for schema violations.
    Error {
        /// Stable machine-readable refusal code.
        code: String,
        /// Human-readable detail (the underlying `Display` text).
        message: String,
    },
    /// `shutdown` acknowledged; the server stops accepting connections.
    Bye,
    /// Answer to a [`WireRequest::Batch`]: one inner response per inner
    /// request, in request order.  Never nests.
    Batch(
        /// The inner responses, request order.
        Vec<WireResponse>,
    ),
}

impl WireResponse {
    /// Stable lowercase response tag (`admitted`, `queued`, `shed`,
    /// `plan`, `stats`, `error`, `bye`, `batch`).
    pub fn kind(&self) -> &'static str {
        match self {
            WireResponse::Admitted { .. } => "admitted",
            WireResponse::Queued { .. } => "queued",
            WireResponse::Shed { .. } => "shed",
            WireResponse::PlanRow { .. } => "plan",
            WireResponse::StatsRow { .. } => "stats",
            WireResponse::Error { .. } => "error",
            WireResponse::Bye => "bye",
            WireResponse::Batch(_) => "batch",
        }
    }

    /// Encode as wire JSON (compact serialization is the frame body).
    pub fn to_json(&self) -> Json {
        let kind = ("kind".to_string(), Json::Str(self.kind().into()));
        match self {
            WireResponse::Admitted { tenant, energy_j } => Json::Obj(vec![
                kind,
                ("tenant".into(), Json::Num(*tenant as f64)),
                ("energy_j".into(), Json::Num(*energy_j)),
            ]),
            WireResponse::Queued { depth } => {
                Json::Obj(vec![kind, ("depth".into(), Json::Num(*depth as f64))])
            }
            WireResponse::Shed { backoff_s, attempt } => Json::Obj(vec![
                kind,
                ("backoff_s".into(), Json::Num(*backoff_s)),
                ("attempt".into(), Json::Num(*attempt as f64)),
            ]),
            WireResponse::PlanRow { tenant, drained, energy_j, plan } => Json::Obj(vec![
                kind,
                ("tenant".into(), Json::Num(*tenant as f64)),
                ("drained".into(), Json::Num(*drained as f64)),
                ("energy_j".into(), Json::Num(*energy_j)),
                (
                    "partition".into(),
                    Json::Arr(plan.partition.iter().map(|&m| Json::Num(m as f64)).collect()),
                ),
                (
                    "bandwidth_hz".into(),
                    Json::Arr(plan.bandwidth_hz.iter().map(|&b| Json::Num(b)).collect()),
                ),
                (
                    "freq_ghz".into(),
                    Json::Arr(plan.freq_ghz.iter().map(|&f| Json::Num(f)).collect()),
                ),
            ]),
            WireResponse::StatsRow { drained, tenants, queue_len, stats } => Json::Obj(vec![
                kind,
                ("drained".into(), Json::Num(*drained as f64)),
                ("tenants".into(), Json::Num(*tenants as f64)),
                ("queue_len".into(), Json::Num(*queue_len as f64)),
                ("submitted".into(), Json::Num(stats.submitted as f64)),
                ("refused".into(), Json::Num(stats.refused as f64)),
                ("superseded".into(), Json::Num(stats.superseded as f64)),
                ("shard_ops".into(), Json::Num(stats.shard_ops as f64)),
                ("replans".into(), Json::Num(stats.replans as f64)),
                ("cache_hits".into(), Json::Num(stats.cache_hits as f64)),
                ("rebases".into(), Json::Num(stats.rebases as f64)),
                ("rejected".into(), Json::Num(stats.rejected as f64)),
                ("rebalance_moves".into(), Json::Num(stats.rebalance_moves as f64)),
                ("breaker_trips".into(), Json::Num(stats.breaker_trips as f64)),
            ]),
            WireResponse::Error { code, message } => Json::Obj(vec![
                kind,
                ("code".into(), Json::Str(code.clone())),
                ("message".into(), Json::Str(message.clone())),
            ]),
            WireResponse::Bye => Json::Obj(vec![kind]),
            WireResponse::Batch(resps) => Json::Obj(vec![
                kind,
                (
                    "responses".into(),
                    Json::Arr(resps.iter().map(WireResponse::to_json).collect()),
                ),
            ]),
        }
    }

    /// Decode a wire response (inverse of [`WireResponse::to_json`];
    /// used by the load generator and tests).
    pub fn from_json(j: &Json) -> Result<WireResponse, WireError> {
        match want_str(j, "kind")? {
            "admitted" => Ok(WireResponse::Admitted {
                tenant: want_tenant(j)?,
                energy_j: want_f64(j, "energy_j")?,
            }),
            "queued" => Ok(WireResponse::Queued { depth: want_usize(j, "depth")? }),
            "shed" => Ok(WireResponse::Shed {
                backoff_s: want_f64(j, "backoff_s")?,
                attempt: want_usize(j, "attempt")? as u32,
            }),
            "plan" => {
                let arr = |key: &str| -> Result<Vec<f64>, WireError> {
                    j.get(key)
                        .and_then(Json::f64_array)
                        .ok_or_else(|| WireError::Parse(format!("missing/non-array {key:?}")))
                };
                Ok(WireResponse::PlanRow {
                    tenant: want_tenant(j)?,
                    drained: want_usize(j, "drained")?,
                    energy_j: want_f64(j, "energy_j")?,
                    plan: Plan {
                        partition: j
                            .get("partition")
                            .and_then(Json::usize_array)
                            .ok_or_else(|| {
                                WireError::Parse("missing/non-array \"partition\"".into())
                            })?,
                        bandwidth_hz: arr("bandwidth_hz")?,
                        freq_ghz: arr("freq_ghz")?,
                    },
                })
            }
            "stats" => {
                let n = |key: &str| -> Result<u64, WireError> {
                    Ok(want_f64(j, key)? as u64)
                };
                Ok(WireResponse::StatsRow {
                    drained: want_usize(j, "drained")?,
                    tenants: want_usize(j, "tenants")?,
                    queue_len: want_usize(j, "queue_len")?,
                    stats: ServiceStats {
                        submitted: n("submitted")?,
                        refused: n("refused")?,
                        superseded: n("superseded")?,
                        shard_ops: n("shard_ops")?,
                        replans: n("replans")?,
                        cache_hits: n("cache_hits")?,
                        rebases: n("rebases")?,
                        rejected: n("rejected")?,
                        rebalance_moves: n("rebalance_moves")?,
                        breaker_trips: n("breaker_trips")?,
                    },
                })
            }
            "error" => Ok(WireResponse::Error {
                code: want_str(j, "code")?.to_string(),
                message: want_str(j, "message")?.to_string(),
            }),
            "bye" => Ok(WireResponse::Bye),
            "batch" => {
                let items = j.get("responses").and_then(Json::as_arr).ok_or_else(|| {
                    WireError::Parse("batch requires a \"responses\" array".into())
                })?;
                let mut resps = Vec::with_capacity(items.len());
                for item in items {
                    let r = WireResponse::from_json(item)?;
                    if matches!(r, WireResponse::Batch(_)) {
                        return Err(WireError::Parse("batch responses cannot nest".into()));
                    }
                    resps.push(r);
                }
                Ok(WireResponse::Batch(resps))
            }
            other => Err(WireError::Parse(format!("unknown response kind {other:?}"))),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn sample_device() -> Device {
        Device {
            model: ModelProfile::alexnet_paper(),
            uplink: Uplink::from_distance(120.0),
            deadline_s: 0.25,
            risk: 0.05,
        }
    }

    #[test]
    fn frame_roundtrip_and_eof() {
        let mut buf = Vec::new();
        write_frame(&mut buf, b"hello").unwrap();
        write_frame(&mut buf, b"").unwrap();
        let mut r = std::io::Cursor::new(buf);
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"hello");
        assert_eq!(read_frame(&mut r).unwrap().unwrap(), b"");
        assert!(read_frame(&mut r).unwrap().is_none(), "clean EOF at a frame boundary");
    }

    #[test]
    fn truncated_and_oversize_frames_are_errors() {
        let mut full = encode_frame(b"payload");
        full.truncate(7); // mid-body
        let mut r = std::io::Cursor::new(full);
        assert!(matches!(read_frame(&mut r), Err(WireError::Frame(_))));

        let mut huge = (MAX_FRAME_LEN + 1).to_be_bytes().to_vec();
        huge.extend_from_slice(&[0; 8]);
        let mut r = std::io::Cursor::new(huge);
        assert!(matches!(read_frame(&mut r), Err(WireError::Frame(_))));

        let mut half_prefix = std::io::Cursor::new(vec![0u8, 0]);
        assert!(matches!(read_frame(&mut half_prefix), Err(WireError::Frame(_))));
    }

    #[test]
    fn every_request_kind_roundtrips() {
        let mut rng = Rng::new(9);
        let sc = Scenario::uniform(&ModelProfile::alexnet_paper(), 3, 12e6, 0.25, 0.05, &mut rng);
        let reqs = vec![
            WireRequest::Admit { tenant: 1, scenario: sc, bound: RiskBound::calibrated(0.8) },
            WireRequest::Delta { tenant: 1, delta: ScenarioDelta::TotalBandwidth(9e6) },
            WireRequest::Delta { tenant: 1, delta: ScenarioDelta::Join(sample_device()) },
            WireRequest::Delta { tenant: 1, delta: ScenarioDelta::Leave(2) },
            WireRequest::Delta {
                tenant: 1,
                delta: ScenarioDelta::Deadline { device: None, deadline_s: 0.3 },
            },
            WireRequest::Delta {
                tenant: 1,
                delta: ScenarioDelta::Risk { device: Some(1), risk: 0.1 },
            },
            WireRequest::Delta {
                tenant: 1,
                delta: ScenarioDelta::Channel {
                    device: 0,
                    uplink: Uplink::from_gain_db(-78.0),
                },
            },
            WireRequest::Delta { tenant: 1, delta: ScenarioDelta::Bound(RiskBound::Gaussian) },
            WireRequest::Plan { tenant: 1 },
            WireRequest::Stats,
            WireRequest::Shutdown,
        ];
        for req in reqs {
            let body = req.to_json().to_string_compact();
            let back = WireRequest::from_json(&Json::parse(&body).unwrap()).unwrap();
            let body2 = back.to_json().to_string_compact();
            assert_eq!(body, body2, "request {:?} must roundtrip byte-identically", req.kind());
        }
    }

    #[test]
    fn every_response_kind_roundtrips() {
        let resps = vec![
            WireResponse::Admitted { tenant: 3, energy_j: 1.25 },
            WireResponse::Queued { depth: 7 },
            WireResponse::Shed { backoff_s: 0.375, attempt: 2 },
            WireResponse::PlanRow {
                tenant: 3,
                drained: 4,
                energy_j: 2.5,
                plan: Plan {
                    partition: vec![0, 3],
                    bandwidth_hz: vec![4e6, 8e6],
                    freq_ghz: vec![1.5, 2.0],
                },
            },
            WireResponse::StatsRow {
                drained: 1,
                tenants: 2,
                queue_len: 0,
                stats: ServiceStats { submitted: 10, superseded: 2, ..Default::default() },
            },
            WireResponse::Error { code: "unknown-tenant".into(), message: "unknown tenant 9".into() },
            WireResponse::Bye,
        ];
        for resp in resps {
            let body = resp.to_json().to_string_compact();
            let back = WireResponse::from_json(&Json::parse(&body).unwrap()).unwrap();
            assert_eq!(
                body,
                back.to_json().to_string_compact(),
                "response {:?} must roundtrip byte-identically",
                resp.kind()
            );
        }
    }

    #[test]
    fn batch_request_and_response_roundtrip() {
        let req = WireRequest::Batch(vec![
            WireRequest::Delta { tenant: 1, delta: ScenarioDelta::TotalBandwidth(9e6) },
            WireRequest::Plan { tenant: 1 },
            WireRequest::Stats,
        ]);
        let body = req.to_json().to_string_compact();
        assert!(body.starts_with(r#"{"kind":"batch","requests":["#));
        let back = WireRequest::from_json(&Json::parse(&body).unwrap()).unwrap();
        assert_eq!(body, back.to_json().to_string_compact());

        let resp = WireResponse::Batch(vec![
            WireResponse::Queued { depth: 1 },
            WireResponse::Shed { backoff_s: 0.1, attempt: 0 },
            WireResponse::Bye,
        ]);
        let body = resp.to_json().to_string_compact();
        assert!(body.starts_with(r#"{"kind":"batch","responses":["#));
        let back = WireResponse::from_json(&Json::parse(&body).unwrap()).unwrap();
        assert_eq!(body, back.to_json().to_string_compact());
    }

    #[test]
    fn nested_batches_are_rejected() {
        let req = r#"{"kind":"batch","requests":[{"kind":"batch","requests":[]}]}"#;
        assert!(matches!(
            WireRequest::from_json(&Json::parse(req).unwrap()),
            Err(WireError::Parse(_))
        ));
        let resp = r#"{"kind":"batch","responses":[{"kind":"batch","responses":[]}]}"#;
        assert!(matches!(
            WireResponse::from_json(&Json::parse(resp).unwrap()),
            Err(WireError::Parse(_))
        ));
        let missing = r#"{"kind":"batch"}"#;
        assert!(WireRequest::from_json(&Json::parse(missing).unwrap()).is_err());
    }

    #[test]
    fn frame_buffer_extracts_every_buffered_frame_greedily() {
        let mut stream = Vec::new();
        for body in [b"alpha".as_slice(), b"", b"gamma-with-more-bytes"] {
            write_frame_into(&mut stream, body).unwrap();
        }
        // Append half of a fourth frame: header + partial body.
        let mut partial = encode_frame(b"delta");
        partial.truncate(7);
        stream.extend_from_slice(&partial);

        let mut fb = FrameBuffer::new();
        let mut r = std::io::Cursor::new(stream);
        assert!(fb.fill_from(&mut r).unwrap() > 0);
        assert_eq!(fb.next_frame().unwrap().unwrap(), b"alpha");
        assert_eq!(fb.next_frame().unwrap().unwrap(), b"");
        assert_eq!(fb.next_frame().unwrap().unwrap(), b"gamma-with-more-bytes");
        // The partial frame stays buffered until more bytes arrive.
        assert!(fb.next_frame().unwrap().is_none());
        assert!(fb.buffered() > 0, "partial frame must be detectable at EOF");
        // EOF now: the cursor is exhausted.
        assert_eq!(fb.fill_from(&mut r).unwrap(), 0);
    }

    #[test]
    fn frame_buffer_rejects_hostile_headers_from_the_header_alone() {
        // A 4 GiB announcement with zero body bytes behind it: the
        // length must be refused before any body buffer could grow.
        let huge = 0xFFFF_FFFFu32.to_be_bytes().to_vec();
        let mut fb = FrameBuffer::new();
        let mut r = std::io::Cursor::new(huge);
        assert!(fb.fill_from(&mut r).unwrap() > 0);
        assert!(matches!(fb.next_frame(), Err(WireError::Frame(_))));
    }

    #[test]
    fn write_frame_into_matches_encode_frame_and_caps_length() {
        let mut out = Vec::new();
        write_frame_into(&mut out, b"payload").unwrap();
        assert_eq!(out, encode_frame(b"payload"));
        let big = vec![0u8; MAX_FRAME_LEN as usize + 1];
        assert!(matches!(write_frame_into(&mut out, &big), Err(WireError::Frame(_))));
    }

    #[test]
    fn bound_wire_spelling_roundtrips_the_scale() {
        for b in [
            RiskBound::Ecr,
            RiskBound::Gaussian,
            RiskBound::Bernstein,
            RiskBound::calibrated(0.8),
        ] {
            assert_eq!(RiskBound::parse(&bound_to_wire(b)), Some(b));
        }
    }

    #[test]
    fn malformed_messages_are_parse_errors_not_panics() {
        for text in [
            r#"{"kind":"warp"}"#,
            r#"{"kind":"plan"}"#,
            r#"{"kind":"delta","tenant":1}"#,
            r#"{"kind":"delta","tenant":1,"delta":{"kind":"join"}}"#,
            r#"{"kind":"admit","tenant":1,"bound":"nope","scenario":{"total_bandwidth_hz":1,"devices":[]}}"#,
            r#"{"kind":"plan","tenant":1.5}"#,
        ] {
            let j = Json::parse(text).unwrap();
            assert!(WireRequest::from_json(&j).is_err(), "{text} must be rejected");
        }
    }
}
