//! PJRT runtime: load AOT HLO-text artifacts and execute them on the CPU
//! client from the rust hot path (python is never involved at runtime).
//!
//! * Interchange format is HLO **text** — see `python/compile/aot.py` and
//!   /opt/xla-example/README for why serialized protos are rejected by
//!   xla_extension 0.5.1.
//! * Weights are uploaded **once** per partition side as persistent
//!   `PjRtBuffer`s (the RWTS sidecar from aot.py) and reused by every
//!   `execute_b` call; only the activation crosses host↔device per
//!   request.
//! * Executables are compiled lazily and cached per (role, m, batch).
//!
//! PJRT handles are raw pointers (`!Send`), so a serving system must own
//! an `Engine` inside a dedicated runtime thread — `coordinator` does
//! exactly that.

// lint:allow-file(hash-order): weight/executable caches are lookup-only
// (keyed get/insert); nothing iterates them into output.
// lint:allow-file(wall-clock): PJRT compile/exec timing is measurement
// output by definition, never an input to planning.
use std::collections::HashMap;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::models::manifest::{ArtifactEntry, Manifest, ManifestModel, Role};

/// A parsed RWTS weight tensor.
#[derive(Clone, Debug)]
pub struct WeightTensor {
    pub name: String,
    pub dims: Vec<usize>,
    pub data: Vec<f32>,
}

/// Parse the RWTS sidecar written by `aot.py::_write_weights`.
pub fn load_weights(path: &Path) -> Result<Vec<WeightTensor>> {
    let raw = std::fs::read(path).with_context(|| format!("reading {}", path.display()))?;
    let mut off = 0usize;
    let take = |off: &mut usize, n: usize| -> Result<&[u8]> {
        let s = raw.get(*off..*off + n).ok_or_else(|| anyhow!("truncated RWTS file"))?;
        *off += n;
        Ok(s)
    };
    if take(&mut off, 4)? != b"RWTS" {
        bail!("bad RWTS magic in {}", path.display());
    }
    let u32_at = |b: &[u8]| u32::from_le_bytes(b.try_into().unwrap());
    let version = u32_at(take(&mut off, 4)?);
    if version != 1 {
        bail!("unsupported RWTS version {version}");
    }
    let count = u32_at(take(&mut off, 4)?) as usize;
    let mut tensors = Vec::with_capacity(count);
    for _ in 0..count {
        let nlen = u32_at(take(&mut off, 4)?) as usize;
        let name = String::from_utf8(take(&mut off, nlen)?.to_vec())?;
        let ndim = u32_at(take(&mut off, 4)?) as usize;
        let mut dims = Vec::with_capacity(ndim);
        for _ in 0..ndim {
            let b = take(&mut off, 8)?;
            dims.push(u64::from_le_bytes(b.try_into().unwrap()) as usize);
        }
        let dtype = u32_at(take(&mut off, 4)?);
        if dtype != 0 {
            bail!("tensor {name}: unsupported dtype {dtype}");
        }
        let elems: usize = dims.iter().product::<usize>().max(1);
        let bytes = take(&mut off, 4 * elems)?;
        let data: Vec<f32> = bytes
            .chunks_exact(4)
            .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
            .collect();
        tensors.push(WeightTensor { name, dims, data });
    }
    if off != raw.len() {
        bail!("{} trailing bytes in {}", raw.len() - off, path.display());
    }
    Ok(tensors)
}

/// One compiled partition side with its weights resident on device.
pub struct LoadedPart {
    exe: xla::PjRtLoadedExecutable,
    weights: Vec<xla::PjRtBuffer>,
    pub input_shape: Vec<usize>,
    pub output_shape: Vec<usize>,
    pub role: Role,
    pub m: usize,
    pub batch: usize,
}

impl LoadedPart {
    /// Execute on a flat activation (row-major, must match input_shape).
    pub fn run(&self, activation: &[f32]) -> Result<Vec<f32>> {
        let want: usize = self.input_shape.iter().product();
        if activation.len() != want {
            bail!(
                "activation has {} elements, artifact expects {:?} = {want}",
                activation.len(),
                self.input_shape
            );
        }
        let client = self.exe.client();
        let input = client.buffer_from_host_buffer::<f32>(activation, &self.input_shape, None)?;
        let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(1 + self.weights.len());
        args.push(&input);
        args.extend(self.weights.iter());
        let result = self.exe.execute_b(&args)?[0][0].to_literal_sync()?;
        // aot.py lowers with return_tuple=True: unwrap the 1-tuple.
        let out = result.to_tuple1()?;
        Ok(out.to_vec::<f32>()?)
    }
}

/// All loaded parts of one model + the host-side weight store.
pub struct ModelRuntime {
    client: xla::PjRtClient,
    manifest_model: ManifestModel,
    artifacts_dir: std::path::PathBuf,
    weights: HashMap<String, WeightTensor>,
    parts: HashMap<(Role, usize, usize), LoadedPart>,
}

impl ModelRuntime {
    /// Number of classes (= last dim of any edge output).
    pub fn num_classes(&self) -> usize {
        self.manifest_model
            .points
            .last()
            .map(|p| p.feat_shape.last().copied().unwrap_or(0))
            .unwrap_or(0)
    }

    pub fn model(&self) -> &ManifestModel {
        &self.manifest_model
    }

    /// Compile-and-cache the given partition side.
    pub fn load_part(&mut self, role: Role, m: usize, batch: usize) -> Result<&LoadedPart> {
        if !self.parts.contains_key(&(role, m, batch)) {
            let entry = self
                .manifest_model
                .artifact(role, m, batch)
                .ok_or_else(|| {
                    anyhow!(
                        "no artifact for {role:?} m={m} batch={batch} in model {}",
                        self.manifest_model.name
                    )
                })?
                .clone();
            let part = self.compile_part(&entry)?;
            self.parts.insert((role, m, batch), part);
        }
        Ok(&self.parts[&(role, m, batch)])
    }

    fn compile_part(&self, entry: &ArtifactEntry) -> Result<LoadedPart> {
        let path = self.artifacts_dir.join(&entry.hlo);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("non-utf8 path"))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let mut weights = Vec::with_capacity(entry.weight_names.len());
        for name in &entry.weight_names {
            let t = self
                .weights
                .get(name)
                .ok_or_else(|| anyhow!("weight {name} missing from sidecar"))?;
            let dims = if t.dims.is_empty() { vec![1] } else { t.dims.clone() };
            weights.push(self.client.buffer_from_host_buffer::<f32>(&t.data, &dims, None)?);
        }
        Ok(LoadedPart {
            exe,
            weights,
            input_shape: entry.input_shape.clone(),
            output_shape: entry.output_shape.clone(),
            role: entry.role,
            m: entry.m,
            batch: entry.batch,
        })
    }

    /// Run the device side (blocks [0, m)) for one request.
    pub fn run_device(&mut self, m: usize, input: &[f32]) -> Result<Vec<f32>> {
        self.load_part(Role::Device, m, 1)?.run(input)
    }

    /// Run the edge side (blocks [m, M)) on a batch of features.
    pub fn run_edge(&mut self, m: usize, batch: usize, features: &[f32]) -> Result<Vec<f32>> {
        self.load_part(Role::Edge, m, batch)?.run(features)
    }

    /// Wall-clock probe: median latency of a part over `iters` runs
    /// (feeds the Fig. 1/5 characterization on *real* PJRT jitter).
    pub fn probe_latency(
        &mut self,
        role: Role,
        m: usize,
        batch: usize,
        iters: usize,
    ) -> Result<Vec<f64>> {
        let part = self.load_part(role, m, batch)?;
        let n_in: usize = part.input_shape.iter().product();
        let input = vec![0.5f32; n_in];
        let mut samples = Vec::with_capacity(iters);
        // warm-up
        part.run(&input)?;
        for _ in 0..iters {
            let t0 = std::time::Instant::now();
            part.run(&input)?;
            samples.push(t0.elapsed().as_secs_f64());
        }
        Ok(samples)
    }
}

/// PJRT engine: one CPU client + per-model runtimes.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
}

impl Engine {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn cpu(artifacts_dir: &Path) -> Result<Engine> {
        let manifest = Manifest::load(artifacts_dir).map_err(|e| anyhow!(e))?;
        let client = xla::PjRtClient::cpu()?;
        Ok(Engine { client, manifest })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Build a runtime for one model (weights parsed host-side once).
    pub fn model_runtime(&self, name: &str) -> Result<ModelRuntime> {
        let mm = self.manifest.model(name).map_err(|e| anyhow!(e))?.clone();
        let weights_path = self.manifest.dir.join(&mm.weights_path);
        let weights = load_weights(&weights_path)?
            .into_iter()
            .map(|t| (t.name.clone(), t))
            .collect();
        Ok(ModelRuntime {
            // PjRtClient is internally reference-counted in the C layer;
            // cloning shares the same client.
            client: self.client.clone(),
            manifest_model: mm,
            artifacts_dir: self.manifest.dir.clone(),
            weights,
            parts: HashMap::new(),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn engine() -> Option<Engine> {
        let dir = Manifest::default_dir();
        dir.join("manifest.json").exists().then(|| Engine::cpu(&dir).unwrap())
    }

    #[test]
    fn weights_sidecar_parses() {
        let Some(e) = engine() else { return };
        for name in ["alexnet", "resnet152"] {
            let mm = e.manifest().model(name).unwrap();
            let w = load_weights(&e.manifest().dir.join(&mm.weights_path)).unwrap();
            assert!(!w.is_empty());
            // every artifact's weight names resolve
            let have: std::collections::HashSet<_> =
                w.iter().map(|t| t.name.clone()).collect();
            for a in &mm.artifacts {
                for n in &a.weight_names {
                    assert!(have.contains(n), "{name}: missing {n}");
                }
            }
        }
    }

    #[test]
    fn device_part_runs_and_produces_finite_features() {
        let Some(e) = engine() else { return };
        let mut rt = e.model_runtime("alexnet").unwrap();
        let input = vec![0.25f32; 32 * 32 * 3];
        let feat = rt.run_device(2, &input).unwrap();
        let expect: usize =
            rt.model().points[2].feat_shape.iter().product();
        assert_eq!(feat.len(), expect);
        assert!(feat.iter().all(|x| x.is_finite()));
    }

    #[test]
    fn split_equals_full_chain() {
        // device(m) ∘ edge(m) must equal edge(0)'s full chain on the same
        // input — the PJRT-level partition-consistency check.
        let Some(e) = engine() else { return };
        let mut rt = e.model_runtime("alexnet").unwrap();
        let input: Vec<f32> =
            (0..32 * 32 * 3).map(|i| ((i % 17) as f32) / 17.0 - 0.5).collect();
        let full = rt.run_edge(0, 1, &input).unwrap();
        for m in [2, 5] {
            let feat = rt.run_device(m, &input).unwrap();
            let split = rt.run_edge(m, 1, &feat).unwrap();
            assert_eq!(split.len(), full.len());
            for (a, b) in split.iter().zip(&full) {
                assert!((a - b).abs() < 1e-3, "m={m}: {a} vs {b}");
            }
        }
    }

    #[test]
    fn batched_edge_matches_singles() {
        let Some(e) = engine() else { return };
        let mut rt = e.model_runtime("resnet152").unwrap();
        let m = 4;
        let feat_len: usize = rt.model().points[m].feat_shape.iter().product();
        let batch = 8usize;
        let feats: Vec<f32> =
            (0..feat_len * batch).map(|i| ((i % 23) as f32) / 23.0).collect();
        let batched = rt.run_edge(m, batch, &feats).unwrap();
        let classes = rt.num_classes();
        assert_eq!(batched.len(), batch * classes);
        for b in 0..3 {
            let single =
                rt.run_edge(m, 1, &feats[b * feat_len..(b + 1) * feat_len]).unwrap();
            for (a, bb) in single.iter().zip(&batched[b * classes..(b + 1) * classes]) {
                assert!((a - bb).abs() < 1e-3);
            }
        }
    }

    #[test]
    fn missing_artifact_is_an_error() {
        let Some(e) = engine() else { return };
        let mut rt = e.model_runtime("alexnet").unwrap();
        assert!(rt.load_part(Role::Edge, 3, 999).is_err());
    }

    #[test]
    fn wrong_activation_size_is_an_error() {
        let Some(e) = engine() else { return };
        let mut rt = e.model_runtime("alexnet").unwrap();
        assert!(rt.run_device(2, &[0.0; 7]).is_err());
    }

    #[test]
    fn latency_probe_returns_samples() {
        let Some(e) = engine() else { return };
        let mut rt = e.model_runtime("alexnet").unwrap();
        let s = rt.probe_latency(Role::Device, 1, 1, 5).unwrap();
        assert_eq!(s.len(), 5);
        assert!(s.iter().all(|&x| x > 0.0));
    }
}
