//! Monte-Carlo uncertainty simulator.
//!
//! Executes a plan against the synthetic hardware's *actual* random
//! inference times (which the planner never saw — it only got means and
//! variances) and measures the empirical deadline-violation probability
//! and energy.  This regenerates Fig. 13(c)/14(c): the violation
//! probability of the robust plan must stay below the risk level ε for
//! every distribution family with the declared moments.

use crate::optim::types::{Plan, Scenario};
use crate::profile::{Dist, SyntheticHardware};
use crate::util::rng::Rng;
use crate::util::stats::Moments;

/// Simulation options.
#[derive(Clone, Debug)]
pub struct SimOptions {
    pub trials: usize,
    pub dist: Dist,
    pub seed: u64,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { trials: 10_000, dist: Dist::Lognormal, seed: 0x5eed }
    }
}

/// Per-device and aggregate outcome of a Monte-Carlo run.
#[derive(Clone, Debug)]
pub struct SimReport {
    /// Empirical P{T_n > D_n} per device.
    pub violation_prob: Vec<f64>,
    /// Max over devices (the number compared against ε).
    pub worst_violation: f64,
    /// Mean over devices.
    pub mean_violation: f64,
    /// Mean measured total energy per trial (J) — includes the *actual*
    /// local time draw, so it can differ slightly from the planner's
    /// expectation.
    pub mean_energy: f64,
    /// Per-device mean end-to-end latency (s).
    pub mean_latency: Vec<f64>,
    /// Per-device 99th-percentile latency (s).
    pub p99_latency: Vec<f64>,
}

/// Run the plan `opts.trials` times against sampled inference times.
pub fn evaluate(sc: &Scenario, plan: &Plan, opts: &SimOptions) -> SimReport {
    assert_eq!(plan.partition.len(), sc.n());
    let mut rng = Rng::new(opts.seed);
    let hardware: Vec<SyntheticHardware> = sc
        .devices
        .iter()
        .map(|d| SyntheticHardware::new(d.model.clone(), opts.dist))
        .collect();

    let mut violations = vec![0usize; sc.n()];
    let mut lat_acc: Vec<Moments> = (0..sc.n()).map(|_| Moments::new()).collect();
    let mut lat_samples: Vec<Vec<f64>> = vec![Vec::with_capacity(opts.trials); sc.n()];
    let mut energy_acc = Moments::new();

    for _ in 0..opts.trials {
        let mut total_energy = 0.0;
        for (i, dev) in sc.devices.iter().enumerate() {
            let m = plan.partition[i];
            let f = plan.freq_ghz[i];
            let b = plan.bandwidth_hz[i];
            let t_loc = hardware[i].sample_t_loc(m, f, &mut rng);
            let t_off = dev.uplink.t_off(dev.model.d_bits(m), b);
            let t_vm = hardware[i].sample_t_vm(m, &mut rng);
            let latency = t_loc + t_off + t_vm;
            if latency > dev.deadline_s {
                violations[i] += 1;
            }
            lat_acc[i].push(latency);
            lat_samples[i].push(latency);
            total_energy += crate::energy::e_loc(dev.model.device.kappa, f, t_loc)
                + dev.uplink.e_off(dev.model.d_bits(m), b);
        }
        energy_acc.push(total_energy);
    }

    let violation_prob: Vec<f64> =
        violations.iter().map(|&v| v as f64 / opts.trials as f64).collect();
    let p99_latency = lat_samples
        .iter()
        .map(|s| crate::util::stats::percentile_of(s, 99.0))
        .collect();
    SimReport {
        worst_violation: violation_prob.iter().cloned().fold(0.0, f64::max),
        mean_violation: violation_prob.iter().sum::<f64>() / sc.n() as f64,
        violation_prob,
        mean_energy: energy_acc.mean(),
        mean_latency: lat_acc.iter().map(Moments::mean).collect(),
        p99_latency,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::engine::{PlanOutcome, PlanRequest, Planner, Policy};
    use crate::models::ModelProfile;

    fn scenario(seed: u64) -> Scenario {
        let mut rng = Rng::new(seed);
        Scenario::uniform(&ModelProfile::alexnet_paper(), 6, 10e6, 0.20, 0.05, &mut rng)
    }

    fn plan_with(sc: &Scenario, policy: Policy) -> PlanOutcome {
        Planner::default().plan(&PlanRequest::new(sc.clone(), policy)).unwrap()
    }

    #[test]
    fn robust_plan_respects_risk_level_all_distributions() {
        // The core soundness claim (Fig. 13c): empirical violation ≤ ε.
        let sc = scenario(21);
        let plan = plan_with(&sc, Policy::Robust).plan;
        for dist in [Dist::Lognormal, Dist::Gamma, Dist::ShiftedExp] {
            let r = evaluate(&sc, &plan, &SimOptions { trials: 8000, dist, seed: 7 });
            assert!(
                r.worst_violation <= sc.devices[0].risk + 0.01,
                "{dist:?}: violation {} > eps {}",
                r.worst_violation,
                sc.devices[0].risk
            );
        }
    }

    #[test]
    fn mean_only_plan_violates_more_than_robust() {
        let sc = scenario(22);
        let robust = plan_with(&sc, Policy::Robust).plan;
        let mean = plan_with(&sc, Policy::MeanOnly).plan;
        let opts = SimOptions { trials: 8000, ..Default::default() };
        let r_rob = evaluate(&sc, &robust, &opts);
        let r_mean = evaluate(&sc, &mean, &opts);
        assert!(
            r_mean.worst_violation > r_rob.worst_violation,
            "mean-only {} vs robust {}",
            r_mean.worst_violation,
            r_rob.worst_violation
        );
    }

    #[test]
    fn worst_case_plan_nearly_never_violates() {
        let sc = scenario(23);
        let worst = plan_with(&sc, Policy::WorstCase).plan;
        let r = evaluate(&sc, &worst, &SimOptions { trials: 8000, ..Default::default() });
        assert!(r.worst_violation < 0.01, "violation {}", r.worst_violation);
    }

    #[test]
    fn energy_estimate_matches_planner_expectation() {
        let sc = scenario(24);
        let rp = plan_with(&sc, Policy::Robust);
        let r = evaluate(&sc, &rp.plan, &SimOptions { trials: 20_000, ..Default::default() });
        // sampled energy uses actual t_loc draws; means should agree ~5%
        assert!(
            (r.mean_energy - rp.energy).abs() / rp.energy < 0.05,
            "sim {} vs plan {}",
            r.mean_energy,
            rp.energy
        );
    }

    #[test]
    fn latencies_below_deadline_on_average() {
        let sc = scenario(25);
        let plan = plan_with(&sc, Policy::Robust).plan;
        let r = evaluate(&sc, &plan, &SimOptions::default());
        for (i, dev) in sc.devices.iter().enumerate() {
            assert!(r.mean_latency[i] < dev.deadline_s);
            assert!(r.p99_latency[i] >= r.mean_latency[i]);
        }
    }
}
