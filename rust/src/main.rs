//! `ripra` — CLI for the robust DNN-partitioning system.
//!
//! Subcommands (hand-rolled parsing; no clap offline):
//!
//! * `ripra plan     ...` — flags derived from [`PlanRequest::CLI_FLAGS`]
//! * `ripra simulate ...` — flags derived from [`FleetOptions::CLI_FLAGS`]
//! * `ripra figure   <fig13a|...|all> [--out DIR] [--quick]`
//! * `ripra serve    --model M --n N [--requests K] [--time-scale X]`,
//!   or `ripra serve --listen ADDR` for the TCP planner frontend
//! * `ripra loadgen  --addr ADDR [--seed S] ...` — replayable wire client
//! * `ripra profile  --model M [--trials T]`
//! * `ripra selftest`
//!
//! All planning routes through the [`ripra::engine`] facade.

// lint:allow-file(wall-clock): the CLI's human summary line prints wall
// seconds; nothing serialized (--json output excludes it).
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::time::Duration;

use anyhow::{anyhow, bail, Result};

use ripra::coordinator::{self, ServeOptions};
use ripra::engine::{CliFlag, PlanRequest, Planner, PlannerBuilder, Policy, RiskBound};
use ripra::fault::FaultOptions;
use ripra::figures::{self, Effort};
use ripra::fleet::loadgen::{self, LoadGenOptions};
use ripra::fleet::{self, FleetOptions};
use ripra::models::manifest::Manifest;
use ripra::models::ModelProfile;
use ripra::optim::Scenario;
use ripra::service::{PlannerService, ServerOptions, ServiceOptions};
use ripra::sim::{self, SimOptions};
use ripra::util::json::Json;
use ripra::util::rng::Rng;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let code = match dispatch(&args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e}");
            1
        }
    };
    std::process::exit(code);
}

/// Build one subcommand's usage section (wrapped flag list + per-flag
/// help) from its [`CliFlag`] table.
fn derived_usage(head: &str, flags: &[CliFlag]) -> (String, String) {
    let mut line = String::from(head);
    let mut width = line.len();
    for f in flags {
        let piece = match f.value {
            Some(v) => format!(" [--{} {}]", f.name, v),
            None => format!(" [--{}]", f.name),
        };
        if width + piece.len() > 76 {
            line.push_str("\n\x20       ");
            width = 8;
        }
        width += piece.len();
        line.push_str(&piece);
    }
    let mut help = String::new();
    for f in flags {
        let left = match f.value {
            Some(v) => format!("--{} {}", f.name, v),
            None => format!("--{}", f.name),
        };
        help.push_str(&format!("\x20          {:<42} {}\n", left, f.help));
    }
    (line, help)
}

/// The `plan` and `simulate` usage sections are generated from
/// [`PlanRequest::CLI_FLAGS`] / [`FleetOptions::CLI_FLAGS`] so the CLI
/// surface cannot drift from the engine and fleet APIs.
fn usage() -> String {
    let (plan_line, plan_help) = derived_usage("plan    ", PlanRequest::CLI_FLAGS);
    let (sim_line, sim_help) = derived_usage("simulate", FleetOptions::CLI_FLAGS);
    format!(
        "usage: ripra <plan|simulate|figure|serve|loadgen|profile|selftest> [options]\n\
         \n\
         {plan_line}\n\
         {plan_help}\
         {sim_line}\n\
         {sim_help}\
         figure   <name|all> [--out DIR] [--quick]\n\
         serve    --model alexnet|resnet152 [--n N] [--requests K] [--time-scale X]\n\
         \x20        [--deadline S] [--risk E] [--bandwidth HZ] [--seed S]\n\
         \x20        [--shards K]   (K >= 1 plans through the sharded service)\n\
         serve    --listen ADDR [--shards K] [--queue N] [--submit-shards K]\n\
         \x20        [--seed S] [--backoff S]\n\
         \x20        (TCP planner frontend; wire protocol in EXPERIMENTS.md)\n\
         loadgen  --addr ADDR [--model M] [--tenants T] [--n N] [--events E]\n\
         \x20        [--rate HZ] [--probe-every K] [--bandwidth HZ] [--deadline S]\n\
         \x20        [--risk E] [--bound B] [--seed S] [--connections C] [--batch K]\n\
         \x20        [--first-tenant T] [--bench FILE] [--json]\n\
         \x20        (C > 1 adds a two-phase throughput comparison)\n\
         profile  [--model M] [--trials T]\n\
         selftest"
    )
}

/// Boolean flags (no value) in a subcommand's flag table.
fn bool_flags_of(flags: &[CliFlag]) -> Vec<&'static str> {
    flags.iter().filter(|f| f.value.is_none()).map(|f| f.name).collect()
}

/// Boolean flags accepted by the `plan` subcommand.
fn plan_bool_flags() -> Vec<&'static str> {
    bool_flags_of(PlanRequest::CLI_FLAGS)
}

/// `--key value` / `--key=value` flags into a map; flags listed in
/// `bool_flags` take no value and parse to `"true"`.  Returns
/// (positional, flags).
fn parse_flags(
    args: &[String],
    bool_flags: &[&str],
) -> Result<(Vec<String>, BTreeMap<String, String>)> {
    let mut pos = Vec::new();
    let mut flags = BTreeMap::new();
    let mut it = args.iter().peekable();
    while let Some(a) = it.next() {
        if let Some(key) = a.strip_prefix("--") {
            if let Some((k, v)) = key.split_once('=') {
                flags.insert(k.to_string(), v.to_string());
                continue;
            }
            if bool_flags.contains(&key) {
                flags.insert(key.to_string(), "true".into());
                continue;
            }
            let v = it.next().ok_or_else(|| anyhow!("flag --{key} needs a value"))?;
            flags.insert(key.to_string(), v.clone());
        } else {
            pos.push(a.clone());
        }
    }
    Ok((pos, flags))
}

fn flag_f64(flags: &BTreeMap<String, String>, key: &str, default: f64) -> Result<f64> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad number {v:?}")),
    }
}

fn flag_usize(flags: &BTreeMap<String, String>, key: &str, default: usize) -> Result<usize> {
    match flags.get(key) {
        None => Ok(default),
        Some(v) => v.parse().map_err(|_| anyhow!("--{key}: bad integer {v:?}")),
    }
}

fn model_of(flags: &BTreeMap<String, String>) -> Result<ModelProfile> {
    let name = flags.get("model").map(String::as_str).unwrap_or("alexnet");
    ModelProfile::by_name(name)
        .ok_or_else(|| anyhow!("unknown model {name:?} (alexnet | resnet152)"))
}

fn scenario_of(flags: &BTreeMap<String, String>) -> Result<Scenario> {
    let model = model_of(flags)?;
    let (b_def, d_def, e_def) = figures::default_setting(&model.name);
    let n = flag_usize(flags, "n", 12)?;
    let b = flag_f64(flags, "bandwidth", b_def)?;
    let d = flag_f64(flags, "deadline", d_def)?;
    let eps = flag_f64(flags, "risk", e_def)?;
    let seed = flag_usize(flags, "seed", 42)? as u64;
    let mut rng = Rng::new(seed);
    Ok(Scenario::uniform(&model, n, b, d, eps, &mut rng))
}

/// Parse the shared `--bound` flag (default: the paper's ECR bound).
fn bound_of(flags: &BTreeMap<String, String>) -> Result<RiskBound> {
    let spelling = flags.get("bound").map(String::as_str).unwrap_or("ecr");
    RiskBound::parse(spelling).ok_or_else(|| {
        anyhow!("unknown bound {spelling:?} (ecr | gauss | bernstein | calibrated[:SCALE])")
    })
}

/// Assemble a [`PlanRequest`] from parsed `plan` flags.
fn plan_request_of(flags: &BTreeMap<String, String>) -> Result<PlanRequest> {
    let scenario = scenario_of(flags)?;
    let spelling = flags.get("policy").map(String::as_str).unwrap_or("robust");
    let policy = Policy::parse(spelling).ok_or_else(|| {
        anyhow!("unknown policy {spelling:?} (robust | worst | mean | exhaustive | multistart)")
    })?;
    let mut req = PlanRequest::new(scenario, policy).with_bound(bound_of(flags)?);
    if flags.contains_key("no-cache") {
        req = req.without_cache();
    }
    Ok(req)
}

fn dispatch(args: &[String]) -> Result<()> {
    let Some(cmd) = args.first() else { bail!("{}", usage()) };
    let rest = &args[1..];
    match cmd.as_str() {
        "plan" => cmd_plan(rest),
        "simulate" => cmd_simulate(rest),
        "figure" => cmd_figure(rest),
        "serve" => cmd_serve(rest),
        "loadgen" => cmd_loadgen(rest),
        "profile" => cmd_profile(rest),
        "selftest" => cmd_selftest(),
        "help" | "--help" | "-h" => {
            println!("{}", usage());
            Ok(())
        }
        other => bail!("unknown command {other:?}\n{}", usage()),
    }
}

fn cmd_plan(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args, &plan_bool_flags())?;
    let req = plan_request_of(&flags)?;
    let trials = flag_usize(&flags, "trials", 10_000)?;
    let as_json = flags.contains_key("json");
    let sc = req.scenario.clone();

    let mut planner: Planner = PlannerBuilder::new().build();
    let out = planner.plan(&req).map_err(|e| anyhow!(e.to_string()))?;

    let rep = (trials > 0)
        .then(|| sim::evaluate(&sc, &out.plan, &SimOptions { trials, ..Default::default() }));

    if as_json {
        let mut j = out.to_json();
        if let (Json::Obj(pairs), Some(rep)) = (&mut j, &rep) {
            pairs.push((
                "monte_carlo".into(),
                Json::Obj(vec![
                    ("trials".into(), Json::Num(trials as f64)),
                    ("worst_violation".into(), Json::Num(rep.worst_violation)),
                    ("mean_violation".into(), Json::Num(rep.mean_violation)),
                    ("mean_energy_j".into(), Json::Num(rep.mean_energy)),
                ]),
            ));
        }
        println!("{}", j.to_string_pretty());
        return Ok(());
    }

    println!(
        "scenario: {} devices, model={}, B={:.1} MHz, D={} ms, eps={}",
        sc.n(),
        sc.devices[0].model.name,
        sc.total_bandwidth_hz / 1e6,
        sc.devices[0].deadline_s * 1e3,
        sc.devices[0].risk
    );
    let d = &out.diagnostics;
    println!(
        "{} [{}]: {} outer iters, {:.2} avg PCCP iters, {} Newton steps, {:.1} ms{}",
        out.policy.name(),
        out.bound,
        d.outer_iters,
        d.avg_pccp_iters,
        d.newton_iters,
        d.wall_time.as_secs_f64() * 1e3,
        if d.cache_hit { " (cache hit)" } else { "" }
    );

    println!("expected total energy: {:.4} J", out.energy);
    println!("  dev  m   b_MHz   f_GHz   slack_ms  margin_ms");
    let mpol = out.policy.margin_policy(out.bound);
    for i in 0..sc.n() {
        let dev = &sc.devices[i];
        println!(
            "  {:>3} {:>2}  {:>6.3}  {:>6.3}  {:>8.2}  {:>9.2}",
            i,
            out.plan.partition[i],
            out.plan.bandwidth_hz[i] / 1e6,
            out.plan.freq_ghz[i],
            dev.deadline_margin(
                out.plan.partition[i],
                out.plan.freq_ghz[i],
                out.plan.bandwidth_hz[i],
                mpol
            ) * 1e3,
            out.diagnostics.margins_s.get(i).copied().unwrap_or(f64::NAN) * 1e3
        );
    }

    if let Some(rep) = rep {
        println!(
            "Monte-Carlo ({} trials): worst violation {:.4} (risk {}), mean energy {:.4} J",
            trials, rep.worst_violation, sc.devices[0].risk, rep.mean_energy
        );
    }
    Ok(())
}

/// Assemble [`FleetOptions`] from parsed `simulate` flags.  Defaults add
/// headroom (bandwidth ×1.25, deadline +20 ms) over the static per-model
/// setting so device joins stay admissible under churn.
fn fleet_options_of(flags: &BTreeMap<String, String>) -> Result<FleetOptions> {
    let model = model_of(flags)?;
    let (b_def, d_def, e_def) = figures::default_setting(&model.name);
    let fd = FaultOptions::default();
    Ok(FleetOptions {
        // --devices is an alias for --n (million-device cohort runs read
        // more naturally as `--devices 1000000 --cohorts`).
        n0: flag_usize(flags, "devices", flag_usize(flags, "n", 6)?)?,
        duration_s: flag_f64(flags, "duration", 30.0)?,
        arrival_rate_hz: flag_f64(flags, "arrival-rate", 0.2)?,
        churn: flag_f64(flags, "churn", 1.0)?,
        total_bandwidth_hz: flag_f64(flags, "bandwidth", b_def * 1.25)?,
        deadline_s: flag_f64(flags, "deadline", d_def + 0.02)?,
        risk: flag_f64(flags, "risk", e_def)?,
        trials: flag_usize(flags, "trials", 1000)?,
        seed: flag_usize(flags, "seed", 7)? as u64,
        threads: 0,
        shards: flag_usize(flags, "shards", 0)?,
        bound: bound_of(flags)?,
        cohorts: flags.contains_key("cohorts"),
        faults: FaultOptions {
            enabled: flags.contains_key("faults"),
            outage_rate_hz: flag_f64(flags, "outage-rate", fd.outage_rate_hz)?,
            outage_mean_s: flag_f64(flags, "outage-mean", fd.outage_mean_s)?,
            blackout_rate_hz: flag_f64(flags, "blackout-rate", fd.blackout_rate_hz)?,
            blackout_mean_s: flag_f64(flags, "blackout-mean", fd.blackout_mean_s)?,
            blackout_depth_db: flag_f64(flags, "blackout-depth", fd.blackout_depth_db)?,
            drop_prob: flag_f64(flags, "drop-prob", fd.drop_prob)?,
            delay_prob: flag_f64(flags, "delay-prob", fd.delay_prob)?,
            delay_mean_s: flag_f64(flags, "delay-mean", fd.delay_mean_s)?,
            backoff_base_s: flag_f64(flags, "backoff", fd.backoff_base_s)?,
        },
        model,
    })
}

fn cmd_simulate(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args, &bool_flags_of(FleetOptions::CLI_FLAGS))?;
    let opts = fleet_options_of(&flags)?;
    let t0 = std::time::Instant::now();
    let rep = fleet::run(&opts).map_err(|e| anyhow!(e.to_string()))?;
    if flags.contains_key("json") {
        // The JSON payload is a deterministic function of the seed (no
        // wall-clock fields), so repeat runs are byte-identical.
        println!("{}", rep.to_json().to_string_pretty());
        return Ok(());
    }
    let s = rep.metrics.summary();
    println!(
        "fleet: model={}, n0={}, {:.0}s simulated, arrivals {:.2}/s, churn x{:.2}, seed {}",
        opts.model.name, opts.n0, opts.duration_s, opts.arrival_rate_hz, opts.churn, opts.seed
    );
    println!(
        "events: {} total, {} accepted, {} rejected, {} absorbed ({:.2}s wall)",
        s.events,
        s.accepted,
        s.rejected,
        s.absorbed,
        t0.elapsed().as_secs_f64()
    );
    let counts = fleet::DELTA_KINDS
        .iter()
        .map(|&k| format!("{k}:{}", rep.metrics.count_of(k)))
        .collect::<Vec<_>>()
        .join("  ");
    println!("deltas: {counts}");
    println!(
        "served: {} cache hits, {} warm replans, {} cold solves (cache hit rate {:.1}%)",
        s.cache_hits,
        s.warm_replans,
        s.cold_solves,
        100.0 * s.cache_hit_rate
    );
    println!(
        "solver: {} Newton iterations total; mean planned energy {:.4} J",
        s.newton_total, s.mean_energy_j
    );
    match s.worst_violation_excess {
        Some(w) => println!(
            "Monte-Carlo ({} trials/step): worst violation excess over eps {w:+.4}",
            opts.trials
        ),
        None => println!("Monte-Carlo check disabled (--trials 0)"),
    }
    if opts.faults.enabled {
        println!(
            "faults: {} degraded steps (peak {} devices), {} deadline violations while degraded",
            s.degraded_steps, s.max_degraded_devices, s.violations_while_degraded
        );
        match (s.mean_time_to_recovery_s, s.max_time_to_recovery_s) {
            (Some(mean), Some(max)) => println!(
                "recovery: {} re-offloads, time-to-recovery mean {:.2}s / max {:.2}s, \
                 local-fallback energy premium {:.4} J",
                s.recoveries, mean, max, s.fallback_energy_premium_j
            ),
            _ => println!(
                "recovery: no completed recoveries in window (energy premium {:.4} J)",
                s.fallback_energy_premium_j
            ),
        }
    }
    println!(
        "final fleet: {} devices, B={:.2} MHz, planned energy {:.4} J, bound {}",
        rep.final_scenario.n(),
        rep.final_scenario.total_bandwidth_hz / 1e6,
        rep.final_outcome.energy,
        rep.final_bound
    );
    Ok(())
}

fn cmd_figure(args: &[String]) -> Result<()> {
    let (pos, flags) = parse_flags(args, &["quick"])?;
    let name = pos.first().map(String::as_str).unwrap_or("all");
    let out = flags.get("out").map(PathBuf::from);
    let effort = if flags.contains_key("quick") { Effort::Quick } else { Effort::Full };
    figures::run(name, out.as_deref(), effort).map_err(|e| anyhow!(e))?;
    Ok(())
}

fn cmd_serve(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args, &[])?;
    // --listen ADDR switches to the TCP planner frontend (wire protocol
    // in EXPERIMENTS.md §Serving); without it the in-process
    // coordinator demo below runs as before.
    if let Some(listen) = flags.get("listen") {
        let opts = ServerOptions {
            listen: listen.clone(),
            shards: flag_usize(&flags, "shards", 2)?.max(1),
            queue_capacity: flag_usize(&flags, "queue", 64)?,
            submit_shards: flag_usize(&flags, "submit-shards", 16)?.max(1),
            seed: flag_usize(&flags, "seed", 7)? as u64,
            backoff_base_s: flag_f64(&flags, "backoff", 0.05)?,
        };
        return ripra::service::server::serve(&opts).map_err(|e| anyhow!(e));
    }
    let mut f2 = flags.clone();
    f2.entry("n".into()).or_insert_with(|| "6".into());
    let sc = scenario_of(&f2)?;
    let model = sc.devices[0].model.name.clone();

    let opts = ServeOptions {
        model,
        requests_per_device: flag_usize(&flags, "requests", 20)?,
        arrival_rate_hz: flag_f64(&flags, "rate", 8.0)?,
        time_scale: flag_f64(&flags, "time-scale", 0.5)?,
        batch_window: Duration::from_millis(flag_usize(&flags, "window-ms", 4)? as u64),
        max_batch: 8,
        seed: flag_usize(&flags, "seed", 7)? as u64,
    };
    let shards = flag_usize(&flags, "shards", 0)?;
    let (out, rep) = if shards == 0 {
        let mut planner = PlannerBuilder::new().build();
        coordinator::plan_and_serve(Manifest::default_dir(), &sc, &mut planner, &opts)?
    } else {
        let mut svc = PlannerService::new(ServiceOptions { shards, ..ServiceOptions::default() })
            .map_err(|e| anyhow!(e.to_string()))?;
        coordinator::plan_and_serve_sharded(Manifest::default_dir(), &sc, &mut svc, 0, &opts)?
    };
    println!("plan: partition={:?}, energy {:.4} J", out.plan.partition, out.energy);
    println!(
        "served {} requests in {:.2}s  ({:.1} req/s)",
        rep.completed,
        rep.wall_time.as_secs_f64(),
        rep.throughput_rps
    );
    println!(
        "latency (model-time): mean {:.1} ms  p50 {:.1} ms  p99 {:.1} ms; violations {}",
        rep.mean_latency_s * 1e3,
        rep.p50_latency_s * 1e3,
        rep.p99_latency_s * 1e3,
        rep.violations
    );
    println!(
        "edge batching: mean batch {:.2}; PJRT exec: device {:.2} ms, edge {:.2} ms; energy {:.3} J",
        rep.mean_batch,
        rep.mean_device_exec_s * 1e3,
        rep.mean_edge_exec_s * 1e3,
        rep.total_energy_j
    );
    Ok(())
}

fn cmd_loadgen(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args, &["json"])?;
    let addr = flags
        .get("addr")
        .ok_or_else(|| anyhow!("loadgen needs --addr HOST:PORT (a running `ripra serve --listen`)"))?
        .clone();
    let defaults = LoadGenOptions::default();
    let opts = LoadGenOptions {
        model: model_of(&flags)?,
        tenants: flag_usize(&flags, "tenants", defaults.tenants)?.max(1),
        devices: flag_usize(&flags, "n", defaults.devices)?.max(1),
        events: flag_usize(&flags, "events", defaults.events)?,
        rate_hz: flag_f64(&flags, "rate", defaults.rate_hz)?,
        probe_every: flag_usize(&flags, "probe-every", defaults.probe_every)?.max(1),
        total_bandwidth_hz: flag_f64(&flags, "bandwidth", defaults.total_bandwidth_hz)?,
        deadline_s: flag_f64(&flags, "deadline", defaults.deadline_s)?,
        risk: flag_f64(&flags, "risk", defaults.risk)?,
        bound: bound_of(&flags)?,
        seed: flag_usize(&flags, "seed", defaults.seed as usize)? as u64,
        connections: flag_usize(&flags, "connections", defaults.connections)?.max(1),
        batch: flag_usize(&flags, "batch", defaults.batch)?,
        first_tenant: flag_usize(&flags, "first-tenant", defaults.first_tenant as usize)? as u64,
    };
    let report = loadgen::run(&addr, &opts).map_err(|e| anyhow!(e))?;
    if flags.contains_key("json") {
        println!("{}", report.to_json().to_string_pretty());
    } else {
        println!("{}", report.summary());
    }
    if let Some(bench) = flags.get("bench") {
        let path = PathBuf::from(bench);
        report.write_bench_rows(&path).map_err(|e| anyhow!(e))?;
        println!("loadgen: serve rows merged into {}", path.display());
    }
    Ok(())
}

fn cmd_profile(args: &[String]) -> Result<()> {
    let (_, flags) = parse_flags(args, &[])?;
    let model = model_of(&flags)?;
    let trials = flag_usize(&flags, "trials", 500)?;
    let hw =
        ripra::profile::SyntheticHardware::new(model.clone(), ripra::profile::Dist::Lognormal);
    let freqs = ripra::profile::dvfs_grid(&model, 6);
    let mut rng = Rng::new(1);
    let profs = ripra::profile::profile_model(&hw, &freqs, trials, &mut rng);
    println!("{}: profiling ({} trials per point x frequency)", model.name, trials);
    println!("  m   g_registry   g_fit     sse        v_table_ms2  v_meas_ms2");
    for pp in &profs {
        println!(
            "  {:>2}  {:>10.4}  {:>8.4}  {:>9.2e}  {:>10.3}  {:>10.3}",
            pp.m,
            model.points[pp.m].g_flops_cycle,
            pp.g_fit,
            pp.fit_sse,
            model.v_loc(pp.m) * 1e6,
            pp.v_max * 1e6
        );
    }
    Ok(())
}

fn cmd_selftest() -> Result<()> {
    // artifacts round-trip: load every model, run a split-vs-full check.
    let dir = Manifest::default_dir();
    println!("artifacts dir: {}", dir.display());
    let engine = ripra::runtime::Engine::cpu(&dir)?;
    println!("PJRT platform: {}", engine.platform());
    for name in ["alexnet", "resnet152"] {
        let mut rt = engine.model_runtime(name)?;
        let input: Vec<f32> = (0..32 * 32 * 3).map(|i| ((i % 13) as f32) / 13.0).collect();
        let full = rt.run_edge(0, 1, &input)?;
        let m = rt.model().num_blocks / 2;
        let feat = rt.run_device(m, &input)?;
        let split = rt.run_edge(m, 1, &feat)?;
        let max_diff =
            full.iter().zip(&split).map(|(a, b)| (a - b).abs()).fold(0.0f32, f32::max);
        println!("{name}: split(m={m}) vs full max |diff| = {max_diff:.2e}");
        if max_diff > 1e-3 {
            bail!("{name}: partition consistency failed");
        }
    }
    println!("selftest OK");
    Ok(())
}
