//! `ripra-lint` — static analysis for the repo's determinism,
//! RNG-stream, structural-contract, and robustness invariants.
//!
//! Usage:
//!
//! ```text
//! ripra-lint [--root DIR] [--json PATH] [--quiet]
//! ```
//!
//! * `--root DIR`  source tree to scan (default: `rust/src` under the
//!   crate root, so `cargo run --release --bin ripra-lint` works from
//!   anywhere in the repo).
//! * `--json PATH` write the machine-readable report there.
//! * `--quiet`     suppress the human table (exit code still reflects
//!   the result).
//!
//! Exit codes: `0` clean, `1` active (unsuppressed) violations,
//! `2` usage or I/O error.  See EXPERIMENTS.md §Static analysis for the
//! rule catalog and the `lint:allow` policy.

use std::path::PathBuf;
use std::process::ExitCode;

use ripra::lint::{self, report};

fn main() -> ExitCode {
    let mut root: Option<PathBuf> = None;
    let mut json_path: Option<PathBuf> = None;
    let mut quiet = false;
    let mut args = std::env::args().skip(1);
    while let Some(arg) = args.next() {
        match arg.as_str() {
            "--root" => match args.next() {
                Some(v) => root = Some(PathBuf::from(v)),
                None => return usage("--root needs a directory"),
            },
            "--json" => match args.next() {
                Some(v) => json_path = Some(PathBuf::from(v)),
                None => return usage("--json needs a path"),
            },
            "--quiet" => quiet = true,
            "--help" | "-h" => {
                println!("usage: ripra-lint [--root DIR] [--json PATH] [--quiet]");
                return ExitCode::SUCCESS;
            }
            other => return usage(&format!("unknown argument `{other}`")),
        }
    }
    let root = root
        .unwrap_or_else(|| PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("rust").join("src"));
    let report = match lint::analyze_root(&root) {
        Ok(r) => r,
        Err(e) => {
            eprintln!("ripra-lint: cannot scan {}: {e}", root.display());
            return ExitCode::from(2);
        }
    };
    if let Some(path) = &json_path {
        let json = report::to_json(&report).to_string_pretty();
        if let Err(e) = std::fs::write(path, json + "\n") {
            eprintln!("ripra-lint: cannot write {}: {e}", path.display());
            return ExitCode::from(2);
        }
    }
    if !quiet {
        print!("{}", report::table(&report));
    }
    if report.is_clean() {
        ExitCode::SUCCESS
    } else {
        ExitCode::from(1)
    }
}

fn usage(msg: &str) -> ExitCode {
    eprintln!("ripra-lint: {msg}");
    eprintln!("usage: ripra-lint [--root DIR] [--json PATH] [--quiet]");
    ExitCode::from(2)
}
