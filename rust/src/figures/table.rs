//! Result table: the common output format of every figure/table
//! regenerator (printed to stdout and optionally dumped as CSV).

use std::io::Write;
use std::path::Path;

/// A labelled table of string cells.
#[derive(Clone, Debug)]
pub struct Table {
    /// Identifier, e.g. "fig13a".
    pub id: String,
    pub title: String,
    pub columns: Vec<String>,
    pub rows: Vec<Vec<String>>,
    /// Free-form context (parameters, paper-expected shape).
    pub notes: String,
}

impl Table {
    pub fn new(id: &str, title: &str, columns: &[&str]) -> Table {
        Table {
            id: id.into(),
            title: title.into(),
            columns: columns.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
            notes: String::new(),
        }
    }

    pub fn with_notes(mut self, notes: &str) -> Table {
        self.notes = notes.into();
        self
    }

    pub fn push_row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.columns.len(), "row arity mismatch in {}", self.id);
        self.rows.push(cells);
    }

    /// Convenience for numeric rows.
    pub fn push_nums(&mut self, cells: &[f64]) {
        self.push_row(cells.iter().map(|x| format_num(*x)).collect());
    }

    pub fn to_csv(&self) -> String {
        let mut s = String::new();
        s.push_str(&self.columns.join(","));
        s.push('\n');
        for row in &self.rows {
            s.push_str(&row.join(","));
            s.push('\n');
        }
        s
    }

    pub fn print(&self) {
        println!("== {} — {}", self.id, self.title);
        if !self.notes.is_empty() {
            println!("   {}", self.notes.replace('\n', "\n   "));
        }
        let widths: Vec<usize> = self
            .columns
            .iter()
            .enumerate()
            .map(|(i, c)| {
                self.rows
                    .iter()
                    .map(|r| r[i].len())
                    .chain(std::iter::once(c.len()))
                    .max()
                    .unwrap_or(0)
            })
            .collect();
        let line = |cells: &[String]| {
            let mut out = String::from("  ");
            for (c, w) in cells.iter().zip(&widths) {
                out.push_str(&format!("{c:>w$}  ", w = w));
            }
            println!("{out}");
        };
        line(&self.columns);
        for row in &self.rows {
            line(row);
        }
        println!();
    }

    /// Write `<dir>/<id>.csv`.
    pub fn save_csv(&self, dir: &Path) -> std::io::Result<()> {
        std::fs::create_dir_all(dir)?;
        let mut f = std::fs::File::create(dir.join(format!("{}.csv", self.id)))?;
        f.write_all(self.to_csv().as_bytes())
    }
}

/// Compact numeric formatting for table cells.
pub fn format_num(x: f64) -> String {
    if x == 0.0 {
        "0".into()
    } else if x.abs() >= 1e5 || x.abs() < 1e-3 {
        format!("{x:.3e}")
    } else if x.fract() == 0.0 {
        format!("{x:.0}")
    } else {
        format!("{x:.4}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip_shape() {
        let mut t = Table::new("t", "demo", &["a", "b"]);
        t.push_nums(&[1.0, 2.5]);
        t.push_row(vec!["x".into(), "y".into()]);
        let csv = t.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.starts_with("a,b\n1,2.5000\n"), "{csv}");
    }

    #[test]
    #[should_panic(expected = "row arity")]
    fn arity_checked() {
        let mut t = Table::new("t", "demo", &["a", "b"]);
        t.push_nums(&[1.0]);
    }

    #[test]
    fn num_formatting() {
        assert_eq!(format_num(0.0), "0");
        assert_eq!(format_num(12.0), "12");
        assert_eq!(format_num(0.12345), "0.1235"); // rounded
        assert!(format_num(1.0e7).contains('e'));
    }
}
