//! Regeneration of every table and figure in the paper's evaluation
//! (§VI) — see DESIGN.md §5 for the experiment index.  Each `figNN`
//! function returns one or more [`Table`]s with the same series the
//! paper plots; `run` dispatches by name and optionally writes CSVs.
//!
//! Absolute numbers come from our substrate (synthetic Jetson/RTX
//! hardware + CPU-PJRT artifacts), so the *shapes* are the reproduction
//! target: who wins, by what factor, where the crossovers sit.

// lint:allow-file(wall-clock): the paper-protocol timing table measures
// solver wall time on purpose (Table "runtime" column).
pub mod table;

use std::path::Path;

use crate::engine::{PlanRequest, Planner, PlannerBuilder, Policy as PlanPolicy};
use crate::models::manifest::{Manifest, Role};
use crate::models::ModelProfile;
use crate::optim::{baselines, AlternatingOptions, Scenario};
use crate::profile::{self, Dist, SyntheticHardware};
use crate::sim::{self, SimOptions};
use crate::util::rng::Rng;
use crate::util::stats::Moments;

pub use table::Table;

/// Effort knob: `Quick` shrinks trial counts/sweeps for tests.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Effort {
    Quick,
    Full,
}

impl Effort {
    fn trials(&self, full: usize) -> usize {
        match self {
            Effort::Quick => (full / 20).max(50),
            Effort::Full => full,
        }
    }
}

fn both_models() -> [ModelProfile; 2] {
    [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()]
}

/// Paper §VI-A defaults per model: (bandwidth, deadline, risk) used by the
/// energy/violation figures.  ResNet deadlines are shifted +30 ms vs the
/// paper (120→150) — our VM/channel substrate makes the paper's exact
/// value infeasible; see EXPERIMENTS.md.
pub fn default_setting(model: &str) -> (f64, f64, f64) {
    match model {
        "alexnet" => (10e6, 0.18, 0.02),
        _ => (30e6, 0.15, 0.04),
    }
}

/// Algorithm-2 options for every paper-protocol reproduction: the paper
/// re-initializes Algorithm 1 each outer iteration, so the (default-on)
/// warm start is disabled to keep figure numbers comparable across PRs.
/// The thread fan-out stays on — it never changes results, only
/// wall-clock (fig11, which *measures* wall-clock, additionally pins
/// `threads` to 1).
fn paper_opts() -> AlternatingOptions {
    AlternatingOptions { warm_start: false, ..Default::default() }
}

/// Engine facade configured for the paper protocol.  Each figure holds
/// its own planner; scenarios inside one figure differ (other ε / D /
/// seed), so the plan cache only coalesces genuinely identical requests.
fn paper_planner() -> Planner {
    PlannerBuilder::new().alternating(paper_opts()).build()
}

// ---------------------------------------------------------------------------
// Characterization (Figs. 1, 3, 5, 6, 7 + Tables II-IV)
// ---------------------------------------------------------------------------

/// Table II: model/hardware pairing.
pub fn table2() -> Vec<Table> {
    let mut t = Table::new("table2", "Configurations of DNNs and hardware", &[
        "model", "device", "f_range_GHz", "kappa", "vm", "vm_GFLOPs", "worst_dev_factor",
    ]);
    for m in both_models() {
        t.push_row(vec![
            m.name.clone(),
            if m.name == "alexnet" {
                "Jetson-NX-CPU (synthetic)".into()
            } else {
                "Jetson-NX-GPU (synthetic)".into()
            },
            format!("[{}, {}]", m.device.f_min_ghz, m.device.f_max_ghz),
            format!("{:.1e}", m.device.kappa),
            "RTX4080 (synthetic)".into(),
            format!("{}", m.vm.gflops_per_sec),
            format!("{}", m.worst_dev_factor),
        ]);
    }
    vec![t]
}

/// Tables III & IV: per-point parameters — registry values side-by-side
/// with re-profiled estimates from the synthetic hardware (the §IV
/// pipeline: 500-trial mean + LM fit of g + max-over-frequency variance).
pub fn table34(effort: Effort) -> Vec<Table> {
    let mut out = Vec::new();
    let mut rng = Rng::new(0x7AB7E);
    for (id, model) in
        [("table3", ModelProfile::alexnet_paper()), ("table4", ModelProfile::resnet152_paper())]
    {
        let hw = SyntheticHardware::new(model.clone(), Dist::Lognormal);
        let freqs = profile::dvfs_grid(&model, 6);
        let profs = profile::profile_model(&hw, &freqs, effort.trials(500), &mut rng);
        let mut t = Table::new(
            id,
            &format!("{} per-point parameters (registry vs re-profiled)", model.name),
            &[
                "m",
                "d_MB",
                "w_GFLOPs",
                "g_registry",
                "g_fit",
                "fit_sse",
                "v_registry_ms2",
                "v_measured_ms2",
            ],
        );
        for m in 0..model.num_points() {
            let p = &model.points[m];
            let (g_fit, sse, v_meas) = if m == 0 {
                (0.0, 0.0, 0.0)
            } else {
                let pp = &profs[m - 1];
                (pp.g_fit, pp.fit_sse, pp.v_max)
            };
            t.push_nums(&[
                m as f64,
                p.d_mb,
                p.w_gflops,
                p.g_flops_cycle,
                g_fit,
                sse,
                p.v_loc_s2 * 1e6,
                v_meas * 1e6,
            ]);
        }
        out.push(t);
    }
    out
}

/// Fig. 1: variation of full-model inference time (CPU vs GPU pairing).
pub fn fig1(effort: Effort) -> Vec<Table> {
    let mut t = Table::new(
        "fig1",
        "Inference-time variation, full model at f_max (500 trials)",
        &["model", "mean_ms", "std_ms", "p95_ms", "max_ms", "max_dev_over_std"],
    )
    .with_notes("Paper: significant randomness; CPU worse than GPU; outliers far beyond p95.");
    let mut rng = Rng::new(0xF161);
    for model in both_models() {
        let hw = SyntheticHardware::new(model.clone(), Dist::Lognormal);
        let m = model.num_blocks();
        let f = model.device.f_max_ghz;
        let mut acc = Moments::new();
        let mut samples = Vec::new();
        for _ in 0..effort.trials(500) {
            let s = hw.sample_t_loc(m, f, &mut rng);
            acc.push(s);
            samples.push(s);
        }
        samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let p95 = crate::util::stats::percentile(&samples, 95.0);
        t.push_row(vec![
            model.name.clone(),
            format!("{:.2}", acc.mean() * 1e3),
            format!("{:.2}", acc.std() * 1e3),
            format!("{:.2}", p95 * 1e3),
            format!("{:.2}", acc.max() * 1e3),
            format!("{:.2}", (acc.max() - acc.mean()) / acc.std()),
        ]);
    }
    vec![t]
}

/// Fig. 3: per-block data size and GFLOPs — paper tables + the real
/// compiled chains from the AOT manifest when present.
pub fn fig3() -> Vec<Table> {
    let mut out = Vec::new();
    for model in both_models() {
        let mut t = Table::new(
            &format!("fig3_{}", model.name),
            &format!("{}: offload size and cumulative GFLOPs per point", model.name),
            &["m", "d_MB(paper)", "w_GFLOPs(paper)", "d_KB(artifact)", "w_GFLOPs(artifact)"],
        )
        .with_notes("Artifact columns come from artifacts/manifest.json (CIFAR-scale chains).");
        let manifest = Manifest::load(&Manifest::default_dir()).ok();
        let mm = manifest.as_ref().and_then(|m| m.model(&model.name).ok());
        for m in 0..model.num_points() {
            let (da, wa) = mm
                .and_then(|mm| mm.points.get(m))
                .map(|p| (p.d_bytes as f64 / 1e3, p.w_gflops))
                .unwrap_or((f64::NAN, f64::NAN));
            t.push_nums(&[m as f64, model.points[m].d_mb, model.points[m].w_gflops, da, wa]);
        }
        out.push(t);
    }
    out
}

/// Fig. 5: per-block inference time variation on the three platforms,
/// plus the *real* per-part latency of the compiled artifacts on PJRT.
pub fn fig5(effort: Effort) -> Vec<Table> {
    let mut out = Vec::new();
    let _rng = Rng::new(0xF5);
    for model in both_models() {
        let hw = SyntheticHardware::new(model.clone(), Dist::Lognormal);
        let f = model.device.f_max_ghz;
        let mut t = Table::new(
            &format!("fig5_{}", model.name),
            &format!("{}: per-block time at f_max across platforms", model.name),
            &["block", "device_mean_ms", "device_std_ms", "vm_mean_ms", "pjrt_device_part_ms"],
        )
        .with_notes("pjrt column: real wall-clock of the compiled device part (cumulative).");
        // real PJRT cumulative device-part latencies (best effort)
        let probe: Vec<f64> = (|| -> anyhow::Result<Vec<f64>> {
            let engine = crate::runtime::Engine::cpu(&Manifest::default_dir())?;
            let mut rt = engine.model_runtime(&model.name)?;
            let mut v = vec![0.0];
            let iters = effort.trials(60).min(60);
            for m in 1..model.num_points() {
                let mut s = rt.probe_latency(Role::Device, m, 1, iters)?;
                s.sort_by(|a, b| a.partial_cmp(b).unwrap());
                v.push(crate::util::stats::percentile(&s, 50.0));
            }
            Ok(v)
        })()
        .unwrap_or_default();
        for k in 1..model.num_points() {
            // per-block std from the variance increment at f (shape-scaled)
            let std_ms = hw.block_var(k, f).sqrt() * 1e3;
            let vm_block = (model.t_vm_mean(k - 1) - model.t_vm_mean(k)).max(0.0);
            t.push_nums(&[
                k as f64,
                hw.block_mean(k, f) * 1e3,
                std_ms,
                vm_block * 1e3,
                probe.get(k).copied().unwrap_or(f64::NAN) * 1e3,
            ]);
        }
        out.push(t);
    }
    out
}

/// Fig. 6: mean time vs frequency with the eq-10 LM fit + residuals.
pub fn fig6(effort: Effort) -> Vec<Table> {
    let mut out = Vec::new();
    let mut rng = Rng::new(0xF6);
    for model in both_models() {
        let hw = SyntheticHardware::new(model.clone(), Dist::Lognormal);
        let freqs = profile::dvfs_grid(&model, 8);
        let profs = profile::profile_model(&hw, &freqs, effort.trials(500), &mut rng);
        let mut t = Table::new(
            &format!("fig6_{}", model.name),
            &format!("{}: measured mean time vs frequency + w/(g·f) fit", model.name),
            &["m", "f_GHz", "measured_ms", "fitted_ms", "g_fit", "sse"],
        );
        for pp in &profs {
            let w = model.points[pp.m].w_gflops;
            for (i, &f) in pp.freqs_ghz.iter().enumerate() {
                t.push_nums(&[
                    pp.m as f64,
                    f,
                    pp.mean_s[i] * 1e3,
                    w / (pp.g_fit * f) * 1e3,
                    pp.g_fit,
                    pp.fit_sse,
                ]);
            }
        }
        out.push(t);
    }
    out
}

/// Fig. 7: variance of inference time vs frequency (non-monotonic).
pub fn fig7(effort: Effort) -> Vec<Table> {
    let mut out = Vec::new();
    let mut rng = Rng::new(0xF7);
    for model in both_models() {
        let hw = SyntheticHardware::new(model.clone(), Dist::Lognormal);
        let freqs = profile::dvfs_grid(&model, 8);
        let profs = profile::profile_model(&hw, &freqs, effort.trials(500), &mut rng);
        let mut t = Table::new(
            &format!("fig7_{}", model.name),
            &format!("{}: variance vs frequency (max rule -> v_table)", model.name),
            &["m", "f_GHz", "var_ms2", "v_table_ms2"],
        )
        .with_notes("Variance peaks inside the DVFS range; eq-11 takes the max.");
        for pp in &profs {
            for (i, &f) in pp.freqs_ghz.iter().enumerate() {
                t.push_nums(&[pp.m as f64, f, pp.var_s2[i] * 1e6, model.v_loc(pp.m) * 1e6]);
            }
        }
        out.push(t);
    }
    out
}

// ---------------------------------------------------------------------------
// Convergence / complexity (Figs. 9, 10, 11)
// ---------------------------------------------------------------------------

/// Fig. 9: average Algorithm-1 (PCCP) iterations vs number of devices.
pub fn fig9(effort: Effort) -> Vec<Table> {
    let ns: &[usize] = match effort {
        Effort::Quick => &[5, 10],
        Effort::Full => &[5, 10, 15, 20, 25, 30],
    };
    let mut t = Table::new(
        "fig9",
        "Average PCCP (Algorithm 1) iterations vs N",
        &["N", "alexnet_iters", "resnet152_iters"],
    )
    .with_notes("Paper: terminates in a few iterations, nearly flat in N.");
    let mut planner = paper_planner();
    for &n in ns {
        let mut row = vec![n as f64];
        for model in both_models() {
            let (b, d, eps) = default_setting(&model.name);
            // more devices need proportionally more bandwidth headroom
            let b = b * (n as f64 / 12.0).max(1.0);
            let mut rng = Rng::new(0xF19 + n as u64);
            let sc = Scenario::uniform(&model, n, b, d, eps, &mut rng);
            let it = planner
                .plan(&PlanRequest::new(sc, PlanPolicy::Robust))
                .map(|o| o.diagnostics.avg_pccp_iters)
                .unwrap_or(f64::NAN);
            row.push(it);
        }
        t.push_nums(&row);
    }
    vec![t]
}

/// Fig. 10: Algorithm-2 convergence trajectories from 3 initial points.
pub fn fig10() -> Vec<Table> {
    let mut out = Vec::new();
    for model in both_models() {
        let (b, d, eps) = default_setting(&model.name);
        let d = if model.name == "alexnet" { 0.22 } else { d + 0.01 };
        let mut rng = Rng::new(0xF10);
        let sc = Scenario::uniform(&model, 6, b, d, eps, &mut rng);
        let inits: Vec<usize> =
            if model.name == "alexnet" { vec![3, 7, 8] } else { vec![1, 8, 9] };
        let mut t = Table::new(
            &format!("fig10_{}", model.name),
            &format!("{}: objective per outer iteration from 3 initial points", model.name),
            &["outer_iter", "init_a", "init_b", "init_c"],
        )
        .with_notes("Paper: fast early convergence, (nearly) the same final objective.");
        let mut planner = paper_planner();
        let mut trajs = Vec::new();
        for &p in &inits {
            let init = vec![p.min(model.num_points() - 1); sc.n()];
            let r = planner.plan(&PlanRequest::new(sc.clone(), PlanPolicy::Robust).with_init(init));
            trajs.push(r.map(|o| o.diagnostics.trajectory).unwrap_or_default());
        }
        let len = trajs.iter().map(Vec::len).max().unwrap_or(0);
        for i in 0..len {
            let row: Vec<f64> = std::iter::once(i as f64)
                .chain(trajs.iter().map(|tr| {
                    tr.get(i).copied().unwrap_or_else(|| *tr.last().unwrap_or(&f64::NAN))
                }))
                .collect();
            t.push_nums(&row);
        }
        out.push(t);
    }
    out
}

/// Fig. 11: average Algorithm-2 runtime vs N.
pub fn fig11(effort: Effort) -> Vec<Table> {
    let ns: &[usize] = match effort {
        Effort::Quick => &[5, 10],
        Effort::Full => &[5, 10, 15, 20, 25, 30],
    };
    let reps = match effort {
        Effort::Quick => 1,
        Effort::Full => 3,
    };
    let mut t = Table::new(
        "fig11",
        "Average Algorithm-2 runtime vs N (seconds)",
        &["N", "alexnet_s", "resnet152_s"],
    )
    .with_notes("Paper: linear growth in N despite the exponential search space.");
    for &n in ns {
        let mut row = vec![n as f64];
        for model in both_models() {
            let (b, d, eps) = default_setting(&model.name);
            let b = b * (n as f64 / 12.0).max(1.0);
            let mut acc = 0.0;
            for rep in 0..reps {
                let mut rng = Rng::new(0xF11 + n as u64 + rep as u64 * 977);
                let sc = Scenario::uniform(&model, n, b, d, eps, &mut rng);
                // Paper protocol: sequential, cold-started Algorithm 2
                // with the plan cache off (the warm-started parallel
                // wall-clock is tracked by benches/planner_scaling.rs).
                let mut planner = PlannerBuilder::new()
                    .alternating(paper_opts())
                    .threads(1)
                    .cache_capacity(0)
                    .build();
                let t0 = std::time::Instant::now();
                let _ = planner.plan(&PlanRequest::new(sc, PlanPolicy::Robust));
                acc += t0.elapsed().as_secs_f64();
            }
            row.push(acc / reps as f64);
        }
        t.push_nums(&row);
    }
    vec![t]
}

// ---------------------------------------------------------------------------
// Energy / violation benchmarks (Figs. 12, 13, 14)
// ---------------------------------------------------------------------------

/// Fig. 12: energy vs N, proposed vs (multi-start) optimal.
pub fn fig12(effort: Effort) -> Vec<Table> {
    let ns: &[usize] = match effort {
        Effort::Quick => &[2, 4],
        Effort::Full => &[2, 4, 6, 8, 10, 12],
    };
    let mut out = Vec::new();
    for model in both_models() {
        // paper: AlexNet D=200 ms B=5 MHz, ResNet D=150 ms B=15 MHz; our
        // channel substrate needs 2x the bandwidth at N=12 scale (see
        // EXPERIMENTS.md).
        let (b0, d, eps) = match model.name.as_str() {
            "alexnet" => (10e6, 0.20, 0.02),
            _ => (30e6, 0.16, 0.04),
        };
        let mut t = Table::new(
            &format!("fig12_{}", model.name),
            &format!("{}: total energy vs N — proposed vs optimal", model.name),
            &["N", "proposed_J", "optimal_J", "gap_pct"],
        )
        .with_notes(
            "optimal = exhaustive (N=2) / multi-start enumeration (documented substitution).\n\
             Paper: proposed tracks optimal closely; energy grows with N.",
        );
        let mut planner = paper_planner();
        for &n in ns {
            let mut rng = Rng::new(0xF12 + n as u64);
            let sc = Scenario::uniform(&model, n, b0, d, eps, &mut rng);
            let prop = planner
                .plan(&PlanRequest::new(
                    sc.clone(),
                    PlanPolicy::Multistart { extra_starts: Vec::new() },
                ))
                .map(|o| o.energy)
                .unwrap_or(f64::NAN);
            let opt = if n == 2 {
                planner
                    .plan(&PlanRequest::new(sc.clone(), PlanPolicy::Exhaustive))
                    .map(|o| o.energy)
                    .unwrap_or(f64::NAN)
            } else {
                // best over both search families: the enumeration
                // multi-start is itself a heuristic at N>2, so the best
                // feasible plan seen anywhere is the optimum estimate.
                baselines::multistart_optimal(&sc, 6, 0xF12)
                    .map(|r| r.energy.min(prop))
                    .unwrap_or(prop)
            };
            t.push_nums(&[n as f64, prop, opt, (prop - opt) / opt * 100.0]);
        }
        out.push(t);
    }
    out
}

/// Figs. 13(a)/14(a): energy vs risk level ε, robust vs worst-case.
pub fn fig_energy_vs_risk(model: &ModelProfile) -> Table {
    let (b, d, _) = default_setting(&model.name);
    let n = 12;
    let id = if model.name == "alexnet" { "fig13a" } else { "fig14a" };
    let mut t = Table::new(
        id,
        &format!("{}: energy vs risk level (N=12)", model.name),
        &["eps", "robust_J", "worst_case_J", "saving_pct"],
    )
    .with_notes(
        "Paper: robust energy decreases monotonically in eps; worst-case flat.\n\
         AlexNet: robust wins at all eps; ResNet152: worst-case wins at small eps\n\
         (conservative eq-11/12 approximations), robust overtakes as eps grows.",
    );
    let mut planner = paper_planner();
    for eps in [0.02, 0.04, 0.06, 0.08] {
        let mut rng = Rng::new(0xF13A);
        let sc = Scenario::uniform(model, n, b, d, eps, &mut rng);
        let rob = planner
            .plan(&PlanRequest::new(sc.clone(), PlanPolicy::Robust))
            .map(|o| o.energy)
            .unwrap_or(f64::NAN);
        let wc = planner
            .plan(&PlanRequest::new(sc, PlanPolicy::WorstCase))
            .map(|o| o.energy)
            .unwrap_or(f64::NAN);
        t.push_nums(&[eps, rob, wc, (1.0 - rob / wc) * 100.0]);
    }
    t
}

/// Figs. 13(b)/14(b): energy vs deadline.
pub fn fig_energy_vs_deadline(model: &ModelProfile) -> Table {
    let (b, _, eps) = default_setting(&model.name);
    let n = 12;
    let (id, deadlines): (&str, Vec<f64>) = if model.name == "alexnet" {
        ("fig13b", vec![0.16, 0.18, 0.20, 0.22, 0.24, 0.26, 0.28])
    } else {
        // paper sweeps 120..180 ms; shifted +30 ms (see EXPERIMENTS.md)
        ("fig14b", vec![0.15, 0.16, 0.17, 0.18, 0.19, 0.20, 0.21])
    };
    let mut t = Table::new(
        id,
        &format!("{}: energy vs deadline (N=12, eps={eps})", model.name),
        &["D_ms", "robust_J", "worst_case_J", "saving_pct"],
    )
    .with_notes("Paper: energy decreases monotonically as the deadline loosens.");
    let mut planner = paper_planner();
    for d in deadlines {
        let mut rng = Rng::new(0xF13B);
        let sc = Scenario::uniform(model, n, b, d, eps, &mut rng);
        let rob = planner
            .plan(&PlanRequest::new(sc.clone(), PlanPolicy::Robust))
            .map(|o| o.energy)
            .unwrap_or(f64::NAN);
        let wc = planner
            .plan(&PlanRequest::new(sc, PlanPolicy::WorstCase))
            .map(|o| o.energy)
            .unwrap_or(f64::NAN);
        t.push_nums(&[d * 1e3, rob, wc, (1.0 - rob / wc) * 100.0]);
    }
    t
}

/// Figs. 13(c)/14(c): empirical deadline-violation probability vs ε.
pub fn fig_violation(model: &ModelProfile, effort: Effort) -> Table {
    let (b, _, _) = default_setting(&model.name);
    let n = 12;
    let (id, deadlines): (&str, [f64; 3]) = if model.name == "alexnet" {
        ("fig13c", [0.16, 0.18, 0.20])
    } else {
        ("fig14c", [0.15, 0.17, 0.19])
    };
    let mut t = Table::new(
        id,
        &format!("{}: empirical violation probability vs risk level", model.name),
        &["eps", "D1_viol", "D2_viol", "D3_viol", "mean_only_viol_D2"],
    )
    .with_notes(
        "Monte-Carlo over the synthetic hardware (lognormal + spikes).\n\
         Paper: violation stays below eps at every deadline.  mean_only\n\
         column shows the unprotected policy for contrast.",
    );
    let trials = effort.trials(10_000);
    let mut planner = paper_planner();
    let mut violation_of = |sc: &Scenario, policy: PlanPolicy| -> f64 {
        planner
            .plan(&PlanRequest::new(sc.clone(), policy))
            .map(|o| {
                sim::evaluate(sc, &o.plan, &SimOptions { trials, ..Default::default() })
                    .worst_violation
            })
            .unwrap_or(f64::NAN)
    };
    for eps in [0.02, 0.04, 0.06, 0.08] {
        let mut row = vec![eps];
        for (i, &d) in deadlines.iter().enumerate() {
            let mut rng = Rng::new(0xF13C + i as u64);
            let sc = Scenario::uniform(model, n, b, d, eps, &mut rng);
            row.push(violation_of(&sc, PlanPolicy::Robust));
        }
        // mean-only contrast at the middle deadline
        let mut rng = Rng::new(0xF13C + 1);
        let sc = Scenario::uniform(model, n, b, deadlines[1], eps, &mut rng);
        row.push(violation_of(&sc, PlanPolicy::MeanOnly));
        t.push_nums(&row);
    }
    t
}

/// Risk-bound family (refactor extension, not a paper figure): planned
/// energy, total reserved margin, and empirical violation per
/// chance-constraint transform at the paper's default setting — the
/// attribution table behind the `--bound` CLI axis.
pub fn fig_bounds(model: &ModelProfile, effort: Effort) -> Table {
    use crate::risk::BOUND_FAMILY;
    let (b, d, eps) = default_setting(&model.name);
    let n = 12;
    let mut t = Table::new(
        &format!("figbounds_{}", model.name),
        &format!("{}: energy and violation per risk bound (N=12, eps={eps})", model.name),
        &["bound", "energy_J", "margin_sum_ms", "worst_violation", "saving_vs_ecr_pct"],
    )
    .with_notes(
        "Each bound transforms the same chance constraint; tighter margins\n\
         save energy while the Monte-Carlo violation must stay near/below eps\n\
         (gauss is exact only for near-normal jitter; see EXPERIMENTS.md).",
    );
    let trials = effort.trials(10_000);
    let mut planner = paper_planner();
    let mut ecr_energy = f64::NAN;
    for bound in BOUND_FAMILY {
        let mut rng = Rng::new(0xB0B0);
        let sc = Scenario::uniform(model, n, b, d, eps, &mut rng);
        let row = planner
            .plan(&PlanRequest::new(sc.clone(), PlanPolicy::Robust).with_bound(bound))
            .map(|o| {
                let viol = sim::evaluate(&sc, &o.plan, &SimOptions { trials, ..Default::default() })
                    .worst_violation;
                let margin_ms: f64 = o.diagnostics.margins_s.iter().sum::<f64>() * 1e3;
                (o.energy, margin_ms, viol)
            })
            .unwrap_or((f64::NAN, f64::NAN, f64::NAN));
        if bound == crate::risk::RiskBound::Ecr {
            ecr_energy = row.0;
        }
        // Saving is only meaningful when both this solve and the ECR
        // reference succeeded; otherwise mark the cell unavailable
        // instead of propagating NaN through the one column this figure
        // exists to report.
        let saving = if row.0.is_finite() && ecr_energy.is_finite() {
            format!("{:.2}", (1.0 - row.0 / ecr_energy) * 100.0)
        } else {
            "n/a".into()
        };
        t.push_row(vec![
            bound.name().into(),
            format!("{:.6}", row.0),
            format!("{:.3}", row.1),
            format!("{:.4}", row.2),
            saving,
        ]);
    }
    t
}

pub fn fig13(effort: Effort) -> Vec<Table> {
    let m = ModelProfile::alexnet_paper();
    vec![fig_energy_vs_risk(&m), fig_energy_vs_deadline(&m), fig_violation(&m, effort)]
}

pub fn fig14(effort: Effort) -> Vec<Table> {
    let m = ModelProfile::resnet152_paper();
    vec![fig_energy_vs_risk(&m), fig_energy_vs_deadline(&m), fig_violation(&m, effort)]
}

// ---------------------------------------------------------------------------
// Dispatch
// ---------------------------------------------------------------------------

pub const ALL: &[&str] = &[
    "table2", "table3", "table4", "fig1", "fig3", "fig5", "fig6", "fig7", "fig9", "fig10",
    "fig11", "fig12", "fig13a", "fig13b", "fig13c", "fig14a", "fig14b", "fig14c", "figbounds",
];

/// Regenerate one named figure (or "all"); print and optionally save CSVs.
pub fn run(name: &str, out_dir: Option<&Path>, effort: Effort) -> Result<Vec<Table>, String> {
    let tables: Vec<Table> = match name {
        "all" => {
            let mut all = Vec::new();
            for n in ALL {
                // table3/table4 share one generator; avoid double work
                if *n == "table4" {
                    continue;
                }
                all.extend(run(n, out_dir, effort)?);
            }
            return Ok(all);
        }
        "table2" => table2(),
        "table3" | "table4" => table34(effort),
        "fig1" => fig1(effort),
        "fig3" => fig3(),
        "fig5" => fig5(effort),
        "fig6" => fig6(effort),
        "fig7" => fig7(effort),
        "fig9" => fig9(effort),
        "fig10" => fig10(),
        "fig11" => fig11(effort),
        "fig12" => fig12(effort),
        "fig13" => fig13(effort),
        "fig14" => fig14(effort),
        "fig13a" => vec![fig_energy_vs_risk(&ModelProfile::alexnet_paper())],
        "fig13b" => vec![fig_energy_vs_deadline(&ModelProfile::alexnet_paper())],
        "fig13c" => vec![fig_violation(&ModelProfile::alexnet_paper(), effort)],
        "fig14a" => vec![fig_energy_vs_risk(&ModelProfile::resnet152_paper())],
        "fig14b" => vec![fig_energy_vs_deadline(&ModelProfile::resnet152_paper())],
        "fig14c" => vec![fig_violation(&ModelProfile::resnet152_paper(), effort)],
        "figbounds" => {
            both_models().into_iter().map(|m| fig_bounds(&m, effort)).collect()
        }
        other => return Err(format!("unknown figure {other:?}; have {ALL:?} or 'all'")),
    };
    for t in &tables {
        t.print();
        if let Some(dir) = out_dir {
            t.save_csv(dir).map_err(|e| e.to_string())?;
        }
    }
    Ok(tables)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn characterization_figures_quick() {
        for name in ["table2", "table3", "fig1", "fig3", "fig7"] {
            let tables = run(name, None, Effort::Quick).unwrap();
            assert!(!tables.is_empty(), "{name}");
            assert!(tables.iter().all(|t| !t.rows.is_empty()), "{name}");
        }
    }

    #[test]
    fn fig9_iterations_small() {
        let t = &fig9(Effort::Quick)[0];
        // a few iterations, not dozens (paper's Fig. 9 range)
        for row in &t.rows {
            let iters: f64 = row[1].parse().unwrap();
            assert!(iters >= 1.0 && iters < 20.0, "{row:?}");
        }
    }

    #[test]
    fn fig13a_shape_robust_monotone() {
        let t = fig_energy_vs_risk(&ModelProfile::alexnet_paper());
        let energies: Vec<f64> = t.rows.iter().map(|r| r[1].parse().unwrap()).collect();
        for w in energies.windows(2) {
            assert!(w[1] <= w[0] * 1.02, "robust energy not decreasing: {energies:?}");
        }
        // robust beats worst-case on AlexNet at every eps (paper's headline)
        for row in &t.rows {
            let saving: f64 = row[3].parse().unwrap();
            assert!(saving > 0.0, "{row:?}");
        }
    }

    #[test]
    fn unknown_figure_rejected() {
        assert!(run("fig99", None, Effort::Quick).is_err());
    }
}
