//! Dense linear algebra substrate (no external BLAS/nalgebra offline).
//!
//! Sized for the optimizer's needs: Newton KKT systems of a few hundred
//! variables.  Row-major `Matrix`, Cholesky / regularized-Cholesky
//! factorization, triangular solves, and the small vector helpers the
//! solvers use on their hot path (allocation-free variants where it
//! matters).

pub mod chol;

pub use chol::Cholesky;

/// Dense row-major matrix of f64.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Matrix {
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { rows, cols, data: vec![0.0; rows * cols] }
    }

    pub fn identity(n: usize) -> Self {
        let mut m = Matrix::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    pub fn from_rows(rows: &[&[f64]]) -> Self {
        let r = rows.len();
        let c = rows.first().map_or(0, |x| x.len());
        assert!(rows.iter().all(|x| x.len() == c), "ragged rows");
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            data.extend_from_slice(row);
        }
        Matrix { rows: r, cols: c, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    pub fn data(&self) -> &[f64] {
        &self.data
    }

    pub fn row(&self, i: usize) -> &[f64] {
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    pub fn fill(&mut self, v: f64) {
        self.data.iter_mut().for_each(|x| *x = v);
    }

    /// self += alpha * (u uᵀ)  — rank-1 update, the barrier Hessian hot path.
    pub fn rank1_update(&mut self, alpha: f64, u: &[f64]) {
        debug_assert_eq!(self.rows, self.cols);
        debug_assert_eq!(u.len(), self.rows);
        let n = self.rows;
        for i in 0..n {
            let aui = alpha * u[i];
            if aui == 0.0 {
                continue;
            }
            let row = &mut self.data[i * n..(i + 1) * n];
            for (rj, &uj) in row.iter_mut().zip(u) {
                *rj += aui * uj;
            }
        }
    }

    /// Reshape to `rows × cols` reusing the existing buffer (grows the
    /// allocation only when the new shape exceeds the current capacity)
    /// and reset every entry to zero.  This is the workspace-reuse
    /// primitive: a warmed-up buffer cycles through differently-shaped
    /// Newton systems without touching the allocator.
    pub fn reset_zeroed(&mut self, rows: usize, cols: usize) {
        self.rows = rows;
        self.cols = cols;
        self.data.clear();
        self.data.resize(rows * cols, 0.0);
    }

    /// self += alpha * I (diagonal regularization).
    pub fn add_diag(&mut self, alpha: f64) {
        debug_assert_eq!(self.rows, self.cols);
        for i in 0..self.rows {
            self.data[i * self.cols + i] += alpha;
        }
    }

    /// y = self * x (allocating).
    pub fn matvec(&self, x: &[f64]) -> Vec<f64> {
        let mut y = vec![0.0; self.rows];
        self.matvec_into(x, &mut y);
        y
    }

    /// y = self * x (no allocation).
    pub fn matvec_into(&self, x: &[f64], y: &mut [f64]) {
        debug_assert_eq!(x.len(), self.cols);
        debug_assert_eq!(y.len(), self.rows);
        for i in 0..self.rows {
            let row = self.row(i);
            let mut acc = 0.0;
            for (a, b) in row.iter().zip(x) {
                acc += a * b;
            }
            y[i] = acc;
        }
    }

    /// y = selfᵀ * x.
    pub fn t_matvec(&self, x: &[f64]) -> Vec<f64> {
        debug_assert_eq!(x.len(), self.rows);
        let mut y = vec![0.0; self.cols];
        for i in 0..self.rows {
            let xi = x[i];
            if xi == 0.0 {
                continue;
            }
            for (yj, &aij) in y.iter_mut().zip(self.row(i)) {
                *yj += aij * xi;
            }
        }
        y
    }

    pub fn matmul(&self, other: &Matrix) -> Matrix {
        assert_eq!(self.cols, other.rows);
        let mut out = Matrix::zeros(self.rows, other.cols);
        for i in 0..self.rows {
            for k in 0..self.cols {
                let aik = self[(i, k)];
                if aik == 0.0 {
                    continue;
                }
                let orow = other.row(k);
                let out_row = out.row_mut(i);
                for (o, &b) in out_row.iter_mut().zip(orow) {
                    *o += aik * b;
                }
            }
        }
        out
    }

    pub fn transpose(&self) -> Matrix {
        let mut out = Matrix::zeros(self.cols, self.rows);
        for i in 0..self.rows {
            for j in 0..self.cols {
                out[(j, i)] = self[(i, j)];
            }
        }
        out
    }

    /// Max |a_ij - b_ij|.
    pub fn max_abs_diff(&self, other: &Matrix) -> f64 {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        self.data
            .iter()
            .zip(&other.data)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0, f64::max)
    }
}

impl std::ops::Index<(usize, usize)> for Matrix {
    type Output = f64;

    #[inline]
    fn index(&self, (i, j): (usize, usize)) -> &f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &self.data[i * self.cols + j]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Matrix {
    #[inline]
    fn index_mut(&mut self, (i, j): (usize, usize)) -> &mut f64 {
        debug_assert!(i < self.rows && j < self.cols);
        &mut self.data[i * self.cols + j]
    }
}

// -- vector helpers ---------------------------------------------------------

pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    a.iter().zip(b).map(|(x, y)| x * y).sum()
}

/// y += alpha * x
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi += alpha * xi;
    }
}

pub fn norm2(x: &[f64]) -> f64 {
    dot(x, x).sqrt()
}

pub fn norm_inf(x: &[f64]) -> f64 {
    x.iter().fold(0.0, |m, v| m.max(v.abs()))
}

pub fn scale(alpha: f64, x: &mut [f64]) {
    x.iter_mut().for_each(|v| *v *= alpha);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_identity() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        assert_eq!(a.matmul(&Matrix::identity(2)), a);
        assert_eq!(Matrix::identity(2).matmul(&a), a);
    }

    #[test]
    fn matmul_known() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Matrix::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Matrix::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matvec_and_transpose_agree() {
        let a = Matrix::from_rows(&[&[1.0, -2.0, 0.5], &[0.0, 3.0, 1.0]]);
        let x = [2.0, 1.0, -1.0];
        assert_eq!(a.matvec(&x), vec![1.0 * 2.0 - 2.0 - 0.5, 3.0 - 1.0]);
        let at = a.transpose();
        let y = [1.0, -1.0];
        assert_eq!(a.t_matvec(&y), at.matvec(&y));
    }

    #[test]
    fn rank1_update_matches_outer_product() {
        let mut m = Matrix::zeros(3, 3);
        let u = [1.0, 2.0, 3.0];
        m.rank1_update(2.0, &u);
        for i in 0..3 {
            for j in 0..3 {
                assert_eq!(m[(i, j)], 2.0 * u[i] * u[j]);
            }
        }
    }

    #[test]
    fn vector_ops() {
        assert_eq!(dot(&[1.0, 2.0], &[3.0, 4.0]), 11.0);
        let mut y = vec![1.0, 1.0];
        axpy(2.0, &[1.0, -1.0], &mut y);
        assert_eq!(y, vec![3.0, -1.0]);
        assert_eq!(norm_inf(&[-3.0, 2.0]), 3.0);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-15);
    }

    #[test]
    #[should_panic]
    fn from_rows_rejects_ragged() {
        Matrix::from_rows(&[&[1.0], &[1.0, 2.0]]);
    }

    #[test]
    fn reset_zeroed_reshapes_and_clears() {
        let mut m = Matrix::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        m.reset_zeroed(3, 1);
        assert_eq!((m.rows(), m.cols()), (3, 1));
        assert!(m.data().iter().all(|&v| v == 0.0));
        m.reset_zeroed(2, 2);
        assert_eq!(m, Matrix::zeros(2, 2));
    }
}
