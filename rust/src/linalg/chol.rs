//! Cholesky factorization + triangular solves.
//!
//! The barrier solver's Newton step factors the (positive-definite)
//! barrier Hessian once per step and reuses the factor for the Schur
//! complement of equality constraints, so the factorization owns its `L`
//! and exposes repeated `solve` calls.  A regularized variant retries with
//! growing diagonal jitter — near the central path's end the Hessian can
//! become numerically semidefinite.

use super::Matrix;

/// Lower-triangular Cholesky factor: A = L Lᵀ.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Matrix,
}

/// Factorization failure (matrix not positive definite).
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cholesky pivot {} is {:.3e} <= 0", self.pivot, self.value)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// Plain factorization; fails if a pivot is non-positive.
    pub fn factor(a: &Matrix) -> Result<Cholesky, NotPositiveDefinite> {
        assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
        let n = a.rows();
        let mut l = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        return Err(NotPositiveDefinite { pivot: i, value: sum });
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(Cholesky { l })
    }

    /// Factor `a + jitter*I`, growing jitter by 10x (up to `max_jitter`)
    /// until the factorization succeeds.  Returns the used jitter.
    pub fn factor_regularized(
        a: &Matrix,
        mut jitter: f64,
        max_jitter: f64,
    ) -> Result<(Cholesky, f64), NotPositiveDefinite> {
        match Cholesky::factor(a) {
            Ok(c) => return Ok((c, 0.0)),
            Err(e) => {
                if jitter <= 0.0 {
                    return Err(e);
                }
            }
        }
        loop {
            let mut b = a.clone();
            b.add_diag(jitter);
            match Cholesky::factor(&b) {
                Ok(c) => return Ok((c, jitter)),
                Err(e) => {
                    jitter *= 10.0;
                    if jitter > max_jitter {
                        return Err(e);
                    }
                }
            }
        }
    }

    /// Solve A x = b.
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = b.to_vec();
        self.solve_in_place(&mut x);
        x
    }

    /// Solve A x = b in place (forward then backward substitution).
    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.l.rows();
        debug_assert_eq!(x.len(), n);
        // L y = b
        for i in 0..n {
            let mut sum = x[i];
            let row = self.l.row(i);
            for k in 0..i {
                sum -= row[k] * x[k];
            }
            x[i] = sum / row[i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut sum = x[i];
            for k in i + 1..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
    }

    /// log det A = 2 Σ log L_ii (used for diagnostics).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    pub fn l(&self) -> &Matrix {
        &self.l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        // A = B Bᵀ + n*I is SPD.
        let mut b = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn factor_and_solve_roundtrip() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20, 60] {
            let a = random_spd(n, &mut rng);
            let chol = Cholesky::factor(&a).unwrap();
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let x = chol.solve(&b);
            let err: f64 = x
                .iter()
                .zip(&x_true)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-8, "n={n} err={err}");
        }
    }

    #[test]
    fn factor_reconstructs() {
        let a = Matrix::from_rows(&[
            &[4.0, 2.0, 0.6],
            &[2.0, 5.0, 1.0],
            &[0.6, 1.0, 3.0],
        ]);
        let c = Cholesky::factor(&a).unwrap();
        let recon = c.l().matmul(&c.l().transpose());
        assert!(recon.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalue -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn regularized_recovers() {
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]); // singular
        let (c, jitter) = Cholesky::factor_regularized(&a, 1e-10, 1.0).unwrap();
        assert!(jitter > 0.0);
        let x = c.solve(&[1.0, 1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn log_det_known() {
        let a = Matrix::from_rows(&[&[4.0, 0.0], &[0.0, 9.0]]);
        let c = Cholesky::factor(&a).unwrap();
        assert!((c.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }
}
