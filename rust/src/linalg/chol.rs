//! Cholesky factorization + triangular solves.
//!
//! The barrier solver's Newton step factors the (positive-definite)
//! barrier Hessian once per step and reuses the factor for the Schur
//! complement of equality constraints, so the factorization owns its `L`
//! and exposes repeated `solve` calls.  A regularized variant retries with
//! growing diagonal jitter — near the central path's end the Hessian can
//! become numerically semidefinite.

use super::Matrix;

/// Lower-triangular Cholesky factor: A = L Lᵀ.
#[derive(Clone, Debug)]
pub struct Cholesky {
    l: Matrix,
}

/// Factorization failure (matrix not positive definite).
#[derive(Debug, Clone, PartialEq)]
pub struct NotPositiveDefinite {
    pub pivot: usize,
    pub value: f64,
}

impl std::fmt::Display for NotPositiveDefinite {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "cholesky pivot {} is {:.3e} <= 0", self.pivot, self.value)
    }
}

impl std::error::Error for NotPositiveDefinite {}

impl Cholesky {
    /// An empty factorization to use as a reusable workspace slot: feed it
    /// matrices through [`Cholesky::factor_into`] /
    /// [`Cholesky::factor_regularized_into`]; the internal buffer is
    /// recycled across factorizations of the same size.
    pub fn empty() -> Cholesky {
        Cholesky { l: Matrix::zeros(0, 0) }
    }

    /// Plain factorization; fails if a pivot is non-positive.
    pub fn factor(a: &Matrix) -> Result<Cholesky, NotPositiveDefinite> {
        let mut c = Cholesky::empty();
        c.factor_into(a)?;
        Ok(c)
    }

    /// Factor `a` into this factorization's storage (no allocation when
    /// the shape matches the previous factorization).  On error the
    /// stored factor is invalid and must not be used for solves.
    pub fn factor_into(&mut self, a: &Matrix) -> Result<(), NotPositiveDefinite> {
        self.factor_jittered_into(a, 0.0)
    }

    /// Factor `a + jitter*I` without materializing the shifted matrix:
    /// the jitter is added to the diagonal as the factorization reads it.
    fn factor_jittered_into(&mut self, a: &Matrix, jitter: f64) -> Result<(), NotPositiveDefinite> {
        assert_eq!(a.rows(), a.cols(), "cholesky needs a square matrix");
        let n = a.rows();
        if self.l.rows() != n || self.l.cols() != n {
            // Shape change: re-zero so the never-written upper triangle is
            // clean.  Same-shape reuse skips this (the previous factor
            // only ever wrote the lower triangle).
            self.l.reset_zeroed(n, n);
        }
        let l = &mut self.l;
        for i in 0..n {
            for j in 0..=i {
                let mut sum = a[(i, j)];
                if i == j && jitter != 0.0 {
                    sum += jitter;
                }
                for k in 0..j {
                    sum -= l[(i, k)] * l[(j, k)];
                }
                if i == j {
                    if sum <= 0.0 || !sum.is_finite() {
                        return Err(NotPositiveDefinite { pivot: i, value: sum });
                    }
                    l[(i, j)] = sum.sqrt();
                } else {
                    l[(i, j)] = sum / l[(j, j)];
                }
            }
        }
        Ok(())
    }

    /// Factor `a + jitter*I`, growing jitter by 10x (up to `max_jitter`)
    /// until the factorization succeeds.  Returns the used jitter.
    pub fn factor_regularized(
        a: &Matrix,
        jitter: f64,
        max_jitter: f64,
    ) -> Result<(Cholesky, f64), NotPositiveDefinite> {
        let mut c = Cholesky::empty();
        let used = c.factor_regularized_into(a, jitter, max_jitter)?;
        Ok((c, used))
    }

    /// In-place variant of [`Cholesky::factor_regularized`]: reuses this
    /// factorization's storage and never clones `a` (the retry ladder
    /// re-reads `a` and adds the jitter on the fly).  Returns the jitter
    /// that succeeded.
    pub fn factor_regularized_into(
        &mut self,
        a: &Matrix,
        mut jitter: f64,
        max_jitter: f64,
    ) -> Result<f64, NotPositiveDefinite> {
        match self.factor_jittered_into(a, 0.0) {
            Ok(()) => return Ok(0.0),
            Err(e) => {
                if jitter <= 0.0 {
                    return Err(e);
                }
            }
        }
        loop {
            match self.factor_jittered_into(a, jitter) {
                Ok(()) => return Ok(jitter),
                Err(e) => {
                    jitter *= 10.0;
                    if jitter > max_jitter {
                        return Err(e);
                    }
                }
            }
        }
    }

    /// Solve A x = b (allocating convenience wrapper over
    /// [`Cholesky::solve_into`]; hot paths should hold their own output
    /// buffer and call `solve_into` / `solve_in_place` directly).
    pub fn solve(&self, b: &[f64]) -> Vec<f64> {
        let mut x = vec![0.0; b.len()];
        self.solve_into(b, &mut x);
        x
    }

    /// Solve A x = b writing into a caller-owned buffer (no allocation).
    pub fn solve_into(&self, b: &[f64], x: &mut [f64]) {
        x.copy_from_slice(b);
        self.solve_in_place(x);
    }

    /// Solve A x = b in place (forward then backward substitution).
    pub fn solve_in_place(&self, x: &mut [f64]) {
        let n = self.l.rows();
        debug_assert_eq!(x.len(), n);
        // L y = b
        for i in 0..n {
            let mut sum = x[i];
            let row = self.l.row(i);
            for k in 0..i {
                sum -= row[k] * x[k];
            }
            x[i] = sum / row[i];
        }
        // Lᵀ x = y
        for i in (0..n).rev() {
            let mut sum = x[i];
            for k in i + 1..n {
                sum -= self.l[(k, i)] * x[k];
            }
            x[i] = sum / self.l[(i, i)];
        }
    }

    /// log det A = 2 Σ log L_ii (used for diagnostics).
    pub fn log_det(&self) -> f64 {
        (0..self.l.rows()).map(|i| self.l[(i, i)].ln()).sum::<f64>() * 2.0
    }

    pub fn l(&self) -> &Matrix {
        &self.l
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
        // A = B Bᵀ + n*I is SPD.
        let mut b = Matrix::zeros(n, n);
        for i in 0..n {
            for j in 0..n {
                b[(i, j)] = rng.normal();
            }
        }
        let mut a = b.matmul(&b.transpose());
        a.add_diag(n as f64);
        a
    }

    #[test]
    fn factor_and_solve_roundtrip() {
        let mut rng = Rng::new(1);
        for n in [1, 2, 5, 20, 60] {
            let a = random_spd(n, &mut rng);
            let chol = Cholesky::factor(&a).unwrap();
            let x_true: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let b = a.matvec(&x_true);
            let x = chol.solve(&b);
            let err: f64 = x
                .iter()
                .zip(&x_true)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0, f64::max);
            assert!(err < 1e-8, "n={n} err={err}");
        }
    }

    #[test]
    fn factor_reconstructs() {
        let a = Matrix::from_rows(&[
            &[4.0, 2.0, 0.6],
            &[2.0, 5.0, 1.0],
            &[0.6, 1.0, 3.0],
        ]);
        let c = Cholesky::factor(&a).unwrap();
        let recon = c.l().matmul(&c.l().transpose());
        assert!(recon.max_abs_diff(&a) < 1e-12);
    }

    #[test]
    fn rejects_indefinite() {
        let a = Matrix::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]); // eigenvalue -1
        assert!(Cholesky::factor(&a).is_err());
    }

    #[test]
    fn regularized_recovers() {
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]); // singular
        let (c, jitter) = Cholesky::factor_regularized(&a, 1e-10, 1.0).unwrap();
        assert!(jitter > 0.0);
        let x = c.solve(&[1.0, 1.0]);
        assert!(x.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn log_det_known() {
        let a = Matrix::from_rows(&[&[4.0, 0.0], &[0.0, 9.0]]);
        let c = Cholesky::factor(&a).unwrap();
        assert!((c.log_det() - (36.0f64).ln()).abs() < 1e-12);
    }

    #[test]
    fn factor_into_reuse_matches_fresh() {
        // A recycled factorization must be bitwise-identical to a fresh
        // one, across shape changes and regularized retries.
        let mut rng = Rng::new(7);
        let mut ws = Cholesky::empty();
        for n in [4usize, 9, 4, 17, 9] {
            let a = random_spd(n, &mut rng);
            ws.factor_into(&a).unwrap();
            let fresh = Cholesky::factor(&a).unwrap();
            assert_eq!(ws.l(), fresh.l(), "n={n}");
        }
        // regularized path on a singular matrix
        let a = Matrix::from_rows(&[&[1.0, 1.0], &[1.0, 1.0]]);
        let used = ws.factor_regularized_into(&a, 1e-10, 1.0).unwrap();
        let (fresh, used_fresh) = Cholesky::factor_regularized(&a, 1e-10, 1.0).unwrap();
        assert_eq!(used, used_fresh);
        assert_eq!(ws.l(), fresh.l());
    }

    #[test]
    fn solve_into_matches_solve() {
        let mut rng = Rng::new(5);
        let a = random_spd(12, &mut rng);
        let c = Cholesky::factor(&a).unwrap();
        let b: Vec<f64> = (0..12).map(|_| rng.normal()).collect();
        let x = c.solve(&b);
        let mut y = vec![0.0; 12];
        c.solve_into(&b, &mut y);
        assert_eq!(x, y);
    }
}
