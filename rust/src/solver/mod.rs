//! Optimization substrate: the `ConvexProgram` interface, a log-barrier
//! interior-point solver (used by both of the paper's subproblems), and
//! Levenberg–Marquardt nonlinear least squares (the §IV mean-time fit).

pub mod barrier;
pub mod lm;
pub mod program;

pub use barrier::{
    solve, solve_from, solve_from_with, solve_with, BarrierOptions, BarrierSolution,
    NewtonWorkspace,
};
pub use program::ConvexProgram;
