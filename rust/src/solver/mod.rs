//! Optimization substrate: the `ConvexProgram` interface, a log-barrier
//! interior-point solver (used by both of the paper's subproblems), and
//! Levenberg–Marquardt nonlinear least squares (the §IV mean-time fit).

pub mod barrier;
pub mod lm;
pub mod program;

pub use barrier::{solve, solve_from, BarrierOptions, BarrierSolution};
pub use program::ConvexProgram;
