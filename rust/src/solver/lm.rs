//! Levenberg–Marquardt nonlinear least squares.
//!
//! §IV of the paper fits the mean-inference-time model t̄(f) = w/(g·f)
//! to measured (frequency, time) pairs with "the nonlinear least squares
//! method"; this is that method.  Generic over the residual function with
//! a forward-difference Jacobian, so the profiler can also fit richer
//! models (e.g. t = a/f + c) for the ablation figures.

use crate::linalg::{self, Cholesky, Matrix};

/// LM options.
#[derive(Clone, Debug)]
pub struct LmOptions {
    pub max_iters: usize,
    /// Initial damping λ.
    pub lambda0: f64,
    /// Stop when the step or the cost improvement is below this.
    pub tol: f64,
    /// Finite-difference step for the Jacobian.
    pub fd_eps: f64,
}

impl Default for LmOptions {
    fn default() -> Self {
        LmOptions { max_iters: 200, lambda0: 1e-3, tol: 1e-12, fd_eps: 1e-7 }
    }
}

/// Fit result.
#[derive(Clone, Debug)]
pub struct LmFit {
    pub params: Vec<f64>,
    /// Final sum of squared residuals (the paper reports this as the
    /// "squared 2-norm of the residual", Fig. 6).
    pub sse: f64,
    pub iters: usize,
    pub converged: bool,
}

/// Minimize ||r(θ)||² over θ.  `residuals(θ, out)` writes the residual
/// vector (fixed length = out.len()).
pub fn fit<R>(n_resid: usize, theta0: &[f64], opts: &LmOptions, mut residuals: R) -> LmFit
where
    R: FnMut(&[f64], &mut [f64]),
{
    let p = theta0.len();
    let mut theta = theta0.to_vec();
    let mut r = vec![0.0; n_resid];
    let mut r_try = vec![0.0; n_resid];
    let mut jac = Matrix::zeros(n_resid, p);
    let mut lambda = opts.lambda0;

    residuals(&theta, &mut r);
    let mut cost = linalg::dot(&r, &r);

    for iter in 0..opts.max_iters {
        // Forward-difference Jacobian.
        for j in 0..p {
            let h = opts.fd_eps * theta[j].abs().max(1.0);
            let mut tp = theta.clone();
            tp[j] += h;
            residuals(&tp, &mut r_try);
            for i in 0..n_resid {
                jac[(i, j)] = (r_try[i] - r[i]) / h;
            }
        }
        // Normal equations: (JᵀJ + λ diag(JᵀJ)) δ = −Jᵀ r
        let mut jtj = Matrix::zeros(p, p);
        for i in 0..n_resid {
            jtj.rank1_update(1.0, jac.row(i));
        }
        let jtr = jac.t_matvec(&r);

        let mut improved = false;
        for _ in 0..30 {
            let mut a = jtj.clone();
            for d in 0..p {
                let scale = jtj[(d, d)].max(1e-12);
                a[(d, d)] += lambda * scale;
            }
            let delta = match Cholesky::factor_regularized(&a, 1e-14, 1.0) {
                Ok((c, _)) => {
                    let mut d = c.solve(&jtr);
                    linalg::scale(-1.0, &mut d);
                    d
                }
                Err(_) => break,
            };
            let mut theta_try = theta.clone();
            linalg::axpy(1.0, &delta, &mut theta_try);
            residuals(&theta_try, &mut r_try);
            let cost_try = linalg::dot(&r_try, &r_try);
            if cost_try < cost {
                let step_norm = linalg::norm2(&delta);
                let gain = cost - cost_try;
                theta = theta_try;
                std::mem::swap(&mut r, &mut r_try);
                cost = cost_try;
                lambda = (lambda * 0.3).max(1e-12);
                improved = true;
                if step_norm < opts.tol || gain < opts.tol {
                    return LmFit { params: theta, sse: cost, iters: iter + 1, converged: true };
                }
                break;
            }
            lambda *= 10.0;
            if lambda > 1e12 {
                break;
            }
        }
        if !improved {
            return LmFit { params: theta, sse: cost, iters: iter + 1, converged: true };
        }
    }
    LmFit { params: theta, sse: cost, iters: opts.max_iters, converged: false }
}

/// Convenience: fit the paper's eq-(10) model  t̄(f) = w / (g f)  for known
/// workload `w_gflops`, returning the fitted `g` (GFLOPs/cycle·GHz — the
/// effective per-cycle throughput) and the residual SSE.
pub fn fit_throughput(w_gflops: f64, freqs_ghz: &[f64], times_s: &[f64]) -> (f64, f64) {
    assert_eq!(freqs_ghz.len(), times_s.len());
    assert!(!freqs_ghz.is_empty());
    // Closed-form warm start: g ≈ mean over samples of w/(t f).
    let g0 = freqs_ghz
        .iter()
        .zip(times_s)
        .map(|(f, t)| w_gflops / (t * f).max(1e-12))
        .sum::<f64>()
        / freqs_ghz.len() as f64;
    let fitres = fit(freqs_ghz.len(), &[g0], &LmOptions::default(), |theta, out| {
        let g = theta[0].max(1e-9);
        for (i, (f, t)) in freqs_ghz.iter().zip(times_s).enumerate() {
            out[i] = w_gflops / (g * f) - t;
        }
    });
    (fitres.params[0], fitres.sse)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn fits_exact_throughput_model() {
        let w = 1.4214; // AlexNet full, Table III
        let g_true = 7.1037;
        let freqs: Vec<f64> = (1..=12).map(|i| 0.1 * i as f64).collect();
        let times: Vec<f64> = freqs.iter().map(|f| w / (g_true * f)).collect();
        let (g, sse) = fit_throughput(w, &freqs, &times);
        assert!((g - g_true).abs() < 1e-6, "g={g}");
        assert!(sse < 1e-12);
    }

    #[test]
    fn fits_noisy_throughput_model() {
        let mut rng = Rng::new(3);
        let (w, g_true) = (23.1064, 307.6753); // ResNet152 full, Table IV
        let freqs: Vec<f64> = (2..=8).map(|i| 0.1 * i as f64).collect();
        let times: Vec<f64> = freqs
            .iter()
            .map(|f| w / (g_true * f) * (1.0 + 0.01 * rng.normal()))
            .collect();
        let (g, _sse) = fit_throughput(w, &freqs, &times);
        assert!((g - g_true).abs() / g_true < 0.03, "g={g}");
    }

    #[test]
    fn generic_fit_recovers_two_params() {
        // y = a e^{-b x} sampled exactly.
        let (a, b) = (2.5, 0.7);
        let xs: Vec<f64> = (0..20).map(|i| 0.2 * i as f64).collect();
        let ys: Vec<f64> = xs.iter().map(|x| a * (-b * x).exp()).collect();
        let fitres = fit(xs.len(), &[1.0, 0.1], &LmOptions::default(), |th, out| {
            for (i, x) in xs.iter().enumerate() {
                out[i] = th[0] * (-th[1] * x).exp() - ys[i];
            }
        });
        assert!(fitres.converged);
        assert!((fitres.params[0] - a).abs() < 1e-5, "{:?}", fitres.params);
        assert!((fitres.params[1] - b).abs() < 1e-5, "{:?}", fitres.params);
    }
}
