//! `ConvexProgram`: the interface the barrier interior-point solver
//! consumes.
//!
//! A program is
//!
//! ```text
//!   minimize    f(x)
//!   subject to  g_i(x) <= 0,  i = 0..num_ineq
//!               A x = b            (optional linear equalities)
//! ```
//!
//! with `f` and every `g_i` convex and twice differentiable on the
//! domain.  Implementors provide analytic gradients/Hessians — both
//! subproblems of the paper (resource allocation (23) and the PCCP
//! iterate (36)) have closed forms, so no autodiff is needed.

use crate::linalg::Matrix;

pub trait ConvexProgram {
    fn num_vars(&self) -> usize;

    fn num_ineq(&self) -> usize;

    fn objective(&self, x: &[f64]) -> f64;

    /// Write ∇f(x) into `g` (len = num_vars).
    fn gradient(&self, x: &[f64], g: &mut [f64]);

    /// Add ∇²f(x), scaled by `scale`, into `h` (num_vars × num_vars).
    fn hessian_accum(&self, x: &[f64], scale: f64, h: &mut Matrix);

    /// Value of inequality i at x (feasible iff < 0 strictly inside).
    fn constraint(&self, i: usize, x: &[f64]) -> f64;

    /// Write ∇g_i(x) into `g`.
    fn constraint_grad(&self, i: usize, x: &[f64], g: &mut [f64]);

    /// Add ∇²g_i(x), scaled by `scale`, into `h`.  Default: zero
    /// (linear constraint).
    fn constraint_hess_accum(&self, _i: usize, _x: &[f64], _scale: f64, _h: &mut Matrix) {
    }

    /// Optional linear equality system (A, b) with A full row rank.
    fn equalities(&self) -> Option<(Matrix, Vec<f64>)> {
        None
    }

    /// A strictly feasible starting point (g_i(x0) < 0 for all i and
    /// A x0 = b).  Programs in this crate construct their own feasible
    /// starts (cheap, structure-specific) rather than running a generic
    /// phase-I.
    fn initial_point(&self) -> Vec<f64>;
}

/// Max_i g_i(x): > 0 means infeasible, < 0 strictly feasible.
pub fn max_violation<P: ConvexProgram + ?Sized>(p: &P, x: &[f64]) -> f64 {
    (0..p.num_ineq())
        .map(|i| p.constraint(i, x))
        .fold(f64::NEG_INFINITY, f64::max)
}

#[cfg(test)]
pub(crate) mod test_programs {
    use super::*;

    /// minimize ||x - target||² s.t. x_i <= cap_i, Σx = sum (if set).
    /// Analytic solutions are easy to derive for test fixtures.
    pub struct BoxQp {
        pub target: Vec<f64>,
        pub cap: Vec<f64>,
        pub sum: Option<f64>,
    }

    impl ConvexProgram for BoxQp {
        fn num_vars(&self) -> usize {
            self.target.len()
        }

        fn num_ineq(&self) -> usize {
            self.cap.len()
        }

        fn objective(&self, x: &[f64]) -> f64 {
            x.iter().zip(&self.target).map(|(a, b)| (a - b) * (a - b)).sum()
        }

        fn gradient(&self, x: &[f64], g: &mut [f64]) {
            for i in 0..x.len() {
                g[i] = 2.0 * (x[i] - self.target[i]);
            }
        }

        fn hessian_accum(&self, _x: &[f64], scale: f64, h: &mut Matrix) {
            for i in 0..self.target.len() {
                h[(i, i)] += 2.0 * scale;
            }
        }

        fn constraint(&self, i: usize, x: &[f64]) -> f64 {
            x[i] - self.cap[i]
        }

        fn constraint_grad(&self, i: usize, _x: &[f64], g: &mut [f64]) {
            g.iter_mut().for_each(|v| *v = 0.0);
            g[i] = 1.0;
        }

        fn equalities(&self) -> Option<(Matrix, Vec<f64>)> {
            self.sum.map(|s| {
                let mut a = Matrix::zeros(1, self.target.len());
                for j in 0..self.target.len() {
                    a[(0, j)] = 1.0;
                }
                (a, vec![s])
            })
        }

        fn initial_point(&self) -> Vec<f64> {
            match self.sum {
                // Equal split satisfies Σx = s; assumes caps allow it.
                Some(s) => vec![s / self.target.len() as f64; self.target.len()],
                None => self.cap.iter().map(|c| c - 1.0).collect(),
            }
        }
    }
}
