//! Log-barrier interior-point solver for `ConvexProgram`s.
//!
//! Standard path-following scheme (Boyd & Vandenberghe ch. 11): for a
//! growing parameter `t`, Newton-center
//!
//! ```text
//!   φ_t(x) = t f(x) − Σ_i log(−g_i(x))      s.t.  A x = b
//! ```
//!
//! The equality-constrained Newton step solves the KKT system
//! `[H Aᵀ; A 0][dx; w] = [−∇φ; 0]` through a Schur complement on the
//! Cholesky factor of `H` (H is positive definite on the central path; a
//! regularized refactor handles the numerically-semidefinite tail).
//!
//! The paper's complexity claims (O(√N log 1/ξ) IPT iterations; §V) are
//! exactly the iteration counts this solver reports, which is what the
//! Fig. 9/11 reproduction measures.

use crate::linalg::{self, Cholesky, Matrix};

use super::program::ConvexProgram;

/// Solver tunables.  Defaults follow B&V's recommendations.
#[derive(Clone, Debug)]
pub struct BarrierOptions {
    /// Initial barrier parameter t.
    pub t0: f64,
    /// Barrier growth factor μ.
    pub mu: f64,
    /// Duality-gap tolerance: stop when num_ineq / t < tol.
    pub tol: f64,
    /// Newton decrement tolerance for the centering stage.
    pub newton_tol: f64,
    /// Max Newton iterations per centering stage.
    pub max_newton: usize,
    /// Backtracking line-search parameters.
    pub ls_alpha: f64,
    pub ls_beta: f64,
}

impl Default for BarrierOptions {
    fn default() -> Self {
        BarrierOptions {
            t0: 1.0,
            mu: 20.0,
            tol: 1e-8,
            newton_tol: 1e-10,
            max_newton: 60,
            ls_alpha: 0.25,
            ls_beta: 0.5,
        }
    }
}

/// Solve outcome + diagnostics (iteration counts feed Figs. 9/11).
#[derive(Clone, Debug)]
pub struct BarrierSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    /// Total Newton iterations across all centering stages.
    pub newton_iters: usize,
    /// Number of outer (centering) stages.
    pub outer_iters: usize,
    /// Final duality-gap bound m/t.
    pub gap: f64,
}

#[derive(Debug, Clone)]
pub enum BarrierError {
    /// The provided initial point is not strictly feasible.
    InfeasibleStart { constraint: usize, value: f64 },
    /// Newton step failed numerically (Hessian not factorizable).
    Numerical(String),
}

impl std::fmt::Display for BarrierError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BarrierError::InfeasibleStart { constraint, value } => write!(
                f,
                "initial point violates constraint {constraint}: g = {value:.3e} >= 0"
            ),
            BarrierError::Numerical(m) => write!(f, "numerical failure: {m}"),
        }
    }
}

impl std::error::Error for BarrierError {}

/// Preallocated buffers for the Newton centering loop.
///
/// One workspace serves any number of solves (shapes may differ between
/// solves; buffers are recycled and only grow).  After the first solve of
/// a given shape the centering loop performs **zero heap allocations** —
/// verified by the counting-allocator test in `rust/tests/alloc.rs` — so
/// hot callers (PCCP's per-device Algorithm-1 loop, the alternation's
/// resource re-solves) should hold one workspace and thread it through
/// [`solve_with`] / [`solve_from_with`].
pub struct NewtonWorkspace {
    /// Barrier Hessian t∇²f + Σ[∇g∇gᵀ/g² − ∇²g/g].
    h: Matrix,
    /// Barrier gradient t∇f − Σ∇g/g.
    grad: Vec<f64>,
    /// Per-constraint gradient scratch.
    cgrad: Vec<f64>,
    /// Constraint values g_i(x) cached from Hessian assembly; the line
    /// search's φ(x) reuses them instead of re-evaluating every g_i.
    gval: Vec<f64>,
    /// Newton direction (also holds y = H⁻¹∇φ in the KKT path).
    dx: Vec<f64>,
    /// Line-search trial point.
    xn: Vec<f64>,
    /// Z = H⁻¹Aᵀ as rows (k × n, flat storage).
    z: Matrix,
    /// Schur complement S = A Z (k × k).
    s: Matrix,
    /// A·y and the Schur solve output w.
    ay: Vec<f64>,
    w: Vec<f64>,
    /// Factorization storage for H and S.
    chol: Cholesky,
    schol: Cholesky,
}

impl Default for NewtonWorkspace {
    fn default() -> Self {
        Self::new()
    }
}

impl NewtonWorkspace {
    pub fn new() -> Self {
        NewtonWorkspace {
            h: Matrix::zeros(0, 0),
            grad: Vec::new(),
            cgrad: Vec::new(),
            gval: Vec::new(),
            dx: Vec::new(),
            xn: Vec::new(),
            z: Matrix::zeros(0, 0),
            s: Matrix::zeros(0, 0),
            ay: Vec::new(),
            w: Vec::new(),
            chol: Cholesky::empty(),
            schol: Cholesky::empty(),
        }
    }

    /// Size every buffer for an (n vars, m ineqs, k equalities) program.
    /// `Vec::resize` never reallocates when shrinking and reuses spare
    /// capacity when growing, so alternating between program shapes stays
    /// allocation-free once the largest shape has been seen.
    fn ensure(&mut self, n: usize, m: usize, k: usize) {
        if self.h.rows() != n || self.h.cols() != n {
            self.h.reset_zeroed(n, n);
        }
        self.grad.resize(n, 0.0);
        self.cgrad.resize(n, 0.0);
        self.gval.resize(m, 0.0);
        self.dx.resize(n, 0.0);
        self.xn.resize(n, 0.0);
        if k > 0 {
            if self.z.rows() != k || self.z.cols() != n {
                self.z.reset_zeroed(k, n);
            }
            if self.s.rows() != k || self.s.cols() != k {
                self.s.reset_zeroed(k, k);
            }
        }
        self.ay.resize(k, 0.0);
        self.w.resize(k, 0.0);
    }
}

pub fn solve<P: ConvexProgram + ?Sized>(
    p: &P,
    opts: &BarrierOptions,
) -> Result<BarrierSolution, BarrierError> {
    let mut ws = NewtonWorkspace::new();
    solve_from_with(p, p.initial_point(), opts, &mut ws)
}

/// Solve starting from a caller-provided strictly feasible point (used for
/// warm starts between PCCP iterations).
pub fn solve_from<P: ConvexProgram + ?Sized>(
    p: &P,
    x: Vec<f64>,
    opts: &BarrierOptions,
) -> Result<BarrierSolution, BarrierError> {
    let mut ws = NewtonWorkspace::new();
    solve_from_with(p, x, opts, &mut ws)
}

/// [`solve`] with a caller-owned workspace (allocation-free hot path).
pub fn solve_with<P: ConvexProgram + ?Sized>(
    p: &P,
    opts: &BarrierOptions,
    ws: &mut NewtonWorkspace,
) -> Result<BarrierSolution, BarrierError> {
    solve_from_with(p, p.initial_point(), opts, ws)
}

/// [`solve_from`] with a caller-owned workspace.  Results are identical
/// (bitwise) to the workspace-free entry points: the workspace only
/// changes where intermediates are stored, never the arithmetic.
pub fn solve_from_with<P: ConvexProgram + ?Sized>(
    p: &P,
    mut x: Vec<f64>,
    opts: &BarrierOptions,
    ws: &mut NewtonWorkspace,
) -> Result<BarrierSolution, BarrierError> {
    let n = p.num_vars();
    let m = p.num_ineq();
    assert_eq!(x.len(), n, "initial point has wrong dimension");

    for i in 0..m {
        let v = p.constraint(i, &x);
        if v >= 0.0 || !v.is_finite() {
            return Err(BarrierError::InfeasibleStart { constraint: i, value: v });
        }
    }

    let eq = p.equalities();
    let k = eq.as_ref().map_or(0, |(a, _)| a.rows());
    ws.ensure(n, m, k);

    let mut t = opts.t0;
    let mut newton_iters = 0;
    let mut outer_iters = 0;

    if m == 0 {
        // Pure Newton on t f(x) once (t irrelevant without a barrier).
        t = 1.0;
    }

    loop {
        outer_iters += 1;
        // ---- Newton centering for φ_t ------------------------------------
        for _ in 0..opts.max_newton {
            newton_iters += 1;
            // Gradient: t ∇f − Σ ∇g_i / g_i
            p.gradient(&x, &mut ws.grad);
            linalg::scale(t, &mut ws.grad);
            // Hessian: t ∇²f + Σ [∇g∇gᵀ/g² − ∇²g/g]
            ws.h.fill(0.0);
            p.hessian_accum(&x, t, &mut ws.h);
            for i in 0..m {
                let gi = p.constraint(i, &x);
                ws.gval[i] = gi;
                p.constraint_grad(i, &x, &mut ws.cgrad);
                linalg::axpy(-1.0 / gi, &ws.cgrad, &mut ws.grad);
                ws.h.rank1_update(1.0 / (gi * gi), &ws.cgrad);
                p.constraint_hess_accum(i, &x, -1.0 / gi, &mut ws.h);
            }

            // Jitter must scale with the matrix norm: near the central
            // path's end the barrier Hessian carries 1/g² terms of ~1e16,
            // where roundoff alone produces O(1e2) negative pivots.
            let max_diag = (0..n).map(|i| ws.h[(i, i)].abs()).fold(1.0, f64::max);
            ws.chol
                .factor_regularized_into(&ws.h, 1e-14 * max_diag, 1e-4 * max_diag)
                .map_err(|e| BarrierError::Numerical(e.to_string()))?;

            // Newton direction (with optional equality KKT via Schur).
            match &eq {
                None => {
                    ws.dx.copy_from_slice(&ws.grad);
                    ws.chol.solve_in_place(&mut ws.dx);
                    linalg::scale(-1.0, &mut ws.dx);
                }
                Some((a, _b)) => {
                    // x0 already satisfies A x = b and steps keep A dx = 0.
                    // y = H⁻¹ grad (held in dx until the final combination)
                    ws.dx.copy_from_slice(&ws.grad);
                    ws.chol.solve_in_place(&mut ws.dx);
                    // Z = H⁻¹ Aᵀ, S = A Z
                    for r in 0..k {
                        ws.z.row_mut(r).copy_from_slice(a.row(r));
                        ws.chol.solve_in_place(ws.z.row_mut(r));
                    }
                    for r in 0..k {
                        for c in 0..k {
                            ws.s[(r, c)] = linalg::dot(a.row(r), ws.z.row(c));
                        }
                    }
                    let s_diag = (0..k).map(|i| ws.s[(i, i)].abs()).fold(1.0, f64::max);
                    ws.schol
                        .factor_regularized_into(&ws.s, 1e-14 * s_diag, 1e-4 * s_diag)
                        .map_err(|e| BarrierError::Numerical(e.to_string()))?;
                    // S w = A y
                    for r in 0..k {
                        ws.ay[r] = linalg::dot(a.row(r), &ws.dx);
                    }
                    ws.w.copy_from_slice(&ws.ay);
                    ws.schol.solve_in_place(&mut ws.w);
                    // dx = −(y − Z w)
                    for r in 0..k {
                        let wr = ws.w[r];
                        linalg::axpy(-wr, ws.z.row(r), &mut ws.dx);
                    }
                    linalg::scale(-1.0, &mut ws.dx);
                }
            }

            // Newton decrement λ² = −∇φᵀ dx
            let lambda2 = -linalg::dot(&ws.grad, &ws.dx);
            if lambda2 / 2.0 <= opts.newton_tol || !lambda2.is_finite() {
                break;
            }

            // Backtracking line search on φ_t, maintaining strict
            // feasibility.  φ(x) comes from the constraint values cached
            // during Hessian assembly — only trial points re-evaluate g.
            let mut phi0 = t * p.objective(&x);
            for i in 0..m {
                phi0 -= (-ws.gval[i]).ln();
            }
            let mut step = 1.0;
            let mut accepted = false;
            loop {
                ws.xn.copy_from_slice(&x);
                linalg::axpy(step, &ws.dx, &mut ws.xn);
                let mut phin = t * p.objective(&ws.xn);
                for i in 0..m {
                    let gi = p.constraint(i, &ws.xn);
                    if gi >= 0.0 {
                        phin = f64::INFINITY;
                        break;
                    }
                    phin -= (-gi).ln();
                }
                if phin <= phi0 - opts.ls_alpha * step * lambda2 {
                    accepted = true;
                    break;
                }
                step *= opts.ls_beta;
                if step < 1e-14 {
                    // Stalled: keep the current iterate, centering is done
                    // to numerical precision.
                    break;
                }
            }
            if !accepted || ws.xn == x {
                break;
            }
            x.copy_from_slice(&ws.xn);
        }

        // ---- Outer stopping rule -----------------------------------------
        let gap = m as f64 / t;
        if m == 0 || gap < opts.tol {
            return Ok(BarrierSolution {
                objective: p.objective(&x),
                x,
                newton_iters,
                outer_iters,
                gap,
            });
        }
        t *= opts.mu;
    }
}

#[cfg(test)]
mod tests {
    use super::super::program::test_programs::BoxQp;
    use super::super::program::{max_violation, ConvexProgram};
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn unconstrained_minimum_inside_caps() {
        // target well below caps -> solution = target
        let p = BoxQp { target: vec![1.0, -2.0, 0.5], cap: vec![10.0, 10.0, 10.0], sum: None };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        for (xi, ti) in s.x.iter().zip(&p.target) {
            assert!((xi - ti).abs() < 1e-5, "{:?}", s.x);
        }
    }

    #[test]
    fn active_cap_binds() {
        // target above cap -> x clipped at cap
        let p = BoxQp { target: vec![5.0], cap: vec![2.0], sum: None };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        assert!((s.x[0] - 2.0).abs() < 1e-4, "{:?}", s.x);
        assert!(max_violation(&p, &s.x) <= 0.0);
    }

    #[test]
    fn equality_constraint_held() {
        // min ||x - (3,0)||² s.t. x1+x2 = 1, x <= 10: analytic x = (2,-1)
        let p = BoxQp { target: vec![3.0, 0.0], cap: vec![10.0, 10.0], sum: Some(1.0) };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        assert!((s.x[0] - 2.0).abs() < 1e-5, "{:?}", s.x);
        assert!((s.x[1] + 1.0).abs() < 1e-5, "{:?}", s.x);
        assert!((s.x.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_infeasible_start() {
        struct Bad;
        impl ConvexProgram for Bad {
            fn num_vars(&self) -> usize {
                1
            }
            fn num_ineq(&self) -> usize {
                1
            }
            fn objective(&self, x: &[f64]) -> f64 {
                x[0]
            }
            fn gradient(&self, _x: &[f64], g: &mut [f64]) {
                g[0] = 1.0;
            }
            fn hessian_accum(&self, _x: &[f64], _s: f64, _h: &mut Matrix) {}
            fn constraint(&self, _i: usize, x: &[f64]) -> f64 {
                x[0] // x <= 0, start at 1 is infeasible
            }
            fn constraint_grad(&self, _i: usize, _x: &[f64], g: &mut [f64]) {
                g[0] = 1.0;
            }
            fn initial_point(&self) -> Vec<f64> {
                vec![1.0]
            }
        }
        assert!(matches!(
            solve(&Bad, &BarrierOptions::default()),
            Err(BarrierError::InfeasibleStart { .. })
        ));
    }

    #[test]
    fn property_random_box_qps_reach_projection() {
        // Projection onto {x <= cap} is min(target, cap) coordinatewise.
        forall("barrier solves random box QPs", 40, |rng| {
            let n = 1 + rng.below(6);
            let target: Vec<f64> = (0..n).map(|_| rng.range(-5.0, 5.0)).collect();
            let cap: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 6.0)).collect();
            let p = BoxQp { target: target.clone(), cap: cap.clone(), sum: None };
            // ensure strictly feasible start exists
            let s = solve(&p, &BarrierOptions::default())
                .map_err(|e| format!("solver failed: {e}"))?;
            for i in 0..n {
                let want = target[i].min(cap[i]);
                crate::util::check::close(s.x[i], want, 1e-4, 1e-4)
                    .map_err(|e| format!("coord {i}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn reports_iteration_counts() {
        let p = BoxQp { target: vec![5.0, 5.0], cap: vec![2.0, 3.0], sum: None };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        assert!(s.newton_iters >= s.outer_iters);
        assert!(s.gap < 1e-8);
    }

    #[test]
    fn workspace_reuse_is_bitwise_identical() {
        // The same workspace cycled through differently-shaped programs
        // (with and without equalities) must reproduce the fresh-workspace
        // solution exactly — solution, objective, and iteration counts.
        let programs = vec![
            BoxQp { target: vec![5.0], cap: vec![2.0], sum: None },
            BoxQp { target: vec![3.0, 0.0], cap: vec![10.0, 10.0], sum: Some(1.0) },
            BoxQp {
                target: vec![1.0, -2.0, 0.5, 4.0],
                cap: vec![10.0, 0.4, 10.0, 1.5],
                sum: None,
            },
            BoxQp { target: vec![5.0], cap: vec![2.0], sum: None },
        ];
        let opts = BarrierOptions::default();
        let mut ws = NewtonWorkspace::new();
        for (idx, p) in programs.iter().enumerate() {
            // warm the workspace on an unrelated shape first
            let reused = solve_with(p, &opts, &mut ws).unwrap();
            let fresh = solve(p, &opts).unwrap();
            assert_eq!(reused.x, fresh.x, "program {idx}");
            assert_eq!(reused.newton_iters, fresh.newton_iters, "program {idx}");
            assert_eq!(reused.outer_iters, fresh.outer_iters, "program {idx}");
            assert!(reused.objective == fresh.objective, "program {idx}");
        }
    }
}
