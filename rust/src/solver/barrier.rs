//! Log-barrier interior-point solver for `ConvexProgram`s.
//!
//! Standard path-following scheme (Boyd & Vandenberghe ch. 11): for a
//! growing parameter `t`, Newton-center
//!
//! ```text
//!   φ_t(x) = t f(x) − Σ_i log(−g_i(x))      s.t.  A x = b
//! ```
//!
//! The equality-constrained Newton step solves the KKT system
//! `[H Aᵀ; A 0][dx; w] = [−∇φ; 0]` through a Schur complement on the
//! Cholesky factor of `H` (H is positive definite on the central path; a
//! regularized refactor handles the numerically-semidefinite tail).
//!
//! The paper's complexity claims (O(√N log 1/ξ) IPT iterations; §V) are
//! exactly the iteration counts this solver reports, which is what the
//! Fig. 9/11 reproduction measures.

use crate::linalg::{self, Cholesky, Matrix};

use super::program::ConvexProgram;

/// Solver tunables.  Defaults follow B&V's recommendations.
#[derive(Clone, Debug)]
pub struct BarrierOptions {
    /// Initial barrier parameter t.
    pub t0: f64,
    /// Barrier growth factor μ.
    pub mu: f64,
    /// Duality-gap tolerance: stop when num_ineq / t < tol.
    pub tol: f64,
    /// Newton decrement tolerance for the centering stage.
    pub newton_tol: f64,
    /// Max Newton iterations per centering stage.
    pub max_newton: usize,
    /// Backtracking line-search parameters.
    pub ls_alpha: f64,
    pub ls_beta: f64,
}

impl Default for BarrierOptions {
    fn default() -> Self {
        BarrierOptions {
            t0: 1.0,
            mu: 20.0,
            tol: 1e-8,
            newton_tol: 1e-10,
            max_newton: 60,
            ls_alpha: 0.25,
            ls_beta: 0.5,
        }
    }
}

/// Solve outcome + diagnostics (iteration counts feed Figs. 9/11).
#[derive(Clone, Debug)]
pub struct BarrierSolution {
    pub x: Vec<f64>,
    pub objective: f64,
    /// Total Newton iterations across all centering stages.
    pub newton_iters: usize,
    /// Number of outer (centering) stages.
    pub outer_iters: usize,
    /// Final duality-gap bound m/t.
    pub gap: f64,
}

#[derive(Debug, Clone)]
pub enum BarrierError {
    /// The provided initial point is not strictly feasible.
    InfeasibleStart { constraint: usize, value: f64 },
    /// Newton step failed numerically (Hessian not factorizable).
    Numerical(String),
}

impl std::fmt::Display for BarrierError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            BarrierError::InfeasibleStart { constraint, value } => write!(
                f,
                "initial point violates constraint {constraint}: g = {value:.3e} >= 0"
            ),
            BarrierError::Numerical(m) => write!(f, "numerical failure: {m}"),
        }
    }
}

impl std::error::Error for BarrierError {}

pub fn solve<P: ConvexProgram + ?Sized>(
    p: &P,
    opts: &BarrierOptions,
) -> Result<BarrierSolution, BarrierError> {
    solve_from(p, p.initial_point(), opts)
}

/// Solve starting from a caller-provided strictly feasible point (used for
/// warm starts between PCCP iterations).
pub fn solve_from<P: ConvexProgram + ?Sized>(
    p: &P,
    mut x: Vec<f64>,
    opts: &BarrierOptions,
) -> Result<BarrierSolution, BarrierError> {
    let n = p.num_vars();
    let m = p.num_ineq();
    assert_eq!(x.len(), n, "initial point has wrong dimension");

    for i in 0..m {
        let v = p.constraint(i, &x);
        if v >= 0.0 || !v.is_finite() {
            return Err(BarrierError::InfeasibleStart { constraint: i, value: v });
        }
    }

    let eq = p.equalities();
    let mut t = opts.t0;
    let mut newton_iters = 0;
    let mut outer_iters = 0;

    // Workspaces reused across Newton iterations (hot-path: no per-iter
    // allocation of the Hessian).
    let mut h = Matrix::zeros(n, n);
    let mut grad = vec![0.0; n];
    let mut cgrad = vec![0.0; n];

    if m == 0 {
        // Pure Newton on t f(x) once (t irrelevant without a barrier).
        t = 1.0;
    }

    loop {
        outer_iters += 1;
        // ---- Newton centering for φ_t ------------------------------------
        for _ in 0..opts.max_newton {
            newton_iters += 1;
            // Gradient: t ∇f − Σ ∇g_i / g_i
            p.gradient(&x, &mut grad);
            linalg::scale(t, &mut grad);
            // Hessian: t ∇²f + Σ [∇g∇gᵀ/g² − ∇²g/g]
            h.fill(0.0);
            p.hessian_accum(&x, t, &mut h);
            for i in 0..m {
                let gi = p.constraint(i, &x);
                p.constraint_grad(i, &x, &mut cgrad);
                linalg::axpy(-1.0 / gi, &cgrad, &mut grad);
                h.rank1_update(1.0 / (gi * gi), &cgrad);
                p.constraint_hess_accum(i, &x, -1.0 / gi, &mut h);
            }

            // Jitter must scale with the matrix norm: near the central
            // path's end the barrier Hessian carries 1/g² terms of ~1e16,
            // where roundoff alone produces O(1e2) negative pivots.
            let max_diag = (0..n).map(|i| h[(i, i)].abs()).fold(1.0, f64::max);
            let (chol, _jit) =
                Cholesky::factor_regularized(&h, 1e-14 * max_diag, 1e-4 * max_diag)
                    .map_err(|e| BarrierError::Numerical(e.to_string()))?;

            // Newton direction (with optional equality KKT via Schur).
            let dx = match &eq {
                None => {
                    let mut d = chol.solve(&grad);
                    linalg::scale(-1.0, &mut d);
                    d
                }
                Some((a, _b)) => {
                    // x0 already satisfies A x = b and steps keep A dx = 0.
                    let k = a.rows();
                    let y = chol.solve(&grad); // H y = grad
                    // Z = H^{-1} Aᵀ, S = A Z
                    let mut s = Matrix::zeros(k, k);
                    let mut z_cols: Vec<Vec<f64>> = Vec::with_capacity(k);
                    for r in 0..k {
                        let zc = chol.solve(a.row(r));
                        z_cols.push(zc);
                    }
                    for r in 0..k {
                        for c in 0..k {
                            s[(r, c)] = linalg::dot(a.row(r), &z_cols[c]);
                        }
                    }
                    let s_diag = (0..k).map(|i| s[(i, i)].abs()).fold(1.0, f64::max);
                    let schol =
                        Cholesky::factor_regularized(&s, 1e-14 * s_diag, 1e-4 * s_diag)
                            .map_err(|e| BarrierError::Numerical(e.to_string()))?
                            .0;
                    // S w = A y
                    let ay: Vec<f64> = (0..k).map(|r| linalg::dot(a.row(r), &y)).collect();
                    let w = schol.solve(&ay);
                    // dx = −(y − Z w)
                    let mut d = y;
                    for r in 0..k {
                        linalg::axpy(-w[r], &z_cols[r], &mut d);
                    }
                    linalg::scale(-1.0, &mut d);
                    d
                }
            };

            // Newton decrement λ² = −∇φᵀ dx
            let lambda2 = -linalg::dot(&grad, &dx);
            if lambda2 / 2.0 <= opts.newton_tol || !lambda2.is_finite() {
                break;
            }

            // Backtracking line search on φ_t, maintaining strict
            // feasibility.
            let phi = |xx: &[f64]| -> f64 {
                let mut v = t * p.objective(xx);
                for i in 0..m {
                    let gi = p.constraint(i, xx);
                    if gi >= 0.0 {
                        return f64::INFINITY;
                    }
                    v -= (-gi).ln();
                }
                v
            };
            let phi0 = phi(&x);
            let mut step = 1.0;
            let mut xn: Vec<f64>;
            loop {
                xn = x.clone();
                linalg::axpy(step, &dx, &mut xn);
                let phin = phi(&xn);
                if phin <= phi0 - opts.ls_alpha * step * lambda2 {
                    break;
                }
                step *= opts.ls_beta;
                if step < 1e-14 {
                    // Stalled: accept current iterate, centering is done to
                    // numerical precision.
                    xn = x.clone();
                    break;
                }
            }
            if xn == x {
                break;
            }
            x = xn;
        }

        // ---- Outer stopping rule -----------------------------------------
        let gap = m as f64 / t;
        if m == 0 || gap < opts.tol {
            return Ok(BarrierSolution {
                objective: p.objective(&x),
                x,
                newton_iters,
                outer_iters,
                gap,
            });
        }
        t *= opts.mu;
    }
}

#[cfg(test)]
mod tests {
    use super::super::program::test_programs::BoxQp;
    use super::super::program::{max_violation, ConvexProgram};
    use super::*;
    use crate::util::check::forall;

    #[test]
    fn unconstrained_minimum_inside_caps() {
        // target well below caps -> solution = target
        let p = BoxQp { target: vec![1.0, -2.0, 0.5], cap: vec![10.0, 10.0, 10.0], sum: None };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        for (xi, ti) in s.x.iter().zip(&p.target) {
            assert!((xi - ti).abs() < 1e-5, "{:?}", s.x);
        }
    }

    #[test]
    fn active_cap_binds() {
        // target above cap -> x clipped at cap
        let p = BoxQp { target: vec![5.0], cap: vec![2.0], sum: None };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        assert!((s.x[0] - 2.0).abs() < 1e-4, "{:?}", s.x);
        assert!(max_violation(&p, &s.x) <= 0.0);
    }

    #[test]
    fn equality_constraint_held() {
        // min ||x - (3,0)||² s.t. x1+x2 = 1, x <= 10: analytic x = (2,-1)
        let p = BoxQp { target: vec![3.0, 0.0], cap: vec![10.0, 10.0], sum: Some(1.0) };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        assert!((s.x[0] - 2.0).abs() < 1e-5, "{:?}", s.x);
        assert!((s.x[1] + 1.0).abs() < 1e-5, "{:?}", s.x);
        assert!((s.x.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn rejects_infeasible_start() {
        struct Bad;
        impl ConvexProgram for Bad {
            fn num_vars(&self) -> usize {
                1
            }
            fn num_ineq(&self) -> usize {
                1
            }
            fn objective(&self, x: &[f64]) -> f64 {
                x[0]
            }
            fn gradient(&self, _x: &[f64], g: &mut [f64]) {
                g[0] = 1.0;
            }
            fn hessian_accum(&self, _x: &[f64], _s: f64, _h: &mut Matrix) {}
            fn constraint(&self, _i: usize, x: &[f64]) -> f64 {
                x[0] // x <= 0, start at 1 is infeasible
            }
            fn constraint_grad(&self, _i: usize, _x: &[f64], g: &mut [f64]) {
                g[0] = 1.0;
            }
            fn initial_point(&self) -> Vec<f64> {
                vec![1.0]
            }
        }
        assert!(matches!(
            solve(&Bad, &BarrierOptions::default()),
            Err(BarrierError::InfeasibleStart { .. })
        ));
    }

    #[test]
    fn property_random_box_qps_reach_projection() {
        // Projection onto {x <= cap} is min(target, cap) coordinatewise.
        forall("barrier solves random box QPs", 40, |rng| {
            let n = 1 + rng.below(6);
            let target: Vec<f64> = (0..n).map(|_| rng.range(-5.0, 5.0)).collect();
            let cap: Vec<f64> = (0..n).map(|_| rng.range(-1.0, 6.0)).collect();
            let p = BoxQp { target: target.clone(), cap: cap.clone(), sum: None };
            // ensure strictly feasible start exists
            let s = solve(&p, &BarrierOptions::default())
                .map_err(|e| format!("solver failed: {e}"))?;
            for i in 0..n {
                let want = target[i].min(cap[i]);
                crate::util::check::close(s.x[i], want, 1e-4, 1e-4)
                    .map_err(|e| format!("coord {i}: {e}"))?;
            }
            Ok(())
        });
    }

    #[test]
    fn reports_iteration_counts() {
        let p = BoxQp { target: vec![5.0, 5.0], cap: vec![2.0, 3.0], sum: None };
        let s = solve(&p, &BarrierOptions::default()).unwrap();
        assert!(s.newton_iters >= s.outer_iters);
        assert!(s.gap < 1e-8);
    }
}
