//! # RIPRA — Robust Inference Partitioning and Resource Allocation
//!
//! Reproduction of *"Robust DNN Partitioning and Resource Allocation
//! Under Uncertain Inference Time"* (Nan, Han, Zhou, Niu; CS.DC 2025) as
//! a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution: a robust planner
//!   (chance-constrained programming + interior-point + penalty
//!   convex-concave procedure) plus the serving coordinator it drives,
//!   with every substrate built in-crate (dense linear algebra, convex
//!   solver, PRNG/statistics, JSON, wireless channel, DVFS energy model,
//!   Monte-Carlo uncertainty simulator).
//! * **L2/L1 (python/compile)** — JAX block-chain models whose hot-spots
//!   are Pallas kernels, AOT-lowered once to HLO text artifacts executed
//!   here through the PJRT CPU client (`runtime`); python is never on the
//!   request path.
//!
//! ## Module map
//!
//! **Start at [`engine`]** — the planning facade every caller goes
//! through: `PlannerBuilder` → `Planner::plan` dispatches all policies
//! through one entrypoint with plan caching, and `Planner::replan`
//! handles incremental scenario changes by warm-starting from the
//! cached plan.  Below it sit the maths ([`optim`], [`risk`],
//! [`solver`]/[`linalg`], [`models`]/[`profile`]/[`channel`]/[`energy`],
//! [`sim`]); above it, the systems: [`service`] (sharded multi-tenant
//! planning plus the TCP wire frontend behind `ripra serve --listen`),
//! [`fleet`] (discrete-event churn simulator and the replayable
//! `loadgen` wire client), [`fault`] (seeded fault injection),
//! [`coordinator`]/[`runtime`] (in-process PJRT serving), and the
//! tooling ([`figures`], [`lint`], [`util`]).
//!
//! The full map — reading order, one paragraph per subsystem, the
//! data-flow diagram, and the cross-cutting invariants (determinism,
//! error contracts, migration policy) — lives in `ARCHITECTURE.md` at
//! the repo root.  `EXPERIMENTS.md` holds each layer's measurement
//! protocol, and `DESIGN.md` maps every paper table/figure to a module.

pub mod channel;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod fault;
pub mod figures;
pub mod fleet;
pub mod linalg;
pub mod lint;
pub mod models;
pub mod optim;
pub mod profile;
pub mod risk;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod solver;
pub mod util;
