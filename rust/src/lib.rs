//! # RIPRA — Robust Inference Partitioning and Resource Allocation
//!
//! Reproduction of *"Robust DNN Partitioning and Resource Allocation
//! Under Uncertain Inference Time"* (Nan, Han, Zhou, Niu; CS.DC 2025) as
//! a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution: a robust planner
//!   (chance-constrained programming + interior-point + penalty
//!   convex-concave procedure) plus the serving coordinator it drives,
//!   with every substrate built in-crate (dense linear algebra, convex
//!   solver, PRNG/statistics, JSON, wireless channel, DVFS energy model,
//!   Monte-Carlo uncertainty simulator).
//! * **L2/L1 (python/compile)** — JAX block-chain models whose hot-spots
//!   are Pallas kernels, AOT-lowered once to HLO text artifacts executed
//!   here through the PJRT CPU client (`runtime`); python is never on the
//!   request path.
//!
//! Start at [`optim::alternating`] (Algorithm 2) for the planner, or
//! [`coordinator`] for the serving runtime.  `DESIGN.md` maps every paper
//! table/figure to a module; `figures` regenerates them.

pub mod channel;
pub mod coordinator;
pub mod energy;
pub mod figures;
pub mod linalg;
pub mod models;
pub mod optim;
pub mod profile;
pub mod runtime;
pub mod sim;
pub mod solver;
pub mod util;
