//! # RIPRA — Robust Inference Partitioning and Resource Allocation
//!
//! Reproduction of *"Robust DNN Partitioning and Resource Allocation
//! Under Uncertain Inference Time"* (Nan, Han, Zhou, Niu; CS.DC 2025) as
//! a three-layer rust + JAX + Pallas system:
//!
//! * **L3 (this crate)** — the paper's contribution: a robust planner
//!   (chance-constrained programming + interior-point + penalty
//!   convex-concave procedure) plus the serving coordinator it drives,
//!   with every substrate built in-crate (dense linear algebra, convex
//!   solver, PRNG/statistics, JSON, wireless channel, DVFS energy model,
//!   Monte-Carlo uncertainty simulator).
//! * **L2/L1 (python/compile)** — JAX block-chain models whose hot-spots
//!   are Pallas kernels, AOT-lowered once to HLO text artifacts executed
//!   here through the PJRT CPU client (`runtime`); python is never on the
//!   request path.
//!
//! ## Module map
//!
//! **Start at [`engine`]** — the planning facade every caller goes
//! through: `PlannerBuilder` → `Planner::plan` dispatches all policies
//! (robust / worst-case / mean-only / exhaustive / multistart) through
//! one entrypoint with plan caching, and `Planner::replan` handles
//! incremental scenario changes (device join/leave, channel/deadline
//! moves) by warm-starting from the cached plan.
//!
//! The layers underneath:
//!
//! * [`optim`] — the paper's algorithms: [`optim::alternating`]
//!   (Algorithm 2), [`optim::pccp`] (Algorithm 1), [`optim::resource`]
//!   (problem (23)), [`optim::ecr`] (Theorem 1), [`optim::baselines`]
//!   (§VI benchmarks), and [`optim::cohort`] — cohort-compressed
//!   planning for million-device fleets: devices are bucketed by the
//!   engine's quantized fingerprint, one representative per cohort is
//!   solved via a two-stage warm start (grouped knapsack + closed-form
//!   Lagrangian bandwidth split) feeding a PCCP polish, and the decision
//!   replicates across members with a per-device feasibility re-check
//!   (opt in with `PlannerBuilder::cohorts(true)` or `ripra simulate
//!   --cohorts`).  The old free-function entry points are
//!   `#[deprecated]` shims over the engine for one release.
//! * [`risk`] — the pluggable chance-constraint transforms
//!   (`RiskBound`: ECR/Cantelli, Gaussian, Bernstein, conformally
//!   calibrated) the robust policy family is parameterized by, plus the
//!   online `Calibration` controller the fleet driver closes the loop
//!   with.
//! * [`solver`] / [`linalg`] — log-barrier interior point over
//!   `ConvexProgram`s with reusable `NewtonWorkspace`s, dense Cholesky,
//!   Levenberg–Marquardt.
//! * [`models`] / [`profile`] / [`channel`] / [`energy`] — the scenario
//!   substrate: DNN/hardware profiles, synthetic profiling, FDMA uplink,
//!   DVFS energy.
//! * [`sim`] — Monte-Carlo validation of the chance constraint.
//! * [`service`] — the scaling layer above the engine: a sharded
//!   multi-tenant `PlannerService` (K independent planners, each with
//!   its own cache and workspace) with deterministic fingerprint-based
//!   device→shard routing, a bounded request queue with backpressure,
//!   batched drains that coalesce covered deltas and fan shards out in
//!   parallel, and load-factor rebalancing on membership churn.
//! * [`fleet`] — discrete-event fleet simulator: seeded churn streams
//!   (join/leave, Gauss–Markov fading, QoS renegotiation) driving
//!   `Planner::replan` — or the sharded service via `--shards` —
//!   end-to-end, with deterministic metrics export.
//! * [`fault`] — seeded, replayable fault schedules for the fleet
//!   simulator: edge-server outage windows (the engine degrades to its
//!   all-local fallback plan), per-device uplink blackouts
//!   (beyond-fade gain collapse), and delta-delivery delays/drops,
//!   plus the jittered exponential backoff that paces re-offloading
//!   when an outage ends.
//! * [`coordinator`] / [`runtime`] — the serving runtime executing plans
//!   on AOT-compiled PJRT artifacts.
//! * [`lint`] — `ripra-lint`, the repo's own static-analysis pass: the
//!   determinism / RNG-stream / structural-contract / robustness
//!   conventions the modules above rely on, turned into machine-checked
//!   rules that run in CI even when the test suite cannot (rule catalog
//!   in EXPERIMENTS.md §Static analysis).
//! * [`figures`] — regenerates every paper table/figure; [`util`] holds
//!   the offline substrate (PRNG, stats, JSON, bench harness, scoped
//!   thread fan-out).
//!
//! `DESIGN.md` maps every paper table/figure to a module; `figures`
//! regenerates them.

pub mod channel;
pub mod coordinator;
pub mod energy;
pub mod engine;
pub mod fault;
pub mod figures;
pub mod fleet;
pub mod linalg;
pub mod lint;
pub mod models;
pub mod optim;
pub mod profile;
pub mod risk;
pub mod runtime;
pub mod service;
pub mod sim;
pub mod solver;
pub mod util;
