//! Profiling substrate: synthetic hardware, inference-time sampling, and
//! the paper's §IV estimators (mean fit, variance/covariance rules).
//!
//! The paper measures per-block inference times on Jetson Xavier NX
//! (CPU/GPU) and an RTX 4080 over 500 trials per configuration.  We do not
//! have that hardware, so this module implements a *synthetic hardware
//! model* with the same statistical contract (DESIGN.md §3):
//!
//! * per-block mean time follows eq. (10): Δt̄_k(f) derived from the
//!   cumulative Tables III/IV columns (w, g);
//! * per-block variance is the increment of the cumulative `v` column,
//!   modulated by a *non-monotonic* frequency shape (Fig. 7's empirical
//!   finding) whose maximum over the DVFS range equals exactly the
//!   table value — so the planner's max-over-frequency rule (eq. 11) is
//!   faithful and conservative;
//! * the sampling *distribution* is configurable (lognormal / gamma /
//!   shifted-exponential) and never revealed to the planner, reproducing
//!   the paper's "mean and variance only, no distribution" regime.
//!
//! On top of the sampler sit the estimators the paper actually runs:
//! empirical mean/variance/covariance over trials (§IV-B) and the
//! nonlinear-least-squares fit of g (§IV-A, via `solver::lm`).

use crate::models::ModelProfile;
use crate::solver::lm;
use crate::util::rng::Rng;
use crate::util::stats::{Covariance, Moments};

/// Jitter distribution family used by the synthetic hardware.  The planner
/// never sees this — only means/variances.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Dist {
    Lognormal,
    Gamma,
    /// shift + Exp: heavy one-sided tail, the adversarial case for
    /// deadline violations.
    ShiftedExp,
}

/// Frequency shape of the variance (Fig. 7): a smooth bump whose maximum
/// over [f_min, f_max] is exactly 1.  `peak_frac` places the bump
/// (AlexNet/CPU: variance peaks at low f; ResNet/GPU: around 0.7 GHz of a
/// [0.2, 0.8] range, i.e. frac ≈ 0.83).
#[derive(Clone, Copy, Debug)]
pub struct VarianceShape {
    pub peak_frac: f64,
    /// Residual level far from the peak (0 < floor <= 1).
    pub floor: f64,
}

impl VarianceShape {
    pub fn for_model(name: &str) -> Self {
        match name {
            "alexnet" => VarianceShape { peak_frac: 0.05, floor: 0.55 },
            _ => VarianceShape { peak_frac: 0.83, floor: 0.55 },
        }
    }

    /// Shape factor in (0, 1]; equals 1 at the peak frequency.
    pub fn at(&self, f_ghz: f64, f_min: f64, f_max: f64) -> f64 {
        let span = (f_max - f_min).max(1e-9);
        let peak = f_min + self.peak_frac * span;
        let z = (f_ghz - peak) / (0.25 * span);
        self.floor + (1.0 - self.floor) * (-z * z).exp()
    }
}

/// Outlier-spike mixture parameters (Fig. 1/5's rare large outliers:
/// I/O stalls, scheduler preemption, thermal events).  A fraction
/// `share` of each block's variance is carried by a Bernoulli(`prob`)
/// additive spike of size s = √(share·var/(prob(1−prob))); the remaining
/// variance stays in the smooth jitter.  Means/variances still match the
/// tables exactly, but the empirical max lands near
/// mean + `worst_dev_factor`·σ — which is what the worst-case baseline
/// plans with (CPUs spike harder than GPUs).
#[derive(Clone, Copy, Debug)]
pub struct SpikeModel {
    pub share: f64,
    pub prob: f64,
}

impl SpikeModel {
    pub fn for_model(name: &str) -> Self {
        match name {
            // CPU: heavy outliers (≈ mean + 8σ max over 500 trials)
            "alexnet" => SpikeModel { share: 0.55, prob: 0.01 },
            // GPU: milder outliers (≈ mean + 5.5σ)
            _ => SpikeModel { share: 0.15, prob: 0.02 },
        }
    }

    /// Spike size for a block with total variance `var`.
    pub fn spike_size(&self, var: f64) -> f64 {
        (self.share * var / (self.prob * (1.0 - self.prob))).sqrt()
    }
}

/// Synthetic hardware: samples per-block and cumulative inference times
/// that honour a `ModelProfile`'s mean/variance tables.
#[derive(Clone, Debug)]
pub struct SyntheticHardware {
    profile: ModelProfile,
    shape: VarianceShape,
    dist: Dist,
    spikes: SpikeModel,
}

impl SyntheticHardware {
    pub fn new(profile: ModelProfile, dist: Dist) -> Self {
        let shape = VarianceShape::for_model(&profile.name);
        let spikes = SpikeModel::for_model(&profile.name);
        SyntheticHardware { profile, shape, dist, spikes }
    }

    pub fn profile(&self) -> &ModelProfile {
        &self.profile
    }

    pub fn dist(&self) -> Dist {
        self.dist
    }

    /// Mean of block k's local time at frequency f (increment of eq. 10).
    pub fn block_mean(&self, k: usize, f_ghz: f64) -> f64 {
        debug_assert!(k >= 1 && k < self.profile.num_points());
        let t_k = self.profile.t_loc_mean(k, f_ghz);
        let t_prev = self.profile.t_loc_mean(k - 1, f_ghz);
        (t_k - t_prev).max(0.0)
    }

    /// Variance of block k's local time at frequency f: table increment ×
    /// frequency shape (≤ the table value, so eq. 11 is an upper bound).
    pub fn block_var(&self, k: usize, f_ghz: f64) -> f64 {
        let dv = (self.profile.v_loc(k) - self.profile.v_loc(k - 1)).max(0.0);
        let hw = self.profile.device;
        dv * self.shape.at(f_ghz, hw.f_min_ghz, hw.f_max_ghz)
    }

    fn draw(&self, mean: f64, var: f64, rng: &mut Rng) -> f64 {
        if mean <= 0.0 {
            return 0.0;
        }
        if var <= 0.0 {
            return mean;
        }
        // Split variance into the smooth component and the outlier spike
        // (total mean/variance unchanged; see SpikeModel).
        let s = self.spikes.spike_size(var);
        let base_mean = mean - self.spikes.prob * s;
        if base_mean > 0.0 {
            let base_var = (1.0 - self.spikes.share) * var;
            let spike = if rng.f64() < self.spikes.prob { s } else { 0.0 };
            return self.draw_smooth(base_mean, base_var, rng) + spike;
        }
        self.draw_smooth(mean, var, rng)
    }

    fn draw_smooth(&self, mean: f64, var: f64, rng: &mut Rng) -> f64 {
        if var <= 0.0 {
            return mean;
        }
        match self.dist {
            Dist::Lognormal => rng.lognormal_mv(mean, var),
            Dist::Gamma => rng.gamma_mv(mean, var),
            Dist::ShiftedExp => {
                let sd = var.sqrt();
                let shift = (mean - sd).max(0.0);
                // if mean < sd the exponential mean absorbs the difference
                let exp_mean = mean - shift;
                shift + rng.exponential(1.0 / exp_mean)
            }
        }
    }

    /// Sample the cumulative local time at partition point m, frequency f
    /// (sum of independent per-block draws — the cumulative mean matches
    /// eq. 10 exactly, the cumulative variance is ≤ the table's v_m).
    pub fn sample_t_loc(&self, m: usize, f_ghz: f64, rng: &mut Rng) -> f64 {
        (1..=m).map(|k| self.draw(self.block_mean(k, f_ghz), self.block_var(k, f_ghz), rng)).sum()
    }

    /// Sample the edge-VM time for the blocks after m.
    pub fn sample_t_vm(&self, m: usize, rng: &mut Rng) -> f64 {
        self.draw(self.profile.t_vm_mean(m), self.profile.v_vm(m), rng)
    }
}

/// Result of profiling one partition point over a frequency sweep
/// (regenerates Fig. 6/7 and the Tables III/IV columns).
#[derive(Clone, Debug)]
pub struct PointProfile {
    pub m: usize,
    pub freqs_ghz: Vec<f64>,
    pub mean_s: Vec<f64>,
    pub var_s2: Vec<f64>,
    /// LM-fitted throughput ĝ (eq. 10) and the fit's residual SSE.
    pub g_fit: f64,
    pub fit_sse: f64,
    /// Max-over-frequency variance (eq. 11).
    pub v_max: f64,
}

/// Run the §IV profiling procedure on synthetic hardware: `trials` per
/// (point, frequency), empirical mean/variance, then the eq-10 LM fit and
/// the eq-11 max rule.
pub fn profile_model(
    hw: &SyntheticHardware,
    freqs_ghz: &[f64],
    trials: usize,
    rng: &mut Rng,
) -> Vec<PointProfile> {
    let prof = hw.profile();
    let mut out = Vec::new();
    for m in 1..prof.num_points() {
        let mut means = Vec::with_capacity(freqs_ghz.len());
        let mut vars = Vec::with_capacity(freqs_ghz.len());
        for &f in freqs_ghz {
            let mut acc = Moments::new();
            for _ in 0..trials {
                acc.push(hw.sample_t_loc(m, f, rng));
            }
            means.push(acc.mean());
            vars.push(acc.variance());
        }
        let w = prof.points[m].w_gflops;
        let (g_fit, fit_sse) = lm::fit_throughput(w, freqs_ghz, &means);
        let v_max = vars.iter().cloned().fold(0.0, f64::max);
        out.push(PointProfile {
            m,
            freqs_ghz: freqs_ghz.to_vec(),
            mean_s: means,
            var_s2: vars,
            g_fit,
            fit_sse,
            v_max,
        });
    }
    out
}

/// Empirical covariance between local and VM times at a point (§IV-B,
/// eq. 12 substrate — with independent executions it concentrates near 0,
/// which is why the paper's W_n keeps only the diagonal in (28)).
pub fn loc_vm_covariance(
    hw: &SyntheticHardware,
    m: usize,
    f_ghz: f64,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut cov = Covariance::new();
    for _ in 0..trials {
        let tl = hw.sample_t_loc(m, f_ghz, rng);
        let tv = hw.sample_t_vm(m, rng);
        cov.push(tl, tv);
    }
    cov.covariance()
}

/// Empirical (max − mean)/σ of the cumulative local time at point m over
/// `trials` runs — the §VI worst-case baseline's planning number (the
/// registry's `worst_dev_factor` is this, rounded).
pub fn measured_worst_factor(
    hw: &SyntheticHardware,
    m: usize,
    f_ghz: f64,
    trials: usize,
    rng: &mut Rng,
) -> f64 {
    let mut acc = Moments::new();
    for _ in 0..trials {
        acc.push(hw.sample_t_loc(m, f_ghz, rng));
    }
    (acc.max() - acc.mean()) / hw.profile().v_loc(m).sqrt()
}

/// Frequency grid over the device's DVFS range.
pub fn dvfs_grid(profile: &ModelProfile, steps: usize) -> Vec<f64> {
    let hw = profile.device;
    (0..steps)
        .map(|i| hw.f_min_ghz + (hw.f_max_ghz - hw.f_min_ghz) * i as f64 / (steps - 1) as f64)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::check::{close, forall};

    fn hw(dist: Dist) -> SyntheticHardware {
        SyntheticHardware::new(ModelProfile::alexnet_paper(), dist)
    }

    #[test]
    fn block_means_are_positive_and_sum_to_cumulative() {
        let hw = hw(Dist::Lognormal);
        let prof = hw.profile();
        for &f in &[0.1, 0.6, 1.2] {
            let mut cum = 0.0;
            for k in 1..prof.num_points() {
                let bm = hw.block_mean(k, f);
                assert!(bm >= 0.0, "block {k} f={f}");
                cum += bm;
                close(cum, prof.t_loc_mean(k, f), 1e-10, 1e-14).unwrap();
            }
        }
    }

    #[test]
    fn sampled_moments_match_tables() {
        // At the variance-peak frequency the cumulative variance should be
        // ≈ the table value; elsewhere it must be below.
        let hw = hw(Dist::Lognormal);
        let prof = hw.profile().clone();
        let mut rng = Rng::new(42);
        let m = prof.num_blocks();
        let f_peak = 0.1 + 0.05 * (1.2 - 0.1); // alexnet shape peak
        let mut acc = Moments::new();
        for _ in 0..60_000 {
            acc.push(hw.sample_t_loc(m, f_peak, &mut rng));
        }
        close(acc.mean(), prof.t_loc_mean(m, f_peak), 0.02, 0.0).unwrap();
        close(acc.variance(), prof.v_loc(m), 0.06, 0.0).unwrap();
    }

    #[test]
    fn variance_never_exceeds_table_max() {
        for dist in [Dist::Lognormal, Dist::Gamma, Dist::ShiftedExp] {
            let hw = hw(dist);
            let prof = hw.profile().clone();
            let m = 4;
            for &f in &dvfs_grid(&prof, 7) {
                let var_sum: f64 = (1..=m).map(|k| hw.block_var(k, f)).sum();
                assert!(
                    var_sum <= prof.v_loc(m) * (1.0 + 1e-9),
                    "dist={dist:?} f={f}: {var_sum} > {}",
                    prof.v_loc(m)
                );
            }
        }
    }

    #[test]
    fn all_distributions_hit_target_moments() {
        forall("sampler moments", 6, |rng| {
            let dist = [Dist::Lognormal, Dist::Gamma, Dist::ShiftedExp][rng.below(3)];
            let hw = hw(dist);
            let mean_target = hw.block_mean(3, 0.8);
            let var_target = hw.block_var(3, 0.8);
            let mut acc = Moments::new();
            for _ in 0..40_000 {
                acc.push(hw.draw(mean_target, var_target, rng));
            }
            close(acc.mean(), mean_target, 0.03, 0.0)
                .map_err(|e| format!("{dist:?} mean: {e}"))?;
            close(acc.variance(), var_target, 0.10, 0.0)
                .map_err(|e| format!("{dist:?} var: {e}"))
        });
    }

    #[test]
    fn profile_recovers_g_within_tolerance() {
        let hw = hw(Dist::Gamma);
        let prof = hw.profile().clone();
        let mut rng = Rng::new(7);
        let freqs = dvfs_grid(&prof, 6);
        let profiles = profile_model(&hw, &freqs, 800, &mut rng);
        for pp in &profiles {
            let g_true = prof.points[pp.m].g_flops_cycle;
            assert!(
                (pp.g_fit - g_true).abs() / g_true < 0.10,
                "m={} fit={} true={}",
                pp.m,
                pp.g_fit,
                g_true
            );
            // Empirical max-over-frequency variance is an estimate of the
            // table value; the spike mixture makes it noisy upward.
            assert!(pp.v_max <= prof.v_loc(pp.m) * 1.8, "m={}", pp.m);
        }
    }

    #[test]
    fn loc_vm_covariance_is_small() {
        let hw = hw(Dist::Lognormal);
        let mut rng = Rng::new(11);
        let cov = loc_vm_covariance(&hw, 4, 0.8, 20_000, &mut rng);
        // Independent draws: |cov| should be far below sqrt(v_loc · v_vm).
        let bound = (hw.profile().v_loc(4) * hw.profile().v_vm(4)).sqrt();
        assert!(cov.abs() < 0.1 * bound + 1e-9, "cov={cov} bound={bound}");
    }

    #[test]
    fn variance_shape_peaks_inside_range() {
        let s = VarianceShape::for_model("resnet152");
        let (lo, hi) = (0.2, 0.8);
        let grid: Vec<f64> = (0..100).map(|i| lo + (hi - lo) * i as f64 / 99.0).collect();
        let vals: Vec<f64> = grid.iter().map(|&f| s.at(f, lo, hi)).collect();
        let max = vals.iter().cloned().fold(0.0, f64::max);
        assert!(max > 0.999 && max <= 1.0 + 1e-12);
        // non-monotonic: interior max strictly above both endpoints
        assert!(vals[0] < max && vals[99] < max);
    }

    #[test]
    fn worst_factor_matches_registry() {
        // The registry's worst_dev_factor should be in the ballpark of
        // what 500-trial profiling on the synthetic hardware observes
        // (loose band: max statistics of a mixture are noisy).
        let mut rng = Rng::new(99);
        for prof in [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()] {
            let declared = prof.worst_dev_factor;
            let f_mid = 0.5 * (prof.device.f_min_ghz + prof.device.f_max_ghz);
            let hw = SyntheticHardware::new(prof.clone(), Dist::Lognormal);
            let m = hw.profile().num_blocks();
            let mut worst = 0.0f64;
            for _ in 0..4 {
                worst = worst.max(measured_worst_factor(&hw, m, f_mid, 500, &mut rng));
            }
            assert!(
                worst > 0.45 * declared && worst < 1.8 * declared,
                "{}: measured {worst:.2} vs declared {declared}",
                hw.profile().name
            );
        }
    }

    #[test]
    fn spike_mixture_preserves_moments() {
        let hw = hw(Dist::Gamma);
        let mut rng = Rng::new(123);
        let (mean_t, var_t) = (hw.block_mean(5, 0.6), hw.block_var(5, 0.6));
        let mut acc = Moments::new();
        for _ in 0..200_000 {
            acc.push(hw.draw(mean_t, var_t, &mut rng));
        }
        close(acc.mean(), mean_t, 0.02, 0.0).unwrap();
        close(acc.variance(), var_t, 0.08, 0.0).unwrap();
    }

    #[test]
    fn dvfs_grid_covers_range() {
        let prof = ModelProfile::resnet152_paper();
        let g = dvfs_grid(&prof, 7);
        assert_eq!(g.len(), 7);
        assert!((g[0] - 0.2).abs() < 1e-12 && (g[6] - 0.8).abs() < 1e-12);
    }
}
