//! Serving coordinator: the L3 runtime that executes a robust plan on the
//! real AOT artifacts.
//!
//! Topology (all std::thread + mpsc; PJRT handles are !Send so one
//! *executor thread* owns the `runtime::Engine` and serializes
//! executions, which is faithful to a single shared CPU/accelerator):
//!
//! ```text
//!  device agent 0 ─┐ (local part exec req)        ┌─> executor thread
//!  device agent 1 ─┼──────────────┐               │   (owns Engine,
//!       ...        │              ├─> exec queue ─┤    device + edge
//!  device agent N ─┘              │               │    parts, weights)
//!        │ features (after link)  │               │
//!        └────────> batcher ──────┘  batched edge execs
//!                      │
//!                      └──> completions → metrics collector (main)
//! ```
//!
//! Each device agent: Poisson arrivals → local inference (real PJRT
//! compute, padded up to the DVFS-model time so the planner's frequency
//! choice matters) → simulated uplink (sleep t_off·time_scale) → feature
//! handed to the batcher.  The batcher groups features per partition
//! point and flushes full batches immediately or on a window timeout
//! (vLLM-style dynamic batching); remainders run at batch 1.
//!
//! This module is the **in-process** serve mode (`ripra serve` without
//! `--listen`): plan once, then execute the plan against real PJRT
//! artifacts.  The other serve mode — `ripra serve --listen ADDR` — is
//! the network-facing *planner frontend* in [`crate::service::server`]:
//! it speaks the length-prefixed wire protocol of
//! [`crate::service::wire`] over TCP and answers admit/delta/plan
//! traffic (e.g. from `ripra loadgen`) instead of executing inference.
//! EXPERIMENTS.md §Serving specifies the wire protocol and the replay
//! methodology for that mode.

// lint:allow-file(wall-clock): real serving-latency harness — measured
// wall times are the *output* here, not a hidden input to planner JSON.
use std::collections::BTreeMap;
use std::path::PathBuf;
use std::sync::mpsc;
use std::time::{Duration, Instant};

use anyhow::{anyhow, Result};

use crate::models::manifest::Role;
use crate::optim::types::{Plan, Scenario};
use crate::profile::{Dist, SyntheticHardware};
use crate::runtime::Engine;
use crate::util::rng::Rng;
use crate::util::stats::{percentile_of, Moments};

/// Serving options.
#[derive(Clone, Debug)]
pub struct ServeOptions {
    /// Model name in the manifest.
    pub model: String,
    /// Requests each device issues.
    pub requests_per_device: usize,
    /// Per-device Poisson arrival rate (requests/s of *virtual* time).
    pub arrival_rate_hz: f64,
    /// Edge batching window.
    pub batch_window: Duration,
    /// Preferred edge batch size (must exist as an artifact batch).
    pub max_batch: usize,
    /// Scale for simulated (wireless / DVFS) sleeps: 1.0 = real time,
    /// 0 = don't sleep (pure-compute stress mode).
    pub time_scale: f64,
    pub seed: u64,
}

impl Default for ServeOptions {
    fn default() -> Self {
        ServeOptions {
            model: "alexnet".into(),
            requests_per_device: 20,
            arrival_rate_hz: 20.0,
            batch_window: Duration::from_millis(4),
            max_batch: 8,
            time_scale: 1.0,
            seed: 0xC0FFEE,
        }
    }
}

/// Aggregate serving outcome.
#[derive(Clone, Debug)]
pub struct ServeReport {
    pub completed: usize,
    /// Requests whose end-to-end latency exceeded the device deadline.
    pub violations: usize,
    pub wall_time: Duration,
    pub throughput_rps: f64,
    pub mean_latency_s: f64,
    pub p50_latency_s: f64,
    pub p99_latency_s: f64,
    /// Mean realized edge batch size.
    pub mean_batch: f64,
    /// Total modeled device energy (J), local + offload.
    pub total_energy_j: f64,
    /// Mean wall time of device-part PJRT executions.
    pub mean_device_exec_s: f64,
    /// Mean wall time of edge-part PJRT executions.
    pub mean_edge_exec_s: f64,
}

// ---- internal messages -----------------------------------------------------

enum ExecReq {
    Device { m: usize, data: Vec<f32>, reply: mpsc::Sender<Result<Vec<f32>>> },
    Edge { m: usize, batch: usize, data: Vec<f32>, reply: mpsc::Sender<Result<Vec<f32>>> },
    Stop,
}

struct FeatureMsg {
    device: usize,
    m: usize,
    feat: Vec<f32>,
    started: Instant,
    enqueued: Instant,
    deadline_s: f64,
}

struct Completion {
    #[allow(dead_code)] // used by richer per-device reporting in figures
    device: usize,
    latency_s: f64,
    batch: usize,
    deadline_s: f64,
}

/// Plan through the engine facade, then serve the result: the one-call
/// path `ripra serve` uses.  The planner is borrowed (not constructed
/// here) so a long-lived coordinator keeps its plan cache and solver
/// workspaces warm across scenario changes.
pub fn plan_and_serve(
    artifacts_dir: PathBuf,
    sc: &Scenario,
    planner: &mut crate::engine::Planner,
    opts: &ServeOptions,
) -> Result<(crate::engine::PlanOutcome, ServeReport)> {
    let outcome = planner
        .plan(&crate::engine::PlanRequest::new(sc.clone(), crate::engine::Policy::Robust))
        .map_err(|e| anyhow!(e.to_string()))?;
    let report = serve(artifacts_dir, sc, &outcome.plan, opts)?;
    Ok((outcome, report))
}

/// Plan through a sharded [`crate::service::PlannerService`] — the
/// `ripra serve --shards K` path — then serve the assembled fleet-wide
/// decision.  The scenario is admitted as tenant `tenant`; a long-lived
/// caller keeps the service borrowed so every shard's plan cache and
/// Newton workspace stay warm across scenario changes, exactly like the
/// single-planner path above.
pub fn plan_and_serve_sharded(
    artifacts_dir: PathBuf,
    sc: &Scenario,
    service: &mut crate::service::PlannerService,
    tenant: crate::service::TenantId,
    opts: &ServeOptions,
) -> Result<(crate::engine::PlanOutcome, ServeReport)> {
    // Re-serving the same tenant id replaces its fleet; the shard
    // planners keep their caches and workspaces, so the re-admission's
    // cold plans probe warm.
    if service.tenant_devices(tenant).is_some() {
        service.remove_tenant(tenant);
    }
    let admitted =
        service.admit_tenant(tenant, sc.clone()).map_err(|e| anyhow!(e.to_string()))?;
    let plan = service.assembled_plan(tenant).expect("tenant admitted above");
    let outcome = crate::engine::PlanOutcome {
        plan: plan.clone(),
        energy: admitted.energy_j,
        policy: crate::engine::Policy::Robust,
        bound: service.tenant_bound(tenant).unwrap_or_default(),
        diagnostics: crate::engine::Diagnostics {
            newton_iters: admitted.newton_iters,
            outer_iters: admitted.outer_iters,
            ..Default::default()
        },
    };
    let report = serve(artifacts_dir, sc, &plan, opts)?;
    Ok((outcome, report))
}

/// Run the serving loop for one scenario + plan on real artifacts.
pub fn serve(
    artifacts_dir: PathBuf,
    sc: &Scenario,
    plan: &Plan,
    opts: &ServeOptions,
) -> Result<ServeReport> {
    let n = sc.n();
    assert_eq!(plan.partition.len(), n);
    let used_points: Vec<usize> = {
        let mut v = plan.partition.clone();
        v.sort_unstable();
        v.dedup();
        v
    };

    // ---- executor thread (owns all PJRT state) ---------------------------
    let (exec_tx, exec_rx) = mpsc::channel::<ExecReq>();
    let model_name = opts.model.clone();
    let max_batch = opts.max_batch;
    let num_blocks: usize = sc.devices[0].model.num_blocks();
    let preload = used_points.clone();
    let exec_handle = std::thread::spawn(move || -> Result<(f64, f64)> {
        let engine = Engine::cpu(&artifacts_dir)?;
        let mut rt = engine.model_runtime(&model_name)?;
        // Pre-compile AND warm-run everything the plan can touch so
        // serving latencies exclude compilation and first-run lazy init.
        for &m in &preload {
            if m > 0 {
                let part = rt.load_part(Role::Device, m, 1)?;
                let zeros = vec![0.0f32; part.input_shape.iter().product()];
                part.run(&zeros)?;
            }
            if m < num_blocks {
                for batch in [1, max_batch] {
                    let part = rt.load_part(Role::Edge, m, batch)?;
                    let zeros = vec![0.0f32; part.input_shape.iter().product()];
                    part.run(&zeros)?;
                }
            }
        }
        let mut dev_acc = Moments::new();
        let mut edge_acc = Moments::new();
        while let Ok(msg) = exec_rx.recv() {
            match msg {
                ExecReq::Device { m, data, reply } => {
                    let t0 = Instant::now();
                    let r = rt.run_device(m, &data);
                    dev_acc.push(t0.elapsed().as_secs_f64());
                    let _ = reply.send(r);
                }
                ExecReq::Edge { m, batch, data, reply } => {
                    let t0 = Instant::now();
                    let r = rt.run_edge(m, batch, &data);
                    edge_acc.push(t0.elapsed().as_secs_f64());
                    let _ = reply.send(r);
                }
                ExecReq::Stop => break,
            }
        }
        Ok((dev_acc.mean(), edge_acc.mean()))
    });

    // ---- batcher thread ---------------------------------------------------
    let (feat_tx, feat_rx) = mpsc::channel::<FeatureMsg>();
    let (done_tx, done_rx) = mpsc::channel::<Completion>();
    let exec_tx_b = exec_tx.clone();
    let window = opts.batch_window;
    let num_blocks_b = num_blocks;
    let done_tx_b = done_tx.clone();
    let batcher = std::thread::spawn(move || {
        // BTreeMap so flush order on disconnect / oldest-deadline scans
        // visit partition points in a fixed order (determinism).
        let mut queues: BTreeMap<usize, Vec<FeatureMsg>> = BTreeMap::new();
        let flush = |m: usize, q: &mut Vec<FeatureMsg>, want: usize| {
            while !q.is_empty() {
                let take = if q.len() >= want { want } else { 1 };
                let group: Vec<FeatureMsg> = q.drain(..take).collect();
                let flat: Vec<f32> =
                    group.iter().flat_map(|g| g.feat.iter().copied()).collect();
                let (rtx, rrx) = mpsc::channel();
                if exec_tx_b
                    .send(ExecReq::Edge { m, batch: take, data: flat, reply: rtx })
                    .is_err()
                {
                    return;
                }
                let _scores = rrx.recv();
                for g in group {
                    let _ = done_tx_b.send(Completion {
                        device: g.device,
                        latency_s: g.started.elapsed().as_secs_f64(),
                        batch: take,
                        deadline_s: g.deadline_s,
                    });
                }
            }
        };
        // Age-based flushing: a queue is flushed as soon as it reaches
        // max_batch OR its *oldest* element has waited for `window`.
        // (A plain recv_timeout(window) is wrong: under continuous
        // arrivals the timeout never fires and sub-full batches starve.)
        loop {
            // deadline of the oldest queued feature across all queues
            let next_flush = queues
                .values()
                .filter_map(|q| q.first())
                .map(|f| f.enqueued + window)
                .min();
            let wait = match next_flush {
                Some(t) => t.saturating_duration_since(Instant::now()),
                None => window,
            };
            let msg = if wait.is_zero() {
                feat_rx.try_recv().map_err(|e| match e {
                    mpsc::TryRecvError::Empty => mpsc::RecvTimeoutError::Timeout,
                    mpsc::TryRecvError::Disconnected => {
                        mpsc::RecvTimeoutError::Disconnected
                    }
                })
            } else {
                feat_rx.recv_timeout(wait)
            };
            match msg {
                Ok(msg) => {
                    if msg.m >= num_blocks_b {
                        // fully-local request: already has its result
                        let _ = done_tx_b.send(Completion {
                            device: msg.device,
                            latency_s: msg.started.elapsed().as_secs_f64(),
                            batch: 1,
                            deadline_s: msg.deadline_s,
                        });
                    } else {
                        let q = queues.entry(msg.m).or_default();
                        q.push(msg);
                    }
                }
                Err(mpsc::RecvTimeoutError::Timeout) => {}
                Err(mpsc::RecvTimeoutError::Disconnected) => {
                    let ms: Vec<usize> = queues.keys().copied().collect();
                    for m in ms {
                        let mut q = queues.remove(&m).unwrap();
                        flush(m, &mut q, max_batch);
                    }
                    break;
                }
            }
            // flush full queues and overdue queues
            let now = Instant::now();
            let due: Vec<usize> = queues
                .iter()
                .filter(|(_, q)| {
                    q.len() >= max_batch
                        || q.first().map(|f| now >= f.enqueued + window).unwrap_or(false)
                })
                .map(|(&m, _)| m)
                .collect();
            for m in due {
                let mut q = queues.remove(&m).unwrap();
                flush(m, &mut q, max_batch);
            }
        }
    });
    drop(done_tx);

    // ---- device agents ----------------------------------------------------
    let t_start = Instant::now();
    let mut agents = Vec::new();
    let mut seed_rng = Rng::new(opts.seed);
    let mut expected_energy = 0.0;
    for i in 0..n {
        let dev = sc.devices[i].clone();
        let m = plan.partition[i];
        let f = plan.freq_ghz[i];
        let b = plan.bandwidth_hz[i];
        expected_energy +=
            dev.energy_mean(m, f, b) * opts.requests_per_device as f64;
        let feat_tx = feat_tx.clone();
        let exec_tx = exec_tx.clone();
        let mut rng = seed_rng.fork(i as u64);
        let reqs = opts.requests_per_device;
        let rate = opts.arrival_rate_hz;
        let scale = opts.time_scale;
        let input_len = 32 * 32 * 3; // CIFAR input
        agents.push(std::thread::spawn(move || {
            let hw = SyntheticHardware::new(dev.model.clone(), Dist::Lognormal);
            for _ in 0..reqs {
                let gap = rng.exponential(rate);
                if scale > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(gap * scale));
                }
                let started = Instant::now();
                let input: Vec<f32> =
                    (0..input_len).map(|_| rng.range(-1.0, 1.0) as f32).collect();
                // local part (real PJRT compute, padded to the DVFS model)
                let feat = if m > 0 {
                    let (rtx, rrx) = mpsc::channel();
                    if exec_tx
                        .send(ExecReq::Device { m, data: input.clone(), reply: rtx })
                        .is_err()
                    {
                        return;
                    }
                    let Ok(Ok(feat)) = rrx.recv() else { return };
                    let virtual_t = hw.sample_t_loc(m, f, &mut rng);
                    let spent = started.elapsed().as_secs_f64();
                    if scale > 0.0 && virtual_t * scale > spent {
                        std::thread::sleep(Duration::from_secs_f64(
                            virtual_t * scale - spent,
                        ));
                    }
                    feat
                } else {
                    input
                };
                // uplink (simulated FDMA share)
                let t_off = dev.uplink.t_off(dev.model.d_bits(m), b);
                if scale > 0.0 {
                    std::thread::sleep(Duration::from_secs_f64(t_off * scale));
                }
                if feat_tx
                    .send(FeatureMsg {
                        device: i,
                        m,
                        feat,
                        started,
                        enqueued: Instant::now(),
                        deadline_s: dev.deadline_s,
                    })
                    .is_err()
                {
                    return;
                }
            }
        }));
    }
    drop(feat_tx);

    // ---- collect ------------------------------------------------------------
    let expected = n * opts.requests_per_device;
    let mut latencies = Vec::with_capacity(expected);
    let mut batch_acc = Moments::new();
    let mut violations = 0usize;
    for c in done_rx {
        // latency compared in scaled time: un-scale so the deadline check
        // is in model time.
        let lat = if opts.time_scale > 0.0 {
            c.latency_s / opts.time_scale
        } else {
            c.latency_s
        };
        if lat > c.deadline_s {
            violations += 1;
        }
        latencies.push(lat);
        batch_acc.push(c.batch as f64);
        if latencies.len() == expected {
            break;
        }
    }
    for a in agents {
        a.join().map_err(|_| anyhow!("device agent panicked"))?;
    }
    // batcher exits when feat senders disconnect and queues drain
    batcher.join().map_err(|_| anyhow!("batcher panicked"))?;
    exec_tx.send(ExecReq::Stop).ok();
    let (dev_exec, edge_exec) =
        exec_handle.join().map_err(|_| anyhow!("executor panicked"))??;

    let wall = t_start.elapsed();
    let mean_latency = latencies.iter().sum::<f64>() / latencies.len().max(1) as f64;
    Ok(ServeReport {
        completed: latencies.len(),
        violations,
        wall_time: wall,
        throughput_rps: latencies.len() as f64 / wall.as_secs_f64(),
        mean_latency_s: mean_latency,
        p50_latency_s: percentile_of(&latencies, 50.0),
        p99_latency_s: percentile_of(&latencies, 99.0),
        mean_batch: batch_acc.mean(),
        total_energy_j: expected_energy,
        mean_device_exec_s: dev_exec,
        mean_edge_exec_s: edge_exec,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::manifest::Manifest;
    use crate::models::ModelProfile;

    fn have_artifacts() -> bool {
        Manifest::default_dir().join("manifest.json").exists()
    }

    fn tiny_scenario() -> (Scenario, Plan) {
        let mut rng = Rng::new(31);
        let sc = Scenario::uniform(&ModelProfile::alexnet_paper(), 3, 10e6, 0.25, 0.05, &mut rng);
        let plan = Plan {
            partition: vec![2, 0, 8],
            bandwidth_hz: vec![3e6, 3e6, 3e6],
            freq_ghz: vec![1.0, 0.5, 1.2],
        };
        (sc, plan)
    }

    #[test]
    fn serve_completes_all_requests() {
        if !have_artifacts() {
            return;
        }
        let (sc, plan) = tiny_scenario();
        let opts = ServeOptions {
            requests_per_device: 6,
            arrival_rate_hz: 200.0,
            time_scale: 0.0, // no sleeps: fast test, pure compute path
            batch_window: Duration::from_millis(2),
            ..Default::default()
        };
        let r = serve(Manifest::default_dir(), &sc, &plan, &opts).unwrap();
        assert_eq!(r.completed, 18);
        assert!(r.throughput_rps > 0.0);
        assert!(r.mean_device_exec_s >= 0.0);
        assert!(r.mean_batch >= 1.0);
    }

    #[test]
    fn serve_batches_under_load() {
        if !have_artifacts() {
            return;
        }
        let mut rng = Rng::new(32);
        let sc =
            Scenario::uniform(&ModelProfile::alexnet_paper(), 6, 10e6, 0.25, 0.05, &mut rng);
        // everyone offloads at the same point -> batchable
        let plan = Plan {
            partition: vec![2; 6],
            bandwidth_hz: vec![1.5e6; 6],
            freq_ghz: vec![1.0; 6],
        };
        let opts = ServeOptions {
            requests_per_device: 16,
            time_scale: 0.0,
            batch_window: Duration::from_millis(30),
            ..Default::default()
        };
        let r = serve(Manifest::default_dir(), &sc, &plan, &opts).unwrap();
        assert_eq!(r.completed, 96);
        assert!(
            r.mean_batch > 1.2,
            "expected batching under load, mean_batch={}",
            r.mean_batch
        );
    }
}
