//! Minimal offline stand-in for the `anyhow` crate (the real crate is not
//! available in this environment).  Implements exactly the API surface
//! this workspace uses: [`Error`], [`Result`], the `anyhow!` / `bail!`
//! macros, and the [`Context`] extension trait.
//!
//! Mirrors the real crate's contract where it matters:
//! * `Error` does **not** implement `std::error::Error` (that would
//!   conflict with the blanket `From<E: Error>` used by `?`);
//! * `?` converts any `E: std::error::Error + Send + Sync + 'static`;
//! * context wraps are prepended to the message, source preserved.

use std::fmt;

/// Boxed dynamic error with a human-readable message chain.
pub struct Error {
    msg: String,
    source: Option<Box<dyn std::error::Error + Send + Sync + 'static>>,
}

impl Error {
    /// Construct from any displayable message.
    pub fn msg<M: fmt::Display>(m: M) -> Error {
        Error { msg: m.to_string(), source: None }
    }

    /// Construct from a concrete error, preserving it as the source.
    pub fn new<E: std::error::Error + Send + Sync + 'static>(e: E) -> Error {
        Error { msg: e.to_string(), source: Some(Box::new(e)) }
    }

    /// Prepend a context line (what the real crate's `.context` does).
    pub fn context<C: fmt::Display>(self, c: C) -> Error {
        Error { msg: format!("{c}: {}", self.msg), source: self.source }
    }

    /// The underlying source error, if one was preserved.
    pub fn source(&self) -> Option<&(dyn std::error::Error + Send + Sync + 'static)> {
        self.source.as_deref()
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Error {
        Error::new(e)
    }
}

/// `anyhow::Result<T>` — `Result` with the boxed error as default.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Attach context to a fallible result.
pub trait Context<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T>;
    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C;
}

impl<T, E: std::error::Error + Send + Sync + 'static> Context<T> for std::result::Result<T, E> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.map_err(|e| Error::new(e).context(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.map_err(|e| Error::new(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display + Send + Sync + 'static>(self, c: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(c))
    }

    fn with_context<C, F>(self, f: F) -> Result<T>
    where
        C: fmt::Display + Send + Sync + 'static,
        F: FnOnce() -> C,
    {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Build an [`Error`] from a format string or a displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($err:expr $(,)?) => {
        $crate::Error::msg($err)
    };
    ($fmt:expr, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
}

/// Early-return with an [`Error`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*))
    };
}

/// Early-return with an [`Error`] when the condition does not hold.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !$cond {
            return Err($crate::Error::msg(format!(
                "condition failed: {}",
                stringify!($cond)
            )));
        }
    };
    ($cond:expr, $($t:tt)*) => {
        if !$cond {
            return Err($crate::anyhow!($($t)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::Other, "boom")
    }

    #[test]
    fn macro_forms() {
        let key = "x";
        let a: Error = anyhow!("flag --{key} needs a value");
        assert_eq!(a.to_string(), "flag --x needs a value");
        let b: Error = anyhow!(String::from("already a string"));
        assert_eq!(b.to_string(), "already a string");
        let c: Error = anyhow!("{} + {}", 1, 2);
        assert_eq!(c.to_string(), "1 + 2");
    }

    #[test]
    fn bail_returns() {
        fn f() -> Result<()> {
            bail!("nope {}", 7);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 7");
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert!(f().unwrap_err().source().is_some());
    }

    #[test]
    fn ensure_checks_condition() {
        fn f(ok: bool) -> Result<u32> {
            ensure!(ok, "wanted {}", true);
            Ok(5)
        }
        assert_eq!(f(true).unwrap(), 5);
        assert_eq!(f(false).unwrap_err().to_string(), "wanted true");
    }

    #[test]
    fn context_prepends() {
        let r: std::result::Result<(), std::io::Error> = Err(io_err());
        let e = r.with_context(|| format!("reading {}", "f.txt")).unwrap_err();
        assert_eq!(e.to_string(), "reading f.txt: boom");
    }
}
