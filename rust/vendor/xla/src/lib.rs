//! Offline stub of the `xla` (xla_extension / PJRT) bindings.
//!
//! The real bindings need the xla_extension shared library, which is not
//! available in this environment.  This stub provides the exact type and
//! method surface `ripra::runtime` compiles against; every entry point
//! that would touch PJRT returns a clean [`Error`] at runtime instead.
//! Artifact-backed tests and benches already gate on the presence of the
//! AOT manifest, so with this stub they skip rather than fail.
//!
//! Swap this path dependency for the real crate in Cargo.toml to run on
//! actual PJRT; no source change is needed in `ripra`.

use std::fmt;

/// PJRT-unavailable (or stubbed-operation) error.
#[derive(Debug, Clone)]
pub struct Error(String);

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error(format!(
        "{what}: PJRT is unavailable (offline xla stub; link the real xla_extension bindings)"
    )))
}

/// Stub PJRT client.  `cpu()` fails: there is no backing runtime.
#[derive(Clone, Debug)]
pub struct PjRtClient;

/// Stub device buffer.
#[derive(Debug)]
pub struct PjRtBuffer;

/// Stub compiled executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable;

/// Stub HLO module proto (parsed from HLO text in the real bindings).
#[derive(Debug)]
pub struct HloModuleProto;

/// Stub XLA computation.
#[derive(Debug)]
pub struct XlaComputation;

/// Stub host literal.
#[derive(Debug)]
pub struct Literal;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        unavailable("PjRtClient::cpu")
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("PjRtClient::compile")
    }

    pub fn buffer_from_host_buffer<T: Copy>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        unavailable("PjRtClient::buffer_from_host_buffer")
    }
}

impl PjRtLoadedExecutable {
    pub fn client(&self) -> PjRtClient {
        PjRtClient
    }

    pub fn execute_b<B: std::borrow::Borrow<PjRtBuffer>>(
        &self,
        _args: &[B],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("PjRtLoadedExecutable::execute_b")
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("PjRtBuffer::to_literal_sync")
    }
}

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

impl Literal {
    pub fn to_tuple1(self) -> Result<Literal> {
        unavailable("Literal::to_tuple1")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("Literal::to_vec")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stub_errors_are_descriptive() {
        let e = PjRtClient::cpu().unwrap_err();
        assert!(e.to_string().contains("PJRT is unavailable"));
    }
}
