//! Fleet-churn end-to-end benchmark: one full discrete-event simulation
//! (events → `Planner::replan` → Monte-Carlo check) per iteration, run
//! sequentially, with the default thread fan-out, and through the
//! sharded `PlannerService` at K ∈ {1, 4, 8} (`fleet_churn_6s_shards*`
//! rows — the sharded-vs-serial speedup in the perf trajectory).
//! Timings plus the run's deterministic health scalars (cache hit rate,
//! warm/cold split, Newton totals, violation excess) merge into
//! `BENCH_planner.json` at the repo root alongside the `alg2_*` planner
//! cases (see EXPERIMENTS.md §Fleet churn and §Service).
//!
//! `cargo bench --bench fleet_churn -- --test` (or `BENCH_SMOKE=1`) runs
//! every case once for CI smoke coverage.

use std::path::Path;
use std::time::Duration;

use ripra::fleet::{self, FleetOptions};
use ripra::util::bench::Bencher;

fn main() {
    let mut bench =
        Bencher::auto().with_window(Duration::from_millis(300), Duration::from_secs(3));

    let base = |threads: usize, shards: usize| FleetOptions {
        n0: 6,
        duration_s: 6.0,
        arrival_rate_hz: 0.5,
        churn: 2.0,
        trials: 200,
        seed: 0xF1EE7,
        threads,
        shards,
        ..FleetOptions::default()
    };
    let cases = [
        ("fleet_churn_6s_seq", base(1, 0)),
        ("fleet_churn_6s_par", base(0, 0)),
        ("fleet_churn_6s_shards1", base(0, 1)),
        ("fleet_churn_6s_shards4", base(0, 4)),
        ("fleet_churn_6s_shards8", base(0, 8)),
    ];

    for (name, opts) in cases {
        bench.bench(name, || {
            fleet::run(&opts)
                .map(|r| r.metrics.summary().newton_total as f64)
                .unwrap_or(f64::NAN)
        });
        // Health scalars from one deterministic run (identical to every
        // timed iteration — same seed, no wall-clock in the metrics).
        if let Ok(rep) = fleet::run(&opts) {
            let s = rep.metrics.summary();
            bench.attach(name, "events", s.events as f64);
            bench.attach(name, "accepted", s.accepted as f64);
            bench.attach(name, "cache_hit_rate", s.cache_hit_rate);
            bench.attach(name, "warm_replans", s.warm_replans as f64);
            bench.attach(name, "cold_solves", s.cold_solves as f64);
            bench.attach(name, "newton_total", s.newton_total as f64);
            bench.attach(name, "mean_energy_j", s.mean_energy_j);
            if let Some(w) = s.worst_violation_excess {
                bench.attach(name, "worst_violation_excess", w);
            }
        }
    }

    bench.write_json(Path::new("BENCH_planner.json")).expect("writing BENCH_planner.json");
    println!("wrote BENCH_planner.json");
}
