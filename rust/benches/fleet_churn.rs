//! Fleet-churn end-to-end benchmark: one full discrete-event simulation
//! (events → `Planner::replan` → Monte-Carlo check) per iteration, run
//! sequentially and with the default thread fan-out.  Timings plus the
//! run's deterministic health scalars (cache hit rate, warm/cold split,
//! Newton totals, violation excess) merge into `BENCH_planner.json` at
//! the repo root alongside the `alg2_*` planner cases — the perf
//! trajectory future PRs diff against (see EXPERIMENTS.md §Fleet churn).

use std::path::Path;
use std::time::Duration;

use ripra::fleet::{self, FleetOptions};
use ripra::util::bench::Bencher;

fn main() {
    let mut bench =
        Bencher::new().with_window(Duration::from_millis(300), Duration::from_secs(3));

    for (tag, threads) in [("seq", 1usize), ("par", 0usize)] {
        let opts = FleetOptions {
            n0: 6,
            duration_s: 6.0,
            arrival_rate_hz: 0.5,
            churn: 2.0,
            trials: 200,
            seed: 0xF1EE7,
            threads,
            ..FleetOptions::default()
        };
        let name = format!("fleet_churn_6s_{tag}");
        bench.bench(&name, || {
            fleet::run(&opts)
                .map(|r| r.metrics.summary().newton_total as f64)
                .unwrap_or(f64::NAN)
        });
        // Health scalars from one deterministic run (identical to every
        // timed iteration — same seed, no wall-clock in the metrics).
        if let Ok(rep) = fleet::run(&opts) {
            let s = rep.metrics.summary();
            bench.attach(&name, "events", s.events as f64);
            bench.attach(&name, "accepted", s.accepted as f64);
            bench.attach(&name, "cache_hit_rate", s.cache_hit_rate);
            bench.attach(&name, "warm_replans", s.warm_replans as f64);
            bench.attach(&name, "cold_solves", s.cold_solves as f64);
            bench.attach(&name, "newton_total", s.newton_total as f64);
            if let Some(w) = s.worst_violation_excess {
                bench.attach(&name, "worst_violation_excess", w);
            }
        }
    }

    bench.write_json(Path::new("BENCH_planner.json")).expect("writing BENCH_planner.json");
    println!("wrote BENCH_planner.json");
}
