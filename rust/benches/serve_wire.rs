//! Wire-serving benchmark: a fresh `Server` on an ephemeral loopback
//! port per iteration, replaying one deterministic `loadgen` script
//! (admissions, churn deltas, plan/stats probes, shutdown) end to end
//! over TCP.  Timings land in `BENCH_planner.json` as `serve_wire_*`
//! cases, and the canonical serving rows — `serve_p50_us`,
//! `serve_p99_us`, `serve_mean_us`, `shed_rate` — merge in under
//! `benches.serve_wire` via [`LoadGenReport::write_bench_rows`] (see
//! EXPERIMENTS.md §Serving for the methodology).
//!
//! `cargo bench --bench serve_wire -- --test` (or `BENCH_SMOKE=1`) runs
//! every case once for CI smoke coverage.

use std::path::Path;
use std::time::Duration;

use ripra::fleet::loadgen::{self, LoadGenOptions, LoadGenReport};
use ripra::service::{Server, ServerOptions};
use ripra::util::bench::Bencher;

/// One full script replay against a fresh server; returns the report.
fn replay(opts: &LoadGenOptions, shards: usize, queue_capacity: usize) -> LoadGenReport {
    let server = Server::bind(&ServerOptions {
        listen: "127.0.0.1:0".into(),
        shards,
        queue_capacity,
        ..ServerOptions::default()
    })
    .expect("bind loopback server");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || server.run());
    // rate 0.0: no pacing sleeps — the bench measures service latency,
    // not the generator's clock.
    let report = loadgen::run_script(&addr, &loadgen::script(opts), 0.0)
        .expect("loadgen replay");
    handle.join().expect("server thread").expect("clean shutdown");
    report
}

/// Throughput-mode replay (`loadgen::run`, connections > 1): one fresh
/// server hosts both the sequential baseline and the concurrent batched
/// phase, so `single_epm` and `throughput_epm` in the returned report
/// are measured back to back against identical serving state.
fn replay_concurrent(opts: &LoadGenOptions, shards: usize, queue_capacity: usize) -> LoadGenReport {
    let server = Server::bind(&ServerOptions {
        listen: "127.0.0.1:0".into(),
        shards,
        queue_capacity,
        ..ServerOptions::default()
    })
    .expect("bind loopback server");
    let addr = server.local_addr().expect("local addr").to_string();
    let handle = std::thread::spawn(move || server.run());
    let report = loadgen::run(&addr, opts).expect("loadgen throughput replay");
    handle.join().expect("server thread").expect("clean shutdown");
    report
}

fn main() {
    let mut bench =
        Bencher::auto().with_window(Duration::from_millis(300), Duration::from_secs(3));

    let base = LoadGenOptions {
        tenants: 2,
        devices: 3,
        events: 48,
        probe_every: 8,
        seed: 0x5E17E,
        ..LoadGenOptions::default()
    };
    let cases = [
        ("serve_wire_shards1", 1usize, 64usize),
        ("serve_wire_shards4", 4, 64),
        // A deliberately tiny queue: the shed path (drop + drain +
        // back-off hint) is on the measured path.
        ("serve_wire_q2_shed", 1, 2),
    ];

    for (name, shards, queue) in cases {
        bench.bench(name, || replay(&base, shards, queue).requests as f64);
        // Latency/shed rows from one deterministic replay (the script is
        // a pure function of the seed; only wall latencies vary).
        let report = replay(&base, shards, queue);
        bench.attach(name, "requests", report.requests as f64);
        bench.attach(name, "sheds", report.sheds as f64);
        bench.attach(name, "errors", report.errors as f64);
        bench.attach(name, "serve_p50_us", report.p50_us);
        bench.attach(name, "serve_p99_us", report.p99_us);
        bench.attach(name, "shed_rate", report.shed_rate);
    }

    // Throughput mode: 4 connections, 16-event batch frames, plus the
    // in-run sequential baseline — the sharded/batched speedup case.
    let conc = LoadGenOptions { connections: 4, batch: 16, events: 256, ..base.clone() };
    let name = "serve_wire_c4_b16";
    bench.bench(name, || replay_concurrent(&conc, 4, 64).requests as f64);
    let report = replay_concurrent(&conc, 4, 64);
    bench.attach(name, "requests", report.requests as f64);
    bench.attach(name, "serve_throughput_epm", report.throughput_epm);
    bench.attach(name, "serve_single_epm", report.single_epm);
    bench.attach(name, "serve_batch_p99_us", report.batch_p99_us);
    bench.attach(name, "serve_connections", report.connections as f64);

    bench.write_json(Path::new("BENCH_planner.json")).expect("writing BENCH_planner.json");
    // The canonical `benches.serve_wire` row merges in on top: the
    // single-replay latency fields plus the throughput comparison
    // (`serve_throughput_epm` next to `serve_single_epm`/`serve_speedup`
    // from the same run, same server).
    report
        .write_bench_rows(Path::new("BENCH_planner.json"))
        .expect("merging serve rows into BENCH_planner.json");
    println!("wrote BENCH_planner.json");
}
