//! PJRT runtime benchmarks: per-part execution latency on the real AOT
//! artifacts (device/edge sides, batch 1 vs 8) and the batching payoff —
//! the serving hot path that `coordinator` drives.

use std::time::Duration;

use ripra::models::manifest::Manifest;
use ripra::runtime::Engine;
use ripra::util::bench::Bencher;

fn main() {
    let dir = Manifest::default_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping pjrt_runtime bench: run `make artifacts` first");
        return;
    }
    let engine = Engine::cpu(&dir).expect("engine");
    let mut bench =
        Bencher::new().with_window(Duration::from_millis(200), Duration::from_millis(800));

    for name in ["alexnet", "resnet152"] {
        let mut rt = engine.model_runtime(name).expect("runtime");
        let blocks = rt.model().num_blocks;
        let mid = blocks / 2;

        let in_len: usize = 32 * 32 * 3;
        let input = vec![0.5f32; in_len];
        // full edge chain (m=0) and split sides
        bench.bench(&format!("{name}_edge_full_b1"), || {
            rt.run_edge(0, 1, &input).unwrap().len()
        });
        bench.bench(&format!("{name}_device_m{mid}_b1"), || {
            rt.run_device(mid, &input).unwrap().len()
        });
        let feat_len: usize = rt.model().points[mid].feat_shape.iter().product();
        let feat = vec![0.25f32; feat_len];
        bench.bench(&format!("{name}_edge_m{mid}_b1"), || {
            rt.run_edge(mid, 1, &feat).unwrap().len()
        });
        let feat8 = vec![0.25f32; feat_len * 8];
        let r8 = bench
            .bench(&format!("{name}_edge_m{mid}_b8"), || {
                rt.run_edge(mid, 8, &feat8).unwrap().len()
            })
            .clone();
        let r1 = bench
            .bench(&format!("{name}_edge_m{mid}_b1_again"), || {
                rt.run_edge(mid, 1, &feat).unwrap().len()
            })
            .clone();
        let speedup = 8.0 * r1.median.as_secs_f64() / r8.median.as_secs_f64();
        println!("  -> {name} batching payoff: batch-8 is {speedup:.2}x the per-item throughput of batch-1");
    }
}
