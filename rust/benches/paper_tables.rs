//! One bench per paper evaluation artifact: times the regeneration of
//! each table/figure series at Quick effort (the Full versions are run by
//! `ripra figure all` and recorded in EXPERIMENTS.md).

use std::time::Duration;

use ripra::figures::{self, Effort};
use ripra::util::bench::Bencher;

fn main() {
    let mut bench =
        Bencher::new().with_window(Duration::from_millis(0), Duration::from_millis(1)).with_max_iters(1);
    for name in ["table3", "fig1", "fig6", "fig7", "fig9", "fig10", "fig12", "fig13a", "fig13c", "fig14a"] {
        bench.bench(&format!("generate_{name}"), || {
            figures::run(name, None, Effort::Quick).map(|t| t.len()).unwrap_or(0)
        });
    }
}
