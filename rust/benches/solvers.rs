//! Solver micro/meso benchmarks: the optimizer's hot paths.
//!
//! - Cholesky + barrier Newton micro-costs (the IPT inner loop)
//! - resource allocation: joint barrier vs dual decomposition (ablation
//!   for DESIGN.md §6 — the O(N^3) vs O(N log^2) trade)
//! - per-device PCCP solve (Algorithm 1 unit of work)

use ripra::linalg::{Cholesky, Matrix};
use ripra::models::ModelProfile;
use ripra::optim::types::{Policy, Scenario};
use ripra::optim::{pccp, resource};
use ripra::util::bench::Bencher;
use ripra::util::rng::Rng;

fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
    let mut b = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            b[(i, j)] = rng.normal();
        }
    }
    let mut a = b.matmul(&b.transpose());
    a.add_diag(n as f64);
    a
}

fn main() {
    let mut bench = Bencher::new();
    let mut rng = Rng::new(1);

    for n in [16usize, 64, 128] {
        let a = random_spd(n, &mut rng);
        let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        bench.bench(&format!("cholesky_factor_{n}"), || {
            Cholesky::factor(&a).unwrap()
        });
        let c = Cholesky::factor(&a).unwrap();
        bench.bench(&format!("cholesky_solve_{n}"), || c.solve(&rhs));
    }

    for n in [4usize, 12, 24] {
        let mut srng = Rng::new(100 + n as u64);
        let sc = Scenario::uniform(
            &ModelProfile::alexnet_paper(),
            n,
            10e6 * (n as f64 / 12.0).max(1.0),
            0.20,
            0.04,
            &mut srng,
        );
        let partition = vec![7usize; n];
        bench.bench(&format!("resource_barrier_n{n}"), || {
            resource::solve(&sc, &partition, Policy::Robust).unwrap().energy
        });
        bench.bench(&format!("resource_dual_n{n}"), || {
            resource::solve_dual(&sc, &partition, Policy::Robust).unwrap().energy
        });
    }

    {
        let mut srng = Rng::new(7);
        let sc =
            Scenario::uniform(&ModelProfile::alexnet_paper(), 1, 10e6, 0.22, 0.04, &mut srng);
        let opts = pccp::PccpOptions::default();
        bench.bench("pccp_device_solve", || {
            pccp::solve_device(&sc.devices[0], 1.0, 3e6, &opts, None).unwrap().m
        });
    }
}
