//! Solver micro/meso benchmarks: the optimizer's hot paths.
//!
//! - Cholesky factor/solve, allocating vs in-place (the IPT inner loop)
//! - resource allocation: joint barrier vs dual decomposition (ablation
//!   for DESIGN.md §6 — the O(N^3) vs O(N log^2) trade)
//! - per-device PCCP solve (Algorithm 1 unit of work) and the scenario
//!   fan-out, sequential vs parallel
//!
//! Results merge into `BENCH_planner.json` (see EXPERIMENTS.md §Perf).

use std::path::Path;

use ripra::linalg::{Cholesky, Matrix};
use ripra::models::ModelProfile;
use ripra::optim::types::{Policy, Scenario};
use ripra::optim::{pccp, resource};
use ripra::risk::RiskBound;
use ripra::util::bench::Bencher;
use ripra::util::rng::Rng;

fn random_spd(n: usize, rng: &mut Rng) -> Matrix {
    let mut b = Matrix::zeros(n, n);
    for i in 0..n {
        for j in 0..n {
            b[(i, j)] = rng.normal();
        }
    }
    let mut a = b.matmul(&b.transpose());
    a.add_diag(n as f64);
    a
}

fn main() {
    // `-- --test` / BENCH_SMOKE=1 runs every case once (CI smoke).
    let mut bench = Bencher::auto();
    let mut rng = Rng::new(1);

    for n in [16usize, 64, 128] {
        let a = random_spd(n, &mut rng);
        let rhs: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        bench.bench(&format!("cholesky_factor_{n}"), || Cholesky::factor(&a).unwrap());
        let mut ws = Cholesky::empty();
        bench.bench(&format!("cholesky_factor_into_{n}"), || {
            ws.factor_into(&a).unwrap();
            ws.l()[(0, 0)] // observe the factor so the stores survive opt
        });
        let c = Cholesky::factor(&a).unwrap();
        bench.bench(&format!("cholesky_solve_{n}"), || c.solve(&rhs));
        let mut out = vec![0.0; n];
        bench.bench(&format!("cholesky_solve_into_{n}"), || {
            c.solve_into(&rhs, &mut out);
            out[0]
        });
    }

    for n in [4usize, 12, 24] {
        let mut srng = Rng::new(100 + n as u64);
        let sc = Scenario::uniform(
            &ModelProfile::alexnet_paper(),
            n,
            10e6 * (n as f64 / 12.0).max(1.0),
            0.20,
            0.04,
            &mut srng,
        );
        let partition = vec![7usize; n];
        bench.bench(&format!("resource_barrier_n{n}"), || {
            resource::solve(&sc, &partition, Policy::ROBUST).unwrap().energy
        });
        // warm start from the previous optimum (Algorithm 2's steady state)
        let prev = resource::solve(&sc, &partition, Policy::ROBUST).unwrap();
        bench.bench(&format!("resource_barrier_warm_n{n}"), || {
            resource::solve_warm(&sc, &partition, Policy::ROBUST, Some(&prev)).unwrap().energy
        });
        bench.bench(&format!("resource_dual_n{n}"), || {
            resource::solve_dual(&sc, &partition, Policy::ROBUST).unwrap().energy
        });
    }

    {
        let mut srng = Rng::new(7);
        let sc =
            Scenario::uniform(&ModelProfile::alexnet_paper(), 1, 10e6, 0.22, 0.04, &mut srng);
        let opts = pccp::PccpOptions::default();
        bench.bench("pccp_device_solve", || {
            pccp::solve_device(&sc.devices[0], 1.0, 3e6, &opts, None, RiskBound::Ecr).unwrap().m
        });
    }

    {
        // scenario-level PCCP: the embarrassingly parallel fan-out
        let mut srng = Rng::new(9);
        let n = 12usize;
        let sc =
            Scenario::uniform(&ModelProfile::alexnet_paper(), n, 10e6, 0.25, 0.05, &mut srng);
        let f = vec![1.1; n];
        let b = vec![10e6 / 6.0; n];
        let seq = pccp::PccpOptions { threads: 1, ..pccp::PccpOptions::default() };
        let par = pccp::PccpOptions::default();
        bench.bench(&format!("pccp_scenario_n{n}_seq"), || {
            pccp::solve(&sc, &f, &b, &seq, None, RiskBound::Ecr).unwrap().newton_iters
        });
        bench.bench(&format!("pccp_scenario_n{n}_par"), || {
            pccp::solve(&sc, &f, &b, &par, None, RiskBound::Ecr).unwrap().newton_iters
        });
    }

    bench.write_json(Path::new("BENCH_planner.json")).expect("writing BENCH_planner.json");
    println!("wrote BENCH_planner.json");
}
