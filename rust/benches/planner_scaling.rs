//! Algorithm-2 end-to-end scaling through the engine facade: regenerates
//! the numbers behind Fig. 9 (PCCP iterations) and Fig. 11 (runtime vs
//! N) for the sequential baseline (`threads = 1`) and the parallel
//! fan-out side by side, plus the engine's service-path wins: plan-cache
//! hits and incremental replanning (device join/leave) vs a cold solve.
//! Timings and iteration counts merge into `BENCH_planner.json` at the
//! repo root — the perf trajectory future PRs diff against (see
//! EXPERIMENTS.md §Perf).

use std::path::Path;
use std::time::Duration;

use ripra::channel::Uplink;
use ripra::engine::{PlanRequest, PlannerBuilder, Policy, ScenarioDelta};
use ripra::models::ModelProfile;
use ripra::optim::{Device, Scenario};
use ripra::util::bench::Bencher;
use ripra::util::rng::Rng;

fn main() {
    // `-- --test` / BENCH_SMOKE=1 runs every case once (CI smoke).
    let mut bench =
        Bencher::auto().with_window(Duration::from_millis(300), Duration::from_secs(3));

    for model in [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()] {
        let (b0, d, eps) = ripra::figures::default_setting(&model.name);
        for n in [5usize, 10, 20, 30] {
            let b = b0 * (n as f64 / 12.0).max(1.0);
            let mut rng = Rng::new(0xBE + n as u64);
            let sc = Scenario::uniform(&model, n, b, d, eps, &mut rng);
            for (tag, threads) in [("seq", 1usize), ("par", 0usize)] {
                // Cache off: every timed iteration is a genuine solve.
                let mut planner =
                    PlannerBuilder::new().threads(threads).cache_capacity(0).build();
                let name = format!("alg2_{}_n{n}_{tag}", model.name);
                bench.bench(&name, || {
                    planner
                        .plan(&PlanRequest::new(sc.clone(), Policy::Robust))
                        .map(|o| o.energy)
                        .unwrap_or(f64::NAN)
                });
                // Iteration counts for the Fig. 9/11 reproduction (one
                // deterministic solve — identical to every timed run).
                if let Ok(o) = planner.plan(&PlanRequest::new(sc.clone(), Policy::Robust)) {
                    bench.attach(&name, "newton_iters", o.diagnostics.newton_iters as f64);
                    bench.attach(&name, "outer_iters", o.diagnostics.outer_iters as f64);
                    bench.attach(&name, "avg_pccp_iters", o.diagnostics.avg_pccp_iters);
                    bench.attach(&name, "energy", o.energy);
                }
            }
            let median = |tag: &str| {
                bench
                    .results()
                    .iter()
                    .find(|r| r.name == format!("alg2_{}_n{n}_{tag}", model.name))
                    .map(|r| r.median.as_secs_f64())
            };
            if let (Some(s), Some(p)) = (median("seq"), median("par")) {
                println!("  -> {} n={n}: parallel speedup {:.2}x", model.name, s / p);
            }
        }
    }

    // ---- engine service paths: cache hits and incremental replanning ----
    {
        let model = ModelProfile::alexnet_paper();
        let (b0, d, eps) = ripra::figures::default_setting(&model.name);
        let n = 12usize;
        let mut rng = Rng::new(0xCAFE);
        // Headroom over the N=12 paper setting so the join replan (13
        // devices) stays feasible.
        let sc = Scenario::uniform(&model, n, b0 * 1.25, d + 0.02, eps, &mut rng);
        let joiner = sc.devices[0].clone();

        let mut planner = PlannerBuilder::new().build();
        planner.plan(&PlanRequest::new(sc.clone(), Policy::Robust)).expect("seed solve");
        bench.bench("engine_cache_hit_n12", || {
            planner
                .plan(&PlanRequest::new(sc.clone(), Policy::Robust))
                .map(|o| o.energy)
                .unwrap_or(f64::NAN)
        });

        // Each iteration replans a join then the matching leave, so the
        // planner returns to the N-device scenario every time.
        bench.bench("engine_replan_join_leave_n12", || {
            let a = planner.replan(&ScenarioDelta::Join(joiner.clone())).expect("join");
            let b = planner.replan(&ScenarioDelta::Leave(n)).expect("leave");
            a.energy + b.energy
        });
        if let Ok(o) = planner.replan(&ScenarioDelta::Join(joiner.clone())) {
            let newton = o.diagnostics.newton_iters as f64;
            bench.attach("engine_replan_join_leave_n12", "join_newton_iters", newton);
            let _ = planner.replan(&ScenarioDelta::Leave(n));
        }
    }

    // ---- risk-bound family: energy at fixed eps across bounds -----------
    // One row per chance-constraint transform on the identical scenario,
    // so BENCH_planner.json records how much energy each bound's margin
    // costs (ecr = the distribution-free default; the others are tighter
    // under stronger assumptions).
    {
        let model = ModelProfile::alexnet_paper();
        let (b0, d, eps) = ripra::figures::default_setting(&model.name);
        let mut rng = Rng::new(0xB0BD);
        let sc = Scenario::uniform(&model, 12, b0, d, eps, &mut rng);
        for bound in ripra::risk::BOUND_FAMILY {
            // Cache off: every timed iteration is a genuine solve.
            let mut planner = PlannerBuilder::new().cache_capacity(0).build();
            let name = format!("bound_energy_{}", bound.name());
            bench.bench(&name, || {
                planner
                    .plan(&PlanRequest::new(sc.clone(), Policy::Robust).with_bound(bound))
                    .map(|o| o.energy)
                    .unwrap_or(f64::NAN)
            });
            if let Ok(o) =
                planner.plan(&PlanRequest::new(sc.clone(), Policy::Robust).with_bound(bound))
            {
                bench.attach(&name, "energy", o.energy);
                bench.attach(&name, "margin_sum_s", o.diagnostics.margins_s.iter().sum::<f64>());
                bench.attach(&name, "newton_iters", o.diagnostics.newton_iters as f64);
            }
        }
    }

    // ---- cohort-compressed planning ------------------------------------
    // `classes` distinct channel classes, each replicated `reps` times —
    // the fingerprint-clustered geometry the cohort path targets.
    let clustered = |classes: usize, reps: usize, b: f64, deadline: f64| {
        let model = ModelProfile::alexnet_paper();
        let mut devices = Vec::with_capacity(classes * reps);
        for c in 0..classes {
            let dev = Device {
                model: model.clone(),
                uplink: Uplink::from_gain_db(-80.0 - 0.5 * c as f64),
                deadline_s: deadline,
                risk: 0.05,
            };
            devices.extend(std::iter::repeat_n(dev, reps));
        }
        Scenario { devices, total_bandwidth_hz: b }
    };

    // 1M devices in 32 cohorts: bucketing and replication are the O(n)
    // parts, the solve itself is O(cohorts).  The relaxed deadline keeps
    // the all-local point reachable, so the fleet stays feasible at any
    // per-device bandwidth share.
    {
        let sc = clustered(32, 31_250, 12.5e6, 2.0);
        let req = PlanRequest::new(sc, Policy::Robust);
        let mut planner = PlannerBuilder::new().cohorts(true).cache_capacity(0).build();
        bench.bench("cohort_1m_devices", || {
            planner.plan(&req).map(|o| o.energy).unwrap_or(f64::NAN)
        });
        if let Ok(o) = planner.plan(&req) {
            bench.attach("cohort_1m_devices", "devices", 1_000_000.0);
            bench.attach("cohort_1m_devices", "cohorts", o.diagnostics.cohorts as f64);
            bench.attach("cohort_1m_devices", "cohort_gap", o.diagnostics.cohort_gap);
            bench.attach("cohort_1m_devices", "energy", o.energy);
        }
    }

    // Cohort vs exact Algorithm 2 on a fleet small enough to solve both
    // ways: the attached gap is the acceptance number (target < 1%).
    {
        let sc = clustered(4, 10, 10e6, 0.25);
        let req = PlanRequest::new(sc, Policy::Robust);
        let mut cohort = PlannerBuilder::new().cohorts(true).cache_capacity(0).build();
        let mut exact = PlannerBuilder::new().cache_capacity(0).build();
        let name = "cohort_vs_exact_gap";
        bench.bench(name, || cohort.plan(&req).map(|o| o.energy).unwrap_or(f64::NAN));
        if let (Ok(c), Ok(e)) = (cohort.plan(&req), exact.plan(&req)) {
            bench.attach(name, "gap", (c.energy - e.energy).abs() / e.energy);
            bench.attach(name, "cohort_energy", c.energy);
            bench.attach(name, "exact_energy", e.energy);
            bench.attach(name, "cohorts", c.diagnostics.cohorts as f64);
        }
    }

    bench.write_json(Path::new("BENCH_planner.json")).expect("writing BENCH_planner.json");
    println!("wrote BENCH_planner.json");
}
