//! Algorithm-2 end-to-end scaling: regenerates the numbers behind Fig. 9
//! (PCCP iterations) and Fig. 11 (runtime vs N) as benchmark output.

use std::time::Duration;

use ripra::models::ModelProfile;
use ripra::optim::{alternating, AlternatingOptions, Scenario};
use ripra::util::bench::Bencher;
use ripra::util::rng::Rng;

fn main() {
    let mut bench =
        Bencher::new().with_window(Duration::from_millis(300), Duration::from_secs(3));
    for model in [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()] {
        let (b0, d, eps) = ripra::figures::default_setting(&model.name);
        for n in [5usize, 10, 20, 30] {
            let b = b0 * (n as f64 / 12.0).max(1.0);
            let mut rng = Rng::new(0xBE + n as u64);
            let sc = Scenario::uniform(&model, n, b, d, eps, &mut rng);
            let r = bench.bench(&format!("alg2_{}_n{n}", model.name), || {
                alternating::solve(&sc, &AlternatingOptions::default(), None)
                    .map(|r| r.energy)
                    .unwrap_or(f64::NAN)
            });
            let _ = r;
        }
    }
}
