//! Algorithm-2 end-to-end scaling: regenerates the numbers behind Fig. 9
//! (PCCP iterations) and Fig. 11 (runtime vs N) as benchmark output, for
//! the sequential baseline (`threads = 1`) and the parallel fan-out side
//! by side.  Timings plus iteration counts are merged into
//! `BENCH_planner.json` at the repo root — the perf trajectory future PRs
//! diff against (see EXPERIMENTS.md §Perf for the methodology).

use std::path::Path;
use std::time::Duration;

use ripra::models::ModelProfile;
use ripra::optim::pccp::PccpOptions;
use ripra::optim::{alternating, AlternatingOptions, Scenario};
use ripra::util::bench::Bencher;
use ripra::util::rng::Rng;

fn main() {
    let mut bench =
        Bencher::new().with_window(Duration::from_millis(300), Duration::from_secs(3));
    let seq = AlternatingOptions {
        threads: 1,
        pccp: PccpOptions { threads: 1, ..PccpOptions::default() },
        ..Default::default()
    };
    let par = AlternatingOptions::default(); // threads = 0: all cores

    for model in [ModelProfile::alexnet_paper(), ModelProfile::resnet152_paper()] {
        let (b0, d, eps) = ripra::figures::default_setting(&model.name);
        for n in [5usize, 10, 20, 30] {
            let b = b0 * (n as f64 / 12.0).max(1.0);
            let mut rng = Rng::new(0xBE + n as u64);
            let sc = Scenario::uniform(&model, n, b, d, eps, &mut rng);
            for (tag, opts) in [("seq", &seq), ("par", &par)] {
                let name = format!("alg2_{}_n{n}_{tag}", model.name);
                bench.bench(&name, || {
                    alternating::solve(&sc, opts, None).map(|r| r.energy).unwrap_or(f64::NAN)
                });
                // Iteration counts for the Fig. 9/11 reproduction (one
                // deterministic solve — identical to every timed run).
                if let Ok(r) = alternating::solve(&sc, opts, None) {
                    bench.attach(&name, "newton_iters", r.newton_iters as f64);
                    bench.attach(&name, "outer_iters", r.outer_iters as f64);
                    bench.attach(&name, "avg_pccp_iters", r.avg_pccp_iters);
                    bench.attach(&name, "energy", r.energy);
                }
            }
            let median = |tag: &str| {
                bench
                    .results()
                    .iter()
                    .find(|r| r.name == format!("alg2_{}_n{n}_{tag}", model.name))
                    .map(|r| r.median.as_secs_f64())
            };
            if let (Some(s), Some(p)) = (median("seq"), median("par")) {
                println!("  -> {} n={n}: parallel speedup {:.2}x", model.name, s / p);
            }
        }
    }

    bench.write_json(Path::new("BENCH_planner.json")).expect("writing BENCH_planner.json");
    println!("wrote BENCH_planner.json");
}
